"""Quickstart: build a sparse matrix, convert to pJDS, run spMVM.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import formats as F, matrices as M, perf_model as PM
from repro.kernels import ops


def main():
    # 1. A sparse matrix with strongly varying row lengths (sAMG analogue)
    m = M.samg(scale=0.002)
    print(f"matrix: {m.shape}, nnz={m.nnz}, N_nzr={m.n_nzr:.1f}")

    # 2. Convert: ELLPACK pads to the global max row length; pJDS sorts
    #    rows and pads per 128-row block (paper Fig. 1)
    ell = F.csr_to_ell(m, row_align=128)
    pjds = F.csr_to_pjds(m, b_r=128)
    print(f"ELLPACK stored elements: {F.storage_elements(ell):>10,}")
    print(f"pJDS    stored elements: {F.storage_elements(pjds):>10,}")
    print(f"data reduction: {100 * F.data_reduction_vs_ellpack(m):.1f}% "
          "(paper Table 1 measured 19-71% on its matrices)")

    # 3. spMVM in the permuted basis (paper Listing 2)
    rng = np.random.default_rng(0)
    x = rng.standard_normal(m.shape[0]).astype(np.float32)
    dev = ops.to_device_pjds(pjds)
    xp = jnp.asarray(pjds.permute(x))
    y = pjds.unpermute(np.asarray(ops.pjds_matvec(dev, xp)))
    y_ref = np.array([x[m.indices[m.indptr[i]:m.indptr[i + 1]]]
                      @ m.data[m.indptr[i]:m.indptr[i + 1]]
                      for i in range(m.n_rows)])
    print(f"max |y - y_ref| = {np.abs(y - y_ref).max():.2e}")

    # 4. Same through the Pallas TPU kernel (interpret mode on CPU)
    y_k = pjds.unpermute(np.asarray(
        ops.pjds_matvec(dev, xp, backend="kernel")))
    print(f"pallas kernel max err = {np.abs(y_k - y_ref).max():.2e}")

    # 5. What the paper's model says about this matrix on an accelerator
    lo, hi = PM.alpha_range(m.n_nzr)
    thresh = PM.n_nzr_upper_for_link_penalty(
        PM.TPU_V5E.hbm_bw, PM.TPU_V5E.ici_bw, alpha=lo)
    print(f"Eq.3 threshold N_nzr <= {thresh:.0f}: this matrix "
          f"(N_nzr={m.n_nzr:.0f}) is "
          + ("LINK-DOMINATED -> keep it resident, avoid host traffic"
             if m.n_nzr < thresh else "compute-worthy"))


if __name__ == "__main__":
    main()
