"""Quickstart: wrap a sparse matrix as a SparseOperator, run y = A x.

The operator protocol (DESIGN.md §8) hides storage format, permutation
and padding: ``operator(m) @ x`` picks a format from row-length
statistics, converts once, and computes in the original basis.  The
same object gives the transpose (``op.T``) and gradients for free.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import formats as F, matrices as M, perf_model as PM
from repro.core.operator import operator


def main():
    # 1. A sparse matrix with strongly varying row lengths (sAMG analogue)
    m = M.samg(scale=0.002)
    print(f"matrix: {m.shape}, nnz={m.nnz}, N_nzr={m.n_nzr:.1f}")

    # 2. Storage: ELLPACK pads to the global max row length; pJDS sorts
    #    rows and pads per 128-row block (paper Fig. 1)
    ell = F.csr_to_ell(m, row_align=128)
    pjds = F.csr_to_pjds(m, b_r=128)
    print(f"ELLPACK stored elements: {F.storage_elements(ell):>10,}")
    print(f"pJDS    stored elements: {F.storage_elements(pjds):>10,}")
    print(f"data reduction: {100 * F.data_reduction_vs_ellpack(m):.1f}% "
          "(paper Table 1 measured 19-71% on its matrices)")

    # 3. The one-line API: a SparseOperator.  format="auto" prices the
    #    candidates (DESIGN.md §5) and backend="auto" picks kernel/ref.
    op = operator(m)
    print(f"operator(m) chose format={op.fmt!r}, shape={op.shape}")

    rng = np.random.default_rng(0)
    x = rng.standard_normal(m.shape[0]).astype(np.float32)
    y = np.asarray(op @ x)                       # original basis, y = A x
    y_ref = np.array([x[m.indices[m.indptr[i]:m.indptr[i + 1]]]
                      @ m.data[m.indptr[i]:m.indptr[i + 1]]
                      for i in range(m.n_rows)])
    print(f"max |op @ x - y_ref| = {np.abs(y - y_ref).max():.2e}")

    # 4. The transpose view costs nothing to build: blocked formats run
    #    A^T x as a scatter-accumulate over the same stored indices
    yt = np.asarray(op.T @ y_ref)
    yt_ref = F.csr_to_dense(m).T @ y_ref
    scale = max(np.abs(yt_ref).max(), 1.0)
    print(f"rel max |op.T @ y - ref| = "
          f"{np.abs(yt - yt_ref).max() / scale:.2e}")

    # 5. And it is differentiable: jax.grad flows through the stored
    #    values (op.with_values) and through x — d(w.Ax)/dx = A^T w
    w = rng.standard_normal(m.shape[0]).astype(np.float32)
    gx = jax.grad(lambda v: jnp.vdot(jnp.asarray(w), op @ v))(jnp.asarray(x))
    print(f"grad wrt x == A^T w: max err = "
          f"{np.abs(np.asarray(gx) - F.csr_to_dense(m).T @ w).max():.2e}")

    # 6. What the paper's model says about this matrix on an accelerator
    lo, hi = PM.alpha_range(m.n_nzr)
    thresh = PM.n_nzr_upper_for_link_penalty(
        PM.TPU_V5E.hbm_bw, PM.TPU_V5E.ici_bw, alpha=lo)
    print(f"Eq.3 threshold N_nzr <= {thresh:.0f}: this matrix "
          f"(N_nzr={m.n_nzr:.0f}) is "
          + ("LINK-DOMINATED -> keep it resident, avoid host traffic"
             if m.n_nzr < thresh else "compute-worthy"))


if __name__ == "__main__":
    main()
