"""End-to-end training driver: ~100M-param qwen2.5-family model for a few
hundred steps on CPU with the production substrate (AdamW + WSD,
checkpoints, auto-resume, straggler watchdog).

    PYTHONPATH=src python examples/train_lm.py [--steps 200] [--arch ID]
"""
import argparse
import dataclasses

import jax

from repro import configs
from repro.models.api import build_model
from repro.train.optimizer import AdamW
from repro.train.schedules import wsd
from repro.train.step import make_train_step
from repro.train.loop import train
from repro.data.pipeline import for_config


def hundred_m(arch: str) -> configs.ArchConfig:
    """Scale the chosen architecture family down to ~100M params."""
    cfg = configs.get(arch)
    return dataclasses.replace(
        cfg, n_layers=8, d_model=640, n_heads=10,
        n_kv_heads=max(1, min(cfg.n_kv_heads, 5)), d_ff=2048,
        head_dim=64, vocab=32_000, window=min(cfg.window, 256),
        n_experts=min(cfg.n_experts, 8) if cfg.n_experts else 0,
        top_k=min(cfg.top_k, 2) if cfg.top_k else 0,
        d_inner=1024 if cfg.d_inner else 0,
        dt_rank=32 if cfg.dt_rank else 0,
        enc_layers=2 if cfg.enc_layers else 0,
        frontend_seq=64 if cfg.frontend_seq else 0,
        param_dtype="float32", activation_dtype="float32",
        name=f"{arch}-100m")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-14b")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt", default="/tmp/repro_train_ckpt")
    args = ap.parse_args()

    cfg = hundred_m(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"arch={cfg.name} params={n/1e6:.1f}M "
          f"steps={args.steps} batch={args.batch} seq={args.seq}")

    opt = AdamW(lr_fn=wsd(3e-4, warmup=20, stable=args.steps // 2,
                          decay=args.steps // 3))
    opt_state = opt.init(params)
    step = jax.jit(make_train_step(model, opt, q_chunk=128, k_chunk=128))
    data = for_config(cfg, batch=args.batch, seq=args.seq)

    params, opt_state, hist = train(
        step_fn=step, params=params, opt_state=opt_state, data=data,
        steps=args.steps, ckpt_dir=args.ckpt, ckpt_every=50, log_every=10)
    print(f"final loss {hist['losses'][-1]:.4f} "
          f"(from {hist['losses'][0]:.4f}); "
          f"stragglers flagged: {len(hist['stragglers'])}")


if __name__ == "__main__":
    main()
