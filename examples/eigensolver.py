"""Lanczos eigensolver on a Holstein-Hubbard-like Hamiltonian (HMEp).

The paper's motivating workload (§1.3, and 'application of our results
to a production-grade eigensolver' in the outlook): extremal eigenvalues
of a sparse quantum Hamiltonian, where spMVM dominates the runtime and
the whole Krylov iteration runs in the pJDS permuted basis (§2.1).

    PYTHONPATH=src python examples/eigensolver.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.core import formats as F, matrices as M, solvers as S
from repro.kernels import ops


def main():
    raw = M.hmep(scale=0.001)
    # symmetrise (physical Hamiltonians are Hermitian)
    d = F.csr_to_dense(raw)
    h = F.csr_from_dense(((d + d.T) / 2).astype(np.float32))
    print(f"Hamiltonian: {h.shape}, nnz={h.nnz}, N_nzr={h.n_nzr:.1f}")

    pj = F.csr_to_pjds(h, b_r=128)
    print(f"pJDS vs ELLPACK reduction: "
          f"{100 * F.data_reduction_vs_ellpack(h):.1f}%")
    dev = ops.to_device_pjds(pj)
    mv = jax.jit(lambda v: ops.pjds_matvec(dev, v))

    rng = np.random.default_rng(0)
    v0 = jnp.asarray(pj.permute(rng.standard_normal(h.n_rows)
                                .astype(np.float32)))
    # permute ONCE before the iteration, work permuted throughout (§2.1)
    al, be = S.lanczos(mv, v0, m=100)
    ritz = S.tridiag_eigvals(al, be)
    print(f"Lanczos Ritz extremes: lam_min~{ritz.min():.4f} "
          f"lam_max~{ritz.max():.4f}")

    ref = np.linalg.eigvalsh(F.csr_to_dense(h))
    print(f"dense reference:       lam_min={ref.min():.4f} "
          f"lam_max={ref.max():.4f}")
    print(f"extremal eigenvalue error: "
          f"{abs(ritz.max() - ref.max()):.2e}")


if __name__ == "__main__":
    main()
