"""Lanczos eigensolver on a Holstein-Hubbard-like Hamiltonian (HMEp).

The paper's motivating workload (§1.3, and 'application of our results
to a production-grade eigensolver' in the outlook): extremal eigenvalues
of a sparse quantum Hamiltonian, where spMVM dominates the runtime.
Since PR 3 the Krylov iteration runs against the SparseOperator
protocol — ``operator(h)`` picks the storage format and keeps every
permutation internal, so the solver sees the original basis end-to-end.
The Ritz estimate is then polished with shift-inverted inverse
iteration, whose inner SPD solves go through ``repro.solve``.

    PYTHONPATH=src python examples/eigensolver.py
"""
import numpy as np
import jax.numpy as jnp

import repro
from repro.core import formats as F, matrices as M, solvers as S
from repro.core.operator import operator


def main():
    raw = M.hmep(scale=0.001)
    # symmetrise (physical Hamiltonians are Hermitian)
    d = F.csr_to_dense(raw)
    h = F.csr_from_dense(((d + d.T) / 2).astype(np.float32))
    print(f"Hamiltonian: {h.shape}, nnz={h.nnz}, N_nzr={h.n_nzr:.1f}")

    print(f"pJDS vs ELLPACK reduction: "
          f"{100 * F.data_reduction_vs_ellpack(h):.1f}%")
    op = operator(h, b_r=128)
    print(f"operator chose format={op.fmt!r}")

    rng = np.random.default_rng(0)
    v0 = jnp.asarray(rng.standard_normal(h.n_rows).astype(np.float32))
    # the operator hides the permuted basis — no permute/unpermute dance
    al, be = S.lanczos(op, v0, m=100)
    ritz = S.tridiag_eigvals(al, be)
    print(f"Lanczos Ritz extremes: lam_min~{ritz.min():.4f} "
          f"lam_max~{ritz.max():.4f}")

    # polish the extremal Ritz value with inverse iteration: for a shift
    # sigma just above lam_max, (sigma*I - H) is SPD, so each inverse-
    # iteration step is a CG solve through the repro.solve front door
    sigma = float(ritz.max()) + 0.02
    dh = F.csr_to_dense(h)
    shifted = operator(
        F.csr_from_dense((sigma * np.eye(h.n_rows, dtype=np.float32) - dh)))
    # warm start: shifted power steps bias v toward the lam_max eigenvector
    v = v0 / jnp.linalg.norm(v0)
    for _ in range(20):
        v = op @ v + 7.0 * v
        v = v / jnp.linalg.norm(v)
    # 1e-4: (sigma*I - H) is near-singular BY DESIGN, so its f32
    # residual floor sits around 1e-5 — far above what the recurrence
    # claims.  Certification (DESIGN.md §11) would demote a 1e-8
    # request to a typed failure; inverse iteration only needs the
    # direction anyway.
    for _ in range(3):
        sol = repro.solve(shifted, v, method="cg", tol=1e-4, maxiter=4000)
        v = sol.x / jnp.linalg.norm(sol.x)
    lam = float(v @ (op @ v))            # Rayleigh quotient, original basis
    print(f"inverse-iteration polish:  lam_max~{lam:.6f} "
          f"(cg iters/step ~{int(sol.iters)})")

    ref = np.linalg.eigvalsh(dh)
    print(f"dense reference:       lam_min={ref.min():.4f} "
          f"lam_max={ref.max():.4f}")
    print(f"extremal eigenvalue error: Lanczos "
          f"{abs(ritz.max() - ref.max()):.2e}, polished "
          f"{abs(lam - ref.max()):.2e}")


if __name__ == "__main__":
    main()
