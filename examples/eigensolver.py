"""Lanczos eigensolver on a Holstein-Hubbard-like Hamiltonian (HMEp).

The paper's motivating workload (§1.3, and 'application of our results
to a production-grade eigensolver' in the outlook): extremal eigenvalues
of a sparse quantum Hamiltonian, where spMVM dominates the runtime.
Since PR 3 the Krylov iteration runs against the SparseOperator
protocol — ``operator(h)`` picks the storage format and keeps every
permutation internal, so the solver sees the original basis end-to-end.

    PYTHONPATH=src python examples/eigensolver.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import formats as F, matrices as M, solvers as S
from repro.core.operator import operator


def main():
    raw = M.hmep(scale=0.001)
    # symmetrise (physical Hamiltonians are Hermitian)
    d = F.csr_to_dense(raw)
    h = F.csr_from_dense(((d + d.T) / 2).astype(np.float32))
    print(f"Hamiltonian: {h.shape}, nnz={h.nnz}, N_nzr={h.n_nzr:.1f}")

    print(f"pJDS vs ELLPACK reduction: "
          f"{100 * F.data_reduction_vs_ellpack(h):.1f}%")
    op = operator(h, b_r=128)
    print(f"operator chose format={op.fmt!r}")

    rng = np.random.default_rng(0)
    v0 = jnp.asarray(rng.standard_normal(h.n_rows).astype(np.float32))
    # the operator hides the permuted basis — no permute/unpermute dance
    al, be = S.lanczos(op, v0, m=100)
    ritz = S.tridiag_eigvals(al, be)
    print(f"Lanczos Ritz extremes: lam_min~{ritz.min():.4f} "
          f"lam_max~{ritz.max():.4f}")

    ref = np.linalg.eigvalsh(F.csr_to_dense(h))
    print(f"dense reference:       lam_min={ref.min():.4f} "
          f"lam_max={ref.max():.4f}")
    print(f"extremal eigenvalue error: "
          f"{abs(ritz.max() - ref.max()):.2e}")


if __name__ == "__main__":
    main()
