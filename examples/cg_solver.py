"""Distributed CG solve over the shard_map spMVM (paper §3 workload).

Spawns itself with 8 host devices, partitions a Poisson system row-wise,
and runs CG with each of the paper's three communication modes,
reporting iteration counts, solve time, and the halo width.

    PYTHONPATH=src python examples/cg_solver.py
"""
import os
import sys

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import formats as F, matrices as M, dist_spmv as D
from repro.core import solvers as S
from repro.launch.mesh import make_host_mesh


def main():
    n_dev = len(jax.devices())
    mesh = make_host_mesh(n_dev)
    m = M.poisson_2d(96, 96)
    print(f"Poisson system: {m.shape}, nnz={m.nnz}, devices={n_dev}")

    dist = D.partition_csr(m, n_dev, b_r=128)
    print(f"row partition: {dist.n_loc} rows/device, halo_w={dist.halo_w}, "
          f"halo traffic {dist.comm_bytes_per_device(4)/1e3:.1f} kB/dev/spMVM "
          f"gathered ({dist.comm_bytes_per_device(4, halo='full')/1e3:.1f} kB "
          f"full-slice)")

    rng = np.random.default_rng(0)
    b = np.zeros(dist.n_global_pad, np.float32)
    b[:m.n_rows] = rng.standard_normal(m.n_rows)
    bj = jax.device_put(jnp.asarray(b), jax.NamedSharding(mesh, P("data")))

    for mode in ("vector", "naive", "overlap"):
        mv = D.make_dist_matvec(dist, mesh, "data", mode)
        t0 = time.perf_counter()
        res = S.cg(mv, bj, maxiter=4000, tol=1e-6)
        jax.block_until_ready(res.x)
        dt = time.perf_counter() - t0
        print(f"mode={mode:8s} iters={int(res.iters):4d} "
              f"rel_res={float(res.residual):.2e} wall={dt:.2f}s")

    # block-CG: 4 right-hand sides through the multi-RHS operator at once
    k = 4
    bk = np.zeros((dist.n_global_pad, k), np.float32)
    bk[:m.n_rows] = rng.standard_normal((m.n_rows, k))
    bkj = jax.device_put(jnp.asarray(bk),
                         jax.NamedSharding(mesh, P("data", None)))
    mm = D.make_dist_matmat(dist, mesh, "data", "overlap")
    t0 = time.perf_counter()
    bres = S.block_cg(mm, bkj, maxiter=4000, tol=1e-6)
    jax.block_until_ready(bres.x)
    dt = time.perf_counter() - t0
    print(f"block-CG  k={k}   iters={int(bres.iters):4d} "
          f"rel_res={float(np.max(np.asarray(bres.residual))):.2e} "
          f"wall={dt:.2f}s")

    # verify against dense solve
    mv = D.make_dist_matvec(dist, mesh, "data", "overlap")
    res = S.cg(mv, bj, maxiter=4000, tol=1e-8)
    x = np.asarray(res.x)[:m.n_rows]
    err = np.linalg.norm(F.csr_to_dense(m) @ x - b[:m.n_rows]) \
        / np.linalg.norm(b[:m.n_rows])
    print(f"true relative residual: {err:.2e}")


if __name__ == "__main__":
    main()
