"""Distributed solves over the mesh SparseOperator (paper §3 workload).

Spawns itself with 8 host devices, partitions a Poisson system row-wise
with ``dist_operator`` — the SAME protocol object a single device uses —
and runs ``repro.solve`` CG with each of the paper's three
communication modes, then
Jacobi-preconditioned CG, block-CG (4 RHS per matrix stream), and
BiCGStab on a non-symmetric perturbation (whose transpose partition
backs ``op.T``).

    PYTHONPATH=src python examples/cg_solver.py
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import time
import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

import repro
from repro.core import formats as F, matrices as M
from repro.core.operator import dist_operator
from repro.launch.mesh import make_host_mesh


def main():
    n_dev = len(jax.devices())
    mesh = make_host_mesh(n_dev)
    m = M.poisson_2d(96, 96)
    print(f"Poisson system: {m.shape}, nnz={m.nnz}, devices={n_dev}")

    op = dist_operator(m, mesh, b_r=128)
    dist = op.dist
    print(f"row partition: {dist.n_loc} rows/device, halo_w={dist.halo_w}, "
          f"halo traffic {dist.comm_bytes_per_device(4)/1e3:.1f} kB/dev/spMVM "
          f"gathered ({dist.comm_bytes_per_device(4, halo='full')/1e3:.1f} kB "
          f"full-slice)")

    rng = np.random.default_rng(0)
    b = np.zeros(op.shape[0], np.float32)
    b[:m.n_rows] = rng.standard_normal(m.n_rows)
    bj = jax.device_put(jnp.asarray(b), jax.NamedSharding(mesh, P("data")))

    for mode in ("vector", "naive", "overlap"):
        # reuse the partition already built for `op` — only the
        # communication schedule changes
        op_m = dist_operator(op.dist, mesh, mode=mode)
        t0 = time.perf_counter()
        res = repro.solve(op_m, bj, method="cg", maxiter=4000,
                          tol=1e-6)
        jax.block_until_ready(res.x)
        dt = time.perf_counter() - t0
        print(f"mode={mode:8s} iters={int(res.iters):4d} "
              f"rel_res={float(res.residual):.2e} wall={dt:.2f}s")

    # Jacobi-preconditioned CG: same solver source, M from op.diagonal()
    res_j = repro.solve(op, bj, method="cg", precond="jacobi",
                        maxiter=4000, tol=1e-6)
    print(f"jacobi-pcg    iters={int(res_j.iters):4d} "
          f"rel_res={float(res_j.residual):.2e}")

    # block-CG: 4 right-hand sides through the operator's matmat at once
    k = 4
    bk = np.zeros((op.shape[0], k), np.float32)
    bk[:m.n_rows] = rng.standard_normal((m.n_rows, k))
    bkj = jax.device_put(jnp.asarray(bk),
                         jax.NamedSharding(mesh, P("data", None)))
    t0 = time.perf_counter()
    # 2e-6, not 1e-6: "converged" is CERTIFIED against the true
    # residual (DESIGN.md §11), and the worst of the 4 columns lands
    # just above 1e-6 at the f32 accuracy floor for this system
    bres = repro.solve(op, bkj, method="block_cg", maxiter=4000,
                       tol=2e-6)
    jax.block_until_ready(bres.x)
    dt = time.perf_counter() - t0
    print(f"block-CG  k={k}   iters={int(bres.iters):4d} "
          f"rel_res={float(np.max(np.asarray(bres.residual))):.2e} "
          f"wall={dt:.2f}s")

    # BiCGStab on a non-symmetric system, distributed: a convection-
    # diffusion operator (Poisson + upwind skew on the x-neighbors) —
    # the transpose partition built by dist_operator also powers op_n.T
    mn = M.convection_poisson(96, 96, beta=0.5)
    op_n = dist_operator(mn, mesh, b_r=128)
    nres = repro.solve(op_n, bj, method="bicgstab", maxiter=4000,
                       tol=1e-6)
    x = np.asarray(nres.x)[:m.n_rows]
    err = np.linalg.norm(F.csr_to_dense(mn) @ x - b[:m.n_rows]) \
        / np.linalg.norm(b[:m.n_rows])
    print(f"bicgstab (non-sym) iters={int(nres.iters):4d} true_res={err:.2e}")

    # verify CG against dense solve (1e-6 is what f32 storage + f32
    # carriers certify on this system; the recurrence would happily
    # CLAIM 1e-8, which is exactly the lie certification exists to stop)
    res = repro.solve(op, bj, method="cg", maxiter=4000, tol=1e-6)
    x = np.asarray(res.x)[:m.n_rows]
    err = np.linalg.norm(F.csr_to_dense(m) @ x - b[:m.n_rows]) \
        / np.linalg.norm(b[:m.n_rows])
    print(f"true relative residual: {err:.2e}")


if __name__ == "__main__":
    main()
