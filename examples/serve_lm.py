"""Batched serving example: continuous batching with the Engine.

    PYTHONPATH=src python examples/serve_lm.py
"""
import time

import numpy as np
import jax

from repro import configs
from repro.models.api import build_model
from repro.serve.engine import Engine, Request


def main():
    cfg = configs.smoke("gemma3-4b")   # local:global pattern incl. windows
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    eng = Engine(model, params, batch_slots=4, max_len=128)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (4 + 3 * i,))
                    .astype(np.int32),
                    max_new=8)
            for i in range(6)]
    t0 = time.perf_counter()
    eng.run(reqs, max_ticks=500)
    dt = time.perf_counter() - t0
    total_new = sum(len(r.out) for r in reqs)
    for r in reqs:
        print(f"req {r.rid}: prompt_len={len(r.prompt)} -> {r.out}")
    print(f"{total_new} tokens in {dt:.2f}s "
          f"({total_new/dt:.1f} tok/s on CPU, batched over 4 slots)")


if __name__ == "__main__":
    main()
