"""Multi-tenant solve serving: registry + continuous-batching scheduler.

Three tenants admit their SPD systems into one OperatorRegistry (each
resident operator keyed by structural fingerprint; a second admit of
the same structure with new coefficients swaps values WITHOUT
reconverting).  A SolveScheduler coalesces everyone's right-hand sides
into certified block-CG groups, sheds requests whose deadline expired
in queue, and keeps per-request latency in its metrics ledger.

    PYTHONPATH=src python examples/serve_solver.py
"""
import dataclasses

import numpy as np

from repro.core import matrices as M
from repro.serve import OperatorRegistry, SolveRequest, SolveScheduler


def main():
    rng = np.random.default_rng(0)
    registry = OperatorRegistry(capacity=4, tune="off")
    tenants = {
        "heat": registry.admit(M.poisson_2d(16, 16)),
        "mesh": registry.admit(M.samg(scale=0.0005)),
        "grid": registry.admit(M.poisson_2d(20, 20)),
    }
    sched = SolveScheduler(registry, slots=4, maxiter=2000, tol=1e-6)

    # a burst of traffic: four RHS per tenant, one with a deadline that
    # has no hope (shed at tick time, never dispatched)
    reqs = []
    for name, entry in tenants.items():
        for k in range(4):
            reqs.append(SolveRequest(
                rid=len(reqs),
                b=rng.standard_normal(entry.shape[0]).astype(np.float32),
                tenant=entry.key,
                deadline_s=0.0 if (name == "mesh" and k == 3) else None))
    for r in reqs:
        sched.submit(r)
    sched.run_until_drained()

    for r in reqs:
        serve = r.diagnostics.get("serve", {})
        print(f"req {r.rid:2d} tenant={serve.get('tenant', '?')[:8]} "
              f"status={r.status:9s} batch_k={serve.get('batch_k', '-')}")

    # same structure, new coefficients: zero-reconversion value swap
    heat = M.poisson_2d(16, 16)
    heat2 = dataclasses.replace(
        heat, data=(heat.data * 2.0).astype(heat.data.dtype))
    entry = registry.admit(heat2)
    print(f"value swap on resident structure: swaps={entry.swaps} "
          f"version={entry.version} (no reconversion, no re-tune)")

    snap = sched.metrics.snapshot()
    print(f"batches={snap['counters']['batches']} "
          f"converged={snap['counters']['converged']} "
          f"shed={snap['counters']['shed']} "
          f"occupancy_mean={snap['occupancy']['mean_s']:.2f} "
          f"p50_total={snap['total_s']['p50_s'] * 1e3:.1f}ms")
    assert snap["counters"]["converged"] == len(reqs) - 1
    assert snap["counters"]["shed"] == 1


if __name__ == "__main__":
    main()
