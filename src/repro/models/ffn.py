"""Dense FFN (SiLU/GeLU gated or plain) + the SparseFFN hook.

``sparse_ffn_density < 1`` swaps the dense matmuls for pJDS spMM — the
paper's storage format as a first-class LM feature (see ``repro.sparse``).
The dense path is what the dry-run/roofline exercises; SparseFFN is an
inference-time compression demonstrated by examples and benchmarks.

``ffn_apply`` also accepts a param dict whose leaves are
``SparseLinear`` operators (``sparse.sparsify_ffn_params``): the sparse
layers are registered pytrees, so such params flow through ``jit``
unchanged — any unstacked FFN call site can be swapped to blocked-sparse
storage without touching the model code around it.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as C
from .sharding import shard


def ffn_init(key, cfg, dtype, d_ff: int | None = None) -> C.Init:
    d = cfg.d_model
    ff = d_ff if d_ff is not None else cfg.d_ff
    gated = cfg.act in ("silu", "geglu")
    ks = C.split_keys(key, 3)
    p, s = {}, {}
    p["w1"], s["w1"] = C.dense_init(ks[0], d, ff, (None, "model"), dtype)
    if gated:
        p["w3"], s["w3"] = C.dense_init(ks[1], d, ff, (None, "model"), dtype)
    p["w2"], s["w2"] = C.dense_init(ks[2], ff, d, ("model", None), dtype)
    return p, s


def ffn_apply(p, cfg, x):
    if not isinstance(p["w1"], dict):
        # SparseLinear leaves: the operator-protocol spMM path
        from repro.sparse.sparse_ffn import sparse_ffn_apply
        return shard(sparse_ffn_apply(p, cfg, x), "batch", None, None)
    act = C.activation(cfg.act)
    h = C.dense_apply(p["w1"], x)
    h = shard(h, "batch", None, "model")
    if "w3" in p:
        h = act(h) * C.dense_apply(p["w3"], x)
    else:
        h = act(h)
    y = C.dense_apply(p["w2"], h)
    return shard(y, "batch", None, None)
