"""LM assembly: embedding -> (period-scanned) block stack -> chunked loss.

Scan-over-layers with HETEROGENEOUS layer patterns: the layer pattern
(e.g. recurrentgemma's (recurrent, recurrent, local)) defines a PERIOD;
params for each period position are stacked over periods and the whole
stack is one ``lax.scan`` whose body applies one period.  Layers that
break uniformity (deepseek's leading dense-FFN layer; pattern remainder
at the bottom of the stack) are hoisted out as unrolled prefix/suffix.
This keeps the HLO O(1) in depth — essential both for real compile times
at scale and for the 40-cell dry-run on this box.

The loss is computed CHUNKED over the sequence so the (B, S, vocab)
logits tensor is never materialised (gemma3's 262k vocab at 65k
tokens/device would be 2+ GiB/device even sharded 16-way).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import common as C
from . import blocks as B
from .sharding import shard
from .unroll import scan_unroll

Pytree = object


@dataclasses.dataclass(frozen=True)
class StackPlan:
    prefix_kinds: tuple          # unrolled leading layers (absolute kinds)
    prefix_moe: tuple
    period_kinds: tuple          # one period
    period_moe: tuple
    n_periods: int
    suffix_kinds: tuple
    suffix_moe: tuple


def make_plan(cfg, n_layers: int, *, force_dense_pattern: bool = False,
              moe_ok: bool = True) -> StackPlan:
    pat = ("global",) if force_dense_pattern else cfg.layer_pattern
    k = len(pat)
    kinds = [pat[i % k] for i in range(n_layers)]
    moe = [bool(cfg.n_experts) and moe_ok and i >= cfg.first_k_dense
           for i in range(n_layers)]
    prefix = cfg.first_k_dense if (cfg.n_experts and moe_ok) else 0
    # prefix must also absorb pattern misalignment (never happens for the
    # assigned archs: MoE archs are uniform-pattern)
    n_scan = n_layers - prefix
    n_periods = n_scan // k
    rem = n_scan % k
    return StackPlan(
        prefix_kinds=tuple(kinds[:prefix]),
        prefix_moe=tuple(moe[:prefix]),
        period_kinds=tuple(kinds[prefix:prefix + k]),
        period_moe=tuple(moe[prefix:prefix + k]),
        n_periods=n_periods,
        suffix_kinds=tuple(kinds[n_layers - rem:]),
        suffix_moe=tuple(moe[n_layers - rem:]),
    )


def _stack_trees(trees):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


def _spec_add_leading(specs):
    return jax.tree.map(
        lambda s: (None, *s) if isinstance(s, tuple) else s, specs,
        is_leaf=lambda s: isinstance(s, tuple))


def stack_init(key, cfg, plan: StackPlan, *, cross: bool, dtype) -> C.Init:
    p, s = {"prefix": [], "suffix": []}, {"prefix": [], "suffix": []}
    keys = C.split_keys(key, len(plan.prefix_kinds) + len(plan.suffix_kinds)
                        + plan.n_periods * len(plan.period_kinds) + 1)
    ki = 0
    for kind, m in zip(plan.prefix_kinds, plan.prefix_moe):
        bp, bs = B.block_init(keys[ki], cfg, kind, use_moe=m, cross=cross,
                              dtype=dtype); ki += 1
        p["prefix"].append(bp); s["prefix"].append(bs)
    period_ps = []
    period_ss = None
    for _ in range(plan.n_periods):
        pp, ss = {}, {}
        for j, (kind, m) in enumerate(zip(plan.period_kinds, plan.period_moe)):
            pp[f"b{j}"], ss[f"b{j}"] = B.block_init(
                keys[ki], cfg, kind, use_moe=m, cross=cross, dtype=dtype)
            ki += 1
        period_ps.append(pp); period_ss = ss
    if plan.n_periods:
        p["periods"] = _stack_trees(period_ps)
        s["periods"] = _spec_add_leading(period_ss)
    for kind, m in zip(plan.suffix_kinds, plan.suffix_moe):
        bp, bs = B.block_init(keys[ki], cfg, kind, use_moe=m, cross=cross,
                              dtype=dtype); ki += 1
        p["suffix"].append(bp); s["suffix"].append(bs)
    return p, s


def stack_apply_train(params, cfg, plan: StackPlan, x, positions, *,
                      causal=True, memory=None, remat=True,
                      q_chunk=512, k_chunk=512):
    aux_total = jnp.float32(0)
    apply = functools.partial(B.block_apply_train, cfg=cfg,
                              positions=positions, causal=causal,
                              memory=memory, q_chunk=q_chunk, k_chunk=k_chunk)
    for bp, kind in zip(params["prefix"], plan.prefix_kinds):
        x, aux = apply(bp, kind=kind, x=x)
        aux_total += aux

    if plan.n_periods:
        def body(x, per):
            aux_p = jnp.float32(0)
            for j, kind in enumerate(plan.period_kinds):
                x, aux = apply(per[f"b{j}"], kind=kind, x=x)
                aux_p += aux
            return x, aux_p
        if remat:
            body = jax.checkpoint(body)
        x, auxs = jax.lax.scan(body, x, params["periods"],
                               unroll=scan_unroll())
        aux_total += auxs.sum()

    for bp, kind in zip(params["suffix"], plan.suffix_kinds):
        x, aux = apply(bp, kind=kind, x=x)
        aux_total += aux
    return x, aux_total


def stack_apply_prefill(params, cfg, plan: StackPlan, x, positions, *,
                        max_len: int, memory=None, cache_dtype,
                        q_chunk=512, k_chunk=512):
    """Forward + build decode caches.  Returns (x, cache pytree)."""
    cache = {"prefix": [], "suffix": []}
    cross = memory is not None

    def one(bp, kind, x):
        return _block_prefill(bp, cfg, kind, x, positions, max_len=max_len,
                              memory=memory, cache_dtype=cache_dtype,
                              q_chunk=q_chunk, k_chunk=k_chunk)

    for bp, kind in zip(params["prefix"], plan.prefix_kinds):
        x, c = one(bp, kind, x)
        cache["prefix"].append(c)
    if plan.n_periods:
        def body(x, per):
            cs = {}
            for j, kind in enumerate(plan.period_kinds):
                x, cs[f"b{j}"] = one(per[f"b{j}"], kind, x)
            return x, cs
        x, cache["periods"] = jax.lax.scan(body, x, params["periods"],
                                           unroll=scan_unroll())
    for bp, kind in zip(params["suffix"], plan.suffix_kinds):
        x, c = one(bp, kind, x)
        cache["suffix"].append(c)
    return x, cache


def _block_prefill(p, cfg, kind, x, positions, *, max_len, memory,
                   cache_dtype, q_chunk, k_chunk):
    from . import attention as A
    from . import ssm as SSM
    from . import rglru as RG
    if kind == "mamba":
        h, st = SSM.mamba_apply_train(p["mamba"], cfg,
                                      C.rmsnorm(p["ln"], x, cfg.norm_eps))
        st = {"conv": st["conv"].astype(cache_dtype), "h": st["h"]}
        return x + h, st
    h = C.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "recurrent":
        h, st = RG.rglru_apply_train(p["rec"], cfg, h)
        st = {"conv": st["conv"].astype(cache_dtype), "h": st["h"]}
        x = x + h
        h2, _ = B._mix_ffn(p, cfg, C.rmsnorm(p["ln2"], x, cfg.norm_eps))
        return x + h2, st
    h_attn, (k_new, v_new) = A.attn_apply_train(
        p["attn"], cfg, h, positions, is_local=(kind == "local"),
        causal=True, q_chunk=q_chunk, k_chunk=k_chunk)
    x = x + h_attn
    c = A.attn_cache_from_prefill(cfg, k_new.astype(cache_dtype),
                                  v_new.astype(cache_dtype),
                                  is_local=(kind == "local"), max_len=max_len)
    if "xattn" in p and memory is not None:
        hx = C.rmsnorm(p["lnx"], x, cfg.norm_eps)
        hd = cfg.resolved_head_dim
        xk = C.dense_apply(p["xattn"]["wk"], memory).reshape(
            *memory.shape[:2], cfg.n_kv_heads, hd)
        xv = C.dense_apply(p["xattn"]["wv"], memory).reshape(
            *memory.shape[:2], cfg.n_kv_heads, hd)
        q = C.dense_apply(p["xattn"]["wq"], hx).reshape(
            *hx.shape[:2], cfg.n_heads, hd)
        o = A.flash_attention(q, xk, xv, causal=False, window=None,
                              q_chunk=q_chunk, k_chunk=k_chunk)
        x = x + C.dense_apply(p["xattn"]["wo"], o.reshape(*hx.shape[:2], -1))
        c = {"self": c, "xk": xk.astype(cache_dtype),
             "xv": xv.astype(cache_dtype)}
    h2, _ = B._mix_ffn(p, cfg, C.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + h2, c


def stack_apply_decode(params, cfg, plan: StackPlan, x, cache, pos):
    """One decode step through the stack. Returns (x, new_cache)."""
    new_cache = {"prefix": [], "suffix": []}
    for bp, c, kind in zip(params["prefix"], cache["prefix"],
                           plan.prefix_kinds):
        x, nc = B.block_apply_decode(bp, cfg, kind, x, c, pos)
        new_cache["prefix"].append(nc)
    if plan.n_periods:
        def body(x, per_and_cache):
            per, cc = per_and_cache
            ncs = {}
            for j, kind in enumerate(plan.period_kinds):
                x, ncs[f"b{j}"] = B.block_apply_decode(
                    per[f"b{j}"], cfg, kind, x, cc[f"b{j}"], pos)
            return x, ncs
        x, new_cache["periods"] = jax.lax.scan(
            body, x, (params["periods"], cache["periods"]),
            unroll=scan_unroll())
    for bp, c, kind in zip(params["suffix"], cache["suffix"],
                           plan.suffix_kinds):
        x, nc = B.block_apply_decode(bp, cfg, kind, x, c, pos)
        new_cache["suffix"].append(nc)
    return x, new_cache


def stack_cache_init(cfg, plan: StackPlan, batch: int, max_len: int, *,
                     cross: bool, dtype):
    def mk(kind):
        return B.block_cache_init(cfg, kind, batch, max_len, cross=cross,
                                  dtype=dtype)
    cache = {"prefix": [mk(k) for k in plan.prefix_kinds],
             "suffix": [mk(k) for k in plan.suffix_kinds]}
    if plan.n_periods:
        per = {f"b{j}": mk(k) for j, k in enumerate(plan.period_kinds)}
        cache["periods"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x, (plan.n_periods, *x.shape)).copy(),
            per)
    return cache


def stack_cache_specs(cfg, plan: StackPlan, *, cross: bool):
    def mk(kind):
        return B.block_cache_specs(cfg, kind, cross=cross)
    specs = {"prefix": [mk(k) for k in plan.prefix_kinds],
             "suffix": [mk(k) for k in plan.suffix_kinds]}
    if plan.n_periods:
        per = {f"b{j}": mk(k) for j, k in enumerate(plan.period_kinds)}
        specs["periods"] = _spec_add_leading(per)
    return specs


# --------------------------------------------------------------------------
# Loss head
# --------------------------------------------------------------------------
def _pick_chunk(t: int, target: int) -> int:
    """Largest divisor of t that is <= target."""
    for c in range(min(target, t), 0, -1):
        if t % c == 0:
            return c
    return 1


def chunked_xent(x, embed_w, labels, chunk: int = 512,
                 vocab: int | None = None):
    """Cross-entropy without materialising full logits.

    x: (B, T, D) final hiddens for the SCORED positions; labels: (B, T)
    int32 with -1 = masked.  embed_w: (V_pad, D); ``vocab`` masks the
    padded tail out of the logsumexp.  Returns mean nll.
    """
    b, t, d = x.shape
    v_pad = embed_w.shape[0]
    pad_mask = (jnp.arange(v_pad) >= vocab) if (vocab and vocab < v_pad) \
        else None
    from .unroll import cost_mode
    if cost_mode():     # single chunk: same flops, no loop to undercount
        chunk = t
    chunk = _pick_chunk(t, chunk)
    n = t // chunk
    xc = jnp.moveaxis(x.reshape(b, n, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, n, chunk), 1, 0)

    def step(carry, xs):
        tot, cnt = carry
        xch, lch = xs
        logits = jnp.einsum("bcd,vd->bcv", xch.astype(jnp.float32),
                            embed_w.astype(jnp.float32))
        if pad_mask is not None:
            logits = jnp.where(pad_mask, -1e30, logits)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(
            logits, jnp.maximum(lch, 0)[..., None], axis=-1)[..., 0]
        mask = (lch >= 0).astype(jnp.float32)
        tot += ((lse - gold) * mask).sum()
        cnt += mask.sum()
        return (tot, cnt), None

    (tot, cnt), _ = jax.lax.scan(step, (jnp.float32(0), jnp.float32(0)),
                                 (xc, lc), unroll=scan_unroll())
    return tot / jnp.maximum(cnt, 1.0)
