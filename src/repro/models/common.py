"""Shared model components: init helpers, norms, RoPE, activations.

Every init helper returns ``(params, specs)`` with matching pytree
structure; ``specs`` leaves are tuples of LOGICAL axis names (see
``models.sharding``), converted to PartitionSpec by the launcher.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

Init = Tuple[dict, dict]


def dtype_of(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16,
            "float16": jnp.float16}[name]


def dense_init(key, in_dim: int, out_dim: int, spec, dtype,
               bias: bool = False, scale: float | None = None) -> Init:
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    w = (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)
    p, s = {"w": w}, {"w": spec}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype)
        s["b"] = (spec[-1],)
    return p, s


def dense_apply(p: dict, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def rmsnorm_init(dim: int, dtype) -> Init:
    return {"g": jnp.ones((dim,), dtype)}, {"g": (None,)}


def rmsnorm(p: dict, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    inv = jax.lax.rsqrt(jnp.mean(x32 * x32, axis=-1, keepdims=True) + eps)
    return (x32 * inv).astype(dt) * p["g"].astype(dt)


def activation(name: str):
    if name in ("silu", "geglu_silu"):
        return jax.nn.silu
    if name in ("gelu", "geglu"):
        return jax.nn.gelu
    raise ValueError(name)


# ----------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D); positions: broadcastable to (..., S)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


VOCAB_PAD = 128  # pad vocab so the table shards on any production axis


def padded_vocab(vocab: int) -> int:
    return (vocab + VOCAB_PAD - 1) // VOCAB_PAD * VOCAB_PAD


def embed_init(key, vocab: int, dim: int, dtype) -> Init:
    """Embedding table, vocab PADDED to a multiple of 128 (Megatron-style)
    so the vocab dim is shardable on the 16-wide model axis for archs like
    granite (49155) / minicpm (122753) / seamless (256206)."""
    vp = padded_vocab(vocab)
    w = (jax.random.normal(key, (vp, dim), jnp.float32) * 0.02).astype(dtype)
    return {"w": w}, {"w": ("model", None)}


def split_keys(key, n: int):
    return list(jax.random.split(key, n))
