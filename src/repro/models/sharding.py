"""Logical-axis sharding rules (MaxText-style), resolved lazily.

Models annotate params/activations with LOGICAL axis names; the launcher
installs a mapping to physical mesh axes.  With no rules installed (unit
tests, single device) annotations are no-ops.

Logical axes:
  batch   -> ("pod", "data") on the multi-pod mesh, ("data",) single-pod
  model   -> ("model",)   tensor-parallel dim (heads / d_ff / vocab / experts)
  expert  -> ("model",)   expert-parallel dim for MoE stacks
  seq     -> None         (sequence kept unsharded; SP is a perf knob)
  None    -> replicated
"""
from __future__ import annotations

import contextlib
from typing import Optional, Sequence

import jax
from jax.sharding import PartitionSpec as P

_RULES: Optional[dict] = None

DEFAULT_SINGLE_POD = {
    "batch": ("data",),
    "model": ("model",),
    "expert": ("model",),
    "seq": None,
    "kvseq": None,
}

DEFAULT_MULTI_POD = {
    "batch": ("pod", "data"),
    "model": ("model",),
    "expert": ("model",),
    "seq": None,
    "kvseq": None,
}


def rules_for(shape_kind: str, global_batch: int, mesh_shape: dict) -> dict:
    """Pick logical->physical rules for a (shape, mesh) cell.

    Context parallelism for tiny-batch decode (long_500k, B=1): the batch
    cannot shard over the data axis, so the KV-cache SEQUENCE dim takes it
    instead — the paper's row-partitioning idea applied to the KV cache.
    """
    multi = "pod" in mesh_shape
    rules = dict(DEFAULT_MULTI_POD if multi else DEFAULT_SINGLE_POD)
    data_ways = mesh_shape.get("data", 1) * mesh_shape.get("pod", 1)
    if shape_kind == "decode" and global_batch % data_ways != 0:
        rules["batch"] = None
        rules["kvseq"] = ("pod", "data") if multi else ("data",)
    return rules


def set_rules(rules: Optional[dict]) -> None:
    global _RULES
    _RULES = rules


def get_rules() -> Optional[dict]:
    return _RULES


@contextlib.contextmanager
def use_rules(rules: Optional[dict]):
    global _RULES
    prev = _RULES
    _RULES = rules
    try:
        yield
    finally:
        _RULES = prev


def logical_to_pspec(axes: Sequence[Optional[str]],
                     rules: Optional[dict] = None) -> P:
    rules = rules if rules is not None else _RULES
    if rules is None:
        return P()
    resolved = []
    for a in axes:
        r = rules.get(a) if a else None
        resolved.append(r if r else None)
    return P(*resolved)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Annotate an activation with logical axes (no-op without rules)."""
    if _RULES is None:
        return x
    return jax.lax.with_sharding_constraint(x, logical_to_pspec(axes))
