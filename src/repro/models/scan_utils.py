"""Chunked linear-recurrence scan shared by Mamba and RG-LRU.

Computes h_t = a_t * h_{t-1} + b_t over the time axis with a TWO-LEVEL
scan: a sequential ``lax.scan`` over chunks carrying the boundary state,
and an ``associative_scan`` inside each chunk.  This bounds the
materialised intermediate to (B, chunk, ...) instead of (B, S, ...) —
for falcon-mamba's (d_inner, d_state) = (8192, 16) state at train_4k the
full-S f32 intermediate would be ~2 GiB/device even with d_inner sharded
16-way, the chunked form ~130 MiB (DESIGN.md: TPU memory-hierarchy
adaptation of the GPU selective-scan kernel's SRAM chunking).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from .unroll import scan_unroll, cost_mode


def chunked_linear_scan(a: jax.Array, b: jax.Array, h0: jax.Array,
                        chunk: int = 0):
    """a, b: (B, S, ...); h0: (B, ...). Returns (h_all: (B, S, ...), h_last).

    h_t = a_t * h_{t-1} + b_t, with h_0 the state *before* the sequence.

    chunk = 0 selects the default schedule: 1024 normally (raised from
    128 after the §Perf falcon iterations — streamed bytes GROW as
    chunks shrink, ~3.5x at 512 vs whole-sequence, because every chunk
    re-streams its tensors through log2(chunk) scan levels plus
    boundary materialisations; 1024 keeps the f32 working set ~0.5 GiB
    per live tensor on the production shard), collapsed to a single
    whole-sequence associative_scan in cost mode (log-depth straight-
    line HLO — every flop visible to cost analysis without unrolling a
    loop).  An explicit chunk is honoured even in cost mode, which is
    how the §Perf iterations measure the chunk trade-off with
    consistent methodology.
    """
    B, S = a.shape[:2]
    if chunk == 0:
        chunk = S if cost_mode() else 1024
    chunk = next(c for c in range(min(chunk, S), 0, -1) if S % c == 0)
    n = S // chunk
    rest = a.shape[2:]
    a_c = a.reshape(B, n, chunk, *rest)
    b_c = b.reshape(B, n, chunk, *rest)

    def combine(c1, c2):
        # c2 is later in time: h = a2*(a1*h + b1) + b2
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    def chunk_step(h, ab):
        a_k, b_k = ab                                  # (B, chunk, ...)
        acc_a, acc_b = jax.lax.associative_scan(
            combine, (a_k, b_k), axis=1)
        h_all = acc_a * h[:, None] + acc_b             # (B, chunk, ...)
        return h_all[:, -1], h_all

    # scan over the chunk axis (time-major)
    h_last, h_chunks = jax.lax.scan(
        chunk_step, h0,
        (jnp.moveaxis(a_c, 1, 0), jnp.moveaxis(b_c, 1, 0)),
        unroll=scan_unroll())
    h_all = jnp.moveaxis(h_chunks, 0, 1).reshape(B, S, *rest)
    return h_all, h_last


def causal_conv1d(x: jax.Array, w: jax.Array, bias: jax.Array,
                  state: jax.Array | None = None):
    """Depthwise causal conv. x: (B, S, C); w: (W, C); state: (B, W-1, C)
    carries the last W-1 inputs from the previous segment.
    Returns (y: (B, S, C), new_state)."""
    B, S, C = x.shape
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((B, W - 1, C), x.dtype)
    xp = jnp.concatenate([state.astype(x.dtype), x], axis=1)  # (B, S+W-1, C)
    y = sum(xp[:, i:i + S] * w[i].astype(x.dtype) for i in range(W))
    y = y + bias.astype(x.dtype)
    new_state = xp[:, -(W - 1):] if W > 1 else jnp.zeros((B, 0, C), x.dtype)
    return y, new_state
