"""Residual blocks: one init/apply pair per layer kind, with uniform
(params, cache) structure inside each kind so stacks of the same kind can
be scanned over.

Kinds: "global" / "local" (attention + FFN-or-MoE), "recurrent"
(RG-LRU + FFN), "mamba" (fused Mamba block).  ``cross=True`` adds
encoder-decoder cross-attention to an attention block.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import common as C
from . import attention as A
from . import ffn as FF
from . import moe as MOE
from . import ssm as SSM
from . import rglru as RG


def block_init(key, cfg, kind: str, *, use_moe: bool, cross: bool,
               dtype) -> C.Init:
    ks = C.split_keys(key, 4)
    p, s = {}, {}
    if kind == "mamba":
        p["ln"], s["ln"] = C.rmsnorm_init(cfg.d_model, dtype)
        p["mamba"], s["mamba"] = SSM.mamba_init(ks[0], cfg, dtype)
        return p, s
    p["ln1"], s["ln1"] = C.rmsnorm_init(cfg.d_model, dtype)
    if kind == "recurrent":
        p["rec"], s["rec"] = RG.rglru_init(ks[0], cfg, dtype)
    else:
        p["attn"], s["attn"] = A.attn_init(ks[0], cfg, dtype)
        if cross:
            p["lnx"], s["lnx"] = C.rmsnorm_init(cfg.d_model, dtype)
            p["xattn"], s["xattn"] = A.attn_init(ks[1], cfg, dtype)
    p["ln2"], s["ln2"] = C.rmsnorm_init(cfg.d_model, dtype)
    if use_moe:
        p["moe"], s["moe"] = MOE.moe_init(ks[2], cfg, dtype)
    else:
        # MoE archs' dense layers use the wider combined width (deepseek)
        d_ff = cfg.d_ff * (cfg.top_k + cfg.n_shared_experts) \
            if cfg.n_experts else cfg.d_ff
        p["mlp"], s["mlp"] = FF.ffn_init(ks[2], cfg, dtype, d_ff=d_ff)
    return p, s


def _mix_ffn(p, cfg, x):
    if "moe" in p:
        y, aux = MOE.moe_apply(p["moe"], cfg, x)
        return y, aux
    return FF.ffn_apply(p["mlp"], cfg, x), jnp.float32(0)


def block_apply_train(p, cfg, kind: str, x, positions, *, causal=True,
                      memory=None, q_chunk=512, k_chunk=512):
    """Returns (x_out, aux_loss).  memory: encoder output for cross-attn."""
    if kind == "mamba":
        h, _ = SSM.mamba_apply_train(p["mamba"], cfg,
                                     C.rmsnorm(p["ln"], x, cfg.norm_eps))
        return x + h, jnp.float32(0)
    h = C.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "recurrent":
        h, _ = RG.rglru_apply_train(p["rec"], cfg, h)
    else:
        h, _ = A.attn_apply_train(p["attn"], cfg, h, positions,
                                  is_local=(kind == "local"), causal=causal,
                                  q_chunk=q_chunk, k_chunk=k_chunk)
        if cfg.parallel_block and "xattn" not in p:
            # PaLM-style parallel residual: attn and MLP read the same
            # normed input; their row-parallel partial sums are added
            # BEFORE the residual, so GSPMD emits one all-reduce/layer
            # instead of two (§Perf, llava iteration).
            h2, aux = _mix_ffn(p, cfg,
                               C.rmsnorm(p["ln2"], x, cfg.norm_eps))
            return x + h + h2, aux
    x = x + h
    if "xattn" in p and memory is not None:
        hx = C.rmsnorm(p["lnx"], x, cfg.norm_eps)
        mem_pos = jnp.arange(memory.shape[1])[None, :]
        q = C.dense_apply(p["xattn"]["wq"], hx).reshape(
            *hx.shape[:2], cfg.n_heads, cfg.resolved_head_dim)
        k = C.dense_apply(p["xattn"]["wk"], memory).reshape(
            *memory.shape[:2], cfg.n_kv_heads, cfg.resolved_head_dim)
        v = C.dense_apply(p["xattn"]["wv"], memory).reshape(
            *memory.shape[:2], cfg.n_kv_heads, cfg.resolved_head_dim)
        o = A.flash_attention(q, k, v, causal=False, window=None,
                              q_chunk=q_chunk, k_chunk=k_chunk)
        x = x + C.dense_apply(p["xattn"]["wo"],
                              o.reshape(*hx.shape[:2], -1))
    h2, aux = _mix_ffn(p, cfg, C.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + h2, aux


def block_apply_decode(p, cfg, kind: str, x, cache, pos):
    """Single-token step. Returns (x_out, new_cache)."""
    if kind == "mamba":
        h, new_c = SSM.mamba_apply_decode(
            p["mamba"], cfg, C.rmsnorm(p["ln"], x, cfg.norm_eps), cache)
        return x + h, new_c
    h = C.rmsnorm(p["ln1"], x, cfg.norm_eps)
    if kind == "recurrent":
        h, new_c = RG.rglru_apply_decode(p["rec"], cfg, h, cache)
    else:
        h, self_c = A.attn_apply_decode(p["attn"], cfg, h, cache["self"]
                                        if "self" in cache else cache, pos,
                                        is_local=(kind == "local"))
        new_c = dict(cache, self=self_c) if "self" in cache else self_c
    x = x + h
    if "xattn" in p and "xk" in cache:
        hx = C.rmsnorm(p["lnx"], x, cfg.norm_eps)
        b = x.shape[0]
        q = C.dense_apply(p["xattn"]["wq"], hx).reshape(
            b, 1, cfg.n_heads, cfg.resolved_head_dim)
        s_enc = cache["xk"].shape[1]
        kv_pos = jnp.broadcast_to(jnp.arange(s_enc, dtype=jnp.int32),
                                  (b, s_enc))
        o = A.decode_attention(q, cache["xk"], cache["xv"], kv_pos,
                               jnp.full((b,), s_enc, jnp.int32))
        x = x + C.dense_apply(p["xattn"]["wo"], o.reshape(b, 1, -1))
    h2, _ = _mix_ffn(p, cfg, C.rmsnorm(p["ln2"], x, cfg.norm_eps))
    return x + h2, new_c


def block_cache_init(cfg, kind: str, batch: int, max_len: int, *,
                     cross: bool, dtype):
    if kind == "mamba":
        return SSM.mamba_cache_init(cfg, batch, dtype)
    if kind == "recurrent":
        return RG.rglru_cache_init(cfg, batch, dtype)
    c = A.attn_cache_init(cfg, batch, max_len,
                          is_local=(kind == "local"), dtype=dtype)
    if cross:
        hd = cfg.resolved_head_dim
        return {"self": c,
                "xk": jnp.zeros((batch, cfg.frontend_seq, cfg.n_kv_heads, hd),
                                dtype),
                "xv": jnp.zeros((batch, cfg.frontend_seq, cfg.n_kv_heads, hd),
                                dtype)}
    return c


def block_cache_specs(cfg, kind: str, *, cross: bool):
    if kind == "mamba":
        return SSM.mamba_cache_specs()
    if kind == "recurrent":
        return RG.rglru_cache_specs()
    c = A.attn_cache_specs(cfg, is_local=(kind == "local"))
    if cross:
        xkv = ("batch", None, "model", None) \
            if cfg.n_kv_heads % 16 == 0 else ("batch", None, None, "model")
        return {"self": c, "xk": xkv, "xv": xkv}
    return c
