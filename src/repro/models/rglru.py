"""RG-LRU recurrent block — recurrentgemma-2b's temporal-mixing layer.

Real-Gated Linear Recurrent Unit (arXiv:2402.19427):
    r_t = sigmoid(W_a x_t + b_a)            recurrence gate
    i_t = sigmoid(W_x x_t + b_x)            input gate
    a_t = exp(-c * softplus(L) * r_t)       per-channel decay, c = 8
    h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Simplification noted in DESIGN.md: the published model uses block-diagonal
gate matrices; we use per-channel (diagonal) gates, which preserves the
recurrence structure and state shapes.  The block wraps the RG-LRU with
the conv1d + gated-output structure of the paper's recurrent block.

Decode is O(1) state: (B, d_inner) + conv tail -> runs long_500k.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import common as C
from .scan_utils import chunked_linear_scan, causal_conv1d
from .sharding import shard

_C = 8.0


def rglru_init(key, cfg, dtype) -> C.Init:
    d, di = cfg.d_model, cfg.d_inner
    cw = cfg.conv_width
    ks = C.split_keys(key, 5)
    p, s = {}, {}
    p["in_x"], s["in_x"] = C.dense_init(ks[0], d, di, (None, "model"), dtype)
    p["in_gate"], s["in_gate"] = C.dense_init(ks[1], d, di, (None, "model"),
                                              dtype)
    p["conv_w"] = (jax.random.normal(ks[2], (cw, di), jnp.float32)
                   / np.sqrt(cw)).astype(dtype)
    s["conv_w"] = (None, "model")
    p["conv_b"] = jnp.zeros((di,), dtype)
    s["conv_b"] = ("model",)
    # diagonal gates + decay parameter Lambda
    p["w_a"] = jnp.zeros((di,), jnp.float32); s["w_a"] = ("model",)
    p["b_a"] = jnp.zeros((di,), jnp.float32); s["b_a"] = ("model",)
    p["w_x"] = jnp.zeros((di,), jnp.float32); s["w_x"] = ("model",)
    p["b_x"] = jnp.zeros((di,), jnp.float32); s["b_x"] = ("model",)
    # init so that a^c in [0.9, 0.999] as in the paper
    u = jax.random.uniform(ks[3], (di,), jnp.float32, 0.9 ** 2, 0.999 ** 2)
    p["lam"] = jnp.log(jnp.expm1(-jnp.log(u) / _C))  # softplus^-1
    s["lam"] = ("model",)
    p["out"], s["out"] = C.dense_init(ks[4], di, d, ("model", None), dtype)
    return p, s


def _gates(p, xc):
    x32 = xc.astype(jnp.float32)
    r = jax.nn.sigmoid(p["w_a"] * x32 + p["b_a"])
    i = jax.nn.sigmoid(p["w_x"] * x32 + p["b_x"])
    log_a = -_C * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * x32)
    return a, b


def rglru_apply_train(p, cfg, x, scan_chunk: int | None = None):
    """x: (B, S, D) normalised input -> (out, cache)."""
    B, S, _ = x.shape
    gate = jax.nn.gelu(C.dense_apply(p["in_gate"], x))
    xs = C.dense_apply(p["in_x"], x)
    xs = shard(xs, "batch", None, "model")
    xc, conv_state = causal_conv1d(xs, p["conv_w"], p["conv_b"])
    a, b = _gates(p, xc)
    h0 = jnp.zeros((B, cfg.d_inner), jnp.float32)
    chunk = scan_chunk if scan_chunk is not None else cfg.ssm_scan_chunk
    h_all, h_last = chunked_linear_scan(a, b, h0, chunk=chunk)
    y = (h_all.astype(x.dtype) * gate)
    out = C.dense_apply(p["out"], y)
    return shard(out, "batch", None, None), {"conv": conv_state, "h": h_last}


def rglru_apply_decode(p, cfg, x, cache):
    gate = jax.nn.gelu(C.dense_apply(p["in_gate"], x))
    xs = C.dense_apply(p["in_x"], x)
    xc, conv_state = causal_conv1d(xs, p["conv_w"], p["conv_b"],
                                   state=cache["conv"])
    a, b = _gates(p, xc)
    h = a[:, 0] * cache["h"] + b[:, 0]
    y = h[:, None].astype(x.dtype) * gate
    out = C.dense_apply(p["out"], y)
    return out, {"conv": conv_state, "h": h}


def rglru_cache_init(cfg, batch: int, dtype=jnp.bfloat16):
    di = cfg.d_inner
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
        "h": jnp.zeros((batch, di), jnp.float32),
    }


def rglru_cache_specs():
    return {"conv": ("batch", None, "model"), "h": ("batch", "model")}
