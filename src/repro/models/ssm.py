"""Mamba-1 (selective SSM) block — falcon-mamba-7b's layer type.

TPU adaptation of the CUDA selective-scan kernel (DESIGN.md §2): the
recurrence is a chunked two-level scan (``scan_utils``); ``d_inner`` is
tensor-sharded over the model axis, so the (B, chunk, d_inner, d_state)
discretised-A intermediate stays ~tens of MiB per device.

Decode is O(1): the carried state is (B, d_inner, d_state) + a (W-1)-tap
conv tail — why this arch runs the long_500k shape.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import common as C
from .scan_utils import chunked_linear_scan, causal_conv1d
from .sharding import shard


def mamba_init(key, cfg, dtype) -> C.Init:
    d, di, n, r = cfg.d_model, cfg.d_inner, cfg.ssm_state, max(cfg.dt_rank, 1)
    cw = cfg.conv_width
    ks = C.split_keys(key, 6)
    p, s = {}, {}
    p["in_proj"], s["in_proj"] = C.dense_init(ks[0], d, 2 * di,
                                              (None, "model"), dtype)
    p["conv_w"] = (jax.random.normal(ks[1], (cw, di), jnp.float32)
                   / np.sqrt(cw)).astype(dtype)
    s["conv_w"] = (None, "model")
    p["conv_b"] = jnp.zeros((di,), dtype)
    s["conv_b"] = ("model",)
    p["x_proj"], s["x_proj"] = C.dense_init(ks[2], di, r + 2 * n,
                                            ("model", None), dtype)
    p["dt_proj"], s["dt_proj"] = C.dense_init(ks[3], r, di, (None, "model"),
                                              dtype, bias=True)
    # S4D-real initialisation of A
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    p["A_log"] = jnp.log(a)
    s["A_log"] = ("model", None)
    p["D"] = jnp.ones((di,), jnp.float32)
    s["D"] = ("model",)
    p["out_proj"], s["out_proj"] = C.dense_init(ks[5], di, d,
                                                ("model", None), dtype)
    return p, s


def _ssm_inputs(p, cfg, x_conv):
    """Shared between train scan and decode step.
    x_conv: (B, S, di) post-conv activations."""
    n, r = cfg.ssm_state, max(cfg.dt_rank, 1)
    proj = C.dense_apply(p["x_proj"], x_conv)
    dt_in, b_in, c_in = jnp.split(proj, [r, r + n], axis=-1)
    dt = jax.nn.softplus(C.dense_apply(p["dt_proj"], dt_in).astype(jnp.float32))
    a_mat = -jnp.exp(p["A_log"].astype(jnp.float32))          # (di, n)
    da = jnp.exp(dt[..., None] * a_mat)                       # (B,S,di,n)
    dbx = (dt * x_conv.astype(jnp.float32))[..., None] \
        * b_in.astype(jnp.float32)[..., None, :]              # (B,S,di,n)
    return da, dbx, c_in


def mamba_apply_train(p, cfg, x, ssm_chunk: int | None = None):
    """x: (B, S, D) normalised input. Returns (out, final_state_dict)."""
    B, S, _ = x.shape
    di = cfg.d_inner
    xz = C.dense_apply(p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)
    xs = shard(xs, "batch", None, "model")
    xc, conv_state = causal_conv1d(xs, p["conv_w"], p["conv_b"])
    xc = jax.nn.silu(xc)
    da, dbx, c_in = _ssm_inputs(p, cfg, xc)
    h0 = jnp.zeros((B, di, cfg.ssm_state), jnp.float32)
    chunk = ssm_chunk if ssm_chunk is not None else cfg.ssm_scan_chunk
    h_all, h_last = chunked_linear_scan(da, dbx, h0, chunk=chunk)
    y = jnp.einsum("bsdn,bsn->bsd", h_all,
                   c_in.astype(jnp.float32))                   # (B,S,di)
    y = y + p["D"].astype(jnp.float32) * xc.astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = C.dense_apply(p["out_proj"], y)
    return shard(out, "batch", None, None), {"conv": conv_state, "h": h_last}


def mamba_apply_decode(p, cfg, x, cache):
    """Single-step decode. x: (B, 1, D); cache: {conv, h}."""
    xz = C.dense_apply(p["in_proj"], x)
    xs, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = causal_conv1d(xs, p["conv_w"], p["conv_b"],
                                   state=cache["conv"])
    xc = jax.nn.silu(xc)
    da, dbx, c_in = _ssm_inputs(p, cfg, xc)                    # S = 1
    h = da[:, 0] * cache["h"] + dbx[:, 0]                      # (B,di,n)
    y = jnp.einsum("bdn,bn->bd", h, c_in[:, 0].astype(jnp.float32))
    y = y + p["D"].astype(jnp.float32) * xc[:, 0].astype(jnp.float32)
    y = (y * jax.nn.silu(z[:, 0].astype(jnp.float32))).astype(x.dtype)
    out = C.dense_apply(p["out_proj"], y[:, None])
    return out, {"conv": conv_state, "h": h}


def mamba_cache_init(cfg, batch: int, dtype=jnp.bfloat16):
    di = cfg.d_inner
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di), dtype),
        "h": jnp.zeros((batch, di, cfg.ssm_state), jnp.float32),
    }


def mamba_cache_specs():
    return {"conv": ("batch", None, "model"), "h": ("batch", "model", None)}
