"""Public model API: ``build_model(cfg)`` -> :class:`Model`.

One class serves all 10 assigned architectures:

* decoder-only LMs (dense / MoE / SSM / hybrid) — ``loss`` / ``prefill``
  / ``decode_step``;
* VLM (llava): precomputed patch embeddings (stub frontend) are prepended
  to the text embeddings;
* enc-dec (seamless): precomputed frame embeddings (stub frontend) feed a
  bidirectional encoder; the decoder cross-attends.

``input_specs(shape_name)`` returns ShapeDtypeStruct stand-ins + logical
PartitionSpecs for every input of the step function the shape exercises —
the dry-run contract (task spec, MULTI-POD DRY-RUN item 2).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ArchConfig, SHAPES, ShapeConfig
from . import common as C
from . import transformer as T
from .sharding import shard

__all__ = ["Model", "build_model"]


def _round_up(x, m):
    return (x + m - 1) // m * m


class Model:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg
        self.dtype = C.dtype_of(cfg.param_dtype)
        self.adt = C.dtype_of(cfg.activation_dtype)
        self.plan = T.make_plan(cfg, cfg.n_layers)
        self.enc_plan = (T.make_plan(cfg, cfg.enc_layers,
                                     force_dense_pattern=True, moe_ok=False)
                         if cfg.is_encdec else None)

    # ------------------------------------------------------------- params
    def init(self, key) -> dict:
        return self._init(key)[0]

    def param_specs(self) -> dict:
        """Spec tree mirroring the param tree (tuples of logical axes).
        Built under eval_shape so no arrays are materialised."""
        box = {}

        def f():
            p, s = self._init(jax.random.PRNGKey(0))
            box["s"] = s
            return p

        jax.eval_shape(f)
        return box["s"]

    def param_shapes(self) -> dict:
        return jax.eval_shape(lambda: self._init(jax.random.PRNGKey(0))[0])

    def _init(self, key) -> C.Init:
        cfg = self.cfg
        ks = C.split_keys(key, 5)
        p, s = {}, {}
        p["embed"], s["embed"] = C.embed_init(ks[0], cfg.vocab, cfg.d_model,
                                              self.dtype)
        if not cfg.tie_embeddings:
            p["unembed"], s["unembed"] = C.embed_init(
                ks[1], cfg.vocab, cfg.d_model, self.dtype)
        p["final_ln"], s["final_ln"] = C.rmsnorm_init(cfg.d_model, self.dtype)
        p["dec"], s["dec"] = T.stack_init(ks[2], cfg, self.plan,
                                          cross=cfg.is_encdec,
                                          dtype=self.dtype)
        if cfg.is_encdec:
            p["enc"], s["enc"] = T.stack_init(ks[3], cfg, self.enc_plan,
                                              cross=False, dtype=self.dtype)
            p["enc_ln"], s["enc_ln"] = C.rmsnorm_init(cfg.d_model, self.dtype)
        return p, s

    def _unembed_w(self, params):
        return params["embed"]["w"] if self.cfg.tie_embeddings \
            else params["unembed"]["w"]

    # --------------------------------------------------------------- train
    def loss(self, params, batch, *, remat: bool = True,
             q_chunk: int = 512, k_chunk: int = 512,
             loss_chunk: int = 512, aux_weight: float = 1e-2):
        """batch: tokens (B,S) int32, labels (B,S) int32 (-1 masked),
        optional frontend (B,F,D) / enc_frames (B,Se,D)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"]["w"].astype(self.adt)[tokens]
        x = shard(x, "batch", None, None)
        memory = None
        if cfg.is_encdec:
            m = batch["enc_frames"].astype(self.adt)
            m = shard(m, "batch", None, None)
            mpos = jnp.arange(m.shape[1])[None, :]
            m, _ = T.stack_apply_train(params["enc"], cfg, self.enc_plan, m,
                                       mpos, causal=False, remat=remat,
                                       q_chunk=q_chunk, k_chunk=k_chunk)
            memory = C.rmsnorm(params["enc_ln"], m, cfg.norm_eps)
        n_front = 0
        if cfg.frontend == "vision":
            fe = batch["frontend"].astype(self.adt)
            x = jnp.concatenate([shard(fe, "batch", None, None), x], axis=1)
            n_front = fe.shape[1]
        positions = jnp.arange(x.shape[1])[None, :]
        x, aux = T.stack_apply_train(params["dec"], cfg, self.plan, x,
                                     positions, memory=memory, remat=remat,
                                     q_chunk=q_chunk, k_chunk=k_chunk)
        x = C.rmsnorm(params["final_ln"], x, cfg.norm_eps)
        scored = x[:, n_front:]
        nll = T.chunked_xent(scored, self._unembed_w(params),
                             batch["labels"], chunk=loss_chunk,
                             vocab=cfg.vocab)
        return nll + aux_weight * aux, {"nll": nll, "aux": aux}

    # ------------------------------------------------------------- serving
    def prefill(self, params, batch, *, max_len: int,
                q_chunk: int = 512, k_chunk: int = 512):
        """Process the full prompt; returns (cache, last-position logits)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x = params["embed"]["w"].astype(self.adt)[tokens]
        x = shard(x, "batch", None, None)
        memory = None
        if cfg.is_encdec:
            m = batch["enc_frames"].astype(self.adt)
            mpos = jnp.arange(m.shape[1])[None, :]
            m, _ = T.stack_apply_train(params["enc"], cfg, self.enc_plan, m,
                                       mpos, causal=False, remat=False,
                                       q_chunk=q_chunk, k_chunk=k_chunk)
            memory = C.rmsnorm(params["enc_ln"], m, cfg.norm_eps)
        if cfg.frontend == "vision":
            fe = batch["frontend"].astype(self.adt)
            x = jnp.concatenate([shard(fe, "batch", None, None), x], axis=1)
        positions = jnp.arange(x.shape[1])[None, :]
        x, cache = T.stack_apply_prefill(params["dec"], cfg, self.plan, x,
                                         positions, max_len=max_len,
                                         memory=memory, cache_dtype=self.adt,
                                         q_chunk=q_chunk, k_chunk=k_chunk)
        x = C.rmsnorm(params["final_ln"], x[:, -1:], cfg.norm_eps)
        logits = self._logits(params, x)
        return cache, logits

    def _logits(self, params, x):
        w = self._unembed_w(params)
        logits = jnp.einsum("btd,vd->btv", x.astype(jnp.float32),
                            w.astype(jnp.float32))
        if w.shape[0] > self.cfg.vocab:   # mask the padded vocab tail
            logits = jnp.where(jnp.arange(w.shape[0]) >= self.cfg.vocab,
                               -1e30, logits)
        return logits

    def decode_step(self, params, cache, tokens, pos):
        """tokens: (B, 1) int32; pos: (B,) absolute positions."""
        cfg = self.cfg
        x = params["embed"]["w"].astype(self.adt)[tokens]
        x, new_cache = T.stack_apply_decode(params["dec"], cfg, self.plan,
                                            x, cache, pos)
        x = C.rmsnorm(params["final_ln"], x, cfg.norm_eps)
        return new_cache, self._logits(params, x)

    def init_cache(self, batch: int, max_len: int):
        return T.stack_cache_init(self.cfg, self.plan, batch, max_len,
                                  cross=self.cfg.is_encdec, dtype=self.adt)

    def cache_specs(self):
        return T.stack_cache_specs(self.cfg, self.plan,
                                   cross=self.cfg.is_encdec)

    # -------------------------------------------------------- dry-run specs
    def input_specs(self, shape: ShapeConfig | str, *,
                    seq_override: Optional[int] = None,
                    batch_override: Optional[int] = None):
        """ShapeDtypeStruct stand-ins + logical specs for the step function
        this shape exercises.  kind 'train'   -> loss(params, batch)
                               'prefill' -> prefill(params, batch)
                               'decode'  -> decode_step(params, cache, t, pos)
        """
        cfg = self.cfg
        if isinstance(shape, str):
            shape = SHAPES[shape]
        s = seq_override or shape.seq_len
        b = batch_override or shape.global_batch
        i32 = jnp.int32
        if shape.kind == "train":
            text = s - (cfg.frontend_seq if cfg.frontend == "vision" else 0)
            batch = {
                "tokens": jax.ShapeDtypeStruct((b, text), i32),
                "labels": jax.ShapeDtypeStruct((b, text), i32),
            }
            specs = {"tokens": ("batch", None), "labels": ("batch", None)}
            if cfg.frontend == "vision":
                batch["frontend"] = jax.ShapeDtypeStruct(
                    (b, cfg.frontend_seq, cfg.d_model), self.adt)
                specs["frontend"] = ("batch", None, None)
            if cfg.is_encdec:
                batch["enc_frames"] = jax.ShapeDtypeStruct(
                    (b, s, cfg.d_model), self.adt)
                specs["enc_frames"] = ("batch", None, None)
            return batch, specs
        if shape.kind == "prefill":
            text = s - (cfg.frontend_seq if cfg.frontend == "vision" else 0)
            batch = {"tokens": jax.ShapeDtypeStruct((b, text), i32)}
            specs = {"tokens": ("batch", None)}
            if cfg.frontend == "vision":
                batch["frontend"] = jax.ShapeDtypeStruct(
                    (b, cfg.frontend_seq, cfg.d_model), self.adt)
                specs["frontend"] = ("batch", None, None)
            if cfg.is_encdec:
                batch["enc_frames"] = jax.ShapeDtypeStruct(
                    (b, s, cfg.d_model), self.adt)
                specs["enc_frames"] = ("batch", None, None)
            return batch, specs
        # decode: cache of length s plus one new token
        cache = jax.eval_shape(lambda: self.init_cache(b, s))
        cache_specs = self.cache_specs()
        batch = {
            "cache": cache,
            "tokens": jax.ShapeDtypeStruct((b, 1), i32),
            "pos": jax.ShapeDtypeStruct((b,), i32),
        }
        specs = {"cache": cache_specs, "tokens": ("batch", None),
                 "pos": ("batch",)}
        return batch, specs


def build_model(cfg: ArchConfig) -> Model:
    return Model(cfg)
