"""GQA attention: flash-style chunked softmax for train/prefill, cached
decode, sliding-window (local) variants, RoPE, qk-norm, QKV bias.

Train/prefill path ("pair-scan flash"): the (q-chunk, kv-chunk) grid is
enumerated host-side and only the pairs that can interact (causal
triangle, intersected with the sliding window band) are visited by one
``lax.scan`` over a static pair list.  This keeps

* memory at O(chunk^2) per step (true flash semantics, online softmax),
* FLOPs at the exact block-triangle/band count — no 2x masked waste, so
  ``cost_analysis`` FLOPs in the dry-run reflect useful work, and
* the HLO size O(1) in sequence length (single scan body) — which also
  keeps the 40-cell dry-run compile times tractable.

This mirrors how the paper's pJDS kernel skips padded work at block
granularity rather than per element (Fig. 2c): the mask only trims the
block edges, block interiors are dense compute.
"""
from __future__ import annotations

import functools
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np

from . import common as C
from .sharding import shard
from .unroll import scan_unroll

# ---------------------------------------------------------------------
# Attention implementation switch (see EXPERIMENTS.md §Perf):
#   "pairs" — one scan over the static (q-chunk, kv-chunk) pair list.
#             O(1) HLO in sequence length; the carry holds the full
#             output accumulator, so each step dynamic-update-slices a
#             (B, nq, cq, H, D) buffer: in-place on TPU, but inflates
#             HloCostAnalysis bytes and serialises updates.
#   "qloop" — static Python loop over q chunks; each q chunk runs an
#             inner scan over exactly its causal/window kv range with a
#             chunk-local carry.  No large DUS; per-q-chunk outputs are
#             concatenated.  HLO grows O(nq) but every buffer is small —
#             the TPU-friendly schedule (independent q-chunk streams).
# ---------------------------------------------------------------------
import contextlib

_ATTN_IMPL = "pairs"


def get_attn_impl() -> str:
    return _ATTN_IMPL


@contextlib.contextmanager
def use_attn_impl(name: str):
    global _ATTN_IMPL
    assert name in ("pairs", "qloop")
    prev = _ATTN_IMPL
    _ATTN_IMPL = name
    try:
        yield
    finally:
        _ATTN_IMPL = prev


def block_pairs(n_q: int, n_k: int, q_chunk: int, k_chunk: int,
                causal: bool, window: Optional[int],
                kv_offset: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Static list of interacting (q_chunk_idx, kv_chunk_idx) pairs.
    ``kv_offset`` shifts q positions relative to kv positions (q token i
    sits at absolute position kv_offset + i), for chunked prefill."""
    qi_l, ki_l = [], []
    for i in range(n_q):
        q_lo = kv_offset + i * q_chunk
        q_hi = kv_offset + (i + 1) * q_chunk - 1
        for j in range(n_k):
            k_lo = j * k_chunk
            k_hi = (j + 1) * k_chunk - 1
            if causal and k_lo > q_hi:
                continue
            if window is not None and k_hi < q_lo - window + 1:
                continue
            qi_l.append(i)
            ki_l.append(j)
    return (np.asarray(qi_l, np.int32), np.asarray(ki_l, np.int32))


def flash_attention(
    q: jax.Array,            # (B, Sq, Hq, D)
    k: jax.Array,            # (B, Sk, Hkv, D)
    v: jax.Array,            # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_chunk: int = 512,
    k_chunk: int = 512,
    kv_offset: int = 0,
    logit_softcap: float = 0.0,
) -> jax.Array:
    b, sq, hq, d = q.shape
    _, sk, hkv, _ = k.shape
    g = hq // hkv
    # largest divisors <= requested, so arbitrary (frontend-extended)
    # sequence lengths work
    q_chunk = next(c for c in range(min(q_chunk, sq), 0, -1) if sq % c == 0)
    k_chunk = next(c for c in range(min(k_chunk, sk), 0, -1) if sk % c == 0)
    nq, nk = sq // q_chunk, sk // k_chunk
    scale = 1.0 / np.sqrt(d)

    qs = q.reshape(b, nq, q_chunk, hkv, g, d)
    ks = k.reshape(b, nk, k_chunk, hkv, d)
    vs = v.reshape(b, nk, k_chunk, hkv, d)

    if _ATTN_IMPL == "qloop":
        return _flash_qloop(qs, ks, vs, b, sq, hq, hkv, g, d, nq, nk,
                            q_chunk, k_chunk, causal, window, kv_offset,
                            scale, logit_softcap, q.dtype)

    pairs_q, pairs_k = block_pairs(nq, nk, q_chunk, k_chunk, causal, window,
                                   kv_offset)

    acc = jnp.zeros((b, nq, q_chunk, hkv, g, d), jnp.float32)
    m = jnp.full((b, nq, q_chunk, hkv, g), -jnp.inf, jnp.float32)
    l = jnp.zeros((b, nq, q_chunk, hkv, g), jnp.float32)

    q_arange = jnp.arange(q_chunk)
    k_arange = jnp.arange(k_chunk)

    def step(carry, pair):
        acc, m, l = carry
        qi, ki = pair
        qc = jax.lax.dynamic_index_in_dim(qs, qi, 1, keepdims=False)
        kc = jax.lax.dynamic_index_in_dim(ks, ki, 1, keepdims=False)
        vc = jax.lax.dynamic_index_in_dim(vs, ki, 1, keepdims=False)
        s = jnp.einsum("bqhgd,bkhd->bqhgk", qc.astype(jnp.float32),
                       kc.astype(jnp.float32)) * scale
        if logit_softcap:
            s = logit_softcap * jnp.tanh(s / logit_softcap)
        qpos = kv_offset + qi * q_chunk + q_arange          # (cq,)
        kpos = ki * k_chunk + k_arange                      # (ck,)
        ok = jnp.ones((q_chunk, k_chunk), bool)
        if causal:
            ok &= kpos[None, :] <= qpos[:, None]
        if window is not None:
            ok &= qpos[:, None] - kpos[None, :] < window
        s = jnp.where(ok[None, :, None, None, :], s, -jnp.inf)

        m_old = jax.lax.dynamic_index_in_dim(m, qi, 1, keepdims=False)
        l_old = jax.lax.dynamic_index_in_dim(l, qi, 1, keepdims=False)
        a_old = jax.lax.dynamic_index_in_dim(acc, qi, 1, keepdims=False)
        m_new = jnp.maximum(m_old, s.max(axis=-1))
        # rows with no valid kv yet keep m = -inf; make exp well-defined
        m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(ok[None, :, None, None, :], p, 0.0)
        corr = jnp.where(jnp.isneginf(m_old), 0.0,
                         jnp.exp(m_old - m_safe))
        l_new = l_old * corr + p.sum(axis=-1)
        pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, vc.astype(jnp.float32))
        a_new = a_old * corr[..., None] + pv
        return (
            jax.lax.dynamic_update_index_in_dim(acc, a_new, qi, 1),
            jax.lax.dynamic_update_index_in_dim(m, m_new, qi, 1),
            jax.lax.dynamic_update_index_in_dim(l, l_new, qi, 1),
        ), None

    (acc, m, l), _ = jax.lax.scan(
        step, (acc, m, l), (jnp.asarray(pairs_q), jnp.asarray(pairs_k)),
        unroll=scan_unroll(),
    )
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.reshape(b, sq, hq, d).astype(q.dtype)


def _flash_qloop(qs, ks, vs, b, sq, hq, hkv, g, d, nq, nk, q_chunk, k_chunk,
                 causal, window, kv_offset, scale, logit_softcap, out_dtype):
    """Per-q-chunk streams with exact static kv ranges (no big DUS)."""
    k_arange = jnp.arange(k_chunk)
    q_arange = jnp.arange(q_chunk)
    outs = []
    for qi in range(nq):
        q_lo = kv_offset + qi * q_chunk
        q_hi = q_lo + q_chunk - 1
        ki_lo, ki_hi = 0, nk - 1
        if causal:
            ki_hi = min(ki_hi, q_hi // k_chunk)
        if window is not None:
            ki_lo = max(ki_lo, (q_lo - window + 1) // k_chunk)
        n_steps = ki_hi - ki_lo + 1
        qc = qs[:, qi]                                  # (b,cq,hkv,g,d)
        kseg = ks[:, ki_lo:ki_hi + 1]                   # (b,n,ck,hkv,d)
        vseg = vs[:, ki_lo:ki_hi + 1]
        qpos = q_lo + q_arange

        def step(carry, xs):
            m, l, acc = carry
            kc, vc, ki = xs
            s = jnp.einsum("bqhgd,bkhd->bqhgk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            if logit_softcap:
                s = logit_softcap * jnp.tanh(s / logit_softcap)
            kpos = ki * k_chunk + k_arange
            ok = jnp.ones((q_chunk, k_chunk), bool)
            if causal:
                ok &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                ok &= qpos[:, None] - kpos[None, :] < window
            s = jnp.where(ok[None, :, None, None, :], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            m_safe = jnp.where(jnp.isneginf(m_new), 0.0, m_new)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(ok[None, :, None, None, :], p, 0.0)
            corr = jnp.where(jnp.isneginf(m), 0.0, jnp.exp(m - m_safe))
            l_new = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bqhgk,bkhd->bqhgd", p, vc.astype(jnp.float32))
            return (m_new, l_new, acc * corr[..., None] + pv), None

        init = (jnp.full((b, q_chunk, hkv, g), -jnp.inf, jnp.float32),
                jnp.zeros((b, q_chunk, hkv, g), jnp.float32),
                jnp.zeros((b, q_chunk, hkv, g, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(
            step, init,
            (jnp.moveaxis(kseg, 1, 0), jnp.moveaxis(vseg, 1, 0),
             jnp.arange(ki_lo, ki_hi + 1)),
            unroll=scan_unroll())
        outs.append(acc / jnp.maximum(l, 1e-30)[..., None])
    out = jnp.stack(outs, axis=1)                       # (b,nq,cq,hkv,g,d)
    return out.reshape(b, sq, hq, d).astype(out_dtype)


def decode_attention(
    q: jax.Array,            # (B, 1, Hq, D)
    k_cache: jax.Array,      # (B, S_cache, Hkv, D)
    v_cache: jax.Array,
    kv_positions: jax.Array, # (B, S_cache) int32 absolute pos; -1 = empty
    pos: jax.Array,          # (B,) current absolute position
    *,
    window: Optional[int] = None,
    logit_softcap: float = 0.0,
) -> jax.Array:
    b, _, hq, d = q.shape
    hkv = k_cache.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(d)
    qg = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                   k_cache.astype(jnp.float32)) * scale
    if logit_softcap:
        s = logit_softcap * jnp.tanh(s / logit_softcap)
    ok = (kv_positions >= 0) & (kv_positions <= pos[:, None])
    if window is not None:
        ok &= pos[:, None] - kv_positions < window
    s = jnp.where(ok[:, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, 1, hq, d).astype(q.dtype)


# --------------------------------------------------------------------------
# Attention block (params + apply)
# --------------------------------------------------------------------------
def attn_init(key, cfg, dtype) -> C.Init:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    hq, hkv = cfg.n_heads, cfg.n_kv_heads
    ks = C.split_keys(key, 4)
    p, s = {}, {}
    p["wq"], s["wq"] = C.dense_init(ks[0], d, hq * hd, (None, "model"), dtype,
                                    bias=cfg.qkv_bias)
    p["wk"], s["wk"] = C.dense_init(ks[1], d, hkv * hd, (None, "model"), dtype,
                                    bias=cfg.qkv_bias)
    p["wv"], s["wv"] = C.dense_init(ks[2], d, hkv * hd, (None, "model"), dtype,
                                    bias=cfg.qkv_bias)
    p["wo"], s["wo"] = C.dense_init(ks[3], hq * hd, d, ("model", None), dtype)
    if cfg.qk_norm:
        p["qn"], s["qn"] = C.rmsnorm_init(hd, dtype)
        p["kn"], s["kn"] = C.rmsnorm_init(hd, dtype)
    return p, s


def _project_qkv(p, cfg, x, positions):
    b, sq, _ = x.shape
    hd = cfg.resolved_head_dim
    # Sharding constraints go on the PACKED (h*hd) projections: head
    # counts like gemma3's 8 need not divide the 16-wide model axis, but
    # the packed feature dims always do.  GSPMD propagates the split into
    # the per-head einsums (contracted-dim TP when heads < axis).
    qp = shard(C.dense_apply(p["wq"], x), "batch", None, "model")
    kp = shard(C.dense_apply(p["wk"], x), "batch", None, "model")
    vp = shard(C.dense_apply(p["wv"], x), "batch", None, "model")
    q = qp.reshape(b, sq, cfg.n_heads, hd)
    k = kp.reshape(b, sq, cfg.n_kv_heads, hd)
    v = vp.reshape(b, sq, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = C.rmsnorm(p["qn"], q, cfg.norm_eps)
        k = C.rmsnorm(p["kn"], k, cfg.norm_eps)
    q = C.apply_rope(q, positions, cfg.rope_theta)
    k = C.apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def attn_apply_train(p, cfg, x, positions, *, is_local: bool,
                     causal: bool = True, q_chunk=512, k_chunk=512):
    """Full-sequence attention (train / prefill).  Returns (out, (k, v))."""
    q, k, v = _project_qkv(p, cfg, x, positions)
    window = cfg.window if is_local else None
    out = flash_attention(q, k, v, causal=causal, window=window,
                          q_chunk=q_chunk, k_chunk=k_chunk,
                          logit_softcap=cfg.logit_softcap)
    b, sq = x.shape[:2]
    y = C.dense_apply(p["wo"], out.reshape(b, sq, -1))
    return shard(y, "batch", None, None), (k, v)


def attn_apply_decode(p, cfg, x, cache, pos, *, is_local: bool):
    """Single-token decode step. cache: dict(k, v, pos_arr, ins)."""
    b = x.shape[0]
    positions = pos[:, None]
    q, k_new, v_new = _project_qkv(p, cfg, x, positions)
    size = cache["k"].shape[1]
    slot = cache["ins"] % size                  # (B,) ring insertion point
    bi = jnp.arange(b)
    k_cache = cache["k"].at[bi, slot].set(k_new[:, 0])
    v_cache = cache["v"].at[bi, slot].set(v_new[:, 0])
    pos_arr = cache["pos"].at[bi, slot].set(pos)
    window = cfg.window if is_local else None
    out = decode_attention(q, k_cache, v_cache, pos_arr, pos,
                           window=window, logit_softcap=cfg.logit_softcap)
    y = C.dense_apply(p["wo"], out.reshape(b, 1, -1))
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos_arr,
                 "ins": cache["ins"] + 1}
    return y, new_cache


def attn_cache_init(cfg, batch: int, max_len: int, *, is_local: bool,
                    dtype=jnp.bfloat16):
    """KV cache: ring buffer of ``window`` slots for local layers, full
    ``max_len`` for global layers — the long_500k memory story."""
    size = min(cfg.window, max_len) if is_local else max_len
    hd = cfg.resolved_head_dim
    return {
        "k": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "v": jnp.zeros((batch, size, cfg.n_kv_heads, hd), dtype),
        "pos": jnp.full((batch, size), -1, jnp.int32),
        "ins": jnp.zeros((batch,), jnp.int32),
    }


def attn_cache_specs(cfg, is_local: bool, model_axis: int = 16):
    """KV-cache sharding: the kv-head dim goes on the model axis when it
    divides (deepseek/seamless, kv=16); otherwise the head_dim does
    (every assigned arch has head_dim % 16 == 0).  The sequence dim
    carries the logical 'kvseq' axis — resolved to the data axis for the
    long_500k context-parallel decode, None otherwise."""
    if cfg.n_kv_heads % model_axis == 0:
        kv = ("batch", "kvseq", "model", None)
    else:
        kv = ("batch", "kvseq", None, "model")
    return {"k": kv, "v": kv, "pos": ("batch", "kvseq"), "ins": ("batch",)}


def attn_cache_from_prefill(cfg, k, v, *, is_local: bool, max_len: int):
    """Build a decode cache from prefill K/V of shape (B, S, Hkv, D)."""
    b, s_in = k.shape[:2]
    size = min(cfg.window, max_len) if is_local else max_len
    pos_in = jnp.arange(s_in, dtype=jnp.int32)
    if is_local and s_in > size:
        k = k[:, -size:]
        v = v[:, -size:]
        pos_keep = pos_in[-size:]
    else:
        pos_keep = pos_in
    kept = k.shape[1]
    kc = jnp.zeros((b, size, *k.shape[2:]), k.dtype)
    vc = jnp.zeros((b, size, *v.shape[2:]), v.dtype)
    pc = jnp.full((b, size), -1, jnp.int32)
    if is_local:
        # ring layout: token at absolute position p lives in slot p % size
        slots = pos_keep % size
        kc = kc.at[:, slots].set(k)
        vc = vc.at[:, slots].set(v)
        pc = pc.at[:, slots].set(jnp.broadcast_to(pos_keep, (b, kept)))
    else:
        kc = kc.at[:, :kept].set(k)
        vc = vc.at[:, :kept].set(v)
        pc = pc.at[:, :kept].set(jnp.broadcast_to(pos_keep, (b, kept)))
    ins = jnp.full((b,), s_in, jnp.int32)
    return {"k": kc, "v": vc, "pos": pc, "ins": ins}
