"""Mixture-of-Experts FFN with SORTED-TOKEN dispatch.

This is the paper's pJDS row-sort idea applied to expert routing
(DESIGN.md §4): in pJDS, rows are sorted by length so that SIMD blocks
are dense; here, tokens are sorted by assigned expert so that each
expert's batch is a contiguous dense block for the per-expert GEMM.
Token->expert dispatch IS a sparse-matrix product (a one-hot gate matrix
times the token batch); sorting + capacity padding turns it into the
block-dense layout a systolic/vector machine wants — ELLPACK-style
padding (capacity) with a pJDS-style sort to minimise it.

Capacity-based: each expert processes at most C = ceil(T*top_k/E * cf)
tokens; overflow tokens are dropped (standard Switch/GShard semantics).
Expert weight stacks are sharded on the EXPERT axis (expert parallel);
GSPMD pads when n_experts is not divisible by the mesh axis.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from . import common as C
from .sharding import shard

__all__ = ["moe_init", "moe_apply"]


def moe_init(key, cfg, dtype) -> C.Init:
    d, ff, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    gated = cfg.act in ("silu", "geglu")
    ks = C.split_keys(key, 5)
    scale = 1.0 / np.sqrt(d)
    p, s = {}, {}
    p["router"], s["router"] = C.dense_init(ks[0], d, e, (None, None),
                                            jnp.float32)
    # Expert-parallel when E divides the model axis (deepseek: 64 experts);
    # otherwise tensor-parallel inside each expert on the d_ff dim
    # (granite: 40 experts, d_ff 512 -> 32/device).
    ep = (e % 16 == 0)

    def estack(k, i, o, ff_axis):
        w = (jax.random.normal(k, (e, i, o), jnp.float32) * scale).astype(dtype)
        spec = ("expert", None, None) if ep else \
            (None, "model", None) if ff_axis == 1 else (None, None, "model")
        return w, spec
    p["w1"], s["w1"] = estack(ks[1], d, ff, 2)
    if gated:
        p["w3"], s["w3"] = estack(ks[2], d, ff, 2)
    p["w2"], s["w2"] = estack(ks[3], ff, d, 1)
    if cfg.n_shared_experts:
        from .ffn import ffn_init
        p["shared"], s["shared"] = ffn_init(
            ks[4], cfg, dtype, d_ff=ff * cfg.n_shared_experts)
    return p, s


def moe_apply(p, cfg, x):
    """x: (B, S, D) -> (B, S, D)."""
    b, s_len, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s_len
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"]["w"])        # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, k)                    # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    if cfg.moe_dispatch == "onehot":
        return _moe_onehot(p, cfg, x, xt, gates, experts, probs)

    shards = cfg.moe_local_shards
    if shards > 1 and t % shards == 0:
        # §Perf optimization (EXPERIMENTS.md §Perf, deepseek iterations):
        # sort/dispatch PER DATA SHARD with an explicit leading shard
        # axis, so (a) the argsort/scatter never crosses the data axis and
        # (b) the (S, E, C, D) buffer can carry explicit ("batch",
        # "expert") sharding constraints — the expert GEMM is then fully
        # local per (data, model) device pair and the only cross-device
        # move is the token all-to-all, as in a hand-written EP MoE.
        y = _sorted_dispatch_sharded(p, cfg, xt, gates, experts, shards)
    else:
        y = _sorted_dispatch(p, cfg, xt, gates, experts, constrain=True)

    if "shared" in p:
        from .ffn import ffn_apply
        y = y + ffn_apply(p["shared"], cfg, xt.reshape(b, s_len, d)
                          ).reshape(t, d)
    y = y.reshape(b, s_len, d).astype(x.dtype)
    return shard(y, "batch", None, None), _aux_loss(probs, experts, e)


def _sorted_dispatch(p, cfg, xt, gates, experts, *, constrain: bool):
    """Sorted (pJDS-style) dispatch for one token block xt: (T, D)."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k

    # ---- sorted dispatch (the pJDS sort step, applied to tokens) ----
    flat_expert = experts.reshape(-1)                           # (T*k,)
    order = jnp.argsort(flat_expert)                            # stable
    sorted_expert = flat_expert[order]
    # position of each dispatched copy within its expert's batch
    pos_in_expert = jnp.arange(t * k) - jnp.searchsorted(
        sorted_expert, sorted_expert, side="left")
    cap = int(np.ceil(t * k / e * cfg.capacity_factor))
    keep = pos_in_expert < cap
    token_of = order // k                                       # (T*k,)

    # scatter tokens into the (E, C, D) block-dense buffer
    slot = sorted_expert * cap + pos_in_expert
    slot = jnp.where(keep, slot, e * cap)                       # overflow bin
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[slot].set(xt[token_of])
    buf = buf[:-1].reshape(e, cap, d)
    if constrain and e % 16 == 0:  # expert-parallel only when E shards
        buf = shard(buf, "expert", None, None)

    # ---- per-expert dense GEMMs (the block-dense compute pJDS enables) --
    act = C.activation(cfg.act)
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(buf.dtype))
    if "w3" in p:
        h = act(h) * jnp.einsum("ecd,edf->ecf", buf, p["w3"].astype(buf.dtype))
    else:
        h = act(h)
    if constrain:
        if e % 16 == 0:
            h = shard(h, "expert", None, None)
        else:
            h = shard(h, None, None, "model")
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(buf.dtype))

    # ---- combine (unsort + gate-weighted sum) ----
    flat_out = out_buf.reshape(e * cap, d)
    flat_gate = gates.reshape(-1)[order]
    contrib = jnp.where(keep[:, None],
                        flat_out[jnp.minimum(slot, e * cap - 1)], 0)
    contrib = contrib * flat_gate[:, None].astype(contrib.dtype)
    return jnp.zeros((t, d), contrib.dtype).at[token_of].add(contrib)


def _sorted_dispatch_sharded(p, cfg, xt, gates, experts, shards):
    """Batched sorted dispatch with an explicit (data-)shard axis."""
    t, d = xt.shape
    e, k = cfg.n_experts, cfg.top_k
    tl = t // shards
    ep = (e % 16 == 0)
    espec = "expert" if ep else None

    xt_s = shard(xt.reshape(shards, tl, d), "batch", None, None)
    g_s = gates.reshape(shards, tl * k)
    e_s = experts.reshape(shards, tl * k)

    order = jnp.argsort(e_s, axis=1)                        # (S, tl*k)
    sorted_e = jnp.take_along_axis(e_s, order, axis=1)
    first = jax.vmap(lambda se: jnp.searchsorted(se, se, side="left"))(
        sorted_e)
    pos_in_e = jnp.arange(tl * k)[None, :] - first
    cap = int(np.ceil(tl * k / e * cfg.capacity_factor))
    keep = pos_in_e < cap
    token_of = order // k                                    # (S, tl*k)

    slot = sorted_e * cap + pos_in_e
    slot = jnp.where(keep, slot, e * cap)
    gathered = jnp.take_along_axis(xt_s, token_of[..., None], axis=1)
    buf = jnp.zeros((shards, e * cap + 1, d), xt.dtype)
    buf = jax.vmap(lambda b, s_, g: b.at[s_].set(g))(buf, slot, gathered)
    buf = buf[:, :-1].reshape(shards, e, cap, d)
    buf = shard(buf, "batch", espec, None, None)

    act = C.activation(cfg.act)
    h = jnp.einsum("secd,edf->secf", buf, p["w1"].astype(buf.dtype))
    if "w3" in p:
        h = act(h) * jnp.einsum("secd,edf->secf", buf,
                                p["w3"].astype(buf.dtype))
    else:
        h = act(h)
    h = shard(h, "batch", espec, None, None if ep else "model")
    out_buf = jnp.einsum("secf,efd->secd", h, p["w2"].astype(buf.dtype))
    out_buf = shard(out_buf, "batch", espec, None, None)

    flat_out = out_buf.reshape(shards, e * cap, d)
    flat_gate = jnp.take_along_axis(g_s, order, axis=1)
    contrib = jnp.take_along_axis(
        flat_out, jnp.minimum(slot, e * cap - 1)[..., None], axis=1)
    contrib = jnp.where(keep[..., None], contrib, 0)
    contrib = contrib * flat_gate[..., None].astype(contrib.dtype)
    y = jnp.zeros((shards, tl, d), contrib.dtype)
    y = jax.vmap(lambda yy, tok, c: yy.at[tok].add(c))(y, token_of, contrib)
    return shard(y, "batch", None, None).reshape(t, d)


def _moe_onehot(p, cfg, x, xt, gates, experts, probs):
    """BASELINE dispatch: dense one-hot gate matrix (GShard-style einsum).

    This is the 'ELLPACK without the sort' of expert routing — every
    token is multiplied against a (T, E, C) one-hot tensor, materialising
    the full padded dispatch even though only top_k entries per token are
    non-zero.  Kept as the §Perf contrast for the sorted (pJDS-analogue)
    path; selected via ``cfg.moe_dispatch='onehot'``.
    """
    b, s_len, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s_len
    cap = int(np.ceil(t * k / e * cfg.capacity_factor))
    # position of each (token, k) assignment within its expert via cumsum
    onehot = jax.nn.one_hot(experts, e, dtype=jnp.int32)       # (T, k, E)
    flat = onehot.reshape(t * k, e)
    pos = jnp.cumsum(flat, axis=0) - flat                      # (T*k, E)
    pos_in_e = (pos * flat).sum(-1).reshape(t, k)
    keep = pos_in_e < cap
    disp = (jax.nn.one_hot(experts, e, dtype=xt.dtype)[..., :, None]
            * jax.nn.one_hot(pos_in_e, cap, dtype=xt.dtype)[..., None, :]
            * keep[..., None, None].astype(xt.dtype))          # (T,k,E,C)
    buf = jnp.einsum("td,tkec->ecd", xt, disp)                 # (E,C,D)
    act = C.activation(cfg.act)
    h = jnp.einsum("ecd,edf->ecf", buf, p["w1"].astype(buf.dtype))
    if "w3" in p:
        h = act(h) * jnp.einsum("ecd,edf->ecf", buf, p["w3"].astype(buf.dtype))
    else:
        h = act(h)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w2"].astype(buf.dtype))
    combine = disp * gates[..., None, None].astype(xt.dtype)
    y = jnp.einsum("ecd,tkec->td", out_buf, combine)
    if "shared" in p:
        from .ffn import ffn_apply
        y = y + ffn_apply(p["shared"], cfg, x).reshape(t, d)
    y = y.reshape(b, s_len, d).astype(x.dtype)
    return shard(y, "batch", None, None), _aux_loss(probs, experts, e)


def _aux_loss(probs, experts, e):
    """Switch-style load-balancing auxiliary loss."""
    t = probs.shape[0]
    me = probs.mean(0)                                   # (E,) mean router prob
    one_hot = jax.nn.one_hot(experts[:, 0], e, dtype=jnp.float32)
    ce = one_hot.mean(0)                                 # fraction routed (top-1)
    return e * jnp.sum(me * ce)
