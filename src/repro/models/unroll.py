"""Cost-mode switch: fully unroll every lax.scan.

XLA's HloCostAnalysis counts a while-loop body ONCE regardless of trip
count, so the scan-over-layers / flash-pair-scan / chunked-linear-scan
structure that keeps compile times tractable also makes
``cost_analysis()`` useless on the full model.  The dry-run therefore
lowers small UNROLLED variants (reduced depth + sequence) with this flag
on, where every flop is visible, and extrapolates exactly (dryrun.py:
linear model in [1, tokens, attn-pairs] x per-period depth delta).
"""
from __future__ import annotations

import contextlib

_COST_MODE = False


def cost_mode() -> bool:
    return _COST_MODE


def scan_unroll() -> bool | int:
    """Pass as ``unroll=`` to lax.scan: fully unrolled in cost mode."""
    return True if _COST_MODE else 1


@contextlib.contextmanager
def cost_mode_enabled():
    global _COST_MODE
    prev = _COST_MODE
    _COST_MODE = True
    try:
        yield
    finally:
        _COST_MODE = prev
