"""Parse collective traffic out of compiled (SPMD-partitioned) HLO.

``compiled.cost_analysis()`` has no collective-bytes entry, so we walk
the partitioned module text (shapes are PER-DEVICE after SPMD
partitioning) and apply ring-algorithm costs per device:

    all-gather          result R local    -> R * (G-1)/G   (receives rest)
    all-reduce          buffer R local    -> 2R * (G-1)/G  (RS + AG phases)
    reduce-scatter      result R local    -> R * (G-1)     (input = R*G)
    all-to-all          buffer R local    -> R * (G-1)/G
    collective-permute  buffer R local    -> R             (one send)

G = replica-group size parsed from the op.  ``-start``/plain ops are
counted, ``-done`` skipped (async pairs would double count).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)\s+"
    r"(?P<op>all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<suffix>-start|-done)?\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\{?\{([0-9, ]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d.strip():
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Returns {'total': bytes_moved_per_device, per-op dict, 'count': n}."""
    per_op = defaultdict(float)
    counts = defaultdict(int)
    for m in _OP_RE.finditer(hlo_text):
        if m.group("suffix") == "-done":
            continue
        op = m.group("op")
        r = _shape_bytes(m.group("shape"))
        line_end = hlo_text.find("\n", m.end())
        line = hlo_text[m.start():line_end if line_end > 0 else None]
        g = _group_size(line)
        if op == "all-gather":
            moved = r * (g - 1) / max(g, 1)
        elif op == "all-reduce":
            moved = 2 * r * (g - 1) / max(g, 1)
        elif op == "reduce-scatter":
            moved = r * (g - 1)
        elif op == "all-to-all":
            moved = r * (g - 1) / max(g, 1)
        else:  # collective-permute
            moved = r
        per_op[op] += moved
        counts[op] += 1
    return {"total": float(sum(per_op.values())),
            "per_op": dict(per_op), "counts": dict(counts)}


def _group_size(line: str) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:  # iota format [num_groups, group_size]
        return int(m.group(2))
    m = _GROUPS_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(len(ids), 1)
    return 2  # collective-permute / unknown: conservative


def hlo_flops_bytes(cost) -> tuple[float, float]:
    """Pull (flops, bytes) out of compiled.cost_analysis().

    jax >= 0.5 returns a flat dict; 0.4.x returns a one-element list of
    per-device dicts.
    """
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    flops = float(cost.get("flops", 0.0))
    bts = float(cost.get("bytes accessed", 0.0))
    return flops, bts
