"""Serving launcher: continuous-batching engine over a request stream.

    PYTHONPATH=src python -m repro.launch.serve --arch gemma3-4b --smoke \
        [--slots 4] [--requests 8] [--max-new 16]
"""
from __future__ import annotations

import argparse
import time

import numpy as np
import jax

from repro import configs
from repro.models.api import build_model
from repro.serve.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=128)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)

    eng = Engine(model, params, batch_slots=args.slots, max_len=args.max_len)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, (4 + i % 13,))
                    .astype(np.int32),
                    max_new=args.max_new)
            for i in range(args.requests)]
    t0 = time.perf_counter()
    eng.run(reqs)
    dt = time.perf_counter() - t0
    tokens = sum(len(r.out) for r in reqs)
    print(f"{len(reqs)} requests, {tokens} tokens in {dt:.2f}s "
          f"({tokens/dt:.1f} tok/s, {args.slots} slots)")
    for r in reqs[:4]:
        print(f"  req {r.rid}: {list(r.prompt[:4])}... -> {r.out[:8]}")


if __name__ == "__main__":
    main()
