import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e): lower + compile every
(architecture x input-shape x mesh) cell and record memory / cost /
collective analysis for the roofline (deliverable g).

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init (task spec, MULTI-POD DRY-RUN
item 0).  Only this entry point sees 512 placeholder devices.

Usage:
    python -m repro.launch.dryrun --arch gemma3-4b --shape train_4k --mesh single
    python -m repro.launch.dryrun --all [--mesh both] [--out experiments/dryrun]

Each cell writes experiments/dryrun/<mesh>/<arch>__<shape>.json
(existing files are skipped -> the full sweep is resumable).
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.models.api import build_model
from repro.models.sharding import rules_for, use_rules, logical_to_pspec
from repro.models.unroll import cost_mode_enabled
from repro.train.optimizer import AdamW
from repro.train.schedules import cosine
from repro.train.step import (make_train_step, train_state_shardings,
                              specs_to_shardings)
from repro import _compat as compat
from repro.launch.mesh import make_production_mesh
from repro.launch.hlo_analysis import collective_bytes, hlo_flops_bytes


SKIP = {
    # long_500k only for sub-quadratic archs (DESIGN.md §7)
    ("llava-next-mistral-7b", "long_500k"): "full attention at 500k",
    ("granite-moe-3b-a800m", "long_500k"): "full attention at 500k",
    ("deepseek-moe-16b", "long_500k"): "full attention at 500k",
    ("starcoder2-15b", "long_500k"): "full attention at 500k",
    ("minicpm-2b", "long_500k"): "full attention at 500k",
    ("qwen2.5-14b", "long_500k"): "full attention at 500k",
    ("seamless-m4t-medium", "long_500k"): "enc-dec full attention at 500k",
}


def _lower_cell(cfg, shape, mesh, rules, *, q_chunk, k_chunk,
                seq_override=None):
    """Lower (not compile) the cell's step function."""
    model = build_model(cfg)
    with compat.set_mesh(mesh), use_rules(rules):
        batch_sds, batch_spec_tree = model.input_specs(
            shape, seq_override=seq_override)
        batch_sh = specs_to_shardings(batch_spec_tree, mesh, rules)
        params_sds = model.param_shapes()
        param_sh, opt_sh = train_state_shardings(model, mesh, rules)

        if shape.kind == "train":
            opt = AdamW(lr_fn=cosine(3e-4, 100, 10_000))
            opt_sds = jax.eval_shape(opt.init, params_sds)
            step = make_train_step(model, opt, remat=True,
                                   q_chunk=q_chunk, k_chunk=k_chunk)
            return jax.jit(
                step,
                in_shardings=(param_sh, opt_sh, batch_sh),
                out_shardings=(param_sh, opt_sh, None),
            ).lower(params_sds, opt_sds, batch_sds)
        if shape.kind == "prefill":
            max_len = seq_override or shape.seq_len

            def prefill(params, batch):
                return model.prefill(params, batch, max_len=max_len,
                                     q_chunk=q_chunk, k_chunk=k_chunk)
            return jax.jit(
                prefill, in_shardings=(param_sh, batch_sh),
            ).lower(params_sds, batch_sds)
        # decode
        cache_sh = specs_to_shardings(batch_spec_tree["cache"], mesh, rules)
        tok_sh = specs_to_shardings(
            {"tokens": batch_spec_tree["tokens"],
             "pos": batch_spec_tree["pos"]}, mesh, rules)
        return jax.jit(
            model.decode_step,
            in_shardings=(param_sh, cache_sh, tok_sh["tokens"],
                          tok_sh["pos"]),
            out_shardings=(cache_sh, None),
        ).lower(params_sds, batch_sds["cache"], batch_sds["tokens"],
                batch_sds["pos"])


def _cost_of(compiled) -> np.ndarray:
    """(flops, bytes, collective_bytes) vector from a compiled module."""
    cost = compiled.cost_analysis() or {}
    flops, byts = hlo_flops_bytes(cost)
    coll = collective_bytes(compiled.as_text())
    return np.array([flops, byts, coll["total"]])


def _depth_variants(cfg):
    """Depth-variant plan for the cost extrapolation.

    Uniform patterns (K=1): [(small1, small2, count)] with one- and
    two-period configs; count = n_periods.

    Multi-kind patterns (gemma3 5:1, recurrentgemma 1:2): layers don't
    interact in cost, so each KIND's per-layer delta is measured from
    1- vs 2-layer single-kind configs (cheap) and combined by the kind's
    occurrence count over the full depth — instead of unrolling whole
    10/16-layer periods (which took 20+ min/compile on one core).
    """
    from repro.models.transformer import make_plan
    plan = make_plan(cfg, cfg.n_layers)
    k = len(plan.period_kinds)
    if k == 1:
        base = len(plan.prefix_kinds) + len(plan.suffix_kinds)
        n1, n2 = base + 1, base + 2
        e1, e2 = (1, 2) if cfg.is_encdec else (0, 0)
        quad = plan.period_kinds[0] == "global"
        return [(dataclasses.replace(cfg, n_layers=n1, enc_layers=e1),
                 dataclasses.replace(cfg, n_layers=n2, enc_layers=e2),
                 plan.n_periods, quad)]
    all_kinds = (list(plan.prefix_kinds)
                 + list(plan.period_kinds) * plan.n_periods
                 + list(plan.suffix_kinds))
    variants = []
    for kind in dict.fromkeys(plan.period_kinds):  # stable unique
        count = sum(1 for x in all_kinds if x == kind)
        # per-layer cost in S: quadratic only for full (global) attention;
        # local windows, recurrences, and SSM scans are linear — fitting
        # them quadratically extrapolates unstably to 32k+ sequences.
        variants.append((
            dataclasses.replace(cfg, layer_pattern=(kind,), n_layers=1),
            dataclasses.replace(cfg, layer_pattern=(kind,), n_layers=2),
            count, kind == "global"))
    return variants


SEQ_VARS = (2560, 3584, 4096)   # >= all windows; multiples of 512; 3 points
                                # solve [1, S, S^2] exactly


def extrapolated_cost(cfg, shape, mesh, rules, *, q_chunk=512, k_chunk=512):
    """Exact cost reconstruction for scan-structured models.

    XLA counts while bodies once, so we compile small UNROLLED variants:
    cost(depth d, seq S) = alpha(S) + d_periods * beta(S), and both
    alpha/beta are exact polynomials [1, S, S^2] for S >= window (block-
    pair attention is chunk-quadratic, everything else linear/const).
    Returns dict with extrapolated (flops, bytes, collective_bytes).

    Variants run with >=1024-token attention chunks: 4x fewer unrolled
    pair bodies than the 512 default, keeping the biggest unrolled
    variant (gemma3: 16 layers) compilable in minutes on one core.  The
    polynomial stays exact for fixed chunking; attention flops differ
    from the 512-chunk schedule only at masked block edges (<~10%).
    """
    q_chunk = max(q_chunk, 1024)
    k_chunk = max(k_chunk, 1024)
    variants = _depth_variants(cfg)
    compiles = 0
    with cost_mode_enabled():
        if shape.kind == "decode":
            total = None
            for vi, (small1, small2, count, _quad) in enumerate(variants):
                c1 = _cost_of(_lower_cell(small1, shape, mesh, rules,
                                          q_chunk=q_chunk,
                                          k_chunk=k_chunk).compile())
                c2 = _cost_of(_lower_cell(small2, shape, mesh, rules,
                                          q_chunk=q_chunk,
                                          k_chunk=k_chunk).compile())
                beta = c2 - c1
                compiles += 2
                if vi == 0:
                    total = (c1 - beta) + count * beta  # alpha + n*beta
                else:
                    total = total + count * beta
        else:
            seqs = list(SEQ_VARS)
            st = float(shape.seq_len)
            f_quad = np.array([[1.0, s, float(s) * s] for s in seqs])
            f_lin = np.array([[1.0, s] for s in seqs])
            t_quad = np.array([1.0, st, st * st])
            t_lin = np.array([1.0, st])
            total = None
            for vi, (small1, small2, count, quad) in enumerate(variants):
                alphas, betas = [], []
                for s in seqs:
                    c1 = _cost_of(_lower_cell(small1, shape, mesh, rules,
                                              q_chunk=q_chunk,
                                              k_chunk=k_chunk,
                                              seq_override=s).compile())
                    c2 = _cost_of(_lower_cell(small2, shape, mesh, rules,
                                              q_chunk=q_chunk,
                                              k_chunk=k_chunk,
                                              seq_override=s).compile())
                    betas.append(c2 - c1)
                    alphas.append(2 * c1 - c2)
                    compiles += 2
                feats, ft = (f_quad, t_quad) if quad else (f_lin, t_lin)
                beta_t = ft @ np.linalg.lstsq(feats, np.array(betas),
                                              rcond=None)[0]
                if vi == 0:
                    # alpha (embed/head/loss/optimizer) is linear in S
                    alpha_t = t_lin @ np.linalg.lstsq(
                        f_lin, np.array(alphas), rcond=None)[0]
                    total = alpha_t + count * beta_t
                else:
                    total = total + count * beta_t
    return {"flops": float(total[0]), "bytes": float(total[1]),
            "collective_bytes": float(total[2]),
            "n_variant_compiles": compiles}


def dryrun_cell(arch: str, shape_name: str, mesh_name: str,
                q_chunk: int = 512, k_chunk: int = 512,
                with_cost: bool = True, attn_impl: str = "pairs",
                overrides: dict | None = None) -> dict:
    """Lower + compile one cell; returns the analysis record.

    ``attn_impl`` / ``overrides`` (ArchConfig fields) are the §Perf
    hillclimbing knobs; baselines use the defaults.
    """
    import contextlib
    from repro.models.attention import use_attn_impl
    with contextlib.ExitStack() as stack:
        stack.enter_context(use_attn_impl(attn_impl))
        return _dryrun_cell_inner(arch, shape_name, mesh_name, q_chunk,
                                  k_chunk, with_cost, attn_impl, overrides)


def _dryrun_cell_inner(arch, shape_name, mesh_name, q_chunk, k_chunk,
                       with_cost, attn_impl, overrides):
    if (arch, shape_name) in SKIP:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_name,
                "status": "skipped", "reason": SKIP[(arch, shape_name)]}
    cfg = configs.get(arch)
    if overrides:
        cfg = dataclasses.replace(cfg, **overrides)
    shape = configs.SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    rules = rules_for(shape.kind, shape.global_batch, dict(mesh.shape))

    t0 = time.time()
    lowered = _lower_cell(cfg, shape, mesh, rules, q_chunk=q_chunk,
                          k_chunk=k_chunk)
    compiled = lowered.compile()
    t_compile = time.time() - t0

    cost = compiled.cost_analysis() or {}
    flops, byts = hlo_flops_bytes(cost)
    mem = compiled.memory_analysis()
    mem_rec = {}
    for attr in ("argument_size_in_bytes", "output_size_in_bytes",
                 "temp_size_in_bytes", "generated_code_size_in_bytes",
                 "alias_size_in_bytes"):
        if hasattr(mem, attr):
            mem_rec[attr] = int(getattr(mem, attr))
    coll = collective_bytes(compiled.as_text())
    chips = 1
    for v in dict(mesh.shape).values():
        chips *= v
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "status": "ok", "chips": chips,
        "attn_impl": attn_impl,
        "overrides": overrides or {},
        "compile_s": round(t_compile, 1),
        "hlo_flops_raw": flops, "hlo_bytes_raw": byts,
        "collective_raw": coll,
        "memory": mem_rec,
        "n_params": cfg.n_params(),
        "n_active_params": cfg.n_active_params(),
        "tokens": shape.global_batch * (1 if shape.kind == "decode"
                                        else shape.seq_len),
        "rules": {k: list(v) if isinstance(v, tuple) else v
                  for k, v in rules.items()},
    }
    if with_cost:
        t1 = time.time()
        rec["cost"] = extrapolated_cost(cfg, shape, mesh, rules,
                                        q_chunk=q_chunk, k_chunk=k_chunk)
        rec["cost"]["variant_compile_s"] = round(time.time() - t1, 1)
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi",
                                                         "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--no-cost", action="store_true",
                    help="skip the unrolled cost-extrapolation variants")
    args = ap.parse_args()

    archs = configs.ARCH_IDS if (args.all or not args.arch) else [args.arch]
    shapes = list(configs.SHAPES) if (args.all or not args.shape) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    for mesh_name in meshes:
        outdir = os.path.join(args.out, mesh_name)
        os.makedirs(outdir, exist_ok=True)
        for arch in archs:
            for shape in shapes:
                fname = os.path.join(outdir, f"{arch}__{shape}.json")
                if os.path.exists(fname) and not args.force:
                    print(f"[skip-existing] {mesh_name}/{arch}/{shape}")
                    continue
                print(f"[dryrun] {mesh_name}/{arch}/{shape} ...", flush=True)
                try:
                    rec = dryrun_cell(arch, shape, mesh_name,
                                      with_cost=not args.no_cost)
                except Exception as e:  # noqa: BLE001 - record and continue
                    rec = {"arch": arch, "shape": shape, "mesh": mesh_name,
                           "status": "error", "error": repr(e),
                           "traceback": traceback.format_exc()[-2000:]}
                with open(fname, "w") as f:
                    json.dump(rec, f, indent=1)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    c = rec.get("cost") or {}
                    extra = (f" flops={c.get('flops', rec['hlo_flops_raw']):.3e}"
                             f" coll={rec['collective_raw']['total']:.3e}B"
                             f" compile={rec['compile_s']}s")
                print(f"[done] {mesh_name}/{arch}/{shape}: {status}{extra}",
                      flush=True)


if __name__ == "__main__":
    main()
