"""Production training launcher.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-14b \
        [--smoke] [--steps N] [--mesh host|single] [--ckpt DIR]

--mesh host   : 1-D data mesh over however many devices exist (the real
                execution path on this box; use XLA_FLAGS to fake more).
--mesh single : the production (16,16) mesh — only valid on real
                hardware of that size; on this box use dryrun.py instead.
--smoke       : reduced same-family config (CPU-runnable end to end).

Fault tolerance: auto-resumes from the latest committed checkpoint in
--ckpt; straggler watchdog logs slow steps (see train/loop.py).
"""
from __future__ import annotations

import argparse
import dataclasses

import jax

from repro import _compat as compat
from repro import configs
from repro.models.api import build_model
from repro.models.sharding import (DEFAULT_SINGLE_POD, set_rules)
from repro.train.optimizer import AdamW
from repro.train.schedules import wsd, cosine
from repro.train.step import (make_train_step, train_state_shardings)
from repro.train.loop import train
from repro.data.pipeline import for_config
from repro.launch.mesh import make_production_mesh, make_host_mesh


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=configs.ARCH_IDS)
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--schedule", default="wsd", choices=["wsd", "cosine"])
    ap.add_argument("--mesh", default="host", choices=["host", "single"])
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    args = ap.parse_args()

    cfg = configs.smoke(args.arch) if args.smoke else configs.get(args.arch)
    model = build_model(cfg)
    if args.mesh == "single":
        mesh = make_production_mesh()
        rules = dict(DEFAULT_SINGLE_POD)
    else:
        mesh = make_host_mesh()
        rules = {"batch": ("data",), "model": None, "expert": None,
                 "seq": None, "kvseq": None}

    lr_fn = (wsd(args.lr, warmup=max(args.steps // 10, 1),
                 stable=args.steps // 2, decay=args.steps // 3)
             if args.schedule == "wsd"
             else cosine(args.lr, max(args.steps // 10, 1), args.steps))
    opt = AdamW(lr_fn=lr_fn)

    with compat.set_mesh(mesh):
        set_rules(rules)
        param_sh, opt_sh = train_state_shardings(model, mesh, rules)
        params = jax.jit(model.init, out_shardings=param_sh)(
            jax.random.PRNGKey(0))
        opt_state = jax.jit(opt.init, out_shardings=opt_sh)(params)
        n = sum(x.size for x in jax.tree.leaves(params))
        print(f"arch={cfg.name} params={n/1e6:.1f}M mesh={dict(mesh.shape)} "
              f"devices={mesh.devices.size}")
        step = jax.jit(make_train_step(model, opt, q_chunk=128, k_chunk=128),
                       in_shardings=(param_sh, opt_sh, None),
                       out_shardings=(param_sh, opt_sh, None))
        data = for_config(cfg, batch=args.batch, seq=args.seq)
        train(step_fn=step, params=params, opt_state=opt_state, data=data,
              steps=args.steps, ckpt_dir=args.ckpt,
              ckpt_every=args.ckpt_every)
        set_rules(None)


if __name__ == "__main__":
    main()
