"""Production mesh construction (task spec: MULTI-POD DRY-RUN item 1).

Defined as FUNCTIONS so importing this module never touches jax device
state; ``jax.make_mesh`` is only called when a launcher actually runs.
jax-version differences (AxisType absent on 0.4.x) are handled by
``repro._compat.make_mesh``.

Topology: TPU v5e, 256 chips/pod as a (16, 16) = (data, model) grid;
multi-pod adds the leading "pod" axis (2 pods = 512 chips) used for
data parallelism across the DCN/ICI pod boundary.
"""
from __future__ import annotations

import jax

from repro._compat import make_mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_host_mesh(n: int | None = None, axis: str = "data"):
    """1-D mesh over however many (host) devices exist — used by the
    distributed-spMVM examples and tests."""
    n = n or len(jax.devices())
    return make_mesh((n,), (axis,))
