"""repro: SELL-C-sigma / pJDS spMVM and Krylov solvers in JAX+Pallas.

Lazy top-level API (PEP 562) — importing ``repro`` stays cheap; the
heavy submodules load on first attribute access::

    import repro
    res = repro.solve(m, b, method="cg")         # the solver front door
    op = repro.operator(m)                       # y = op @ x
    dop = repro.dist_operator(m, mesh)           # mesh-distributed

Everything else lives in the submodules: ``repro.core`` (formats,
matrices, solvers, perf model), ``repro.kernels`` (device kernels and
dispatch), ``repro.tune`` (autotuner), ``repro.serve`` (engines).
"""
from __future__ import annotations

__all__ = ["solve", "SolveResult", "SolveFailure", "operator",
           "dist_operator", "load_mm", "save_mm", "preprocess"]

_LAZY = {
    "solve": "repro.api",
    "SolveResult": "repro.core.solvers",
    "SolveFailure": "repro.api",
    "operator": "repro.core.operator",
    "dist_operator": "repro.core.operator",
    "load_mm": "repro.core.io_mm",
    "save_mm": "repro.core.io_mm",
    "preprocess": "repro.core.reorder",
}


def __getattr__(name: str):
    target = _LAZY.get(name)
    if target is None:
        raise AttributeError(f"module 'repro' has no attribute {name!r}")
    import importlib
    return getattr(importlib.import_module(target), name)


def __dir__():
    return sorted(set(globals()) | set(_LAZY))
