"""Fault-injection harness: controlled chaos for the solve pipeline.

Each context manager injects ONE failure mode from the DESIGN.md §11
taxonomy and restores clean state on exit, so a chaos test reads as

    with faults.poison_values(m, count=3):
        res = repro.solve(m, b, fallback="off")
    assert res.status == "non_finite"

Injection points and their caveats:

* **Values** (:func:`poison_values`) mutate the HOST matrix in place —
  the fault reaches the device only through builds that happen inside
  the ``with`` block.  Operators built before the block stay clean.
* **Tune cache** (:func:`corrupt_tune_cache`) mangles the JSON file on
  disk in a chosen ``mode``; the loader/quarantine layer must degrade
  to a re-measurement, never crash.
* **Solve paths** (:func:`fail_strategy`, :func:`fail_kernel_backend`)
  monkeypatch ``repro.api._one_solve`` so selected ladder rungs raise
  — the way a bad kernel launch or an XLA lowering bug would surface.
  These are patch-at-call-time faults and need no rebuild.
* **Halo exchange** (:func:`drop_halo`, :func:`garble_halo`) patch the
  ``dist_spmv`` exchange primitives.  jax traces capture the patched
  function, so the distributed matvec must be TRACED inside the block
  (build the operator / first call inside ``with``); closures traced
  earlier keep their healthy exchange.  ``garble_halo`` corrupts the
  received buffer as a function of the iterate — per-call-INCONSISTENT
  on purpose: a consistently wrong exchange is just a different linear
  operator, which a Krylov solve happily "solves" and certifies.  An
  x-dependent corruption breaks linearity, which the breakdown /
  stagnation detectors and the certification arbiter can actually see.
  ``drop_halo`` (zeroed halo) IS a consistent wrong operator — tests
  using it must certify out-of-band against the clean matrix.
"""
from __future__ import annotations

import contextlib
import json
import pathlib

import numpy as np

__all__ = [
    "poison_values",
    "corrupt_tune_cache",
    "fail_strategy",
    "fail_kernel_backend",
    "drop_halo",
    "garble_halo",
    "InjectedFault",
]


class InjectedFault(RuntimeError):
    """Raised by the forced-failure patches; lets tests distinguish the
    injected fault from a genuine one."""


# --------------------------------------------------------------------------
# Data faults
# --------------------------------------------------------------------------
@contextlib.contextmanager
def poison_values(m, *, count: int = 1, value: float = float("nan"),
                  seed: int = 0):
    """Overwrite ``count`` stored values of host CSR ``m`` with
    ``value`` (NaN by default), restoring them on exit."""
    from repro.kernels import ops as K
    data = np.asarray(m.data)
    if data.size == 0:
        raise ValueError("cannot poison a matrix with no stored values")
    rng = np.random.default_rng(seed)
    idx = rng.choice(data.size, size=min(count, data.size), replace=False)
    saved = data[idx].copy()
    data[idx] = value
    # the device-build cache keys on the host object's id — an in-place
    # mutation aliases stale builds both ways (clean build hiding the
    # poison on entry, poisoned build surviving the restore on exit)
    K.clear_device_cache()
    try:
        yield m
    finally:
        data[idx] = saved
        K.clear_device_cache()


# --------------------------------------------------------------------------
# Tune-cache faults
# --------------------------------------------------------------------------
def _rewrite_records(path: pathlib.Path, fn):
    payload = json.loads(path.read_text())
    entries = payload.get("entries", {})
    payload["entries"] = {k: fn(v) for k, v in entries.items()}
    path.write_text(json.dumps(payload))


@contextlib.contextmanager
def corrupt_tune_cache(path, mode: str = "truncate"):
    """Mangle the tune-cache file at ``path``; original bytes restored
    on exit.  ``mode``:

    * ``"truncate"`` — cut the file mid-JSON (crashed writer).
    * ``"garbage"``  — replace with non-JSON bytes.
    * ``"bad_schema"`` — stamp every record ``schema: 999`` (written
      by a future version).
    * ``"missing_keys"`` — strip every record down to its stamp
      (hand-edited into uselessness).
    """
    p = pathlib.Path(path)
    orig = p.read_bytes() if p.exists() else None
    if mode == "truncate":
        if orig is None:
            raise FileNotFoundError(p)
        p.write_bytes(orig[: max(1, len(orig) // 2)])
    elif mode == "garbage":
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_bytes(b"\x00not json at all{{{")
    elif mode == "bad_schema":
        _rewrite_records(p, lambda rec: {**rec, "schema": 999}
                         if isinstance(rec, dict) else rec)
    elif mode == "missing_keys":
        _rewrite_records(p, lambda rec: {"schema": rec.get("schema")}
                         if isinstance(rec, dict) else rec)
    else:
        raise ValueError(f"unknown corrupt_tune_cache mode {mode!r}")
    try:
        yield p
    finally:
        if orig is None:
            p.unlink(missing_ok=True)
        else:
            p.write_bytes(orig)


# --------------------------------------------------------------------------
# Solve-path faults
# --------------------------------------------------------------------------
@contextlib.contextmanager
def fail_strategy(*strategies: str):
    """Make ``api._one_solve`` raise :class:`InjectedFault` for the
    given strategies (``"fused"``, ``"composed"``) — a ladder rung that
    dies the way a broken lowering does."""
    from repro import api
    orig = api._one_solve

    def patched(op, b, *, strategy, **kw):
        if strategy in strategies:
            raise InjectedFault(f"injected {strategy} failure")
        return orig(op, b, strategy=strategy, **kw)

    api._one_solve = patched
    try:
        yield
    finally:
        api._one_solve = orig


@contextlib.contextmanager
def fail_kernel_backend():
    """Make ``api._one_solve`` raise :class:`InjectedFault` whenever the
    operator resolves to the Pallas kernel backend — simulates a kernel
    launch failure; only the ``kernel->ref`` rung (and beyond) can
    succeed."""
    from repro import api
    from repro.kernels import ops as K
    orig = api._one_solve

    def patched(op, b, **kw):
        backend = getattr(op, "backend", None)
        if backend is not None and K.resolve_backend(backend) == "kernel":
            raise InjectedFault("injected kernel-launch failure")
        return orig(op, b, **kw)

    api._one_solve = patched
    try:
        yield
    finally:
        api._one_solve = orig


# --------------------------------------------------------------------------
# Halo-exchange faults (distributed operator)
# --------------------------------------------------------------------------
@contextlib.contextmanager
def drop_halo():
    """Zero the received halo buffer — a silently wrong but CONSISTENT
    linear operator (a lost message every call).  In-band certification
    cannot see this (it certifies through the same broken operator);
    tests must check against the clean matrix out-of-band."""
    from repro.core import dist_spmv as D
    of, og = D._exchange_halo_full, D._exchange_halo_gathered

    def full(x_blk, axis, n_dev, halo_w):
        return jnp_zeros_like(of(x_blk, axis, n_dev, halo_w))

    def gathered(x_blk, *a, **kw):
        return jnp_zeros_like(og(x_blk, *a, **kw))

    def jnp_zeros_like(ext):
        import jax.numpy as jnp
        return jnp.zeros_like(ext)

    D._exchange_halo_full = full
    D._exchange_halo = full
    D._exchange_halo_gathered = gathered
    try:
        yield
    finally:
        D._exchange_halo_full = of
        D._exchange_halo = of
        D._exchange_halo_gathered = og


@contextlib.contextmanager
def garble_halo(scale: float = 1.0):
    """Corrupt the received halo with an iterate-dependent term —
    per-call-inconsistent, so the effective operator is NOT linear and
    the solver's breakdown/divergence detectors (or the certification
    arbiter) catch it instead of converging to a wrong answer."""
    from repro.core import dist_spmv as D
    import jax.numpy as jnp
    of, og = D._exchange_halo_full, D._exchange_halo_gathered

    def _garble(ext, x_blk):
        # nonlinear in x: breaks the Krylov invariants every iteration
        noise = jnp.tanh(jnp.sum(x_blk.astype(jnp.float32)) * 7.0) + 0.5
        return ext + scale * noise * jnp.sign(ext)

    def full(x_blk, axis, n_dev, halo_w):
        return _garble(of(x_blk, axis, n_dev, halo_w), x_blk)

    def gathered(x_blk, *a, **kw):
        return _garble(og(x_blk, *a, **kw), x_blk)

    D._exchange_halo_full = full
    D._exchange_halo = full
    D._exchange_halo_gathered = gathered
    try:
        yield
    finally:
        D._exchange_halo_full = of
        D._exchange_halo = of
        D._exchange_halo_gathered = og
