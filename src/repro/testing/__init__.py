"""Test-support utilities: the fault-injection harness.

``repro.testing.faults`` holds the chaos toolbox behind
``tests/test_robustness.py`` — context managers that inject the
failure modes DESIGN.md §11 claims the solve pipeline survives.
"""
from repro.testing import faults

__all__ = ["faults"]
