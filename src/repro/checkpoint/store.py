"""Fault-tolerant sharded checkpointing with elastic restore.

Layout:  <dir>/step_<N>/
             manifest.json     tree structure, shapes, dtypes, shardings
             leaf_<i>.npy      one file per pytree leaf
             _COMMITTED        written last -> atomic visibility

Properties needed at 1000+ nodes, scaled to this box:
* **Atomic commit** — writers stage into ``step_N.tmp`` and rename; a
  crash mid-save never corrupts the latest checkpoint; ``latest_step``
  only considers committed dirs.
* **Async save** — ``save_async`` snapshots to host memory synchronously
  (device_get) and writes in a background thread, so the train loop
  blocks only for the copy, not the I/O.
* **Elastic restore** — leaves are stored unsharded; ``restore`` takes a
  target sharding tree for the CURRENT mesh, so a job restarted on a
  different topology (node failure, pod shrink) re-shards transparently.
  (At real scale each host writes its shard slice; the manifest format
  already records the source PartitionSpec for that extension.)
* Data-pipeline state and the step counter ride along -> exact resume.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np


def _leaf_paths(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def save(path: str, step: int, tree: Any, extra: Optional[dict] = None,
         spec_tree: Any = None) -> str:
    """Synchronous atomic save. Returns the committed directory."""
    leaves, treedef = _leaf_paths(tree)
    host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
    return _write(path, step, host_leaves, treedef, extra, spec_tree)


def _write(path, step, host_leaves, treedef, extra, spec_tree):
    final = os.path.join(path, f"step_{step:010d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [{"file": f"leaf_{i}.npy", "shape": list(x.shape),
                    "dtype": str(x.dtype)} for i, x in enumerate(host_leaves)],
        "extra": extra or {},
        "specs": jax.tree.map(
            lambda s: list(s), spec_tree,
            is_leaf=lambda s: isinstance(s, tuple)) if spec_tree else None,
    }
    for i, x in enumerate(host_leaves):
        np.save(os.path.join(tmp, f"leaf_{i}.npy"), x)
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    with open(os.path.join(tmp, "_COMMITTED"), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    return final


class AsyncCheckpointer:
    """Snapshot synchronously, write in a background thread."""

    def __init__(self):
        self._thread: Optional[threading.Thread] = None

    def save(self, path: str, step: int, tree: Any,
             extra: Optional[dict] = None, spec_tree: Any = None) -> None:
        self.wait()
        leaves, treedef = _leaf_paths(tree)
        host_leaves = [np.asarray(jax.device_get(x)) for x in leaves]
        self._thread = threading.Thread(
            target=_write, args=(path, step, host_leaves, treedef, extra,
                                 spec_tree), daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(path: str) -> Optional[int]:
    if not os.path.isdir(path):
        return None
    best = None
    for d in os.listdir(path):
        if d.startswith("step_") and not d.endswith(".tmp"):
            full = os.path.join(path, d)
            if os.path.exists(os.path.join(full, "_COMMITTED")):
                best = max(best or -1, int(d[5:]))
    return best


def restore(path: str, step: int, target_tree: Any,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``target_tree`` (a pytree of arrays or
    ShapeDtypeStructs).  ``shardings``: optional matching tree of
    NamedShardings for the CURRENT mesh (elastic restore)."""
    d = os.path.join(path, f"step_{step:010d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    leaves, treedef = jax.tree.flatten(target_tree)
    if len(leaves) != len(manifest["leaves"]):
        raise ValueError(
            f"checkpoint has {len(manifest['leaves'])} leaves, "
            f"target expects {len(leaves)}")
    host = [np.load(os.path.join(d, m["file"]))
            for m in manifest["leaves"]]
    shard_leaves = (jax.tree.leaves(
        shardings, is_leaf=lambda s: hasattr(s, "spec"))
        if shardings is not None else [None] * len(host))
    out = []
    for x, tgt, sh in zip(host, leaves, shard_leaves):
        arr = x.astype(tgt.dtype) if hasattr(tgt, "dtype") else x
        out.append(jax.device_put(arr, sh) if sh is not None
                   else jax.numpy.asarray(arr))
    return treedef.unflatten(out), manifest["extra"]
