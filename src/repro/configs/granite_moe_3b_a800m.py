"""granite-moe-3b-a800m [moe]: 40 experts, top-8, fine-grained.

32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert) vocab=49155.
[hf:ibm-granite/granite-3.0 family]

MoE dispatch uses the sorted-token formulation — the pJDS row-sort idea
applied to expert routing (DESIGN.md §4).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    n_layers=32,
    d_model=1536,
    n_heads=24,
    n_kv_heads=8,
    d_ff=512,
    vocab=49155,
    head_dim=64,
    act="silu",
    tie_embeddings=True,
    n_experts=40,
    top_k=8,
    # §Perf (EXPERIMENTS.md): per-data-shard sorted dispatch
    moe_local_shards=16,
    subquadratic=False,
)

SMOKE_CONFIG = ArchConfig(
    name="granite-moe-3b-a800m-smoke",
    family="moe",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=32,
    vocab=512,
    head_dim=16,
    act="silu",
    tie_embeddings=True,
    n_experts=8,
    top_k=2,
    subquadratic=False,
    param_dtype="float32",
    activation_dtype="float32",
)
