"""seamless-m4t-medium [audio]: encoder-decoder multimodal backbone.

12L encoder + 12L decoder, d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=256206. [arXiv:2308.11596]

The speech frontend is a STUB per the task spec: ``input_specs`` supplies
precomputed frame embeddings for the encoder; the decoder is a standard
causal transformer with cross-attention.  Cross-attention K/V are
computed once from the encoder output at prefill and kept on device — the
paper §3 remark that "parts of those vectors may be kept on the device"
applied to serving.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="seamless-m4t-medium",
    family="audio",
    n_layers=12,
    enc_layers=12,
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    d_ff=4096,
    vocab=256_206,
    head_dim=64,
    act="gelu",
    tie_embeddings=False,
    frontend="audio",
    frontend_seq=1024,
    subquadratic=False,
)

SMOKE_CONFIG = ArchConfig(
    name="seamless-m4t-medium-smoke",
    family="audio",
    n_layers=2,
    enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=128,
    vocab=512,
    head_dim=16,
    act="gelu",
    tie_embeddings=False,
    frontend="audio",
    frontend_seq=16,
    subquadratic=False,
    param_dtype="float32",
    activation_dtype="float32",
)
