"""minicpm-2b [dense]: llama-like architecture trained with the WSD
(warmup-stable-decay) schedule — the schedule is implemented in
``repro.train.schedules`` and exercised by the training example.

40L d_model=2304 36H (MHA kv=36) d_ff=5760 vocab=122753. [arXiv:2404.06395]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="minicpm-2b",
    family="dense",
    n_layers=40,
    d_model=2304,
    n_heads=36,
    n_kv_heads=36,
    d_ff=5760,
    vocab=122_753,
    head_dim=64,
    act="silu",
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE_CONFIG = ArchConfig(
    name="minicpm-2b-smoke",
    family="dense",
    n_layers=2,
    d_model=72,
    n_heads=6,
    n_kv_heads=6,
    d_ff=144,
    vocab=512,
    head_dim=12,
    act="silu",
    tie_embeddings=True,
    subquadratic=False,
    param_dtype="float32",
    activation_dtype="float32",
)
