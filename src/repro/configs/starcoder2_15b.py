"""starcoder2-15b [dense]: GQA + RoPE code model.

40L d_model=6144 48H (GQA kv=4) d_ff=24576 vocab=49152. [arXiv:2402.19173]
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="starcoder2-15b",
    family="dense",
    n_layers=40,
    d_model=6144,
    n_heads=48,
    n_kv_heads=4,
    d_ff=24576,
    vocab=49152,
    head_dim=128,
    qkv_bias=True,
    rope_theta=100_000.0,
    act="gelu",
    tie_embeddings=True,
    subquadratic=False,
)

SMOKE_CONFIG = ArchConfig(
    name="starcoder2-15b-smoke",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=256,
    vocab=512,
    head_dim=16,
    qkv_bias=True,
    act="gelu",
    tie_embeddings=True,
    subquadratic=False,
    param_dtype="float32",
    activation_dtype="float32",
)
