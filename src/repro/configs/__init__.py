from .base import (
    ARCH_IDS,
    ArchConfig,
    SHAPES,
    ShapeConfig,
    get,
    list_archs,
    smoke,
)

__all__ = ["ARCH_IDS", "ArchConfig", "SHAPES", "ShapeConfig", "get",
           "list_archs", "smoke"]
