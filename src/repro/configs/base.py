"""Architecture configuration schema + registry.

One ``<arch>.py`` per assigned architecture lives next to this file; each
exports ``CONFIG`` (full published size) and ``SMOKE_CONFIG`` (a reduced
same-family config for CPU smoke tests).  ``repro.configs.get(name)``
resolves either.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Tuple

__all__ = ["ArchConfig", "ShapeConfig", "SHAPES", "get", "list_archs",
           "smoke", "ARCH_IDS"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int | None = None
    # layer pattern, cycled over depth. entries: global|local|recurrent|mamba
    layer_pattern: Tuple[str, ...] = ("global",)
    window: int = 4096              # local-attention window
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    logit_softcap: float = 0.0
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"               # silu | gelu | geglu (geglu = gated gelu)
    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    first_k_dense: int = 0          # leading dense-FFN layers (deepseek-moe)
    capacity_factor: float = 1.25
    moe_dispatch: str = "sorted"    # sorted (pJDS-style) | onehot (baseline)
    moe_local_shards: int = 0       # >1: sort/dispatch per data shard (vmap)
                                    # so routing never crosses the data axis
    # SSM (mamba1)
    ssm_state: int = 0
    d_inner: int = 0
    conv_width: int = 4
    dt_rank: int = 0
    ssm_scan_chunk: int = 0   # 0 = auto (128; collapsed in cost mode);
                              # >0 = fixed, honoured even in cost mode
    # encoder-decoder
    enc_layers: int = 0
    # modality frontend stub: precomputed embeddings are a model INPUT
    frontend: str | None = None     # vision | audio
    frontend_seq: int = 0           # patches / frames per example
    # paper technique hook: FFN weight density (<1 -> pJDS SparseFFN)
    sparse_ffn_density: float = 1.0
    # §Perf variant: parallel attention+MLP residual block (PaLM-style)
    # -> the two row-parallel partial sums share ONE all-reduce per layer
    parallel_block: bool = False
    # capability flags
    subquadratic: bool = False      # may run long_500k
    # dtypes
    param_dtype: str = "bfloat16"
    activation_dtype: str = "bfloat16"

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim if self.head_dim else self.d_model // self.n_heads

    @property
    def is_encdec(self) -> bool:
        return self.enc_layers > 0

    def pattern_at(self, i: int) -> str:
        return self.layer_pattern[i % len(self.layer_pattern)]

    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks), for 6ND."""
        d, ff, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_layer = {}
        att = d * hd * self.n_heads + 2 * d * hd * self.n_kv_heads + hd * self.n_heads * d
        mlp_mult = 3 if self.act in ("silu", "geglu") else 2
        dense_mlp = mlp_mult * d * ff
        moe_mlp = (self.n_experts + self.n_shared_experts) * mlp_mult * d * ff \
            + d * self.n_experts
        if self.d_inner:
            mamba = (2 * d * self.d_inner            # in_proj
                     + self.conv_width * self.d_inner
                     + self.d_inner * (max(self.dt_rank, 1) + 2 * self.ssm_state)
                     + max(self.dt_rank, 1) * self.d_inner
                     + self.d_inner * self.ssm_state  # A
                     + self.d_inner * d)              # out_proj
        else:
            mamba = 0
        rec = (3 * d * self.d_inner + self.conv_width * self.d_inner
               + 2 * self.d_inner + self.d_inner * d) if self.d_inner else 0
        total = emb
        n_blocks = self.n_layers + self.enc_layers
        for i in range(n_blocks):
            pat = self.pattern_at(i)
            if pat == "mamba":
                total += mamba
            elif pat == "recurrent":
                total += rec + dense_mlp
            else:
                total += att + (moe_mlp if (self.n_experts and i >= self.first_k_dense)
                                else dense_mlp)
        return int(total)

    def n_active_params(self) -> int:
        """Active parameters per token (MoE: top_k + shared experts only)."""
        if not self.n_experts:
            return self.n_params()
        d, ff = self.d_model, self.d_ff
        mlp_mult = 3 if self.act in ("silu", "geglu") else 2
        full = self.n_params()
        inactive = (self.n_experts - self.top_k) * mlp_mult * d * ff \
            * max(self.n_layers - self.first_k_dense, 0)
        return int(full - inactive)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str            # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "llava-next-mistral-7b",
    "recurrentgemma-2b",
    "falcon-mamba-7b",
    "granite-moe-3b-a800m",
    "deepseek-moe-16b",
    "gemma3-4b",
    "starcoder2-15b",
    "minicpm-2b",
    "qwen2.5-14b",
    "seamless-m4t-medium",
]


def _module(name: str):
    return importlib.import_module(f"repro.configs.{name.replace('-', '_').replace('.', '_')}")


def get(name: str) -> ArchConfig:
    return _module(name).CONFIG


def smoke(name: str) -> ArchConfig:
    return _module(name).SMOKE_CONFIG


def list_archs():
    return list(ARCH_IDS)
