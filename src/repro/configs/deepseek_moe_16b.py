"""deepseek-moe-16b [moe]: fine-grained 64 routed experts top-6 + 2 shared.

28L d_model=2048 16H (MHA kv=16) d_ff=1408 (per expert) vocab=102400.
[arXiv:2401.06066]

First layer uses a dense FFN (first_k_dense=1) as in the published model;
dense-layer width = d_ff * (top_k + shared) = 11264 (paper: 10944).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=102400,
    head_dim=128,
    act="silu",
    tie_embeddings=False,
    n_experts=64,
    n_shared_experts=2,
    top_k=6,
    first_k_dense=1,
    # §Perf (EXPERIMENTS.md): per-data-shard sorted dispatch — 15x lower
    # collective bound vs the global sort on the (16,16) mesh
    moe_local_shards=16,
    subquadratic=False,
)

SMOKE_CONFIG = ArchConfig(
    name="deepseek-moe-16b-smoke",
    family="moe",
    n_layers=3,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    d_ff=32,
    vocab=512,
    head_dim=16,
    act="silu",
    tie_embeddings=False,
    n_experts=8,
    n_shared_experts=2,
    top_k=2,
    first_k_dense=1,
    subquadratic=False,
    param_dtype="float32",
    activation_dtype="float32",
)
