"""recurrentgemma-2b [hybrid]: RG-LRU + local attention, 1:2 ratio.

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000. [arXiv:2402.19427]
Block pattern (recurrent, recurrent, local-attn) repeating; window 2048;
GeGLU MLP; lru_width = d_model. Sub-quadratic -> runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256_000,
    head_dim=256,
    layer_pattern=("recurrent", "recurrent", "local"),
    window=2048,
    act="geglu",
    tie_embeddings=True,
    d_inner=2560,
    conv_width=4,
    subquadratic=True,
)

SMOKE_CONFIG = ArchConfig(
    name="recurrentgemma-2b-smoke",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    d_ff=128,
    vocab=512,
    head_dim=16,
    layer_pattern=("recurrent", "recurrent", "local"),
    window=16,
    act="geglu",
    tie_embeddings=True,
    d_inner=64,
    conv_width=4,
    subquadratic=True,
    param_dtype="float32",
    activation_dtype="float32",
)
