"""llava-next-mistral-7b [vlm]: Mistral-7B backbone + anyres vision stub.

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=32000.
[hf:llava-hf/llava-v1.6-mistral-7b-hf]

The anyres tiling frontend is a STUB per the task spec: ``input_specs``
supplies precomputed patch embeddings (anyres base tile 24x24 = 576
patches) which the model prepends to the text embeddings.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    head_dim=128,
    rope_theta=1_000_000.0,
    act="silu",
    tie_embeddings=False,
    frontend="vision",
    frontend_seq=576,
    subquadratic=False,
)

SMOKE_CONFIG = ArchConfig(
    name="llava-next-mistral-7b-smoke",
    family="vlm",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    head_dim=16,
    act="silu",
    tie_embeddings=False,
    frontend="vision",
    frontend_seq=16,
    subquadratic=False,
    param_dtype="float32",
    activation_dtype="float32",
)
