"""gemma3-4b [dense]: 5:1 local:global attention, 128k context.

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144. [hf:google/gemma-3]
Sliding window 1024 on local layers, qk-norm, GeGLU.  Decode cost is
O(window) for 5/6 of layers -> qualifies for long_500k (DESIGN.md §5).
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    d_ff=10240,
    vocab=262_144,
    head_dim=256,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    act="geglu",
    tie_embeddings=True,
    subquadratic=True,
)

SMOKE_CONFIG = ArchConfig(
    name="gemma3-4b-smoke",
    family="dense",
    n_layers=6,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    d_ff=128,
    vocab=512,
    head_dim=16,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    window=16,
    qk_norm=True,
    act="geglu",
    tie_embeddings=True,
    subquadratic=True,
    param_dtype="float32",
    activation_dtype="float32",
)
