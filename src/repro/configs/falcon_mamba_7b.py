"""falcon-mamba-7b [ssm]: pure Mamba-1, attention-free.

64L d_model=4096 (attn-free) vocab=65024, ssm_state=16. [arXiv:2410.05355]
d_inner = 2*d_model = 8192, dt_rank = d_model/16 = 256, conv width 4.
Sub-quadratic (O(1) decode state) -> runs long_500k.
"""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=65024,
    layer_pattern=("mamba",),
    ssm_state=16,
    d_inner=8192,
    conv_width=4,
    dt_rank=256,
    tie_embeddings=False,
    subquadratic=True,
)

SMOKE_CONFIG = ArchConfig(
    name="falcon-mamba-7b-smoke",
    family="ssm",
    n_layers=3,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    d_ff=0,
    vocab=512,
    layer_pattern=("mamba",),
    ssm_state=8,
    d_inner=128,
    conv_width=4,
    dt_rank=8,
    tie_embeddings=False,
    subquadratic=True,
    param_dtype="float32",
    activation_dtype="float32",
)
