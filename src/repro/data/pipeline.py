"""Deterministic, checkpointable synthetic data pipeline.

Batches are a pure function of (seed, step): restart/resume reproduces
the exact stream with no stored buffers (counter-based Philox), which is
what makes the data state trivially part of a fault-tolerance checkpoint
— the checkpoint stores just ``{"seed", "step"}``.

Produces LM batches (tokens/labels = next-token targets) plus the stub
frontend embeddings for the vlm/audio archs.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    batch: int
    seq: int
    seed: int = 0
    step: int = 0
    frontend: str | None = None
    frontend_seq: int = 0
    d_model: int = 0
    encdec: bool = False

    def next(self) -> dict:
        rng = np.random.default_rng([self.seed, self.step])
        # zipf-ish marginals so the loss curve is non-trivial
        z = rng.zipf(1.3, size=(self.batch, self.seq + 1))
        tokens = np.minimum(z - 1, self.vocab - 1).astype(np.int32)
        out = {
            "tokens": tokens[:, :-1],
            "labels": tokens[:, 1:].copy(),
        }
        if self.frontend == "vision":
            out["frontend"] = rng.standard_normal(
                (self.batch, self.frontend_seq, self.d_model)
            ).astype(np.float32)
        if self.encdec:
            out["enc_frames"] = rng.standard_normal(
                (self.batch, self.seq, self.d_model)).astype(np.float32)
        self.step += 1
        return out

    # ------------------------------------------------------ checkpointing
    def state_dict(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def load_state_dict(self, d: dict) -> None:
        self.seed = int(d["seed"])
        self.step = int(d["step"])


def for_config(cfg, batch: int, seq: int, seed: int = 0) -> SyntheticLM:
    return SyntheticLM(
        vocab=cfg.vocab, batch=batch, seq=seq, seed=seed,
        frontend=cfg.frontend if cfg.frontend == "vision" else None,
        frontend_seq=cfg.frontend_seq, d_model=cfg.d_model,
        encdec=cfg.is_encdec,
    )
