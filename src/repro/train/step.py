"""train_step / eval_step builders + sharding wiring for pjit.

``make_sharded_train_step`` returns a jit-compiled step with explicit
in/out shardings derived from the model's logical param specs, the
ZeRO-1 optimizer-state specs, and the batch specs — the single function
the launcher lowers for the dry-run and runs for real training.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.sharding import logical_to_pspec, use_rules
from .optimizer import AdamW, AdamWState, zero1_specs


def make_train_step(model, opt: AdamW, *, remat: bool = True,
                    q_chunk: int = 512, k_chunk: int = 512):
    def train_step(params, opt_state, batch):
        def loss_fn(p):
            loss, aux = model.loss(p, batch, remat=remat, q_chunk=q_chunk,
                                   k_chunk=k_chunk)
            return loss, aux
        (loss, aux), grads = jax.value_and_grad(loss_fn, has_aux=True)(params)
        new_params, new_state, info = opt.update(grads, opt_state, params)
        metrics = {"loss": loss, **{k: v for k, v in aux.items()}, **info}
        return new_params, new_state, metrics
    return train_step


def _tuple_leaf(x):
    return isinstance(x, tuple)


def specs_to_shardings(spec_tree, mesh: Mesh, rules: dict):
    """Logical-axes tuples -> NamedSharding tree."""
    def one(axes):
        with use_rules(rules):
            return NamedSharding(mesh, logical_to_pspec(axes))
    return jax.tree.map(one, spec_tree, is_leaf=_tuple_leaf)


def train_state_shardings(model, mesh: Mesh, rules: dict):
    """(param_shardings, opt_shardings) for the mesh."""
    pspecs = model.param_specs()
    pshapes = model.param_shapes()
    param_sh = specs_to_shardings(pspecs, mesh, rules)
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
    with use_rules(rules):
        z1 = zero1_specs(pspecs, pshapes, mesh, data_axes=data_axes)
    state_leaf_sh = jax.tree.map(
        lambda axes: NamedSharding(mesh, P(*axes)), z1, is_leaf=_tuple_leaf)
    scalar = NamedSharding(mesh, P())
    opt_sh = AdamWState(step=scalar, m=state_leaf_sh, v=state_leaf_sh,
                        master=state_leaf_sh)
    return param_sh, opt_sh


def batch_shardings(batch_specs, mesh: Mesh, rules: dict):
    return specs_to_shardings(batch_specs, mesh, rules)
