"""Production train loop: jit'd step + checkpoint/restart + watchdog.

Fault-tolerance contract (scaled to this box, designed for 1000+ nodes):
* auto-resume from the latest committed checkpoint (params, optimizer,
  data-pipeline state, step counter);
* periodic async checkpoints off the critical path;
* straggler watchdog: records step times, flags steps slower than
  ``straggler_factor`` x the running median (at scale this signal feeds
  the controller that evicts the slow host and restarts from the last
  checkpoint — the restart path is exactly ``resume=True``).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import numpy as np

from repro.checkpoint import store
from repro.data.pipeline import SyntheticLM


@dataclasses.dataclass
class Watchdog:
    straggler_factor: float = 3.0
    times: list = dataclasses.field(default_factory=list)
    stragglers: list = dataclasses.field(default_factory=list)

    def record(self, step: int, dt: float) -> bool:
        self.times.append(dt)
        med = float(np.median(self.times[-50:]))
        slow = len(self.times) > 5 and dt > self.straggler_factor * med
        if slow:
            self.stragglers.append((step, dt, med))
        return slow


def train(
    *,
    step_fn: Callable,          # (params, opt_state, batch) -> (p, s, metrics)
    params,
    opt_state,
    data: SyntheticLM,
    steps: int,
    ckpt_dir: Optional[str] = None,
    ckpt_every: int = 100,
    resume: bool = True,
    log_every: int = 10,
    log_fn: Callable[[str], None] = print,
):
    start = 0
    ckpt = store.AsyncCheckpointer()
    if ckpt_dir and resume:
        latest = store.latest_step(ckpt_dir)
        if latest is not None:
            (params, opt_state), extra = store.restore(
                ckpt_dir, latest, (params, opt_state))
            data.load_state_dict(extra["data"])
            start = latest
            log_fn(f"[resume] restored step {latest}")
    wd = Watchdog()
    losses = []
    for step in range(start, steps):
        batch = {k: jax.numpy.asarray(v) for k, v in data.next().items()}
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        jax.block_until_ready(metrics["loss"])
        dt = time.perf_counter() - t0
        if wd.record(step, dt):
            log_fn(f"[watchdog] straggler step {step}: {dt:.2f}s")
        losses.append(float(metrics["loss"]))
        if step % log_every == 0:
            log_fn(f"step {step:5d} loss {losses[-1]:.4f} "
                   f"lr {float(metrics['lr']):.2e} {dt*1e3:.0f}ms")
        if ckpt_dir and (step + 1) % ckpt_every == 0:
            ckpt.save(ckpt_dir, step + 1, (params, opt_state),
                      extra={"data": data.state_dict()})
    ckpt.wait()
    if ckpt_dir:
        store.save(ckpt_dir, steps, (params, opt_state),
                   extra={"data": data.state_dict()})
    return params, opt_state, {"losses": losses,
                               "stragglers": wd.stragglers}
