"""AdamW with f32 master weights + ZeRO-1 style optimizer-state sharding.

Optimizer state (m, v, master) triples the parameter footprint in f32;
at scale it must not be replicated across data-parallel replicas.  ZeRO-1
here is expressed through GSPMD: ``zero1_specs`` takes each parameter's
tensor-parallel PartitionSpec and additionally shards the largest
still-replicated dimension over the data axis (and the pod axis on the
multi-pod mesh).  XLA then keeps m/v/master distributed and inserts the
(reduce-scatter / all-gather) pair around the update — the standard
ZeRO-1 communication pattern — without hand-written collectives.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: dict
    v: dict
    master: dict


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr_fn: object                 # step -> lr
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0

    def init(self, params) -> AdamWState:
        f32 = lambda t: jax.tree.map(
            lambda x: jnp.zeros(x.shape, jnp.float32), t)
        master = jax.tree.map(lambda x: x.astype(jnp.float32), params)
        return AdamWState(step=jnp.zeros((), jnp.int32), m=f32(params),
                          v=f32(params), master=master)

    def update(self, grads, state: AdamWState, params):
        gnorm = global_norm(grads)
        scale = jnp.minimum(1.0, self.grad_clip / jnp.maximum(gnorm, 1e-12))
        step = state.step + 1
        lr = self.lr_fn(step)
        b1c = 1 - self.b1 ** step.astype(jnp.float32)
        b2c = 1 - self.b2 ** step.astype(jnp.float32)

        def upd(g, m, v, mw):
            g = g.astype(jnp.float32) * scale
            m = self.b1 * m + (1 - self.b1) * g
            v = self.b2 * v + (1 - self.b2) * g * g
            mhat = m / b1c
            vhat = v / b2c
            mw = mw - lr * (mhat / (jnp.sqrt(vhat) + self.eps)
                            + self.weight_decay * mw)
            return m, v, mw

        flat_g, td = jax.tree.flatten(grads)
        flat_m = td.flatten_up_to(state.m)
        flat_v = td.flatten_up_to(state.v)
        flat_w = td.flatten_up_to(state.master)
        out = [upd(g, m, v, w) for g, m, v, w in
               zip(flat_g, flat_m, flat_v, flat_w)]
        new_m = td.unflatten([o[0] for o in out])
        new_v = td.unflatten([o[1] for o in out])
        new_w = td.unflatten([o[2] for o in out])
        new_params = jax.tree.map(
            lambda w, p: w.astype(p.dtype), new_w, params)
        return new_params, AdamWState(step=step, m=new_m, v=new_v,
                                      master=new_w), {"grad_norm": gnorm,
                                                      "lr": lr}


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


# ----------------------------------------------------------------- ZeRO-1
def zero1_axis(shape, pspec_axes, mesh_axes_free, mesh_shape) -> tuple:
    """Pick the largest dim of ``shape`` not already sharded and assign the
    free (data[, pod]) axes to it if divisible; returns new axes tuple."""
    axes = list(pspec_axes) + [None] * (len(shape) - len(pspec_axes))
    free = [a for a in mesh_axes_free]
    if not free:
        return tuple(axes)
    needed = 1
    for a in free:
        needed *= mesh_shape[a]
    # largest unsharded, divisible dim
    cands = sorted(
        (i for i in range(len(shape)) if axes[i] is None
         and shape[i] % needed == 0 and shape[i] >= needed),
        key=lambda i: -shape[i])
    if not cands:
        return tuple(axes)
    i = cands[0]
    axes[i] = tuple(free)
    return tuple(axes)


def zero1_specs(param_specs, param_shapes, mesh, data_axes=("data",)):
    """Opt-state logical axes: param spec + data/pod sharding on the
    largest replicated dim.  ``param_specs`` leaves are logical-axis
    tuples, resolved against the mesh's physical axes by the caller."""
    from repro.models.sharding import logical_to_pspec

    def one(spec_axes, shp):
        p = logical_to_pspec(spec_axes)
        phys = list(p) + [None] * (len(shp.shape) - len(p))
        free = [a for a in data_axes if a in mesh.shape]
        return zero1_axis(shp.shape, phys, free, dict(mesh.shape))

    return jax.tree.map(one, param_specs, param_shapes,
                        is_leaf=lambda x: isinstance(x, tuple))
