"""LR schedules, including WSD (warmup-stable-decay) from MiniCPM
(arXiv:2404.06395) — the schedule the assigned minicpm-2b was trained
with — plus cosine for the other archs."""
from __future__ import annotations

import jax.numpy as jnp


def wsd(peak_lr: float, warmup: int, stable: int, decay: int,
        final_frac: float = 0.1):
    """Warmup-Stable-Decay: linear warmup, flat plateau, exponential-ish
    decay to final_frac * peak over the decay window."""
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        dec_t = jnp.clip((s - warmup - stable) / max(decay, 1), 0.0, 1.0)
        dec = peak_lr * jnp.exp(jnp.log(final_frac) * dec_t)
        return jnp.where(s < warmup, warm,
                         jnp.where(s < warmup + stable, peak_lr, dec))
    return fn


def cosine(peak_lr: float, warmup: int, total: int,
           final_frac: float = 0.1):
    def fn(step):
        s = step.astype(jnp.float32)
        warm = peak_lr * s / max(warmup, 1)
        t = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = final_frac + (1 - final_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
        return jnp.where(s < warmup, warm, peak_lr * cos)
    return fn


def constant(lr: float):
    return lambda step: jnp.full((), lr, jnp.float32)
