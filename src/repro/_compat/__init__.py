"""Compatibility shims for the pinned toolchain (jax==0.4.37).

The repo targets the modern jax API surface (``jax.shard_map``,
``jax.set_mesh``, ``jax.sharding.AxisType``); this module backfills the
pieces that 0.4.x spells differently so the same code runs on both.
Import from here instead of guarding at each call site:

    from repro._compat import shard_map, set_mesh, AxisType
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5 explicit-sharding API
    from jax.sharding import AxisType
except ImportError:  # pragma: no cover - taken on jax 0.4.x
    AxisType = None

try:
    shard_map = jax.shard_map
except AttributeError:  # pragma: no cover - taken on jax 0.4.x
    from jax.experimental.shard_map import shard_map


def set_mesh(mesh):
    """``with set_mesh(mesh): ...`` on any jax version.

    Uses ``jax.set_mesh`` when present; on 0.4.x a ``Mesh`` is its own
    ambient-mesh context manager, so the mesh itself is returned.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def make_mesh(shape, axes):
    """``jax.make_mesh`` with Auto axis types where the API supports them."""
    if AxisType is None:
        return jax.make_mesh(shape, axes)
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))
