"""Deterministic stand-in for ``hypothesis`` when it is not installed.

The container the tier-1 suite runs in may not ship ``hypothesis`` (CI
installs the real thing — see .github/workflows/ci.yml).  Rather than
skipping the property tests, this module implements the tiny slice of
the hypothesis API the suite uses — ``given``, ``settings``, ``assume``
and the ``integers`` / ``floats`` / ``sampled_from`` / ``booleans``
strategies —
with deterministic pseudo-random example generation seeded from the test
name.  Every property test still executes ``max_examples`` drawn
examples; what is lost vs real hypothesis is only shrinking and the
example database.

``tests/conftest.py`` calls :func:`install` before collection when the
real package is missing; test modules keep their plain
``from hypothesis import given, settings, strategies as st`` imports.
"""
from __future__ import annotations

import sys
import types
import zlib

import numpy as np

_DEFAULT_MAX_EXAMPLES = 20


class _Strategy:
    def __init__(self, draw, label):
        self._draw = draw
        self._label = label

    def draw(self, rng):
        return self._draw(rng)

    def __repr__(self):  # pragma: no cover - debugging aid
        return f"_Strategy({self._label})"


def integers(min_value, max_value):
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        f"integers({min_value}, {max_value})",
    )


def floats(min_value, max_value, **_kw):
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        f"floats({min_value}, {max_value})",
    )


def sampled_from(elements):
    elems = list(elements)
    return _Strategy(
        lambda rng: elems[int(rng.integers(0, len(elems)))],
        f"sampled_from({elems!r})",
    )


def booleans():
    return _Strategy(lambda rng: bool(rng.integers(0, 2)), "booleans()")


class _Unsatisfied(Exception):
    """Raised by :func:`assume` to discard the current drawn example."""


def assume(condition):
    """Discard the current example when ``condition`` is falsy (the real
    hypothesis re-draws; the fallback just skips the example)."""
    if not condition:
        raise _Unsatisfied
    return True


def given(**strategies):
    """Decorator: run the test once per drawn example (kwargs style only)."""

    def deco(fn):
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_hf_max_examples", _DEFAULT_MAX_EXAMPLES)
            seed = zlib.crc32(f"{fn.__module__}.{fn.__qualname__}".encode())
            rng = np.random.default_rng(seed)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in strategies.items()}
                try:
                    fn(*args, **kwargs, **drawn)
                except _Unsatisfied:
                    continue           # assume() rejected this example
                except Exception as e:  # re-raise with the failing example
                    raise AssertionError(
                        f"falsifying example (hypothesis fallback): {drawn}"
                    ) from e

        # No functools.wraps: __wrapped__ would make pytest resolve the
        # drawn argument names as fixtures.
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper._hf_inner = fn
        return wrapper

    return deco


def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_kw):
    def deco(fn):
        fn._hf_max_examples = max_examples
        return fn

    return deco


class HealthCheck:  # pragma: no cover - parity with the real API surface
    all = staticmethod(lambda: [])
    too_slow = "too_slow"


def install():
    """Register this module as ``hypothesis`` if the real one is absent."""
    if "hypothesis" in sys.modules:
        return
    try:
        import hypothesis  # noqa: F401
        return
    except ModuleNotFoundError:
        pass
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    for f in (integers, floats, sampled_from, booleans):
        setattr(st, f.__name__, f)
    mod.given = given
    mod.settings = settings
    mod.assume = assume
    mod.strategies = st
    mod.HealthCheck = HealthCheck
    mod.__is_fallback__ = True
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st
