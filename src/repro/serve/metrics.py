"""Serving observability: latency summaries, occupancy, typed counters.

One :class:`ServeMetrics` instance rides a :class:`~repro.serve.
scheduler.SolveScheduler` and records everything the serving bench and
the request diagnostics export (DESIGN.md §12):

* per-request latency split three ways — ``queue_s`` (submit ->
  dispatch), ``solve_s`` (dispatch -> completion), ``total_s`` — each a
  :class:`LatencySummary` with count/mean/p50/p99/max;
* ``occupancy`` — filled slots / total slots per dispatched batch, the
  continuous-batching health signal (an occupancy stuck at 1/slots
  means coalescing never happens and block-CG amortisation is lost);
* ``counters`` — monotonically increasing typed event counts:
  ``admitted`` / ``rejected`` / ``shed`` / ``converged`` / ``failed`` /
  ``error`` / ``batches`` / ``group_splits`` (poisoned-batch bisection
  re-solves, PR 7's machinery) / ``value_swaps`` / ``evictions``.

Everything here is plain host-side bookkeeping — no clock of its own
(the scheduler owns time, so deterministic-clock tests drive real
latency numbers), no device work, no locks (the scheduler is
single-threaded per tick by design).
"""
from __future__ import annotations

import dataclasses
from collections import defaultdict
from typing import Dict, List

import numpy as np

__all__ = ["LatencySummary", "ServeMetrics"]


class LatencySummary:
    """Streaming-ish summary of a latency series.

    Samples are kept (the serving bench wants exact p50/p99 over a few
    thousand requests; a reservoir would be premature here) and
    summarised on demand.  ``percentile`` uses the lower interpolation
    so a p99 over a small deterministic test series is an actual
    observed sample, not an invented midpoint.
    """

    def __init__(self, name: str = ""):
        self.name = name
        self._xs: List[float] = []

    def observe(self, seconds: float) -> None:
        self._xs.append(float(seconds))

    @property
    def count(self) -> int:
        return len(self._xs)

    def percentile(self, p: float) -> float:
        if not self._xs:
            return float("nan")
        return float(np.percentile(self._xs, p, method="lower"))

    def snapshot(self) -> dict:
        if not self._xs:
            return {"count": 0}
        xs = np.asarray(self._xs)
        return {
            "count": len(self._xs),
            "mean_s": float(xs.mean()),
            "p50_s": self.percentile(50),
            "p99_s": self.percentile(99),
            "max_s": float(xs.max()),
        }


@dataclasses.dataclass
class ServeMetrics:
    """The scheduler's ledger; see the module docstring for the fields.

    ``inc`` / ``observe_request`` / ``observe_batch`` are the only write
    paths; ``snapshot`` renders one JSON-ready dict (the shape
    ``BENCH_serve.json`` rows and ``request.diagnostics["serve"]``
    summaries are built from)."""

    counters: Dict[str, int] = dataclasses.field(
        default_factory=lambda: defaultdict(int))
    queue_s: LatencySummary = dataclasses.field(
        default_factory=lambda: LatencySummary("queue_s"))
    solve_s: LatencySummary = dataclasses.field(
        default_factory=lambda: LatencySummary("solve_s"))
    total_s: LatencySummary = dataclasses.field(
        default_factory=lambda: LatencySummary("total_s"))
    occupancy: LatencySummary = dataclasses.field(
        default_factory=lambda: LatencySummary("occupancy"))

    def inc(self, name: str, n: int = 1) -> None:
        self.counters[name] += n

    def observe_request(self, queue_s: float, solve_s: float,
                        total_s: float) -> None:
        self.queue_s.observe(queue_s)
        self.solve_s.observe(solve_s)
        self.total_s.observe(total_s)

    def observe_batch(self, filled: int, slots: int) -> None:
        self.inc("batches")
        self.occupancy.observe(filled / max(slots, 1))

    def snapshot(self) -> dict:
        return {
            "counters": dict(self.counters),
            "queue_s": self.queue_s.snapshot(),
            "solve_s": self.solve_s.snapshot(),
            "total_s": self.total_s.snapshot(),
            "occupancy": self.occupancy.snapshot(),
        }
