"""Batched serving engines: continuous batching over prefill + decode,
and batched linear solves over a shared :class:`SparseOperator`.

:class:`Engine` is a minimal production-shape LM engine: requests queue
up, get prefill'd into free cache slots, and every engine tick runs one
batched ``decode_step`` for all active slots.  Finished sequences (EOS
or max tokens) free their slot for the next queued request — continuous
batching as in vLLM, scaled to the shapes this box can run.  Param
trees may contain ``SparseLinear`` operator leaves (``repro.sparse``) —
they are registered pytrees, so the jitted decode step carries them
like any dense weight.

:class:`SolveEngine` is the same serving idea applied to the paper's
actual workload: many independent right-hand sides against ONE resident
sparse matrix.  Requests queue up, get batched ``slots`` at a time into
a multi-RHS block-CG solve (``repro.solve(..., method="block_cg")``
over the operator's ``matmat``), so the matrix is streamed from memory
once per iteration for the whole batch — the spMM amortisation the
SELL-C-sigma follow-up identifies — and the SAME code serves a
single-device operator or a mesh-distributed one (DESIGN.md §8).

The decode path is the one the decode_32k / long_500k dry-run cells
lower; here it runs for real on reduced configs (examples/serve_lm.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 eos_id: int = -1):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.cache = model.init_cache(batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.budget: List[int] = [0] * batch_slots
        self._decode = jax.jit(model.decode_step)
        self._last_tok = np.zeros((batch_slots, 1), np.int32)

    def _prefill_one(self, slot: int, req: Request):
        """Prefill a single request by streaming its prompt through decode
        steps into the slot's cache rows (slot-local prefill keeps the
        batched cache layout; a production engine would use a chunked
        prefill kernel)."""
        toks = req.prompt.astype(np.int32)
        for t, tok in enumerate(toks):
            # .copy(): jnp.asarray may zero-copy alias numpy buffers on
            # CPU; we mutate these between async dispatches
            step_tok = jnp.asarray(self._last_tok.copy())
            step_tok = step_tok.at[slot, 0].set(int(tok))
            pos = jnp.asarray(self.pos.copy())
            self.cache, logits = self._decode(self.params, self.cache,
                                              step_tok, pos)
            self.pos[slot] += 1
        nxt = int(np.argmax(np.asarray(logits)[slot, -1]))
        self._last_tok[slot, 0] = nxt
        req.out.append(nxt)

    def submit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.active[s] is None:
                self.active[s] = req
                # prefill emits the first token; budget covers the rest
                self.budget[s] = req.max_new - 1
                self.pos[s] = 0
                self._reset_slot(s)
                self._prefill_one(s, req)
                if self.budget[s] <= 0:
                    req.done = True
                    self.active[s] = None
                return True
        return False

    def _reset_slot(self, s: int):
        fresh = self.model.init_cache(1, self.max_len)

        def put_leaf(path, old, new):
            # leaves under "periods" carry a leading stacked-layer axis,
            # so their batch axis is 1; flat leaves have batch at axis 0.
            stacked = any(getattr(k, "key", None) == "periods"
                          for k in path)
            if stacked:
                return old.at[:, s:s + 1].set(new)
            return old.at[s:s + 1].set(new)

        self.cache = jax.tree_util.tree_map_with_path(put_leaf, self.cache,
                                                      fresh)

    def step(self):
        """One engine tick: batched decode for all active slots."""
        if not any(r is not None and not r.done for r in self.active):
            return
        toks = jnp.asarray(self._last_tok.copy())
        pos = jnp.asarray(self.pos.copy())
        self.cache, logits = self._decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        for s, req in enumerate(self.active):
            if req is None or req.done:
                continue
            self.pos[s] += 1
            self.budget[s] -= 1
            tok = int(nxt[s])
            req.out.append(tok)
            self._last_tok[s, 0] = tok
            if tok == self.eos or self.budget[s] <= 0:
                req.done = True
                self.active[s] = None

    def run(self, requests: List[Request], max_ticks: int = 10_000):
        queue = list(requests)
        done: List[Request] = []
        ticks = 0
        while (queue or any(self.active)) and ticks < max_ticks:
            while queue and self.submit(queue[0]):
                queue.pop(0)
            self.step()
            done.extend(r for r in requests if r.done and r not in done)
            ticks += 1
        return requests


# --------------------------------------------------------------------------
# Linear-solve serving over the operator protocol
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SolveRequest:
    rid: int
    b: np.ndarray                # (n,) right-hand side, original basis
    deadline_s: Optional[float] = None   # seconds from run() start; None = no deadline
    x: Optional[np.ndarray] = None
    iters: int = 0
    residual: float = float("inf")
    status: str = "pending"      # converged/maxiter/breakdown/diverged/
    #                              non_finite/rejected/shed/error
    diagnostics: dict = dataclasses.field(default_factory=dict)
    done: bool = False


class SolveEngine:
    """Batched linear-solve serving against one resident SparseOperator.

    ``op`` is any square :class:`repro.core.operator.SparseOperator`
    (``operator(m)`` or ``dist_operator(m, mesh)`` — the engine code is
    identical for both).  Queued right-hand sides are packed ``slots``
    columns at a time (zero-padded when the queue runs short; a zero
    column converges instantly) and solved with one multi-RHS block-CG,
    so every CG iteration streams the matrix once for the whole batch.
    SPD systems only — the block-CG contract.

    Hardening (DESIGN.md §11): right-hand sides are admission-checked
    (non-finite or wrong-shape ``b`` is ``rejected`` before it can
    poison a batch), requests carry optional per-request deadlines
    (expired requests are ``shed`` before dispatch, never solved), and
    every batch is CERTIFIED per column against the original system.
    When certification fails for some columns — one poisoned RHS NaNs
    the shared block-CG Gram matrix, taking every column down with it —
    the engine bisects the group, re-solves the halves, and keeps
    splitting until healthy requests succeed and only the genuinely
    poisoned request fails, with a typed ``status`` + diagnostics.
    """

    def __init__(self, op, *, slots: int = 4, maxiter: int = 2000,
                 tol: float = 1e-6, jacobi_precond: bool = False,
                 cert_slack: float = 10.0):
        if op.shape[0] != op.shape[1]:
            raise ValueError("SolveEngine serves square systems")
        self.op = op
        self.slots = slots
        self.maxiter = maxiter
        self.tol = tol
        # tol stops the recurrence; certification accepts within
        # cert_slack * tol.  The slack absorbs recurrence-vs-true
        # drift near the storage dtype's accuracy floor (f32 at
        # tol=1e-7 lands a hair above tol) — a poisoned column sits
        # at NaN or O(1), orders of magnitude past any sane slack.
        self._cert_tol = tol * cert_slack
        # Jacobi scaling as a per-column pre/post transform keeps the
        # block solver untouched: solve (D^-1/2 A D^-1/2) x' = D^-1/2 b.
        # The scaled-apply closure is built ONCE — it is the block
        # solver's static jit key, so a fresh one per batch would
        # recompile every batch.
        self._scale = None
        self._scaled_apply = None
        if jacobi_precond:
            d = np.asarray(op.diagonal())
            self._scale = np.where(d > 0, 1.0 / np.sqrt(np.abs(d) + 1e-30),
                                   1.0).astype(d.dtype)
            s = jnp.asarray(self._scale)[:, None]
            self._scaled_apply = lambda X: s * op.matmat(s * X)

    def _dispatch(self, batch: List[SolveRequest]):
        """One block-CG solve for ``batch`` (zero-padded to ``slots``
        columns so the jit key is batch-size independent).  Returns
        ``(x, rr, res)`` where ``rr`` is the per-column TRUE relative
        residual of the ORIGINAL system — the certification signal —
        regardless of Jacobi scaling."""
        import repro
        n = self.op.shape[0]
        dt = np.dtype(self.op.dtype)
        bmat = np.zeros((n, self.slots), dtype=dt)
        for j, req in enumerate(batch):
            bmat[: len(req.b), j] = req.b
        if self._scale is None:
            res = repro.solve(self.op, jnp.asarray(bmat),
                              method="block_cg", maxiter=self.maxiter,
                              tol=self.tol, fallback="off")
            x = np.asarray(res.x)
        else:
            res = repro.solve(self._scaled_apply,
                              jnp.asarray(self._scale[:, None] * bmat),
                              method="block_cg", maxiter=self.maxiter,
                              tol=self.tol, fallback="off")
            x = np.asarray(self._scale[:, None] * np.asarray(res.x))
        with np.errstate(invalid="ignore", over="ignore"):
            ax = np.asarray(self.op.matmat(jnp.asarray(x)))
            r = bmat - ax
            rr = np.linalg.norm(r, axis=0) \
                / np.maximum(np.linalg.norm(bmat, axis=0), 1e-30)
            if self._scale is None:
                rr_cert = rr
            else:
                # certify in the basis the solver targeted tol in (the
                # scaled system); rr stays original-basis for reporting.
                # s*(b - A x) == b' - A' x', so no second matmat needed.
                sc = self._scale[:, None]
                rr_cert = np.linalg.norm(sc * r, axis=0) \
                    / np.maximum(np.linalg.norm(sc * bmat, axis=0), 1e-30)
        return x, rr, rr_cert, res

    def _solve_group(self, batch: List[SolveRequest]) -> None:
        """Solve a group, certify each column, bisect on failure.

        A single poisoned column corrupts the whole block-CG recurrence
        (the Gram matrix couples the columns), so certification failure
        says "someone in this group is bad", not who.  Splitting the
        group in half and re-solving isolates the culprit in
        O(log slots) extra solves while every healthy request still
        gets a certified answer."""
        try:
            x, rr, rr_cert, res = self._dispatch(batch)
        except Exception as e:                       # infrastructure failure
            if len(batch) == 1:
                req = batch[0]
                req.status = "error"
                req.diagnostics["error"] = f"{type(e).__name__}: {e}"
                req.done = True
                return
            mid = (len(batch) + 1) // 2
            self._solve_group(batch[:mid])
            self._solve_group(batch[mid:])
            return
        retry: List[SolveRequest] = []
        for j, req in enumerate(batch):
            rn = float(rr_cert[j])
            if np.isfinite(rn) and rn <= self._cert_tol:
                req.x = x[: len(req.b), j]
                req.iters = int(res.iters)
                req.residual = float(rr[j])
                req.status = "converged"
                req.done = True
            elif len(batch) == 1:
                # isolated and still failing: this request is the poison
                req.x = x[: len(req.b), j]
                req.iters = int(res.iters)
                req.residual = float(rr[j])
                req.status = "non_finite" if not np.isfinite(rn) \
                    else res.status
                if req.status == "converged":   # recurrence lied; rn didn't
                    req.status = "diverged"
                req.diagnostics["true_residual"] = rn
                req.diagnostics.update(
                    {k: v for k, v in res.diagnostics.items()
                     if k not in req.diagnostics})
                req.done = True
            else:
                retry.append(req)
        if retry:
            if len(retry) == 1:
                self._solve_group(retry)
            else:
                mid = (len(retry) + 1) // 2
                self._solve_group(retry[:mid])
                self._solve_group(retry[mid:])

    def _admit(self, req: SolveRequest) -> bool:
        """Reject a request whose RHS would poison the batch: wrong
        shape, too long for the operator, or non-finite entries."""
        b = np.asarray(req.b)
        reason = None
        if b.ndim != 1:
            reason = f"b must be 1-D, got shape {b.shape}"
        elif len(b) > self.op.shape[0]:
            reason = (f"b has {len(b)} rows, operator has "
                      f"{self.op.shape[0]}")
        elif not np.all(np.isfinite(b)):
            reason = "b contains non-finite values"
        if reason is not None:
            req.status = "rejected"
            req.diagnostics["reason"] = reason
            req.done = True
            return False
        return True

    def run(self, requests: List[SolveRequest]) -> List[SolveRequest]:
        import time
        t0 = time.monotonic()
        queue = list(requests)
        while queue:
            batch: List[SolveRequest] = []
            while queue and len(batch) < self.slots:
                req = queue.pop(0)
                if req.deadline_s is not None \
                        and time.monotonic() - t0 >= req.deadline_s:
                    req.status = "shed"
                    req.diagnostics["deadline_s"] = req.deadline_s
                    req.done = True
                    continue
                if self._admit(req):
                    batch.append(req)
            if batch:
                self._solve_group(batch)
        return requests
