"""Serving engines: LM continuous batching, and the solve-serving shim.

:class:`Engine` is a minimal production-shape LM engine: requests queue
up, get prefill'd into free cache slots, and every engine tick runs one
batched ``decode_step`` for all active slots.  Finished sequences (EOS
or max tokens) free their slot for the next queued request — continuous
batching as in vLLM, scaled to the shapes this box can run.  Param
trees may contain ``SparseLinear`` operator leaves (``repro.sparse``) —
they are registered pytrees, so the jitted decode step carries them
like any dense weight.  The decode path is the one the decode_32k /
long_500k dry-run cells lower; here it runs for real on reduced
configs (examples/serve_lm.py).

Linear-solve serving lives in the multi-tenant subsystem next door
(DESIGN.md §12): :mod:`repro.serve.registry` keys resident operators by
structural fingerprint (shared persistent tune cache, zero-warmup warm
admits, zero-reconversion value swaps), :mod:`repro.serve.scheduler`
coalesces concurrent requests into certified block-CG groups with
deadline shedding and tick-based slot recycling, and
:mod:`repro.serve.metrics` keeps the ledger.  :class:`SolveEngine`
survives as a thin single-operator COMPATIBILITY SHIM over that path —
same constructor, same blocking ``run(requests)``, same typed request
statuses — for callers who have one operator in hand and no interest
in tenancy.  New code should drive the registry + scheduler directly.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from .scheduler import SolveRequest  # re-export: the shim's request type

__all__ = ["Engine", "Request", "SolveEngine", "SolveRequest"]


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray           # (S,) int32
    max_new: int = 16
    out: list = dataclasses.field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, model, params, *, batch_slots: int, max_len: int,
                 eos_id: int = -1):
        self.model = model
        self.params = params
        self.slots = batch_slots
        self.max_len = max_len
        self.eos = eos_id
        self.cache = model.init_cache(batch_slots, max_len)
        self.pos = np.zeros(batch_slots, np.int32)
        self.active: List[Optional[Request]] = [None] * batch_slots
        self.budget: List[int] = [0] * batch_slots
        self._decode = jax.jit(model.decode_step)
        self._last_tok = np.zeros((batch_slots, 1), np.int32)

    def _prefill_one(self, slot: int, req: Request):
        """Prefill a single request by streaming its prompt through decode
        steps into the slot's cache rows (slot-local prefill keeps the
        batched cache layout; a production engine would use a chunked
        prefill kernel)."""
        toks = req.prompt.astype(np.int32)
        for t, tok in enumerate(toks):
            # .copy(): jnp.asarray may zero-copy alias numpy buffers on
            # CPU; we mutate these between async dispatches
            step_tok = jnp.asarray(self._last_tok.copy())
            step_tok = step_tok.at[slot, 0].set(int(tok))
            pos = jnp.asarray(self.pos.copy())
            self.cache, logits = self._decode(self.params, self.cache,
                                              step_tok, pos)
            self.pos[slot] += 1
        nxt = int(np.argmax(np.asarray(logits)[slot, -1]))
        self._last_tok[slot, 0] = nxt
        req.out.append(nxt)

    def submit(self, req: Request) -> bool:
        for s in range(self.slots):
            if self.active[s] is None:
                self.active[s] = req
                # prefill emits the first token; budget covers the rest
                self.budget[s] = req.max_new - 1
                self.pos[s] = 0
                self._reset_slot(s)
                self._prefill_one(s, req)
                if self.budget[s] <= 0:
                    req.done = True
                    self.active[s] = None
                return True
        return False

    def _reset_slot(self, s: int):
        fresh = self.model.init_cache(1, self.max_len)

        def put_leaf(path, old, new):
            # leaves under "periods" carry a leading stacked-layer axis,
            # so their batch axis is 1; flat leaves have batch at axis 0.
            stacked = any(getattr(k, "key", None) == "periods"
                          for k in path)
            if stacked:
                return old.at[:, s:s + 1].set(new)
            return old.at[s:s + 1].set(new)

        self.cache = jax.tree_util.tree_map_with_path(put_leaf, self.cache,
                                                      fresh)

    def step(self):
        """One engine tick: batched decode for all active slots."""
        if not any(r is not None and not r.done for r in self.active):
            return
        toks = jnp.asarray(self._last_tok.copy())
        pos = jnp.asarray(self.pos.copy())
        self.cache, logits = self._decode(self.params, self.cache, toks, pos)
        nxt = np.asarray(jnp.argmax(logits[:, -1], axis=-1)).astype(np.int32)
        for s, req in enumerate(self.active):
            if req is None or req.done:
                continue
            self.pos[s] += 1
            self.budget[s] -= 1
            tok = int(nxt[s])
            req.out.append(tok)
            self._last_tok[s, 0] = tok
            if tok == self.eos or self.budget[s] <= 0:
                req.done = True
                self.active[s] = None

    def run(self, requests: List[Request], max_ticks: int = 10_000):
        queue = list(requests)
        done: List[Request] = []
        ticks = 0
        while (queue or any(self.active)) and ticks < max_ticks:
            while queue and self.submit(queue[0]):
                queue.pop(0)
            self.step()
            done.extend(r for r in requests if r.done and r not in done)
            ticks += 1
        return requests


# --------------------------------------------------------------------------
# Linear-solve serving over the operator protocol
# --------------------------------------------------------------------------
class SolveEngine:
    """Single-operator compatibility shim over the serving subsystem.

    ``SolveEngine(op).run(requests)`` behaves exactly as it did before
    the multi-tenant split: queued right-hand sides are packed ``slots``
    columns at a time into certified multi-RHS block-CG groups (SPD
    systems only — the block-CG contract), with admission checks,
    deadline shedding (``deadline_s`` measured from ``run()`` start) and
    poisoned-batch bisection.  Internally it is one resident operator in
    an :class:`~repro.serve.registry.OperatorRegistry` driven by a
    :class:`~repro.serve.scheduler.SolveScheduler`; the scheduler's
    metrics are exposed as ``engine.metrics`` and per-request summaries
    land in ``request.diagnostics["serve"]``.

    The ``_dispatch`` / ``_admit`` methods remain the fault-injection
    seams the chaos suite targets — they route into the underlying
    :class:`~repro.serve.scheduler.GroupSolver`.
    """

    def __init__(self, op, *, slots: int = 4, maxiter: int = 2000,
                 tol: float = 1e-6, jacobi_precond: bool = False,
                 cert_slack: float = 10.0):
        if op.shape[0] != op.shape[1]:
            raise ValueError("SolveEngine serves square systems")
        from .registry import OperatorRegistry
        from .scheduler import SolveScheduler

        self.op = op
        self.slots = slots
        self.maxiter = maxiter
        self.tol = tol
        self.registry = OperatorRegistry(capacity=1)
        self.entry = self.registry.admit_operator(op)
        self.scheduler = SolveScheduler(
            self.registry, slots=slots, maxiter=maxiter, tol=tol,
            jacobi_precond=jacobi_precond, cert_slack=cert_slack)
        solver = self.scheduler.solver_for(self.entry)
        # late-bound hooks: a monkeypatched engine._dispatch/_admit is
        # picked up because the lambdas resolve the attribute per call
        solver._dispatch_fn = lambda batch: self._dispatch(batch)
        solver._admit_fn = lambda req: self._admit(req)
        self._solver = solver

    @property
    def metrics(self):
        return self.scheduler.metrics

    def _dispatch(self, batch: List[SolveRequest]):
        return self._solver.dispatch_impl(batch)

    def _admit(self, req: SolveRequest) -> bool:
        return self._solver.admit_impl(req)

    def run(self, requests: List[SolveRequest]) -> List[SolveRequest]:
        """Submit ``requests`` and block until all are finalized."""
        for req in requests:
            self.scheduler.submit(req)
        self.scheduler.run_until_drained()
        return requests
