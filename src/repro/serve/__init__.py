"""Multi-tenant solve serving (DESIGN.md §12).

Layered registry -> scheduler -> group solver:

* :class:`OperatorRegistry` (``registry.py``) — resident operators
  keyed by structural fingerprint, sharing the persistent tune cache
  (warm admits measure nothing) with zero-reconversion value swaps and
  LRU eviction;
* :class:`SolveScheduler` (``scheduler.py``) — async admission,
  continuous RHS batching into certified block-CG groups, deadline
  shedding, tick-based slot recycling;
* :class:`ServeMetrics` (``metrics.py``) — latency/occupancy summaries
  and typed counters;
* :class:`SolveEngine` / :class:`Engine` (``engine.py``) — the
  single-operator compatibility shim and the LM decode engine.
"""
from .metrics import LatencySummary, ServeMetrics
from .registry import OperatorRegistry, RegistryMismatch, ResidentOperator
from .scheduler import GroupSolver, SolveRequest, SolveScheduler
from .engine import Engine, Request, SolveEngine

__all__ = [
    "Engine", "Request", "SolveEngine", "SolveRequest",
    "OperatorRegistry", "RegistryMismatch", "ResidentOperator",
    "GroupSolver", "SolveScheduler",
    "ServeMetrics", "LatencySummary",
]
