"""Multi-tenant operator registry keyed by structural fingerprint.

The serving workload (DESIGN.md §12) is many tenants, few structures:
a tenant shows up with a matrix, and very often its sparsity STRUCTURE
is one the system has already served — the same mesh re-assembled with
new coefficients, a sibling deployment of the same model, the next
time step of a PDE.  Everything expensive about admitting an operator
is a function of the structure alone:

* the tuned kernel statics (``repro.tune`` caches them persistently
  under ``formats.structural_fingerprint`` — the SAME key this registry
  uses, so a registry admit and a bare ``operator(m, tune="auto")``
  share one cache: a new tenant whose structure was ever tuned, by
  anyone, on this host, admits with ZERO tuning measurements);
* the format conversion (permutation + padding — value-independent);
* the value map (where each host nonzero lands in the stored stream).

So the registry keys resident operators by fingerprint and makes the
warm paths free: a warm admit with identical values is a pure lookup; a
warm admit with NEW values on the same structure is a zero-reconversion
VALUE SWAP (one gather through the entry's value map into the existing
layout — no format conversion, no re-tuning, tuned statics survive by
construction of the fingerprint).  A warm admit whose shape / nnz /
dtype policy contradicts the resident entry is REJECTED with
:class:`RegistryMismatch` — a sha1 collision or a caller mixing
storage contracts must never be silently served someone else's
operator.

Capacity is bounded: admitting past ``capacity`` evicts the least
recently used resident (its persistent tune-cache entry survives, so
re-admission later is still measurement-free).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Callable, Optional

import jax.numpy as jnp
import numpy as np

__all__ = ["RegistryMismatch", "ResidentOperator", "OperatorRegistry"]

# Above this nnz an f32-exact value map cannot be built (the tag stream
# would lose integer precision); value swaps fall back to a full
# reconversion, which is correct but not zero-cost.
_MAP_EXACT_NNZ = 1 << 24


class RegistryMismatch(ValueError):
    """A fingerprint hit whose shape / nnz / dtype policy contradicts
    the resident entry: served would be wrong, so admit refuses."""


@dataclasses.dataclass
class ResidentOperator:
    """One resident tenant operator and its serving bookkeeping."""

    key: str                     # structural fingerprint (or opaque:<id>)
    op: object                   # SparseOperator serving this structure
    shape: tuple
    nnz: int
    policy: str                  # dtype-policy contract (cache.dtype_policy)
    backend: str = "auto"
    build_kwargs: dict = dataclasses.field(default_factory=dict)
    tune_info: Optional[dict] = None   # {"cached": bool, "label": str}
    host: bool = False           # admitted from a host CSR (swaps possible)
    hits: int = 0
    swaps: int = 0
    version: int = 0             # bumped on every value swap — consumers
    #                              caching derived state (jacobi scales,
    #                              jit closures) key on it
    _data_sha: Optional[str] = None
    _val_map: Optional[np.ndarray] = None   # stored slot -> nnz index (-1 pad)
    _dtype: Optional[object] = None

    def stats(self) -> dict:
        return {"key": self.key, "shape": list(self.shape), "nnz": self.nnz,
                "policy": self.policy, "hits": self.hits,
                "swaps": self.swaps,
                "tuned": None if self.tune_info is None else self.tune_info}


def _data_sha(data: np.ndarray) -> str:
    return hashlib.sha1(np.ascontiguousarray(data).tobytes()).hexdigest()


class OperatorRegistry:
    """LRU-bounded registry of resident operators; see module docstring.

    ``tune`` is the registry-wide default admission policy (``"auto"`` /
    ``"force"`` / ``"off"``); ``cache`` / ``measure_fn`` thread straight
    into ``repro.tune.autotune`` — an injected ``measure_fn`` is the
    test/bench hook that PROVES a warm admit measures nothing (the
    bench counts its calls)."""

    def __init__(self, capacity: int = 8, *, tune: str = "auto",
                 cache=None, measure_fn: Optional[Callable] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1; got {capacity}")
        self.capacity = capacity
        self.tune = tune
        self.cache = cache
        self.measure_fn = measure_fn
        self.evictions = 0
        self._entries: "OrderedDict[str, ResidentOperator]" = OrderedDict()

    # -- lookup ------------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return key in self._entries

    def keys(self):
        return list(self._entries)

    def get(self, key: str) -> Optional[ResidentOperator]:
        e = self._entries.get(key)
        if e is not None:
            self._entries.move_to_end(key)
        return e

    def entries(self):
        return list(self._entries.values())

    # -- admission ---------------------------------------------------------
    def admit(self, m, *, dtype=None, index_dtype="auto", backend="auto",
              tune: Optional[str] = None,
              format: str = "auto") -> ResidentOperator:
        """Admit a host ``CSRMatrix`` and return its resident entry.

        Cold structure: tune (per registry/``tune`` policy — a
        persistent-cache hit already costs zero measurements), build the
        operator once, insert (evicting LRU past capacity).  Warm
        structure: verify the shape/nnz/dtype-policy contract
        (:class:`RegistryMismatch` on contradiction), then swap values
        in-place through the value map iff they changed.  The entry's
        LAYOUT is fixed at first admission — warm admits serve the
        resident layout regardless of ``format``/``tune`` arguments."""
        from repro.core import formats as F
        from repro.tune import cache as C

        if not isinstance(m, F.CSRMatrix):
            raise TypeError(
                f"admit() takes a host CSRMatrix; got {type(m).__name__} "
                "(wrap existing operators with admit_operator())")
        key = F.structural_fingerprint(m)
        policy = C.dtype_policy(dtype, index_dtype)
        e = self._entries.get(key)
        if e is not None:
            self._check_contract(e, m, policy)
            self._entries.move_to_end(key)
            e.hits += 1
            sha = _data_sha(m.data)
            if sha != e._data_sha:
                self._swap_values(e, m)
                e._data_sha = sha
            return e

        tune = self.tune if tune is None else tune
        build_kwargs = {"format": format}
        tune_info = None
        if tune in ("auto", "force"):
            from repro.tune import autotune
            tr = autotune(m, format=format, dtype=dtype,
                          index_dtype=index_dtype, cache=self.cache,
                          force=(tune == "force"),
                          measure_fn=self.measure_fn)
            build_kwargs = tr.best.build_kwargs()
            tune_info = {"cached": tr.cached, "label": tr.best.label()}
        elif tune not in ("off", False, None):
            raise ValueError(f"tune must be 'auto', 'force' or 'off'; "
                             f"got {tune!r}")

        from repro.core.operator import operator
        op = operator(m, dtype=dtype, index_dtype=index_dtype,
                      backend=backend, **build_kwargs)
        # Record the RESOLVED layout, not the request: the value-map
        # build must replay the exact conversion.
        build_kwargs = dict(build_kwargs)
        build_kwargs["format"] = op.fmt
        e = ResidentOperator(key=key, op=op, shape=tuple(m.shape),
                             nnz=m.nnz, policy=policy, backend=backend,
                             build_kwargs=build_kwargs,
                             tune_info=tune_info, host=True,
                             _data_sha=_data_sha(m.data), _dtype=dtype)
        self._insert(key, e)
        return e

    def admit_operator(self, op, key: Optional[str] = None
                       ) -> ResidentOperator:
        """Register an EXISTING operator (no host matrix).  No tuning,
        no value swaps — the compatibility path :class:`~repro.serve.
        engine.SolveEngine` rides; ``key`` defaults to an opaque
        identity key (such entries never alias a fingerprint)."""
        key = key or f"opaque:{id(op):x}"
        e = self._entries.get(key)
        if e is not None:
            self._entries.move_to_end(key)
            e.hits += 1
            return e
        e = ResidentOperator(key=key, op=op, shape=tuple(op.shape),
                             nnz=-1, policy="as-built", host=False)
        self._insert(key, e)
        return e

    def evict(self, key: str) -> bool:
        return self._entries.pop(key, None) is not None

    def stats(self) -> dict:
        return {"resident": len(self._entries), "capacity": self.capacity,
                "evictions": self.evictions,
                "entries": [e.stats() for e in self._entries.values()]}

    # -- internals ---------------------------------------------------------
    def _insert(self, key: str, e: ResidentOperator) -> None:
        self._entries[key] = e
        while len(self._entries) > self.capacity:
            self._entries.popitem(last=False)
            self.evictions += 1

    @staticmethod
    def _check_contract(e: ResidentOperator, m, policy: str) -> None:
        if tuple(e.shape) != tuple(m.shape) or e.nnz != m.nnz:
            raise RegistryMismatch(
                f"fingerprint {e.key[:12]} hit with mismatched structure: "
                f"resident shape={e.shape} nnz={e.nnz}, "
                f"offered shape={tuple(m.shape)} nnz={m.nnz}")
        if e.policy != policy:
            raise RegistryMismatch(
                f"fingerprint {e.key[:12]} hit with mismatched dtype "
                f"policy: resident {e.policy!r}, offered {policy!r} — "
                "evict first or use a separate registry per storage "
                "contract")
        if not e.host:
            raise RegistryMismatch(
                f"entry {e.key[:12]} was admitted as an opaque operator; "
                "it cannot serve host-matrix admissions")

    def _swap_values(self, e: ResidentOperator, m) -> None:
        """New coefficients on the resident structure, without touching
        it: gather the host value stream through the entry's value map
        into the stored layout and ``with_values`` the operator.  Falls
        back to a full rebuild when the map cannot be exact."""
        vmap = self._value_map(e, m)
        if vmap is None:
            from repro.core.operator import operator
            kw = dict(e.build_kwargs)
            e.op = operator(m, dtype=e._dtype, backend=e.backend, **kw)
        else:
            stored = np.where(vmap >= 0, m.data[np.clip(vmap, 0, None)],
                              0.0).astype(np.float32)
            e.op = e.op.with_values(
                jnp.asarray(stored).astype(e.op.values.dtype))
        e.swaps += 1
        e.version += 1

    @staticmethod
    def _value_map(e: ResidentOperator, m) -> Optional[np.ndarray]:
        """stored-slot -> host-nnz-index (-1 for padding), built ONCE
        per entry by replaying the structure conversion on a tag stream
        (data[i] = i + 1, exactly representable in f32 below 2^24):
        every stored slot then carries the index of the host nonzero it
        came from — format conversions are pure gather/pad, so this is
        the whole layout in one array."""
        if e._val_map is not None:
            return e._val_map
        if m.nnz >= _MAP_EXACT_NNZ:
            return None
        import dataclasses as _dc

        from repro.kernels import ops as K

        tags = np.arange(1, m.nnz + 1, dtype=np.float32)
        m_tag = _dc.replace(m, data=tags)
        dev = K.as_device(m_tag, **e.build_kwargs)
        stored = dev.dev.data if dev.fmt == "csr" else dev.dev.val
        stored = np.asarray(stored, dtype=np.float64)
        vmap = np.rint(stored).astype(np.int64) - 1
        if vmap.shape != tuple(e.op.values.shape):
            return None                      # layout replay drifted: rebuild
        e._val_map = vmap
        return vmap
