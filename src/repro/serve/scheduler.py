"""Continuous-batching solve scheduler over the operator registry.

The serving pipeline (DESIGN.md §12) is

    submit() ──admission──> per-operator queue ──tick()──> block-CG group
       │                        │                              │
       rejected (typed)         shed (deadline expired)        certified /
                                                               bisected

* **Async admission** — :meth:`SolveScheduler.submit` validates the RHS
  against its tenant's resident operator (shape, finiteness) and
  enqueues; nothing solves until a tick.  Submission order is preserved
  per operator except where deadlines reorder it.
* **Continuous RHS batching** — every :meth:`~SolveScheduler.tick`
  pops up to ``slots`` queued requests PER resident operator and solves
  them as ONE multi-RHS block-CG group (``repro.solve(...,
  method="block_cg")``), so each CG iteration streams the matrix once
  for the whole group — the k-RHS spMM amortisation PR 2 measured
  (>3.5x over k separate matvecs) collected from the request queue
  instead of from a caller who hand-batches.  Completed groups free
  their slots for the next tick's queue drain: tick-based slot
  recycling, the block-solve analogue of token-level continuous
  batching.
* **Deadline-aware shedding** — a request may carry ``deadline_s``
  (seconds after submission).  Expired requests are shed at the next
  tick, before they can occupy a slot; live deadlined requests are
  batched earliest-deadline-first ahead of deadline-free ones.
* **Certification + bisection** — each group rides PR 7's machinery
  (:class:`GroupSolver`): per-column certification against the original
  system, poisoned-group bisection isolating a bad column in O(log
  slots) re-solves, typed per-request ``status``.  A bisection consumes
  extra group solves, not extra tickets: the healthy requests complete
  in the same tick and their slots recycle normally.
* **Metrics** — every event lands in a :class:`~repro.serve.metrics.
  ServeMetrics` (queue/solve/total latency, batch occupancy, typed
  counters) and each completed request carries its own summary under
  ``request.diagnostics["serve"]``.

The scheduler owns time through an injectable ``clock`` (default
``time.monotonic``) — deterministic-clock tests drive shedding and
latency accounting without sleeping.  SPD systems only: the block-CG
contract, inherited from the group solver.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax.numpy as jnp
import numpy as np

from .metrics import ServeMetrics
from .registry import OperatorRegistry, ResidentOperator

__all__ = ["SolveRequest", "GroupSolver", "SolveScheduler"]


@dataclasses.dataclass
class SolveRequest:
    """One tenant solve request: ``x = A^-1 b`` against the resident
    operator of ``tenant`` (a registry key; optional when only one
    operator is resident).  ``deadline_s`` counts from submission."""

    rid: int
    b: np.ndarray                # (n,) right-hand side, original basis
    tenant: Optional[str] = None
    deadline_s: Optional[float] = None
    x: Optional[np.ndarray] = None
    iters: int = 0
    residual: float = float("inf")
    status: str = "pending"      # queued/converged/maxiter/breakdown/
    #                              diverged/non_finite/rejected/shed/error
    diagnostics: dict = dataclasses.field(default_factory=dict)
    done: bool = False


class GroupSolver:
    """Certified block-CG group solves against ONE resident operator.

    This is PR 7's hardened engine core, re-homed so the scheduler (and
    the :class:`~repro.serve.engine.SolveEngine` compatibility shim) can
    share it: zero-padded ``slots``-column dispatch, per-column
    certification in the solver's own basis, poisoned-group bisection,
    typed statuses.  ``dispatch_fn`` / ``admit_fn`` are indirection
    hooks for the shim (the chaos suite monkeypatches the engine's
    methods; the hooks route those patches here).

    Reads ``entry.op`` at every dispatch and keys the cached Jacobi
    scaling on ``entry.version``, so registry value swaps take effect
    without rebuilding the solver.
    """

    def __init__(self, entry: ResidentOperator, *, slots: int = 4,
                 maxiter: int = 2000, tol: float = 1e-6,
                 jacobi_precond: bool = False, cert_slack: float = 10.0,
                 metrics: Optional[ServeMetrics] = None,
                 dispatch_fn: Optional[Callable] = None,
                 admit_fn: Optional[Callable] = None):
        if entry.op.shape[0] != entry.op.shape[1]:
            raise ValueError("GroupSolver serves square systems")
        self.entry = entry
        self.slots = slots
        self.maxiter = maxiter
        self.tol = tol
        self.jacobi_precond = jacobi_precond
        # tol stops the recurrence; certification accepts within
        # cert_slack * tol (recurrence-vs-true drift near the storage
        # dtype's accuracy floor; a poisoned column sits at NaN or
        # O(1), orders of magnitude past any sane slack).
        self._cert_tol = tol * cert_slack
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self._dispatch_fn = dispatch_fn
        self._admit_fn = admit_fn
        self._scale = None
        self._scaled_apply = None
        self._scale_version = None

    # -- hooks -------------------------------------------------------------
    def admit(self, req: SolveRequest) -> bool:
        if self._admit_fn is not None:
            return self._admit_fn(req)
        return self.admit_impl(req)

    def dispatch(self, batch: List[SolveRequest]):
        if self._dispatch_fn is not None:
            return self._dispatch_fn(batch)
        return self.dispatch_impl(batch)

    # -- admission ---------------------------------------------------------
    def admit_impl(self, req: SolveRequest) -> bool:
        """Reject a request whose RHS would poison a batch: wrong
        shape, too long for the operator, or non-finite entries."""
        op = self.entry.op
        b = np.asarray(req.b)
        reason = None
        if b.ndim != 1:
            reason = f"b must be 1-D, got shape {b.shape}"
        elif len(b) > op.shape[0]:
            reason = f"b has {len(b)} rows, operator has {op.shape[0]}"
        elif not np.all(np.isfinite(b)):
            reason = "b contains non-finite values"
        if reason is not None:
            req.status = "rejected"
            req.diagnostics["reason"] = reason
            req.done = True
            return False
        return True

    # -- dispatch ----------------------------------------------------------
    def _jacobi(self):
        """(scale, scaled_apply) for the current operator values; the
        closure is the block solver's static jit key, so it is rebuilt
        only when a value swap bumps ``entry.version``."""
        if not self.jacobi_precond:
            return None, None
        if self._scale_version != self.entry.version:
            op = self.entry.op
            d = np.asarray(op.diagonal())
            scale = np.where(d > 0, 1.0 / np.sqrt(np.abs(d) + 1e-30),
                             1.0).astype(d.dtype)
            s = jnp.asarray(scale)[:, None]
            self._scale = scale
            self._scaled_apply = lambda X: s * op.matmat(s * X)
            self._scale_version = self.entry.version
        return self._scale, self._scaled_apply

    def dispatch_impl(self, batch: List[SolveRequest]):
        """One block-CG solve for ``batch`` (zero-padded to ``slots``
        columns so the jit key is batch-size independent).  Returns
        ``(x, rr, rr_cert, res)`` where ``rr`` is the per-column TRUE
        relative residual of the ORIGINAL system and ``rr_cert`` the
        certification signal in the basis the solver targeted tol in."""
        import repro
        op = self.entry.op
        scale, scaled_apply = self._jacobi()
        n = op.shape[0]
        dt = np.dtype(op.dtype) if np.dtype(op.dtype).kind == "f" \
            else np.dtype(np.float32)
        bmat = np.zeros((n, self.slots), dtype=dt)
        for j, req in enumerate(batch):
            bmat[: len(req.b), j] = req.b
        if scale is None:
            res = repro.solve(op, jnp.asarray(bmat), method="block_cg",
                              maxiter=self.maxiter, tol=self.tol,
                              fallback="off")
            x = np.asarray(res.x)
        else:
            res = repro.solve(scaled_apply,
                              jnp.asarray(scale[:, None] * bmat),
                              method="block_cg", maxiter=self.maxiter,
                              tol=self.tol, fallback="off")
            x = np.asarray(scale[:, None] * np.asarray(res.x))
        with np.errstate(invalid="ignore", over="ignore"):
            ax = np.asarray(op.matmat(jnp.asarray(x)))
            r = bmat - ax
            rr = np.linalg.norm(r, axis=0) \
                / np.maximum(np.linalg.norm(bmat, axis=0), 1e-30)
            if scale is None:
                rr_cert = rr
            else:
                # s*(b - A x) == b' - A' x', so no second matmat needed.
                sc = scale[:, None]
                rr_cert = np.linalg.norm(sc * r, axis=0) \
                    / np.maximum(np.linalg.norm(sc * bmat, axis=0), 1e-30)
        return x, rr, rr_cert, res

    # -- group solve with certification + bisection ------------------------
    def solve_group(self, batch: List[SolveRequest]) -> None:
        """Solve a group, certify each column, bisect on failure.

        A single poisoned column corrupts the whole block-CG recurrence
        (the Gram matrix couples the columns), so certification failure
        says "someone in this group is bad", not who.  Splitting the
        group in half and re-solving isolates the culprit in
        O(log slots) extra solves while every healthy request still
        gets a certified answer."""
        try:
            x, rr, rr_cert, res = self.dispatch(batch)
        except Exception as e:                       # infrastructure failure
            if len(batch) == 1:
                req = batch[0]
                req.status = "error"
                req.diagnostics["error"] = f"{type(e).__name__}: {e}"
                req.done = True
                return
            self.metrics.inc("group_splits")
            mid = (len(batch) + 1) // 2
            self.solve_group(batch[:mid])
            self.solve_group(batch[mid:])
            return
        retry: List[SolveRequest] = []
        for j, req in enumerate(batch):
            rn = float(rr_cert[j])
            if np.isfinite(rn) and rn <= self._cert_tol:
                req.x = x[: len(req.b), j]
                req.iters = int(res.iters)
                req.residual = float(rr[j])
                req.status = "converged"
                req.done = True
            elif len(batch) == 1:
                # isolated and still failing: this request is the poison
                req.x = x[: len(req.b), j]
                req.iters = int(res.iters)
                req.residual = float(rr[j])
                req.status = "non_finite" if not np.isfinite(rn) \
                    else res.status
                if req.status == "converged":   # recurrence lied; rn didn't
                    req.status = "diverged"
                req.diagnostics["true_residual"] = rn
                req.diagnostics.update(
                    {k: v for k, v in res.diagnostics.items()
                     if k not in req.diagnostics})
                req.done = True
            else:
                retry.append(req)
        if retry:
            self.metrics.inc("group_splits")
            if len(retry) == 1:
                self.solve_group(retry)
            else:
                mid = (len(retry) + 1) // 2
                self.solve_group(retry[:mid])
                self.solve_group(retry[mid:])


@dataclasses.dataclass
class _Queued:
    req: SolveRequest
    key: str
    seq: int
    t_submit: float
    t_deadline: Optional[float]       # absolute clock time; None = never


class SolveScheduler:
    """The multi-tenant serving loop; see the module docstring.

    ``registry`` holds the resident operators (one queue + one
    :class:`GroupSolver` per resident).  ``slots``/``maxiter``/``tol``/
    ``jacobi_precond``/``cert_slack`` parameterize every group solver;
    ``clock`` is injectable for deterministic tests."""

    def __init__(self, registry: OperatorRegistry, *, slots: int = 4,
                 maxiter: int = 2000, tol: float = 1e-6,
                 jacobi_precond: bool = False, cert_slack: float = 10.0,
                 clock: Callable[[], float] = time.monotonic,
                 metrics: Optional[ServeMetrics] = None):
        self.registry = registry
        self.slots = slots
        self.maxiter = maxiter
        self.tol = tol
        self.jacobi_precond = jacobi_precond
        self.cert_slack = cert_slack
        self.clock = clock
        self.metrics = metrics if metrics is not None else ServeMetrics()
        self._queues: Dict[str, deque] = {}
        self._solvers: Dict[str, GroupSolver] = {}
        self._seq = 0

    # -- solvers -----------------------------------------------------------
    def solver_for(self, entry: ResidentOperator) -> GroupSolver:
        s = self._solvers.get(entry.key)
        if s is None or s.entry is not entry:
            s = GroupSolver(entry, slots=self.slots, maxiter=self.maxiter,
                            tol=self.tol, jacobi_precond=self.jacobi_precond,
                            cert_slack=self.cert_slack, metrics=self.metrics)
            self._solvers[entry.key] = s
        return s

    def _resolve_entry(self, tenant: Optional[str]) -> ResidentOperator:
        if tenant is None:
            entries = self.registry.entries()
            if len(entries) == 1:
                return entries[0]
            raise ValueError(
                f"tenant=None is ambiguous with {len(entries)} resident "
                "operators; pass the registry key (request.tenant)")
        e = self.registry.get(tenant)
        if e is None:
            raise KeyError(f"no resident operator for tenant {tenant!r} — "
                           "admit it first (registry.admit)")
        return e

    # -- admission ---------------------------------------------------------
    def submit(self, req: SolveRequest,
               tenant: Optional[str] = None) -> SolveRequest:
        """Asynchronous admission: validate, enqueue, return.  The
        request solves at a later :meth:`tick`; a rejected request is
        finalized immediately (typed ``status="rejected"``)."""
        key_req = tenant if tenant is not None else req.tenant
        entry = self._resolve_entry(key_req)
        req.tenant = entry.key
        solver = self.solver_for(entry)
        if not solver.admit(req):
            self.metrics.inc("rejected")
            return req
        now = self.clock()
        self._seq += 1
        item = _Queued(req=req, key=entry.key, seq=self._seq, t_submit=now,
                       t_deadline=(None if req.deadline_s is None
                                   else now + req.deadline_s))
        self._queues.setdefault(entry.key, deque()).append(item)
        req.status = "queued"
        self.metrics.inc("admitted")
        return req

    # -- the serving loop --------------------------------------------------
    def pending(self) -> int:
        return sum(len(q) for q in self._queues.values())

    def _shed_expired(self, items: List[_Queued], now: float
                      ) -> List[_Queued]:
        live = []
        for it in sorted(items, key=lambda i: (i.t_deadline is None,
                                               i.t_deadline or 0.0, i.seq)):
            if it.t_deadline is not None and now >= it.t_deadline:
                it.req.status = "shed"
                it.req.diagnostics["deadline_s"] = it.req.deadline_s
                it.req.diagnostics["serve"] = {
                    "queue_s": now - it.t_submit, "tenant": it.key}
                it.req.done = True
                self.metrics.inc("shed")
            else:
                live.append(it)
        return live

    def tick(self) -> int:
        """One scheduling round: per resident operator, shed expired
        requests, form ONE group (earliest-deadline-first, FIFO among
        deadline-free), solve it, account.  Returns the number of
        requests finalized this tick (solved, failed, or shed)."""
        finalized = 0
        for key in list(self._queues):
            q = self._queues.get(key)
            if not q:
                self._queues.pop(key, None)
                continue
            now = self.clock()
            n_before = len(q)
            live = self._shed_expired(list(q), now)
            finalized += n_before - len(live)
            # EDF among deadlined, then FIFO: _shed_expired already
            # returns that order (deadlined ascending, then by seq).
            batch_items = live[: self.slots]
            rest = live[self.slots:]
            self._queues[key] = deque(rest)
            if not batch_items:
                continue
            entry = self.registry.get(key)
            solver = self.solver_for(entry)
            t_start = self.clock()
            solver.solve_group([it.req for it in batch_items])
            t_end = self.clock()
            self.metrics.observe_batch(len(batch_items), self.slots)
            for it in batch_items:
                req = it.req
                queue_s = t_start - it.t_submit
                solve_s = t_end - t_start
                req.diagnostics["serve"] = {
                    "queue_s": queue_s, "solve_s": solve_s,
                    "total_s": queue_s + solve_s,
                    "batch_k": len(batch_items), "tenant": key,
                }
                self.metrics.observe_request(queue_s, solve_s,
                                             queue_s + solve_s)
                if req.status == "converged":
                    self.metrics.inc("converged")
                elif req.status == "error":
                    self.metrics.inc("error")
                else:
                    self.metrics.inc("failed")
                finalized += 1
        return finalized

    def run_until_drained(self, max_ticks: int = 10_000) -> int:
        """Tick until every queue is empty; returns ticks consumed."""
        ticks = 0
        while self.pending() and ticks < max_ticks:
            self.tick()
            ticks += 1
        return ticks
