"""Pallas TPU kernel for ELLPACK-R sparse matrix-vector multiplication.

TPU adaptation of paper Listing 1 — the baseline the paper improves on.

Layout: ``val``/``col_idx`` are ``(max_nzr, n_pad)`` jagged-diagonal-major
(the paper's ``val[j*N + i]``), tiled as (chunk_l sublanes, tile_r lanes).
``col_idx`` may be an int16 compressed stream; ``val`` may be bf16 (f32
accumulation), same contract as the blocked kernels.

ELLPACK-R semantics on TPU: the *storage* is padded to the global max row
length (that is ELLPACK's deficiency the paper fixes), but the *compute*
skips whole tiles whose rows are all shorter than the current jagged
diagonal — the scalar-prefetched ``tile_chunks`` array holds the
per-row-tile chunk count, the tile-granular analogue of the per-thread
``rowmax[]`` early exit.  Unlike a GPU warp, a TPU grid step is
all-or-nothing, so skipping happens at (chunk_l x tile_r) tile
granularity; without the pJDS sort, one long row in a tile forces the
whole tile through — exactly the "light boxes" hardware-reservation
waste of paper Fig. 2b, reproduced structurally.  Skipped steps also
clamp their val/col index maps to the tile's last real chunk, so the
early exit saves the DMA traffic as well as the compute.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._backend import acc_dtype, chunk_clamp, resolve_interpret

__all__ = ["ell_matvec_kernel_call"]


def _ellr_spmv_kernel(tile_chunks_ref, val_ref, col_ref, x_ref, y_ref):
    i = pl.program_id(0)   # row tile
    j = pl.program_id(1)   # jagged-diagonal chunk

    @pl.when(j == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    # ELLPACK-R early exit: skip chunks past this tile's longest row.
    @pl.when(j < tile_chunks_ref[i])
    def _body():
        x = x_ref[...]
        gathered = x[col_ref[...].astype(jnp.int32)]
        dt = y_ref.dtype
        contrib = val_ref[...].astype(dt) * gathered.astype(dt)
        y_ref[...] += jnp.sum(contrib, axis=0)[None, :]


@functools.partial(
    jax.jit,
    static_argnames=("chunk_l", "tile_r", "interpret"),
)
def ell_matvec_kernel_call(
    val: jax.Array,
    col_idx: jax.Array,
    tile_chunks: jax.Array,
    x: jax.Array,
    *,
    chunk_l: int = 8,
    tile_r: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """y = A_ell @ x.

    val/col_idx: (max_nzr, n_pad), max_nzr % chunk_l == 0, n_pad % tile_r == 0;
    col_idx int16 or int32.
    tile_chunks: (n_pad // tile_r,) int32 — ceil(tile_row_max / chunk_l).
    interpret:   None = compiled on TPU, interpret elsewhere.
    """
    max_nzr, n_pad = val.shape
    if max_nzr % chunk_l or n_pad % tile_r:
        raise ValueError("shape not aligned to (chunk_l, tile_r)")
    n_chunks = max_nzr // chunk_l
    n_tiles = n_pad // tile_r
    dt = acc_dtype(val.dtype, x.dtype)

    # Clamp skipped chunks' DMAs to the tile's last computed chunk (an
    # all-empty tile has tile_chunks == 0: chunk_clamp guards it).
    mat_map = lambda i, j, tc: (chunk_clamp(j, tc[i]), i)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(n_tiles, n_chunks),
        in_specs=[
            pl.BlockSpec((chunk_l, tile_r), mat_map),                 # val
            pl.BlockSpec((chunk_l, tile_r), mat_map),                 # col
            pl.BlockSpec(x.shape, lambda i, j, tc: (0,)),             # x resident
        ],
        out_specs=pl.BlockSpec((1, tile_r), lambda i, j, tc: (i, 0)),
    )
    y = pl.pallas_call(
        _ellr_spmv_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_tiles, tile_r), dt),
        interpret=resolve_interpret(interpret),
        name="ellr_spmv",
    )(tile_chunks, val, col_idx, x)
    return y.reshape(n_pad)
