"""Fused Krylov-iteration kernel: SELL-C-sigma spMV + partial dot
reductions in ONE pass over the stored tiles.

The paper's roofline (§4) prices an spMVM-bound solver entirely by HBM
traffic per iteration, and the SELL-C-sigma follow-up (arXiv:1307.6209)
points at amortising that traffic across the whole iteration as the way
past it.  A composed CG/BiCGStab step leaks traffic around the spMV
kernel: the dot products (<p,Ap>, <r,r>, ...) re-read y = Ax and the
carrier vectors from HBM as separate HLO reductions.  This kernel rides
``sell_spmv.py``'s PrefetchScalarGridSpec grid unchanged — scalar-
prefetched window extents, VMEM-pinned output slab, window-local
unpermute fused as the slab epilogue — and extends the epilogue: while
the finished slab is STILL VMEM-resident (already back in original row
order), it reduces the three lane-partial dot products

    d1 = <y, w1>   d2 = <y, w2>   dy = <y, y>   dw = <w2, w2>
    dz = <w1, w2>

against two weight slabs that ride the same (w, 0) BlockSpec as the
inverse permutation.  The partials leave the kernel as one (n_win, b_r)
row per window — b_r lanes instead of n_rows elements — and a tiny jnp
``sum`` outside finishes the scalars.  y itself is written to HBM once,
exactly as before; the dots cost no extra pass over y or the carriers.

``dw`` and ``dz`` never touch y at all: the self-dot of the second
weight slab and the cross-dot of the two weight slabs, reduced while
both are resident anyway.  The solvers always route their residual-type
carrier through ``w2``, so every iteration gets an EXACT ||r||^2 (or
||s||^2) for free — the scalar that, carried purely by recurrence,
cancels catastrophically once convergence is fast (the classic
pipelined-CG drift) — and BiCGStab reads the EXACT <rhat, s> from
``dz`` instead of assuming it zero (the assumption whose f32 drift
stalls the pipelined rho recurrence).  Only the single-step look-ahead
used for the loop's exit test remains a recurrence.

With the right (w1, w2) choice per call, a fully-recurrent CG/BiCGStab
body (``core.solvers.fused_cg`` / ``fused_bicgstab``) needs NO other
per-iteration vector reduction: every alpha/beta/omega/residual-norm
scalar follows algebraically from these four dots.

Restrictions (checked): resident RHS only (``x_tiles == 1`` — the
column-blocked grid would visit the slab once per x tile and the
epilogue runs once), square operands, 1-D carriers.  ``dw`` is reduced
in the epilogue, so a sigma-window with NO stored chunks contributes
nothing to it — exact whenever every row window stores at least one
chunk (any operand with nonzero diagonals qualifies; the dispatcher's
ref path has no such caveat).

Off-TPU the dispatcher (:func:`fused_matvec_dots`) uses the jnp ref
path — ``sell_matvec_ref`` plus the five dots — which XLA fuses inside
the solver's ``while_loop``; the kernel path compiles on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from . import ref as R
from ._backend import acc_dtype, chunk_clamp, resolve_interpret
from .pjds_spmv import block_extents
from .sell_spmv import window_blocks

__all__ = ["fused_spmv_dots_kernel_call", "fused_matvec_dots",
           "make_matvec_dots"]


def _fused_iter_kernel(wstart_ref, wcnt_ref, slot_ref, val_ref, col_ref,
                       x_ref, w1_ref, w2_ref, inv_ref,
                       y_ref, d1_ref, d2_ref, dy_ref, dw_ref, dz_ref):
    w = pl.program_id(0)
    c = pl.program_id(1)

    # First visit of this window: zero the slab AND its dot partials (a
    # window with no stored chunks never reaches the epilogue, so its
    # contribution to every dot must already be zero).
    @pl.when(c == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)
        d1_ref[...] = jnp.zeros_like(d1_ref)
        d2_ref[...] = jnp.zeros_like(d2_ref)
        dy_ref[...] = jnp.zeros_like(dy_ref)
        dw_ref[...] = jnp.zeros_like(dw_ref)
        dz_ref[...] = jnp.zeros_like(dz_ref)

    @pl.when(c < wcnt_ref[w])
    def _body():
        slot = slot_ref[wstart_ref[w] + c]       # row block within the slab
        idx = col_ref[...].astype(jnp.int32)     # (chunk_l, b_r); int16 ok
        contrib = val_ref[...].astype(y_ref.dtype) \
            * x_ref[idx].astype(y_ref.dtype)
        y_ref[slot, :] += jnp.sum(contrib, axis=0)

    # Epilogue on the window's last chunk: unpermute in-slab (exactly as
    # sell_spmv does), then reduce the dot partials against the weight
    # slabs while everything is VMEM-resident — the permutation AND the
    # reductions never touch HBM.
    @pl.when(c == wcnt_ref[w] - 1)
    def _epilogue():
        ys = y_ref[...].reshape(-1)
        yo = ys[inv_ref[...].reshape(-1)].reshape(y_ref.shape)
        y_ref[...] = yo
        w1s = w1_ref[...].astype(yo.dtype)
        w2s = w2_ref[...].astype(yo.dtype)
        d1_ref[0, :] = jnp.sum(yo * w1s, axis=0)
        d2_ref[0, :] = jnp.sum(yo * w2s, axis=0)
        dy_ref[0, :] = jnp.sum(yo * yo, axis=0)
        dw_ref[0, :] = jnp.sum(w2s * w2s, axis=0)
        dz_ref[0, :] = jnp.sum(w1s * w2s, axis=0)


@functools.partial(
    jax.jit,
    static_argnames=("n_blocks", "chunk_l", "sigma", "max_win_chunks",
                     "interpret"),
)
def fused_spmv_dots_kernel_call(
    val: jax.Array,
    col_idx: jax.Array,
    chunk_map: jax.Array,
    inv_perm: jax.Array,
    x: jax.Array,
    w1: jax.Array,
    w2: jax.Array,
    *,
    n_blocks: int,
    chunk_l: int = 8,
    sigma: int = 0,
    max_win_chunks: int | None = None,
    interpret: bool | None = None,
):
    """(y, <y,w1>, <y,w2>, <y,y>, <w2,w2>, <w1,w2>) with y = A_sell @ x
    in ORIGINAL row order.

    Same operand contract as ``sell_matvec_kernel_call`` (resident-x
    grid only); ``w1``/``w2`` are (n_blocks * b_r,) weight vectors in
    the original basis, zero-padded past the real rows — padded rows
    store zero values, so y is zero there and the y-dots are exact.
    Returns y of shape (n_blocks * b_r,) plus five scalars, all in the
    accumulator dtype.  The <w2,w2> and <w1,w2> partials reduce in the
    epilogue, so they miss windows with zero stored chunks (see module
    docstring).
    """
    total_jds, b_r = val.shape
    if total_jds % chunk_l:
        raise ValueError(
            f"total_jds={total_jds} not a multiple of chunk_l={chunk_l}")
    n_pad = n_blocks * b_r
    for name, v in (("inv_perm", inv_perm), ("w1", w1), ("w2", w2)):
        if v.shape != (n_pad,):
            raise ValueError(f"{name} shape {v.shape} != ({n_pad},)")
    n_chunks = total_jds // chunk_l
    if max_win_chunks is None:
        max_win_chunks = n_chunks
    dt = acc_dtype(val.dtype, x.dtype)

    w_b = window_blocks(sigma, b_r, n_blocks)
    n_win = -(-n_blocks // w_b)
    n_out = n_win * w_b * b_r
    win_map = chunk_map // w_b
    wstart, wcnt = block_extents(win_map, n_win)
    slot = (chunk_map - win_map * w_b).astype(jnp.int32)
    inv_pad = jnp.concatenate([
        inv_perm.astype(jnp.int32),
        jnp.arange(n_pad, n_out, dtype=jnp.int32)])
    inv_local = (inv_pad - (jnp.arange(n_out, dtype=jnp.int32)
                            // (w_b * b_r)) * (w_b * b_r))
    inv_local = inv_local.reshape(n_win * w_b, b_r)

    def _slab(v):
        return jnp.pad(v, (0, n_out - n_pad)).reshape(n_win * w_b, b_r)

    x_len = x.shape[0]
    mat_map = lambda w, c, ws, wc, sl: (ws[w] + chunk_clamp(c, wc[w]), 0)
    slab_map = lambda w, c, ws, wc, sl: (w, 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_win, max_win_chunks),
        in_specs=[
            pl.BlockSpec((chunk_l, b_r), mat_map),                 # val
            pl.BlockSpec((chunk_l, b_r), mat_map),                 # col
            pl.BlockSpec((x_len,), lambda w, c, ws, wc, sl: (0,)),  # x
            pl.BlockSpec((w_b, b_r), slab_map),                    # w1 slab
            pl.BlockSpec((w_b, b_r), slab_map),                    # w2 slab
            pl.BlockSpec((w_b, b_r), slab_map),                    # inv slab
        ],
        out_specs=[
            pl.BlockSpec((w_b, b_r), slab_map),                    # y slab
            pl.BlockSpec((1, b_r), slab_map),                      # d1
            pl.BlockSpec((1, b_r), slab_map),                      # d2
            pl.BlockSpec((1, b_r), slab_map),                      # dy
            pl.BlockSpec((1, b_r), slab_map),                      # dw
            pl.BlockSpec((1, b_r), slab_map),                      # dz
        ],
    )
    y_blk, d1, d2, dy, dw, dz = pl.pallas_call(
        _fused_iter_kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_win * w_b, b_r), dt),
            jax.ShapeDtypeStruct((n_win, b_r), dt),
            jax.ShapeDtypeStruct((n_win, b_r), dt),
            jax.ShapeDtypeStruct((n_win, b_r), dt),
            jax.ShapeDtypeStruct((n_win, b_r), dt),
            jax.ShapeDtypeStruct((n_win, b_r), dt),
        ],
        interpret=resolve_interpret(interpret),
        name="fused_iter_spmv_dots",
    )(wstart, wcnt, slot, val, col_idx, x, _slab(w1), _slab(w2), inv_local)
    y = y_blk.reshape(n_out)[:n_pad]
    return y, d1.sum(), d2.sum(), dy.sum(), dw.sum(), dz.sum()


def fused_matvec_dots(a, x, w1, w2, *, backend: str = "ref",
                      interpret: bool | None = None):
    """Dispatching (y, <y,w1>, <y,w2>, <y,y>, <w2,w2>, <w1,w2>) over a
    ``SELLDevice``.

    ``backend`` is the RESOLVED backend string ("kernel" on TPU, "ref"
    elsewhere — callers go through ``ops.resolve_backend``); the ref
    path is the same gather/segment-sum jnp program the plain sell
    matvec uses, plus three dots XLA fuses into the solver loop.
    Carriers live at the padded length ``a.n_rows_pad``.
    """
    if backend == "kernel":
        return fused_spmv_dots_kernel_call(
            a.val, a.col_idx, a.chunk_map, a.inv_perm, x, w1, w2,
            n_blocks=a.n_blocks, chunk_l=a.chunk_l, sigma=a.sigma,
            max_win_chunks=a.max_win_chunks, interpret=interpret)
    y = R.sell_matvec_ref(a.val, a.col_idx, a.row_block, a.inv_perm, x,
                          a.n_blocks)
    dt = y.dtype
    w1c = w1.astype(dt)
    w2c = w2.astype(dt)
    return (y, jnp.vdot(y, w1c), jnp.vdot(y, w2c),
            jnp.vdot(y, y), jnp.vdot(w2c, w2c), jnp.vdot(w1c, w2c))


def make_matvec_dots(a, *, backend: str = "ref"):
    """A stable closure over one ``SELLDevice`` — the static jit key the
    fused solvers (``core.solvers.fused_cg``/``fused_bicgstab``) hash on,
    so build it once per operand and reuse it across solves."""
    def matvec_dots(v, w1, w2):
        return fused_matvec_dots(a, v, w1, w2, backend=backend)
    return matvec_dots
