"""Pallas TPU kernel for pJDS sparse matrix x dense matrix (multi-RHS).

Y = A_pjds @ X with X: (n_cols_pad, n_rhs).  This is the kernel behind
``repro.sparse.SparseFFN`` (pJDS-stored pruned FFN weights applied to a
batch of activations) — the paper's format promoted to a first-class LM
feature (DESIGN.md §4).

Grid: (rhs tile, row block, chunk) with chunks innermost, sharing the
prefetched-extent design of ``pjds_spmv.py``: the scalar-prefetched
``block_chunk_start``/``block_chunks`` arrays drive the val/col
BlockSpec index maps, the (b_r, rhs_t) output block stays VMEM-pinned
across its block's chunk sweep and is written back exactly once per rhs
tile, and the X tile stays resident across a full sweep of the matrix.
Per step the kernel gathers (chunk_l, b_r) rows of the X tile —
amortising each gathered RHS row over ``rhs_t`` lanes, which lifts the
arithmetic intensity from the spMVM's ~2/12 flop/byte to ~2*rhs_t/12:
multi-RHS is how a sparse format escapes the memory roofline on TPU.
int16 index / bf16 value streams cut the per-nonzero matrix bytes the
same way they do for the spMVM kernels; accumulation stays f32.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._backend import acc_dtype, chunk_clamp, resolve_interpret
from .pjds_spmv import block_extents

__all__ = ["pjds_matmat_kernel_call"]


def _pjds_spmm_kernel(start_ref, cnt_ref, val_ref, col_ref, x_ref, y_ref):
    b = pl.program_id(1)
    c = pl.program_id(2)

    @pl.when(c == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(c < cnt_ref[b])
    def _body():
        x = x_ref[...]                              # (n_cols_pad, rhs_t)
        idx = col_ref[...].astype(jnp.int32)        # (chunk_l, b_r); int16 ok
        gathered = x[idx]                           # (chunk_l, b_r, rhs_t)
        dt = y_ref.dtype
        contrib = val_ref[...].astype(dt)[..., None] * gathered.astype(dt)
        y_ref[...] += jnp.sum(contrib, axis=0)      # (b_r, rhs_t)


@functools.partial(
    jax.jit,
    static_argnames=("n_blocks", "chunk_l", "max_chunks", "rhs_t",
                     "interpret"),
)
def pjds_matmat_kernel_call(
    val: jax.Array,
    col_idx: jax.Array,
    chunk_map: jax.Array,
    x: jax.Array,
    *,
    n_blocks: int,
    chunk_l: int = 8,
    max_chunks: int | None = None,
    rhs_t: int = 128,
    interpret: bool | None = None,
) -> jax.Array:
    """Y = A_pjds @ X (permuted basis).

    val/col_idx: (total_jds, b_r), col_idx int16 or int32;
    chunk_map: (total_jds//chunk_l,) non-decreasing int32;
    x: (n_cols_pad, n_rhs) with n_rhs % min(rhs_t, n_rhs) == 0 — the RHS
    tile shrinks to n_rhs for narrow blocks (k < rhs_t), so small
    multi-RHS counts (the distributed block solvers use k ~ 4) run as a
    single tile instead of failing the alignment check.
    max_chunks: static max chunks of any single block (None: total).
    Returns (n_blocks * b_r, n_rhs) in the accumulator dtype.
    """
    total_jds, b_r = val.shape
    n_cols_pad, n_rhs = x.shape
    dt = acc_dtype(val.dtype, x.dtype)
    if n_rhs == 0:                      # empty RHS block: nothing to do
        return jnp.zeros((n_blocks * b_r, 0), dt)
    rhs_t = min(rhs_t, n_rhs)
    if total_jds % chunk_l or n_rhs % rhs_t:
        raise ValueError("shapes not aligned to (chunk_l, rhs_t)")
    n_chunks = total_jds // chunk_l
    if max_chunks is None:
        max_chunks = n_chunks
    n_tiles = n_rhs // rhs_t
    start, cnt = block_extents(chunk_map, n_blocks)

    mat_map = lambda t, b, c, s, n: (s[b] + chunk_clamp(c, n[b]), 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_tiles, n_blocks, max_chunks),
        in_specs=[
            pl.BlockSpec((chunk_l, b_r), mat_map),                       # val
            pl.BlockSpec((chunk_l, b_r), mat_map),                       # col
            pl.BlockSpec((n_cols_pad, rhs_t),
                         lambda t, b, c, s, n: (0, t)),                  # X tile
        ],
        out_specs=pl.BlockSpec((b_r, rhs_t), lambda t, b, c, s, n: (b, t)),
    )
    y = pl.pallas_call(
        _pjds_spmm_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks * b_r, n_rhs), dt),
        interpret=resolve_interpret(interpret),
        name="pjds_spmm",
    )(start, cnt, val, col_idx, x)
    return y
