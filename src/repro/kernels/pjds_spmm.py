"""Pallas TPU kernel for pJDS sparse matrix x dense matrix (multi-RHS).

Y = A_pjds @ X with X: (n_cols_pad, n_rhs).  This is the kernel behind
``repro.sparse.SparseFFN`` (pJDS-stored pruned FFN weights applied to a
batch of activations) — the paper's format promoted to a first-class LM
feature (DESIGN.md §4).

Grid: (rhs tiles, jagged chunks) with chunks innermost so the X tile
stays resident across a full sweep of the matrix.  Per step the kernel
gathers (chunk_l, b_r) rows of the X tile — amortising each gathered RHS
row over ``rhs_t`` lanes, which lifts the arithmetic intensity from the
spMVM's ~2/12 flop/byte to ~2*rhs_t/12: multi-RHS is how a sparse format
escapes the memory roofline on TPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pjds_matmat_kernel_call"]


def _acc_dtype(*dts):
    r = jnp.result_type(*dts)
    if r in (jnp.bfloat16, jnp.float16):
        return jnp.float32
    return r


def _pjds_spmm_kernel(chunk_map_ref, val_ref, col_ref, x_ref, y_ref):
    g = pl.program_id(1)
    blk = chunk_map_ref[g]

    @pl.when(g == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...]                              # (n_cols_pad, rhs_t)
    idx = col_ref[...]                          # (chunk_l, b_r)
    gathered = x[idx]                           # (chunk_l, b_r, rhs_t)
    dt = y_ref.dtype
    contrib = val_ref[...].astype(dt)[..., None] * gathered.astype(dt)
    acc = jnp.sum(contrib, axis=0)              # (b_r, rhs_t)
    b_r = acc.shape[0]
    y_ref[pl.dslice(blk * b_r, b_r), :] += acc


@functools.partial(
    jax.jit,
    static_argnames=("n_blocks", "chunk_l", "rhs_t", "interpret"),
)
def pjds_matmat_kernel_call(
    val: jax.Array,
    col_idx: jax.Array,
    chunk_map: jax.Array,
    x: jax.Array,
    *,
    n_blocks: int,
    chunk_l: int = 8,
    rhs_t: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """Y = A_pjds @ X (permuted basis).

    val/col_idx: (total_jds, b_r); chunk_map: (total_jds//chunk_l,) int32;
    x: (n_cols_pad, n_rhs) with n_rhs % min(rhs_t, n_rhs) == 0 — the RHS
    tile shrinks to n_rhs for narrow blocks (k < rhs_t), so small
    multi-RHS counts (the distributed block solvers use k ~ 4) run as a
    single tile instead of failing the alignment check.
    Returns (n_blocks * b_r, n_rhs) in the accumulator dtype.
    """
    total_jds, b_r = val.shape
    n_cols_pad, n_rhs = x.shape
    dt = _acc_dtype(val.dtype, x.dtype)
    if n_rhs == 0:                      # empty RHS block: nothing to do
        return jnp.zeros((n_blocks * b_r, 0), dt)
    rhs_t = min(rhs_t, n_rhs)
    if total_jds % chunk_l or n_rhs % rhs_t:
        raise ValueError("shapes not aligned to (chunk_l, rhs_t)")
    n_chunks = total_jds // chunk_l
    n_tiles = n_rhs // rhs_t

    y = pl.pallas_call(
        _pjds_spmm_kernel,
        grid=(n_tiles, n_chunks),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                        # chunk_map
            pl.BlockSpec((chunk_l, b_r), lambda t, g: (g, 0)),            # val
            pl.BlockSpec((chunk_l, b_r), lambda t, g: (g, 0)),            # col
            pl.BlockSpec((n_cols_pad, rhs_t), lambda t, g: (0, t)),       # X tile
        ],
        out_specs=pl.BlockSpec((n_blocks * b_r, rhs_t), lambda t, g: (0, t)),
        out_shape=jax.ShapeDtypeStruct((n_blocks * b_r, n_rhs), dt),
        interpret=interpret,
        name="pjds_spmm",
    )(chunk_map, val, col_idx, x)
    return y
