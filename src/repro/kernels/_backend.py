"""Shared backend plumbing for the Pallas kernels.

Lives below ``kernels.ops`` (which imports the kernel modules) so the
kernels themselves can resolve defaults without a circular import;
``ops.resolve_backend`` / ``ops.resolve_interpret`` re-export these as
the public spellings.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = ["resolve_interpret", "acc_dtype", "chunk_clamp", "tile_contrib",
           "pad_x_to_tiles"]


def resolve_interpret(interpret: bool | None) -> bool:
    """The one place the kernels' ``interpret`` default is decided:
    ``None`` (the default everywhere) means *compiled* Pallas on TPU and
    interpret mode elsewhere (CPU/GPU lack a Mosaic backend, interpret
    is the only way the kernels run there at all).  An explicit bool is
    the escape hatch — e.g. ``interpret=True`` on TPU to debug a kernel
    with host prints."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)


def acc_dtype(*dts):
    """Accumulator dtype rule shared by every kernel and ref: sub-f32
    value/RHS streams (bf16/f16 storage) accumulate — and return — in
    f32; f32/f64 stay put.  Low-precision STORAGE never means
    low-precision ARITHMETIC."""
    r = jnp.result_type(*dts)
    if r in (jnp.bfloat16, jnp.float16):
        return jnp.float32
    return r


def chunk_clamp(c, cnt):
    """Clamp a grid chunk index to a block's last REAL chunk — the shared
    piece of every prefetched BlockSpec index map: steps past the block's
    extent keep DMA'ing the same tile (no new transfer) while the kernel
    body's ``pl.when`` skips their compute.  The inner max guards blocks
    whose chunk count is 0 (all-empty ELLPACK-R tiles)."""
    return jnp.minimum(c, jnp.maximum(cnt - 1, 0))


def tile_contrib(val, idx, x, t, x_t, x_tiles, dt):
    """Per-entry contribution ``val * x[idx]`` of one (chunk_l, b_r) tile
    against the resident x tile ``t`` — the shared body of the blocked
    spMV kernels.  With one tile (resident x) it is a plain gather; with
    a column-blocked x the gather is masked to the tile's column range
    (entries outside contribute 0 this sweep and are picked up by their
    own tile)."""
    if x_tiles == 1:
        return val.astype(dt) * x[idx].astype(dt)
    lo = t * x_t
    loc = jnp.clip(idx - lo, 0, x_t - 1)
    hit = (idx >= lo) & (idx < lo + x_t)
    return jnp.where(hit, val.astype(dt) * x[loc].astype(dt), 0)


def pad_x_to_tiles(x: jax.Array, x_tiles: int):
    """Zero-pad a 1-D RHS to a multiple of ``x_tiles`` (kernel tiling
    needs equal tiles; stored column indices never reach the pad, and a
    padded lane's gather is masked or multiplied by a zero value).
    Returns (padded x, tile length)."""
    n = x.shape[0]
    rem = n % x_tiles
    if rem:
        x = jnp.pad(x, (0, x_tiles - rem))
    return x, x.shape[0] // x_tiles
