"""Pallas TPU kernel for CMRS sparse matrix-vector multiplication.

CMRS (arXiv:1203.2946) on the TPU tiling (DESIGN.md §13): rows stay in
ORIGINAL order, grouped into strips of ``b_r`` consecutive rows, and
each strip's nonzeros are packed densely into ``(strip_su, b_r)``
lane-major tiles with an int8 ``row_in_strip`` stream routing every
slot back to its row.  Relative to pJDS this trades per-row padding for
an in-kernel segment reduction:

* The grid and scalar-prefetch machinery are pJDS's exactly —
  ``(strip, x_tile, chunk)`` with per-strip (start, count) extents
  driving the val/col/ris BlockSpec index maps
  (``pjds_spmv.block_extents``); only the reduction differs.
* A pJDS chunk reduces over sublanes (every slot of lane r belongs to
  row r).  A CMRS chunk's slots belong to ARBITRARY rows of the strip,
  so the kernel flattens the chunk to ``(1, chunk_l * b_r)`` and
  multiplies by a one-hot ``(chunk_l * b_r, b_r)`` routing matrix built
  from ``row_in_strip`` — a segment-sum phrased as an MXU matmul,
  costing ``2 * b_r`` flops per stored slot
  (``perf_model.cmrs_reduce_seconds``; dispatch prices the kernel as
  ``max(memory_term, compute_term)``).
* Padding slots carry val == 0 / col == PAD_COL / row_in_strip == 0:
  they gather x[0] and route a zero product into row 0 — harmless, no
  masking needed (the ``formats.PAD_COL`` contract).

VMEM working set per step: 3 matrix tiles (val, col, int8 ris) + the
x tile + the one-hot routing matrix + one (1, b_r) output block.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._backend import (acc_dtype, chunk_clamp, pad_x_to_tiles,
                       resolve_interpret, tile_contrib)
from .pjds_spmv import block_extents

__all__ = ["cmrs_matvec_kernel_call"]


def _cmrs_spmv_kernel(start_ref, cnt_ref, val_ref, col_ref, ris_ref, x_ref,
                      y_ref, *, x_tiles, x_t):
    s = pl.program_id(0)
    t = pl.program_id(1)
    c = pl.program_id(2)

    # First visit of this strip's output block: zero it while VMEM-pinned.
    @pl.when((t == 0) & (c == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(c < cnt_ref[s])
    def _body():
        idx = col_ref[...].astype(jnp.int32)     # (chunk_l, b_r); int16 ok
        contrib = tile_contrib(val_ref[...], idx, x_ref[...], t, x_t,
                               x_tiles, y_ref.dtype)
        chunk_l, b_r = contrib.shape
        flat = contrib.reshape(1, chunk_l * b_r)
        ris = ris_ref[...].astype(jnp.int32).reshape(chunk_l * b_r, 1)
        lanes = jax.lax.broadcasted_iota(jnp.int32, (chunk_l * b_r, b_r), 1)
        onehot = (ris == lanes).astype(y_ref.dtype)
        y_ref[0, :] += jnp.dot(flat, onehot,
                               preferred_element_type=y_ref.dtype)[0]


@functools.partial(
    jax.jit,
    static_argnames=("n_strips", "chunk_l", "max_chunks", "x_tiles",
                     "interpret"),
)
def cmrs_matvec_kernel_call(
    val: jax.Array,
    col_idx: jax.Array,
    row_in_strip: jax.Array,
    chunk_map: jax.Array,
    x: jax.Array,
    *,
    n_strips: int,
    chunk_l: int = 8,
    max_chunks: int | None = None,
    x_tiles: int = 1,
    interpret: bool | None = None,
) -> jax.Array:
    """y = A_cmrs @ x in the ORIGINAL row order.

    val/col_idx/row_in_strip: (total_su, b_r) with total_su % chunk_l
                 == 0 (guaranteed when the format was built with
                 ``diag_align`` a multiple of ``chunk_l``; the
                 ``ops.to_device_cmrs`` wrapper checks).  col_idx int16
                 or int32, row_in_strip int8 — both upcast in-kernel.
    chunk_map:   (total_su // chunk_l,) non-decreasing int32 strip id
                 per chunk.
    x:           (n_cols_pad,) RHS, original column order.
    max_chunks:  static max chunks of any single strip (``CMRSDevice``
                 carries it); None falls back to the total chunk count.
    interpret:   None = compiled on TPU, interpret elsewhere.
    Returns y:   (n_strips * b_r,) in the accumulator dtype.
    """
    total_su, b_r = val.shape
    if total_su % chunk_l:
        raise ValueError(
            f"total_su={total_su} not a multiple of chunk_l={chunk_l}")
    n_chunks = total_su // chunk_l
    if max_chunks is None:
        max_chunks = n_chunks
    x, x_t = pad_x_to_tiles(x, x_tiles)
    dt = acc_dtype(val.dtype, x.dtype)
    start, cnt = block_extents(chunk_map, n_strips)

    mat_map = lambda b, t, c, s, n: (s[b] + chunk_clamp(c, n[b]), 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_strips, x_tiles, max_chunks),
        in_specs=[
            pl.BlockSpec((chunk_l, b_r), mat_map),                # val tile
            pl.BlockSpec((chunk_l, b_r), mat_map),                # col tile
            pl.BlockSpec((chunk_l, b_r), mat_map),                # ris tile
            pl.BlockSpec((x_t,), lambda b, t, c, s, n: (t,)),     # x tile
        ],
        out_specs=pl.BlockSpec((1, b_r), lambda b, t, c, s, n: (b, 0)),
    )
    y_blk = pl.pallas_call(
        functools.partial(_cmrs_spmv_kernel, x_tiles=x_tiles, x_t=x_t),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_strips, b_r), dt),
        interpret=resolve_interpret(interpret),
        name="cmrs_spmv",
    )(start, cnt, val, col_idx, row_in_strip, x)
    return y_blk.reshape(n_strips * b_r)
