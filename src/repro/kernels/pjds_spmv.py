"""Pallas TPU kernel for pJDS sparse matrix-vector multiplication.

This is the TPU adaptation of paper Listing 2, rebuilt around the memory
stream (DESIGN.md §2/§2b):

* ``val``/``col_idx`` are ``(total_jds, b_r)`` with rows on LANES
  (b_r = 128 by default) and jagged diagonals on SUBLANES — the paper's
  column-major ELLPACK layout restricted to each sorted row block.
  ``col_idx`` may be int16 (compressed index stream) or int32; ``val``
  may be bf16 (compressed value stream) or f32/f64 — accumulation is
  always at least f32.
* The grid is 2-D ``(row_block, chunk)`` (3-D with the optional x-tile
  axis): chunks of ``chunk_l`` jagged diagonals stream the row block's
  slab of the matrix while the ``(1, b_r)`` output block stays pinned in
  VMEM — the whole ``y`` never has to be resident, and each output block
  is written back to HBM exactly once.
* The per-block chunk extents ride a ``PrefetchScalarGridSpec``: the
  scalar-prefetched ``block_chunk_start``/``block_chunks`` arrays (both
  derived from ``chunk_map`` inside this call) drive the val/col
  BlockSpec index maps directly, so the next block's tiles are DMA'd
  while the current one computes — no SMEM lookup on the critical path.
  Grid steps past a block's real chunk count clamp their index map to
  the last real tile (no new DMA) and skip compute.
* ``x_tiles > 1`` column-blocks the RHS: grid axis t holds an
  ``n_cols_pad / x_tiles`` slice of x in VMEM and the gather is masked
  to it.  This lifts the x-resident VMEM ceiling for single-device
  matrices at a measured price — the matrix stream is re-read per x
  tile and each output block accumulates across tiles —
  ``perf_model.predicted_spmv_seconds(x_tiles=...)`` prices exactly
  that trade (the distributed layer instead slices x structurally and
  always runs ``x_tiles=1``).

Padded entries follow the ``formats.PAD_COL`` sentinel contract: column
0 (in range — the gather reads x[0] without masking) and value 0 (the
product contributes nothing).

VMEM working set per step: 2 tiles * chunk_l * b_r * itemsize
+ x tile + one (1, b_r) output block.

Accumulation is in f32 for sub-f32 inputs; output dtype is the
accumulator dtype (callers cast down if desired).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._backend import (acc_dtype, chunk_clamp, pad_x_to_tiles,
                       resolve_interpret, tile_contrib)

__all__ = ["pjds_matvec_kernel_call", "block_extents"]


def block_extents(chunk_map: jax.Array, n_blocks: int):
    """Per-block (first chunk, chunk count) from the ascending per-chunk
    block-id map — the scalar-prefetch operands of the blocked kernels.
    ``chunk_map`` must be non-decreasing (stacked/padded distributed
    operands pad with the LAST block id, which keeps it so); every block
    has at least one chunk (block_len >= diag_align >= chunk_l)."""
    n_chunks = chunk_map.shape[0]
    start = jnp.searchsorted(chunk_map, jnp.arange(n_blocks, dtype=chunk_map.dtype),
                             side="left").astype(jnp.int32)
    cnt = jnp.diff(jnp.append(start, jnp.int32(n_chunks)))
    return start, cnt


def _pjds_spmv_kernel(start_ref, cnt_ref, val_ref, col_ref, x_ref, y_ref,
                      *, x_tiles, x_t):
    b = pl.program_id(0)
    t = pl.program_id(1)
    c = pl.program_id(2)

    # First visit of this output block: zero it while it is VMEM-pinned.
    @pl.when((t == 0) & (c == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(c < cnt_ref[b])
    def _body():
        idx = col_ref[...].astype(jnp.int32)     # (chunk_l, b_r); int16 ok
        contrib = tile_contrib(val_ref[...], idx, x_ref[...], t, x_t,
                               x_tiles, y_ref.dtype)
        y_ref[0, :] += jnp.sum(contrib, axis=0)


@functools.partial(
    jax.jit,
    static_argnames=("n_blocks", "chunk_l", "max_chunks", "x_tiles",
                     "interpret"),
)
def pjds_matvec_kernel_call(
    val: jax.Array,
    col_idx: jax.Array,
    chunk_map: jax.Array,
    x: jax.Array,
    *,
    n_blocks: int,
    chunk_l: int = 8,
    max_chunks: int | None = None,
    x_tiles: int = 1,
    interpret: bool | None = None,
) -> jax.Array:
    """y = A_pjds @ x (permuted basis).

    ``chunk_l`` must divide every pJDS block length (guaranteed when the
    format was built with ``diag_align`` a multiple of ``chunk_l``); the
    ``ops.to_device_pjds`` wrapper checks this.  Larger ``chunk_l`` means
    fewer grid steps at the cost of more padding — a measured trade-off in
    benchmarks/bench_kernels.py.

    val/col_idx: (total_jds, b_r) with total_jds % chunk_l == 0; col_idx
                 int16 or int32 (upcast in-kernel for the gather).
    chunk_map:   (total_jds // chunk_l,) non-decreasing int32 row-block
                 id per chunk.
    x:           (n_cols_pad,) RHS in the permuted basis (zero-padded
                 internally to a multiple of x_tiles; stored indices
                 never reach the pad).
    max_chunks:  static max chunks of any single block (``PJDSDevice``
                 carries it); None falls back to the total chunk count —
                 correct but with n_blocks * n_chunks grid steps.
    interpret:   None = compiled on TPU, interpret elsewhere
                 (``ops.resolve_interpret``).
    Returns y:   (n_blocks * b_r,) in the accumulator dtype.
    """
    total_jds, b_r = val.shape
    if total_jds % chunk_l:
        raise ValueError(f"total_jds={total_jds} not a multiple of chunk_l={chunk_l}")
    n_chunks = total_jds // chunk_l
    if max_chunks is None:
        max_chunks = n_chunks
    x, x_t = pad_x_to_tiles(x, x_tiles)
    dt = acc_dtype(val.dtype, x.dtype)
    start, cnt = block_extents(chunk_map, n_blocks)

    mat_map = lambda b, t, c, s, n: (s[b] + chunk_clamp(c, n[b]), 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_blocks, x_tiles, max_chunks),
        in_specs=[
            pl.BlockSpec((chunk_l, b_r), mat_map),                # val tile
            pl.BlockSpec((chunk_l, b_r), mat_map),                # col tile
            pl.BlockSpec((x_t,), lambda b, t, c, s, n: (t,)),     # x tile
        ],
        out_specs=pl.BlockSpec((1, b_r), lambda b, t, c, s, n: (b, 0)),
    )
    y_blk = pl.pallas_call(
        functools.partial(_pjds_spmv_kernel, x_tiles=x_tiles, x_t=x_t),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_blocks, b_r), dt),
        interpret=resolve_interpret(interpret),
        name="pjds_spmv",
    )(start, cnt, val, col_idx, x)
    return y_blk.reshape(n_blocks * b_r)
