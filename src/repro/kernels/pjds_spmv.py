"""Pallas TPU kernel for pJDS sparse matrix-vector multiplication.

This is the TPU adaptation of paper Listing 2.  Refer to DESIGN.md §2 for
the layout rationale; in short:

* ``val``/``col_idx`` are ``(total_jds, b_r)`` with rows on LANES
  (b_r = 128 by default) and jagged diagonals on SUBLANES — the paper's
  column-major ELLPACK layout restricted to each sorted row block.
* The grid walks jagged-diagonal *chunks* of ``chunk_l`` sublanes
  (a multiple of 8), so each grid step streams one (chunk_l, b_r) VMEM
  tile of values + indices: the TPU analogue of one coalesced warp load.
* ``chunk_map`` (SMEM) says which pJDS row block a chunk belongs to —
  this is the kernel-side form of the paper's ``col_start[]`` array.
  Because blocks are stored contiguously, walking chunks sequentially
  needs NO gather on the matrix data; only the RHS is gathered.
* The RHS ``x`` is resident in VMEM for the whole kernel.  Single-device
  callers must respect the VMEM budget; the distributed layer
  (``core.dist_spmv``) makes this structural by handing each device only
  its local column slice (DESIGN.md: enforced alpha -> 1/N_nzr).

VMEM working set per step: 2 tiles * chunk_l * b_r * itemsize
(+ x + y resident).  With chunk_l=64, b_r=128, f32: 64 KiB of tiles.

Accumulation is in f32 for sub-f32 inputs; output dtype is the
accumulator dtype (callers cast down if desired).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["pjds_matvec_kernel_call"]


def _acc_dtype(*dts):
    r = jnp.result_type(*dts)
    if r in (jnp.bfloat16, jnp.float16):
        return jnp.float32
    return r


def _pjds_spmv_kernel(chunk_map_ref, val_ref, col_ref, x_ref, y_ref):
    g = pl.program_id(0)
    blk = chunk_map_ref[g]

    # Zero the (fully VMEM-resident) output once, before any accumulation.
    @pl.when(g == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...]
    idx = col_ref[...]                       # (chunk_l, b_r)
    gathered = x[idx]                        # VPU dynamic-gather from VMEM
    dt = y_ref.dtype
    contrib = val_ref[...].astype(dt) * gathered.astype(dt)
    y_ref[blk, :] += jnp.sum(contrib, axis=0)


@functools.partial(
    jax.jit,
    static_argnames=("n_blocks", "chunk_l", "interpret"),
)
def pjds_matvec_kernel_call(
    val: jax.Array,
    col_idx: jax.Array,
    chunk_map: jax.Array,
    x: jax.Array,
    *,
    n_blocks: int,
    chunk_l: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """y = A_pjds @ x (permuted basis).

    ``chunk_l`` must divide every pJDS block length (guaranteed when the
    format was built with ``diag_align`` a multiple of ``chunk_l``); the
    ``ops.to_device_pjds`` wrapper checks this.  Larger ``chunk_l`` means
    fewer grid steps at the cost of more padding — a measured trade-off in
    benchmarks/bench_kernels.py.

    val/col_idx: (total_jds, b_r) with total_jds % chunk_l == 0.
    chunk_map:   (total_jds // chunk_l,) int32 row-block id per chunk.
    x:           (n_cols_pad,) RHS in the permuted basis.
    Returns y:   (n_blocks * b_r,) in the accumulator dtype.
    """
    total_jds, b_r = val.shape
    if total_jds % chunk_l:
        raise ValueError(f"total_jds={total_jds} not a multiple of chunk_l={chunk_l}")
    n_chunks = total_jds // chunk_l
    dt = _acc_dtype(val.dtype, x.dtype)

    y_blk = pl.pallas_call(
        _pjds_spmv_kernel,
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                # chunk_map
            pl.BlockSpec((chunk_l, b_r), lambda g: (g, 0)),       # val tile
            pl.BlockSpec((chunk_l, b_r), lambda g: (g, 0)),       # col tile
            pl.BlockSpec(x.shape, lambda g: (0,)),                # x resident
        ],
        out_specs=pl.BlockSpec((n_blocks, b_r), lambda g: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, b_r), dt),
        interpret=interpret,
        name="pjds_spmv",
    )(chunk_map, val, col_idx, x)
    return y_blk.reshape(n_blocks * b_r)
