"""Pallas TPU kernel for SELL-C-sigma sparse matrix-vector multiplication.

SELL-C-sigma (Kreutzer et al. 2013, PAPERS.md) is the published successor
of the paper's pJDS format: rows are sorted by non-zero count only inside
windows of ``sigma`` rows instead of globally, bounding how far any row
moves from its original position.  ``sigma = n_rows`` reproduces pJDS,
``sigma = C`` (= ``b_r`` here) is pure sliced ELLPACK.  See DESIGN.md §3.

The kernel shares the prefetched multi-tile grid of ``pjds_spmv.py``
(scalar-prefetched chunk extents driving the BlockSpec index maps, an
optional column-blocked x axis, int16 index / bf16 value streams with f32
accumulation) with one structural difference: the *output block is a
whole sigma window* — ``w_b = sigma / b_r`` row blocks — instead of one
row block.  Because the SELL row sort never crosses a sigma-window
boundary, the window-local inverse permutation that takes y back to the
original row order is applied INSIDE the kernel, fused after the
window's last chunk, as a gather that stays entirely within the
VMEM-pinned output slab.  The whole ``y`` is never resident (the pJDS
global sort would need exactly that, which is why the pJDS kernel leaves
the unpermute to the caller), each output slab is written to HBM once,
already in original row order, and the unpermute costs no HBM traffic.

Consequences of the fused unpermute:

* ``sell_matvec`` consumes x and produces y in the ORIGINAL basis when
  the matrix was built with ``permuted_cols=False`` — no host-side
  permutation on either side of the call.  This is what the unified
  dispatch layer (``ops.spmv``) relies on.
* The RHS gather locality of the original ordering is preserved up to
  sigma, which is the whole point of bounding the sort window.

When sigma is not a usable window size (not commensurate with ``b_r``,
or >= the padded row count — the pJDS limit), the window degenerates to
the full output, reproducing the old whole-y-resident behaviour.

VMEM working set per step: 2 tiles * chunk_l * b_r * itemsize
+ x tile + one (w_b, b_r) output slab + its slice of ``inv_perm``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._backend import (acc_dtype, chunk_clamp, pad_x_to_tiles,
                       resolve_interpret, tile_contrib)
from .pjds_spmv import block_extents

__all__ = ["sell_matvec_kernel_call", "window_blocks"]


def window_blocks(sigma: int, b_r: int, n_blocks: int) -> int:
    """Row blocks per kernel output slab (``w_b``): the smallest block
    multiple whose row span is also a multiple of sigma, so every
    sigma-sized sort window — and therefore every entry of the inverse
    permutation — lies inside exactly one slab.  Falls back to the whole
    output when sigma and b_r are incommensurate or the window would
    cover everything anyway."""
    if sigma >= n_blocks * b_r:
        return max(n_blocks, 1)
    if sigma >= b_r and sigma % b_r == 0:
        return sigma // b_r
    if sigma > 0 and b_r % sigma == 0:
        return 1
    return max(n_blocks, 1)


def _sell_spmv_kernel(wstart_ref, wcnt_ref, slot_ref, val_ref, col_ref,
                      x_ref, inv_ref, y_ref, *, x_tiles, x_t):
    w = pl.program_id(0)
    t = pl.program_id(1)
    c = pl.program_id(2)

    # First visit of this output slab: zero it while it is VMEM-pinned.
    @pl.when((t == 0) & (c == 0))
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    @pl.when(c < wcnt_ref[w])
    def _body():
        slot = slot_ref[wstart_ref[w] + c]       # row block within the slab
        idx = col_ref[...].astype(jnp.int32)     # (chunk_l, b_r); int16 ok
        contrib = tile_contrib(val_ref[...], idx, x_ref[...], t, x_t,
                               x_tiles, y_ref.dtype)
        y_ref[slot, :] += jnp.sum(contrib, axis=0)

    # Fused window-local unpermute: after the slab's last accumulation,
    # gather the window-sorted slab back to the original row order — the
    # permutation never leaves the slab, so this costs no HBM traffic.
    @pl.when((t == x_tiles - 1) & (c == wcnt_ref[w] - 1))
    def _unpermute():
        ys = y_ref[...].reshape(-1)
        y_ref[...] = ys[inv_ref[...].reshape(-1)].reshape(y_ref.shape)


@functools.partial(
    jax.jit,
    static_argnames=("n_blocks", "chunk_l", "sigma", "max_win_chunks",
                     "x_tiles", "interpret"),
)
def sell_matvec_kernel_call(
    val: jax.Array,
    col_idx: jax.Array,
    chunk_map: jax.Array,
    inv_perm: jax.Array,
    x: jax.Array,
    *,
    n_blocks: int,
    chunk_l: int = 8,
    sigma: int = 0,
    max_win_chunks: int | None = None,
    x_tiles: int = 1,
    interpret: bool | None = None,
) -> jax.Array:
    """y = A_sell @ x, returned in the ORIGINAL row order.

    ``chunk_l`` must divide every SELL chunk (= pJDS block) length; the
    ``ops.to_device_sell`` wrapper checks this.

    val/col_idx: (total_jds, b_r) with total_jds % chunk_l == 0; col_idx
                 int16 or int32.
    chunk_map:   (total_jds // chunk_l,) non-decreasing int32 row-block
                 id per chunk.
    inv_perm:    (n_blocks * b_r,) int32, window-local inverse of the
                 sigma-window row sort: y_out[i] = y_sorted[inv_perm[i]].
    x:           (n_cols_pad,) RHS (zero-padded internally to a multiple
                 of x_tiles).  Original basis when the matrix was built
                 with permuted_cols=False (the dispatch-layer default);
                 permuted basis otherwise.
    sigma:       the sort window (rows); sets the output-slab size via
                 :func:`window_blocks`.  0 (or >= n_rows_pad) keeps the
                 whole output resident.
    max_win_chunks: static max chunk count of any window slab
                 (``SELLDevice`` carries it); None falls back to the
                 total chunk count.
    Returns y:   (n_blocks * b_r,) in the accumulator dtype.
    """
    total_jds, b_r = val.shape
    if total_jds % chunk_l:
        raise ValueError(f"total_jds={total_jds} not a multiple of chunk_l={chunk_l}")
    if inv_perm.shape != (n_blocks * b_r,):
        raise ValueError(f"inv_perm shape {inv_perm.shape} != ({n_blocks * b_r},)")
    n_chunks = total_jds // chunk_l
    x, x_t = pad_x_to_tiles(x, x_tiles)
    if max_win_chunks is None:
        max_win_chunks = n_chunks
    dt = acc_dtype(val.dtype, x.dtype)

    w_b = window_blocks(sigma, b_r, n_blocks)
    n_win = -(-n_blocks // w_b)
    n_out = n_win * w_b * b_r
    # Window id per chunk, then per-window extents + slab-local slots.
    win_map = chunk_map // w_b
    wstart, wcnt = block_extents(win_map, n_win)
    slot = (chunk_map - win_map * w_b).astype(jnp.int32)
    # Slab-local inverse permutation, padded with identity past n_blocks
    # (the final window of a non-divisible block count).
    inv_pad = jnp.concatenate([
        inv_perm.astype(jnp.int32),
        jnp.arange(n_blocks * b_r, n_out, dtype=jnp.int32)])
    inv_local = (inv_pad - (jnp.arange(n_out, dtype=jnp.int32)
                            // (w_b * b_r)) * (w_b * b_r))
    inv_local = inv_local.reshape(n_win * w_b, b_r)

    mat_map = lambda w, t, c, ws, wc, sl: (ws[w] + chunk_clamp(c, wc[w]), 0)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_win, x_tiles, max_win_chunks),
        in_specs=[
            pl.BlockSpec((chunk_l, b_r), mat_map),                    # val
            pl.BlockSpec((chunk_l, b_r), mat_map),                    # col
            pl.BlockSpec((x_t,), lambda w, t, c, ws, wc, sl: (t,)),   # x tile
            pl.BlockSpec((w_b, b_r), lambda w, t, c, ws, wc, sl: (w, 0)),
        ],
        out_specs=pl.BlockSpec((w_b, b_r), lambda w, t, c, ws, wc, sl: (w, 0)),
    )
    y_blk = pl.pallas_call(
        functools.partial(_sell_spmv_kernel, x_tiles=x_tiles, x_t=x_t),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_win * w_b, b_r), dt),
        interpret=resolve_interpret(interpret),
        name="sell_spmv",
    )(wstart, wcnt, slot, val, col_idx, x, inv_local)
    return y_blk.reshape(n_out)[: n_blocks * b_r]
