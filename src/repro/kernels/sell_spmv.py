"""Pallas TPU kernel for SELL-C-sigma sparse matrix-vector multiplication.

SELL-C-sigma (Kreutzer et al. 2013, PAPERS.md) is the published successor
of the paper's pJDS format: rows are sorted by non-zero count only inside
windows of ``sigma`` rows instead of globally, bounding how far any row
moves from its original position.  ``sigma = n_rows`` reproduces pJDS,
``sigma = C`` (= ``b_r`` here) is pure sliced ELLPACK.  See DESIGN.md §3.

The kernel reuses the chunked (chunk_l, b_r) VMEM-tile walk of
``pjds_spmv.py`` — storage layout is identical — with one structural
difference: because the row permutation is *window-local*, the inverse
permutation that takes y back to the original row order is applied
INSIDE the kernel, fused after the last accumulation step.  Every entry
of ``inv_perm`` satisfies ``|inv_perm[i] - i| < sigma``, so on hardware
the final gather touches only a sigma-sized neighbourhood of the
VMEM-resident accumulator (a pJDS global sort would make this a full
scatter across all of y — the reason the pJDS kernel leaves the
unpermute to the caller).

Consequences of the fused unpermute:

* ``sell_matvec`` consumes x and produces y in the ORIGINAL basis when
  the matrix was built with ``permuted_cols=False`` — no host-side
  permutation on either side of the call.  This is what the unified
  dispatch layer (``ops.spmv``) relies on.
* The RHS gather locality of the original ordering is preserved up to
  sigma, which is the whole point of bounding the sort window.

VMEM working set per step: 2 tiles * chunk_l * b_r * itemsize
(+ x + y + inv_perm resident), same as the pJDS kernel plus 4 bytes/row
for the permutation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["sell_matvec_kernel_call"]


def _acc_dtype(*dts):
    r = jnp.result_type(*dts)
    if r in (jnp.bfloat16, jnp.float16):
        return jnp.float32
    return r


def _sell_spmv_kernel(chunk_map_ref, val_ref, col_ref, x_ref, inv_ref, y_ref,
                      *, n_chunks):
    g = pl.program_id(0)
    blk = chunk_map_ref[g]

    # Zero the (fully VMEM-resident) output once, before any accumulation.
    @pl.when(g == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...]
    idx = col_ref[...]                       # (chunk_l, b_r)
    gathered = x[idx]                        # VPU dynamic-gather from VMEM
    dt = y_ref.dtype
    contrib = val_ref[...].astype(dt) * gathered.astype(dt)
    y_ref[blk, :] += jnp.sum(contrib, axis=0)

    # Fused window-local unpermute: after the last chunk, take the
    # window-sorted accumulator back to the original row order.  Each
    # gather index stays within sigma of its destination.
    @pl.when(g == n_chunks - 1)
    def _unpermute():
        ys = y_ref[...].reshape(-1)
        y_ref[...] = ys[inv_ref[...]].reshape(y_ref.shape)


@functools.partial(
    jax.jit,
    static_argnames=("n_blocks", "chunk_l", "interpret"),
)
def sell_matvec_kernel_call(
    val: jax.Array,
    col_idx: jax.Array,
    chunk_map: jax.Array,
    inv_perm: jax.Array,
    x: jax.Array,
    *,
    n_blocks: int,
    chunk_l: int = 8,
    interpret: bool = True,
) -> jax.Array:
    """y = A_sell @ x, returned in the ORIGINAL row order.

    ``chunk_l`` must divide every SELL chunk (= pJDS block) length; the
    ``ops.to_device_sell`` wrapper checks this.

    val/col_idx: (total_jds, b_r) with total_jds % chunk_l == 0.
    chunk_map:   (total_jds // chunk_l,) int32 row-block id per chunk.
    inv_perm:    (n_blocks * b_r,) int32, window-local inverse of the
                 sigma-window row sort: y_out[i] = y_sorted[inv_perm[i]].
    x:           (n_cols_pad,) RHS.  Original basis when the matrix was
                 built with permuted_cols=False (the dispatch-layer
                 default); permuted basis otherwise.
    Returns y:   (n_blocks * b_r,) in the accumulator dtype.
    """
    total_jds, b_r = val.shape
    if total_jds % chunk_l:
        raise ValueError(f"total_jds={total_jds} not a multiple of chunk_l={chunk_l}")
    if inv_perm.shape != (n_blocks * b_r,):
        raise ValueError(f"inv_perm shape {inv_perm.shape} != ({n_blocks * b_r},)")
    n_chunks = total_jds // chunk_l
    dt = _acc_dtype(val.dtype, x.dtype)

    y_blk = pl.pallas_call(
        functools.partial(_sell_spmv_kernel, n_chunks=n_chunks),
        grid=(n_chunks,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),                # chunk_map
            pl.BlockSpec((chunk_l, b_r), lambda g: (g, 0)),       # val tile
            pl.BlockSpec((chunk_l, b_r), lambda g: (g, 0)),       # col tile
            pl.BlockSpec(x.shape, lambda g: (0,)),                # x resident
            pl.BlockSpec(inv_perm.shape, lambda g: (0,)),         # inv resident
        ],
        out_specs=pl.BlockSpec((n_blocks, b_r), lambda g: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((n_blocks, b_r), dt),
        interpret=interpret,
        name="sell_spmv",
    )(chunk_map, val, col_idx, x, inv_perm)
    return y_blk.reshape(n_blocks * b_r)
