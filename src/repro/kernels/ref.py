"""Pure-jnp oracles for the sparse kernels (the ``ref.py`` layer).

Each function is the mathematical specification of the matching Pallas
kernel, written with plain vectorised jnp ops (no pallas, no control
flow).  Tests assert ``allclose(kernel, ref)`` over shape/dtype sweeps;
the distributed layer and benchmarks also use these as a fast jittable
fallback on CPU.

All refs operate on the DEVICE layout produced by ``ops.to_device_*``:
zero padding in ``val`` and clamped-valid padding in ``col_idx`` make
masking unnecessary for correctness (padded terms contribute 0).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ._backend import acc_dtype as _acc_dtype

__all__ = ["pjds_matvec_ref", "pjds_matmat_ref", "ell_matvec_ref",
           "sell_matvec_ref", "csr_matvec_ref",
           "csr_rmatvec_ref", "ell_rmatvec_ref", "blocked_rmatvec_ref",
           "cmrs_matvec_ref", "cmrs_rmatvec_ref",
           "partial_reduce_epilogue_ref"]


def pjds_matvec_ref(val: jax.Array, col_idx: jax.Array, row_block: jax.Array,
                    x: jax.Array, n_blocks: int) -> jax.Array:
    """pJDS y = A x in the permuted basis (paper Listing 2).

    val/col_idx: (total_jds, b_r); row_block: (total_jds,) int32 mapping
    each jagged-diagonal row to its pJDS row block; x: (n_pad,).
    Returns y: (n_blocks * b_r,).
    """
    b_r = val.shape[1]
    dt = _acc_dtype(val.dtype, x.dtype)
    gathered = x[col_idx].astype(dt) * val.astype(dt)      # (total_jds, b_r)
    y_blk = jax.ops.segment_sum(gathered, row_block, num_segments=n_blocks)
    return y_blk.reshape(n_blocks * b_r)


def pjds_matmat_ref(val: jax.Array, col_idx: jax.Array, row_block: jax.Array,
                    x: jax.Array, n_blocks: int) -> jax.Array:
    """pJDS Y = A X, multi-RHS.  x: (n_pad, n_rhs) -> (n_blocks*b_r, n_rhs)."""
    b_r = val.shape[1]
    dt = _acc_dtype(val.dtype, x.dtype)
    gathered = x[col_idx].astype(dt)                       # (total, b_r, n_rhs)
    contrib = gathered * val.astype(dt)[..., None]
    y_blk = jax.ops.segment_sum(contrib, row_block, num_segments=n_blocks)
    return y_blk.reshape(n_blocks * b_r, x.shape[1])


def sell_matvec_ref(val: jax.Array, col_idx: jax.Array, row_block: jax.Array,
                    inv_perm: jax.Array, x: jax.Array,
                    n_blocks: int) -> jax.Array:
    """SELL-C-sigma y = A x with the window-local unpermute fused: the
    storage-layout matvec is identical to pJDS, then ``inv_perm`` takes y
    back to the original row order (y[i] = y_sorted[inv_perm[i]])."""
    y_sorted = pjds_matvec_ref(val, col_idx, row_block, x, n_blocks)
    return y_sorted[inv_perm]


def partial_reduce_epilogue_ref(y_sorted: jax.Array, own_pos: jax.Array,
                                red_send_pos: jax.Array, red_lens: tuple):
    """Local half of the 2-D partial-sum reduction epilogue.

    A 2-D-partitioned device's kernel output ``y_sorted`` holds PARTIAL
    sums for its whole row block in the SORTED row basis.  The epilogue
    never unpermutes the full block: it gathers the device's OWN y slice
    (``own_pos``, the sorted positions of its segment) and, per grid-row
    ring distance, the compact buffer of partial rows to ship
    (``red_send_pos[kk, :red_lens[kk]]``; padding lanes gather position 0
    and are dropped by the receiver's scatter sentinel).  The collective
    ppermute + scatter-add lives in ``core.dist_spmv``; this function is
    the kernel-side, unit-testable piece.

    Returns ``(y_own, bufs)`` with one buffer per entry of ``red_lens``
    (``None`` for empty distances).
    """
    y_own = y_sorted[own_pos]
    bufs = [y_sorted[red_send_pos[kk, :h]] if h else None
            for kk, h in enumerate(red_lens)]
    return y_own, bufs


def cmrs_matvec_ref(val: jax.Array, col_idx: jax.Array,
                    row_in_strip: jax.Array, strip_map: jax.Array,
                    x: jax.Array, n_strips: int) -> jax.Array:
    """CMRS y = A x in the ORIGINAL row order (no permutation).

    val/col_idx/row_in_strip: (total_su, b_r); strip_map: (total_su,)
    int32 mapping each sublane-row to its strip.  Each slot scatters to
    global row ``strip_map * b_r + row_in_strip`` — padding slots carry
    val == 0 so their scatter target (row 0 of the strip) is harmless.
    x: (n_pad,) or (n_pad, k); returns (n_strips * b_r[, k]).
    """
    b_r = val.shape[1]
    dt = _acc_dtype(val.dtype, x.dtype)
    rows = strip_map[:, None] * b_r + row_in_strip.astype(jnp.int32)
    gathered = x[col_idx].astype(dt)           # (total_su, b_r[, k])
    v = val.astype(dt)
    contrib = gathered * (v[..., None] if gathered.ndim == 3 else v)
    flat = contrib.reshape(-1, *contrib.shape[2:])
    return jax.ops.segment_sum(flat, rows.reshape(-1),
                               num_segments=n_strips * b_r)


def cmrs_rmatvec_ref(val: jax.Array, col_idx: jax.Array,
                     row_in_strip: jax.Array, strip_map: jax.Array,
                     y: jax.Array, n_cols: int) -> jax.Array:
    """CMRS z = A^T y: gather y at each slot's global row, scatter by
    column.  y: (n_rows_pad,) or (n_rows_pad, k); returns (n_cols[, k])."""
    b_r = val.shape[1]
    dt = _acc_dtype(val.dtype, y.dtype)
    rows = strip_map[:, None] * b_r + row_in_strip.astype(jnp.int32)
    gathered = y[rows].astype(dt)              # (total_su, b_r[, k])
    v = val.astype(dt)
    contrib = gathered * (v[..., None] if gathered.ndim == 3 else v)
    flat = contrib.reshape(-1, *contrib.shape[2:])
    return jax.ops.segment_sum(flat, col_idx.reshape(-1).astype(jnp.int32),
                               num_segments=n_cols)


def csr_matvec_ref(data: jax.Array, indices: jax.Array, row_ids: jax.Array,
                   x: jax.Array, n_rows: int) -> jax.Array:
    """CSR y = A x as a flat gather + segment-sum over the nnz stream —
    the dispatch layer's fallback for matrices too small/empty to be
    worth a blocked format (no Pallas kernel: the irregular baseline).
    ``x`` may carry a trailing RHS-block axis: (n,) or (n, k)."""
    dt = _acc_dtype(data.dtype, x.dtype)
    xg = x[indices].astype(dt)                 # (nnz,) or (nnz, k)
    d = data.astype(dt)
    contrib = d[:, None] * xg if xg.ndim == 2 else d * xg
    return jax.ops.segment_sum(contrib, row_ids, num_segments=n_rows)


def csr_rmatvec_ref(data: jax.Array, indices: jax.Array, row_ids: jax.Array,
                    y: jax.Array, n_cols: int) -> jax.Array:
    """CSR x = A^T y via the SWAPPED gather: read y along rows, scatter-
    accumulate along columns (segment ids = the column stream).  ``y``
    may carry a trailing RHS-block axis: (n_rows,) or (n_rows, k)."""
    dt = _acc_dtype(data.dtype, y.dtype)
    yg = y[row_ids].astype(dt)                 # (nnz,) or (nnz, k)
    d = data.astype(dt)
    contrib = d[:, None] * yg if yg.ndim == 2 else d * yg
    return jax.ops.segment_sum(contrib, indices, num_segments=n_cols)


def ell_rmatvec_ref(val: jax.Array, col_idx: jax.Array, rowlen: jax.Array,
                    y: jax.Array, n_cols: int) -> jax.Array:
    """ELLPACK-R x = A^T y: per-entry scatter-accumulate into the column
    space.  y: (n_pad,) or (n_pad, k) in STORAGE row order."""
    dt = _acc_dtype(val.dtype, y.dtype)
    j = jnp.arange(val.shape[0], dtype=jnp.int32)[:, None]
    mask = j < rowlen[None, :]
    v = jnp.where(mask, val, 0).astype(dt)
    contrib = v[..., None] * y.astype(dt)[None, :] if y.ndim == 2 \
        else v * y.astype(dt)[None, :]
    flat = contrib.reshape(-1, *contrib.shape[2:])
    return jax.ops.segment_sum(flat, col_idx.reshape(-1),
                               num_segments=n_cols)


def blocked_rmatvec_ref(val: jax.Array, col_idx: jax.Array,
                        row_block: jax.Array, y: jax.Array,
                        n_cols: int) -> jax.Array:
    """pJDS/SELL x = A^T y: the transpose of the blocked gather is a
    scatter-accumulate over ``col_idx`` (rows read from y at the entry's
    permuted row position).  y: (n_rows_pad,) or (n_rows_pad, k) in the
    PERMUTED (storage) basis."""
    b_r = val.shape[1]
    dt = _acc_dtype(val.dtype, y.dtype)
    rows = row_block[:, None] * b_r + jnp.arange(b_r, dtype=jnp.int32)[None]
    yg = y[rows].astype(dt)                    # (total_jds, b_r[, k])
    v = val.astype(dt)
    contrib = v[..., None] * yg if yg.ndim == 3 else v * yg
    flat = contrib.reshape(-1, *contrib.shape[2:])
    return jax.ops.segment_sum(flat, col_idx.reshape(-1),
                               num_segments=n_cols)


def ell_matvec_ref(val: jax.Array, col_idx: jax.Array, rowlen: jax.Array,
                   x: jax.Array) -> jax.Array:
    """ELLPACK-R y = A x (paper Listing 1), jagged-diagonal-major layout.

    val/col_idx: (max_nzr, n_pad); rowlen: (n_pad,); x: (n_pad_cols,) or
    (n_pad_cols, k) for a block of RHS vectors.
    The rowlen mask reproduces ELLPACK-R semantics exactly (padded values
    are zero anyway, but masking keeps NaN/Inf padding safe).
    """
    dt = _acc_dtype(val.dtype, x.dtype)
    j = jnp.arange(val.shape[0], dtype=jnp.int32)[:, None]
    mask = j < rowlen[None, :]
    xg = x[col_idx].astype(dt)           # (max_nzr, n_pad[, k])
    v = val.astype(dt)
    if xg.ndim == 3:
        v, mask = v[..., None], mask[..., None]
    contrib = jnp.where(mask, xg * v, 0)
    return contrib.sum(axis=0)
