"""jit'd public wrappers around the Pallas kernels + the unified
dispatch layer.

Two levels of API live here:

* **Per-format containers and matvecs** — ``to_device_pjds`` /
  ``to_device_ell`` / ``to_device_sell`` / ``to_device_csr`` move a
  host-side format (``repro.core.formats``) onto the device with the
  kernel-side metadata (chunk maps, tile chunk counts, window inverse
  permutations) precomputed; ``pjds_matvec`` / ``ell_matvec`` /
  ``sell_matvec`` / ``csr_matvec`` / ``pjds_matmat`` dispatch to either
  the Pallas kernel (``backend='kernel'``, interpret-mode on CPU) or the
  pure-jnp oracle (``backend='ref'``, fast on CPU and used inside the
  distributed layer).

* **The unified entry point** — ``spmv(a, x, format="auto")`` wraps any
  matrix in a :class:`SparseDevice`: it inspects row-length statistics,
  prices each candidate format with ``core.perf_model``'s overhead
  estimates (``select_format``), converts once, caches the device
  representation, and computes y = A x in the ORIGINAL basis regardless
  of which format won.  Callers never touch permutations or padding.
  See DESIGN.md §5 for the selection heuristic.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import warnings
import weakref
from typing import Literal, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import perf_model as PM
from . import ref as R
from ._backend import resolve_interpret
from .pjds_spmv import pjds_matvec_kernel_call
from .pjds_spmm import pjds_matmat_kernel_call
from .ellr_spmv import ell_matvec_kernel_call
from .sell_spmv import sell_matvec_kernel_call, window_blocks
from .cmrs_spmv import cmrs_matvec_kernel_call

__all__ = [
    "PJDSDevice",
    "ELLDevice",
    "SELLDevice",
    "CSRDevice",
    "CMRSDevice",
    "SparseDevice",
    "to_device_pjds",
    "to_device_ell",
    "to_device_sell",
    "to_device_csr",
    "to_device_cmrs",
    "pjds_matvec",
    "pjds_matmat",
    "ell_matvec",
    "sell_matvec",
    "csr_matvec",
    "cmrs_matvec",
    "select_format",
    "as_device",
    "spmv",
    "clear_device_cache",
    "resolve_backend",
    "resolve_interpret",
    "choose_x_tiles",
]

Backend = Literal["auto", "kernel", "ref"]
FormatName = Literal["auto", "csr", "ellpack_r", "pjds", "sell", "cmrs"]
Tune = Literal["off", "auto", "force"]


def resolve_backend(backend: Backend) -> str:
    """The one place ``backend="auto"`` is decided: the Pallas kernels on
    TPU, the jnp refs everywhere else (on CPU the kernels only run in
    interpret mode — Python per grid step — so the refs are the fast
    path).  Explicit ``"kernel"``/``"ref"`` pass through untouched.

    The companion :func:`resolve_interpret` (re-exported from
    ``kernels._backend``) is the same decision one level down: with the
    kernel backend selected, ``interpret=None`` means compiled Pallas on
    TPU and interpret mode elsewhere — so ``backend="kernel"`` off-TPU
    still runs (slowly, for testing), never crashes."""
    if backend in ("kernel", "ref"):
        return backend
    if backend != "auto":
        raise ValueError(f"unknown backend {backend!r}")
    return "kernel" if jax.default_backend() == "tpu" else "ref"


_resolve_backend = resolve_backend   # the satellite-task spelling


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PJDSDevice:
    """Device-resident pJDS operand.  Registered as a pytree so it can be
    closed over / passed through jit and shard_map.

    ``val`` carries the (possibly bf16-compressed) value stream and
    ``col_idx`` the (possibly int16-compressed) index stream exactly as
    built by ``formats.csr_to_pjds(index_dtype=...)``; ``max_chunks`` is
    the static per-block chunk ceiling the prefetched kernel grid needs
    (None falls back to the total chunk count — correct, more grid
    steps)."""

    val: jax.Array                     # (total_jds, b_r)
    col_idx: jax.Array                 # (total_jds, b_r) int16/int32
    chunk_map: jax.Array               # (total_jds // chunk_l,) int32
    row_block: jax.Array               # (total_jds,) int32 (for the ref)
    n_blocks: int = dataclasses.field(metadata=dict(static=True))
    b_r: int = dataclasses.field(metadata=dict(static=True))
    chunk_l: int = dataclasses.field(metadata=dict(static=True))
    max_chunks: Optional[int] = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def n_rows_pad(self) -> int:
        return self.n_blocks * self.b_r


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ELLDevice:
    val: jax.Array                     # (max_nzr, n_pad)
    col_idx: jax.Array                 # (max_nzr, n_pad) int32
    rowlen: jax.Array                  # (n_pad,) int32
    tile_chunks: jax.Array             # (n_pad // tile_r,) int32
    chunk_l: int = dataclasses.field(metadata=dict(static=True))
    tile_r: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class SELLDevice:
    """Device-resident SELL-C-sigma operand: pJDS chunk layout plus the
    window-local inverse permutation the kernel fuses into its epilogue."""

    val: jax.Array                     # (total_jds, b_r)
    col_idx: jax.Array                 # (total_jds, b_r) int16/int32
    chunk_map: jax.Array               # (total_jds // chunk_l,) int32
    row_block: jax.Array               # (total_jds,) int32 (for the ref)
    inv_perm: jax.Array                # (n_blocks * b_r,) int32, window-local
    n_blocks: int = dataclasses.field(metadata=dict(static=True))
    b_r: int = dataclasses.field(metadata=dict(static=True))
    chunk_l: int = dataclasses.field(metadata=dict(static=True))
    sigma: int = dataclasses.field(metadata=dict(static=True))
    max_win_chunks: Optional[int] = dataclasses.field(
        default=None, metadata=dict(static=True))
    max_chunks: Optional[int] = dataclasses.field(
        default=None, metadata=dict(static=True))   # per-BLOCK (spMM path)

    @property
    def n_rows_pad(self) -> int:
        return self.n_blocks * self.b_r


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CSRDevice:
    """Device-resident CSR as flat nnz streams (gather + segment-sum ref;
    no Pallas kernel — the irregular baseline for tiny matrices)."""

    data: jax.Array                    # (nnz,)
    indices: jax.Array                 # (nnz,) int32
    row_ids: jax.Array                 # (nnz,) int32
    n_rows: int = dataclasses.field(metadata=dict(static=True))


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class CMRSDevice:
    """Device-resident CMRS operand (``formats.CMRSMatrix``): strips of
    b_r consecutive ORIGINAL-order rows, nonzeros packed densely with an
    int8 row-in-strip routing stream.  ``chunk_map`` plays pJDS's role —
    strip id per (chunk_l, b_r) tile chunk for the scalar-prefetched
    kernel grid; ``strip_map`` is its per-sublane-row sibling for the
    segment-sum refs."""

    val: jax.Array                     # (total_su, b_r)
    col_idx: jax.Array                 # (total_su, b_r) int16/int32
    row_in_strip: jax.Array            # (total_su, b_r) int8
    chunk_map: jax.Array               # (total_su // chunk_l,) int32
    strip_map: jax.Array               # (total_su,) int32 (for the ref)
    n_strips: int = dataclasses.field(metadata=dict(static=True))
    b_r: int = dataclasses.field(metadata=dict(static=True))
    chunk_l: int = dataclasses.field(metadata=dict(static=True))
    max_chunks: Optional[int] = dataclasses.field(
        default=None, metadata=dict(static=True))

    @property
    def n_rows_pad(self) -> int:
        return self.n_strips * self.b_r


def _blocked_maps(block_len: np.ndarray, chunk_l: int, n_blocks: int):
    row_block = np.repeat(np.arange(n_blocks, dtype=np.int32), block_len)
    return row_block, row_block[::chunk_l].copy()


def to_device_pjds(p: F.PJDSMatrix, chunk_l: int = 8,
                   dtype=None) -> PJDSDevice:
    if np.any(p.block_len % chunk_l):
        raise ValueError(
            f"chunk_l={chunk_l} must divide every block length; rebuild the "
            f"pJDS matrix with diag_align a multiple of chunk_l"
        )
    # block id per jagged-diagonal row, then per chunk
    row_block, chunk_map = _blocked_maps(p.block_len, chunk_l, p.n_blocks)
    val = p.val if dtype is None else p.val.astype(dtype)
    return PJDSDevice(
        val=jnp.asarray(val),
        col_idx=jnp.asarray(p.col_idx),
        chunk_map=jnp.asarray(chunk_map),
        row_block=jnp.asarray(row_block),
        n_blocks=p.n_blocks,
        b_r=p.b_r,
        chunk_l=chunk_l,
        max_chunks=int(p.block_len.max(initial=chunk_l)) // chunk_l,
    )


def to_device_ell(e: F.ELLMatrix, chunk_l: int = 8, tile_r: int = 128,
                  dtype=None) -> ELLDevice:
    if e.val.shape[0] % chunk_l or e.n_rows_pad % tile_r:
        raise ValueError("ELL shapes not aligned to (chunk_l, tile_r); "
                         "rebuild with matching row_align/diag_align")
    tile_max = e.rowlen.reshape(-1, tile_r).max(axis=1)
    tile_chunks = ((tile_max + chunk_l - 1) // chunk_l).astype(np.int32)
    val = e.val if dtype is None else e.val.astype(dtype)
    return ELLDevice(
        val=jnp.asarray(val),
        col_idx=jnp.asarray(e.col_idx),
        rowlen=jnp.asarray(e.rowlen),
        tile_chunks=jnp.asarray(tile_chunks),
        chunk_l=chunk_l,
        tile_r=tile_r,
    )


def to_device_sell(s: F.SELLMatrix, chunk_l: int = 8,
                   dtype=None) -> SELLDevice:
    p = s.pjds
    if np.any(p.block_len % chunk_l):
        raise ValueError(
            f"chunk_l={chunk_l} must divide every chunk length; rebuild the "
            f"SELL matrix with diag_align a multiple of chunk_l"
        )
    row_block, chunk_map = _blocked_maps(p.block_len, chunk_l, p.n_blocks)
    val = p.val if dtype is None else p.val.astype(dtype)
    # Static per-window chunk ceiling for the slab-output kernel grid.
    w_b = window_blocks(s.sigma, p.b_r, p.n_blocks)
    win_chunks = (np.add.reduceat(p.block_len // chunk_l,
                                  np.arange(0, p.n_blocks, w_b))
                  if p.n_blocks else np.array([1]))
    return SELLDevice(
        val=jnp.asarray(val),
        col_idx=jnp.asarray(p.col_idx),
        chunk_map=jnp.asarray(chunk_map),
        row_block=jnp.asarray(row_block),
        inv_perm=jnp.asarray(p.inv_perm),
        n_blocks=p.n_blocks,
        b_r=p.b_r,
        chunk_l=chunk_l,
        sigma=s.sigma,
        max_win_chunks=int(win_chunks.max(initial=1)),
        max_chunks=int(p.block_len.max(initial=chunk_l)) // chunk_l,
    )


def to_device_csr(m: F.CSRMatrix, dtype=None) -> CSRDevice:
    data = m.data if dtype is None else m.data.astype(dtype)
    row_ids = np.repeat(np.arange(m.n_rows, dtype=np.int32),
                        m.row_lengths())
    return CSRDevice(
        data=jnp.asarray(data),
        indices=jnp.asarray(m.indices),
        row_ids=jnp.asarray(row_ids),
        n_rows=m.n_rows,
    )


def to_device_cmrs(c: F.CMRSMatrix, chunk_l: int = 8,
                   dtype=None) -> CMRSDevice:
    if np.any(c.strip_len % chunk_l):
        raise ValueError(
            f"chunk_l={chunk_l} must divide every strip length; rebuild the "
            f"CMRS matrix with diag_align a multiple of chunk_l"
        )
    strip_map, chunk_map = _blocked_maps(c.strip_len, chunk_l, c.n_strips)
    val = c.val if dtype is None else c.val.astype(dtype)
    return CMRSDevice(
        val=jnp.asarray(val),
        col_idx=jnp.asarray(c.col_idx),
        row_in_strip=jnp.asarray(c.row_in_strip),
        chunk_map=jnp.asarray(chunk_map),
        strip_map=jnp.asarray(strip_map),
        n_strips=c.n_strips,
        b_r=c.b_r,
        chunk_l=chunk_l,
        max_chunks=int(c.strip_len.max(initial=chunk_l)) // chunk_l,
    )


def choose_x_tiles(n_cols_pad: int, itemsize: int,
                   vmem_limit: Optional[int] = None) -> int:
    """Column-tile count for the x-blocked kernels: the smallest power of
    two whose x tile fits the VMEM allowance (a quarter of the chip's
    VMEM by default — the matrix tiles, the output block and double
    buffering need the rest).  Matrices whose RHS already fits return 1
    (the resident fast path).  Callers fall back to 1 when the tile
    count does not divide the runtime x length."""
    if vmem_limit is None:
        vmem_limit = PM.TPU_V5E.vmem_bytes // 4
    t = 1
    while n_cols_pad * itemsize > t * vmem_limit and t < 4096:
        t *= 2
    return t


def pjds_matvec(a: PJDSDevice, x: jax.Array,
                backend: Backend = "ref", x_tiles: int = 1) -> jax.Array:
    """y = A x in the permuted basis; y has n_rows_pad entries.
    ``x_tiles > 1`` column-blocks the RHS on the kernel path (the ref is
    a flat gather and never needs it); the kernel pads x internally to a
    tile multiple, so any x length tiles."""
    if resolve_backend(backend) == "kernel":
        return pjds_matvec_kernel_call(
            a.val, a.col_idx, a.chunk_map, x,
            n_blocks=a.n_blocks, chunk_l=a.chunk_l, max_chunks=a.max_chunks,
            x_tiles=x_tiles,
        )
    return R.pjds_matvec_ref(a.val, a.col_idx, a.row_block, x, a.n_blocks)


def pjds_matmat(a: PJDSDevice, x: jax.Array, backend: Backend = "ref",
                rhs_t: int = 128) -> jax.Array:
    """Y = A X; X: (n_cols_pad, n_rhs)."""
    if resolve_backend(backend) == "kernel":
        return pjds_matmat_kernel_call(
            a.val, a.col_idx, a.chunk_map, x,
            n_blocks=a.n_blocks, chunk_l=a.chunk_l, max_chunks=a.max_chunks,
            rhs_t=rhs_t,
        )
    return R.pjds_matmat_ref(a.val, a.col_idx, a.row_block, x, a.n_blocks)


def ell_matvec(a: ELLDevice, x: jax.Array,
               backend: Backend = "ref") -> jax.Array:
    if resolve_backend(backend) == "kernel":
        return ell_matvec_kernel_call(
            a.val, a.col_idx, a.tile_chunks, x,
            chunk_l=a.chunk_l, tile_r=a.tile_r,
        )
    return R.ell_matvec_ref(a.val, a.col_idx, a.rowlen, x)


def sell_matvec(a: SELLDevice, x: jax.Array,
                backend: Backend = "ref", x_tiles: int = 1) -> jax.Array:
    """y = A x with rows back in the ORIGINAL order (the window-local
    inverse permutation is fused); y has n_rows_pad entries."""
    if resolve_backend(backend) == "kernel":
        return sell_matvec_kernel_call(
            a.val, a.col_idx, a.chunk_map, a.inv_perm, x,
            n_blocks=a.n_blocks, chunk_l=a.chunk_l, sigma=a.sigma,
            max_win_chunks=a.max_win_chunks, x_tiles=x_tiles,
        )
    return R.sell_matvec_ref(a.val, a.col_idx, a.row_block, a.inv_perm, x,
                             a.n_blocks)


def csr_matvec(a: CSRDevice, x: jax.Array,
               backend: Backend = "ref") -> jax.Array:
    # No Pallas kernel for CSR — the ref path IS the implementation.
    del backend
    return R.csr_matvec_ref(a.data, a.indices, a.row_ids, x, a.n_rows)


def cmrs_matvec(a: CMRSDevice, x: jax.Array,
                backend: Backend = "ref", x_tiles: int = 1) -> jax.Array:
    """y = A x in the ORIGINAL row order; y has n_rows_pad entries."""
    if resolve_backend(backend) == "kernel":
        return cmrs_matvec_kernel_call(
            a.val, a.col_idx, a.row_in_strip, a.chunk_map, x,
            n_strips=a.n_strips, chunk_l=a.chunk_l, max_chunks=a.max_chunks,
            x_tiles=x_tiles,
        )
    return R.cmrs_matvec_ref(a.val, a.col_idx, a.row_in_strip, a.strip_map,
                             x, a.n_strips)


# --------------------------------------------------------------------------
# Unified dispatch: SparseDevice + spmv(a, x, format="auto")
# --------------------------------------------------------------------------
_CSR_MIN_ROWS_FACTOR = 2       # below 2*b_r rows, block padding dominates
_CSR_IRREGULAR_FACTOR = 4.0    # scalar gather stream can't saturate HBM
_ELL_OVERHEAD_TOL = 0.05       # near-constant rows: skip sorting entirely


def select_format(
    m: F.CSRMatrix,
    *,
    b_r: int = 128,
    diag_align: int = 8,
    sigma: Optional[int] = None,
    spec: PM.TPUSpec = PM.TPU_V5E,
    value_dtype=None,
    index_dtype="auto",
    x_tiles: int = 1,
) -> str:
    """Pick a storage format from row-length statistics alone.

    Deterministic for a fixed matrix: prices each candidate's predicted
    memory-bound spMVM time (``perf_model.predicted_spmv_seconds``) from
    its estimated padded storage (``formats.estimate_storage_elements``)
    plus the HBM cost of any out-of-kernel permutation, then takes the
    first minimum in the fixed order ellpack_r < sell < pjds < cmrs.
    CSR wins only for degenerate inputs (empty, or too few rows to fill
    blocks).  CMRS is priced as ``max(memory, compute)``: its densely
    packed strips store ~nnz elements regardless of row-length skew —
    where ELLPACK/pJDS pad — but every slot costs ``2 * b_r`` MXU flops
    in the kernel's one-hot segment reduction
    (``perf_model.cmrs_reduce_seconds``), so it wins exactly when the
    padding bytes it saves outweigh that compute floor (power-law /
    hub-dominated patterns).
    The pricing sees the byte widths that will actually be STORED —
    ``value_dtype`` (bf16 storage halves the value stream) and
    ``index_dtype`` (int16 when the column span fits halves the index
    stream) — so compressed variants are priced correctly; RHS/LHS
    traffic stays priced at the uncompressed vector width (the vectors
    do not shrink with the matrix).  ``x_tiles > 1`` — dispatch has
    determined x cannot be VMEM-resident — restricts the choice to the
    formats whose kernels support a column-blocked RHS (sell/pjds) and
    prices them with the tiled grid's re-read terms
    (``perf_model.spmvm_bytes``: matrix stream × x_tiles, x re-read per
    row block).  The full rationale is DESIGN.md §5.
    """
    n = m.n_rows
    if m.nnz == 0 or n < _CSR_MIN_ROWS_FACTOR * b_r:
        return "csr"
    rl = m.row_lengths()
    n_nzr = m.n_nzr
    if sigma is None:
        sigma = 8 * b_r
    vb = np.dtype(value_dtype).itemsize if value_dtype is not None \
        else m.data.dtype.itemsize
    vecb = max(4, m.data.dtype.itemsize)
    ib = F.resolve_index_dtype(index_dtype, m.shape[1]).itemsize
    n_row_blocks = -(-n // b_r)

    ell_elems = F.estimate_storage_elements(rl, "ellpack_r", b_r, diag_align)
    if x_tiles <= 1 and ell_elems / m.nnz - 1.0 <= _ELL_OVERHEAD_TOL:
        return "ellpack_r"    # rows (nearly) constant: no sort, no perm

    candidates = {
        "ellpack_r": PM.predicted_spmv_seconds(
            ell_elems, n, n_nzr, spec=spec, value_bytes=vb, index_bytes=ib,
            vec_bytes=vecb, fmt="ellpack_r"),
        "sell": PM.predicted_spmv_seconds(
            F.estimate_storage_elements(rl, "sell", b_r, diag_align, sigma),
            n, n_nzr,
            perm_bytes=PM.perm_traffic_bytes(n, vecb, window_local=True),
            spec=spec, value_bytes=vb, index_bytes=ib, vec_bytes=vecb,
            x_tiles=x_tiles, n_row_blocks=n_row_blocks, fmt="sell"),
        "pjds": PM.predicted_spmv_seconds(
            F.estimate_storage_elements(rl, "pjds", b_r, diag_align),
            n, n_nzr,
            perm_bytes=PM.perm_traffic_bytes(n, vecb, window_local=False),
            spec=spec, value_bytes=vb, index_bytes=ib, vec_bytes=vecb,
            x_tiles=x_tiles, n_row_blocks=n_row_blocks, fmt="pjds"),
    }
    cmrs_elems = F.estimate_storage_elements(rl, "cmrs", b_r, diag_align)
    candidates["cmrs"] = max(
        PM.predicted_spmv_seconds(
            cmrs_elems, n, n_nzr, spec=spec, value_bytes=vb,
            index_bytes=ib + PM.CMRS_RIS_BYTES, vec_bytes=vecb,
            x_tiles=x_tiles, n_row_blocks=n_row_blocks, fmt="cmrs"),
        PM.cmrs_reduce_seconds(cmrs_elems * x_tiles, b_r, spec))
    if x_tiles > 1:
        candidates.pop("ellpack_r")   # its kernel keeps x resident
    return min(candidates, key=candidates.get)


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class SparseDevice:
    """A matrix ready for ``spmv``: one chosen format, converted once.

    Whatever the inner format, ``matvec`` consumes x and returns y in the
    ORIGINAL basis (length ``shape[0]``) — permutations, padding and
    basis changes are internal.  Device arrays are cached per host
    matrix by ``as_device``; hold on to the wrapper (or keep the host
    matrix alive) to amortise conversion across calls.

    Registered as a pytree (device arrays are the leaves) so it can flow
    through ``jit`` / ``shard_map`` / ``lax.while_loop`` carriers — the
    substrate the :mod:`repro.core.operator` protocol builds on.
    """

    fmt: str = dataclasses.field(metadata=dict(static=True))
    shape: Tuple[int, int] = dataclasses.field(metadata=dict(static=True))
    dev: Union[PJDSDevice, ELLDevice, SELLDevice, CSRDevice, CMRSDevice]
    inv_perm: Optional[jax.Array]      # pjds only: undo the global row sort
    x_tiles: int = dataclasses.field(default=1, metadata=dict(static=True))
    # Preprocessing (reorder=) permutation: the stored matrix is
    # B = P A P^T with perm[k] = old index at new position k
    # (core.reorder's convention), and every entry point sandwiches —
    # y = B_path(x[pre_perm])[pre_inv] — so callers always see the
    # ORIGINAL basis.  None (default) = no preprocessing, zero overhead.
    pre_perm: Optional[jax.Array] = None
    pre_inv: Optional[jax.Array] = None

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def value_dtype(self):
        """Dtype of the STORED value stream (bf16 for compressed builds);
        results still come back in the accumulator dtype (>= f32)."""
        return (self.dev.data if self.fmt == "csr" else self.dev.val).dtype

    @property
    def index_dtype(self):
        """Dtype of the stored column-index stream (int16 or int32)."""
        if self.fmt == "csr":
            return self.dev.indices.dtype
        return self.dev.col_idx.dtype

    def matvec(self, x: jax.Array, backend: Backend = "auto") -> jax.Array:
        """y = A x, original basis, length shape[0]."""
        backend = resolve_backend(backend)
        if x.ndim == 2:
            return self.matmat(x, backend)
        self._check_cols(x)
        if self.pre_perm is not None:
            x = x[self.pre_perm]
        y = self._matvec_stored(x, backend)
        if self.pre_inv is not None:
            y = y[self.pre_inv]
        return y

    def _matvec_stored(self, x: jax.Array, backend: str) -> jax.Array:
        if self.fmt == "csr":
            return csr_matvec(self.dev, x, backend)
        if self.fmt == "ellpack_r":
            return ell_matvec(self.dev, x, backend)[: self.n_rows]
        if self.fmt == "sell":
            return sell_matvec(self.dev, x, backend,
                               x_tiles=self.x_tiles)[: self.n_rows]
        if self.fmt == "pjds":
            y_p = pjds_matvec(self.dev, x, backend, x_tiles=self.x_tiles)
            return y_p[self.inv_perm][: self.n_rows]
        if self.fmt == "cmrs":
            return cmrs_matvec(self.dev, x, backend,
                               x_tiles=self.x_tiles)[: self.n_rows]
        raise ValueError(f"unknown format {self.fmt!r}")

    def matmat(self, x: jax.Array, backend: Backend = "auto") -> jax.Array:
        """Y = A X for a block of RHS vectors, original basis.

        x: (n_cols, k) -> (shape[0], k).  The blocked formats ride the
        multi-RHS pJDS path (the storage layouts are identical, only the
        row unpermute differs) and honor ``backend``; CSR/ELLPACK have
        no multi-RHS Pallas kernel, so they always use the generalized
        refs — an explicit ``backend="kernel"`` falls back silently.
        """
        backend = resolve_backend(backend)
        self._check_cols(x)
        if self.pre_perm is not None:
            x = x[self.pre_perm]
        y = self._matmat_stored(x, backend)
        if self.pre_inv is not None:
            y = y[self.pre_inv]
        return y

    def _matmat_stored(self, x: jax.Array, backend: str) -> jax.Array:
        if self.fmt == "csr":
            return R.csr_matvec_ref(self.dev.data, self.dev.indices,
                                    self.dev.row_ids, x, self.dev.n_rows)
        if self.fmt == "ellpack_r":
            return R.ell_matvec_ref(self.dev.val, self.dev.col_idx,
                                    self.dev.rowlen, x)[: self.n_rows]
        if self.fmt in ("sell", "pjds"):
            d = self.dev
            a = d if self.fmt == "pjds" else PJDSDevice(
                val=d.val, col_idx=d.col_idx, chunk_map=d.chunk_map,
                row_block=d.row_block, n_blocks=d.n_blocks, b_r=d.b_r,
                chunk_l=d.chunk_l, max_chunks=d.max_chunks)
            y_p = pjds_matmat(a, x, backend)
            inv = d.inv_perm if self.fmt == "sell" else self.inv_perm
            return y_p[inv][: self.n_rows]
        if self.fmt == "cmrs":
            d = self.dev
            return R.cmrs_matvec_ref(d.val, d.col_idx, d.row_in_strip,
                                     d.strip_map, x,
                                     d.n_strips)[: self.n_rows]
        raise ValueError(f"unknown format {self.fmt!r}")

    def rmatvec(self, y: jax.Array, backend: Backend = "auto") -> jax.Array:
        """x = A^T y, original basis: (shape[0],) -> (shape[1],).

        The blocked formats run the transpose as a scatter-accumulate
        over their stored column indices (``ref.blocked_rmatvec_ref``);
        CSR swaps the roles of its gather and its segment ids.  For a
        kernel-speed transpose build the CSC-of-blocks device operand
        instead (``core.operator.operator(a, transpose="device")``).
        """
        # the transpose refs handle 1-D and 2-D y with one code path
        return self.rmatmat(y, backend)

    def rmatmat(self, y: jax.Array, backend: Backend = "auto") -> jax.Array:
        """X = A^T Y, original basis: (shape[0][, k]) -> (shape[1][, k])."""
        del backend    # scatter path only; see operator(transpose="device")
        self._check_rows(y)
        # A^T = P^T B^T P, so the transpose wears the SAME sandwich as
        # the forward (B = P A P^T is symmetric-permuted).
        if self.pre_perm is not None:
            y = y[self.pre_perm]
        z = self._rmatmat_stored(y)
        if self.pre_inv is not None:
            z = z[self.pre_inv]
        return z

    def _rmatmat_stored(self, y: jax.Array) -> jax.Array:
        n_cols = self.shape[1]
        if self.fmt == "csr":
            return R.csr_rmatvec_ref(self.dev.data, self.dev.indices,
                                     self.dev.row_ids, y, n_cols)
        if self.fmt == "ellpack_r":
            y_pad = self._pad_rows(y, self.dev.val.shape[1])
            return R.ell_rmatvec_ref(self.dev.val, self.dev.col_idx,
                                     self.dev.rowlen, y_pad, n_cols)
        if self.fmt in ("sell", "pjds"):
            d = self.dev
            inv = d.inv_perm if self.fmt == "sell" else self.inv_perm
            y_p = self._scatter_to_storage(y, inv)
            return R.blocked_rmatvec_ref(d.val, d.col_idx, d.row_block,
                                         y_p, n_cols)
        if self.fmt == "cmrs":
            d = self.dev
            y_pad = self._pad_rows(y, d.n_rows_pad)
            return R.cmrs_rmatvec_ref(d.val, d.col_idx, d.row_in_strip,
                                      d.strip_map, y_pad, n_cols)
        raise ValueError(f"unknown format {self.fmt!r}")

    def _pad_rows(self, y: jax.Array, n_pad: int) -> jax.Array:
        pad = [(0, n_pad - self.n_rows)] + [(0, 0)] * (y.ndim - 1)
        return jnp.pad(y[: self.n_rows], pad)

    def _scatter_to_storage(self, y: jax.Array, inv_perm) -> jax.Array:
        """Inverse of the matvec epilogue ``y_p[inv_perm][:n_rows]``:
        place y's entries at their storage (permuted) positions, zeros in
        the padded rows (whose stored values are zero anyway)."""
        n_pad = inv_perm.shape[0]
        y_p = jnp.zeros((n_pad,) + y.shape[1:], y.dtype)
        return y_p.at[inv_perm[: self.n_rows]].set(y[: self.n_rows])

    def _check_cols(self, x: jax.Array) -> None:
        n = x.shape[0] if x.ndim == 2 else x.shape[-1]
        if n < self.shape[1]:
            # jax clamps out-of-range gathers, which would silently
            # return garbage instead of failing.
            raise ValueError(
                f"x has {n} entries; matrix has {self.shape[1]} columns")

    def _check_rows(self, y: jax.Array) -> None:
        if y.shape[0] < self.shape[0]:
            raise ValueError(
                f"y has {y.shape[0]} entries; matrix has {self.shape[0]} rows")

    def storage_elements(self) -> int:
        if self.fmt == "csr":
            return int(self.dev.data.size)
        return int(self.dev.val.size)


# Conversion cache: host matrix -> device representation.  Keyed by the
# host object's id and the build parameters; a weakref callback evicts
# the entry when the host matrix is garbage-collected (id reuse safety),
# and the stored weakref is re-checked on hit.
_DEVICE_CACHE: dict = {}

# Dense ndarray inputs can't be id-cached (callers rebuild them freely),
# so they get a small content-addressed LRU: (shape, dtype, byte digest)
# -> the converted CSRMatrix.  Returning the SAME CSR object for equal
# content lets the id-keyed device cache above hit too, closing the hole
# where every dense call silently reconverted from scratch.
_DENSE_CSR_CACHE: "collections.OrderedDict" = collections.OrderedDict()
_DENSE_CSR_CACHE_MAX = 16


def _dense_to_csr_cached(a: np.ndarray) -> F.CSRMatrix:
    key = (a.shape, a.dtype.str,
           hashlib.sha1(np.ascontiguousarray(a).tobytes()).hexdigest())
    hit = _DENSE_CSR_CACHE.get(key)
    if hit is not None:
        _DENSE_CSR_CACHE.move_to_end(key)
        return hit
    m = F.csr_from_dense(a)
    _DENSE_CSR_CACHE[key] = m
    while len(_DENSE_CSR_CACHE) > _DENSE_CSR_CACHE_MAX:
        _DENSE_CSR_CACHE.popitem(last=False)
    return m


def clear_device_cache() -> None:
    _DEVICE_CACHE.clear()
    _DENSE_CSR_CACHE.clear()


def _cache_put(key, m, dev) -> None:
    try:
        ref = weakref.ref(m, lambda _unused, k=key: _DEVICE_CACHE.pop(k, None))
    except TypeError:            # not weakref-able: skip caching
        return
    _DEVICE_CACHE[key] = (ref, dev)


def as_device(
    a: Union[F.CSRMatrix, np.ndarray, SparseDevice],
    format: FormatName = "auto",
    *,
    b_r: int = 128,
    diag_align: int = 8,
    sigma: Optional[int] = None,
    chunk_l: int = 16,
    dtype=None,
    index_dtype="auto",
    x_tiles: Union[int, str] = "auto",
    tune: Tune = "off",
    validate: str = "off",
    reorder: str = "off",
) -> SparseDevice:
    """Wrap a matrix as a :class:`SparseDevice`, converting at most once.

    ``a`` may be a host CSRMatrix, a dense ndarray (content-hashed into a
    small LRU, so repeated calls with equal data reuse one conversion),
    or an existing SparseDevice (returned unchanged; ``format`` must
    agree or be auto).

    Storage compression knobs:

    * ``dtype`` — the stored VALUE dtype (e.g. ``jnp.bfloat16`` halves
      the value stream; accumulation stays f32).
    * ``index_dtype`` — the stored column-index dtype; ``"auto"``
      (default) compresses to int16 whenever the column span fits
      (``formats.min_index_dtype``), falling back to int32.
    * ``x_tiles`` — RHS column blocking for the blocked kernels;
      ``"auto"`` picks :func:`choose_x_tiles` (1 — resident x — unless
      the RHS would blow the VMEM budget).

    ``chunk_l`` defaults to 16 — the measured sweet spot of the
    grid-step-count vs padding trade now that the prefetched kernels
    stream (chunk_l, b_r) tiles per step (benchmarks/bench_kernels.py
    records the sweep); pass 8 to reproduce the old minimal-padding
    builds.

    ``tune`` switches from the static heuristic to the EMPIRICAL
    autotuner (``repro.tune``, DESIGN.md §9): ``"auto"`` looks the
    matrix's structural fingerprint up in the persistent tuning cache,
    measuring the pruned candidate set on a miss; ``"force"``
    re-measures and overwrites the cached decision.  The tuned statics
    (format, b_r, diag_align, chunk_l, sigma, x_tiles) then REPLACE the
    corresponding arguments — an explicit ``format`` (not ``"auto"``)
    restricts the search to that format, and the ``dtype`` /
    ``index_dtype`` storage policy is part of the cache key, never
    overridden.  A caller-supplied ``diag_align`` is ignored under
    tuning: the build must match the measured geometry exactly.

    ``reorder`` is the PREPROCESSING stage (``core.reorder.preprocess``,
    DESIGN.md §13): ``"rcm"`` applies the reverse Cuthill-McKee
    symmetric permutation before conversion (and before tuning — the
    reordered structure is what gets fingerprinted and measured);
    ``"auto"`` applies it only when the calibrated perf model predicts
    the bandwidth/storage gain beats the one-time permute cost plus the
    per-matvec permute/unpermute sandwich; ``"off"`` (default) skips it.
    The permutation is recorded on the returned ``SparseDevice``
    (``pre_perm``/``pre_inv``), so ``matvec``/``rmatvec`` transparently
    accept and return vectors in the ORIGINAL basis.  Non-square
    matrices and ``reorder="auto"`` quietly skip (RCM is a symmetric
    permutation); an explicit ``"rcm"`` on a non-square matrix raises.

    ``validate`` is the admission gate for host matrices
    (``formats.validate_csr``): ``"check"`` raises
    ``formats.CSRValidationError`` on out-of-range/unsorted indices,
    duplicates, non-finite values or corrupt ``indptr``; ``"repair"``
    rebuilds the matrix (dropping poisoned entries, merging duplicates)
    and converts the repaired copy; ``"off"`` (default) trusts the
    input.  Existing SparseDevice inputs skip validation (they were
    admitted when first converted).

    This is the conversion/caching layer under the operator protocol —
    new code should usually go one level up and call
    ``repro.core.operator.operator(a)``, which adds transpose,
    ``__matmul__`` and autodiff on top of the device representation
    built here (DESIGN.md §8).
    """
    if isinstance(a, SparseDevice):
        if format not in ("auto", a.fmt):
            raise ValueError(
                f"matrix already converted to {a.fmt!r}; asked for {format!r}")
        return a
    if isinstance(a, np.ndarray):
        a = _dense_to_csr_cached(a)
    if not isinstance(a, F.CSRMatrix):
        raise TypeError(f"cannot dispatch on {type(a)}")

    if validate not in ("off", "check", "repair"):
        raise ValueError(f"validate must be 'off', 'check' or 'repair'; "
                         f"got {validate!r}")
    if validate != "off":
        a, _report = F.validate_csr(a, repair=(validate == "repair"))

    if tune not in ("off", "auto", "force"):
        raise ValueError(f"tune must be 'off', 'auto' or 'force'; "
                         f"got {tune!r}")
    if reorder not in ("off", "auto", "rcm"):
        raise ValueError(f"reorder must be 'off', 'auto' or 'rcm'; "
                         f"got {reorder!r}")

    if x_tiles == "auto":
        # Size the tile by the RUNTIME vector width (>= f32), not the
        # stored value width: a bf16 build still gathers from an f32 x.
        x_tiles = choose_x_tiles(a.shape[1], max(4, a.data.dtype.itemsize))
    x_tiles = int(x_tiles)

    key = (id(a), format, b_r, diag_align, sigma, chunk_l,
           np.dtype(dtype).name if dtype is not None else None,
           "auto" if index_dtype == "auto" else np.dtype(index_dtype).name,
           x_tiles, reorder, tune)
    if tune != "force":      # force must re-measure, never serve a hit
        hit = _DEVICE_CACHE.get(key)
        if hit is not None and hit[0]() is a:
            return hit[1]

    # Preprocessing stage: runs BEFORE tuning so the reordered structure
    # is what gets fingerprinted, priced and measured.
    a_orig = a
    pre_perm = pre_inv = None
    if reorder != "off":
        from repro.core import reorder as RO   # deferred: light module
        pp = RO.preprocess(a, reorder=reorder,
                           value_bytes=(np.dtype(dtype).itemsize
                                        if dtype is not None
                                        else a.data.dtype.itemsize))
        if pp.applied:
            a = pp.matrix
            pre_perm = jnp.asarray(pp.perm.astype(np.int32))
            pre_inv = jnp.asarray(pp.inv_perm.astype(np.int32))

    if tune != "off":
        from repro import tune as T   # deferred: tune imports this module
        best = T.autotune(a, format=format, dtype=dtype,
                          index_dtype=index_dtype,
                          force=(tune == "force")).best
        # Rebuild with EXACTLY the geometry the tuner measured
        # (Candidate.build_kwargs, which owns diag_align) — a
        # caller-supplied diag_align would change padding out from
        # under the cached decision.
        sd = as_device(a, dtype=dtype, index_dtype=index_dtype,
                       tune="off", **best.build_kwargs())
        if pre_perm is not None:
            sd = dataclasses.replace(sd, pre_perm=pre_perm,
                                     pre_inv=pre_inv)
        if tune != "force":
            _cache_put(key, a_orig, sd)
        return sd

    # The kernels need diag_align % chunk_l == 0; raise it once here so
    # the selection pricing sees the same padding the builders produce.
    da = max(diag_align, chunk_l)

    fmt = format
    if fmt == "auto":
        # When dispatch already decided x cannot be VMEM-resident, only
        # the sell/pjds kernels can column-block it — select_format then
        # restricts to those AND prices them with the tiled-grid re-read
        # terms.  (An EXPLICIT format request, and the matmat paths, run
        # resident regardless: x_tiles is a spMV-kernel knob, documented
        # in pjds_spmv.py.)
        fmt = select_format(a, b_r=b_r, diag_align=da, sigma=sigma,
                            value_dtype=dtype, index_dtype=index_dtype,
                            x_tiles=x_tiles)

    inv_perm = None
    if fmt == "csr":
        dev = to_device_csr(a, dtype=dtype)
    elif fmt == "ellpack_r":
        e = F.csr_to_ell(a, row_align=b_r, diag_align=da,
                         index_dtype=index_dtype)
        dev = to_device_ell(e, chunk_l=chunk_l, tile_r=b_r, dtype=dtype)
    elif fmt == "sell":
        s = F.csr_to_sell(a, c=b_r, sigma=sigma, diag_align=da,
                          permuted_cols=False, index_dtype=index_dtype)
        dev = to_device_sell(s, chunk_l=chunk_l, dtype=dtype)
    elif fmt == "pjds":
        p = F.csr_to_pjds(a, b_r=b_r, diag_align=da, permuted_cols=False,
                          index_dtype=index_dtype)
        dev = to_device_pjds(p, chunk_l=chunk_l, dtype=dtype)
        inv_perm = jnp.asarray(p.inv_perm)
    elif fmt == "cmrs":
        c = F.csr_to_cmrs(a, b_r=b_r, diag_align=da,
                          index_dtype=index_dtype)
        dev = to_device_cmrs(c, chunk_l=chunk_l, dtype=dtype)
    else:
        raise ValueError(f"unknown format {fmt!r}")

    sd = SparseDevice(fmt=fmt, shape=a.shape, dev=dev, inv_perm=inv_perm,
                      x_tiles=x_tiles, pre_perm=pre_perm, pre_inv=pre_inv)
    _cache_put(key, a_orig, sd)
    return sd


def spmv(
    a: Union[F.CSRMatrix, np.ndarray, SparseDevice],
    x: jax.Array,
    format: FormatName = "auto",
    backend: Backend = "auto",
    **convert_kwargs,
) -> jax.Array:
    """y = A x through the unified dispatch layer (original basis).

    .. deprecated::
        ``spmv`` is kept as a thin shim over the operator protocol:
        ``spmv(a, x)`` == ``operator(a) @ x`` (``repro.core.operator``).
        New code should build the operator once and reuse it — it adds
        ``.T``, ``rmatvec`` and ``jax.grad`` support that this function
        does not expose.

    ``format="auto"`` measures the matrix and picks CSR-ref / ELLPACK-R /
    pJDS / SELL-C-sigma (``select_format``); an explicit name forces the
    format.  ``backend="auto"`` resolves in :func:`resolve_backend`.  A
    2-D ``x`` of shape (n_cols, k) is dispatched to the multi-RHS spMM
    path, returning (n_rows, k).  The converted device representation is
    cached, so repeated ``spmv`` calls with the same host matrix convert
    once.  ``convert_kwargs`` (b_r, diag_align, sigma, chunk_l, dtype,
    index_dtype, x_tiles, tune) pass through to :func:`as_device` — in
    particular ``dtype=jnp.bfloat16`` stores a compressed value stream,
    ``index_dtype="auto"`` (the default) compresses indices to int16
    whenever the column span fits, and ``tune="auto"`` replaces the
    static format/statics heuristic with the measured autotuner
    (``repro.tune``; ``"force"`` re-measures, bypassing the persistent
    cache).
    """
    warnings.warn(
        "kernels.ops.spmv is deprecated: build the operator once — "
        "`operator(a) @ x` (repro.core.operator) — or call repro.solve "
        "for whole systems", DeprecationWarning, stacklevel=2)
    from repro.core.operator import operator as _operator
    op = _operator(a, format=format, backend=backend, **convert_kwargs)
    return op @ jnp.asarray(x)
