"""jit'd public wrappers around the Pallas kernels + device containers.

``to_device_pjds`` / ``to_device_ell`` move a host-side format
(``repro.core.formats``) onto the device with the kernel-side metadata
(chunk maps, tile chunk counts) precomputed.  ``pjds_matvec`` /
``ell_matvec`` / ``pjds_matmat`` dispatch to either the Pallas kernel
(``backend='kernel'``, interpret-mode on CPU) or the pure-jnp oracle
(``backend='ref'``, fast on CPU and used inside the distributed layer).
"""
from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from . import ref as R
from .pjds_spmv import pjds_matvec_kernel_call
from .pjds_spmm import pjds_matmat_kernel_call
from .ellr_spmv import ell_matvec_kernel_call

__all__ = [
    "PJDSDevice",
    "ELLDevice",
    "to_device_pjds",
    "to_device_ell",
    "pjds_matvec",
    "pjds_matmat",
    "ell_matvec",
]

Backend = Literal["kernel", "ref"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class PJDSDevice:
    """Device-resident pJDS operand.  Registered as a pytree so it can be
    closed over / passed through jit and shard_map."""

    val: jax.Array                     # (total_jds, b_r)
    col_idx: jax.Array                 # (total_jds, b_r) int32
    chunk_map: jax.Array               # (total_jds // chunk_l,) int32
    row_block: jax.Array               # (total_jds,) int32 (for the ref)
    n_blocks: int = dataclasses.field(metadata=dict(static=True))
    b_r: int = dataclasses.field(metadata=dict(static=True))
    chunk_l: int = dataclasses.field(metadata=dict(static=True))

    @property
    def n_rows_pad(self) -> int:
        return self.n_blocks * self.b_r


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ELLDevice:
    val: jax.Array                     # (max_nzr, n_pad)
    col_idx: jax.Array                 # (max_nzr, n_pad) int32
    rowlen: jax.Array                  # (n_pad,) int32
    tile_chunks: jax.Array             # (n_pad // tile_r,) int32
    chunk_l: int = dataclasses.field(metadata=dict(static=True))
    tile_r: int = dataclasses.field(metadata=dict(static=True))


def to_device_pjds(p: F.PJDSMatrix, chunk_l: int = 8,
                   dtype=None) -> PJDSDevice:
    if np.any(p.block_len % chunk_l):
        raise ValueError(
            f"chunk_l={chunk_l} must divide every block length; rebuild the "
            f"pJDS matrix with diag_align a multiple of chunk_l"
        )
    # block id per jagged-diagonal row, then per chunk
    row_block = np.repeat(
        np.arange(p.n_blocks, dtype=np.int32), p.block_len
    )
    chunk_map = row_block[::chunk_l].copy()
    val = p.val if dtype is None else p.val.astype(dtype)
    return PJDSDevice(
        val=jnp.asarray(val),
        col_idx=jnp.asarray(p.col_idx),
        chunk_map=jnp.asarray(chunk_map),
        row_block=jnp.asarray(row_block),
        n_blocks=p.n_blocks,
        b_r=p.b_r,
        chunk_l=chunk_l,
    )


def to_device_ell(e: F.ELLMatrix, chunk_l: int = 8, tile_r: int = 128,
                  dtype=None) -> ELLDevice:
    if e.val.shape[0] % chunk_l or e.n_rows_pad % tile_r:
        raise ValueError("ELL shapes not aligned to (chunk_l, tile_r); "
                         "rebuild with matching row_align/diag_align")
    tile_max = e.rowlen.reshape(-1, tile_r).max(axis=1)
    tile_chunks = ((tile_max + chunk_l - 1) // chunk_l).astype(np.int32)
    val = e.val if dtype is None else e.val.astype(dtype)
    return ELLDevice(
        val=jnp.asarray(val),
        col_idx=jnp.asarray(e.col_idx),
        rowlen=jnp.asarray(e.rowlen),
        tile_chunks=jnp.asarray(tile_chunks),
        chunk_l=chunk_l,
        tile_r=tile_r,
    )


def pjds_matvec(a: PJDSDevice, x: jax.Array,
                backend: Backend = "ref") -> jax.Array:
    """y = A x in the permuted basis; y has n_rows_pad entries."""
    if backend == "kernel":
        return pjds_matvec_kernel_call(
            a.val, a.col_idx, a.chunk_map, x,
            n_blocks=a.n_blocks, chunk_l=a.chunk_l,
        )
    return R.pjds_matvec_ref(a.val, a.col_idx, a.row_block, x, a.n_blocks)


def pjds_matmat(a: PJDSDevice, x: jax.Array, backend: Backend = "ref",
                rhs_t: int = 128) -> jax.Array:
    """Y = A X; X: (n_cols_pad, n_rhs)."""
    if backend == "kernel":
        return pjds_matmat_kernel_call(
            a.val, a.col_idx, a.chunk_map, x,
            n_blocks=a.n_blocks, chunk_l=a.chunk_l, rhs_t=rhs_t,
        )
    return R.pjds_matmat_ref(a.val, a.col_idx, a.row_block, x, a.n_blocks)


def ell_matvec(a: ELLDevice, x: jax.Array,
               backend: Backend = "ref") -> jax.Array:
    if backend == "kernel":
        return ell_matvec_kernel_call(
            a.val, a.col_idx, a.tile_chunks, x,
            chunk_l=a.chunk_l, tile_r=a.tile_r,
        )
    return R.ell_matvec_ref(a.val, a.col_idx, a.rowlen, x)
