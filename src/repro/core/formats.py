"""Sparse matrix storage formats from Kreutzer et al. 2011 (+ successors).

Implements the host-side (numpy) construction of the formats the paper
compares, with the TPU-adapted memory layouts consumed by the Pallas
kernels in ``repro.kernels``:

* CSR           — the CPU baseline / interchange format.
* ELLPACK       — rows compressed left, padded to the *global* max row
                  length, stored jagged-diagonal-major (column-major in
                  the paper's ``val[j*N + i]`` sense).
* ELLPACK-R     — same storage as ELLPACK plus an explicit ``rowlen``
                  array so the kernel skips padding (paper Listing 1).
* pJDS          — the paper's contribution: rows sorted by non-zero count,
                  then padded per *block* of ``b_r`` consecutive rows to
                  the block-local maximum (paper Fig. 1, Listing 2).
* SELL-C-sigma  — beyond-paper: the published successor of pJDS (sorting
                  window sigma instead of a global sort); pJDS is the
                  sigma = n_rows special case.

TPU adaptation (see DESIGN.md §2): the paper pads row counts to the warp
size (32) so a warp issues coalesced loads.  On TPU the analogous unit is
the (sublane, lane) = (8, 128) vector register tile, so

* ``b_r`` (rows per block)   defaults to 128  → rows live on lanes,
* jagged-diagonal counts are padded to multiples of 8 → full sublanes.

Layout of the blocked arrays: ``val``/``col_idx`` have shape
``(total_jds, b_r)`` — jagged diagonals major, rows minor — which is
exactly the paper's column-major ELLPACK layout, restricted to one block,
and gives the Pallas kernels clean (8k, 128) VMEM tiles.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Tuple

import numpy as np

__all__ = [
    "CSRMatrix",
    "ELLMatrix",
    "PJDSMatrix",
    "SELLMatrix",
    "csr_from_dense",
    "csr_to_dense",
    "csr_from_coo",
    "validate_csr",
    "CSRValidationError",
    "ValidationReport",
    "csr_to_ell",
    "csr_to_pjds",
    "csr_to_sell",
    "CMRSMatrix",
    "csr_to_cmrs",
    "ell_to_dense",
    "pjds_to_dense",
    "sell_to_dense",
    "cmrs_to_dense",
    "format_nbytes",
    "storage_elements",
    "data_reduction_vs_ellpack",
    "windowed_sort_perm",
    "windowed_block_lengths",
    "estimate_storage_elements",
    "csr_remote_columns_by_distance",
    "csr_transpose",
    "csr_diagonal",
    "structural_fingerprint",
    "PAD_COL",
    "min_index_dtype",
    "resolve_index_dtype",
    "assert_padding_invariant",
]

_DEFAULT_BR = 128          # rows per pJDS block (lane dimension on TPU)
_DEFAULT_DIAG_ALIGN = 8    # jagged-diagonal padding (sublane dimension)

# ----------------------------------------------------------------------
# Padding sentinel (audited end-to-end; see assert_padding_invariant).
#
# Every blocked format pads its val/col_idx arrays.  The invariant is:
#
#   padded entries store  val == 0  AND  col_idx == PAD_COL (== 0).
#
# PAD_COL is an IN-RANGE column, so the kernels' RHS gather reads x[0]
# for padded lanes without masking; correctness comes from val == 0
# (the product contributes nothing to the accumulator).  This is what
# lets every kernel and ref skip per-entry masks on the hot path, and
# it must survive index compression: PAD_COL == 0 is representable in
# any index dtype.  Code that rewrites stored values (e.g.
# ``operator.with_values``) must preserve the zeros in padded slots.
# ----------------------------------------------------------------------
PAD_COL = 0

# When True every converter audits its freshly built arrays (numpy-level,
# O(stored elements)).  Enabled in debug builds (i.e. unless python runs
# with -O); flip module-globally to force either way.
PAD_AUDIT = bool(__debug__)


def min_index_dtype(span: int) -> np.dtype:
    """Narrowest signed integer dtype that can address columns
    ``[0, span)``.  int16 covers spans up to 2**15 — comfortably the
    per-device column slices the distributed partitioner produces —
    otherwise int32."""
    return np.dtype(np.int16) if span <= 2 ** 15 else np.dtype(np.int32)


def resolve_index_dtype(index_dtype, span: int) -> np.dtype:
    """Resolve an ``index_dtype`` build argument: ``"auto"`` compresses
    to :func:`min_index_dtype`; an explicit dtype is validated against
    the addressable span (a lossy narrowing is a build error, not a
    silent wrap)."""
    if index_dtype == "auto":
        return min_index_dtype(span)
    dt = np.dtype(index_dtype)
    if dt.kind != "i":
        raise ValueError(f"index_dtype must be a signed integer; got {dt}")
    if span > np.iinfo(dt).max + 1:
        raise ValueError(
            f"index_dtype {dt} cannot address {span} columns "
            f"(max span {np.iinfo(dt).max + 1})")
    return dt


# --------------------------------------------------------------------------
# CSR (interchange format)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class CSRMatrix:
    """Host-side CSR. ``indptr`` int64, ``indices`` int32."""

    indptr: np.ndarray
    indices: np.ndarray
    data: np.ndarray
    shape: Tuple[int, int]

    @property
    def n_rows(self) -> int:
        return self.shape[0]

    @property
    def n_cols(self) -> int:
        return self.shape[1]

    @property
    def nnz(self) -> int:
        return int(self.indptr[-1])

    def row_lengths(self) -> np.ndarray:
        return np.diff(self.indptr).astype(np.int32)

    @property
    def n_nzr(self) -> float:
        """Average non-zeros per row (the paper's N_nzr)."""
        return self.nnz / max(self.n_rows, 1)

    def matvec(self, x: np.ndarray) -> np.ndarray:
        """Reference numpy spMVM (oracle for everything else)."""
        y = np.zeros(self.n_rows, dtype=np.result_type(self.data, x))
        for i in range(self.n_rows):
            lo, hi = self.indptr[i], self.indptr[i + 1]
            if hi > lo:
                y[i] = np.dot(self.data[lo:hi], x[self.indices[lo:hi]])
        return y


def csr_from_dense(a: np.ndarray) -> CSRMatrix:
    n_rows, n_cols = a.shape
    mask = a != 0
    indptr = np.zeros(n_rows + 1, dtype=np.int64)
    np.cumsum(mask.sum(axis=1), out=indptr[1:])
    indices = np.nonzero(mask)[1].astype(np.int32)
    data = a[mask]
    return CSRMatrix(indptr, indices, data, (n_rows, n_cols))


def csr_to_dense(m: CSRMatrix) -> np.ndarray:
    a = np.zeros(m.shape, dtype=m.data.dtype)
    for i in range(m.n_rows):
        lo, hi = m.indptr[i], m.indptr[i + 1]
        a[i, m.indices[lo:hi]] = m.data[lo:hi]
    return a


def csr_from_coo(
    rows: np.ndarray,
    cols: np.ndarray,
    vals: np.ndarray,
    shape: Tuple[int, int],
    sum_duplicates: bool = True,
) -> CSRMatrix:
    """Build CSR from COO triplets (vectorised; no scipy dependency).

    Sorted-per-row invariant: the ``lexsort((cols, rows))`` below runs
    BEFORE the ``sum_duplicates`` branch, so the output's within-row
    column indices are ascending on BOTH paths.  Callers that pass
    ``sum_duplicates=False`` (``csr_transpose``,
    ``reorder.permute_symmetric``) therefore still satisfy the sorted
    invariant that ``validate_csr`` enforces and that int16 span
    compression (``resolve_index_dtype``) assumes — they merely skip
    deduplication, not the sort.  (On the dedup path the invariant also
    follows from ``np.unique`` of the ``row * n_cols + col`` key.)"""
    rows = np.asarray(rows, dtype=np.int64)
    cols = np.asarray(cols, dtype=np.int64)
    vals = np.asarray(vals)
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if sum_duplicates and len(rows):
        key = rows * shape[1] + cols
        uniq, inv = np.unique(key, return_inverse=True)
        summed = np.zeros(len(uniq), dtype=vals.dtype)
        np.add.at(summed, inv, vals)
        rows = (uniq // shape[1]).astype(np.int64)
        cols = (uniq % shape[1]).astype(np.int64)
        vals = summed
    indptr = np.zeros(shape[0] + 1, dtype=np.int64)
    np.add.at(indptr, rows + 1, 1)
    np.cumsum(indptr, out=indptr)
    return CSRMatrix(indptr, cols.astype(np.int32), vals, shape)


class CSRValidationError(ValueError):
    """A host CSR matrix failed admission validation.  ``report`` is the
    :class:`ValidationReport` with per-issue counts."""

    def __init__(self, message: str, report: "ValidationReport"):
        super().__init__(message)
        self.report = report


@dataclasses.dataclass
class ValidationReport:
    """What :func:`validate_csr` found (and, under ``repair=True``,
    fixed).  ``issues`` maps issue name -> count; ``ok`` is pre-repair
    cleanliness, ``repaired`` whether a rebuilt matrix was returned."""

    issues: dict
    repaired: bool = False

    @property
    def ok(self) -> bool:
        return not self.issues


def validate_csr(m: CSRMatrix, *, repair: bool = False
                 ) -> tuple[CSRMatrix, ValidationReport]:
    """Admission check for a host CSR matrix: structural integrity of
    ``indptr`` (length, monotone, bounds), column indices in range and
    sorted per row, no within-row duplicates, finite values.

    ``repair=False`` raises :class:`CSRValidationError` on the first
    report of ANY issue; ``repair=True`` rebuilds the matrix instead —
    out-of-range columns and non-finite values are DROPPED, duplicates
    summed, rows re-sorted (via :func:`csr_from_coo`) — and returns the
    repaired copy.  A non-monotone / mis-sized ``indptr`` is structural
    corruption with no trustworthy row boundaries, so it raises even
    under ``repair=True``.  Returns ``(matrix, report)``; the input is
    returned untouched (and unscanned structure shared) when clean.
    """
    indptr = np.asarray(m.indptr)
    indices = np.asarray(m.indices)
    data = np.asarray(m.data)
    n_rows, n_cols = m.shape
    issues: dict = {}

    structural = []
    if indptr.ndim != 1 or len(indptr) != n_rows + 1:
        structural.append("indptr_shape")
    else:
        if int(indptr[0]) != 0 or int(indptr[-1]) != len(indices):
            structural.append("indptr_bounds")
        if np.any(np.diff(indptr) < 0):
            structural.append("indptr_non_monotone")
    if len(indices) != len(data):
        structural.append("indices_data_mismatch")
    if structural:
        report = ValidationReport({k: 1 for k in structural})
        raise CSRValidationError(
            f"CSR structure is corrupt ({', '.join(structural)}): row "
            "boundaries cannot be trusted, not repairable", report)

    out_of_range = (indices < 0) | (indices >= n_cols)
    n_oor = int(out_of_range.sum())
    if n_oor:
        issues["out_of_range_indices"] = n_oor
    finite = np.isfinite(data)
    n_nonfinite = int((~finite).sum())
    if n_nonfinite:
        issues["non_finite_values"] = n_nonfinite

    rows = np.repeat(np.arange(n_rows, dtype=np.int64), np.diff(indptr))
    # sorted-within-row and duplicate detection in one pass over the
    # (row, col) key sequence: a non-increasing step inside a row is
    # either out of order or a duplicate
    if len(indices):
        keys = rows * max(n_cols, 1) + np.clip(indices, 0, n_cols - 1)
        step = np.diff(keys)
        same_row = np.diff(rows) == 0
        n_dup = int(((step == 0) & same_row).sum())
        n_unsorted = int(((step < 0) & same_row).sum())
        if n_dup:
            issues["duplicate_indices"] = n_dup
        if n_unsorted:
            issues["unsorted_indices"] = n_unsorted

    if not issues:
        return m, ValidationReport({})
    if not repair:
        raise CSRValidationError(
            "CSR failed validation: "
            + ", ".join(f"{k}={v}" for k, v in issues.items())
            + " (pass repair=True / validate='repair' to rebuild)",
            ValidationReport(dict(issues)))
    keep = finite & ~out_of_range
    fixed = csr_from_coo(rows[keep], indices[keep].astype(np.int64),
                         data[keep], m.shape, sum_duplicates=True)
    fixed = CSRMatrix(fixed.indptr, fixed.indices,
                      fixed.data.astype(data.dtype), m.shape)
    return fixed, ValidationReport(dict(issues), repaired=True)


# --------------------------------------------------------------------------
# ELLPACK / ELLPACK-R
# --------------------------------------------------------------------------
@dataclasses.dataclass
class ELLMatrix:
    """ELLPACK(-R), jagged-diagonal-major: ``val[j, i]`` = j-th nonzero of
    row i (the paper's ``val[j*N + i]``).  Padded entries have val 0 and a
    clamped (valid) column index so gathers stay in range.

    ``rowlen`` turns plain ELLPACK into ELLPACK-R (paper Listing 1).
    """

    val: np.ndarray       # (max_nzr_pad, n_rows_pad)
    col_idx: np.ndarray   # (max_nzr_pad, n_rows_pad) int32
    rowlen: np.ndarray    # (n_rows_pad,) int32
    shape: Tuple[int, int]
    n_rows_pad: int

    @property
    def max_nzr(self) -> int:
        return self.val.shape[0]


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def csr_to_ell(
    m: CSRMatrix,
    row_align: int = _DEFAULT_BR,
    diag_align: int = _DEFAULT_DIAG_ALIGN,
    index_dtype="auto",
) -> ELLMatrix:
    rl = m.row_lengths()
    max_nzr = _pad_to(max(int(rl.max(initial=0)), 1), diag_align)
    n_pad = _pad_to(m.n_rows, row_align)
    idt = resolve_index_dtype(index_dtype, m.shape[1])
    val = np.zeros((max_nzr, n_pad), dtype=m.data.dtype)
    col = np.full((max_nzr, n_pad), PAD_COL, dtype=idt)
    for i in range(m.n_rows):
        lo, hi = m.indptr[i], m.indptr[i + 1]
        val[: hi - lo, i] = m.data[lo:hi]
        col[: hi - lo, i] = m.indices[lo:hi]
    rowlen = np.zeros(n_pad, dtype=np.int32)
    rowlen[: m.n_rows] = rl
    e = ELLMatrix(val, col, rowlen, m.shape, n_pad)
    if PAD_AUDIT:
        assert_padding_invariant(e)
    return e


def ell_to_dense(e: ELLMatrix) -> np.ndarray:
    a = np.zeros((e.shape[0], e.shape[1]), dtype=e.val.dtype)
    for i in range(e.shape[0]):
        for j in range(int(e.rowlen[i])):
            a[i, e.col_idx[j, i]] += e.val[j, i]
    return a


# --------------------------------------------------------------------------
# pJDS — the paper's contribution
# --------------------------------------------------------------------------
@dataclasses.dataclass
class PJDSMatrix:
    """Padded Jagged Diagonals Storage (paper Fig. 1), TPU-blocked.

    Rows are sorted by descending non-zero count; blocks of ``b_r``
    consecutive *sorted* rows are padded to the block-local max length
    (rounded up to ``diag_align`` sublanes).  Block ``b`` occupies rows
    ``block_start[b]:block_start[b+1]`` of the flat ``(total_jds, b_r)``
    ``val``/``col_idx`` arrays — this is the paper's per-column
    ``col_start[]`` offset array at block granularity.

    The operation computed by the kernels is in the *permuted* basis
    (paper §2.1): ``y_p = A_p @ x_p`` with ``x_p = x[perm]``; with
    ``permuted_cols=True`` the stored column indices already live in the
    permuted basis (symmetric permutation, the right choice for the
    Krylov solvers in ``core.solvers``).
    """

    val: np.ndarray         # (total_jds, b_r)
    col_idx: np.ndarray     # (total_jds, b_r) int32
    block_start: np.ndarray # (n_blocks + 1,) int32
    block_len: np.ndarray   # (n_blocks,) int32  == diff(block_start)
    rowlen: np.ndarray      # (n_rows_pad,) int32, sorted order
    perm: np.ndarray        # (n_rows_pad,) int32: perm[p] = original row at sorted pos p
    inv_perm: np.ndarray    # (n_rows_pad,) int32
    shape: Tuple[int, int]
    b_r: int
    n_rows_pad: int
    permuted_cols: bool

    @property
    def n_blocks(self) -> int:
        return len(self.block_len)

    @property
    def total_jds(self) -> int:
        return self.val.shape[0]

    def permute(self, x: np.ndarray) -> np.ndarray:
        """Take ``x`` (original basis) to the sorted/permuted basis."""
        xp = np.zeros(self.n_rows_pad, dtype=x.dtype)
        n = min(self.shape[1], len(x))
        # perm includes padded positions pointing past n_rows; guard them.
        valid = self.perm < n
        xp[valid] = x[self.perm[valid]]
        return xp

    def unpermute(self, yp: np.ndarray) -> np.ndarray:
        """Take a padded permuted vector back to the original basis."""
        y = np.zeros(self.shape[0], dtype=yp.dtype)
        valid = self.perm < self.shape[0]
        y[self.perm[valid]] = yp[valid]
        return y


def csr_to_pjds(
    m: CSRMatrix,
    b_r: int = _DEFAULT_BR,
    diag_align: int = _DEFAULT_DIAG_ALIGN,
    permuted_cols: bool = True,
    index_dtype="auto",
) -> PJDSMatrix:
    rl = m.row_lengths()
    n_pad = _pad_to(m.n_rows, b_r)
    rl_pad = np.zeros(n_pad, dtype=np.int64)
    rl_pad[: m.n_rows] = rl
    # "sort" step (Fig. 1): stable sort by descending row length.
    perm = np.argsort(-rl_pad, kind="stable").astype(np.int32)
    return _pjds_with_perm(m, perm, b_r, diag_align, permuted_cols,
                           index_dtype)


def pjds_to_dense(p: PJDSMatrix) -> np.ndarray:
    """Densify in the ORIGINAL basis (undoes row/col permutation)."""
    n_rows, n_cols = p.shape
    a = np.zeros((n_rows, n_cols), dtype=p.val.dtype)
    for b in range(p.n_blocks):
        s, e = int(p.block_start[b]), int(p.block_start[b + 1])
        for r in range(p.b_r):
            pos = b * p.b_r + r
            orig = int(p.perm[pos])
            if orig >= n_rows:
                continue
            for j in range(s, e):
                v = p.val[j, r]
                if v != 0:
                    c = int(p.col_idx[j, r])
                    if p.permuted_cols:
                        c = int(p.perm[c])
                    a[orig, c] += v
    return a


# --------------------------------------------------------------------------
# SELL-C-sigma (beyond paper: pJDS with a bounded sorting window)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SELLMatrix:
    """SELL-C-sigma: like pJDS but rows are sorted only inside windows of
    ``sigma`` rows, preserving locality of the original ordering.
    ``sigma = n_rows`` reproduces pJDS; ``sigma = C`` is pure sliced
    ELLPACK.  Storage layout is identical to :class:`PJDSMatrix`.
    """

    pjds: PJDSMatrix
    sigma: int


def windowed_sort_perm(rowlen: np.ndarray, sigma: int) -> np.ndarray:
    """Permutation sorting rows by DESCENDING length inside each window
    of ``sigma`` rows (stable within the window) — the SELL-C-sigma sort
    step, shared by the converter, the storage estimator, and the
    distributed partitioner so their padding always agrees.
    ``perm[p]`` = original row at sorted position ``p``;
    ``|perm[p] - p| < sigma`` for every entry."""
    rl = np.asarray(rowlen, dtype=np.int64)
    n = len(rl)
    perm = np.arange(n, dtype=np.int32)
    for w in range(0, n, sigma):
        hi = min(w + sigma, n)
        sub = np.argsort(-rl[w:hi], kind="stable")
        perm[w:hi] = (w + sub).astype(np.int32)
    return perm


def csr_to_sell(
    m: CSRMatrix,
    c: int = _DEFAULT_BR,
    sigma: int | None = None,
    diag_align: int = _DEFAULT_DIAG_ALIGN,
    permuted_cols: bool = True,
    index_dtype="auto",
) -> SELLMatrix:
    if sigma is None:
        sigma = 8 * c
    rl = m.row_lengths()
    n_pad = _pad_to(m.n_rows, c)
    rl_pad = np.zeros(n_pad, dtype=np.int64)
    rl_pad[: m.n_rows] = rl
    perm = windowed_sort_perm(rl_pad, sigma)
    # Reuse the pJDS constructor machinery by faking the sort: build a CSR
    # with rows pre-permuted, convert with an identity-sort guarantee, then
    # compose permutations.
    pj = _pjds_with_perm(m, perm, c, diag_align, permuted_cols, index_dtype)
    return SELLMatrix(pjds=pj, sigma=sigma)


def _pjds_with_perm(
    m: CSRMatrix,
    perm: np.ndarray,
    b_r: int,
    diag_align: int,
    permuted_cols: bool,
    index_dtype="auto",
) -> PJDSMatrix:
    """pJDS blocking with an externally supplied row permutation."""
    if permuted_cols and m.shape[0] != m.shape[1]:
        raise ValueError("symmetric permutation requires a square matrix")
    n_pad = len(perm)
    inv_perm = np.empty_like(perm)
    inv_perm[perm] = np.arange(n_pad, dtype=np.int32)
    rl = m.row_lengths()
    rl_pad = np.zeros(n_pad, dtype=np.int64)
    rl_pad[: m.n_rows] = rl
    sorted_rl = rl_pad[perm]
    n_blocks = n_pad // b_r
    block_len = np.zeros(n_blocks, dtype=np.int32)
    for b in range(n_blocks):
        blk = sorted_rl[b * b_r : (b + 1) * b_r]
        block_len[b] = _pad_to(max(int(blk.max(initial=0)), 1), diag_align)
    block_start = np.zeros(n_blocks + 1, dtype=np.int32)
    np.cumsum(block_len, out=block_start[1:])
    total = int(block_start[-1])
    # With a symmetric permutation the stored indices live in the PERMUTED
    # column space, whose addressable span is the padded row count.
    idt = resolve_index_dtype(index_dtype,
                              n_pad if permuted_cols else m.shape[1])
    val = np.zeros((total, b_r), dtype=m.data.dtype)
    col = np.full((total, b_r), PAD_COL, dtype=idt)
    for b in range(n_blocks):
        s = block_start[b]
        for r in range(b_r):
            p = b * b_r + r
            orig = perm[p]
            if orig >= m.n_rows:
                continue
            lo, hi = m.indptr[orig], m.indptr[orig + 1]
            cols_r = m.indices[lo:hi]
            if permuted_cols:
                cols_r = inv_perm[cols_r]
            val[s : s + (hi - lo), r] = m.data[lo:hi]
            col[s : s + (hi - lo), r] = cols_r.astype(idt)
    pj = PJDSMatrix(
        val=val,
        col_idx=col,
        block_start=block_start,
        block_len=block_len,
        rowlen=sorted_rl.astype(np.int32),
        perm=perm.astype(np.int32),
        inv_perm=inv_perm.astype(np.int32),
        shape=m.shape,
        b_r=b_r,
        n_rows_pad=n_pad,
        permuted_cols=permuted_cols,
    )
    if PAD_AUDIT:
        assert_padding_invariant(pj)
    return pj


def sell_to_dense(s: SELLMatrix) -> np.ndarray:
    return pjds_to_dense(s.pjds)


# --------------------------------------------------------------------------
# CMRS — Compressed Multi-Row Storage (arXiv:1203.2946), TPU-blocked
# --------------------------------------------------------------------------
@dataclasses.dataclass
class CMRSMatrix:
    """CMRS adapted to the TPU tiling: rows stay in ORIGINAL order (no
    sort, no permutation epilogue) and are grouped into *strips* of
    ``b_r`` consecutive rows.  Each strip's nonzeros are packed densely,
    row-major, into ``(strip_su, b_r)`` lane-major tiles: entry ``k`` of
    a strip lands at sublane ``k // b_r``, lane ``k % b_r`` relative to
    the strip's first sublane-row.  ``row_in_strip`` is the paper's
    per-entry row stream (int8, values in ``[0, b_r)``) that routes each
    slot back to its row inside the strip.

    ``strip_su[s] = ceil(strip_nnz / b_r)`` padded to ``diag_align``
    (min 1); ``strip_start`` is its exclusive prefix sum in sublane-rows,
    so strip ``s`` owns tile rows ``strip_start[s]:strip_start[s+1]``.
    Padding slots carry the usual sentinel (``val == 0``,
    ``col == PAD_COL``) plus ``row_in_strip == 0``; ``strip_nnz`` keeps
    the true per-strip count so the pad audit and ``cmrs_to_dense`` can
    tell padding from stored entries exactly.

    Storage is ~``nnz`` padded to tile granularity — per-row padding
    vanishes entirely, which is where CMRS beats ELLPACK/pJDS on
    power-law patterns — at the cost of ``b_r`` flops per slot in the
    kernel's one-hot segment reduction (``perf_model.cmrs_reduce_seconds``).
    """

    val: np.ndarray            # (total_su, b_r)
    col_idx: np.ndarray        # (total_su, b_r) int16/int32
    row_in_strip: np.ndarray   # (total_su, b_r) int8
    strip_start: np.ndarray    # (n_strips + 1,) int32, sublane-row offsets
    strip_len: np.ndarray      # (n_strips,) int32 == diff(strip_start)
    strip_nnz: np.ndarray      # (n_strips,) int64, true nonzeros per strip
    shape: Tuple[int, int]
    b_r: int
    n_rows_pad: int

    @property
    def n_strips(self) -> int:
        return len(self.strip_len)

    @property
    def total_su(self) -> int:
        return int(self.strip_start[-1])


def csr_to_cmrs(
    m: CSRMatrix,
    b_r: int = _DEFAULT_BR,
    diag_align: int = _DEFAULT_DIAG_ALIGN,
    index_dtype="auto",
) -> CMRSMatrix:
    """Pack ``m`` into CMRS strips of ``b_r`` rows (original order)."""
    n = m.n_rows
    n_pad = _pad_to(max(n, 1), b_r)
    n_strips = n_pad // b_r
    rl = m.row_lengths()
    idt = resolve_index_dtype(index_dtype, m.n_cols)

    strip_nnz = np.zeros(n_strips, dtype=np.int64)
    counts = np.add.reduceat(
        np.concatenate([rl, np.zeros(n_pad - n, dtype=rl.dtype)]),
        np.arange(0, n_pad, b_r))
    strip_nnz[:] = counts
    strip_len = np.array(
        [_pad_to(max(-(-int(c) // b_r), 1), diag_align) for c in strip_nnz],
        dtype=np.int32)
    strip_start = np.zeros(n_strips + 1, dtype=np.int32)
    np.cumsum(strip_len, out=strip_start[1:])

    total = int(strip_start[-1])
    val = np.zeros((total, b_r), dtype=m.data.dtype)
    col = np.full((total, b_r), PAD_COL, dtype=idt)
    ris = np.zeros((total, b_r), dtype=np.int8)
    for s in range(n_strips):
        r0, r1 = s * b_r, min((s + 1) * b_r, n)
        lo, hi = int(m.indptr[r0]), int(m.indptr[r1])
        cnt = hi - lo
        if cnt == 0:
            continue
        su = int(strip_len[s])
        flat_v = np.zeros(su * b_r, dtype=m.data.dtype)
        flat_c = np.full(su * b_r, PAD_COL, dtype=idt)
        flat_r = np.zeros(su * b_r, dtype=np.int8)
        flat_v[:cnt] = m.data[lo:hi]
        flat_c[:cnt] = m.indices[lo:hi].astype(idt)
        flat_r[:cnt] = np.repeat(
            np.arange(r1 - r0, dtype=np.int64), rl[r0:r1]).astype(np.int8)
        s0 = int(strip_start[s])
        val[s0 : s0 + su] = flat_v.reshape(su, b_r)
        col[s0 : s0 + su] = flat_c.reshape(su, b_r)
        ris[s0 : s0 + su] = flat_r.reshape(su, b_r)

    cm = CMRSMatrix(
        val=val, col_idx=col, row_in_strip=ris,
        strip_start=strip_start, strip_len=strip_len, strip_nnz=strip_nnz,
        shape=m.shape, b_r=b_r, n_rows_pad=n_pad)
    if PAD_AUDIT:
        assert_padding_invariant(cm)
    return cm


def cmrs_to_dense(c: CMRSMatrix) -> np.ndarray:
    a = np.zeros(c.shape, dtype=c.val.dtype)
    for s in range(c.n_strips):
        s0, su = int(c.strip_start[s]), int(c.strip_len[s])
        cnt = int(c.strip_nnz[s])
        v = c.val[s0 : s0 + su].reshape(-1)[:cnt]
        ci = c.col_idx[s0 : s0 + su].reshape(-1)[:cnt]
        ri = c.row_in_strip[s0 : s0 + su].reshape(-1)[:cnt]
        np.add.at(a, (s * c.b_r + ri.astype(np.int64), ci.astype(np.int64)), v)
    return a


# --------------------------------------------------------------------------
# Transpose metadata (the operator protocol's rmatvec "device" path)
# --------------------------------------------------------------------------
def csr_transpose(m: CSRMatrix) -> CSRMatrix:
    """A^T as a host CSR — i.e. the CSC view of ``m`` re-read as CSR.

    This is the "CSC-of-blocks" build of the operator protocol: feeding
    the result through the normal blocked converters gives a device
    representation whose FORWARD kernels compute ``A^T x``, so the
    transpose path reuses the gather-structured spMVM instead of a
    scatter (DESIGN.md §8).

    The ``sum_duplicates=False`` fast path is safe here: duplicates in
    ``m`` stay duplicates in the transpose (matvec sums them either
    way), and ``csr_from_coo`` sorts within rows before that branch, so
    the result still satisfies the sorted-per-row invariant.
    """
    rows = np.repeat(np.arange(m.n_rows, dtype=np.int64), m.row_lengths())
    return csr_from_coo(m.indices.astype(np.int64), rows, m.data,
                        (m.n_cols, m.n_rows), sum_duplicates=False)


def csr_diagonal(m: CSRMatrix) -> np.ndarray:
    """diag(A) for a square CSR (missing entries are 0) — the Jacobi
    preconditioner's input, extracted once host-side."""
    if m.shape[0] != m.shape[1]:
        raise ValueError("diagonal requires a square matrix")
    d = np.zeros(m.n_rows, dtype=m.data.dtype)
    rows = np.repeat(np.arange(m.n_rows, dtype=np.int64), m.row_lengths())
    on_diag = m.indices == rows
    # accumulate (not assign): duplicate (i, i) entries sum in matvec,
    # so the diagonal must agree
    np.add.at(d, rows[on_diag], m.data[on_diag])
    return d


# --------------------------------------------------------------------------
# Structural fingerprint (the autotuner's cache key component)
# --------------------------------------------------------------------------
def structural_fingerprint(m: CSRMatrix) -> str:
    """sha1 digest of the matrix STRUCTURE: shape + indptr + indices,
    deliberately excluding the stored values.

    Every quantity the tuner's search space and the perf model depend on
    — row lengths, padding, column spans, halo coupling — is a function
    of the structure alone, so tuned kernel statics transfer across
    value updates (a solver re-assembling coefficients on a fixed mesh
    keeps its cache hit), while any structural edit (new entry, reorder,
    resize) changes the digest and invalidates the cached decision.
    """
    h = hashlib.sha1()
    h.update(np.asarray(m.shape, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(m.indptr, dtype=np.int64).tobytes())
    h.update(np.ascontiguousarray(m.indices, dtype=np.int64).tobytes())
    return h.hexdigest()


# --------------------------------------------------------------------------
# Distributed-partition helper: measured halo coupling
# --------------------------------------------------------------------------
def csr_remote_columns_by_distance(
    sl: CSRMatrix, p: int, n_loc: int, n_dev: int
) -> dict:
    """For device ``p``'s row slice ``sl`` (a CSR over the GLOBAL column
    space) under a uniform n_loc-row ring partition: the slice-local
    column indices it references in each OTHER device's slice, keyed by
    signed ring distance d (owner = (p + d) % n_dev, |d| <= n_dev//2).

    Each value is sorted and unique — the gather set of the paper's
    "local gather + point-to-point" halo exchange, i.e. exactly the
    entries of the neighbor's x slice that must cross the wire.
    """
    cols = sl.indices.astype(np.int64)
    own_lo, own_hi = p * n_loc, (p + 1) * n_loc
    rcols = cols[(cols < own_lo) | (cols >= own_hi)]
    owner = rcols // n_loc
    d = (owner - p + n_dev) % n_dev
    d = np.where(d > n_dev // 2, d - n_dev, d)
    return {
        int(dd): np.unique(rcols[d == dd] % n_loc).astype(np.int32)
        for dd in np.unique(d)
    }


# --------------------------------------------------------------------------
# Padding-sentinel audit
# --------------------------------------------------------------------------
def _check_pad(name: str, val_pad: np.ndarray, col_pad: np.ndarray) -> None:
    if val_pad.size and np.any(val_pad != 0):
        raise AssertionError(
            f"{name}: padded entries carry non-zero values — the unmasked "
            f"kernels would add them into y")
    if col_pad.size and np.any(col_pad != PAD_COL):
        raise AssertionError(
            f"{name}: padded entries carry column != PAD_COL ({PAD_COL}) — "
            f"the RHS gather would touch arbitrary (possibly stale-halo) "
            f"entries of x")


def assert_padding_invariant(fmt) -> None:
    """Audit the padding sentinel invariant (see :data:`PAD_COL`): every
    padded slot of a blocked format must store ``val == 0`` and
    ``col_idx == PAD_COL``.  Raises AssertionError on violation.  Called
    by the converters when :data:`PAD_AUDIT` is set (debug builds);
    callable directly on any format object."""
    if isinstance(fmt, SELLMatrix):
        fmt = fmt.pjds
    if isinstance(fmt, ELLMatrix):
        j = np.arange(fmt.val.shape[0])[:, None]
        pad = j >= fmt.rowlen[None, :]
        _check_pad("ELLMatrix", fmt.val[pad], fmt.col_idx[pad])
        return
    if isinstance(fmt, PJDSMatrix):
        for b in range(fmt.n_blocks):
            s, e = int(fmt.block_start[b]), int(fmt.block_start[b + 1])
            rl = fmt.rowlen[b * fmt.b_r : (b + 1) * fmt.b_r]  # sorted order
            j = np.arange(e - s)[:, None]
            pad = j >= rl[None, :]
            _check_pad(f"PJDSMatrix block {b}", fmt.val[s:e][pad],
                       fmt.col_idx[s:e][pad])
        return
    if isinstance(fmt, CMRSMatrix):
        for s in range(fmt.n_strips):
            s0, su = int(fmt.strip_start[s]), int(fmt.strip_len[s])
            cnt = int(fmt.strip_nnz[s])
            v = fmt.val[s0 : s0 + su].reshape(-1)[cnt:]
            c = fmt.col_idx[s0 : s0 + su].reshape(-1)[cnt:]
            _check_pad(f"CMRSMatrix strip {s}", v, c)
            r = fmt.row_in_strip[s0 : s0 + su].reshape(-1)[cnt:]
            if r.size and np.any(r != 0):
                raise AssertionError(
                    f"CMRSMatrix strip {s}: padded entries carry "
                    f"row_in_strip != 0 — the segment reduction would "
                    f"scatter stale zeros into arbitrary rows")
        return
    if isinstance(fmt, CSRMatrix):
        return              # CSR stores no padding
    raise TypeError(type(fmt))


# --------------------------------------------------------------------------
# Memory accounting (paper Table 1, "data reduction" column)
# --------------------------------------------------------------------------
def storage_elements(fmt) -> int:
    """Number of stored value elements (incl. padding zeros) — the paper's
    measure for the ELLPACK-vs-pJDS comparison."""
    if isinstance(fmt, CSRMatrix):
        return fmt.nnz
    if isinstance(fmt, ELLMatrix):
        return int(fmt.val.size)
    if isinstance(fmt, PJDSMatrix):
        return int(fmt.val.size)
    if isinstance(fmt, SELLMatrix):
        return int(fmt.pjds.val.size)
    if isinstance(fmt, CMRSMatrix):
        return int(fmt.val.size)
    raise TypeError(type(fmt))


def format_nbytes(fmt, value_bytes: int | None = None,
                  index_bytes: int | None = None) -> int:
    """Total footprint: values + column indices + per-format metadata.

    ``value_bytes`` / ``index_bytes`` default to the widths ACTUALLY
    stored (so an int16-index / bf16-value build reports its compressed
    footprint); pass explicit widths to price a hypothetical storage
    precision instead."""
    if isinstance(fmt, SELLMatrix):
        return format_nbytes(fmt.pjds, value_bytes, index_bytes)
    if value_bytes is None:
        value_bytes = (fmt.data if isinstance(fmt, CSRMatrix)
                       else fmt.val).dtype.itemsize
    if index_bytes is None:
        index_bytes = (fmt.indices if isinstance(fmt, CSRMatrix)
                       else fmt.col_idx).dtype.itemsize
    e = storage_elements(fmt)
    base = e * (value_bytes + index_bytes)
    if isinstance(fmt, CSRMatrix):
        return base + (fmt.n_rows + 1) * 8
    if isinstance(fmt, ELLMatrix):
        return base + fmt.n_rows_pad * 4          # rowlen (ELLPACK-R)
    if isinstance(fmt, PJDSMatrix):
        return base + (fmt.n_blocks + 1) * 4 + fmt.n_rows_pad * 4  # col_start + perm
    if isinstance(fmt, CMRSMatrix):
        # + the int8 row-in-strip stream and the strip offsets
        return base + e * 1 + (fmt.n_strips + 1) * 4
    raise TypeError(type(fmt))


def data_reduction_vs_ellpack(m: CSRMatrix, b_r: int = _DEFAULT_BR) -> float:
    """Paper Table 1: fraction of ELLPACK storage saved by pJDS."""
    ell = csr_to_ell(m, row_align=b_r)
    pj = csr_to_pjds(m, b_r=b_r, permuted_cols=(m.shape[0] == m.shape[1]))
    return 1.0 - storage_elements(pj) / storage_elements(ell)


# --------------------------------------------------------------------------
# Storage estimators from row lengths alone (no matrix build).
# The dispatch layer (kernels.ops.select_format) prices each candidate
# format with these before converting anything.
# --------------------------------------------------------------------------
def windowed_block_lengths(
    rowlen: np.ndarray,
    b_r: int = _DEFAULT_BR,
    diag_align: int = _DEFAULT_DIAG_ALIGN,
    sigma: int | None = None,
) -> np.ndarray:
    """Per-block padded jagged-diagonal counts of a blocked (pJDS / SELL)
    layout, computed from row lengths alone.  ``sigma=None`` is the global
    sort (pJDS); ``sigma <= b_r`` degenerates to no sort (sliced ELLPACK).
    Matches the ``block_len`` the real converters produce."""
    rl = np.asarray(rowlen, dtype=np.int64)
    n_pad = _pad_to(max(len(rl), 1), b_r)
    rl_pad = np.zeros(n_pad, dtype=np.int64)
    rl_pad[: len(rl)] = rl
    if sigma is None or sigma >= n_pad:
        srt = -np.sort(-rl_pad)
    else:
        srt = rl_pad[windowed_sort_perm(rl_pad, sigma)]
    blk_max = srt.reshape(-1, b_r).max(axis=1)
    return np.array(
        [_pad_to(max(int(b), 1), diag_align) for b in blk_max], dtype=np.int32
    )


def estimate_storage_elements(
    rowlen: np.ndarray,
    fmt: str,
    b_r: int = _DEFAULT_BR,
    diag_align: int = _DEFAULT_DIAG_ALIGN,
    sigma: int | None = None,
) -> int:
    """Stored value elements (incl. padding) a format WOULD use, from row
    lengths alone.  Agrees with ``storage_elements`` on the built matrix."""
    rl = np.asarray(rowlen, dtype=np.int64)
    if fmt == "csr":
        return int(rl.sum())
    if fmt in ("ellpack", "ellpack_r"):
        n_pad = _pad_to(max(len(rl), 1), b_r)
        return n_pad * _pad_to(max(int(rl.max(initial=0)), 1), diag_align)
    if fmt == "pjds":
        return int(windowed_block_lengths(rl, b_r, diag_align, None).sum()) * b_r
    if fmt == "sell":
        if sigma is None:
            sigma = 8 * b_r
        return int(windowed_block_lengths(rl, b_r, diag_align, sigma).sum()) * b_r
    if fmt == "cmrs":
        n_pad = _pad_to(max(len(rl), 1), b_r)
        rl_pad = np.zeros(n_pad, dtype=np.int64)
        rl_pad[: len(rl)] = rl
        strip_nnz = rl_pad.reshape(-1, b_r).sum(axis=1)
        su = np.array(
            [_pad_to(max(-(-int(c) // b_r), 1), diag_align)
             for c in strip_nnz], dtype=np.int64)
        return int(su.sum()) * b_r
    raise ValueError(f"unknown format {fmt!r}")
