"""Krylov solvers on top of (distributed) spMVM.

The paper's motivation (§1.1): spMVM dominates sparse eigensolvers and
linear solvers, and "for most iterative spMVM algorithms such as Krylov
subspace methods, permutation of the indices needs to be done only before
the start and after the end of the algorithm".  These solvers are written
against an abstract ``matvec`` closure, so they run unchanged on:

* a single-device pJDS operator (``ops.pjds_matvec``), in the permuted
  basis end-to-end, or
* the distributed operator (``dist_spmv.make_dist_matvec``) over a mesh,
  with all vector arithmetic staying sharded (jnp elementwise ops and
  ``jnp.vdot`` lower to per-shard compute + all-reduce under pjit).

All loops are ``jax.lax.while_loop`` / ``fori_loop`` so the whole solve
is one compiled program (no host round-trips per iteration).

The BLOCK variants (``block_cg``, ``block_lanczos``) carry ``k`` vectors
at once through a multi-RHS operator (``ops.pjds_matmat`` /
``dist_spmv.make_dist_matmat``): the matrix is streamed from memory once
per iteration for all k systems, and in the distributed case the halo
exchange set-up cost is amortised the same way — the two levers the
SELL-C-sigma follow-up (arXiv:1307.6209) identifies for escaping the
spMVM memory roofline.  All k-by-k reductions (X^T Y) lower to per-shard
matmuls + all-reduce under pjit, so the block solvers stay fully sharded.
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["cg", "CGResult", "lanczos", "power_iteration",
           "block_cg", "BlockCGResult", "block_lanczos",
           "block_tridiag_eigvals"]

MatVec = Callable[[jax.Array], jax.Array]


class CGResult(NamedTuple):
    x: jax.Array
    iters: jax.Array
    residual: jax.Array


@functools.partial(jax.jit, static_argnums=(0, 3))
def cg(matvec: MatVec, b: jax.Array, x0: jax.Array | None = None,
       maxiter: int = 500, tol: float = 1e-6) -> CGResult:
    """Conjugate gradients for SPD A (classic, unpreconditioned)."""
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x)
    p = r
    rs = jnp.vdot(r, r)
    b2 = jnp.maximum(jnp.vdot(b, b), 1e-30)

    def cond(state):
        _, _, _, rs, k = state
        return (rs / b2 > tol ** 2) & (k < maxiter)

    def body(state):
        x, r, p, rs, k = state
        ap = matvec(p)
        alpha = rs / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        p = r + (rs_new / rs) * p
        return x, r, p, rs_new, k + 1

    x, r, p, rs, k = jax.lax.while_loop(cond, body, (x, r, p, rs, jnp.int32(0)))
    return CGResult(x=x, iters=k, residual=jnp.sqrt(rs / b2))


@functools.partial(jax.jit, static_argnums=(0, 2))
def lanczos(matvec: MatVec, v0: jax.Array, m: int = 50):
    """m-step Lanczos: returns (alphas, betas) of the tridiagonal T_m.
    Eigenvalues of T_m approximate extremal eigenvalues of symmetric A —
    the Holstein-Hubbard (HMEp) use case of the paper's group."""
    v = v0 / jnp.linalg.norm(v0)

    def body(carry, _):
        v_prev, v, beta = carry
        w = matvec(v) - beta * v_prev
        alpha = jnp.vdot(w, v)
        w = w - alpha * v
        # one step of full reorthogonalisation against the two known vectors
        w = w - jnp.vdot(w, v) * v
        beta_new = jnp.linalg.norm(w)
        v_new = w / jnp.maximum(beta_new, 1e-30)
        return (v, v_new, beta_new), (alpha, beta_new)

    (_, _, _), (alphas, betas) = jax.lax.scan(
        body, (jnp.zeros_like(v), v, jnp.asarray(0.0, v.dtype)), None, length=m
    )
    return alphas, betas


class BlockCGResult(NamedTuple):
    x: jax.Array          # (n, k)
    iters: jax.Array
    residual: jax.Array   # (k,) per-column relative residual


def _ridge_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """Solve the k-by-k system with a tiny trace-relative ridge so the
    block recurrences survive a column converging early (the Gram
    matrices go singular exactly when a residual column hits zero)."""
    k = a.shape[0]
    eps = jnp.asarray(jnp.finfo(a.dtype).eps, a.dtype)
    ridge = eps * (jnp.trace(a) / k) + jnp.asarray(1e-30, a.dtype)
    return jnp.linalg.solve(a + ridge * jnp.eye(k, dtype=a.dtype), b)


@functools.partial(jax.jit, static_argnums=(0, 3))
def block_cg(matvec: MatVec, b: jax.Array, x0: jax.Array | None = None,
             maxiter: int = 500, tol: float = 1e-6) -> BlockCGResult:
    """Block conjugate gradients (O'Leary 1980) for SPD A, k RHS at once.

    b: (n, k).  ``matvec`` must accept (n, k) — e.g. the multi-RHS
    distributed operator from ``dist_spmv.make_dist_matmat``.  Stops
    when EVERY column's relative residual is below ``tol``.
    """
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x)
    p = r
    rtr = r.T @ r                                     # (k, k)
    b2 = jnp.maximum(jnp.sum(b * b, axis=0), 1e-30)   # (k,)

    def cond(state):
        _, _, _, rtr, k_it = state
        res2 = jnp.diagonal(rtr) / b2
        return jnp.any(res2 > tol ** 2) & (k_it < maxiter)

    def body(state):
        x, r, p, rtr, k_it = state
        ap = matvec(p)
        alpha = _ridge_solve(p.T @ ap, rtr)           # (k, k)
        x = x + p @ alpha
        r = r - ap @ alpha
        rtr_new = r.T @ r
        beta = _ridge_solve(rtr, rtr_new)
        p = r + p @ beta
        return x, r, p, rtr_new, k_it + 1

    x, r, p, rtr, k_it = jax.lax.while_loop(
        cond, body, (x, r, p, rtr, jnp.int32(0)))
    return BlockCGResult(x=x, iters=k_it,
                         residual=jnp.sqrt(jnp.diagonal(rtr) / b2))


def _chol_qr(w: jax.Array):
    """CholeskyQR: W = Q R with Q^T Q = I via the k-by-k Gram matrix —
    only matmuls and a k-by-k factorization, so it stays sharded along n
    (a tall-skinny QR would gather W).  Returns (Q, R upper)."""
    k = w.shape[1]
    g = w.T @ w
    eps = jnp.asarray(jnp.finfo(g.dtype).eps, g.dtype)
    g = g + (eps * (jnp.trace(g) / k) + jnp.asarray(1e-30, g.dtype)) \
        * jnp.eye(k, dtype=g.dtype)
    l = jnp.linalg.cholesky(g)                        # G = L L^T
    # Q = W L^{-T}:  solve L Y = W^T, Q = Y^T
    q = jax.scipy.linalg.solve_triangular(l, w.T, lower=True).T
    return q, l.T


@functools.partial(jax.jit, static_argnums=(0, 2))
def block_lanczos(matvec: MatVec, v0: jax.Array, m: int = 25):
    """m-step block Lanczos for symmetric A with block size k = v0.shape[1].

    Returns (A_blocks (m, k, k), B_blocks (m, k, k)) of the block
    tridiagonal T_m:  A V_j = V_{j-1} B_{j-1}^T + V_j A_j + V_{j+1} B_j.
    Eigenvalues of T_m approximate extremal eigenvalues of A, converging
    faster per matrix pass than scalar Lanczos because every pass streams
    the matrix once for k directions (``block_tridiag_eigvals`` builds
    and solves T_m host-side)."""
    v, _ = _chol_qr(v0)
    k = v.shape[1]

    def body(carry, _):
        v_prev, v, b_prev = carry
        w = matvec(v) - v_prev @ b_prev.T
        a = v.T @ w
        w = w - v @ a
        # one full reorthogonalisation pass against the two known blocks
        w = w - v @ (v.T @ w) - v_prev @ (v_prev.T @ w)
        v_new, b = _chol_qr(w)
        return (v, v_new, b), (a, b)

    init = (jnp.zeros_like(v), v, jnp.zeros((k, k), v.dtype))
    _, (alphas, betas) = jax.lax.scan(body, init, None, length=m)
    return alphas, betas


def block_tridiag_eigvals(a_blocks, b_blocks):
    """Eigenvalues of the block-Lanczos block tridiagonal (host, numpy)."""
    import numpy as np
    a = np.asarray(a_blocks, dtype=np.float64)
    b = np.asarray(b_blocks, dtype=np.float64)
    m, k, _ = a.shape
    t = np.zeros((m * k, m * k))
    for j in range(m):
        s = slice(j * k, (j + 1) * k)
        t[s, s] = (a[j] + a[j].T) / 2
        if j + 1 < m:
            s1 = slice((j + 1) * k, (j + 2) * k)
            t[s1, s] = b[j]
            t[s, s1] = b[j].T
    return np.linalg.eigvalsh(t)


def tridiag_eigvals(alphas, betas):
    """Eigenvalues of the Lanczos tridiagonal (host-side, numpy)."""
    import numpy as np
    a = np.asarray(alphas, dtype=np.float64)
    b = np.asarray(betas, dtype=np.float64)[:-1]
    t = np.diag(a) + np.diag(b, 1) + np.diag(b, -1)
    return np.linalg.eigvalsh(t)


@functools.partial(jax.jit, static_argnums=(0, 2))
def power_iteration(matvec: MatVec, v0: jax.Array, iters: int = 100):
    """Dominant eigenpair via power iteration."""
    def body(v, _):
        w = matvec(v)
        lam = jnp.vdot(v, w)
        v_new = w / jnp.maximum(jnp.linalg.norm(w), 1e-30)
        return v_new, lam

    v, lams = jax.lax.scan(body, v0 / jnp.linalg.norm(v0), None, length=iters)
    return v, lams[-1]
