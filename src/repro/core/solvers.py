"""Krylov solvers on top of (distributed) spMVM.

The paper's motivation (§1.1): spMVM dominates sparse eigensolvers and
linear solvers, and "for most iterative spMVM algorithms such as Krylov
subspace methods, permutation of the indices needs to be done only before
the start and after the end of the algorithm".  These solvers are written
against an abstract ``matvec`` closure, so they run unchanged on:

* a single-device pJDS operator (``ops.pjds_matvec``), in the permuted
  basis end-to-end, or
* the distributed operator (``dist_spmv.make_dist_matvec``) over a mesh,
  with all vector arithmetic staying sharded (jnp elementwise ops and
  ``jnp.vdot`` lower to per-shard compute + all-reduce under pjit).

All loops are ``jax.lax.while_loop`` / ``fori_loop`` so the whole solve
is one compiled program (no host round-trips per iteration).
"""
from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp

__all__ = ["cg", "CGResult", "lanczos", "power_iteration"]

MatVec = Callable[[jax.Array], jax.Array]


class CGResult(NamedTuple):
    x: jax.Array
    iters: jax.Array
    residual: jax.Array


@functools.partial(jax.jit, static_argnums=(0, 3))
def cg(matvec: MatVec, b: jax.Array, x0: jax.Array | None = None,
       maxiter: int = 500, tol: float = 1e-6) -> CGResult:
    """Conjugate gradients for SPD A (classic, unpreconditioned)."""
    x = jnp.zeros_like(b) if x0 is None else x0
    r = b - matvec(x)
    p = r
    rs = jnp.vdot(r, r)
    b2 = jnp.maximum(jnp.vdot(b, b), 1e-30)

    def cond(state):
        _, _, _, rs, k = state
        return (rs / b2 > tol ** 2) & (k < maxiter)

    def body(state):
        x, r, p, rs, k = state
        ap = matvec(p)
        alpha = rs / jnp.vdot(p, ap)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        p = r + (rs_new / rs) * p
        return x, r, p, rs_new, k + 1

    x, r, p, rs, k = jax.lax.while_loop(cond, body, (x, r, p, rs, jnp.int32(0)))
    return CGResult(x=x, iters=k, residual=jnp.sqrt(rs / b2))


@functools.partial(jax.jit, static_argnums=(0, 2))
def lanczos(matvec: MatVec, v0: jax.Array, m: int = 50):
    """m-step Lanczos: returns (alphas, betas) of the tridiagonal T_m.
    Eigenvalues of T_m approximate extremal eigenvalues of symmetric A —
    the Holstein-Hubbard (HMEp) use case of the paper's group."""
    v = v0 / jnp.linalg.norm(v0)

    def body(carry, _):
        v_prev, v, beta = carry
        w = matvec(v) - beta * v_prev
        alpha = jnp.vdot(w, v)
        w = w - alpha * v
        # one step of full reorthogonalisation against the two known vectors
        w = w - jnp.vdot(w, v) * v
        beta_new = jnp.linalg.norm(w)
        v_new = w / jnp.maximum(beta_new, 1e-30)
        return (v, v_new, beta_new), (alpha, beta_new)

    (_, _, _), (alphas, betas) = jax.lax.scan(
        body, (jnp.zeros_like(v), v, jnp.asarray(0.0, v.dtype)), None, length=m
    )
    return alphas, betas


def tridiag_eigvals(alphas, betas):
    """Eigenvalues of the Lanczos tridiagonal (host-side, numpy)."""
    import numpy as np
    a = np.asarray(alphas, dtype=np.float64)
    b = np.asarray(betas, dtype=np.float64)[:-1]
    t = np.diag(a) + np.diag(b, 1) + np.diag(b, -1)
    return np.linalg.eigvalsh(t)


@functools.partial(jax.jit, static_argnums=(0, 2))
def power_iteration(matvec: MatVec, v0: jax.Array, iters: int = 100):
    """Dominant eigenpair via power iteration."""
    def body(v, _):
        w = matvec(v)
        lam = jnp.vdot(v, w)
        v_new = w / jnp.maximum(jnp.linalg.norm(w), 1e-30)
        return v_new, lam

    v, lams = jax.lax.scan(body, v0 / jnp.linalg.norm(v0), None, length=iters)
    return v, lams[-1]
