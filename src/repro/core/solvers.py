"""Krylov solvers on top of (distributed) spMVM.

The paper's motivation (§1.1): spMVM dominates sparse eigensolvers and
linear solvers, and "for most iterative spMVM algorithms such as Krylov
subspace methods, permutation of the indices needs to be done only before
the start and after the end of the algorithm".  Every solver here takes
``a`` as either a :class:`repro.core.operator.SparseOperator` or a bare
``matvec`` closure (``_matvec_of`` normalizes), so ONE solver source runs
unchanged on:

* a single-device operator (``operator(m)`` — any storage format, in the
  original basis), a hand-built matvec closure (e.g. the permuted-basis
  pJDS closures the older tests use), or
* the distributed operator (``dist_operator(m, mesh)``) over a mesh,
  with all vector arithmetic staying sharded (jnp elementwise ops and
  ``jnp.vdot`` lower to per-shard compute + all-reduce under pjit).

Every linear solver returns one :class:`SolveResult`; all options are
keyword-only.  ``cg``/``bicgstab`` take an optional preconditioner ``M``
(a callable ``z = M(r)`` or ``"jacobi"``, which reads ``a.diagonal()``
— see :func:`jacobi`).  Non-symmetric DUAL systems (``A^T y = c``) need
no new code at all: pass ``op.T`` — the operator protocol's lazy
transpose view — to any solver.  The user-facing front door is
``repro.solve`` (``repro.api``), which also owns operator construction,
solver-level tuning and mixed-precision refinement.

Two iteration strategies share each method's math:

* the COMPOSED bodies (``cg``/``bicgstab``) apply the operator and then
  reduce the dot products as separate HLO ops — correct everywhere, but
  each reduction is another pass over vectors the spMV just wrote;
* the FUSED bodies (``fused_cg``/``fused_bicgstab``) take a
  ``matvec_dots(v, w1, w2)`` closure (``kernels.fused_iter``) returning
  ``(Av, <Av,w1>, <Av,w2>, <Av,Av>, <w2,w2>, <w1,w2>)`` — the dots
  reduced in
  the spMV kernel's epilogue while y is still VMEM-resident — and carry
  every remaining scalar (BiCGStab's rho, the exit test's look-ahead
  norm) by algebraic recurrence, so the loop body contains NO
  standalone vector reduction.  Carriers live at the operand's padded
  length; ``x0`` is donated back to the solver.

:func:`iterative_refinement` layers mixed precision on top: an inner
solve against a bf16(+int16) operand, with the residual correction
``x += solve(A_lo, b - A_f32 x)`` computed against the full-precision
operator — storage at 0.50x bytes/nnz, accuracy at the f32 target.

All loops are ``jax.lax.while_loop`` / ``fori_loop`` so the whole solve
is one compiled program (no host round-trips per iteration).

The BLOCK variants (``block_cg``, ``block_lanczos``) carry ``k`` vectors
at once through a multi-RHS operator (the protocol's ``matmat``): the
matrix is streamed from memory once per iteration for all k systems, and
in the distributed case the halo exchange set-up cost is amortised the
same way — the two levers the SELL-C-sigma follow-up (arXiv:1307.6209)
identifies for escaping the spMVM memory roofline.  All k-by-k
reductions (X^T Y) lower to per-shard matmuls + all-reduce under pjit,
so the block solvers stay fully sharded.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Callable

import jax
import jax.numpy as jnp

__all__ = ["SolveResult", "STATUS_NAMES", "cg", "bicgstab", "block_cg",
           "fused_cg", "fused_bicgstab", "iterative_refinement",
           "jacobi", "lanczos", "power_iteration", "tridiag_eigvals",
           "block_lanczos", "block_tridiag_eigvals"]

MatVec = Callable[[jax.Array], jax.Array]
Operator = "SparseOperator | MatVec"     # accepted by every solver

# (Av, <Av,w1>, <Av,w2>, <Av,Av>, <w2,w2>, <w1,w2>) — kernels.fused_iter
MatVecDots = Callable[[jax.Array, jax.Array, jax.Array], tuple]


# Terminal status codes.  Inside the compiled loops the same integers
# serve as the failure FLAG carried through the while_loop state, with 0
# meaning "no failure observed yet"; ``_result`` resolves the final code
# (a flag of 0 becomes converged or maxiter depending on the residual).
STATUS_CONVERGED = 0
STATUS_MAXITER = 1
STATUS_BREAKDOWN = 2
STATUS_DIVERGED = 3
STATUS_NON_FINITE = 4
STATUS_NAMES = ("converged", "maxiter", "breakdown", "diverged",
                "non_finite")

# Failure-detection thresholds (active only when tol > 0 — the tuner's
# and benchmark's tol <= 0 fixed-length probes must run to maxiter
# untouched).  DIVERGED when the squared RELATIVE residual exceeds
# _DIVERGE_REL2 (relative residual 1e6 from a start of ~1).
# Stagnation — two consecutive _STAG_WINDOW checkpoints without a
# _STAG_RTOL relative improvement (see _health) — reports as BREAKDOWN
# (the recurrence has stopped making progress, e.g. a singular
# operator's residual floor).  Checkpointed progress, NOT a
# running-minimum window: ill-conditioned f32 CG is non-monotone
# enough to spend >1500 iterations above its starting residual while
# genuinely converging.
_DIVERGE_REL2 = 1e12
_STAG_WINDOW = 500
_STAG_RTOL = 0.01


@dataclasses.dataclass
class SolveResult:
    """The one result type every linear solver returns.

    ``x``/``iters``/``residual`` stay lazy jax arrays (no forced device
    sync); ``residual`` is the relative residual ||r||/||b|| the solver
    terminated on (per column, shape (k,), for ``block_cg``) and
    ``converged`` is ``all(residual <= tol)``.  ``status_code`` is the
    device-side termination code (see ``STATUS_NAMES``); reading the
    ``status`` string forces the sync.  ``diagnostics`` carries
    failure-path detail (certified true residual, restart counts,
    refinement stall reasons, degradation-ladder rungs).  ``info``
    carries strategy / per-phase timing / refinement diagnostics —
    populated by the solver (``strategy``) and extended by
    ``repro.solve`` (``phase_s``, ``tune``, ``refine``, ``ladder``).
    """

    x: jax.Array
    iters: jax.Array
    residual: jax.Array
    converged: jax.Array
    method: str = ""
    info: dict = dataclasses.field(default_factory=dict)
    status_code: jax.Array | int = 0
    diagnostics: dict = dataclasses.field(default_factory=dict)

    @property
    def status(self) -> str:
        """Termination status string — one of ``STATUS_NAMES``.  This
        forces the device sync (the code is a lazy array)."""
        return STATUS_NAMES[int(self.status_code)]


def _result(method: str, x, iters, residual, tol: float, *,
            flag=0, diagnostics=None, **info) -> SolveResult:
    res = jnp.asarray(residual)
    flag = jnp.asarray(flag, jnp.int32)
    ok = jnp.all(res <= tol)
    code = jnp.where(ok, STATUS_CONVERGED,
                     jnp.where(flag != 0, flag, STATUS_MAXITER))
    return SolveResult(x=x, iters=iters, residual=residual,
                       converged=ok, method=method, info=dict(info),
                       status_code=code,
                       diagnostics=dict(diagnostics or {}))


def _matvec_of(a) -> MatVec:
    """Normalize ``SparseOperator | MatVec`` to one apply callable.

    Operators dispatch 1-D carriers to ``matvec`` and 2-D blocks to
    ``matmat`` (the distributed operator shards the two differently);
    bare closures pass through untouched — the pre-protocol call sites
    keep working as shims.
    """
    mv = getattr(a, "matvec", None)
    if mv is None:
        return a
    # One closure PER OPERATOR, cached on the instance: the closure is
    # the jitted solvers' static cache key, so a fresh one per call
    # would retrace + recompile every solve.
    cached = getattr(a, "_solver_apply", None)
    if cached is not None:
        return cached
    mm = getattr(a, "matmat", None)

    def apply(x: jax.Array) -> jax.Array:
        return mv(x) if x.ndim == 1 else mm(x)

    try:
        a._solver_apply = apply
    except (AttributeError, TypeError):
        pass
    return apply


def jacobi(a) -> MatVec:
    """Jacobi (diagonal) preconditioner ``z = D^{-1} r`` from an
    operator's ``diagonal()``.  Zero diagonal entries (e.g. the padded
    tail of a distributed operator) pass through unscaled."""
    d = getattr(a, "diagonal", None)
    if d is None:
        raise TypeError(
            "jacobi needs a SparseOperator with .diagonal(); got "
            f"{type(a).__name__} — pass M as an explicit callable instead")
    cached = getattr(a, "_jacobi_precond", None)
    if cached is not None:       # stable closure == stable jit cache key
        return cached
    diag = d()
    inv = jnp.where(diag != 0, 1.0 / jnp.where(diag != 0, diag, 1), 1.0)
    inv = inv.astype(diag.dtype)

    def precond(r: jax.Array) -> jax.Array:
        return r * (inv if r.ndim == 1 else inv[:, None])

    try:
        a._jacobi_precond = precond
    except (AttributeError, TypeError):
        pass
    return precond


def _identity(r: jax.Array) -> jax.Array:
    """Module-level no-op preconditioner: a STABLE static jit key (a
    fresh lambda per call would recompile the solver every time)."""
    return r


def _not_done(res2, tol):
    """Loop-exit test on the squared relative residual.  ``tol <= 0``
    means "run to maxiter" — the tuner's and benchmark's fixed-length
    probes rely on this, since a converged f32 residual (or the fused
    look-ahead's clamp) can reach EXACTLY zero and would otherwise end
    the probe early.  A NON-FINITE ``res2`` exits the loop (for tol > 0)
    — but as a detected failure, not as convergence: the loop bodies
    flag it via :func:`_health` and the result reports
    ``status == "non_finite"``.  (``res2 > tol*tol`` alone is False for
    NaN, which used to end the loop with the failure masked.)"""
    return (tol <= 0.0) | (jnp.isfinite(res2) & (res2 > tol * tol))


def _health(flag, rel2, best, since, *, breakdown, check):
    """One failure-detection step shared by every solver loop body.

    ``rel2`` is the squared relative residual the body just produced;
    ``breakdown`` the body's method-specific breakdown predicate (CG
    ``p·Ap <= 0``, BiCGStab ``rho -> 0``, block-CG a non-finite /
    indefinite Gram step); ``check`` gates everything off for tol <= 0
    probe runs.  Returns the updated ``(flag, best, since)`` — ``flag``
    latches the FIRST failure observed (0 = healthy).

    Stagnation is judged at CHECKPOINTS, not against a running minimum:
    ``best`` holds the residual at the last checkpoint and ``since``
    the iterations since the last checkpoint that showed progress.
    Every ``_STAG_WINDOW`` iterations the current residual is compared
    against the previous checkpoint's; a relative improvement of at
    least ``_STAG_RTOL`` resets the clock, and only TWO consecutive
    no-progress checkpoints fire BREAKDOWN.  A running-minimum window
    false-positives on ill-conditioned CG, whose residual is
    non-monotone: measured on a cond~1e6 SPD system, the residual
    climbs to 7.6x its starting value and sets no new minimum for the
    first ~1500 of the 15000 iterations it genuinely needs — yet it
    IMPROVES between any two adjacent checkpoints on its way back
    down, which is exactly what this predicate measures.  A singular
    operator's residual floor is flat across checkpoints and still
    fires, one window later."""
    finite = jnp.isfinite(rel2)
    since = since + 1
    at_ckpt = (since % _STAG_WINDOW) == 0
    progressed = finite & (rel2 <= best * (1.0 - _STAG_RTOL))
    stalled = at_ckpt & ~progressed & (since >= 2 * _STAG_WINDOW)
    new = jnp.where(~finite, STATUS_NON_FINITE,
          jnp.where(breakdown, STATUS_BREAKDOWN,
          jnp.where(rel2 > _DIVERGE_REL2, STATUS_DIVERGED,
          jnp.where(stalled, STATUS_BREAKDOWN, 0))))
    new = jnp.where(check, new, 0).astype(jnp.int32)
    best = jnp.where(at_ckpt, rel2, best)
    since = jnp.where(at_ckpt & progressed, 0, since)
    return jnp.where(flag != 0, flag, new), best, since


def _nz(d):
    """Replace an exactly-zero denominator with a tiny value — keeps
    probe-mode (tol <= 0) carriers finite after a residual hits 0.0
    instead of spreading NaN through the remaining timed iterations."""
    return jnp.where(d == 0, jnp.asarray(1e-30, d.dtype), d)


def _precond_of(M, a) -> MatVec | None:
    if M is None:
        return None
    if M == "jacobi":
        return jacobi(a)
    if callable(M):
        return M
    raise TypeError(f"M must be None, 'jacobi' or a callable; got {M!r}")


def cg(a: Operator, b: jax.Array, *, x0: jax.Array | None = None,
       maxiter: int = 500, tol: float = 1e-6, M=None) -> SolveResult:
    """(Preconditioned) conjugate gradients for SPD A.

    ``a``: SparseOperator or matvec closure.  ``M``: optional
    preconditioner — ``"jacobi"`` (diagonal, from ``a.diagonal()``) or a
    callable ``z = M(r)`` approximating ``A^{-1} r``.  Convergence is
    checked on the TRUE residual ||r|| / ||b||, so results with and
    without M are directly comparable.
    """
    matvec = _matvec_of(a)
    pre = _precond_of(M, a)
    x0 = jnp.zeros_like(b) if x0 is None else x0
    if pre is None:
        x, k, res, flag = _cg(matvec, b, x0, maxiter, tol)
    else:
        x, k, res, flag = _pcg(matvec, pre, b, x0, maxiter, tol)
    return _result("cg", x, k, res, tol, flag=flag, strategy="composed")


def _health_init(rel2, tol):
    """Initial (flag, best, since) carriers: a non-finite INITIAL
    residual (poisoned b / x0 / values) is flagged before the loop
    ever runs a body."""
    check = tol > 0.0
    flag = jnp.where(check & ~jnp.isfinite(rel2),
                     STATUS_NON_FINITE, 0).astype(jnp.int32)
    best = jnp.where(jnp.isfinite(rel2), rel2, jnp.inf)
    return flag, jnp.asarray(best, jnp.asarray(rel2).dtype), jnp.int32(0)


@functools.partial(jax.jit, static_argnums=(0, 3))
def _cg(matvec: MatVec, b: jax.Array, x0: jax.Array,
        maxiter: int = 500, tol: float = 1e-6):
    x = x0
    r = b - matvec(x)
    p = r
    rs = jnp.vdot(r, r)
    b2 = jnp.maximum(jnp.vdot(b, b), 1e-30)
    check = tol > 0.0
    flag, best, since = _health_init(rs / b2, tol)

    def cond(state):
        _, _, _, rs, k, flag, _, _ = state
        return (flag == 0) & _not_done(rs / b2, tol) & (k < maxiter)

    def body(state):
        x, r, p, rs, k, flag, best, since = state
        ap = matvec(p)
        pap = jnp.vdot(p, ap)
        # p·Ap <= 0 => A is not SPD along p: CG breakdown.  Zero the
        # step so x/r stay at the last healthy iterate (the select
        # fuses into the axpy — no extra memory pass).
        bad = check & ((pap <= 0.0) | ~jnp.isfinite(pap))
        alpha = jnp.where(bad, 0.0, rs / _nz(pap))
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        flag, best, since = _health(flag, rs_new / b2, best, since,
                                    breakdown=bad, check=check)
        p = r + (rs_new / _nz(rs)) * p
        return x, r, p, rs_new, k + 1, flag, best, since

    x, r, p, rs, k, flag, best, since = jax.lax.while_loop(
        cond, body, (x, r, p, rs, jnp.int32(0), flag, best, since))
    return x, k, jnp.sqrt(rs / b2), flag


@functools.partial(jax.jit, static_argnums=(0, 1, 4))
def _pcg(matvec: MatVec, precond: MatVec, b: jax.Array, x0: jax.Array,
         maxiter: int = 500, tol: float = 1e-6):
    """Preconditioned CG: same recurrence with z = M r directions."""
    x = x0
    r = b - matvec(x)
    z = precond(r)
    p = z
    rz = jnp.vdot(r, z)
    rs = jnp.vdot(r, r)
    b2 = jnp.maximum(jnp.vdot(b, b), 1e-30)
    check = tol > 0.0
    flag, best, since = _health_init(rs / b2, tol)

    def cond(state):
        _, _, _, _, rs, k, flag, _, _ = state
        return (flag == 0) & _not_done(rs / b2, tol) & (k < maxiter)

    def body(state):
        x, r, p, rz, rs, k, flag, best, since = state
        ap = matvec(p)
        pap = jnp.vdot(p, ap)
        bad = check & ((pap <= 0.0) | ~jnp.isfinite(pap))
        alpha = jnp.where(bad, 0.0, rz / _nz(pap))
        x = x + alpha * p
        r = r - alpha * ap
        z = precond(r)
        rz_new = jnp.vdot(r, z)
        rs_new = jnp.vdot(r, r)
        flag, best, since = _health(flag, rs_new / b2, best, since,
                                    breakdown=bad, check=check)
        p = z + (rz_new / _nz(rz)) * p
        return x, r, p, rz_new, rs_new, k + 1, flag, best, since

    x, r, p, rz, rs, k, flag, best, since = jax.lax.while_loop(
        cond, body, (x, r, p, rz, rs, jnp.int32(0), flag, best, since))
    return x, k, jnp.sqrt(rs / b2), flag


def bicgstab(a: Operator, b: jax.Array, *, x0: jax.Array | None = None,
             maxiter: int = 1000, tol: float = 1e-6,
             M=None) -> SolveResult:
    """BiCGStab (van der Vorst 1992) for general (non-symmetric) A.

    Transpose-free: the recurrence itself never applies ``A^T`` — but
    the DUAL system ``A^T y = c`` is solved by simply passing ``op.T``
    (the protocol's lazy transpose view) as ``a``.  ``M`` as in
    :func:`cg` (right preconditioning: A M z-directions).
    """
    matvec = _matvec_of(a)
    pre = _precond_of(M, a) or _identity
    x0 = jnp.zeros_like(b) if x0 is None else x0
    x, k, res, flag = _bicgstab(matvec, pre, b, x0, maxiter, tol)
    return _result("bicgstab", x, k, res, tol, flag=flag,
                   strategy="composed")


@functools.partial(jax.jit, static_argnums=(0, 1, 4))
def _bicgstab(matvec: MatVec, precond: MatVec, b: jax.Array, x0: jax.Array,
              maxiter: int = 1000, tol: float = 1e-6):
    dt = b.dtype
    tiny = jnp.asarray(1e-30, dt)

    def _safe(d):
        return jnp.where(jnp.abs(d) > tiny, d, tiny)

    x = x0
    r = b - matvec(x)
    rhat = r                       # shadow residual, fixed
    one = jnp.asarray(1.0, dt)
    b2 = jnp.maximum(jnp.vdot(b, b), 1e-30)
    check = tol > 0.0
    flag, best, since = _health_init(jnp.vdot(r, r) / b2, tol)
    state = (x, r, jnp.zeros_like(b), jnp.zeros_like(b),
             one, one, one, jnp.vdot(r, r), jnp.int32(0),
             flag, best, since)

    def cond(state):
        rs, k, flag = state[7], state[8], state[9]
        return (flag == 0) & _not_done(rs / b2, tol) & (k < maxiter)

    def body(state):
        x, r, p, v, rho, alpha, omega, _rs, k, flag, best, since = state
        rho_new = jnp.vdot(rhat, r)
        beta = (rho_new / _safe(rho)) * (alpha / _safe(omega))
        p = r + beta * (p - omega * v)
        p_hat = precond(p)
        v = matvec(p_hat)
        rhat_v = jnp.vdot(rhat, v)
        alpha = rho_new / _safe(rhat_v)
        s = r - alpha * v
        s_hat = precond(s)
        t = matvec(s_hat)
        tt = jnp.vdot(t, t)
        omega = jnp.vdot(t, s) / _safe(tt)
        x = x + alpha * p_hat + omega * s_hat
        r = s - omega * t
        rs_new = jnp.vdot(r, r)
        # rho -> 0 (serious breakdown: r orthogonal to the shadow
        # residual) or a vanishing <rhat, Ap> / <t, t> — the _safe
        # clamps keep the carriers finite, the flag makes it a typed
        # failure instead of silent garbage.
        bad = ((jnp.abs(rho_new) <= tiny) | (jnp.abs(rhat_v) <= tiny)
               | (jnp.abs(tt) <= tiny))
        flag, best, since = _health(flag, rs_new / b2, best, since,
                                    breakdown=bad, check=check)
        return (x, r, p, v, rho_new, alpha, omega, rs_new, k + 1,
                flag, best, since)

    out = jax.lax.while_loop(cond, body, state)
    x, rs, k, flag = out[0], out[7], out[8], out[9]
    return x, k, jnp.sqrt(rs / b2), flag


# --------------------------------------------------------------------------
# Fused-iteration solvers (spMV + dots in one kernel pass)
# --------------------------------------------------------------------------
def fused_cg(matvec_dots: MatVecDots, b: jax.Array, *,
             x0: jax.Array | None = None, maxiter: int = 500,
             tol: float = 1e-6) -> SolveResult:
    """CG whose loop body is ONE fused spMV+dots pass and three axpys.

    ``matvec_dots`` is the closure ``kernels.fused_iter.make_matvec_dots``
    builds over a SELL operand (build it once — it is the static jit
    key).  Each pass ``matvec_dots(p, p, r)`` returns Ap together with
    <Ap,p>, <Ap,r>, <Ap,Ap> and the EXACT <r,r> (the epilogue's free
    self-dot of the w2 slab), so alpha and beta use an exact residual
    norm every iteration; only the exit test's one-step look-ahead

        <r',r'> = <r,r> - 2 alpha <Ap,r> + alpha^2 <Ap,Ap>

    is a recurrence (clamped at 0).  The host driver then certifies the
    TRUE residual ``||b - Ax||/||b||`` with one composed pass and warm-
    restarts if the look-ahead exited optimistically — the reported
    residual/converged are always honest.  Carriers live at the
    operand's padded length (pad rows stay exactly zero through every
    recurrence); ``x0`` is donated to the solve.  Unpreconditioned (the
    fused epilogue reduces plain dots; ``repro.solve`` falls back to the
    composed body when a preconditioner is requested).
    """
    return _fused_drive(_fused_cg, "cg", matvec_dots, b, x0, maxiter, tol)


def fused_bicgstab(matvec_dots: MatVecDots, b: jax.Array, *,
                   x0: jax.Array | None = None, maxiter: int = 1000,
                   tol: float = 1e-6) -> SolveResult:
    """BiCGStab over the fused spMV+dots pass (two per iteration).

    Every scalar the composed body reduces separately arrives fused:
    pass one, ``matvec_dots(p, rhat, r)``, yields v = Ap with <v,rhat>,
    <v,r>, <v,v> and the exact ||r||^2; pass two,
    ``matvec_dots(s, rhat, s)``, yields t = As with <t,rhat>, <t,s>,
    <t,t>, the exact ||s||^2 AND the exact <rhat,s> (the epilogue's
    w1·w2 cross-dot).  The two scalars with no direct dot follow:

        rho_{k+1} = <rhat, r'> = <rhat,s> - omega <t, rhat>,
        ||r'||^2  = ||s||^2 - 2 omega <t,s> + omega^2 <t,t>,

    the latter only as the exit test's one-step look-ahead.  rho uses
    the measured <rhat,s>, NOT the textbook simplification <rhat,s> = 0
    — exact in exact arithmetic, but its f32 drift stalls the rho
    recurrence on matrices where composed BiCGStab converges fine.
    Same host restart driver and carrier/donation contract as
    :func:`fused_cg`.
    """
    return _fused_drive(_fused_bicgstab, "bicgstab", matvec_dots, b, x0,
                        maxiter, tol)


def _fused_drive(loop_fn, method: str, matvec_dots: MatVecDots,
                 b: jax.Array, x0, maxiter: int, tol: float) -> SolveResult:
    """Host driver shared by the fused solvers: run the compiled loop,
    certify the true residual with one composed pass, warm-restart while
    it still improves.  At most a handful of host syncs per SOLVE —
    versus one per iteration for a scipy-style stepped loop.

    The certification is the ARBITER: a loop that exits claiming
    convergence (its look-ahead recurrence under tol) whose certified
    TRUE residual stays above tol is demoted to ``status="diverged"``
    with the evidence in ``diagnostics`` — never returned as converged.
    """
    x = jnp.zeros_like(b) if x0 is None else x0
    total, restarts = 0, 0
    rn_prev = float("inf")
    flag, demoted = 0, False
    while True:
        x, k, _, lflag = loop_fn(matvec_dots, b, x, maxiter - total, tol)
        total += int(k)
        flag = int(lflag)
        rn = float(_true_residual(matvec_dots, b, x))
        if not math.isfinite(rn):
            flag = flag or STATUS_NON_FINITE
            break
        if (tol > 0 and rn <= tol) or flag != 0 or total >= maxiter:
            break
        if int(k) == 0 or rn >= rn_prev:
            # the look-ahead claimed convergence (or a restart made no
            # progress) but the certified residual disagrees — demote
            demoted = tol > 0
            break
        rn_prev = rn
        restarts += 1
    if demoted and flag == 0:
        flag = STATUS_DIVERGED
    diagnostics = {"true_residual": rn, "restarts": restarts,
                   "certified": bool(math.isfinite(rn) and tol > 0
                                     and rn <= tol)}
    if demoted:
        diagnostics["demoted"] = True
    return _result(method, x, total, rn, tol, flag=flag,
                   diagnostics=diagnostics,
                   strategy="fused", restarts=restarts)


@functools.partial(jax.jit, static_argnums=(0,))
def _true_residual(matvec_dots: MatVecDots, b: jax.Array, x: jax.Array):
    r = b - matvec_dots(x, x, x)[0]
    return jnp.sqrt(jnp.vdot(r, r) / jnp.maximum(jnp.vdot(b, b), 1e-30))


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def _fused_cg(matvec_dots: MatVecDots, b: jax.Array, x0: jax.Array,
              maxiter, tol):
    r = b - matvec_dots(x0, x0, b)[0]
    rs = jnp.vdot(r, r)            # exact, once per (re)start
    b2 = jnp.maximum(jnp.vdot(b, b), 1e-30)
    check = tol > 0.0
    flag, best, since = _health_init(rs / b2, tol)

    def cond(state):
        _, _, _, rs, k, flag, _, _ = state
        return (flag == 0) & _not_done(rs / b2, tol) & (k < maxiter)

    def body(state):
        x, r, p, _rs, k, flag, best, since = state
        ap, pap, r_ap, apap, rr, _ = matvec_dots(p, p, r)  # rr exact
        bad = check & ((pap <= 0.0) | ~jnp.isfinite(pap))
        alpha = jnp.where(bad, 0.0, rr / _nz(pap))
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.maximum(rr - 2.0 * alpha * r_ap + alpha * alpha * apap,
                             0.0)
        flag, best, since = _health(flag, rs_new / b2, best, since,
                                    breakdown=bad, check=check)
        p = r + (rs_new / jnp.maximum(rr, 1e-30)) * p
        return x, r, p, rs_new, k + 1, flag, best, since

    x, r, p, rs, k, flag, best, since = jax.lax.while_loop(
        cond, body, (x0, r, r, rs, jnp.int32(0), flag, best, since))
    return x, k, jnp.sqrt(rs / b2), flag


@functools.partial(jax.jit, static_argnums=(0,), donate_argnums=(2,))
def _fused_bicgstab(matvec_dots: MatVecDots, b: jax.Array, x0: jax.Array,
                    maxiter, tol):
    dt = b.dtype
    tiny = jnp.asarray(1e-30, dt)

    def _safe(d):
        return jnp.where(jnp.abs(d) > tiny, d, tiny)

    r = b - matvec_dots(x0, x0, b)[0]
    rhat = r                       # shadow residual, fixed
    rs0 = jnp.vdot(r, r)           # exact, once per (re)start
    b2 = jnp.maximum(jnp.vdot(b, b), 1e-30)
    one = jnp.asarray(1.0, dt)
    check = tol > 0.0
    flag, best, since = _health_init(rs0 / b2, tol)
    # state: (x, r, p, v, rho, rho_prev, alpha, omega, rs, k, health);
    # rho_1 = <rhat, r0> = ||r0||^2 and rho_0 := rho_1 so the first
    # beta is (rho_1/rho_0)(alpha/omega) = 1 and p_1 = r0 (v = p = 0).
    state = (x0, r, jnp.zeros_like(b), jnp.zeros_like(b),
             rs0, rs0, one, one, rs0, jnp.int32(0), flag, best, since)

    def cond(state):
        rs, k, flag = state[8], state[9], state[10]
        return (flag == 0) & _not_done(rs / b2, tol) & (k < maxiter)

    def body(state):
        (x, r, p, v, rho, rho_prev, alpha, omega, rs, k,
         flag, best, since) = state
        beta = (rho / _safe(rho_prev)) * (alpha / _safe(omega))
        p = r + beta * (p - omega * v)
        v, rhat_v, _r_v, _vv, _rr, _ = matvec_dots(p, rhat, r)
        alpha = rho / _safe(rhat_v)
        s = r - alpha * v
        # rhat_s = <rhat, s> EXACT from the epilogue cross-dot — the
        # textbook pipelined recurrence assumes it zero, and its f32
        # drift stalls the rho recurrence (stagnation at ~1e-5)
        t, t_rhat, t_s, tt, ss, rhat_s = matvec_dots(s, rhat, s)
        omega = t_s / _safe(tt)
        x = x + alpha * p + omega * s
        r = s - omega * t
        rs_new = jnp.maximum(ss - 2.0 * omega * t_s + omega * omega * tt, 0.0)
        rho_next = rhat_s - omega * t_rhat
        bad = ((jnp.abs(rho) <= tiny) | (jnp.abs(rhat_v) <= tiny)
               | (jnp.abs(tt) <= tiny))
        flag, best, since = _health(flag, rs_new / b2, best, since,
                                    breakdown=bad, check=check)
        return (x, r, p, v, rho_next, rho, alpha, omega, rs_new, k + 1,
                flag, best, since)

    out = jax.lax.while_loop(cond, body, state)
    x, rs, k, flag = out[0], out[8], out[9], out[10]
    return x, k, jnp.sqrt(rs / b2), flag


# --------------------------------------------------------------------------
# Mixed-precision iterative refinement
# --------------------------------------------------------------------------
def iterative_refinement(residual_of: MatVec, inner_solve, b: jax.Array, *,
                         x0: jax.Array | None = None, tol: float = 1e-6,
                         max_rounds: int = 10):
    """Outer f32 correction loop over a low-precision inner solve.

    ``residual_of(x) -> b - A x`` MUST apply the FULL-precision
    operator; ``inner_solve(r) -> (dx, iters, inner_residual)`` solves
    ``A dx = r`` against the low-precision (bf16+int16) operand to its
    own looser tolerance.  Classic iterative refinement: each round the
    true f32 residual is re-measured and the correction added, so the
    bf16 storage only ever limits CONVERGENCE RATE, never the final
    accuracy — rounds stop at ``tol`` on the true relative residual, on
    ``max_rounds``, or when a round fails to reduce the residual
    (divergent inner operand — e.g. a matrix too ill-conditioned for
    bf16 values).

    Host-driven by design: a handful of rounds, each a full compiled
    inner solve, with per-round diagnostics the caller can report.
    Returns ``(x, rel_residual, rounds, reason)`` where ``rounds`` is
    one dict per correction (inner iteration count, residual entering
    the round) and ``reason`` names why the outer loop stopped:
    ``"converged"``, ``"max_rounds"``, ``"stalled"`` (a round failed to
    reduce the true residual — the divergence guard; the caller should
    escalate to a full-precision solve instead of burning more rounds)
    or ``"non_finite"`` (a poisoned operand/correction).
    """
    bn = max(float(jnp.linalg.norm(b)), 1e-30)
    x = jnp.zeros_like(b) if x0 is None else x0
    rounds = []
    rn_prev = float("inf")
    while True:
        r = residual_of(x)
        rn = float(jnp.linalg.norm(r)) / bn
        if not math.isfinite(rn):
            reason = "non_finite"
            break
        if rn <= tol:
            reason = "converged"
            break
        if len(rounds) >= max_rounds:
            reason = "max_rounds"
            break
        if rn >= rn_prev:
            reason = "stalled"
            break
        dx, iters, inner_res = inner_solve(r)
        x = x + dx.astype(x.dtype)
        rounds.append({"residual_in": rn, "inner_iters": int(iters),
                       "inner_residual": float(inner_res)})
        rn_prev = rn
    return x, rn, rounds, reason


def lanczos(a: Operator, v0: jax.Array, m: int = 50):
    """m-step Lanczos: returns (alphas, betas) of the tridiagonal T_m.
    Eigenvalues of T_m approximate extremal eigenvalues of symmetric A —
    the Holstein-Hubbard (HMEp) use case of the paper's group."""
    return _lanczos(_matvec_of(a), v0, m)


@functools.partial(jax.jit, static_argnums=(0, 2))
def _lanczos(matvec: MatVec, v0: jax.Array, m: int = 50):
    v = v0 / jnp.linalg.norm(v0)

    def body(carry, _):
        v_prev, v, beta = carry
        w = matvec(v) - beta * v_prev
        alpha = jnp.vdot(w, v)
        w = w - alpha * v
        # one step of full reorthogonalisation against the two known vectors
        w = w - jnp.vdot(w, v) * v
        beta_new = jnp.linalg.norm(w)
        v_new = w / jnp.maximum(beta_new, 1e-30)
        return (v, v_new, beta_new), (alpha, beta_new)

    (_, _, _), (alphas, betas) = jax.lax.scan(
        body, (jnp.zeros_like(v), v, jnp.asarray(0.0, v.dtype)), None, length=m
    )
    return alphas, betas


def _ridge(a: jax.Array) -> jax.Array:
    """Tiny trace-relative ridge for the k-by-k Gram systems — shared by
    block-CG and CholeskyQR so the two regularize identically."""
    k = a.shape[0]
    eps = jnp.asarray(jnp.finfo(a.dtype).eps, a.dtype)
    scale = eps * (jnp.trace(a) / k) + jnp.asarray(1e-30, a.dtype)
    return scale * jnp.eye(k, dtype=a.dtype)


def _ridge_solve(a: jax.Array, b: jax.Array) -> jax.Array:
    """Solve the k-by-k system with a tiny trace-relative ridge so the
    block recurrences survive a column converging early (the Gram
    matrices go singular exactly when a residual column hits zero)."""
    return jnp.linalg.solve(a + _ridge(a), b)


def block_cg(a: Operator, b: jax.Array, *, x0: jax.Array | None = None,
             maxiter: int = 500, tol: float = 1e-6) -> SolveResult:
    """Block conjugate gradients (O'Leary 1980) for SPD A, k RHS at once.

    b: (n, k).  ``a``: SparseOperator (its ``matmat`` runs the k systems
    per matrix stream) or a closure accepting (n, k).  Stops when EVERY
    column's relative residual is below ``tol``; ``result.residual`` is
    the per-column vector, ``result.converged`` requires all columns.
    """
    x, k_it, res, flag = _block_cg(_matvec_of(a), b,
                                   jnp.zeros_like(b) if x0 is None else x0,
                                   maxiter, tol)
    return _result("block_cg", x, k_it, res, tol, flag=flag,
                   strategy="composed")


@functools.partial(jax.jit, static_argnums=(0, 3))
def _block_cg(matvec: MatVec, b: jax.Array, x0: jax.Array,
              maxiter: int = 500, tol: float = 1e-6):
    x = x0
    r = b - matvec(x)
    p = r
    rtr = r.T @ r                                     # (k, k)
    b2 = jnp.maximum(jnp.sum(b * b, axis=0), 1e-30)   # (k,)
    check = tol > 0.0
    flag, best, since = _health_init(
        jnp.max(jnp.diagonal(rtr) / b2), tol)

    def cond(state):
        _, _, _, rtr, k_it, flag, _, _ = state
        res2 = jnp.diagonal(rtr) / b2
        return ((flag == 0) & jnp.any(_not_done(res2, tol))
                & (k_it < maxiter))

    def body(state):
        x, r, p, rtr, k_it, flag, best, since = state
        ap = matvec(p)
        ptap = p.T @ ap
        alpha = _ridge_solve(ptap, rtr)               # (k, k)
        # A direction with p_j·Ap_j <= 0 (indefinite A) or a Gram solve
        # gone non-finite (the k-by-k factorization failing on a
        # poisoned/singular block) is a block breakdown: zero the step
        # so x/r hold the last healthy iterate.  Columns already under
        # tol are exempt — their directions legitimately shrink to 0.
        live = jnp.diagonal(rtr) / b2 > tol * tol
        bad = check & (jnp.any(live & (jnp.diagonal(ptap) <= 0.0))
                       | ~jnp.all(jnp.isfinite(alpha)))
        alpha = jnp.where(bad, jnp.zeros_like(alpha), alpha)
        x = x + p @ alpha
        r = r - ap @ alpha
        rtr_new = r.T @ r
        flag, best, since = _health(
            flag, jnp.max(jnp.diagonal(rtr_new) / b2), best, since,
            breakdown=bad, check=check)
        beta = _ridge_solve(rtr, rtr_new)
        p = r + p @ beta
        return x, r, p, rtr_new, k_it + 1, flag, best, since

    x, r, p, rtr, k_it, flag, best, since = jax.lax.while_loop(
        cond, body, (x, r, p, rtr, jnp.int32(0), flag, best, since))
    return x, k_it, jnp.sqrt(jnp.diagonal(rtr) / b2), flag


def _chol_qr(w: jax.Array):
    """CholeskyQR: W = Q R with Q^T Q = I via the k-by-k Gram matrix —
    only matmuls and a k-by-k factorization, so it stays sharded along n
    (a tall-skinny QR would gather W).  Returns (Q, R upper)."""
    g = w.T @ w
    g = g + _ridge(g)
    l = jnp.linalg.cholesky(g)                        # G = L L^T
    # Q = W L^{-T}:  solve L Y = W^T, Q = Y^T
    q = jax.scipy.linalg.solve_triangular(l, w.T, lower=True).T
    return q, l.T


def block_lanczos(a: Operator, v0: jax.Array, m: int = 25):
    """m-step block Lanczos for symmetric A with block size k = v0.shape[1].

    Returns (A_blocks (m, k, k), B_blocks (m, k, k)) of the block
    tridiagonal T_m:  A V_j = V_{j-1} B_{j-1}^T + V_j A_j + V_{j+1} B_j.
    Eigenvalues of T_m approximate extremal eigenvalues of A, converging
    faster per matrix pass than scalar Lanczos because every pass streams
    the matrix once for k directions (``block_tridiag_eigvals`` builds
    and solves T_m host-side)."""
    return _block_lanczos(_matvec_of(a), v0, m)


@functools.partial(jax.jit, static_argnums=(0, 2))
def _block_lanczos(matvec: MatVec, v0: jax.Array, m: int = 25):
    v, _ = _chol_qr(v0)
    k = v.shape[1]

    def body(carry, _):
        v_prev, v, b_prev = carry
        w = matvec(v) - v_prev @ b_prev.T
        a = v.T @ w
        w = w - v @ a
        # one full reorthogonalisation pass against the two known blocks
        w = w - v @ (v.T @ w) - v_prev @ (v_prev.T @ w)
        v_new, b = _chol_qr(w)
        return (v, v_new, b), (a, b)

    init = (jnp.zeros_like(v), v, jnp.zeros((k, k), v.dtype))
    _, (alphas, betas) = jax.lax.scan(body, init, None, length=m)
    return alphas, betas


def block_tridiag_eigvals(a_blocks, b_blocks):
    """Eigenvalues of the block-Lanczos block tridiagonal (host, numpy)."""
    import numpy as np
    a = np.asarray(a_blocks, dtype=np.float64)
    b = np.asarray(b_blocks, dtype=np.float64)
    m, k, _ = a.shape
    t = np.zeros((m * k, m * k))
    for j in range(m):
        s = slice(j * k, (j + 1) * k)
        t[s, s] = (a[j] + a[j].T) / 2
        if j + 1 < m:
            s1 = slice((j + 1) * k, (j + 2) * k)
            t[s1, s] = b[j]
            t[s, s1] = b[j].T
    return np.linalg.eigvalsh(t)


def tridiag_eigvals(alphas, betas):
    """Eigenvalues of the Lanczos tridiagonal (host-side, numpy)."""
    import numpy as np
    a = np.asarray(alphas, dtype=np.float64)
    b = np.asarray(betas, dtype=np.float64)[:-1]
    t = np.diag(a) + np.diag(b, 1) + np.diag(b, -1)
    return np.linalg.eigvalsh(t)


def power_iteration(a: Operator, v0: jax.Array, iters: int = 100):
    """Dominant eigenpair via power iteration."""
    return _power_iteration(_matvec_of(a), v0, iters)


@functools.partial(jax.jit, static_argnums=(0, 2))
def _power_iteration(matvec: MatVec, v0: jax.Array, iters: int = 100):
    def body(v, _):
        w = matvec(v)
        lam = jnp.vdot(v, w)
        v_new = w / jnp.maximum(jnp.linalg.norm(w), 1e-30)
        return v_new, lam

    v, lams = jax.lax.scan(body, v0 / jnp.linalg.norm(v0), None, length=iters)
    return v, lams[-1]
