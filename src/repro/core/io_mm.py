"""Dependency-free Matrix Market (``.mtx``) ingestion and export.

The NIST Matrix Market exchange format is the lingua franca of sparse
test collections (SuiteSparse, the matrices of the source paper's
benchmark set), so the ecosystem layer reads and writes it natively —
no scipy required.  Supported header space:

* ``coordinate`` (sparse triplets, 1-based) and ``array`` (dense,
  column-major) formats;
* ``real`` / ``integer`` / ``pattern`` value fields (``pattern``
  entries load as 1.0; ``complex`` / ``hermitian`` are rejected with a
  clear error rather than silently mangled);
* ``general`` / ``symmetric`` / ``skew-symmetric`` symmetries — the
  stored lower triangle is expanded on load (skew off-diagonals with
  the sign flip, and an explicitly stored nonzero skew diagonal is
  rejected as malformed).

Every load funnels through :func:`formats.validate_csr` before the
matrix enters the pipeline — files from the wild carry duplicates,
unsorted triplets and out-of-range indices, and the admission layer is
where those die (``validate="repair"`` sums/drops/sorts,
``"strict"`` raises, ``"off"`` trusts the file).  Duplicate triplets
are summed by the CSR build itself (the Matrix Market convention).

The writer emits value formats wide enough to round-trip the dtype
losslessly through decimal (9 significant digits for f32, 17 for f64),
so ``save_mm`` → ``load_mm`` is bit-exact; ``symmetry="auto"``
detects symmetric / skew-symmetric square matrices and stores only the
lower triangle, halving the file like the reference collections do.
"""
from __future__ import annotations

import io
import os
from typing import Optional, TextIO, Tuple, Union

import numpy as np

from repro.core import formats as F

__all__ = ["load_mm", "save_mm", "read_mm", "write_mm", "MMHeader",
           "MatrixMarketError"]

_FORMATS = ("coordinate", "array")
_FIELDS = ("real", "integer", "pattern")
_SYMMETRIES = ("general", "symmetric", "skew-symmetric")


class MatrixMarketError(ValueError):
    """Malformed or unsupported Matrix Market content."""


class MMHeader:
    """Parsed banner + size line of a Matrix Market file."""

    def __init__(self, format: str, field: str, symmetry: str,
                 shape: Tuple[int, int], nnz: Optional[int]):
        self.format = format
        self.field = field
        self.symmetry = symmetry
        self.shape = shape
        self.nnz = nnz          # None for array format

    def __repr__(self):
        return (f"MMHeader({self.format}, {self.field}, {self.symmetry}, "
                f"shape={self.shape}, nnz={self.nnz})")


def _parse_banner(line: str) -> Tuple[str, str, str]:
    parts = line.strip().lower().split()
    if len(parts) != 5 or parts[0] != "%%matrixmarket" or parts[1] != "matrix":
        raise MatrixMarketError(
            f"not a Matrix Market file: bad banner {line.strip()!r}")
    fmt, field, sym = parts[2], parts[3], parts[4]
    if fmt not in _FORMATS:
        raise MatrixMarketError(f"unsupported format {fmt!r} "
                                f"(supported: {_FORMATS})")
    if field not in _FIELDS:
        raise MatrixMarketError(f"unsupported field {field!r} "
                                f"(supported: {_FIELDS})")
    if sym not in _SYMMETRIES:
        raise MatrixMarketError(f"unsupported symmetry {sym!r} "
                                f"(supported: {_SYMMETRIES})")
    return fmt, field, sym


def _data_lines(f: TextIO):
    for line in f:
        s = line.strip()
        if not s or s.startswith("%"):
            continue
        yield s


def read_mm(f: TextIO) -> Tuple[MMHeader, np.ndarray, np.ndarray, np.ndarray]:
    """Parse an open text stream into ``(header, rows, cols, vals)`` COO
    triplets (0-based, symmetry EXPANDED, duplicates NOT summed — the
    CSR build owns deduplication).  Low-level; most callers want
    :func:`load_mm`."""
    banner = f.readline()
    fmt, field, sym = _parse_banner(banner)
    lines = _data_lines(f)
    try:
        size = next(lines)
    except StopIteration:
        raise MatrixMarketError("missing size line")
    toks = size.split()
    vdt = np.int64 if field == "integer" else np.float64

    if fmt == "coordinate":
        if len(toks) != 3:
            raise MatrixMarketError(
                f"coordinate size line needs 'rows cols nnz'; got {size!r}")
        n_rows, n_cols, nnz = (int(t) for t in toks)
        want = 2 if field == "pattern" else 3
        rows = np.empty(nnz, dtype=np.int64)
        cols = np.empty(nnz, dtype=np.int64)
        vals = np.ones(nnz, dtype=vdt)
        k = 0
        for s in lines:
            t = s.split()
            if len(t) != want:
                raise MatrixMarketError(
                    f"entry {k}: expected {want} tokens, got {s!r}")
            if k >= nnz:
                raise MatrixMarketError(
                    f"more than the declared {nnz} entries")
            rows[k] = int(t[0]) - 1
            cols[k] = int(t[1]) - 1
            if want == 3:
                vals[k] = vdt(t[2]) if field == "integer" else float(t[2])
            k += 1
        if k != nnz:
            raise MatrixMarketError(f"declared {nnz} entries, found {k}")
    else:                                   # array (dense, column-major)
        if len(toks) != 2:
            raise MatrixMarketError(
                f"array size line needs 'rows cols'; got {size!r}")
        n_rows, n_cols = (int(t) for t in toks)
        if field == "pattern":
            raise MatrixMarketError("array format cannot be pattern")
        if sym == "general":
            pairs = [(i, j) for j in range(n_cols) for i in range(n_rows)]
        elif sym == "symmetric":            # lower triangle incl. diagonal
            pairs = [(i, j) for j in range(n_cols) for i in range(j, n_rows)]
        else:                               # skew: strict lower triangle
            pairs = [(i, j) for j in range(n_cols)
                     for i in range(j + 1, n_rows)]
        nnz = len(pairs)
        vals = np.empty(nnz, dtype=vdt)
        k = 0
        for s in lines:
            for tok in s.split():
                if k >= nnz:
                    raise MatrixMarketError(
                        f"more than the expected {nnz} array values")
                vals[k] = vdt(tok) if field == "integer" else float(tok)
                k += 1
        if k != nnz:
            raise MatrixMarketError(f"expected {nnz} array values, found {k}")
        rows = np.array([p[0] for p in pairs], dtype=np.int64)
        cols = np.array([p[1] for p in pairs], dtype=np.int64)

    if sym != "general":
        if n_rows != n_cols:
            raise MatrixMarketError(
                f"{sym} declared on a {n_rows}x{n_cols} matrix")
        off = rows != cols
        if sym == "skew-symmetric" and np.any(vals[~off] != 0):
            raise MatrixMarketError(
                "skew-symmetric file stores a nonzero diagonal")
        sign = -1 if sym == "skew-symmetric" else 1
        r0, c0 = rows, cols
        rows = np.concatenate([r0, c0[off]])
        cols = np.concatenate([c0, r0[off]])
        vals = np.concatenate([vals, sign * vals[off]])

    hdr = MMHeader(fmt, field, sym, (n_rows, n_cols),
                   nnz if fmt == "coordinate" else None)
    return hdr, rows, cols, vals


def load_mm(source: Union[str, os.PathLike, TextIO], *,
            dtype=np.float64, validate: str = "repair") -> F.CSRMatrix:
    """Load a Matrix Market file (path or open text stream) as a host
    :class:`formats.CSRMatrix`.

    ``dtype`` is the value dtype of the returned matrix (float64
    default; integer-valued files cast exactly for any float dtype wide
    enough).  ``validate`` gates the admission check:
    ``"repair"`` (default) rebuilds through
    ``validate_csr(repair=True)`` — duplicates summed, out-of-range
    and non-finite entries dropped; ``"strict"`` raises
    ``CSRValidationError`` on any issue; ``"off"`` skips the scan.
    """
    if validate not in ("repair", "strict", "off"):
        raise ValueError(f"validate must be 'repair', 'strict' or 'off'; "
                         f"got {validate!r}")
    if isinstance(source, (str, os.PathLike)):
        with open(source, "r") as f:
            hdr, rows, cols, vals = read_mm(f)
    else:
        hdr, rows, cols, vals = read_mm(source)
    # Out-of-range indices would crash the bincount inside csr_from_coo;
    # clamp here and let validate_csr report/drop them (strict raises).
    n_rows, n_cols = hdr.shape
    bad = ((rows < 0) | (rows >= n_rows) | (cols < 0) | (cols >= n_cols))
    if np.any(bad):
        if validate != "repair":
            raise MatrixMarketError(
                f"{int(bad.sum())} entries outside the declared "
                f"{n_rows}x{n_cols} shape")
        keep = ~bad
        rows, cols, vals = rows[keep], cols[keep], vals[keep]
    m = F.csr_from_coo(rows, cols, vals.astype(dtype), shape=hdr.shape)
    if validate != "off":
        m, _ = F.validate_csr(m, repair=(validate == "repair"))
    return m


def _value_format(data: np.ndarray) -> str:
    # Enough decimal digits to round-trip the binary value exactly:
    # 9 for binary32, 17 for binary64.
    return "%.9g" if data.dtype.itemsize <= 4 else "%.17g"


def _detect_symmetry(m: F.CSRMatrix) -> str:
    if m.shape[0] != m.shape[1]:
        return "general"
    mt = F.csr_transpose(m)
    same_struct = (np.array_equal(m.indptr, mt.indptr)
                   and np.array_equal(m.indices, mt.indices))
    if not same_struct:
        return "general"
    if np.array_equal(m.data, mt.data):
        return "symmetric"
    if (np.array_equal(m.data, -mt.data)
            and np.all(F.csr_diagonal(m) == 0)):
        return "skew-symmetric"
    return "general"


def write_mm(f: TextIO, m: F.CSRMatrix, *, symmetry: str = "auto",
             field: str = "auto", comment: Optional[str] = None) -> None:
    """Write ``m`` to an open text stream in coordinate format.

    ``symmetry="auto"`` detects symmetric / skew-symmetric square
    matrices (structure AND values) and stores the lower triangle only;
    explicit ``"general"`` / ``"symmetric"`` / ``"skew-symmetric"``
    skip detection (the caller asserts the property — symmetric output
    of a non-symmetric matrix silently drops the upper triangle).
    ``field="auto"`` writes ``integer`` for integer dtypes, else
    ``real``; ``field="pattern"`` stores structure only.
    """
    if symmetry == "auto":
        symmetry = _detect_symmetry(m)
    if symmetry not in _SYMMETRIES:
        raise ValueError(f"symmetry must be 'auto' or one of {_SYMMETRIES}; "
                         f"got {symmetry!r}")
    if field == "auto":
        field = "integer" if np.issubdtype(m.data.dtype, np.integer) \
            else "real"
    if field not in _FIELDS:
        raise ValueError(f"field must be 'auto' or one of {_FIELDS}; "
                         f"got {field!r}")

    rows = np.repeat(np.arange(m.n_rows, dtype=np.int64), m.row_lengths())
    cols = np.asarray(m.indices, dtype=np.int64)
    vals = np.asarray(m.data)
    if symmetry != "general":
        keep = rows >= cols if symmetry == "symmetric" else rows > cols
        rows, cols, vals = rows[keep], cols[keep], vals[keep]

    f.write(f"%%MatrixMarket matrix coordinate {field} {symmetry}\n")
    if comment:
        for line in comment.splitlines():
            f.write(f"% {line}\n")
    f.write(f"{m.shape[0]} {m.shape[1]} {len(vals)}\n")
    if field == "pattern":
        for r, c in zip(rows, cols):
            f.write(f"{r + 1} {c + 1}\n")
    elif field == "integer":
        for r, c, v in zip(rows, cols, vals):
            f.write(f"{r + 1} {c + 1} {int(v)}\n")
    else:
        vf = _value_format(vals)
        for r, c, v in zip(rows, cols, vals):
            f.write(f"{r + 1} {c + 1} {vf % v}\n")


def save_mm(dest: Union[str, os.PathLike, TextIO], m: F.CSRMatrix, *,
            symmetry: str = "auto", field: str = "auto",
            comment: Optional[str] = None) -> None:
    """Write ``m`` as a coordinate Matrix Market file (path or stream).
    See :func:`write_mm` for the symmetry / field knobs; the value
    format is chosen so ``load_mm(save_mm(...))`` round-trips the
    stored dtype bit-exactly."""
    if isinstance(dest, (str, os.PathLike)):
        with open(dest, "w") as f:
            write_mm(f, m, symmetry=symmetry, field=field, comment=comment)
    else:
        write_mm(dest, m, symmetry=symmetry, field=field, comment=comment)
