"""The paper's performance models (Eq. 1-4), retargeted at TPU v5e.

Paper (Fermi GPU)                    ->  here (TPU v5e target)
  B_GPU   device-memory bandwidth        HBM_BW       = 819 GB/s
  B_PCI   host link bandwidth            ICI_LINK_BW  = 50 GB/s  (per link)
  SP/DP peak                             PEAK_FLOPS   = 197e12 bf16 / chip

Eq. (1): worst-case code balance of the ELLPACK/pJDS kernel,
    B_W^DP = (6 + 4*alpha + 8/N_nzr_max) bytes/flop
with alpha in [1/N_nzr, 1] the RHS cache-reuse parameter.  On TPU the
pJDS kernel keeps the local RHS slice resident in VMEM, which *enforces*
the alpha -> 1/N_nzr limit for the distributed blocks (DESIGN.md §2).

Eq. (2)-(4): device-vs-link time model.  The paper derives the range of
N_nzr for which accelerator spMVM is worthwhile given the ratio
B_dev/B_link; identical math bounds when a TPU chip's spMVM is worth the
ICI halo traffic.

Also hosts the three-term roofline used by EXPERIMENTS.md §Roofline,
and the CALIBRATION layer: the spec numbers above are data-sheet values,
but ``repro.tune`` fits an effective bandwidth scale and a per-format
fixed overhead from MEASURED spMVM rows (``tune.calibrate``), installs
them here (:func:`set_calibration`), and every
:func:`predicted_spmv_seconds` call — including the dispatch heuristic
``kernels.ops.select_format`` — then prices candidates against the
machine that was actually measured instead of the data sheet.
"""
from __future__ import annotations

import dataclasses
from typing import Mapping, Optional

__all__ = [
    "TPUSpec",
    "TPU_V5E",
    "Calibration",
    "set_calibration",
    "get_calibration",
    "clear_calibration",
    "code_balance",
    "alpha_range",
    "t_mvm",
    "t_link",
    "t_link_gathered",
    "predicted_dist_spmv_seconds",
    "choose_halo",
    "n_nzr_upper_for_link_penalty",
    "n_nzr_lower_for_link_penalty",
    "spmvm_flops",
    "spmvm_bytes",
    "perm_traffic_bytes",
    "CMRS_RIS_BYTES",
    "cmrs_reduce_seconds",
    "predicted_spmv_seconds",
    "SOLVER_SPMV_COUNT",
    "SOLVER_VECTOR_PASSES",
    "solver_iteration_bytes",
    "predicted_iteration_seconds",
    "roofline_terms",
    "RooflineReport",
]


@dataclasses.dataclass(frozen=True)
class TPUSpec:
    name: str
    peak_flops: float        # FLOP/s per chip (bf16 MXU)
    peak_flops_f32: float    # FLOP/s per chip (f32 VPU-bound spMVM path)
    hbm_bw: float            # bytes/s per chip
    ici_bw: float            # bytes/s per link
    vmem_bytes: int
    hbm_bytes: int


TPU_V5E = TPUSpec(
    name="tpu-v5e",
    peak_flops=197e12,
    peak_flops_f32=197e12 / 4,  # f32 through the MXU at quarter rate
    hbm_bw=819e9,
    ici_bw=50e9,
    vmem_bytes=128 * 2 ** 20,
    hbm_bytes=16 * 2 ** 30,
)


# ------------------------------------------------------------- calibration
@dataclasses.dataclass(frozen=True)
class Calibration:
    """Measured correction to the memory-bound time model.

    ``predicted = bytes / (spec.hbm_bw * bw_scale) + overhead_s[fmt]``

    ``bw_scale`` is the ratio of the EFFECTIVE streaming bandwidth the
    measured kernel achieved to the spec's data-sheet number (off-TPU it
    absorbs the CPU-vs-TPU gap wholesale, so the model still ranks
    candidates on the machine that was measured); ``overhead_s`` is a
    per-format fixed launch/epilogue cost in seconds (missing formats
    cost 0).  Fit by ``repro.tune.calibrate.fit_calibration`` from
    measured rows; ``source`` records where the rows came from.
    """

    bw_scale: float
    overhead_s: Mapping[str, float] = dataclasses.field(default_factory=dict)
    source: str = ""
    # ---- link calibration (repro.tune.calibrate.fit_link_calibration) ----
    # Effective ICI/interconnect bandwidth scale, and the per-MESSAGE
    # fixed cost of each halo flavour in seconds — the gather/ppermute/
    # scatter set-up the pure bytes/bandwidth term cannot see.  This is
    # exactly why an uncalibrated model makes the gathered exchange look
    # free at toy scale: 15x fewer bytes, but the same number of
    # messages, each paying pack/unpack latency.  Missing halo keys cost
    # 0 (the uncalibrated data-sheet behaviour).
    link_bw_scale: float = 1.0
    msg_overhead_s: Mapping[str, float] = dataclasses.field(
        default_factory=dict)

    def __post_init__(self):
        if not (self.bw_scale > 0):
            raise ValueError(f"bw_scale must be > 0; got {self.bw_scale}")
        if not (self.link_bw_scale > 0):
            raise ValueError(
                f"link_bw_scale must be > 0; got {self.link_bw_scale}")


_CALIBRATION: Optional[Calibration] = None


def set_calibration(cal: Optional[Calibration]) -> None:
    """Install ``cal`` as the process-wide default calibration: every
    subsequent :func:`predicted_spmv_seconds` call without an explicit
    ``calibration=`` argument uses it (including the ones inside
    ``kernels.ops.select_format``).  ``None`` uninstalls."""
    global _CALIBRATION
    if cal is not None and not isinstance(cal, Calibration):
        raise TypeError(f"expected Calibration or None; got {type(cal)}")
    _CALIBRATION = cal


def get_calibration() -> Optional[Calibration]:
    return _CALIBRATION


def clear_calibration() -> None:
    set_calibration(None)


# ---------------------------------------------------------------- Eq. (1)
def code_balance(alpha: float, n_nzr: float, value_bytes: int = 8,
                 index_bytes: int = 4) -> float:
    """Worst-case code balance in bytes/flop (paper Eq. 1, generalised to
    any value precision).  DP (value_bytes=8):  6 + 4*alpha + 8/N_nzr.
    SP (value_bytes=4):                          4 + 2*alpha + 4/N_nzr.
    """
    # per non-zero: val + col_idx + alpha*RHS element + LHS (read+write) / row,
    # over 2 flops.  DP: (8 + 4 + 8a + 16/N)/2 = 6 + 4a + 8/N  (paper Eq. 1)
    # SP: (4 + 4 + 4a +  8/N)/2 = 4 + 2a + 4/N
    return (
        value_bytes + index_bytes + value_bytes * alpha
        + 2 * value_bytes / n_nzr
    ) / 2.0


def alpha_range(n_nzr: float) -> tuple[float, float]:
    """Admissible RHS reuse parameter: [1/N_nzr (perfect reuse), 1 (none)]."""
    return (1.0 / n_nzr, 1.0)


# ------------------------------------------------------------- Eq. (2)-(4)
def t_mvm(n_rows: float, n_nzr: float, alpha: float, dev_bw: float,
          value_bytes: int = 8) -> float:
    """Paper Eq. (2) left: wallclock of the on-device spMVM.
    T = (value_bytes*N / B_dev) * [N_nzr*(alpha + 3/2) + 2]  (DP form)."""
    return (value_bytes * n_rows / dev_bw) * (n_nzr * (alpha + 1.5) + 2.0)


def t_link(n_rows: float, link_bw: float, value_bytes: int = 8) -> float:
    """Paper Eq. (2) right: moving RHS in and LHS out over the slow link."""
    return 2 * value_bytes * n_rows / link_bw


def t_link_gathered(halo_elems: float, link_bw: float,
                    value_bytes: int = 8, k: int = 1, *,
                    msgs: int = 0, halo: str = "gathered",
                    calibration="default") -> float:
    """Gathered-halo refinement of the Eq. (2) link term: with the
    compressed exchange only the MEASURED per-neighbor halo entries cross
    the link, not the full slice.  ``halo_elems`` is the sum of the
    per-neighbor gathered halo sizes (``DistPJDS.halo_lens`` plus, on a
    2-D grid, ``red_lens``; equals ``comm_bytes_per_device() /
    value_bytes``); ``k`` scales for a multi-RHS block, whose halo
    buffers carry k columns per entry.  With this term the model prices
    what the wire actually carries — a purely block-diagonal partition
    (halo_elems == 0, msgs == 0) costs no link time at all, where the
    slice-proportional Eq. (2) term would still charge
    ``2 * n_loc * value_bytes / B_link``.

    ``msgs`` is the point-to-point message count per device per spMVM
    (``DistPJDS.comm_msgs_per_device``): each message pays the
    calibrated per-message fixed cost ``msg_overhead_s[halo]`` — the
    gather/ppermute/scatter set-up that dominates at toy scale and made
    the UNcalibrated model wrongly prefer the gathered exchange there.
    The link bandwidth is scaled by the calibrated ``link_bw_scale``.
    Without an installed calibration (or with ``msgs=0``, the old
    signature) the term reduces to the pure bytes/bandwidth model."""
    if calibration == "default":
        calibration = _CALIBRATION
    scale = calibration.link_bw_scale if calibration is not None else 1.0
    fixed = (calibration.msg_overhead_s.get(halo, 0.0)
             if calibration is not None else 0.0)
    return value_bytes * k * halo_elems / (link_bw * scale) + msgs * fixed


def predicted_dist_spmv_seconds(dist, halo: str = "gathered",
                                mode: str = "overlap", *, k: int = 1,
                                value_bytes: int = 4, index_bytes: int = 4,
                                spec: TPUSpec = TPU_V5E,
                                calibration="default") -> float:
    """Per-device wall-time estimate of one distributed spMVM over a
    :class:`~repro.core.dist_spmv.DistPJDS` partition (duck-typed to
    avoid a core->core import cycle).

    compute:  local + remote operand streams through the calibrated
              single-device model (Eq. 1/2 left);
    comm:     the calibrated link term — measured bytes over the scaled
              link bandwidth plus the per-message fixed cost
              (:func:`t_link_gathered`).

    Modes ``vector``/``naive`` serialize compute after comm; modes
    ``overlap``/``pipeline`` hide the exchange behind the LOCAL kernel
    (the paper's §3.1 task mode), so only the part of the exchange that
    outlasts it is charged.  This is the decision function behind
    ``dist_operator(halo="auto")`` — see :func:`choose_halo`."""
    if calibration == "default":
        calibration = _CALIBRATION
    blk_rows = dist.n_blocks * dist.b_r

    def _t(val_arr):
        elems = int(val_arr.shape[1]) * int(val_arr.shape[2])
        if elems == 0:
            return 0.0
        return k * predicted_spmv_seconds(
            elems, blk_rows, elems / blk_rows, spec=spec,
            value_bytes=value_bytes, index_bytes=index_bytes,
            fmt="pjds", calibration=calibration)

    t_loc = _t(dist.loc_val)
    t_rem = _t(dist.rem_val)
    elems = dist.comm_bytes_per_device(value_bytes=1, k=k, halo=halo)
    t_comm = t_link_gathered(elems, spec.ici_bw, value_bytes, 1,
                             msgs=dist.comm_msgs_per_device(halo),
                             halo=halo, calibration=calibration)
    if mode in ("overlap", "pipeline"):
        return max(t_loc, t_comm) + t_rem
    return t_loc + t_rem + t_comm


def choose_halo(dist, mode: str = "overlap", *, k: int = 1,
                value_bytes: int = 4, spec: TPUSpec = TPU_V5E,
                calibration="default") -> str:
    """The calibrated gathered-vs-full crossover decision
    (``dist_operator(halo="auto")``): price both exchange flavours with
    :func:`predicted_dist_spmv_seconds` and return the cheaper one.
    Ties (e.g. halo_w == 0: nothing crosses the wire either way) go to
    ``"gathered"``."""
    t_g = predicted_dist_spmv_seconds(dist, "gathered", mode, k=k,
                                      value_bytes=value_bytes, spec=spec,
                                      calibration=calibration)
    t_f = predicted_dist_spmv_seconds(dist, "full", mode, k=k,
                                      value_bytes=value_bytes, spec=spec,
                                      calibration=calibration)
    return "full" if t_f < t_g else "gathered"


def n_nzr_upper_for_link_penalty(dev_bw: float, link_bw: float,
                                 alpha: float) -> float:
    """Paper Eq. (3): below this N_nzr the link transfer costs >= 50% extra
    (T_MVM <= T_link) -> accelerator not worthwhile."""
    return 2.0 * (dev_bw / link_bw - 1.0) / (alpha + 1.5)


def n_nzr_lower_for_link_penalty(dev_bw: float, link_bw: float,
                                 alpha: float) -> float:
    """Paper Eq. (4): above this N_nzr the link penalty is < 10%
    (T_MVM >= 10*T_link)."""
    return (20.0 * dev_bw / link_bw - 2.0) / (alpha + 1.5)


# -------------------------------------------------------------- roofline
def spmvm_flops(nnz: int) -> int:
    """2 flops (multiply + add) per stored non-zero."""
    return 2 * nnz


def spmvm_bytes(stored_elements: int, n_rows: int, alpha: float,
                n_nzr: float, value_bytes: int = 8,
                index_bytes: int = 4, x_tiles: int = 1,
                n_row_blocks: int = 1,
                vec_bytes: int | None = None) -> float:
    """Minimum HBM traffic of one spMVM in a given format: matrix values +
    indices stream once; RHS traffic scales with alpha; LHS written once.

    ``value_bytes``/``index_bytes`` are the STORED matrix widths, so a
    bf16-value / int16-index build is priced at its compressed stream
    (the whole point of the compressed formats: bytes/nnz drops from
    4+4 to 2+2 before padding).  ``vec_bytes`` is the width of the
    RHS/LHS vectors, which do NOT compress with the matrix — a bf16
    build still reads f32 x and writes the f32 accumulator — and
    defaults to at least f32 (``max(4, value_bytes)``).

    ``x_tiles > 1`` prices the column-blocked-x kernel grid
    (row block, x tile, chunk): the matrix stream is re-read once per x
    tile, and the RHS — no longer resident — is re-read once per row
    block (``n_row_blocks``) instead of once, replacing the alpha term.
    The model makes the trade explicit: column blocking buys a bounded
    VMEM footprint with strictly more HBM traffic, so dispatch only
    reaches for it when x cannot be resident at all."""
    if vec_bytes is None:
        vec_bytes = max(4, value_bytes)
    if x_tiles > 1:
        rhs = n_row_blocks * n_rows * vec_bytes        # x re-read per block
    else:
        rhs = alpha * n_nzr * n_rows * vec_bytes       # resident: alpha term
    return (
        x_tiles * stored_elements * (value_bytes + index_bytes)
        + rhs
        + 2 * n_rows * vec_bytes
    )


def perm_traffic_bytes(n_rows: int, value_bytes: int = 4,
                       index_bytes: int = 4,
                       window_local: bool = False) -> float:
    """Extra HBM traffic of undoing a row sort OUTSIDE the kernel: the
    permutation index stream plus a read+write pass over y.  A
    window-local (SELL-C-sigma) unpermute is fused into the kernel while
    y is still VMEM-resident, so it costs no HBM traffic at all — the
    structural advantage dispatch weighs against pJDS's (slightly)
    smaller padding (DESIGN.md §5)."""
    if window_local:
        return 0.0
    return float(n_rows) * (2 * value_bytes + index_bytes)


# CMRS stores one extra byte per slot: the int8 row-in-strip stream that
# routes each densely-packed slot back to its row (core.formats.CMRSMatrix).
CMRS_RIS_BYTES = 1


def cmrs_reduce_seconds(stored_elements: int, b_r: int,
                        spec: TPUSpec = TPU_V5E) -> float:
    """Compute term of the CMRS in-kernel segment reduction: every
    stored slot feeds a one-hot ``(1, chunk*b_r) @ (chunk*b_r, b_r)``
    matmul, i.e. ``2 * b_r`` f32 MXU flops per slot.  CMRS trades
    ELLPACK/pJDS's padding bytes for these flops, so callers price it
    as ``max(memory_term, this)`` — on TPU the MXU overlaps the HBM
    stream, and whichever term is longer bounds the kernel."""
    return 2.0 * float(stored_elements) * float(b_r) / spec.peak_flops_f32


def predicted_spmv_seconds(stored_elements: int, n_rows: int, n_nzr: float,
                           perm_bytes: float = 0.0,
                           irregular_factor: float = 1.0,
                           spec: TPUSpec = TPU_V5E,
                           value_bytes: int = 4,
                           index_bytes: int = 4,
                           x_tiles: int = 1,
                           n_row_blocks: int = 1,
                           vec_bytes: int | None = None,
                           fmt: str | None = None,
                           calibration="default") -> float:
    """Memory-bound time estimate of one spMVM in a candidate format —
    the quantity ``kernels.ops.select_format`` minimises.  Uses the
    enforced alpha -> 1/N_nzr limit (VMEM-resident RHS, DESIGN.md §2);
    ``irregular_factor`` derates formats without a blocked kernel (CSR's
    scalar gather stream cannot saturate HBM).  ``value_bytes`` /
    ``index_bytes`` are the STORED stream widths, ``vec_bytes`` the
    uncompressed RHS/LHS width, and ``x_tiles`` / ``n_row_blocks``
    price the column-blocked-x grid — see :func:`spmvm_bytes`.

    ``calibration`` applies a measured :class:`Calibration` — effective
    bandwidth scale plus the per-format overhead looked up by ``fmt`` —
    on top of the structural byte model; the default picks up whatever
    :func:`set_calibration` installed (``None`` forces the uncalibrated
    data-sheet estimate)."""
    n_nzr = max(n_nzr, 1e-9)
    alpha = 1.0 / n_nzr
    b = spmvm_bytes(stored_elements, n_rows, alpha, n_nzr,
                    value_bytes, index_bytes, x_tiles, n_row_blocks,
                    vec_bytes)
    t = (b * irregular_factor + perm_bytes) / spec.hbm_bw
    if calibration == "default":
        calibration = _CALIBRATION
    if calibration is not None:
        t = t / calibration.bw_scale
        if fmt is not None:
            t += calibration.overhead_s.get(fmt, 0.0)
    return max(t, 0.0)


# ------------------------------------------------- solver-iteration model
# spMV applications per Krylov iteration (BiCGStab applies A twice).
SOLVER_SPMV_COUNT: Mapping[str, int] = {
    "cg": 1,
    "bicgstab": 2,
    "block_cg": 1,
}

# Carrier-vector HBM passes per iteration BEYOND the spMV's own rhs/lhs
# traffic (each pass = n_rows * vec_bytes read OR written), counted off
# the solver bodies in ``core.solvers``:
#
#   cg composed:   3 axpys (2 passes each: read+write over x/r/p) +
#                  3 dots re-reading (p, Ap_vs_r, r) = 6 + 3 extra Ap/r
#                  reads -> 12;  fused: the dots ride the spMV epilogue
#                  and only the 3 axpys + Ap read remain -> 7.
#   bicgstab composed: two half-steps, ~2x cg's vector work -> 22;
#                  fused: -> 14.
#   block_cg:      same passes as cg but each is k columns wide; the
#                  caller multiplies by k via ``n_vec``; no fused path.
SOLVER_VECTOR_PASSES: Mapping[str, Mapping[str, int]] = {
    "cg": {"composed": 12, "fused": 7},
    "bicgstab": {"composed": 22, "fused": 14},
    "block_cg": {"composed": 12, "fused": 12},
}


def solver_iteration_bytes(stored_elements: int, n_rows: int, n_nzr: float,
                           *, method: str = "cg",
                           strategy: str = "composed",
                           value_bytes: int = 4, index_bytes: int = 4,
                           vec_bytes: int = 4, n_vec: int = 1,
                           x_tiles: int = 1,
                           n_row_blocks: int = 1) -> float:
    """Minimum HBM traffic of ONE solver iteration: the method's spMV
    streams plus the carrier-vector passes around them.

    This is the honesty fix the fused-iteration work is judged with:
    pricing an iteration as spMV bytes only (the old ``perf_iter`` /
    ``roofline`` habit) hides exactly the traffic the fused kernel
    removes — the axpy/dot passes over x/r/p — and overstates how close
    the composed baseline already was to the roofline.  ``n_vec``
    scales the carrier passes for block solvers (k columns per pass).
    """
    spmv_count = SOLVER_SPMV_COUNT[method]
    passes = SOLVER_VECTOR_PASSES[method][strategy]
    alpha = 1.0 / max(n_nzr, 1e-9)
    spmv = spmvm_bytes(stored_elements, n_rows, alpha, n_nzr,
                       value_bytes, index_bytes, x_tiles, n_row_blocks,
                       vec_bytes)
    return spmv_count * spmv + passes * n_vec * float(n_rows) * vec_bytes


def predicted_iteration_seconds(stored_elements: int, n_rows: int,
                                n_nzr: float, *, method: str = "cg",
                                strategy: str = "composed",
                                spec: TPUSpec = TPU_V5E,
                                value_bytes: int = 4, index_bytes: int = 4,
                                vec_bytes: int = 4, n_vec: int = 1,
                                x_tiles: int = 1, n_row_blocks: int = 1,
                                fmt: str | None = None,
                                calibration="default") -> float:
    """Memory-bound time of one solver iteration — the quantity
    ``tune.tune_solver`` measures and ``benchmarks/bench_solve``
    reports predicted-vs-measured for.  Same calibration semantics as
    :func:`predicted_spmv_seconds`, with the per-format overhead
    charged once per spMV application."""
    b = solver_iteration_bytes(
        stored_elements, n_rows, n_nzr, method=method, strategy=strategy,
        value_bytes=value_bytes, index_bytes=index_bytes,
        vec_bytes=vec_bytes, n_vec=n_vec, x_tiles=x_tiles,
        n_row_blocks=n_row_blocks)
    t = b / spec.hbm_bw
    if calibration == "default":
        calibration = _CALIBRATION
    if calibration is not None:
        t = t / calibration.bw_scale
        if fmt is not None:
            t += SOLVER_SPMV_COUNT[method] * calibration.overhead_s.get(
                fmt, 0.0)
    return max(t, 0.0)


@dataclasses.dataclass
class RooflineReport:
    compute_s: float
    memory_s: float
    collective_s: float
    chips: int

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def bound_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    def fraction_of_roofline(self, achieved_s: float) -> float:
        """How close a measured/estimated step time is to the roofline bound."""
        return self.bound_s / achieved_s if achieved_s > 0 else 0.0


def roofline_terms(hlo_flops: float, hlo_bytes: float,
                   collective_bytes: float, chips: int,
                   spec: TPUSpec = TPU_V5E,
                   flops_rate: float | None = None) -> RooflineReport:
    """EXPERIMENTS.md §Roofline three-term model.

    compute    = HLO_FLOPs / (chips * peak)
    memory     = HLO_bytes / (chips * HBM_bw)
    collective = collective_bytes / (chips * link_bw)

    ``hlo_flops``/``hlo_bytes`` are GLOBAL (whole-program) numbers from
    ``compiled.cost_analysis()``; collective_bytes parsed from the HLO.
    """
    rate = flops_rate if flops_rate is not None else spec.peak_flops
    return RooflineReport(
        compute_s=hlo_flops / (chips * rate),
        memory_s=hlo_bytes / (chips * spec.hbm_bw),
        collective_s=collective_bytes / (chips * spec.ici_bw),
        chips=chips,
    )
