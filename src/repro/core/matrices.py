"""Synthetic generators reproducing the row-length statistics of the
paper's five test matrices (§1.3, Fig. 3).

The originals are not redistributable, so each generator produces a
matrix with the published *structural* statistics — dimension (scalable),
average non-zeros per row N_nzr, row-length spread, and characteristic
substructure (off-diagonals for HMEp, dense 5x5 blocks for DLR2, ...).
That is exactly what the paper's format/memory/performance analysis
depends on; the numeric values are random but deterministic per seed.

All generators take a ``scale`` in (0, 1] that shrinks the dimension while
preserving N_nzr and relative row-length distribution, so the full suite
runs on a laptop (repro band 5/5).
"""
from __future__ import annotations

import numpy as np

from .formats import CSRMatrix, csr_from_coo

__all__ = [
    "hmep",
    "samg",
    "dlr1",
    "dlr2",
    "uhbr",
    "TEST_MATRICES",
    "make_test_matrix",
    "poisson_2d",
    "convection_poisson",
    "power_law",
]

# Published statistics (paper §1.3) — dimension, avg nnz/row.
_PUBLISHED = {
    "HMEp": dict(dim=6_200_000, n_nzr=15),
    "sAMG": dict(dim=3_400_000, n_nzr=7),
    "DLR1": dict(dim=280_000, n_nzr=144),
    "DLR2": dict(dim=540_000, n_nzr=315),
    "UHBR": dict(dim=4_500_000, n_nzr=123),
}


def _dedup_clip(rows, cols, vals, n):
    keep = (cols >= 0) & (cols < n)
    return rows[keep], cols[keep], vals[keep]


def hmep(scale: float = 0.01, seed: int = 0) -> CSRMatrix:
    """Holstein-Hubbard model matrix analogue: very sparse (~15 nnz/row)
    with contiguous off-diagonals (published length 15 000, scaled)."""
    rng = np.random.default_rng(seed)
    n = max(int(_PUBLISHED["HMEp"]["dim"] * scale), 256)
    off_len = max(int(15_000 * scale * 4), 8)  # off-diagonal offset magnitude
    rows_l, cols_l, vals_l = [], [], []
    idx = np.arange(n)
    # main diagonal + a few contiguous off-diagonals (hopping terms)
    offsets = [0, 1, -1, off_len, -off_len, 3 * off_len, -3 * off_len]
    for off in offsets:
        r = idx
        c = idx + off
        v = rng.standard_normal(n)
        r, c, v = _dedup_clip(r, c, v, n)
        rows_l.append(r), cols_l.append(c), vals_l.append(v)
    # phonon coupling: ~8 extra scattered entries/row, row count varies
    extra = rng.poisson(8.0, size=n)
    tot = int(extra.sum())
    r = np.repeat(idx, extra)
    c = rng.integers(0, n, size=tot)
    v = rng.standard_normal(tot)
    rows_l.append(r), cols_l.append(c), vals_l.append(v)
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    vals = np.concatenate(vals_l)
    return csr_from_coo(rows, cols, vals, (n, n))


def samg(scale: float = 0.01, seed: int = 1) -> CSRMatrix:
    """Adaptive-multigrid Poisson analogue: N_nzr ~ 7, longest row > 4x the
    shortest, weight concentrated on short rows (paper Fig. 3)."""
    rng = np.random.default_rng(seed)
    n = max(int(_PUBLISHED["sAMG"]["dim"] * scale), 256)
    # row lengths: mostly 4-8 (short), heavy tail to ~30
    rl = np.clip(rng.geometric(0.35, size=n) + 3, 4, 30)
    tot = int(rl.sum())
    rows = np.repeat(np.arange(n), rl)
    # unstructured mesh neighbours: local band + occasional long-range
    jitter = rng.integers(-50, 51, size=tot)
    cols = np.clip(rows + jitter, 0, n - 1)
    far = rng.random(tot) < 0.05
    cols[far] = rng.integers(0, n, size=int(far.sum()))
    vals = rng.standard_normal(tot)
    m = csr_from_coo(rows, cols, vals, (n, n))
    return _spd_shift(m)


def dlr1(scale: float = 0.05, seed: int = 2) -> CSRMatrix:
    """Adjoint CFD (TAU) analogue: N_nzr ~ 144, narrow spread
    (max/min ~ 2; 80% of rows >= 0.8 * max)."""
    rng = np.random.default_rng(seed)
    n = max(int(_PUBLISHED["DLR1"]["dim"] * scale), 512)
    max_rl = 160
    rl = np.where(
        rng.random(n) < 0.8,
        rng.integers(int(0.8 * max_rl), max_rl + 1, size=n),
        rng.integers(max_rl // 2, int(0.8 * max_rl), size=n),
    )
    return _banded_random(n, rl, band=400, rng=rng)


def dlr2(scale: float = 0.05, seed: int = 3) -> CSRMatrix:
    """Aerodynamic-gradients analogue: N_nzr ~ 315, built entirely of dense
    5x5 subblocks (paper: '...consists entirely of dense 5x5 subblocks')."""
    rng = np.random.default_rng(seed)
    n_pts = max(int(_PUBLISHED["DLR2"]["dim"] * scale) // 5, 128)
    n = n_pts * 5
    nbrs_per_pt = 315 // 5  # 63 block-neighbours -> ~315 nnz/row
    rows_l, cols_l, vals_l = [], [], []
    for pt in range(n_pts):
        k = max(int(rng.normal(nbrs_per_pt, 8)), 8)
        nb = np.unique(
            np.clip(pt + rng.integers(-200, 201, size=k), 0, n_pts - 1)
        )
        # dense 5x5 block for each neighbour pair
        bi, bj = np.meshgrid(np.arange(5), np.arange(5), indexing="ij")
        for q in nb:
            rows_l.append(pt * 5 + bi.ravel())
            cols_l.append(q * 5 + bj.ravel())
        vals_l.append(rng.standard_normal(len(nb) * 25))
    rows = np.concatenate(rows_l)
    cols = np.concatenate(cols_l)
    vals = np.concatenate(vals_l)
    return csr_from_coo(rows, cols, vals, (n, n))


def uhbr(scale: float = 0.01, seed: int = 4) -> CSRMatrix:
    """UHBR turbine-fan (TRACE) analogue: large dimension, N_nzr ~ 123,
    moderate spread."""
    rng = np.random.default_rng(seed)
    n = max(int(_PUBLISHED["UHBR"]["dim"] * scale), 512)
    rl = np.clip(rng.normal(123, 30, size=n).astype(np.int64), 20, 220)
    return _banded_random(n, rl, band=600, rng=rng)


def _banded_random(n, rl, band, rng) -> CSRMatrix:
    tot = int(rl.sum())
    rows = np.repeat(np.arange(n), rl)
    jitter = rng.integers(-band, band + 1, size=tot)
    cols = np.clip(rows + jitter, 0, n - 1)
    vals = rng.standard_normal(tot)
    return csr_from_coo(rows, cols, vals, (n, n))


def _spd_shift(m: CSRMatrix) -> CSRMatrix:
    """Make (A + A^T)/2 + shift*I so Krylov examples converge (CG needs SPD).
    Done densely only for small n; otherwise adds a diagonal shift."""
    n = m.shape[0]
    rl = m.row_lengths()
    shift = float(np.abs(m.data).max(initial=1.0)) * (int(rl.max(initial=1)) + 1)
    diag_rows = np.arange(n)
    rows = np.concatenate([np.repeat(np.arange(n), rl), diag_rows])
    cols = np.concatenate([m.indices, diag_rows])
    vals = np.concatenate([m.data, np.full(n, shift, dtype=m.data.dtype)])
    return csr_from_coo(rows, cols, vals, (n, n))


def power_law(n: int = 4096, seed: int = 7, exponent: float = 1.6,
              min_rl: int = 2) -> CSRMatrix:
    """Zipf-distributed row lengths — the extreme row-length-variance
    case (scale-free graphs, web/social adjacency) where the formats
    diverge most: ELLPACK pads every row to the rare hub length, pJDS
    needs a global sort to avoid that, SELL-C-sigma bounds the sort.
    The format-dispatch benchmarks and tests use this as the worst-case
    pattern alongside the paper's five matrices."""
    rng = np.random.default_rng(seed)
    rl = np.clip(rng.zipf(exponent, size=n) + min_rl - 1, min_rl, n // 4)
    tot = int(rl.sum())
    rows = np.repeat(np.arange(n), rl)
    cols = rng.integers(0, n, size=tot)
    vals = rng.standard_normal(tot)
    return csr_from_coo(rows, cols, vals, (n, n))


def poisson_2d(nx: int = 64, ny: int = 64) -> CSRMatrix:
    """5-point Laplacian on an nx x ny grid — small SPD matrix for solver
    tests and the quickstart example."""
    n = nx * ny
    idx = np.arange(n).reshape(nx, ny)
    rows_l, cols_l, vals_l = [], [], []
    rows_l.append(idx.ravel()); cols_l.append(idx.ravel())
    vals_l.append(np.full(n, 4.0))
    for shift, axis in ((1, 0), (-1, 0), (1, 1), (-1, 1)):
        src = idx.take(range(max(0, shift), idx.shape[axis] + min(0, shift)), axis=axis)
        dst = idx.take(range(max(0, -shift), idx.shape[axis] + min(0, -shift)), axis=axis)
        rows_l.append(src.ravel()); cols_l.append(dst.ravel())
        vals_l.append(np.full(src.size, -1.0))
    rows = np.concatenate(rows_l); cols = np.concatenate(cols_l)
    vals = np.concatenate(vals_l)
    return csr_from_coo(rows, cols, vals, (n, n))


def convection_poisson(nx: int = 64, ny: int = 64,
                       beta: float = 0.5) -> CSRMatrix:
    """Poisson + upwind convection skew on the fast-axis neighbors
    (entries at col == row ± 1, which in ``poisson_2d`` exist only for
    true grid neighbors): non-symmetric, with positive-definite
    symmetric part for |beta| < 1 — the BiCGStab test operator."""
    m = poisson_2d(nx, ny)
    rows = np.repeat(np.arange(m.n_rows), np.diff(m.indptr))
    cols = m.indices.astype(np.int64)
    data = m.data.astype(np.float64).copy()
    data[cols == rows + 1] += beta
    data[cols == rows - 1] -= beta
    return CSRMatrix(m.indptr, m.indices, data.astype(np.float32), m.shape)


TEST_MATRICES = {
    "HMEp": hmep,
    "sAMG": samg,
    "DLR1": dlr1,
    "DLR2": dlr2,
    "UHBR": uhbr,
}


def make_test_matrix(name: str, scale: float | None = None, seed: int | None = None) -> CSRMatrix:
    fn = TEST_MATRICES[name]
    kwargs = {}
    if scale is not None:
        kwargs["scale"] = scale
    if seed is not None:
        kwargs["seed"] = seed
    return fn(**kwargs)
