"""Distributed-memory spMVM / spMM (paper §3) on a JAX device mesh.

Partitioning is over a 2-D device grid ``(gr, gc)`` with ``P = gr*gc``
devices in row-major order (``i = p // gc``, ``j = p % gc``):

* grid row ``i`` owns the contiguous row block ``I_i`` of ``gc * n_loc``
  matrix rows, split among its ``gc`` devices by COLUMN block — device
  ``(i, j)`` stores ``A[I_i, J_j]`` where ``J_j`` is the union of the
  x-slices owned by grid column ``j``;
* every device still owns exactly the ``n_loc`` rows of x and y that a
  1-D partition would give it (device p's y slice is segment ``j`` of
  ``I_i``), so vectors, solvers and the operator protocol are unchanged.

``grid=(P, 1)`` is EXACTLY the paper's 1-D row partition (the default);
``grid=(1, P)`` is pure column partitioning; square-ish grids shrink
both the halo surface and the per-device x working set as O(1/sqrt(P))
— the scaling geometry the paper's model says 1-D cannot deliver.

Two exchanges follow from the geometry:

* **x halo** along each grid COLUMN (ring of ``gr``): device ``(i, j)``
  needs remote x entries of devices ``(i', j)`` at signed ring distance
  ``d = i' - i``; exactly the 1-D halo machinery, reused verbatim
  (``halo_w`` / ``send_idx`` / ``recv_idx`` / ``halo_lens``).
* **y reduction** along each grid ROW (ring of ``gc``): device
  ``(i, j)`` computes PARTIAL sums for the other segments of ``I_i``
  and ships them to their owners, which scatter-add them into their own
  y slice.  The reduction is folded into the kernel epilogue: kernels
  return y in the SORTED row basis, and the partition records the
  sorted POSITIONS of every outgoing partial row (``red_send_pos``) and
  of the device's own segment (``seg_pos``), so no dense unpermute or
  extended y buffer ever materialises — see
  ``kernels.ref.partial_reduce_epilogue_ref``.

Both parts are stored in SELL-C-sigma-windowed blocked storage — going
one step beyond the paper, whose multi-GPU code still used ELLPACK-R and
left "an implementation of the pJDS format in the multi-GPU code" as
future work (paper §3, Conclusions).  The row sort is windowed INSIDE
each device block (sigma rows per window, default 8*b_r), so no
permutation crosses the network and the halo/RHS access pattern keeps
the locality of the original row ordering up to sigma (DESIGN.md §3/§6).

Halo exchange (paper §3: "local gather + point-to-point") has two
implementations, selected by ``halo=``:

* ``"gathered"`` (default) — the paper-faithful compressed exchange: at
  partition time each device records, per ring neighbor, WHICH of its
  columns that neighbor actually references (``send_idx``), padded to a
  static per-neighbor maximum.  At run time each device gathers exactly
  those entries, ``ppermute``s the compact buffers, and scatters the
  received values into a dense ext buffer (``recv_idx``; padding lanes
  carry an out-of-range sentinel and are dropped).  The y reduction is
  compressed the same way (``red_send_pos`` / ``red_recv_idx``).
* ``"full"`` — the bulk baseline: ring-shift whole x slices
  ``2*halo_w`` times and whole partial y segments ``2*red_w`` times.

A purely block-diagonal matrix measures ``halo_w == 0`` and skips the
exchange (and the remote kernel) entirely.

Four communication modes (paper §3.1), distinguished by their data
dependences — inspect the compiled HLO to see the schedules differ:

* ``vector``   — bulk-synchronous: halo exchange completes (barrier),
  then one combined spMVM pass.
* ``naive``    — split kernels, but the halo exchange is *ordered after*
  the local kernel (an ``optimization_barrier`` models MPI libraries
  without asynchronous progress).  The paper predicts no benefit over
  vector mode; the serialized schedule reproduces that.
* ``overlap``  — task mode: the halo ppermutes depend only on x, the
  local kernel depends only on x -> XLA's async collectives MAY overlap
  the halo with the local spMVM ("hope XLA overlaps it").
* ``pipeline`` — double-buffered gathered exchange with an EXPLICIT
  dependency structure: the remote operand is split per ring distance
  into stage operands at partition time; stage s's spMV consumes only
  its own compact buffer, and an ``optimization_barrier`` ties stage
  s+1's received buffer into stage s's input so the next exchange is
  materialised no later than the start of the current remote compute
  (one buffer ahead; deeper prefetch is left to the async scheduler).
  This is the paper's "explicit overlap" result as a dataflow graph
  instead of a dedicated MPI thread.

Multi-RHS: ``dist_matmat`` applies the same partition to a block of
``k`` right-hand sides (x of shape ``(n_global_pad, k)``), riding the
``pjds_matmat`` kernel; the gathered halo/reduction buffers simply
carry ``k`` columns per entry, so the matrix stream AND the per-entry
exchange set-up cost are amortised over ``k`` vectors (SELL-C-sigma
follow-up, arXiv:1307.6209 §"multi-vector").
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import formats as F
from repro._compat import shard_map
from repro.kernels import ops
from repro.kernels import ref as R

Mode = Literal["vector", "naive", "overlap", "pipeline"]
Halo = Literal["gathered", "full"]

__all__ = ["DistPJDS", "partition_csr", "dist_matvec", "make_dist_matvec",
           "dist_matmat", "make_dist_matmat", "padded_global_size",
           "halo_distances", "grid_shapes"]


def halo_distances(w: int) -> list[int]:
    """Signed ring distances of a width-w exchange, in slot order."""
    return [d for d in range(-w, w + 1) if d != 0]


def grid_shapes(n_dev: int) -> list[tuple[int, int]]:
    """All (gr, gc) factorizations of n_dev, 1-D row partition first."""
    out = [(n_dev // gc, gc) for gc in range(1, n_dev + 1)
           if n_dev % gc == 0]
    return out


def _col_ring_pairs(n_dev: int, gc: int, d: int) -> list[tuple[int, int]]:
    """src->dst ppermute pairs shifting by +d within each grid COLUMN
    (the x-halo ring).  gc == 1 recovers the 1-D device ring."""
    gr = n_dev // gc
    return [(q, ((q // gc + d) % gr) * gc + q % gc) for q in range(n_dev)]


def _row_ring_pairs(n_dev: int, gc: int, t: int) -> list[tuple[int, int]]:
    """src->dst ppermute pairs shifting by +t within each grid ROW
    (the partial-sum reduction ring)."""
    return [(q, (q // gc) * gc + (q % gc + t) % gc) for q in range(n_dev)]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DistPJDS:
    """Stacked per-device local/remote pJDS operands (leading axis = device)."""

    loc_val: jax.Array        # (P, loc_jds, b_r)
    loc_col: jax.Array
    loc_chunk_map: jax.Array  # (P, loc_jds // chunk_l)
    loc_row_block: jax.Array  # (P, loc_jds)
    rem_val: jax.Array        # (P, rem_jds, b_r)
    rem_col: jax.Array        # columns in EXT (halo buffer) coordinates
    rem_chunk_map: jax.Array
    rem_row_block: jax.Array
    inv_perm: jax.Array       # (P, blk_rows) sorted position of each block row
    send_idx: jax.Array       # (P, 2*halo_w, max_h) int32: local columns this
                              # device gathers for each outgoing ppermute
    recv_idx: jax.Array       # (P, 2*halo_w, max_h) int32: ext-buffer slots
                              # the received compact buffer scatters into
                              # (padding = ext_len sentinel, dropped)
    n_dev: int = dataclasses.field(metadata=dict(static=True))
    n_loc: int = dataclasses.field(metadata=dict(static=True))
    n_blocks: int = dataclasses.field(metadata=dict(static=True))
                              # kernel row blocks = blk_rows // b_r
                              # (blk_rows == gc * n_loc; n_loc // b_r in 1-D)
    b_r: int = dataclasses.field(metadata=dict(static=True))
    chunk_l: int = dataclasses.field(metadata=dict(static=True))
    halo_w: int = dataclasses.field(metadata=dict(static=True))
    halo_lens: tuple = dataclasses.field(metadata=dict(static=True))
                              # per-distance gathered halo sizes (elements),
                              # ordered as halo_distances(halo_w)
    n_rows: int = dataclasses.field(metadata=dict(static=True))  # unpadded
    sigma: int = dataclasses.field(metadata=dict(static=True))   # sort window
    loc_max_chunks: int = dataclasses.field(
        default=None, metadata=dict(static=True))  # prefetched-grid ceilings
    rem_max_chunks: int = dataclasses.field(
        default=None, metadata=dict(static=True))
    rem_chunk_l: int = dataclasses.field(
        default=None, metadata=dict(static=True))
        # tile height of the REMOTE operand when tuned independently of
        # the local one (None -> shares chunk_l); see repro.tune
    # ---- 2-D grid fields (all carry degenerate shapes in 1-D) ----------
    seg_pos: jax.Array = None
        # (P, gc, n_loc) int32: sorted positions of segment (j+s)%gc of
        # this device's row block; row 0 is the device's OWN y slice
        # (== the 1-D inv_perm when gc == 1)
    red_send_pos: jax.Array = None
        # (P, n_red, max_r) int32: positions in SORTED y of the partial
        # rows shipped for reduction distance red_dists[kk] (pad = 0,
        # dropped by the receiver)
    red_recv_idx: jax.Array = None
        # (P, n_red, max_r) int32: own-slice rows the received partials
        # scatter-ADD into (pad = n_loc sentinel, dropped)
    stage_val: jax.Array = None      # (P, S, stage_jds, b_r) per-distance
    stage_col: jax.Array = None      #   remote operands for mode="pipeline"
    stage_chunk_map: jax.Array = None  # (P, S, stage_jds // rem_chunk_l)
    stage_row_block: jax.Array = None  # (P, S, stage_jds)
    grid: tuple = dataclasses.field(
        default=None, metadata=dict(static=True))   # (gr, gc); None = (P, 1)
    red_w: int = dataclasses.field(
        default=0, metadata=dict(static=True))      # reduction ring width
    red_lens: tuple = dataclasses.field(
        default=(), metadata=dict(static=True))
        # per-distance gathered reduction sizes, ordered as
        # halo_distances(red_w)
    stage_dists: tuple = dataclasses.field(
        default=(), metadata=dict(static=True))
        # the signed ring distance of each pipeline stage operand
    stage_max_chunks: int = dataclasses.field(
        default=1, metadata=dict(static=True))

    @property
    def rem_chunk_l_eff(self) -> int:
        return self.chunk_l if self.rem_chunk_l is None else self.rem_chunk_l

    @property
    def grid_eff(self) -> tuple:
        return (self.n_dev, 1) if self.grid is None else self.grid

    @property
    def blk_rows(self) -> int:
        """Matrix rows of one device block (gc * n_loc)."""
        return self.n_blocks * self.b_r

    @property
    def n_global_pad(self) -> int:
        return self.n_dev * self.n_loc

    @property
    def ext_len(self) -> int:
        return (2 * self.halo_w + 1) * self.n_loc

    def comm_bytes_per_device(self, value_bytes: int = 8, k: int = 1,
                              halo: Halo = "gathered") -> int:
        """Exchange traffic per device per spMVM (send == recv volume),
        x halo plus partial-sum reduction.

        ``"gathered"`` reports the MEASURED per-neighbor set sizes the
        compressed exchange actually ships; ``"full"`` the full-slice /
        full-segment ring shifts of the bulk baseline.  ``k`` scales for
        multi-RHS (``dist_matmat``)."""
        if halo == "full":
            n_red = sum(1 for h in self.red_lens if h)
            return (2 * self.halo_w + n_red) * self.n_loc * value_bytes * k
        if halo != "gathered":
            raise ValueError(halo)
        return (sum(self.halo_lens) + sum(self.red_lens)) * value_bytes * k

    def comm_msgs_per_device(self, halo: Halo = "gathered") -> int:
        """Point-to-point messages per device per spMVM — the quantity
        the calibrated per-message fixed cost multiplies
        (``perf_model.t_link``)."""
        if halo == "full":
            return 2 * self.halo_w + sum(1 for h in self.red_lens if h)
        if halo != "gathered":
            raise ValueError(halo)
        return (sum(1 for h in self.halo_lens if h) +
                sum(1 for h in self.red_lens if h))


def padded_global_size(n_rows: int, n_dev: int, b_r: int = 128) -> int:
    per = b_r * n_dev
    return ((n_rows + per - 1) // per) * per


def _csr_row_slice(m: F.CSRMatrix, lo: int, hi: int, n_loc: int) -> F.CSRMatrix:
    """Rows [lo, hi) of m as a standalone CSR of n_loc rows (zero-padded)."""
    hi = min(hi, m.n_rows)
    counts = np.zeros(n_loc, dtype=np.int64)
    if hi > lo:
        counts[: hi - lo] = np.diff(m.indptr[lo : hi + 1])
    indptr = np.zeros(n_loc + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    s, e = (m.indptr[lo], m.indptr[hi]) if hi > lo else (0, 0)
    return F.CSRMatrix(indptr, m.indices[s:e].copy(), m.data[s:e].copy(),
                       (n_loc, m.shape[1]))


def _split_loc_rem(local: F.CSRMatrix, p: int, n_loc: int, n_dev: int,
                   halo_w: int):
    """1-D helper (used by ``repro.tune``): split a device's row slice
    into local-column and remote-column CSRs, remapping columns to
    slice-local / halo-buffer coordinates."""
    own_lo, own_hi = p * n_loc, (p + 1) * n_loc
    rl = np.diff(local.indptr)
    rows = np.repeat(np.arange(local.n_rows), rl)
    cols = local.indices.astype(np.int64)
    vals = local.data
    is_loc = (cols >= own_lo) & (cols < own_hi)

    loc = F.csr_from_coo(rows[is_loc], cols[is_loc] - own_lo, vals[is_loc],
                         (n_loc, n_loc), sum_duplicates=False)
    rcols = cols[~is_loc]
    owner = rcols // n_loc
    d = (owner - p + n_dev) % n_dev          # ring distance
    d = np.where(d > n_dev // 2, d - n_dev, d)
    ext = (d + halo_w) * n_loc + (rcols % n_loc)
    rem = F.csr_from_coo(rows[~is_loc], ext, vals[~is_loc],
                         (n_loc, (2 * halo_w + 1) * n_loc),
                         sum_duplicates=False)
    return loc, rem


def _pad_lead(a: np.ndarray, longest: int, edge: bool) -> np.ndarray:
    """Pad axis 0 to ``longest``.  Values/columns pad with ZERO (the
    padding sentinel: phantom chunks contribute nothing); chunk/row
    block maps pad with their LAST entry so they stay non-decreasing.
    A degenerate device whose map is EMPTY (it owns no stored entries)
    pads with zeros instead — every phantom chunk then targets block 0
    with all-zero values, a collective-compatible empty program."""
    if a.shape[0] == longest:
        return a
    if edge and a.shape[0] == 0:
        return np.zeros((longest,) + a.shape[1:], a.dtype)
    pad = [(0, longest - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
    return np.pad(a, pad, mode="edge" if edge else "constant")


def partition_csr(
    m: F.CSRMatrix,
    n_dev: int,
    b_r: int = 128,
    diag_align: int = 8,
    chunk_l: int = 8,
    halo_w: int | None = None,
    sigma: int | None = None,
    index_dtype="auto",
    rem_chunk_l: int | None = None,
    grid: tuple | None = None,
    build_stages: bool = True,
) -> DistPJDS:
    """Partition a global CSR onto an ``n_dev``-device grid as
    :class:`DistPJDS`.

    ``grid=(gr, gc)`` selects the 2-D block layout (``gr * gc == n_dev``,
    row-major device order); ``None`` is the 1-D row partition
    ``(n_dev, 1)``.  Device ``(i, j)`` stores ``A[I_i, J_j]`` — the x
    halo runs along grid columns (ring of ``gr``), the partial-sum y
    reduction along grid rows (ring of ``gc``); both are measured from
    the matrix and recorded as compressed gather/scatter index sets.

    ``halo_w`` is measured when not given; a matrix whose halo window
    reaches the ring radius effectively all-gathers — the pattern the
    paper's model flags as not multi-accelerator-friendly.  A purely
    block-diagonal matrix measures ``halo_w == 0`` (no exchange at all).

    ``sigma`` bounds the per-device row-sort window (SELL-C-sigma style;
    default 8*b_r, clamped to the device block height).

    ``index_dtype="auto"`` compresses the stored column-index streams:
    the local operand addresses only its n_loc-column slice and the
    remote operand only the (2*halo_w+1)*n_loc ext buffer, so the
    partition STRUCTURALLY bounds the index span — int16 indices
    whenever the per-device slice fits, however large the global matrix
    is.  2-D grids tighten the bound further (both spans shrink with
    the grid), which is where the paper's distributed scaling and the
    compressed-stream work compound.

    ``rem_chunk_l`` gives the REMOTE (halo-coupling) operand its own
    tile height; ``None`` shares ``chunk_l``.  ``repro.tune`` measures
    the two independently.

    ``build_stages`` additionally splits the remote operand per ring
    distance into the stage operands ``mode="pipeline"`` consumes
    (costs roughly a second copy of the remote operand; set False to
    drop it when the pipeline mode is never used).
    """
    if m.shape[0] != m.shape[1]:
        raise ValueError("distributed spMVM expects a square matrix")
    if grid is None:
        gr, gc = n_dev, 1
    else:
        gr, gc = (int(grid[0]), int(grid[1]))
        if gr < 1 or gc < 1 or gr * gc != n_dev:
            raise ValueError(f"grid {grid!r} incompatible with n_dev={n_dev}")
    n_pad = padded_global_size(m.n_rows, n_dev, b_r)
    n_loc = n_pad // n_dev
    blk_rows = gc * n_loc

    # COO view of each device block A[I_i, J_j], annotated with the
    # signed grid-column ring distance of every entry's x owner.
    row_slices = [_csr_row_slice(m, i * blk_rows, (i + 1) * blk_rows,
                                 blk_rows) for i in range(gr)]
    dev_rows, dev_cols, dev_vals, dev_d = [], [], [], []
    needs = []
    for p in range(n_dev):
        i, j = divmod(p, gc)
        sl = row_slices[i]
        rl = np.diff(sl.indptr)
        rows = np.repeat(np.arange(blk_rows), rl)
        cols = sl.indices.astype(np.int64)
        vals = sl.data
        owner = cols // n_loc                 # device owning x[col]
        keep = owner % gc == j                # this device's column block
        rows, cols, vals, owner = (rows[keep], cols[keep], vals[keep],
                                   owner[keep])
        d = (owner // gc - i) % gr            # grid-column ring distance
        if gr > 1:
            d = np.where(d > gr // 2, d - gr, d)
        dev_rows.append(rows)
        dev_cols.append(cols)
        dev_vals.append(vals)
        dev_d.append(d)
        nd = {}
        for dd in np.unique(d):
            if dd == 0:
                continue
            nd[int(dd)] = np.unique(cols[d == dd] % n_loc)
        needs.append(nd)

    measured = max((max((abs(d) for d in nd), default=0) for nd in needs),
                   default=0)
    if halo_w is None:
        halo_w = measured
    else:
        halo_w = int(halo_w)
        if halo_w < measured:
            raise ValueError(
                f"halo_w={halo_w} too small: matrix couples devices at ring "
                f"distance {measured}")
    if halo_w > gr // 2 and gr > 1:
        halo_w = gr // 2
    if gr == 1:
        halo_w = 0

    dists = halo_distances(halo_w)
    halo_lens = tuple(
        max((len(nd.get(d, ())) for nd in needs), default=0) for d in dists)
    ext_len = (2 * halo_w + 1) * n_loc
    max_h = max(halo_lens, default=0)
    # send_idx[p, i]: the local columns device p gathers when the exchange
    # for distance dists[i] fires (p serves the grid-column neighbor at
    # ring distance -d, so the gather list is THAT device's need set).
    # recv_idx[p, i]: where the compact buffer received from distance +d
    # lands in p's ext buffer.  Pad gathers with 0 (valid, ignored
    # downstream) and scatters with the ext_len sentinel (dropped).
    send_idx = np.zeros((n_dev, len(dists), max_h), dtype=np.int32)
    recv_idx = np.full((n_dev, len(dists), max_h), ext_len, dtype=np.int32)
    for k, d in enumerate(dists):
        for p in range(n_dev):
            i, j = divmod(p, gc)
            served = ((i - d) % gr) * gc + j
            snd = needs[served].get(d)
            if snd is not None and len(snd):
                send_idx[p, k, : len(snd)] = snd
            rcv = needs[p].get(d)
            if rcv is not None and len(rcv):
                recv_idx[p, k, : len(rcv)] = (d + halo_w) * n_loc + rcv

    # Partial-sum reduction need sets: which rows of each FOREIGN
    # segment of its row block this device actually touches, by signed
    # grid-row ring distance t (the SENDER's structure decides — the
    # receiver scatter-adds exactly what the sender ships).
    red_needs = []
    for p in range(n_dev):
        i, j = divmod(p, gc)
        seg = dev_rows[p] // n_loc
        t = (seg - j) % gc
        if gc > 1:
            t = np.where(t > gc // 2, t - gc, t)
        nd = {}
        for tt in np.unique(t):
            if tt == 0:
                continue
            nd[int(tt)] = np.unique(dev_rows[p][t == tt] % n_loc)
        red_needs.append(nd)
    red_w = max((max((abs(t) for t in nd), default=0) for nd in red_needs),
                default=0)
    red_dists = halo_distances(red_w)
    red_lens = tuple(
        max((len(nd.get(t, ())) for nd in red_needs), default=0)
        for t in red_dists)
    max_r = max(red_lens, default=0)

    sig = min(int(sigma) if sigma is not None else 8 * b_r, blk_rows)
    sig = max(sig, 1)

    rcl = chunk_l if rem_chunk_l is None else int(rem_chunk_l)
    stage_dists = tuple(d for k, d in enumerate(dists)
                        if build_stages and halo_lens[k] > 0)
    locs, rems, invs, seg_pos = [], [], [], []
    stage_ops = []
    for p in range(n_dev):
        i, j = divmod(p, gc)
        rows, cols, vals, d = (dev_rows[p], dev_cols[p], dev_vals[p],
                               dev_d[p])
        is_loc = d == 0
        loc = F.csr_from_coo(rows[is_loc], cols[is_loc] % n_loc,
                             vals[is_loc], (blk_rows, n_loc),
                             sum_duplicates=False)
        ext = (d[~is_loc] + halo_w) * n_loc + (cols[~is_loc] % n_loc)
        rem = F.csr_from_coo(rows[~is_loc], ext, vals[~is_loc],
                             (blk_rows, ext_len), sum_duplicates=False)
        # One shared per-device row sort (by TOTAL row length) so all
        # partial results add in the same permuted order — windowed to
        # sigma rows (SELL-C-sigma) so the inverse permutation stays
        # window-local.  Local and remote operands may carry different
        # tile heights; each pads its own jagged diagonals.
        total_rl = loc.row_lengths() + rem.row_lengths()
        perm = F.windowed_sort_perm(total_rl, sig)
        pj_loc = F._pjds_with_perm(loc, perm, b_r,
                                   max(diag_align, chunk_l), False,
                                   index_dtype)
        pj_rem = F._pjds_with_perm(rem, perm, b_r,
                                   max(diag_align, rcl), False,
                                   index_dtype)
        locs.append(ops.to_device_pjds(pj_loc, chunk_l))
        rems.append(ops.to_device_pjds(pj_rem, rcl))
        stages = []
        for ds in stage_dists:
            ss = ~is_loc & (d == ds)
            st = F.csr_from_coo(rows[ss], cols[ss] % n_loc, vals[ss],
                                (blk_rows, n_loc), sum_duplicates=False)
            pj_st = F._pjds_with_perm(st, perm, b_r,
                                      max(diag_align, rcl), False,
                                      index_dtype)
            stages.append(ops.to_device_pjds(pj_st, rcl))
        stage_ops.append(stages)
        inv = np.empty(blk_rows, dtype=np.int32)
        inv[perm] = np.arange(blk_rows, dtype=np.int32)
        invs.append(inv)
        seg_pos.append(np.stack(
            [inv[((j + s) % gc) * n_loc : ((j + s) % gc + 1) * n_loc]
             for s in range(gc)]))

    # Reduction gather positions (into SORTED y) and scatter-add rows.
    red_send_pos = np.zeros((n_dev, len(red_dists), max_r), dtype=np.int32)
    red_recv_idx = np.full((n_dev, len(red_dists), max_r), n_loc,
                           dtype=np.int32)
    for kk, t in enumerate(red_dists):
        for p in range(n_dev):
            i, j = divmod(p, gc)
            snd = red_needs[p].get(t)
            if snd is not None and len(snd):
                jt = (j + t) % gc
                red_send_pos[p, kk, : len(snd)] = invs[p][jt * n_loc + snd]
            src = i * gc + (j - t) % gc
            rcv = red_needs[src].get(t)
            if rcv is not None and len(rcv):
                red_recv_idx[p, kk, : len(rcv)] = rcv

    def _stack(devs, attr, edge=False):
        # Devices pad to one shared leading extent (see _pad_lead).
        arrs = [np.asarray(getattr(dv, attr)) for dv in devs]
        longest = max(a.shape[0] for a in arrs)
        return jnp.asarray(np.stack(
            [_pad_lead(a, longest, edge) for a in arrs]))

    def _stack_stages(attr, edge=False):
        # (P, S, ...) stack across devices AND stages, one shared extent.
        if not stage_dists:
            like = np.asarray(getattr(locs[0], attr))
            return jnp.zeros((n_dev, 0, 0) + like.shape[1:], like.dtype)
        arrs = [[np.asarray(getattr(st, attr)) for st in stages]
                for stages in stage_ops]
        longest = max(a.shape[0] for row in arrs for a in row)
        return jnp.asarray(np.stack(
            [np.stack([_pad_lead(a, longest, edge) for a in row])
             for row in arrs]))

    n_blocks = blk_rows // b_r

    def _max_chunks(devs) -> int:
        # Static per-block chunk ceiling ACROSS devices, including the
        # phantom chunks the shared-extent padding appends to each
        # device's last block.
        longest = max(int(dv.chunk_map.shape[0]) for dv in devs)
        mx = 1
        for dv in devs:
            cm = _pad_lead(np.asarray(dv.chunk_map), longest, edge=True)
            if len(cm):
                mx = max(mx, int(np.bincount(cm, minlength=1).max()))
        return mx

    return DistPJDS(
        loc_val=_stack(locs, "val"),
        loc_col=_stack(locs, "col_idx"),
        loc_chunk_map=_stack(locs, "chunk_map", edge=True),
        loc_row_block=_stack(locs, "row_block", edge=True),
        rem_val=_stack(rems, "val"),
        rem_col=_stack(rems, "col_idx"),
        rem_chunk_map=_stack(rems, "chunk_map", edge=True),
        rem_row_block=_stack(rems, "row_block", edge=True),
        inv_perm=jnp.asarray(np.stack(invs)),
        send_idx=jnp.asarray(send_idx),
        recv_idx=jnp.asarray(recv_idx),
        n_dev=n_dev,
        n_loc=n_loc,
        n_blocks=n_blocks,
        b_r=b_r,
        chunk_l=chunk_l,
        halo_w=halo_w,
        halo_lens=halo_lens,
        n_rows=m.n_rows,
        sigma=sig,
        loc_max_chunks=_max_chunks(locs),
        rem_max_chunks=_max_chunks(rems),
        rem_chunk_l=None if rcl == chunk_l else rcl,
        seg_pos=jnp.asarray(np.stack(seg_pos)),
        red_send_pos=jnp.asarray(red_send_pos),
        red_recv_idx=jnp.asarray(red_recv_idx),
        stage_val=_stack_stages("val"),
        stage_col=_stack_stages("col_idx"),
        stage_chunk_map=_stack_stages("chunk_map", edge=True),
        stage_row_block=_stack_stages("row_block", edge=True),
        grid=None if gc == 1 else (gr, gc),
        red_w=red_w,
        red_lens=red_lens,
        stage_dists=stage_dists,
        stage_max_chunks=(max((_max_chunks([st for stages in stage_ops
                                            for st in stages]),), default=1)
                          if stage_dists else 1),
    )


# --------------------------------------------------------------------------
# The shard_map'd operator
# --------------------------------------------------------------------------
def _local_spmv(val, col, chunk_map, row_block, x, n_blocks, b_r, chunk_l,
                backend, max_chunks=None):
    a = ops.PJDSDevice(val=val, col_idx=col, chunk_map=chunk_map,
                       row_block=row_block, n_blocks=n_blocks, b_r=b_r,
                       chunk_l=chunk_l, max_chunks=max_chunks)
    if x.ndim == 2:
        return ops.pjds_matmat(a, x, backend=backend)
    return ops.pjds_matvec(a, x, backend=backend)


def _exchange_halo_full(x_blk, axis: str, n_dev: int, halo_w: int,
                        gc: int = 1):
    """Bulk ring ppermute halo: ext buffer = x slices of the grid-column
    neighbors at ring distances -halo_w .. +halo_w."""
    parts = []
    for d in range(halo_w, 0, -1):  # from distance -d (send own slice +d)
        parts.append(jax.lax.ppermute(
            x_blk, axis, _col_ring_pairs(n_dev, gc, d)))
    parts.append(x_blk)
    for d in range(1, halo_w + 1):  # from distance +d
        parts.append(jax.lax.ppermute(
            x_blk, axis, _col_ring_pairs(n_dev, gc, -d)))
    return jnp.concatenate(parts)


# Backwards-compatible alias (pre-gathered name).
_exchange_halo = _exchange_halo_full


def _exchange_halo_gathered(x_blk, send_idx, recv_idx, axis: str, n_dev: int,
                            halo_w: int, halo_lens: tuple, gc: int = 1):
    """Compressed halo: gather referenced entries -> ppermute compact
    per-neighbor buffers -> scatter into the dense ext buffer.

    The ext buffer keeps the same (2w+1)*n_loc coordinates as the bulk
    exchange (slot w — this device's own slice — stays zero; remote
    columns never point there), so ``rem_col`` is identical either way.
    Distances whose measured halo is empty ship nothing at all.
    """
    n_loc = x_blk.shape[0]
    ext = jnp.zeros(((2 * halo_w + 1) * n_loc,) + x_blk.shape[1:],
                    x_blk.dtype)
    for i, d in enumerate(halo_distances(halo_w)):
        h = halo_lens[i]
        if h == 0:
            continue
        buf = x_blk[send_idx[i, :h]]
        buf = jax.lax.ppermute(buf, axis, _col_ring_pairs(n_dev, gc, -d))
        ext = ext.at[recv_idx[i, :h]].set(buf, mode="drop")
    return ext


def _reduce_partials(dist: DistPJDS, y, seg_pos, red_send_pos, red_recv_idx,
                     *, axis: str, halo: Halo):
    """Fold the grid-row partial-sum reduction into the kernel epilogue.

    ``y`` is this device's blk_rows partial result in the SORTED basis;
    the epilogue gathers the device's own y slice and the per-neighbor
    partial rows directly from it (no dense unpermute), ships the
    partials along the grid-row ring, and scatter-adds what arrives.
    """
    gr, gc = dist.grid_eff
    red_dists = halo_distances(dist.red_w)
    if halo == "full":
        # bulk baseline: ship whole partial segments.  Distances whose
        # measured coupling is empty must still be SKIPPED: on an even
        # ring, +gc/2 and -gc/2 are the same partner and the wrap
        # convention parks all coupling on +gc/2 — shipping the empty
        # mirror distance would double-count the shared segment.
        y_own = y[seg_pos[0]]
        for kk, t in enumerate(red_dists):
            if dist.red_lens[kk] == 0:
                continue
            buf = y[seg_pos[t % gc]]
            buf = jax.lax.ppermute(buf, axis,
                                   _row_ring_pairs(dist.n_dev, gc, t))
            y_own = y_own + buf
        return y_own
    y_own, bufs = R.partial_reduce_epilogue_ref(
        y, seg_pos[0], red_send_pos, dist.red_lens)
    for kk, t in enumerate(red_dists):
        if dist.red_lens[kk] == 0:
            continue
        buf = jax.lax.ppermute(bufs[kk], axis,
                               _row_ring_pairs(dist.n_dev, gc, t))
        h = dist.red_lens[kk]
        y_own = y_own.at[red_recv_idx[kk, :h]].add(buf, mode="drop")
    return y_own


def dist_matvec_local(dist: DistPJDS, x_blk: jax.Array, *, axis: str,
                      mode: Mode = "overlap",
                      backend: ops.Backend = "ref",
                      halo: Halo = "gathered") -> jax.Array:
    """Per-shard body: x_blk is this device's (n_loc,) or (n_loc, k) slice;
    operand leaves of ``dist`` carry a leading length-1 device axis (from
    shard_map)."""
    sq = lambda a: a[0]
    gr, gc = dist.grid_eff
    n_loc = dist.n_loc
    loc_spmv = functools.partial(_local_spmv, n_blocks=dist.n_blocks,
                                 b_r=dist.b_r, chunk_l=dist.chunk_l,
                                 backend=backend,
                                 max_chunks=dist.loc_max_chunks)
    rem_spmv = functools.partial(_local_spmv, n_blocks=dist.n_blocks,
                                 b_r=dist.b_r, chunk_l=dist.rem_chunk_l_eff,
                                 backend=backend,
                                 max_chunks=dist.rem_max_chunks)
    loc_args = (sq(dist.loc_val), sq(dist.loc_col), sq(dist.loc_chunk_map),
                sq(dist.loc_row_block))
    rem_args = (sq(dist.rem_val), sq(dist.rem_col), sq(dist.rem_chunk_map),
                sq(dist.rem_row_block))

    if halo == "gathered":
        exchange = functools.partial(
            _exchange_halo_gathered, send_idx=sq(dist.send_idx),
            recv_idx=sq(dist.recv_idx), axis=axis, n_dev=dist.n_dev,
            halo_w=dist.halo_w, halo_lens=dist.halo_lens, gc=gc)
        no_halo = sum(dist.halo_lens) == 0
    elif halo == "full":
        exchange = functools.partial(
            _exchange_halo_full, axis=axis, n_dev=dist.n_dev,
            halo_w=dist.halo_w, gc=gc)
        no_halo = dist.halo_w == 0
    else:
        raise ValueError(halo)

    if no_halo:
        # Block-diagonal-in-x partition: no halo crosses the network, so
        # every mode degenerates to the local kernel (the grid-row
        # reduction below may still communicate when gc > 1).
        y = loc_spmv(*loc_args, x_blk)
    elif mode == "vector":
        # comm, then (implicitly fused) full spMVM — bulk synchronous.
        ext = exchange(x_blk)
        ext, x_dep = jax.lax.optimization_barrier((ext, x_blk))
        y = loc_spmv(*loc_args, x_dep) + rem_spmv(*rem_args, ext)
    elif mode == "naive":
        # local kernel first, comm strictly after (no async progress).
        y_loc = loc_spmv(*loc_args, x_blk)
        x_after, _ = jax.lax.optimization_barrier((x_blk, y_loc))
        y = y_loc + rem_spmv(*rem_args, exchange(x_after))
    elif mode == "overlap":
        # task mode: halo and local kernel are independent -> overlapped.
        ext = exchange(x_blk)
        y_loc = loc_spmv(*loc_args, x_blk)
        y = y_loc + rem_spmv(*rem_args, ext)
    elif mode == "pipeline":
        y = _pipeline_body(dist, x_blk, loc_spmv, loc_args, axis=axis,
                           halo=halo, backend=backend, gc=gc)
    else:
        raise ValueError(mode)

    if gc == 1:
        # 1-D: the device owns its whole row block — just undo the sort.
        y = y[sq(dist.seg_pos)[0]]
    else:
        y = _reduce_partials(dist, y, sq(dist.seg_pos),
                             sq(dist.red_send_pos), sq(dist.red_recv_idx),
                             axis=axis, halo=halo)
    return y.astype(x_blk.dtype)


def _pipeline_body(dist: DistPJDS, x_blk, loc_spmv, loc_args, *, axis: str,
                   halo: Halo, backend, gc: int):
    """Double-buffered halo pipeline (explicit dependency structure).

    Every stage's compact exchange buffer is gathered up front; the
    ``optimization_barrier`` before stage s's remote spMV ties in stage
    s+1's RECEIVED buffer, so exchange s+1 is materialised no later than
    the start of compute s — the guaranteed one-buffer-ahead schedule of
    the paper's explicit-overlap mode (deeper prefetch remains legal).
    """
    if not dist.stage_dists:
        if sum(dist.halo_lens) > 0:
            raise ValueError(
                "mode='pipeline' needs per-distance stage operands; "
                "repartition with build_stages=True")
        return loc_spmv(*loc_args, x_blk)
    sq = lambda a: a[0]
    n_loc = dist.n_loc
    dists = halo_distances(dist.halo_w)
    send_idx, recv_idx = sq(dist.send_idx), sq(dist.recv_idx)
    stage_spmv = functools.partial(_local_spmv, n_blocks=dist.n_blocks,
                                   b_r=dist.b_r,
                                   chunk_l=dist.rem_chunk_l_eff,
                                   backend=backend,
                                   max_chunks=dist.stage_max_chunks)
    bufs = []
    for d in dist.stage_dists:
        k = dists.index(d)
        pairs = _col_ring_pairs(dist.n_dev, gc, -d)
        if halo == "gathered":
            h = dist.halo_lens[k]
            buf = x_blk[send_idx[k, :h]]
        else:
            buf = x_blk
        bufs.append(jax.lax.ppermute(buf, axis, pairs))

    y = loc_spmv(*loc_args, x_blk)
    for s, d in enumerate(dist.stage_dists):
        k = dists.index(d)
        if s + 1 < len(bufs):
            # double buffer: the NEXT stage's received buffer must exist
            # before this stage's remote compute is allowed to start.
            bufs[s], bufs[s + 1] = jax.lax.optimization_barrier(
                (bufs[s], bufs[s + 1]))
        if halo == "gathered":
            h = dist.halo_lens[k]
            loc_cols = recv_idx[k, :h] - (d + dist.halo_w) * n_loc
            ext_s = jnp.zeros((n_loc,) + x_blk.shape[1:], x_blk.dtype
                              ).at[loc_cols].set(bufs[s], mode="drop")
        else:
            ext_s = bufs[s]
        y = y + stage_spmv(sq(dist.stage_val)[s], sq(dist.stage_col)[s],
                           sq(dist.stage_chunk_map)[s],
                           sq(dist.stage_row_block)[s], ext_s)
    return y


def _make_dist_op(dist: DistPJDS, mesh: Mesh, axis: str, mode: Mode,
                  backend: ops.Backend, halo: Halo, multi_rhs: bool):
    n_dev = dist.n_dev
    if mesh.shape[axis] != n_dev:
        raise ValueError(f"mesh axis {axis}={mesh.shape[axis]} != {n_dev}")

    operand_specs = DistPJDS(
        **{f.name: (P(axis) if getattr(dist, f.name) is not None else None)
           for f in dataclasses.fields(DistPJDS)
           if f.metadata.get("static") is not True},
        **{f.name: getattr(dist, f.name)
           for f in dataclasses.fields(DistPJDS)
           if f.metadata.get("static") is True},
    )
    x_spec = P(axis, None) if multi_rhs else P(axis)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(operand_specs, x_spec),
        out_specs=x_spec,
    )
    def _mv(d, x_blk):
        return dist_matvec_local(d, x_blk, axis=axis, mode=mode,
                                 backend=backend, halo=halo)

    return functools.partial(_mv, dist)


def make_dist_matvec(dist: DistPJDS, mesh: Mesh, axis: str = "data",
                     mode: Mode = "overlap",
                     backend: ops.Backend = "ref",
                     halo: Halo = "gathered"):
    """Build a jit-able y = A x over a mesh axis.  x: (n_global_pad,)
    sharded along ``axis``; returns y with the same sharding.

    .. deprecated::
        Kept as the raw closure under the operator protocol — new code
        should build ``core.operator.dist_operator(m, mesh)`` instead,
        which wraps this exact function and adds ``op.T`` (transposed
        partition), ``diagonal()`` for Jacobi preconditioning, and
        x-gradients.  ``backend="auto"`` resolves in
        ``kernels.ops.resolve_backend``.
    """
    warnings.warn(
        "make_dist_matvec is deprecated: use "
        "core.operator.dist_operator(m, mesh) — the operator wraps this "
        "closure and adds .T, diagonal() and gradients — or repro.solve "
        "for whole systems", DeprecationWarning, stacklevel=2)
    return _make_dist_op(dist, mesh, axis, mode, backend, halo,
                         multi_rhs=False)


def make_dist_matmat(dist: DistPJDS, mesh: Mesh, axis: str = "data",
                     mode: Mode = "overlap",
                     backend: ops.Backend = "ref",
                     halo: Halo = "gathered"):
    """Build a jit-able Y = A X for a block of RHS vectors.
    X: (n_global_pad, k) sharded (axis, None); returns Y alike.

    .. deprecated::
        Shim — see :func:`make_dist_matvec`; prefer
        ``core.operator.dist_operator(m, mesh).matmat``.
    """
    warnings.warn(
        "make_dist_matmat is deprecated: use "
        "core.operator.dist_operator(m, mesh).matmat instead",
        DeprecationWarning, stacklevel=2)
    return _make_dist_op(dist, mesh, axis, mode, backend, halo,
                         multi_rhs=True)


def dist_matvec(dist: DistPJDS, x: jax.Array, mesh: Mesh, axis: str = "data",
                mode: Mode = "overlap",
                backend: ops.Backend = "ref",
                halo: Halo = "gathered") -> jax.Array:
    return _make_dist_op(dist, mesh, axis, mode, backend, halo,
                         multi_rhs=False)(x)


def dist_matmat(dist: DistPJDS, x: jax.Array, mesh: Mesh, axis: str = "data",
                mode: Mode = "overlap",
                backend: ops.Backend = "ref",
                halo: Halo = "gathered") -> jax.Array:
    if x.ndim != 2:
        raise ValueError(f"dist_matmat expects x of shape (n, k); got "
                         f"{x.shape}")
    return _make_dist_op(dist, mesh, axis, mode, backend, halo,
                         multi_rhs=True)(x)
