"""Distributed-memory spMVM / spMM (paper §3) on a JAX device mesh.

Row-wise partitioning exactly as in the paper: device ``p`` owns a
contiguous slice of rows and the conformal slice of the RHS/LHS vectors.
Each device's rows are split into

* ``A_loc`` — entries whose column falls inside the device's own RHS
  slice (the block-diagonal part; needs no communication), and
* ``A_rem`` — entries pointing into other devices' slices (the paper's
  "non-local" part; its columns define the halo).

Both parts are stored in SELL-C-sigma-windowed blocked storage — going
one step beyond the paper, whose multi-GPU code still used ELLPACK-R and
left "an implementation of the pJDS format in the multi-GPU code" as
future work (paper §3, Conclusions).  The row sort is windowed INSIDE
each device (sigma rows per window, default 8*b_r; ``sigma >= n_loc``
recovers the device-local global sort, i.e. per-device pJDS), so no
permutation crosses the network, the inverse permutation applied to y
after the kernels is window-local, and the halo/RHS access pattern keeps
the locality of the original row ordering up to sigma (DESIGN.md §3/§6).

Halo exchange (paper §3: "local gather + point-to-point") has two
implementations, selected by ``halo=``:

* ``"gathered"`` (default) — the paper-faithful compressed exchange: at
  partition time each device records, per ring neighbor, WHICH of its
  columns that neighbor actually references (``send_idx``), padded to a
  static per-neighbor maximum.  At run time each device gathers exactly
  those entries, ``ppermute``s the compact buffers, and scatters the
  received values into a dense ext buffer (``recv_idx``; padding lanes
  carry an out-of-range sentinel and are dropped).  Communication volume
  is the MEASURED coupling ``sum(halo_lens)`` elements, not the slice
  size — the quantity the paper's Eq. 2-4 link term should see.
* ``"full"`` — the previous behaviour: ring-shift the whole x slice
  ``2*halo_w`` times.  Kept as the bulk baseline ``benchmarks/bench_dist``
  compares against.

A purely block-diagonal matrix measures ``halo_w == 0`` and skips the
exchange (and the remote kernel) entirely.

Three communication modes (paper §3.1), distinguished by their data
dependences — inspect the compiled HLO to see the schedules differ:

* ``vector``  — bulk-synchronous: halo exchange completes (barrier), then
  one combined spMVM pass.
* ``naive``   — split kernels, but the halo exchange is *ordered after*
  the local kernel (an ``optimization_barrier`` models MPI libraries
  without asynchronous progress: the transfer really happens at the
  Wait).  The paper predicts no benefit over vector mode; the serialized
  schedule reproduces that.
* ``overlap`` — task mode: the halo ppermutes depend only on x, the local
  kernel depends only on x -> XLA's async collectives overlap the halo
  with the local spMVM.  This is the TPU-idiomatic equivalent of the
  paper's dedicated-MPI-thread task mode.

Multi-RHS: ``dist_matmat`` applies the same partition to a block of
``k`` right-hand sides (x of shape ``(n_global_pad, k)``), riding the
``pjds_matmat`` kernel; the gathered halo buffers simply carry ``k``
columns per entry, so the matrix stream AND the per-entry exchange
set-up cost are amortised over ``k`` vectors (SELL-C-sigma follow-up,
arXiv:1307.6209 §"multi-vector").  The block solvers in
``core.solvers`` (block-CG / block-Lanczos) run on top of it.
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import formats as F
from repro._compat import shard_map
from repro.kernels import ops

Mode = Literal["vector", "naive", "overlap"]
Halo = Literal["gathered", "full"]

__all__ = ["DistPJDS", "partition_csr", "dist_matvec", "make_dist_matvec",
           "dist_matmat", "make_dist_matmat", "padded_global_size",
           "halo_distances"]


def halo_distances(halo_w: int) -> list[int]:
    """Signed ring distances of the halo, in ext-buffer slot order."""
    return [d for d in range(-halo_w, halo_w + 1) if d != 0]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DistPJDS:
    """Stacked per-device local/remote pJDS operands (leading axis = device)."""

    loc_val: jax.Array        # (P, loc_jds, b_r)
    loc_col: jax.Array
    loc_chunk_map: jax.Array  # (P, loc_jds // chunk_l)
    loc_row_block: jax.Array  # (P, loc_jds)
    rem_val: jax.Array        # (P, rem_jds, b_r)
    rem_col: jax.Array        # columns in EXT (halo buffer) coordinates
    rem_chunk_map: jax.Array
    rem_row_block: jax.Array
    inv_perm: jax.Array       # (P, n_loc) undo the device-local row sort
    send_idx: jax.Array       # (P, 2*halo_w, max_h) int32: local columns this
                              # device gathers for each outgoing ppermute
    recv_idx: jax.Array       # (P, 2*halo_w, max_h) int32: ext-buffer slots
                              # the received compact buffer scatters into
                              # (padding = ext_len sentinel, dropped)
    n_dev: int = dataclasses.field(metadata=dict(static=True))
    n_loc: int = dataclasses.field(metadata=dict(static=True))
    n_blocks: int = dataclasses.field(metadata=dict(static=True))
    b_r: int = dataclasses.field(metadata=dict(static=True))
    chunk_l: int = dataclasses.field(metadata=dict(static=True))
    halo_w: int = dataclasses.field(metadata=dict(static=True))
    halo_lens: tuple = dataclasses.field(metadata=dict(static=True))
                              # per-distance gathered halo sizes (elements),
                              # ordered as halo_distances(halo_w)
    n_rows: int = dataclasses.field(metadata=dict(static=True))  # unpadded
    sigma: int = dataclasses.field(metadata=dict(static=True))   # sort window
    loc_max_chunks: int = dataclasses.field(
        default=None, metadata=dict(static=True))  # prefetched-grid ceilings
    rem_max_chunks: int = dataclasses.field(
        default=None, metadata=dict(static=True))
    rem_chunk_l: int = dataclasses.field(
        default=None, metadata=dict(static=True))
        # tile height of the REMOTE operand when tuned independently of
        # the local one (None -> shares chunk_l); see repro.tune

    @property
    def rem_chunk_l_eff(self) -> int:
        return self.chunk_l if self.rem_chunk_l is None else self.rem_chunk_l

    @property
    def n_global_pad(self) -> int:
        return self.n_dev * self.n_loc

    @property
    def ext_len(self) -> int:
        return (2 * self.halo_w + 1) * self.n_loc

    def comm_bytes_per_device(self, value_bytes: int = 8, k: int = 1,
                              halo: Halo = "gathered") -> int:
        """Halo traffic per device per spMVM (send == recv volume).

        ``"gathered"`` reports the MEASURED per-neighbor halo sizes the
        compressed exchange actually ships; ``"full"`` the 2*halo_w
        full-slice ring shifts of the bulk baseline.  ``k`` scales for
        multi-RHS (``dist_matmat``)."""
        if halo == "full":
            return 2 * self.halo_w * self.n_loc * value_bytes * k
        if halo != "gathered":
            raise ValueError(halo)
        return sum(self.halo_lens) * value_bytes * k


def padded_global_size(n_rows: int, n_dev: int, b_r: int = 128) -> int:
    per = b_r * n_dev
    return ((n_rows + per - 1) // per) * per


def _csr_row_slice(m: F.CSRMatrix, lo: int, hi: int, n_loc: int) -> F.CSRMatrix:
    """Rows [lo, hi) of m as a standalone CSR of n_loc rows (zero-padded)."""
    hi = min(hi, m.n_rows)
    counts = np.zeros(n_loc, dtype=np.int64)
    if hi > lo:
        counts[: hi - lo] = np.diff(m.indptr[lo : hi + 1])
    indptr = np.zeros(n_loc + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    s, e = (m.indptr[lo], m.indptr[hi]) if hi > lo else (0, 0)
    return F.CSRMatrix(indptr, m.indices[s:e].copy(), m.data[s:e].copy(),
                       (n_loc, m.shape[1]))


def _split_loc_rem(local: F.CSRMatrix, p: int, n_loc: int, n_dev: int,
                   halo_w: int):
    """Split a device's row slice into local-column and remote-column CSRs,
    remapping columns to slice-local / halo-buffer coordinates."""
    own_lo, own_hi = p * n_loc, (p + 1) * n_loc
    rl = np.diff(local.indptr)
    rows = np.repeat(np.arange(local.n_rows), rl)
    cols = local.indices.astype(np.int64)
    vals = local.data
    is_loc = (cols >= own_lo) & (cols < own_hi)

    loc = F.csr_from_coo(rows[is_loc], cols[is_loc] - own_lo, vals[is_loc],
                         (n_loc, n_loc), sum_duplicates=False)
    rcols = cols[~is_loc]
    owner = rcols // n_loc
    d = (owner - p + n_dev) % n_dev          # ring distance
    d = np.where(d > n_dev // 2, d - n_dev, d)
    ext = (d + halo_w) * n_loc + (rcols % n_loc)
    rem = F.csr_from_coo(rows[~is_loc], ext, vals[~is_loc],
                         (n_loc, (2 * halo_w + 1) * n_loc),
                         sum_duplicates=False)
    return loc, rem


def partition_csr(
    m: F.CSRMatrix,
    n_dev: int,
    b_r: int = 128,
    diag_align: int = 8,
    chunk_l: int = 8,
    halo_w: int | None = None,
    sigma: int | None = None,
    index_dtype="auto",
    rem_chunk_l: int | None = None,
) -> DistPJDS:
    """Row-partition a global CSR onto ``n_dev`` devices as :class:`DistPJDS`.

    ``halo_w`` is measured from the matrix when not given; a matrix whose
    halo window reaches n_dev//2 effectively all-gathers — the pattern the
    paper's model flags as not multi-accelerator-friendly.  A purely
    block-diagonal matrix measures ``halo_w == 0`` (no exchange at all).

    Alongside the window, the partitioner records the per-neighbor
    gather/scatter index sets of the compressed halo exchange: which of
    each device's columns every ring neighbor actually references,
    padded to the static per-distance maximum (``halo_lens``).

    ``sigma`` bounds the per-device row-sort window (SELL-C-sigma style;
    default 8*b_r).  ``sigma >= n_loc`` recovers the device-local global
    sort, i.e. per-device pJDS.

    ``index_dtype="auto"`` compresses the stored column-index streams:
    the local operand addresses only its n_loc-column slice and the
    remote operand only the (2*halo_w+1)*n_loc ext buffer, so the row
    partition STRUCTURALLY bounds the index span — int16 indices
    whenever the per-device slice fits, however large the global matrix
    is.  This is where the paper's distributed scaling and the
    compressed-stream work compound.

    ``rem_chunk_l`` gives the REMOTE (halo-coupling) operand its own
    tile height — its rows are structurally much shorter than the local
    block-diagonal rows, so padding both to one chunk_l wastes storage
    on whichever side fits worse.  ``None`` shares ``chunk_l`` (the old
    behaviour); ``repro.tune.tune_partition`` measures the two
    independently and ``dist_operator(tune="auto")`` feeds them here.
    """
    if m.shape[0] != m.shape[1]:
        raise ValueError("distributed spMVM expects a square matrix")
    n_pad = padded_global_size(m.n_rows, n_dev, b_r)
    n_loc = n_pad // n_dev

    slices = [_csr_row_slice(m, p * n_loc, (p + 1) * n_loc, n_loc)
              for p in range(n_dev)]
    # Measure which remote columns each device references, per signed ring
    # distance — this is both the halo window and the gather sets.
    needs = [F.csr_remote_columns_by_distance(sl, p, n_loc, n_dev)
             for p, sl in enumerate(slices)]
    measured = max((max((abs(d) for d in nd), default=0) for nd in needs),
                   default=0)
    if halo_w is None:
        halo_w = measured
    else:
        halo_w = int(halo_w)
        if halo_w < measured:
            raise ValueError(
                f"halo_w={halo_w} too small: matrix couples devices at ring "
                f"distance {measured}")
    if halo_w > n_dev // 2 and n_dev > 1:
        halo_w = n_dev // 2

    dists = halo_distances(halo_w)
    halo_lens = tuple(
        max((len(nd.get(d, ())) for nd in needs), default=0) for d in dists)
    ext_len = (2 * halo_w + 1) * n_loc
    max_h = max(halo_lens, default=0)
    # send_idx[p, i]: the local columns device p gathers when the exchange
    # for distance dists[i] fires (p serves neighbor (p - d) % n_dev, so
    # the gather list is THAT device's need set).  recv_idx[p, i]: where
    # the compact buffer received from (p + d) % n_dev lands in p's ext
    # buffer.  Pad gathers with 0 (valid, ignored downstream) and
    # scatters with the ext_len sentinel (dropped).
    send_idx = np.zeros((n_dev, len(dists), max_h), dtype=np.int32)
    recv_idx = np.full((n_dev, len(dists), max_h), ext_len, dtype=np.int32)
    for i, d in enumerate(dists):
        for p in range(n_dev):
            snd = needs[(p - d) % n_dev].get(d)
            if snd is not None and len(snd):
                send_idx[p, i, : len(snd)] = snd
            rcv = needs[p].get(d)
            if rcv is not None and len(rcv):
                recv_idx[p, i, : len(rcv)] = (d + halo_w) * n_loc + rcv

    sig = min(int(sigma) if sigma is not None else 8 * b_r, n_loc)
    sig = max(sig, 1)

    rcl = chunk_l if rem_chunk_l is None else int(rem_chunk_l)
    locs, rems, invs = [], [], []
    for p in range(n_dev):
        loc, rem = _split_loc_rem(slices[p], p, n_loc, n_dev, halo_w)
        # One shared per-device row sort (by TOTAL row length) so the two
        # partial results add in the same permuted order — windowed to
        # sigma rows (SELL-C-sigma) so the inverse permutation applied to
        # y stays window-local.  Local and remote operands may carry
        # different tile heights; each pads its own jagged diagonals.
        total_rl = loc.row_lengths() + rem.row_lengths()
        perm = F.windowed_sort_perm(total_rl, sig)
        pj_loc = F._pjds_with_perm(loc, perm, b_r,
                                   max(diag_align, chunk_l), False,
                                   index_dtype)
        pj_rem = F._pjds_with_perm(rem, perm, b_r,
                                   max(diag_align, rcl), False,
                                   index_dtype)
        locs.append(ops.to_device_pjds(pj_loc, chunk_l))
        rems.append(ops.to_device_pjds(pj_rem, rcl))
        inv = np.empty(n_loc, dtype=np.int32)
        inv[perm] = np.arange(n_loc, dtype=np.int32)
        invs.append(inv)

    def _stack(devs, attr, edge=False):
        # Devices pad to one shared leading extent.  Values/columns pad
        # with ZERO (the padding sentinel: phantom chunks contribute
        # nothing); chunk/row block maps pad with their LAST entry so
        # they stay non-decreasing — the prefetched kernels derive the
        # per-block chunk extents from them by binary search.
        arrs = [np.asarray(getattr(d, attr)) for d in devs]
        longest = max(a.shape[0] for a in arrs)
        out = []
        for a in arrs:
            pad = [(0, longest - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
            out.append(np.pad(a, pad, mode="edge" if edge else "constant"))
        return jnp.asarray(np.stack(out))

    n_blocks = n_loc // b_r

    def _max_chunks(devs) -> int:
        # Static per-block chunk ceiling ACROSS devices, including the
        # phantom chunks the shared-extent padding appends to each
        # device's last block.
        longest = max(int(d.chunk_map.shape[0]) for d in devs)
        mx = 1
        for d in devs:
            cm = np.asarray(d.chunk_map)
            cm = np.pad(cm, (0, longest - len(cm)), mode="edge")
            mx = max(mx, int(np.bincount(cm, minlength=1).max()))
        return mx

    return DistPJDS(
        loc_val=_stack(locs, "val"),
        loc_col=_stack(locs, "col_idx"),
        loc_chunk_map=_stack(locs, "chunk_map", edge=True),
        loc_row_block=_stack(locs, "row_block", edge=True),
        rem_val=_stack(rems, "val"),
        rem_col=_stack(rems, "col_idx"),
        rem_chunk_map=_stack(rems, "chunk_map", edge=True),
        rem_row_block=_stack(rems, "row_block", edge=True),
        inv_perm=jnp.asarray(np.stack(invs)),
        send_idx=jnp.asarray(send_idx),
        recv_idx=jnp.asarray(recv_idx),
        n_dev=n_dev,
        n_loc=n_loc,
        n_blocks=n_blocks,
        b_r=b_r,
        chunk_l=chunk_l,
        halo_w=halo_w,
        halo_lens=halo_lens,
        n_rows=m.n_rows,
        sigma=sig,
        loc_max_chunks=_max_chunks(locs),
        rem_max_chunks=_max_chunks(rems),
        rem_chunk_l=None if rcl == chunk_l else rcl,
    )


# --------------------------------------------------------------------------
# The shard_map'd operator
# --------------------------------------------------------------------------
def _local_spmv(val, col, chunk_map, row_block, x, n_blocks, b_r, chunk_l,
                backend, max_chunks=None):
    a = ops.PJDSDevice(val=val, col_idx=col, chunk_map=chunk_map,
                       row_block=row_block, n_blocks=n_blocks, b_r=b_r,
                       chunk_l=chunk_l, max_chunks=max_chunks)
    if x.ndim == 2:
        return ops.pjds_matmat(a, x, backend=backend)
    return ops.pjds_matvec(a, x, backend=backend)


def _exchange_halo_full(x_blk, axis: str, n_dev: int, halo_w: int):
    """Bulk ring ppermute halo: ext buffer = slices of devices p-w..p+w."""
    parts = []
    for d in range(halo_w, 0, -1):  # from p-d (send own slice to p+d)
        parts.append(jax.lax.ppermute(
            x_blk, axis, [(i, (i + d) % n_dev) for i in range(n_dev)]))
    parts.append(x_blk)
    for d in range(1, halo_w + 1):  # from p+d
        parts.append(jax.lax.ppermute(
            x_blk, axis, [(i, (i - d) % n_dev) for i in range(n_dev)]))
    return jnp.concatenate(parts)


# Backwards-compatible alias (pre-gathered name).
_exchange_halo = _exchange_halo_full


def _exchange_halo_gathered(x_blk, send_idx, recv_idx, axis: str, n_dev: int,
                            halo_w: int, halo_lens: tuple):
    """Compressed halo: gather referenced entries -> ppermute compact
    per-neighbor buffers -> scatter into the dense ext buffer.

    The ext buffer keeps the same (2w+1)*n_loc coordinates as the bulk
    exchange (slot w — this device's own slice — stays zero; remote
    columns never point there), so ``rem_col`` is identical either way.
    Distances whose measured halo is empty ship nothing at all.
    """
    n_loc = x_blk.shape[0]
    ext = jnp.zeros(((2 * halo_w + 1) * n_loc,) + x_blk.shape[1:],
                    x_blk.dtype)
    for i, d in enumerate(halo_distances(halo_w)):
        h = halo_lens[i]
        if h == 0:
            continue
        buf = x_blk[send_idx[i, :h]]
        buf = jax.lax.ppermute(
            buf, axis, [(q, (q - d) % n_dev) for q in range(n_dev)])
        ext = ext.at[recv_idx[i, :h]].set(buf, mode="drop")
    return ext


def dist_matvec_local(dist: DistPJDS, x_blk: jax.Array, *, axis: str,
                      mode: Mode = "overlap",
                      backend: ops.Backend = "ref",
                      halo: Halo = "gathered") -> jax.Array:
    """Per-shard body: x_blk is this device's (n_loc,) or (n_loc, k) slice;
    operand leaves of ``dist`` carry a leading length-1 device axis (from
    shard_map)."""
    sq = lambda a: a[0]
    loc_spmv = functools.partial(_local_spmv, n_blocks=dist.n_blocks,
                                 b_r=dist.b_r, chunk_l=dist.chunk_l,
                                 backend=backend,
                                 max_chunks=dist.loc_max_chunks)
    rem_spmv = functools.partial(_local_spmv, n_blocks=dist.n_blocks,
                                 b_r=dist.b_r, chunk_l=dist.rem_chunk_l_eff,
                                 backend=backend,
                                 max_chunks=dist.rem_max_chunks)
    loc_args = (sq(dist.loc_val), sq(dist.loc_col), sq(dist.loc_chunk_map),
                sq(dist.loc_row_block))
    rem_args = (sq(dist.rem_val), sq(dist.rem_col), sq(dist.rem_chunk_map),
                sq(dist.rem_row_block))

    if halo == "gathered":
        exchange = functools.partial(
            _exchange_halo_gathered, send_idx=sq(dist.send_idx),
            recv_idx=sq(dist.recv_idx), axis=axis, n_dev=dist.n_dev,
            halo_w=dist.halo_w, halo_lens=dist.halo_lens)
        no_halo = sum(dist.halo_lens) == 0
    elif halo == "full":
        exchange = functools.partial(
            _exchange_halo_full, axis=axis, n_dev=dist.n_dev,
            halo_w=dist.halo_w)
        no_halo = dist.halo_w == 0
    else:
        raise ValueError(halo)

    if no_halo:
        # Block-diagonal partition: nothing crosses the network, so every
        # mode degenerates to the local kernel alone.
        y = loc_spmv(*loc_args, x_blk)
    elif mode == "vector":
        # comm, then (implicitly fused) full spMVM — bulk synchronous.
        ext = exchange(x_blk)
        ext, x_dep = jax.lax.optimization_barrier((ext, x_blk))
        y = loc_spmv(*loc_args, x_dep) + rem_spmv(*rem_args, ext)
    elif mode == "naive":
        # local kernel first, comm strictly after (no async progress).
        y_loc = loc_spmv(*loc_args, x_blk)
        x_after, _ = jax.lax.optimization_barrier((x_blk, y_loc))
        y = y_loc + rem_spmv(*rem_args, exchange(x_after))
    elif mode == "overlap":
        # task mode: halo and local kernel are independent -> overlapped.
        ext = exchange(x_blk)
        y_loc = loc_spmv(*loc_args, x_blk)
        y = y_loc + rem_spmv(*rem_args, ext)
    else:
        raise ValueError(mode)
    # undo the device-local row sort
    return y[sq(dist.inv_perm)].astype(x_blk.dtype)


def _make_dist_op(dist: DistPJDS, mesh: Mesh, axis: str, mode: Mode,
                  backend: ops.Backend, halo: Halo, multi_rhs: bool):
    n_dev = dist.n_dev
    if mesh.shape[axis] != n_dev:
        raise ValueError(f"mesh axis {axis}={mesh.shape[axis]} != {n_dev}")

    operand_specs = DistPJDS(
        **{f.name: P(axis) for f in dataclasses.fields(DistPJDS)
           if f.metadata.get("static") is not True},
        **{f.name: getattr(dist, f.name)
           for f in dataclasses.fields(DistPJDS)
           if f.metadata.get("static") is True},
    )
    x_spec = P(axis, None) if multi_rhs else P(axis)

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(operand_specs, x_spec),
        out_specs=x_spec,
    )
    def _mv(d, x_blk):
        return dist_matvec_local(d, x_blk, axis=axis, mode=mode,
                                 backend=backend, halo=halo)

    return functools.partial(_mv, dist)


def make_dist_matvec(dist: DistPJDS, mesh: Mesh, axis: str = "data",
                     mode: Mode = "overlap",
                     backend: ops.Backend = "ref",
                     halo: Halo = "gathered"):
    """Build a jit-able y = A x over a mesh axis.  x: (n_global_pad,)
    sharded along ``axis``; returns y with the same sharding.

    .. deprecated::
        Kept as the raw closure under the operator protocol — new code
        should build ``core.operator.dist_operator(m, mesh)`` instead,
        which wraps this exact function and adds ``op.T`` (transposed
        partition), ``diagonal()`` for Jacobi preconditioning, and
        x-gradients.  ``backend="auto"`` resolves in
        ``kernels.ops.resolve_backend``.
    """
    warnings.warn(
        "make_dist_matvec is deprecated: use "
        "core.operator.dist_operator(m, mesh) — the operator wraps this "
        "closure and adds .T, diagonal() and gradients — or repro.solve "
        "for whole systems", DeprecationWarning, stacklevel=2)
    return _make_dist_op(dist, mesh, axis, mode, backend, halo,
                         multi_rhs=False)


def make_dist_matmat(dist: DistPJDS, mesh: Mesh, axis: str = "data",
                     mode: Mode = "overlap",
                     backend: ops.Backend = "ref",
                     halo: Halo = "gathered"):
    """Build a jit-able Y = A X for a block of RHS vectors.
    X: (n_global_pad, k) sharded (axis, None); returns Y alike.

    .. deprecated::
        Shim — see :func:`make_dist_matvec`; prefer
        ``core.operator.dist_operator(m, mesh).matmat``.
    """
    warnings.warn(
        "make_dist_matmat is deprecated: use "
        "core.operator.dist_operator(m, mesh).matmat instead",
        DeprecationWarning, stacklevel=2)
    return _make_dist_op(dist, mesh, axis, mode, backend, halo,
                         multi_rhs=True)


def dist_matvec(dist: DistPJDS, x: jax.Array, mesh: Mesh, axis: str = "data",
                mode: Mode = "overlap",
                backend: ops.Backend = "ref",
                halo: Halo = "gathered") -> jax.Array:
    return _make_dist_op(dist, mesh, axis, mode, backend, halo,
                         multi_rhs=False)(x)


def dist_matmat(dist: DistPJDS, x: jax.Array, mesh: Mesh, axis: str = "data",
                mode: Mode = "overlap",
                backend: ops.Backend = "ref",
                halo: Halo = "gathered") -> jax.Array:
    if x.ndim != 2:
        raise ValueError(f"dist_matmat expects x of shape (n, k); got "
                         f"{x.shape}")
    return _make_dist_op(dist, mesh, axis, mode, backend, halo,
                         multi_rhs=True)(x)
