"""Distributed-memory spMVM (paper §3) on a JAX device mesh.

Row-wise partitioning exactly as in the paper: device ``p`` owns a
contiguous slice of rows and the conformal slice of the RHS/LHS vectors.
Each device's rows are split into

* ``A_loc`` — entries whose column falls inside the device's own RHS
  slice (the block-diagonal part; needs no communication), and
* ``A_rem`` — entries pointing into other devices' slices (the paper's
  "non-local" part; its columns define the halo).

Both parts are stored in SELL-C-sigma-windowed blocked storage — going
one step beyond the paper, whose multi-GPU code still used ELLPACK-R and
left "an implementation of the pJDS format in the multi-GPU code" as
future work (paper §3, Conclusions).  The row sort is windowed INSIDE
each device (sigma rows per window, default 8*b_r; ``sigma >= n_loc``
recovers the device-local global sort, i.e. per-device pJDS), so no
permutation crosses the network, the inverse permutation applied to y
after the kernels is window-local, and the halo/RHS access pattern keeps
the locality of the original row ordering up to sigma (DESIGN.md §3/§6).

The halo moves with ``lax.ppermute`` ring shifts of the x slice — the
JAX-native form of the paper's "local gather + point-to-point" step.  The
partitioner measures the needed window ``w`` (max column distance in
units of slices); for the banded test matrices w is 1-2, for general
matrices it degrades toward all-gather, which is the paper's observation
that some sparsity patterns are invalid for multi-accelerator scaling.

Three communication modes (paper §3.1), distinguished by their data
dependences — inspect the compiled HLO to see the schedules differ:

* ``vector``  — bulk-synchronous: halo exchange completes (barrier), then
  one combined spMVM pass.
* ``naive``   — split kernels, but the halo exchange is *ordered after*
  the local kernel (an ``optimization_barrier`` models MPI libraries
  without asynchronous progress: the transfer really happens at the
  Wait).  The paper predicts no benefit over vector mode; the serialized
  schedule reproduces that.
* ``overlap`` — task mode: the halo ppermutes depend only on x, the local
  kernel depends only on x -> XLA's async collectives overlap the halo
  with the local spMVM.  This is the TPU-idiomatic equivalent of the
  paper's dedicated-MPI-thread task mode.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from . import formats as F
from repro._compat import shard_map
from repro.kernels import ops

Mode = Literal["vector", "naive", "overlap"]

__all__ = ["DistPJDS", "partition_csr", "dist_matvec", "make_dist_matvec",
           "padded_global_size"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DistPJDS:
    """Stacked per-device local/remote pJDS operands (leading axis = device)."""

    loc_val: jax.Array        # (P, loc_jds, b_r)
    loc_col: jax.Array
    loc_chunk_map: jax.Array  # (P, loc_jds // chunk_l)
    loc_row_block: jax.Array  # (P, loc_jds)
    rem_val: jax.Array        # (P, rem_jds, b_r)
    rem_col: jax.Array        # columns in EXT (halo buffer) coordinates
    rem_chunk_map: jax.Array
    rem_row_block: jax.Array
    inv_perm: jax.Array       # (P, n_loc) undo the device-local row sort
    n_dev: int = dataclasses.field(metadata=dict(static=True))
    n_loc: int = dataclasses.field(metadata=dict(static=True))
    n_blocks: int = dataclasses.field(metadata=dict(static=True))
    b_r: int = dataclasses.field(metadata=dict(static=True))
    chunk_l: int = dataclasses.field(metadata=dict(static=True))
    halo_w: int = dataclasses.field(metadata=dict(static=True))
    n_rows: int = dataclasses.field(metadata=dict(static=True))  # unpadded
    sigma: int = dataclasses.field(metadata=dict(static=True))   # sort window

    @property
    def n_global_pad(self) -> int:
        return self.n_dev * self.n_loc

    def comm_bytes_per_device(self, value_bytes: int = 8) -> int:
        """Halo traffic per device per spMVM (both directions)."""
        return 2 * self.halo_w * self.n_loc * value_bytes


def padded_global_size(n_rows: int, n_dev: int, b_r: int = 128) -> int:
    per = b_r * n_dev
    return ((n_rows + per - 1) // per) * per


def _csr_row_slice(m: F.CSRMatrix, lo: int, hi: int, n_loc: int) -> F.CSRMatrix:
    """Rows [lo, hi) of m as a standalone CSR of n_loc rows (zero-padded)."""
    hi = min(hi, m.n_rows)
    counts = np.zeros(n_loc, dtype=np.int64)
    if hi > lo:
        counts[: hi - lo] = np.diff(m.indptr[lo : hi + 1])
    indptr = np.zeros(n_loc + 1, dtype=np.int64)
    np.cumsum(counts, out=indptr[1:])
    s, e = (m.indptr[lo], m.indptr[hi]) if hi > lo else (0, 0)
    return F.CSRMatrix(indptr, m.indices[s:e].copy(), m.data[s:e].copy(),
                       (n_loc, m.shape[1]))


def _split_loc_rem(local: F.CSRMatrix, p: int, n_loc: int, n_dev: int,
                   halo_w: int):
    """Split a device's row slice into local-column and remote-column CSRs,
    remapping columns to slice-local / halo-buffer coordinates."""
    own_lo, own_hi = p * n_loc, (p + 1) * n_loc
    rl = np.diff(local.indptr)
    rows = np.repeat(np.arange(local.n_rows), rl)
    cols = local.indices.astype(np.int64)
    vals = local.data
    is_loc = (cols >= own_lo) & (cols < own_hi)

    loc = F.csr_from_coo(rows[is_loc], cols[is_loc] - own_lo, vals[is_loc],
                         (n_loc, n_loc), sum_duplicates=False)
    rcols = cols[~is_loc]
    owner = rcols // n_loc
    d = (owner - p + n_dev) % n_dev          # ring distance
    d = np.where(d > n_dev // 2, d - n_dev, d)
    ext = (d + halo_w) * n_loc + (rcols % n_loc)
    rem = F.csr_from_coo(rows[~is_loc], ext, vals[~is_loc],
                         (n_loc, (2 * halo_w + 1) * n_loc),
                         sum_duplicates=False)
    return loc, rem


def partition_csr(
    m: F.CSRMatrix,
    n_dev: int,
    b_r: int = 128,
    diag_align: int = 8,
    chunk_l: int = 8,
    halo_w: int | None = None,
    sigma: int | None = None,
) -> DistPJDS:
    """Row-partition a global CSR onto ``n_dev`` devices as :class:`DistPJDS`.

    ``halo_w`` is measured from the matrix when not given; a matrix whose
    halo window reaches n_dev//2 effectively all-gathers — the pattern the
    paper's model flags as not multi-accelerator-friendly.

    ``sigma`` bounds the per-device row-sort window (SELL-C-sigma style;
    default 8*b_r).  ``sigma >= n_loc`` recovers the device-local global
    sort, i.e. per-device pJDS.
    """
    if m.shape[0] != m.shape[1]:
        raise ValueError("distributed spMVM expects a square matrix")
    n_pad = padded_global_size(m.n_rows, n_dev, b_r)
    n_loc = n_pad // n_dev

    # Measure the halo window.
    if halo_w is None:
        halo_w = 0
        for p in range(n_dev):
            sl = _csr_row_slice(m, p * n_loc, (p + 1) * n_loc, n_loc)
            if sl.nnz == 0:
                continue
            owner = sl.indices.astype(np.int64) // n_loc
            d = (owner - p + n_dev) % n_dev
            d = np.where(d > n_dev // 2, n_dev - d, d)
            halo_w = max(halo_w, int(d.max(initial=0)))
    halo_w = max(int(halo_w), 1)
    if halo_w > n_dev // 2 and n_dev > 1:
        halo_w = max(n_dev // 2, 1)

    sig = min(int(sigma) if sigma is not None else 8 * b_r, n_loc)
    sig = max(sig, 1)

    locs, rems, invs = [], [], []
    for p in range(n_dev):
        sl = _csr_row_slice(m, p * n_loc, (p + 1) * n_loc, n_loc)
        loc, rem = _split_loc_rem(sl, p, n_loc, n_dev, halo_w)
        # One shared per-device row sort (by TOTAL row length) so the two
        # partial results add in the same permuted order — windowed to
        # sigma rows (SELL-C-sigma) so the inverse permutation applied to
        # y stays window-local.
        total_rl = loc.row_lengths() + rem.row_lengths()
        perm = F.windowed_sort_perm(total_rl, sig)
        pj_loc = F._pjds_with_perm(loc, perm, b_r, diag_align, False)
        pj_rem = F._pjds_with_perm(rem, perm, b_r, diag_align, False)
        locs.append(ops.to_device_pjds(pj_loc, chunk_l))
        rems.append(ops.to_device_pjds(pj_rem, chunk_l))
        inv = np.empty(n_loc, dtype=np.int32)
        inv[perm] = np.arange(n_loc, dtype=np.int32)
        invs.append(inv)

    def _stack(devs, attr):
        arrs = [np.asarray(getattr(d, attr)) for d in devs]
        longest = max(a.shape[0] for a in arrs)
        out = []
        for a in arrs:
            pad = [(0, longest - a.shape[0])] + [(0, 0)] * (a.ndim - 1)
            out.append(np.pad(a, pad))
        return jnp.asarray(np.stack(out))

    n_blocks = n_loc // b_r
    return DistPJDS(
        loc_val=_stack(locs, "val"),
        loc_col=_stack(locs, "col_idx"),
        loc_chunk_map=_stack(locs, "chunk_map"),
        loc_row_block=_stack(locs, "row_block"),
        rem_val=_stack(rems, "val"),
        rem_col=_stack(rems, "col_idx"),
        rem_chunk_map=_stack(rems, "chunk_map"),
        rem_row_block=_stack(rems, "row_block"),
        inv_perm=jnp.asarray(np.stack(invs)),
        n_dev=n_dev,
        n_loc=n_loc,
        n_blocks=n_blocks,
        b_r=b_r,
        chunk_l=chunk_l,
        halo_w=halo_w,
        n_rows=m.n_rows,
        sigma=sig,
    )


# --------------------------------------------------------------------------
# The shard_map'd operator
# --------------------------------------------------------------------------
def _local_spmv(val, col, chunk_map, row_block, x, n_blocks, b_r, chunk_l,
                backend):
    a = ops.PJDSDevice(val=val, col_idx=col, chunk_map=chunk_map,
                       row_block=row_block, n_blocks=n_blocks, b_r=b_r,
                       chunk_l=chunk_l)
    return ops.pjds_matvec(a, x, backend=backend)


def _exchange_halo(x_blk, axis: str, n_dev: int, halo_w: int):
    """Ring ppermute halo: ext buffer = slices of devices p-w..p+w."""
    parts = []
    for d in range(halo_w, 0, -1):  # from p-d (send own slice to p+d)
        parts.append(jax.lax.ppermute(
            x_blk, axis, [(i, (i + d) % n_dev) for i in range(n_dev)]))
    parts.append(x_blk)
    for d in range(1, halo_w + 1):  # from p+d
        parts.append(jax.lax.ppermute(
            x_blk, axis, [(i, (i - d) % n_dev) for i in range(n_dev)]))
    return jnp.concatenate(parts)


def dist_matvec_local(dist: DistPJDS, x_blk: jax.Array, *, axis: str,
                      mode: Mode = "overlap",
                      backend: ops.Backend = "ref") -> jax.Array:
    """Per-shard body: x_blk is this device's (n_loc,) slice; operand leaves
    of ``dist`` carry a leading length-1 device axis (from shard_map)."""
    sq = lambda a: a[0]
    spmv = functools.partial(_local_spmv, n_blocks=dist.n_blocks,
                             b_r=dist.b_r, chunk_l=dist.chunk_l,
                             backend=backend)
    loc_args = (sq(dist.loc_val), sq(dist.loc_col), sq(dist.loc_chunk_map),
                sq(dist.loc_row_block))
    rem_args = (sq(dist.rem_val), sq(dist.rem_col), sq(dist.rem_chunk_map),
                sq(dist.rem_row_block))

    if mode == "vector":
        # comm, then (implicitly fused) full spMVM — bulk synchronous.
        ext = _exchange_halo(x_blk, axis, dist.n_dev, dist.halo_w)
        ext, x_dep = jax.lax.optimization_barrier((ext, x_blk))
        y = spmv(*loc_args, x_dep) + spmv(*rem_args, ext)
    elif mode == "naive":
        # local kernel first, comm strictly after (no async progress).
        y_loc = spmv(*loc_args, x_blk)
        x_after, _ = jax.lax.optimization_barrier((x_blk, y_loc))
        ext = _exchange_halo(x_after, axis, dist.n_dev, dist.halo_w)
        y = y_loc + spmv(*rem_args, ext)
    elif mode == "overlap":
        # task mode: halo and local kernel are independent -> overlapped.
        ext = _exchange_halo(x_blk, axis, dist.n_dev, dist.halo_w)
        y_loc = spmv(*loc_args, x_blk)
        y = y_loc + spmv(*rem_args, ext)
    else:
        raise ValueError(mode)
    # undo the device-local row sort
    return y[sq(dist.inv_perm)].astype(x_blk.dtype)


def make_dist_matvec(dist: DistPJDS, mesh: Mesh, axis: str = "data",
                     mode: Mode = "overlap",
                     backend: ops.Backend = "ref"):
    """Build a jit-able y = A x over a mesh axis.  x: (n_global_pad,)
    sharded along ``axis``; returns y with the same sharding."""
    n_dev = dist.n_dev
    if mesh.shape[axis] != n_dev:
        raise ValueError(f"mesh axis {axis}={mesh.shape[axis]} != {n_dev}")

    operand_specs = DistPJDS(
        **{f.name: P(axis) for f in dataclasses.fields(DistPJDS)
           if f.metadata.get("static") is not True},
        **{f.name: getattr(dist, f.name)
           for f in dataclasses.fields(DistPJDS)
           if f.metadata.get("static") is True},
    )

    @functools.partial(
        shard_map,
        mesh=mesh,
        in_specs=(operand_specs, P(axis)),
        out_specs=P(axis),
    )
    def _mv(d, x_blk):
        return dist_matvec_local(d, x_blk, axis=axis, mode=mode,
                                 backend=backend)

    return functools.partial(_mv, dist)


def dist_matvec(dist: DistPJDS, x: jax.Array, mesh: Mesh, axis: str = "data",
                mode: Mode = "overlap",
                backend: ops.Backend = "ref") -> jax.Array:
    return make_dist_matvec(dist, mesh, axis, mode, backend)(x)
