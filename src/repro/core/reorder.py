"""Bandwidth-reducing reordering — the preprocessing stage (DESIGN.md §13).

The paper observes (§2.2/§3) that matrices whose nonzeros scatter across
the full column space are "invalidated" for multi-accelerator spMVM: the
halo degenerates toward an all-gather.  A symmetric Reverse Cuthill-McKee
(RCM) permutation concentrates nonzeros near the diagonal, shrinking the
partitioner's measured halo width — the collective term of the
distributed roofline drops in direct proportion (EXPERIMENTS.md §Perf,
sparse-core iteration).

Pure numpy BFS implementation (no scipy).  The permutation composes with
pJDS's *local* row sort (dist_spmv sorts within each device slice), so
RCM fixes inter-device locality while pJDS fixes intra-device padding —
the two operate at different levels of the hierarchy.

Permutation convention (used by EVERY function in this module, and by
the ``pre_perm`` sandwich in ``kernels.ops.SparseDevice``):

    perm[k] = old index placed at new position k,
    inv[perm] = arange(n)  (so inv[old] = new position of old index).

:func:`preprocess` is the priced entry point: it decides — via the
calibrated perf model — whether applying RCM is predicted to pay for
its per-matvec permute/unpermute sandwich (and, distributed, whether
the halo-traffic reduction pays), and returns the permuted matrix plus
the bookkeeping the operator layers thread through.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from . import perf_model as PM
from .formats import CSRMatrix, csr_from_coo, estimate_storage_elements

__all__ = ["rcm_permutation", "permute_symmetric", "bandwidth",
           "Preprocessed", "preprocess"]


def rcm_permutation(m: CSRMatrix) -> np.ndarray:
    """Reverse Cuthill-McKee ordering of the symmetrised adjacency.

    Returns ``perm`` in the module's convention: ``perm[k]`` is the OLD
    row index placed at new position ``k`` — exactly what
    :func:`permute_symmetric` consumes (``B[k, :] = A[perm[k], :]`` up
    to the matching column permutation).  The new position of old row
    ``i`` is therefore ``inv[i]`` with ``inv[perm] = arange(n)``."""
    n = m.n_rows
    # symmetrised adjacency in CSR form (A + A^T pattern)
    rl = np.diff(m.indptr)
    rows = np.repeat(np.arange(n, dtype=np.int64), rl)
    cols = m.indices.astype(np.int64)
    ar = np.concatenate([rows, cols])
    ac = np.concatenate([cols, rows])
    order = np.lexsort((ac, ar))
    ar, ac = ar[order], ac[order]
    keep = np.ones(len(ar), bool)
    keep[1:] = (ar[1:] != ar[:-1]) | (ac[1:] != ac[:-1])
    ar, ac = ar[keep], ac[keep]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, ar + 1, 1)
    np.cumsum(indptr, out=indptr)

    degree = np.diff(indptr)
    visited = np.zeros(n, bool)
    result = np.empty(n, np.int64)
    pos = 0
    # BFS from minimum-degree node of each component
    remaining = np.argsort(degree, kind="stable")
    rem_i = 0
    while pos < n:
        while rem_i < n and visited[remaining[rem_i]]:
            rem_i += 1
        start = remaining[rem_i]
        visited[start] = True
        result[pos] = start
        head = pos
        pos += 1
        while head < pos:
            u = result[head]
            head += 1
            nbrs = ac[indptr[u]:indptr[u + 1]]
            nbrs = nbrs[~visited[nbrs]]
            if len(nbrs):
                nbrs = nbrs[np.argsort(degree[nbrs], kind="stable")]
                visited[nbrs] = True
                result[pos:pos + len(nbrs)] = nbrs
                pos += len(nbrs)
    return result[::-1].copy()          # the "reverse" in RCM


def permute_symmetric(m: CSRMatrix, perm: np.ndarray) -> CSRMatrix:
    """B = P A P^T with perm[k] = old index placed at new position k.

    Square matrices only: the SAME permutation is applied to rows and
    columns, so a rectangular input has no symmetric permutation (and
    indexing the row-sized inverse with column indices would silently
    produce garbage).  The ``sum_duplicates=False`` fast path is safe:
    ``csr_from_coo`` sorts within rows before that branch (see its
    docstring), and a permutation maps distinct (row, col) pairs to
    distinct pairs — no new duplicates to merge."""
    n = m.n_rows
    if m.shape[0] != m.shape[1]:
        raise ValueError(
            f"permute_symmetric requires a square matrix; got {m.shape}")
    perm = np.asarray(perm)
    if perm.shape != (n,):
        raise ValueError(
            f"perm must have shape ({n},) to permute a {m.shape} matrix; "
            f"got {perm.shape}")
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)
    rl = np.diff(m.indptr)
    rows = np.repeat(np.arange(n, dtype=np.int64), rl)
    new_rows = inv[rows]
    new_cols = inv[m.indices.astype(np.int64)]
    return csr_from_coo(new_rows, new_cols, m.data.copy(), m.shape,
                        sum_duplicates=False)


def bandwidth(m: CSRMatrix) -> int:
    """max |row - col| over stored entries — the locality metric RCM
    minimises and :func:`preprocess` prices halo traffic with."""
    rl = np.diff(m.indptr)
    rows = np.repeat(np.arange(m.n_rows, dtype=np.int64), rl)
    if len(rows) == 0:
        return 0
    return int(np.abs(rows - m.indices.astype(np.int64)).max())


# --------------------------------------------------------------------------
# The priced preprocessing stage (DESIGN.md §13)
# --------------------------------------------------------------------------
@dataclasses.dataclass
class Preprocessed:
    """Outcome of :func:`preprocess`.  When ``applied`` is False,
    ``matrix`` is the input object unchanged and the permutations are
    None; otherwise ``matrix = P A P^T`` and the caller must sandwich
    every apply — ``y = B_path(x[perm])[inv_perm]`` — to stay in the
    original basis."""

    matrix: CSRMatrix
    perm: Optional[np.ndarray]
    inv_perm: Optional[np.ndarray]
    applied: bool
    reason: str
    bandwidth_before: int
    bandwidth_after: int
    predicted_off_s: float
    predicted_on_s: float


_PREPROCESS_FMTS = ("ellpack_r", "sell", "pjds", "cmrs")


def _best_format_seconds(rl: np.ndarray, n: int, nnz: int, *,
                         n_dev: int, value_bytes: int, index_bytes: int,
                         vec_bytes: int, spec, calibration) -> float:
    """Cheapest predicted single-chip spMVM time over the blocked
    formats for the given ROW-LENGTH ORDER (sell/cmrs storage depends on
    it; dispatch re-decides the actual format later).  Distributed
    callers price the per-device slice (uniform 1-D row split)."""
    n_loc = -(-n // n_dev)
    best = np.inf
    for fmt in _PREPROCESS_FMTS:
        elems = estimate_storage_elements(rl, fmt)
        ib = index_bytes + (PM.CMRS_RIS_BYTES if fmt == "cmrs" else 0)
        t = PM.predicted_spmv_seconds(
            -(-elems // n_dev), n_loc, max(nnz / max(n, 1), 1.0),
            perm_bytes=PM.perm_traffic_bytes(
                n_loc, vec_bytes, window_local=(fmt != "pjds")),
            spec=spec, value_bytes=value_bytes, index_bytes=ib,
            vec_bytes=vec_bytes, fmt=fmt, calibration=calibration)
        if fmt == "cmrs":
            t = max(t, PM.cmrs_reduce_seconds(-(-elems // n_dev), 128, spec))
        best = min(best, t)
    return float(best)


def _gathered_halo_elements(rows: np.ndarray, cols: np.ndarray,
                            n: int, n_dev: int) -> float:
    """Mean per-device count of UNIQUE remote x entries under a uniform
    1-D row partition — what the gathered halo exchange ships
    (``dist_spmv.comm_bytes_per_device`` measures the same quantity on
    the built partition)."""
    if n_dev <= 1 or len(rows) == 0:
        return 0.0
    n_loc = -(-n // n_dev)
    dev_r = rows // n_loc
    remote = dev_r != cols // n_loc
    if not remote.any():
        return 0.0
    pairs = np.unique(dev_r[remote] * np.int64(n) + cols[remote])
    return len(pairs) / n_dev


def preprocess(m: CSRMatrix, reorder: str = "auto", *,
               n_dev: int = 1,
               spec: PM.TPUSpec = PM.TPU_V5E,
               calibration="default",
               min_gain: float = 0.02,
               value_bytes: Optional[int] = None,
               vec_bytes: Optional[int] = None) -> Preprocessed:
    """The priced RCM preprocessing stage.

    ``"off"`` returns the input untouched; ``"rcm"`` always applies the
    permutation (raising on non-square input); ``"auto"`` applies it
    only when the model predicts a win of at least ``min_gain``
    (relative) — comparing, per matvec,

    * single chip: the best blocked format's predicted time on the
      ORIGINAL row-length order vs the REORDERED order plus the
      unfusable permute/unpermute sandwich
      (``2 * perm_traffic_bytes(n)``) the operator wraps around the
      stored matrix;
    * ``n_dev > 1``: the same per-device-slice terms plus the gathered
      halo-exchange time (``t_link_gathered``) over the EXACT per-device
      unique remote-column counts of a uniform 1-D row partition, before
      vs after reordering — the paper's §2.2 locality argument, priced
      instead of assumed.

    Both sides use the installed :class:`perf_model.Calibration` (pass
    ``calibration=None`` for data-sheet numbers), so "auto" follows the
    measured machine whenever one was calibrated.  Non-square or empty
    matrices: "auto" quietly skips, "rcm" raises (RCM is a symmetric
    permutation).
    """
    if reorder not in ("off", "auto", "rcm"):
        raise ValueError(f"reorder must be 'off', 'auto' or 'rcm'; "
                         f"got {reorder!r}")
    bw0 = bandwidth(m)
    skip = None
    if reorder == "off":
        skip = "off"
    elif m.shape[0] != m.shape[1]:
        if reorder == "rcm":
            raise ValueError(
                f"reorder='rcm' requires a square matrix; got {m.shape}")
        skip = "non_square"
    elif m.nnz == 0:
        if reorder == "rcm":
            raise ValueError("reorder='rcm' on an empty matrix")
        skip = "empty"
    if skip is not None:
        return Preprocessed(m, None, None, False, skip, bw0, bw0,
                            float("nan"), float("nan"))

    if value_bytes is None:
        value_bytes = m.data.dtype.itemsize
    if vec_bytes is None:
        vec_bytes = max(4, value_bytes)
    n, nnz = m.n_rows, m.nnz
    perm = rcm_permutation(m)
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)

    rl = m.row_lengths()
    rows = np.repeat(np.arange(n, dtype=np.int64), rl)
    cols = m.indices.astype(np.int64)
    bw1 = int(np.abs(inv[rows] - inv[cols]).max(initial=0))

    index_bytes = m.indices.dtype.itemsize
    price = dict(n_dev=n_dev, value_bytes=value_bytes,
                 index_bytes=index_bytes, vec_bytes=vec_bytes,
                 spec=spec, calibration=calibration)
    t_off = _best_format_seconds(rl, n, nnz, **price)
    # Row lengths of B = P A P^T are rl[perm] — order is all that
    # changes, and order is what sell/cmrs storage estimates react to.
    t_on = _best_format_seconds(rl[perm], n, nnz, **price)
    # The outer sandwich is NOT fusable into the kernels: one gather of
    # x into the permuted basis, one of y back out, per matvec.
    cal = PM.get_calibration() if calibration == "default" else calibration
    bw_scale = cal.bw_scale if cal is not None else 1.0
    t_on += 2 * PM.perm_traffic_bytes(n, vec_bytes) / (spec.hbm_bw * bw_scale)
    if n_dev > 1:
        halo0 = _gathered_halo_elements(rows, cols, n, n_dev)
        halo1 = _gathered_halo_elements(inv[rows], inv[cols], n, n_dev)
        t_off += PM.t_link_gathered(halo0, spec.ici_bw,
                                    value_bytes=vec_bytes, msgs=2,
                                    calibration=calibration)
        t_on += PM.t_link_gathered(halo1, spec.ici_bw,
                                   value_bytes=vec_bytes, msgs=2,
                                   calibration=calibration)

    apply = (reorder == "rcm") or (t_on < t_off * (1.0 - min_gain))
    if not apply:
        return Preprocessed(m, None, None, False,
                            f"predicted_loss: on={t_on:.3e}s off={t_off:.3e}s",
                            bw0, bw1, t_off, t_on)
    reason = ("forced" if reorder == "rcm"
              else f"predicted_gain: on={t_on:.3e}s off={t_off:.3e}s")
    return Preprocessed(permute_symmetric(m, perm), perm, inv, True,
                        reason, bw0, bw1, t_off, t_on)
