"""Bandwidth-reducing reordering (beyond-paper optimization).

The paper observes (§2.2/§3) that matrices whose nonzeros scatter across
the full column space are "invalidated" for multi-accelerator spMVM: the
halo degenerates toward an all-gather.  A symmetric Reverse Cuthill-McKee
(RCM) permutation concentrates nonzeros near the diagonal, shrinking the
partitioner's measured halo width — the collective term of the
distributed roofline drops in direct proportion (EXPERIMENTS.md §Perf,
sparse-core iteration).

Pure numpy BFS implementation (no scipy).  The permutation composes with
pJDS's *local* row sort (dist_spmv sorts within each device slice), so
RCM fixes inter-device locality while pJDS fixes intra-device padding —
the two operate at different levels of the hierarchy.
"""
from __future__ import annotations

import numpy as np

from .formats import CSRMatrix, csr_from_coo

__all__ = ["rcm_permutation", "permute_symmetric"]


def rcm_permutation(m: CSRMatrix) -> np.ndarray:
    """Reverse Cuthill-McKee ordering of the symmetrised adjacency.
    Returns perm with new_index = position of old row in perm."""
    n = m.n_rows
    # symmetrised adjacency in CSR form (A + A^T pattern)
    rl = np.diff(m.indptr)
    rows = np.repeat(np.arange(n, dtype=np.int64), rl)
    cols = m.indices.astype(np.int64)
    ar = np.concatenate([rows, cols])
    ac = np.concatenate([cols, rows])
    order = np.lexsort((ac, ar))
    ar, ac = ar[order], ac[order]
    keep = np.ones(len(ar), bool)
    keep[1:] = (ar[1:] != ar[:-1]) | (ac[1:] != ac[:-1])
    ar, ac = ar[keep], ac[keep]
    indptr = np.zeros(n + 1, np.int64)
    np.add.at(indptr, ar + 1, 1)
    np.cumsum(indptr, out=indptr)

    degree = np.diff(indptr)
    visited = np.zeros(n, bool)
    result = np.empty(n, np.int64)
    pos = 0
    # BFS from minimum-degree node of each component
    remaining = np.argsort(degree, kind="stable")
    rem_i = 0
    while pos < n:
        while rem_i < n and visited[remaining[rem_i]]:
            rem_i += 1
        start = remaining[rem_i]
        visited[start] = True
        result[pos] = start
        head = pos
        pos += 1
        while head < pos:
            u = result[head]
            head += 1
            nbrs = ac[indptr[u]:indptr[u + 1]]
            nbrs = nbrs[~visited[nbrs]]
            if len(nbrs):
                nbrs = nbrs[np.argsort(degree[nbrs], kind="stable")]
                visited[nbrs] = True
                result[pos:pos + len(nbrs)] = nbrs
                pos += len(nbrs)
    return result[::-1].copy()          # the "reverse" in RCM


def permute_symmetric(m: CSRMatrix, perm: np.ndarray) -> CSRMatrix:
    """B = P A P^T with perm[k] = old index placed at new position k."""
    n = m.n_rows
    inv = np.empty(n, np.int64)
    inv[perm] = np.arange(n)
    rl = np.diff(m.indptr)
    rows = np.repeat(np.arange(n, dtype=np.int64), rl)
    new_rows = inv[rows]
    new_cols = inv[m.indices.astype(np.int64)]
    return csr_from_coo(new_rows, new_cols, m.data.copy(), m.shape,
                        sum_duplicates=False)


def bandwidth(m: CSRMatrix) -> int:
    rl = np.diff(m.indptr)
    rows = np.repeat(np.arange(m.n_rows, dtype=np.int64), rl)
    if len(rows) == 0:
        return 0
    return int(np.abs(rows - m.indices.astype(np.int64)).max())
