"""The ``SparseOperator`` protocol: one mesh-aware, differentiable
linear-operator API over single-device and distributed spMVM.

The paper's promise is that callers see ``y = A x`` while storage
format, permutation and halo plumbing stay hidden.  This module is that
promise as an API: every operator — whatever lives inside — offers

* ``shape`` / ``dtype`` and ``__matmul__`` sugar (``op @ x`` dispatches
  1-D -> ``matvec``, 2-D -> ``matmat``), both in the ORIGINAL basis;
* a transpose family: ``op.T`` is a lazy view whose ``matvec`` is
  ``op.rmatvec``.  Blocked formats run ``A^T x`` as a scatter-accumulate
  over their stored column indices (``kernels.ref.blocked_rmatvec_ref``),
  or — with ``transpose="device"`` — through a CSC-of-blocks device
  build (``formats.csr_transpose`` fed back through the forward
  kernels); CSR swaps its gather and its segment ids;
* custom derivative rules so ``jax.grad`` (and ``jax.jvp``) works
  through both the stored values and x, even when the forward pass ran
  the Pallas kernels (tangents and cotangents ride the jnp ref path —
  same math, and ``d(Ax)/d(val)`` reuses the forward gather structure);
* pytree registration, so operators flow through ``jit`` / ``shard_map``
  / ``lax.while_loop`` carriers and can sit inside model param trees.

Two implementations cover the repo's stacks:

* :class:`DeviceOperator` — wraps the dispatch layer's
  ``kernels.ops.SparseDevice`` (CSR / ELLPACK-R / pJDS / SELL-C-sigma,
  chosen by ``format="auto"``).  Build with :func:`operator`.
* :class:`DistOperator` — wraps ``core.dist_spmv`` (row-partitioned
  SELL-windowed storage + gathered halo exchange over a mesh axis).
  Build with :func:`dist_operator`.  Its transpose is the transposed
  partition — ``A^T``'s halo is the mirror coupling, measured the same
  way — so ``op.T`` and x-gradients stay fully distributed.

A mesh operator and a local operator are interchangeable anywhere a
``SparseOperator`` (or bare matvec callable) is accepted — in
particular every solver in ``core.solvers`` runs unmodified on both.
See DESIGN.md §8.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import dist_spmv as D
from repro.core import perf_model as PM
from repro.kernels import ops

__all__ = [
    "SparseOperator",
    "DeviceOperator",
    "TransposeOperator",
    "DistOperator",
    "operator",
    "dist_operator",
]


# --------------------------------------------------------------------------
# The protocol
# --------------------------------------------------------------------------
class SparseOperator:
    """Abstract linear operator y = A x in the original basis.

    Implementations provide ``shape``, ``dtype``, ``matvec``, ``matmat``,
    ``rmatvec``, ``rmatmat`` and (square operators) ``diagonal``; the
    base class supplies the ``@`` sugar and the lazy transpose view.
    Implementations must also be registered pytrees.
    """

    shape: tuple

    @property
    def dtype(self):
        raise NotImplementedError

    def matvec(self, x: jax.Array) -> jax.Array:
        """y = A x: x (shape[1],) [or longer, padded] -> y (shape[0],)."""
        raise NotImplementedError

    def matmat(self, x: jax.Array) -> jax.Array:
        """Y = A X: X (shape[1], k) -> Y (shape[0], k)."""
        raise NotImplementedError

    def rmatvec(self, y: jax.Array) -> jax.Array:
        """x = A^T y: y (shape[0],) -> x (shape[1],)."""
        raise NotImplementedError

    def rmatmat(self, y: jax.Array) -> jax.Array:
        """X = A^T Y: Y (shape[0], k) -> X (shape[1], k)."""
        raise NotImplementedError

    def diagonal(self) -> jax.Array:
        """diag(A) for square operators (the Jacobi preconditioner)."""
        raise NotImplementedError

    @property
    def T(self) -> "SparseOperator":
        """Lazy transpose view, memoized so ``op.T is op.T`` (repeated
        solves on the view reuse one solver closure / jit entry) and
        ``op.T.T is op``."""
        t = getattr(self, "_T", None)
        if t is None:
            t = TransposeOperator(self)
            self._T = t
        return t

    def __matmul__(self, x):
        x = jnp.asarray(x)
        if x.ndim == 1:
            return self.matvec(x)
        if x.ndim == 2:
            return self.matmat(x)
        raise ValueError(f"operator @ x expects 1-D or 2-D x; got {x.shape}")


@jax.tree_util.register_pytree_node_class
class TransposeOperator(SparseOperator):
    """Lazy ``A^T`` view: forwards to the base operator's r-methods."""

    def __init__(self, base: SparseOperator):
        self.base = base

    @property
    def shape(self):
        s = self.base.shape
        return (s[1], s[0])

    @property
    def dtype(self):
        return self.base.dtype

    def matvec(self, x):
        return self.base.rmatvec(x)

    def matmat(self, x):
        return self.base.rmatmat(x)

    def rmatvec(self, y):
        return self.base.matvec(y)

    def rmatmat(self, y):
        return self.base.matmat(y)

    def diagonal(self):
        return self.base.diagonal()      # diag(A^T) == diag(A)

    @property
    def T(self):
        return self.base

    def tree_flatten(self):
        return (self.base,), None

    @classmethod
    def tree_unflatten(cls, _aux, children):
        return cls(children[0])


# --------------------------------------------------------------------------
# Differentiable application (single device)
# --------------------------------------------------------------------------
def _ref_apply(dev: ops.SparseDevice, x: jax.Array) -> jax.Array:
    """The pure-jnp (gather + segment-sum) application — differentiable
    by construction; the custom derivative rule below differentiates
    THIS, so grads are exact for the kernel backend too (same math)."""
    return dev.matvec(x, backend="ref")


@functools.partial(jax.custom_jvp, nondiff_argnums=(2,))
def _device_apply(dev: ops.SparseDevice, x: jax.Array, backend: str):
    return dev.matvec(x, backend=backend)


@_device_apply.defjvp
def _device_apply_jvp(backend, primals, tangents):
    dev, x = primals
    # The tangent rides the ref path: A(val_dot) x + A x_dot, built from
    # transposable jnp ops — so REVERSE mode falls out by transposition
    # (d(Ax)/dx^T g = A^T g, the scatter-accumulate transpose, and
    # d(Ax)/d(val)^T g reuses the forward gather; integer leaves carry
    # float0) while FORWARD mode (jax.jvp) works directly.  The primal
    # still runs the requested backend (Pallas kernels have no rules).
    y = _device_apply(dev, x, backend)
    y_dot = jax.jvp(_ref_apply, primals, tangents)[1]
    return y, y_dot


@jax.tree_util.register_pytree_node_class
class DeviceOperator(SparseOperator):
    """Single-device :class:`SparseOperator` over a dispatch-layer
    ``SparseDevice`` (format chosen once, conversion cached).

    ``t_dev``, when present, is the CSC-of-blocks device build of
    ``A^T`` (``operator(a, transpose="device")``): ``rmatvec`` then runs
    the FORWARD kernels on the transposed operand instead of the
    scatter-accumulate ref.  ``backend="auto"`` resolves per call in
    ``kernels.ops.resolve_backend``.
    """

    def __init__(self, dev: ops.SparseDevice,
                 t_dev: Optional[ops.SparseDevice] = None,
                 backend: ops.Backend = "auto"):
        self.dev = dev
        self.t_dev = t_dev
        self.backend = backend
        self._diag = None                 # lazy; not part of the pytree

    # -- structure ---------------------------------------------------------
    @property
    def shape(self):
        return self.dev.shape

    @property
    def fmt(self) -> str:
        return self.dev.fmt

    @property
    def dtype(self):
        return self.values.dtype

    @property
    def values(self) -> jax.Array:
        """The stored value leaf (the differentiable parameters)."""
        d = self.dev.dev
        return d.data if self.dev.fmt == "csr" else d.val

    def with_values(self, val: jax.Array) -> "DeviceOperator":
        """Same sparsity structure, new stored values — the handle
        ``jax.grad`` differentiates through:
        ``jax.grad(lambda v: loss(op.with_values(v) @ x))(op.values)``.
        Drops any ``t_dev`` (its values live in transposed order)."""
        inner = self.dev.dev
        field = "data" if self.dev.fmt == "csr" else "val"
        inner = dataclasses.replace(inner, **{field: val})
        return DeviceOperator(dataclasses.replace(self.dev, dev=inner),
                              backend=self.backend)

    # -- application -------------------------------------------------------
    def matvec(self, x, backend: Optional[ops.Backend] = None):
        return _device_apply(self.dev, x, backend or self.backend)

    def matmat(self, x, backend: Optional[ops.Backend] = None):
        return _device_apply(self.dev, x, backend or self.backend)

    def rmatvec(self, y, backend: Optional[ops.Backend] = None):
        if self.t_dev is not None:
            return _device_apply(self.t_dev, y, backend or self.backend)
        return self.dev.rmatvec(y)

    def rmatmat(self, y, backend: Optional[ops.Backend] = None):
        if self.t_dev is not None:
            return _device_apply(self.t_dev, y, backend or self.backend)
        return self.dev.rmatmat(y)

    def diagonal(self):
        if self.shape[0] != self.shape[1]:
            raise ValueError("diagonal requires a square operator")
        if self._diag is None:
            d = _device_diagonal(self.dev)
            if isinstance(d, jax.core.Tracer):
                return d         # never cache a tracer past its trace
            self._diag = d
        return self._diag

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        return (self.dev, self.t_dev), (self.backend,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], t_dev=children[1], backend=aux[0])


def _device_diagonal(sd: ops.SparseDevice) -> jax.Array:
    """diag(A) straight from the device layout (no host matrix needed):
    mask each stored entry on ``column == original row`` and reduce with
    the same segment structure the matvec uses.  A preprocessing
    permutation (``reorder=``) stores B = P A P^T, whose diagonal is
    diag(A) permuted — ``diag(A) = diag(B)[pre_inv]``."""
    dg = _device_diagonal_stored(sd)
    if sd.pre_inv is not None:
        dg = dg[sd.pre_inv]
    return dg


def _device_diagonal_stored(sd: ops.SparseDevice) -> jax.Array:
    n = sd.shape[0]
    d = sd.dev
    if sd.fmt == "csr":
        keep = jnp.where(d.indices == d.row_ids, d.data, 0)
        return jax.ops.segment_sum(keep, d.row_ids, num_segments=n)
    if sd.fmt == "ellpack_r":
        rows = jnp.arange(d.val.shape[1], dtype=jnp.int32)[None, :]
        j = jnp.arange(d.val.shape[0], dtype=jnp.int32)[:, None]
        mask = (d.col_idx == rows) & (j < d.rowlen[None, :])
        return jnp.where(mask, d.val, 0).sum(axis=0)[:n]
    if sd.fmt in ("sell", "pjds"):
        inv = d.inv_perm if sd.fmt == "sell" else sd.inv_perm
        n_pad = inv.shape[0]
        b_r = d.val.shape[1]
        # original row index of each storage (permuted) position
        orig = jnp.zeros(n_pad, jnp.int32).at[inv].set(
            jnp.arange(n_pad, dtype=jnp.int32))
        pos = d.row_block[:, None] * b_r + jnp.arange(b_r,
                                                      dtype=jnp.int32)[None]
        mask = d.col_idx == orig[pos]
        keep = jnp.where(mask, d.val, 0)
        blk = jax.ops.segment_sum(keep, d.row_block,
                                  num_segments=int(n_pad // b_r))
        return blk.reshape(n_pad)[inv][:n]
    if sd.fmt == "cmrs":
        b_r = d.val.shape[1]
        rows = d.strip_map[:, None] * b_r + d.row_in_strip.astype(jnp.int32)
        keep = jnp.where(d.col_idx.astype(jnp.int32) == rows, d.val, 0)
        return jax.ops.segment_sum(
            keep.reshape(-1), rows.reshape(-1),
            num_segments=d.n_strips * b_r)[:n]
    raise ValueError(f"unknown format {sd.fmt!r}")


# --------------------------------------------------------------------------
# Distributed operator
# --------------------------------------------------------------------------
def _linear_with_transpose(fwd, bwd, x):
    """Wrap a linear sharded application with an explicit transpose rule:
    gradients w.r.t. x flow through ``bwd`` (the transposed partition's
    forward pass) instead of JAX trying to transpose the halo exchange."""
    @jax.custom_vjp
    def apply(xx):
        return fwd(xx)

    apply.defvjp(lambda xx: (fwd(xx), None), lambda _res, g: (bwd(g),))
    return apply(x)


@jax.tree_util.register_pytree_node_class
class DistOperator(SparseOperator):
    """Mesh-distributed :class:`SparseOperator` over a ``DistPJDS``
    row partition (``core.dist_spmv``).

    Vectors are GLOBAL padded vectors of length ``n_global_pad``,
    sharded along ``axis`` (``P(axis)`` / ``P(axis, None)`` for blocks);
    the operator returns the same sharding.  ``t_dist``, when present,
    is the row partition of ``A^T`` — the transpose halo is the mirror
    coupling, measured at partition time like the forward one — and
    powers ``rmatvec``/``op.T`` plus the x-cotangent of ``jax.grad``.
    Gradients w.r.t. the distributed stored values are not defined
    (inference/solver operator; train on :class:`DeviceOperator`).
    """

    def __init__(self, dist: D.DistPJDS, mesh,
                 t_dist: Optional[D.DistPJDS] = None,
                 diag: Optional[jax.Array] = None,
                 axis: str = "data", mode: D.Mode = "overlap",
                 backend: ops.Backend = "auto", halo: D.Halo = "gathered",
                 pre_perm: Optional[jax.Array] = None,
                 pre_inv: Optional[jax.Array] = None):
        self.dist = dist
        self.mesh = mesh
        self.t_dist = t_dist
        self.diag = diag
        self.axis = axis
        self.mode = mode
        self.backend = backend
        self.halo = halo
        # Preprocessing (reorder=) permutation over the PADDED global
        # index space (identity on the pad tail): the partition holds
        # B = P A P^T and every apply sandwiches, so callers stay in
        # the original basis.  ``diag`` is already original-basis.
        self.pre_perm = pre_perm
        self.pre_inv = pre_inv
        self._fwd_cache = {}     # (which partition, multi_rhs) -> closure

    # -- structure ---------------------------------------------------------
    @property
    def shape(self):
        n = self.dist.n_global_pad
        return (n, n)

    @property
    def n_rows(self) -> int:
        """Unpadded global row count (rows past this are zero)."""
        return self.dist.n_rows

    @property
    def dtype(self):
        return self.dist.loc_val.dtype

    # -- application -------------------------------------------------------
    def _fwd(self, dist, multi_rhs):
        # Memoized per instance: the shard_map closure is built once per
        # (partition, arity) — rebuilding per call would discard the
        # build-once amortization AND defeat the solvers' jit cache.
        key = (dist is self.t_dist, multi_rhs)
        fn = self._fwd_cache.get(key)
        if fn is None:
            fn = D._make_dist_op(dist, self.mesh, self.axis, self.mode,
                                 self.backend, self.halo,
                                 multi_rhs=multi_rhs)
            self._fwd_cache[key] = fn
        return fn

    def _sandwich(self, apply, v):
        """Run ``apply`` in the stored (reordered) basis: gather v into
        it, gather the result back out.  B = P A P^T is
        symmetric-permuted, so the SAME sandwich serves A and A^T."""
        if self.pre_perm is None:
            return apply(v)
        return apply(v[self.pre_perm])[self.pre_inv]

    def matvec(self, x):
        fwd = self._fwd(self.dist, multi_rhs=False)
        if self.t_dist is None:
            return self._sandwich(fwd, x)
        return self._sandwich(lambda v: _linear_with_transpose(
            fwd, self._fwd(self.t_dist, multi_rhs=False), v), x)

    def matmat(self, x):
        fwd = self._fwd(self.dist, multi_rhs=True)
        if self.t_dist is None:
            return self._sandwich(fwd, x)
        return self._sandwich(lambda v: _linear_with_transpose(
            fwd, self._fwd(self.t_dist, multi_rhs=True), v), x)

    def rmatvec(self, y):
        if self.t_dist is None:
            raise ValueError(
                "this DistOperator was built without a transpose partition; "
                "use dist_operator(m, mesh, transpose='device')")
        return self._sandwich(lambda v: _linear_with_transpose(
            self._fwd(self.t_dist, multi_rhs=False),
            self._fwd(self.dist, multi_rhs=False), v), y)

    def rmatmat(self, y):
        if self.t_dist is None:
            raise ValueError(
                "this DistOperator was built without a transpose partition; "
                "use dist_operator(m, mesh, transpose='device')")
        return self._sandwich(lambda v: _linear_with_transpose(
            self._fwd(self.t_dist, multi_rhs=True),
            self._fwd(self.dist, multi_rhs=True), v), y)

    def diagonal(self):
        if self.diag is None:
            raise ValueError("this DistOperator carries no diagonal; "
                             "build it with dist_operator(m, mesh)")
        return self.diag

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        return ((self.dist, self.t_dist, self.diag, self.pre_perm,
                 self.pre_inv),
                (self.mesh, self.axis, self.mode, self.backend, self.halo))

    @classmethod
    def tree_unflatten(cls, aux, children):
        dist, t_dist, diag, pre_perm, pre_inv = children
        mesh, axis, mode, backend, halo = aux
        return cls(dist, mesh, t_dist=t_dist, diag=diag, axis=axis,
                   mode=mode, backend=backend, halo=halo,
                   pre_perm=pre_perm, pre_inv=pre_inv)


# --------------------------------------------------------------------------
# Factories
# --------------------------------------------------------------------------
def operator(
    a: Union[F.CSRMatrix, np.ndarray, ops.SparseDevice, SparseOperator],
    format: ops.FormatName = "auto",
    *,
    backend: ops.Backend = "auto",
    transpose: str = "ref",
    **convert_kwargs,
) -> SparseOperator:
    """Wrap ``a`` as a single-device :class:`SparseOperator`.

    ``a`` may be a host CSRMatrix, a dense ndarray, an existing
    ``SparseDevice``, or already an operator (returned unchanged).
    Conversion and caching ride :func:`kernels.ops.as_device`;
    ``format``/``convert_kwargs`` (b_r, diag_align, sigma, chunk_l,
    dtype, index_dtype, x_tiles, tune, reorder) pass through — in
    particular ``reorder="auto"`` runs the priced RCM preprocessing
    stage (``core.reorder.preprocess``): the permutation is recorded on
    the device operand and every apply transparently permutes in and
    unpermutes out, so callers stay in the original basis (with
    ``transpose="device"`` each operand prices and sandwiches its own
    reorder independently), and
    ``dtype=jnp.bfloat16`` stores a compressed bf16 value stream (f32
    accumulation; ``op.dtype`` reports the storage dtype, results come
    back f32), ``index_dtype="auto"`` (the default) compresses the
    column indices to int16 whenever the column span fits, and
    ``tune="auto"`` replaces the static dispatch heuristic with the
    measured autotuner (``repro.tune``, DESIGN.md §9; with
    ``transpose="device"`` the transposed operand is tuned
    independently — its row statistics are A's COLUMN statistics).
    ``transpose="device"`` additionally converts
    ``A^T`` (``formats.csr_transpose`` — the CSC-of-blocks build) so
    ``op.T @ x`` runs the forward kernels; the default ``"ref"`` serves
    transposes from the scatter-accumulate refs with no extra storage.
    """
    if isinstance(a, SparseOperator):
        return a
    if isinstance(a, ops.SparseDevice):
        if format not in ("auto", a.fmt):
            raise ValueError(
                f"matrix already converted to {a.fmt!r}; asked for {format!r}")
        if transpose == "device":
            raise ValueError(
                "transpose='device' needs the host matrix to build the "
                "transposed operand; pass the CSRMatrix (or ndarray) "
                "instead of a SparseDevice")
        if transpose != "ref":
            raise ValueError(f"transpose must be 'ref' or 'device'; "
                             f"got {transpose!r}")
        return DeviceOperator(a, backend=backend)
    if isinstance(a, np.ndarray):
        a = ops._dense_to_csr_cached(a)
    if not isinstance(a, F.CSRMatrix):
        raise TypeError(f"cannot build an operator from {type(a)}")
    dev = ops.as_device(a, format, **convert_kwargs)
    t_dev = None
    if transpose == "device":
        t_dev = ops.as_device(F.csr_transpose(a), format, **convert_kwargs)
    elif transpose != "ref":
        raise ValueError(f"transpose must be 'ref' or 'device'; "
                         f"got {transpose!r}")
    return DeviceOperator(dev, t_dev=t_dev, backend=backend)


def dist_operator(
    m: Union[F.CSRMatrix, D.DistPJDS],
    mesh,
    *,
    axis: str = "data",
    mode: str = "overlap",
    backend: ops.Backend = "auto",
    halo: str = "gathered",
    transpose: str = "device",
    b_r: int = 128,
    diag_align: int = 8,
    chunk_l: int = 8,
    halo_w: Optional[int] = None,
    sigma: Optional[int] = None,
    index_dtype="auto",
    tune: str = "off",
    grid=None,
    build_stages: bool = True,
    reorder: str = "off",
) -> DistOperator:
    """Partition ``m`` over ``mesh[axis]`` as a :class:`DistOperator`.

    With a host CSR, the transpose partition (``transpose="device"``,
    the default) and the global diagonal are built alongside, so
    ``op.T``, x-gradients and Jacobi preconditioning work distributed;
    ``transpose=None`` skips the second partition.  Passing an existing
    ``DistPJDS`` wraps it as-is (no transpose, no diagonal).
    ``index_dtype="auto"`` stores int16 column indices whenever the
    per-device slice spans fit (they are structurally bounded by the
    row partition — see ``dist_spmv.partition_csr``).

    ``grid=(gr, gc)`` partitions over a 2-D device grid (halo volume
    shrinks with ``gr``, the partial-sum reduction rides grid rows of
    ``gc`` — see ``dist_spmv``); the transpose partition uses the
    SWAPPED grid ``(gc, gr)``, since transposing exchanges the roles of
    the x halo and the y reduction.  ``grid="auto"`` picks the shape:
    measured by the tuner when ``tune`` is on, otherwise the
    model-cheapest of ``dist_spmv.grid_shapes`` under
    ``perf_model.predicted_dist_spmv_seconds``.

    ``halo="auto"`` resolves the gathered-vs-full exchange crossover
    from the installed ``perf_model`` calibration
    (``perf_model.choose_halo``; fit the per-message fixed costs with
    ``tune.calibrate.fit_link_calibration`` first, or let the tuner
    measure the winner directly).  ``mode="auto"`` likewise defers to
    the tuner, falling back to ``"overlap"``.

    ``reorder="auto"|"rcm"`` runs the priced RCM preprocessing stage
    (``core.reorder.preprocess``) on the host CSR before partitioning,
    with the halo term evaluated at this mesh's device count: "auto"
    applies the permutation only when the calibrated model predicts the
    reduced halo outweighs the per-apply permute sandwich, "rcm" forces
    it.  The operator records the permutation and every
    matvec/rmatvec/solve transparently permutes in and unpermutes out,
    so callers stay in the original row/column basis (the diagonal is
    stored original-basis too).

    ``tune="auto"|"force"`` measures the best tile height for the LOCAL
    and REMOTE operands independently (``repro.tune.tune_partition``;
    cached persistently like the single-device tuner) and partitions
    with the winners — the forward and transpose partitions are tuned
    separately, since ``A^T``'s halo coupling is the mirror structure.
    When any of ``grid``/``halo``/``mode`` is ``"auto"`` the tuner
    additionally sweeps the communication config over ``mesh`` (one
    timed sharded spMVM per candidate) and the measured winners fill
    the auto slots.
    """
    if isinstance(m, D.DistPJDS):
        if grid not in (None, "auto"):
            raise ValueError("grid cannot be changed on an existing "
                             "DistPJDS; partition the host CSR instead")
        if mode == "auto":
            mode = "overlap"
        if halo == "auto":
            halo = PM.choose_halo(m, mode=mode,
                                  value_bytes=m.loc_val.dtype.itemsize)
        return DistOperator(m, mesh, axis=axis, mode=mode, backend=backend,
                            halo=halo)
    n_dev = mesh.shape[axis]
    if tune not in ("off", "auto", "force"):
        raise ValueError(f"tune must be 'off', 'auto' or 'force'; "
                         f"got {tune!r}")
    if reorder not in ("off", "auto", "rcm"):
        raise ValueError(f"reorder must be 'off', 'auto' or 'rcm'; "
                         f"got {reorder!r}")

    perm_host = inv_host = None
    diag_host = F.csr_diagonal(m)          # original basis, pre-reorder
    if reorder != "off":
        from repro.core import reorder as RO
        pp = RO.preprocess(m, reorder=reorder, n_dev=n_dev,
                           value_bytes=m.data.dtype.itemsize)
        if pp.applied:
            m = pp.matrix
            perm_host, inv_host = pp.perm, pp.inv_perm

    sweep = tune != "off" and ("auto" in (grid, halo, mode))

    def _chunks(mm, comm_sweep=False):
        if tune == "off":
            return chunk_l, None, None
        from repro import tune as T    # deferred: tune imports kernels.ops
        tp = T.tune_partition(mm, n_dev, b_r=b_r, diag_align=diag_align,
                              sigma=sigma, index_dtype=index_dtype,
                              force=(tune == "force"),
                              mesh=mesh if comm_sweep else None, axis=axis)
        return tp.chunk_l, tp.rem_chunk_l, tp

    cl, rcl, tp = _chunks(m, comm_sweep=sweep)
    if sweep:
        if grid == "auto":
            grid = tp.grid
        if halo == "auto" and tp.halo:
            halo = tp.halo
        if mode == "auto" and tp.mode:
            mode = tp.mode
    if mode == "auto":
        mode = "overlap"

    def _build(mm, g, clb, rclb, hw):
        return D.partition_csr(mm, n_dev, b_r=b_r, diag_align=diag_align,
                               chunk_l=clb, halo_w=hw, sigma=sigma,
                               index_dtype=index_dtype, rem_chunk_l=rclb,
                               grid=g, build_stages=build_stages)

    if grid == "auto":
        # No measured sweep available: price every grid shape with the
        # (calibrated) perf model and keep the cheapest partition.
        cands = [_build(m, g if g != (n_dev, 1) else None, cl, rcl, halo_w)
                 for g in D.grid_shapes(n_dev)]
        hs = ("gathered", "full") if halo == "auto" else (halo,)
        cost = [min(PM.predicted_dist_spmv_seconds(
                        d, halo=h, mode=mode,
                        value_bytes=d.loc_val.dtype.itemsize)
                    for h in hs) for d in cands]
        dist = cands[int(np.argmin(cost))]
    else:
        dist = _build(m, grid, cl, rcl, halo_w)
    if halo == "auto":
        halo = PM.choose_halo(dist, mode=mode,
                              value_bytes=dist.loc_val.dtype.itemsize)

    t_dist = None
    if transpose == "device":
        mt = F.csr_transpose(m)
        cl_t, rcl_t, _ = _chunks(mt)
        g = dist.grid
        t_dist = _build(mt, (g[1], g[0]) if g else None, cl_t, rcl_t, None)
    elif transpose is not None:
        raise ValueError(f"transpose must be 'device' or None; "
                         f"got {transpose!r}")
    dg = np.zeros(dist.n_global_pad, dtype=m.data.dtype)
    dg[: m.n_rows] = diag_host
    pre_perm = pre_inv = None
    if perm_host is not None:
        # Extend to the padded global space with an identity tail so the
        # sandwich gathers commute with the partition padding.
        tail = np.arange(m.n_rows, dist.n_global_pad)
        pre_perm = jnp.asarray(
            np.concatenate([perm_host, tail]).astype(np.int32))
        pre_inv = jnp.asarray(
            np.concatenate([inv_host, tail]).astype(np.int32))
    return DistOperator(dist, mesh, t_dist=t_dist, diag=jnp.asarray(dg),
                        axis=axis, mode=mode, backend=backend, halo=halo,
                        pre_perm=pre_perm, pre_inv=pre_inv)
