"""``repro.solve`` — the one front door to every linear solve.

The rest of the package is layered exactly like the paper's software
stack: storage formats (``core.formats``), device kernels
(``kernels``), the operator protocol (``core.operator``), Krylov
methods (``core.solvers``), the autotuner (``tune``).  ``solve`` is the
seam that composes them for the common case::

    import repro
    res = repro.solve(m, b)                      # host CSR, CG, tuned
    res = repro.solve(op, b, method="bicgstab")  # existing operator
    res.x, res.residual, res.iters, res.converged, res.info

It owns the three decisions a caller would otherwise wire by hand:

* STRATEGY — the fused spMV+dots iteration (``kernels.fused_iter`` +
  ``solvers.fused_cg``/``fused_bicgstab``) whenever the operand
  supports it (single-device SELL, resident RHS, square, no
  preconditioner), the composed operator bodies otherwise (Dist
  operators, block solves, preconditioned solves, bare closures);
* TUNING — for host matrices, ``tune.tune_solver`` measures layout
  candidates under the solver's own iteration (the config that wins
  per ITERATION, not per matvec) and caches the winner under the
  structural-fingerprint key;
* PRECISION — ``refine`` wraps the solve in mixed-precision iterative
  refinement (``solvers.iterative_refinement``): inner iterations
  against a bf16(+int16) operand at 0.50x bytes/nnz, residual
  corrections against the full-precision operator, final accuracy at
  the f32 target.

``refine="auto"`` turns refinement on exactly when a host matrix is
requested with a sub-f32 ``dtype`` (the outer operator is then built at
native f32 and the INNER one at the requested dtype); ``refine=True``
forces it — for an existing f32 operator the inner operand is a bf16
cast of it (Device and Dist operators both).  Refining a bare closure
or a block solve raises (there is nothing to cast / no block
refinement path).

Every call returns :class:`repro.core.solvers.SolveResult`; ``info``
carries ``strategy``, per-phase wall-clock ``phase_s`` (tune / build /
solve), the tuner's decision under ``tune`` and per-round refinement
diagnostics under ``refine``.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core import solvers as S
from repro.core.solvers import SolveResult

__all__ = ["solve"]

_METHODS = ("cg", "bicgstab", "block_cg")
_DEFAULT_MAXITER = {"cg": 500, "bicgstab": 1000, "block_cg": 500}


def _is_host_matrix(a) -> bool:
    from repro.core import formats as F
    return isinstance(a, F.CSRMatrix)


def _is_sub_f32(dtype) -> bool:
    if dtype is None:
        return False
    dt = jnp.dtype(dtype)
    return jnp.issubdtype(dt, jnp.floating) and dt.itemsize < 4


def _fused_eligible(op, method: str, precond, b: jax.Array) -> bool:
    """The fused iteration needs: a single-device SELL operand with the
    resident-x grid (x_tiles == 1 — the fused epilogue runs once per
    window), square, 1-D RHS, no preconditioner (the epilogue reduces
    plain dots), and a cg/bicgstab recurrence."""
    from repro.core.operator import DeviceOperator
    return (method in ("cg", "bicgstab") and precond is None
            and b.ndim == 1 and isinstance(op, DeviceOperator)
            and op.fmt == "sell" and op.dev.x_tiles == 1
            and op.shape[0] == op.shape[1])


def _fused_dots_of(op):
    """The fused-pass closure over ``op``'s SELL operand, cached on the
    operator instance — it is the static jit key of the fused solvers,
    so one closure per operand means one compile per operand."""
    cached = getattr(op, "_fused_dots", None)
    if cached is not None:
        return cached
    from repro.kernels import ops as K
    from repro.kernels.fused_iter import make_matvec_dots
    mvd = make_matvec_dots(op.dev.dev, backend=K.resolve_backend(op.backend))
    try:
        op._fused_dots = mvd
    except (AttributeError, TypeError):
        pass
    return mvd


def _cast_low_precision(op):
    """A bf16 clone of an existing f32 operator for refinement's inner
    solves: every floating leaf of the device/distributed operand drops
    to bf16 (0.25x value bytes); a single-device SELL operand whose
    column space fits additionally compresses ``col_idx`` to int16,
    landing on the PR-4 0.50x bytes/nnz layout.  Structure-only fields
    (index maps, permutations, halo tables) are untouched, so the clone
    shares the original's partition/layout exactly."""
    import dataclasses as _dc

    from repro.core.operator import DeviceOperator, DistOperator

    def _lo(leaf):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf.astype(jnp.bfloat16)
        return leaf

    if isinstance(op, DeviceOperator):
        dev = jax.tree_util.tree_map(_lo, op.dev)
        inner = dev.dev
        if (op.fmt == "sell" and hasattr(inner, "col_idx")
                and op.shape[1] <= jnp.iinfo(jnp.int16).max):
            inner = _dc.replace(inner,
                                col_idx=inner.col_idx.astype(jnp.int16))
            dev = _dc.replace(dev, dev=inner)
        return DeviceOperator(dev, backend=op.backend)
    if isinstance(op, DistOperator):
        dist = jax.tree_util.tree_map(_lo, op.dist)
        return DistOperator(dist, op.mesh, axis=op.axis, mode=op.mode,
                            backend=op.backend, halo=op.halo,
                            diag=op.diag)
    raise ValueError(
        "refine=True needs a Device/Dist operator (or a host matrix) to "
        f"cast to bf16; got {type(op).__name__}")


def _pad_to(v: jax.Array, n_pad: int) -> jax.Array:
    return v if v.shape[0] == n_pad else jnp.pad(v, (0, n_pad - v.shape[0]))


def _one_solve(op, b, *, method, strategy, maxiter, tol, precond,
               x0=None) -> SolveResult:
    if strategy == "fused":
        mvd = _fused_dots_of(op)
        n, n_pad = op.shape[0], op.dev.dev.n_rows_pad
        bp = _pad_to(b, n_pad)
        x0p = None if x0 is None else _pad_to(x0, n_pad)
        fn = S.fused_cg if method == "cg" else S.fused_bicgstab
        res = fn(mvd, bp, x0=x0p, maxiter=maxiter, tol=tol)
        res.x = res.x[:n]
        return res
    if method == "cg":
        return S.cg(op, b, x0=x0, maxiter=maxiter, tol=tol, M=precond)
    if method == "bicgstab":
        return S.bicgstab(op, b, x0=x0, maxiter=maxiter, tol=tol, M=precond)
    return S.block_cg(op, b, x0=x0, maxiter=maxiter, tol=tol)


def _refined_solve(op, op_lo, b, *, method, strategy, maxiter, tol,
                   precond, x0=None) -> SolveResult:
    """Mixed-precision refinement: inner ``method`` solves on the
    low-precision operand, residual corrections on the full-precision
    one.  The inner tolerance is floored at 1e-3 — bf16 storage cannot
    resolve much further, and the outer loop closes the rest."""
    apply_full = S._matvec_of(op)
    inner_tol = max(tol, 1e-3)
    inner_strategy = ("fused" if _fused_eligible(op_lo, method, precond, b)
                      else "composed")

    def residual_of(x):
        return b - apply_full(x)

    def inner(r):
        rr = _one_solve(op_lo, r.astype(b.dtype), method=method,
                        strategy=inner_strategy, maxiter=maxiter,
                        tol=inner_tol, precond=precond)
        return rr.x.astype(b.dtype), rr.iters, rr.residual

    x, rn, rounds = S.iterative_refinement(residual_of, inner, b,
                                           x0=x0, tol=tol)
    total = sum(r["inner_iters"] for r in rounds)
    res = S._result(method, x, total, rn, tol,
                    strategy=f"{inner_strategy}+refined")
    res.info["refine"] = {
        "rounds": rounds,
        "inner_dtype": str(op_lo.dtype),
        "inner_tol": inner_tol,
    }
    return res


def solve(a, b, *, method: str = "cg", precond=None, tol: float = 1e-6,
          maxiter: int | None = None, x0=None, tune="auto",
          refine="auto", format: str = "auto", dtype=None,
          index_dtype="auto", backend="auto",
          **convert_kwargs) -> SolveResult:
    """Solve ``A x = b``; see the module docstring for the decisions
    this front door makes.

    ``a``: a host ``CSRMatrix`` (an operator is built — ``format`` /
    ``dtype`` / ``index_dtype`` / ``backend`` and any further
    ``as_device`` keywords apply, unless the tuner picks the layout), an
    existing ``SparseOperator`` (used as-is), or a bare matvec closure
    (composed strategy only).  ``method``: ``"cg"`` (SPD),
    ``"bicgstab"`` (general), ``"block_cg"`` (SPD, b of shape (n, k)).
    ``precond``: ``None``, ``"jacobi"`` or a callable ``z = M(r)``.
    ``tune``: ``"auto"`` measures solver-level layout candidates for
    host matrices (cached; ``"force"`` re-measures), ``"off"`` builds
    the heuristic layout.  ``refine``: ``"auto"`` / ``True`` / ``False``
    mixed-precision refinement, see module docstring.
    """
    if method not in _METHODS:
        raise ValueError(f"method must be one of {_METHODS}; got {method!r}")
    b = jnp.asarray(b)
    if method == "block_cg" and b.ndim != 2:
        raise ValueError(f"block_cg expects b of shape (n, k); got {b.shape}")
    if method != "block_cg" and b.ndim != 1:
        raise ValueError(f"{method} expects a 1-D b; got shape {b.shape}")
    if refine is True and method == "block_cg":
        raise ValueError("refine is not available for block_cg "
                         "(no block refinement path)")
    if refine is True and callable(precond):
        raise ValueError("refine=True cannot re-derive a callable precond "
                         "for the low-precision operand; use precond="
                         "'jacobi' or None")
    maxiter = _DEFAULT_MAXITER[method] if maxiter is None else maxiter
    phase_s: dict = {}
    info_tune = None
    strategy_pref = None
    op_lo = None

    if _is_host_matrix(a):
        m = a
        do_refine = (refine is True
                     or (refine == "auto" and _is_sub_f32(dtype)
                         and method != "block_cg"))
        inner_dtype = dtype if _is_sub_f32(dtype) else jnp.bfloat16
        build_kwargs = dict(convert_kwargs)
        t0 = time.perf_counter()
        if tune not in ("off", False, None) and method != "block_cg":
            from repro import tune as T
            st = T.tune_solver(m, method=method,
                               dtype=None if do_refine else dtype,
                               index_dtype=index_dtype,
                               force=(tune == "force"))
            strategy_pref = st.strategy
            build_kwargs = st.layout.build_kwargs()
            info_tune = {"cached": st.cached, "strategy": st.strategy,
                         "layout": st.layout.label()}
        else:
            build_kwargs.setdefault("format", format)
            if (build_kwargs["format"] == "auto"
                    and method in ("cg", "bicgstab") and precond is None):
                build_kwargs["format"] = "sell"   # fused-eligible build
        phase_s["tune"] = time.perf_counter() - t0

        from repro.core.operator import operator
        t0 = time.perf_counter()
        op = operator(m, dtype=None if do_refine else dtype,
                      index_dtype=index_dtype, backend=backend,
                      **build_kwargs)
        if do_refine:
            op_lo = operator(m, dtype=inner_dtype, index_dtype=index_dtype,
                             backend=backend, **build_kwargs)
        phase_s["build"] = time.perf_counter() - t0
    else:
        op = a
        is_operator = hasattr(op, "matvec")
        do_refine = refine is True
        if do_refine and not is_operator:
            raise ValueError("refine=True needs an operator or host matrix; "
                             "got a bare closure")
        if do_refine and _is_sub_f32(getattr(op, "dtype", None)):
            raise ValueError("refine=True expects a full-precision operator "
                             "to refine against; this one is already "
                             f"{op.dtype} — pass the host matrix instead")
        t0 = time.perf_counter()
        if do_refine:
            op_lo = _cast_low_precision(op)
        phase_s["build"] = time.perf_counter() - t0

    strategy = ("fused"
                if (_fused_eligible(op, method, precond, b)
                    and strategy_pref != "composed")
                else "composed")

    t0 = time.perf_counter()
    if do_refine:
        res = _refined_solve(op, op_lo, b, method=method, strategy=strategy,
                             maxiter=maxiter, tol=tol, precond=precond,
                             x0=x0)
    else:
        res = _one_solve(op, b, method=method, strategy=strategy,
                         maxiter=maxiter, tol=tol, precond=precond, x0=x0)
    phase_s["solve"] = time.perf_counter() - t0

    res.info["phase_s"] = phase_s
    if info_tune is not None:
        res.info["tune"] = info_tune
    return res
