"""``repro.solve`` — the one front door to every linear solve.

The rest of the package is layered exactly like the paper's software
stack: storage formats (``core.formats``), device kernels
(``kernels``), the operator protocol (``core.operator``), Krylov
methods (``core.solvers``), the autotuner (``tune``).  ``solve`` is the
seam that composes them for the common case::

    import repro
    res = repro.solve(m, b)                      # host CSR, CG, tuned
    res = repro.solve(op, b, method="bicgstab")  # existing operator
    res.x, res.residual, res.iters, res.converged, res.info

It owns the three decisions a caller would otherwise wire by hand:

* STRATEGY — the fused spMV+dots iteration (``kernels.fused_iter`` +
  ``solvers.fused_cg``/``fused_bicgstab``) whenever the operand
  supports it (single-device SELL, resident RHS, square, no
  preconditioner), the composed operator bodies otherwise (Dist
  operators, block solves, preconditioned solves, bare closures);
* TUNING — for host matrices, ``tune.tune_solver`` measures layout
  candidates under the solver's own iteration (the config that wins
  per ITERATION, not per matvec) and caches the winner under the
  structural-fingerprint key;
* PRECISION — ``refine`` wraps the solve in mixed-precision iterative
  refinement (``solvers.iterative_refinement``): inner iterations
  against a bf16(+int16) operand at 0.50x bytes/nnz, residual
  corrections against the full-precision operator, final accuracy at
  the f32 target.

``refine="auto"`` turns refinement on exactly when a host matrix is
requested with a sub-f32 ``dtype`` (the outer operator is then built at
native f32 and the INNER one at the requested dtype); ``refine=True``
forces it — for an existing f32 operator the inner operand is a bf16
cast of it (Device and Dist operators both).  Refining a bare closure
or a block solve raises (there is nothing to cast / no block
refinement path).

Every call returns :class:`repro.core.solvers.SolveResult`; ``info``
carries ``strategy``, per-phase wall-clock ``phase_s`` (tune / build /
solve), the tuner's decision under ``tune`` and per-round refinement
diagnostics under ``refine``.
"""
from __future__ import annotations

import math
import time

import jax
import jax.numpy as jnp

from repro.core import solvers as S
from repro.core.solvers import SolveResult

__all__ = ["solve", "SolveFailure"]

_METHODS = ("cg", "bicgstab", "block_cg")
_DEFAULT_MAXITER = {"cg": 500, "bicgstab": 1000, "block_cg": 500}


class SolveFailure(RuntimeError):
    """Raised by :func:`solve` when the degradation ladder is exhausted:
    every rung either raised or ended in a failure status (breakdown /
    diverged / non_finite / decertified).  Carries the evidence —
    ``ladder`` is the per-rung record (label, status or error, certified
    residual) and ``result`` the last :class:`SolveResult` produced (its
    ``status``/``diagnostics`` describe the final failure), or ``None``
    if every rung raised before producing one."""

    def __init__(self, message: str, *, result=None, ladder=None):
        super().__init__(message)
        self.result = result
        self.ladder = list(ladder or [])


def _is_host_matrix(a) -> bool:
    from repro.core import formats as F
    return isinstance(a, F.CSRMatrix)


def _is_sub_f32(dtype) -> bool:
    if dtype is None:
        return False
    dt = jnp.dtype(dtype)
    return jnp.issubdtype(dt, jnp.floating) and dt.itemsize < 4


def _fused_eligible(op, method: str, precond, b: jax.Array) -> bool:
    """The fused iteration needs: a single-device SELL operand with the
    resident-x grid (x_tiles == 1 — the fused epilogue runs once per
    window), square, 1-D RHS, no preconditioner (the epilogue reduces
    plain dots), and a cg/bicgstab recurrence."""
    from repro.core.operator import DeviceOperator
    return (method in ("cg", "bicgstab") and precond is None
            and b.ndim == 1 and isinstance(op, DeviceOperator)
            and op.fmt == "sell" and op.dev.x_tiles == 1
            and op.shape[0] == op.shape[1])


def _fused_dots_of(op):
    """The fused-pass closure over ``op``'s SELL operand, cached on the
    operator instance — it is the static jit key of the fused solvers,
    so one closure per operand means one compile per operand."""
    cached = getattr(op, "_fused_dots", None)
    if cached is not None:
        return cached
    from repro.kernels import ops as K
    from repro.kernels.fused_iter import make_matvec_dots
    mvd = make_matvec_dots(op.dev.dev, backend=K.resolve_backend(op.backend))
    try:
        op._fused_dots = mvd
    except (AttributeError, TypeError):
        pass
    return mvd


def _cast_low_precision(op):
    """A bf16 clone of an existing f32 operator for refinement's inner
    solves: every floating leaf of the device/distributed operand drops
    to bf16 (0.25x value bytes); a single-device SELL operand whose
    column space fits additionally compresses ``col_idx`` to int16,
    landing on the PR-4 0.50x bytes/nnz layout.  Structure-only fields
    (index maps, permutations, halo tables) are untouched, so the clone
    shares the original's partition/layout exactly."""
    import dataclasses as _dc

    from repro.core.operator import DeviceOperator, DistOperator

    def _lo(leaf):
        if hasattr(leaf, "dtype") and jnp.issubdtype(leaf.dtype, jnp.floating):
            return leaf.astype(jnp.bfloat16)
        return leaf

    if isinstance(op, DeviceOperator):
        dev = jax.tree_util.tree_map(_lo, op.dev)
        inner = dev.dev
        if (op.fmt == "sell" and hasattr(inner, "col_idx")
                and op.shape[1] <= jnp.iinfo(jnp.int16).max):
            inner = _dc.replace(inner,
                                col_idx=inner.col_idx.astype(jnp.int16))
            dev = _dc.replace(dev, dev=inner)
        return DeviceOperator(dev, backend=op.backend)
    if isinstance(op, DistOperator):
        dist = jax.tree_util.tree_map(_lo, op.dist)
        return DistOperator(dist, op.mesh, axis=op.axis, mode=op.mode,
                            backend=op.backend, halo=op.halo,
                            diag=op.diag)
    raise ValueError(
        "refine=True needs a Device/Dist operator (or a host matrix) to "
        f"cast to bf16; got {type(op).__name__}")


def _pad_to(v: jax.Array, n_pad: int) -> jax.Array:
    return v if v.shape[0] == n_pad else jnp.pad(v, (0, n_pad - v.shape[0]))


def _one_solve(op, b, *, method, strategy, maxiter, tol, precond,
               x0=None) -> SolveResult:
    if strategy == "fused":
        mvd = _fused_dots_of(op)
        n, n_pad = op.shape[0], op.dev.dev.n_rows_pad
        bp = _pad_to(b, n_pad)
        x0p = None if x0 is None else _pad_to(x0, n_pad)
        fn = S.fused_cg if method == "cg" else S.fused_bicgstab
        res = fn(mvd, bp, x0=x0p, maxiter=maxiter, tol=tol)
        res.x = res.x[:n]
        return res
    if method == "cg":
        return S.cg(op, b, x0=x0, maxiter=maxiter, tol=tol, M=precond)
    if method == "bicgstab":
        return S.bicgstab(op, b, x0=x0, maxiter=maxiter, tol=tol, M=precond)
    return S.block_cg(op, b, x0=x0, maxiter=maxiter, tol=tol)


def _refined_solve(op, op_lo, b, *, method, strategy, maxiter, tol,
                   precond, x0=None) -> SolveResult:
    """Mixed-precision refinement: inner ``method`` solves on the
    low-precision operand, residual corrections on the full-precision
    one.  The inner tolerance is floored at 1e-3 — bf16 storage cannot
    resolve much further, and the outer loop closes the rest."""
    apply_full = S._matvec_of(op)
    inner_tol = max(tol, 1e-3)
    inner_strategy = ("fused" if _fused_eligible(op_lo, method, precond, b)
                      else "composed")

    def residual_of(x):
        return b - apply_full(x)

    def inner(r):
        rr = _one_solve(op_lo, r.astype(b.dtype), method=method,
                        strategy=inner_strategy, maxiter=maxiter,
                        tol=inner_tol, precond=precond)
        return rr.x.astype(b.dtype), rr.iters, rr.residual

    x, rn, rounds, reason = S.iterative_refinement(residual_of, inner, b,
                                                   x0=x0, tol=tol)
    # The divergence guard: a stalled or poisoned refinement is a typed
    # failure (the ladder escalates to the f32 rung), not maxiter worth
    # of useless corrections.
    flag = {"stalled": S.STATUS_DIVERGED,
            "non_finite": S.STATUS_NON_FINITE}.get(reason, 0)
    total = sum(r["inner_iters"] for r in rounds)
    res = S._result(method, x, total, rn, tol, flag=flag,
                    diagnostics={"refine_reason": reason,
                                 "true_residual": rn,
                                 "certified": reason == "converged"},
                    strategy=f"{inner_strategy}+refined")
    res.info["refine"] = {
        "rounds": rounds,
        "reason": reason,
        "inner_dtype": str(op_lo.dtype),
        "inner_tol": inner_tol,
    }
    return res


def _true_rel_residual(op, b, x) -> float:
    """Certified relative true residual ||b - A x|| / ||b|| through the
    operator (max over columns for block RHS) — the arbiter behind
    ``status == "converged"``."""
    r = b - S._matvec_of(op)(x)
    if b.ndim == 1:
        return float(jnp.linalg.norm(r)
                     / jnp.maximum(jnp.linalg.norm(b), 1e-30))
    num = jnp.linalg.norm(r, axis=0)
    den = jnp.maximum(jnp.linalg.norm(b, axis=0), 1e-30)
    return float(jnp.max(num / den))


def _certify(res: SolveResult, op, b, tol: float) -> SolveResult:
    """Demote a "converged" claim whose certified true residual misses
    tol (recurrence drift, a broken kernel, a garbled exchange):
    certification is the arbiter, not the recurrence.  Skipped when the
    solver already certified (fused drive / refinement measure the true
    residual themselves — ``diagnostics["true_residual"]`` present)."""
    if tol <= 0:
        return res
    if "true_residual" not in res.diagnostics:
        try:
            rn = _true_rel_residual(op, b, res.x)
        except Exception as e:                      # certification broke
            res.diagnostics["certify_error"] = f"{type(e).__name__}: {e}"
            rn = float("nan")
        res.diagnostics["true_residual"] = rn
        res.diagnostics["certified"] = rn == rn and rn <= tol
    if res.status == "converged" and not res.diagnostics.get("certified"):
        res.status_code = S.STATUS_DIVERGED
        res.converged = jnp.asarray(False)
        res.diagnostics["demoted"] = True
    return res


def solve(a, b, *, method: str = "cg", precond=None, tol: float = 1e-6,
          maxiter: int | None = None, x0=None, tune="auto",
          refine="auto", fallback="auto", format: str = "auto", dtype=None,
          index_dtype="auto", backend="auto",
          **convert_kwargs) -> SolveResult:
    """Solve ``A x = b``; see the module docstring for the decisions
    this front door makes.

    ``a``: a host ``CSRMatrix`` (an operator is built — ``format`` /
    ``dtype`` / ``index_dtype`` / ``backend`` and any further
    ``as_device`` keywords apply, unless the tuner picks the layout), an
    existing ``SparseOperator`` (used as-is), or a bare matvec closure
    (composed strategy only).  ``method``: ``"cg"`` (SPD),
    ``"bicgstab"`` (general), ``"block_cg"`` (SPD, b of shape (n, k)).
    ``precond``: ``None``, ``"jacobi"`` or a callable ``z = M(r)``.
    ``tune``: ``"auto"`` measures solver-level layout candidates for
    host matrices (cached; ``"force"`` re-measures), ``"off"`` builds
    the heuristic layout.  ``refine``: ``"auto"`` / ``True`` / ``False``
    mixed-precision refinement, see module docstring.

    ``fallback="auto"`` (default) arms the degradation ladder: a rung
    that raises or ends in a failure status (breakdown / diverged /
    non_finite / a "converged" claim demoted by the true-residual
    certification) falls through fused->composed, bf16-refined->f32,
    kernel backend->ref and a final escalation retry (fresh x0 + jacobi)
    — the rungs taken are recorded in ``result.info["ladder"]`` and
    exhaustion raises a typed :class:`SolveFailure`.  ``fallback="off"``
    runs only the preferred configuration and returns its typed result
    (``result.status``) without retrying or raising.  Either way a
    result with ``status == "converged"`` has a certified true residual
    ``<= tol`` (see ``result.diagnostics["true_residual"]``).
    """
    if method not in _METHODS:
        raise ValueError(f"method must be one of {_METHODS}; got {method!r}")
    if not isinstance(b, jax.Array):
        b = jnp.asarray(b)
    if method == "block_cg" and b.ndim != 2:
        raise ValueError(f"block_cg expects b of shape (n, k); got {b.shape}")
    if method != "block_cg" and b.ndim != 1:
        raise ValueError(f"{method} expects a 1-D b; got shape {b.shape}")
    if refine is True and method == "block_cg":
        raise ValueError("refine is not available for block_cg "
                         "(no block refinement path)")
    if refine is True and callable(precond):
        raise ValueError("refine=True cannot re-derive a callable precond "
                         "for the low-precision operand; use precond="
                         "'jacobi' or None")
    maxiter = _DEFAULT_MAXITER[method] if maxiter is None else maxiter
    phase_s: dict = {}
    info_tune = None
    strategy_pref = None
    op_lo = None

    if _is_host_matrix(a):
        m = a
        do_refine = (refine is True
                     or (refine == "auto" and _is_sub_f32(dtype)
                         and method != "block_cg"))
        inner_dtype = dtype if _is_sub_f32(dtype) else jnp.bfloat16
        build_kwargs = dict(convert_kwargs)
        t0 = time.perf_counter()
        if tune not in ("off", False, None) and method != "block_cg":
            from repro import tune as T
            st = T.tune_solver(m, method=method,
                               dtype=None if do_refine else dtype,
                               index_dtype=index_dtype,
                               force=(tune == "force"))
            strategy_pref = st.strategy
            build_kwargs = st.layout.build_kwargs()
            if "validate" in convert_kwargs:   # admission gate survives
                build_kwargs["validate"] = convert_kwargs["validate"]
            info_tune = {"cached": st.cached, "strategy": st.strategy,
                         "layout": st.layout.label()}
        else:
            build_kwargs.setdefault("format", format)
            if (build_kwargs["format"] == "auto"
                    and method in ("cg", "bicgstab") and precond is None):
                build_kwargs["format"] = "sell"   # fused-eligible build
        phase_s["tune"] = time.perf_counter() - t0

        from repro.core.operator import operator
        t0 = time.perf_counter()
        op = operator(m, dtype=None if do_refine else dtype,
                      index_dtype=index_dtype, backend=backend,
                      **build_kwargs)
        if do_refine:
            op_lo = operator(m, dtype=inner_dtype, index_dtype=index_dtype,
                             backend=backend, **build_kwargs)
        phase_s["build"] = time.perf_counter() - t0
    else:
        op = a
        is_operator = hasattr(op, "matvec")
        do_refine = refine is True
        if do_refine and not is_operator:
            raise ValueError("refine=True needs an operator or host matrix; "
                             "got a bare closure")
        if do_refine and _is_sub_f32(getattr(op, "dtype", None)):
            raise ValueError("refine=True expects a full-precision operator "
                             "to refine against; this one is already "
                             f"{op.dtype} — pass the host matrix instead")
        t0 = time.perf_counter()
        if do_refine:
            op_lo = _cast_low_precision(op)
        phase_s["build"] = time.perf_counter() - t0

    strategy = ("fused"
                if (_fused_eligible(op, method, precond, b)
                    and strategy_pref != "composed")
                else "composed")

    t0 = time.perf_counter()
    res, ladder = _ladder_solve(op, op_lo, b, method=method,
                                strategy=strategy, maxiter=maxiter, tol=tol,
                                precond=precond, x0=x0, fallback=fallback)
    phase_s["solve"] = time.perf_counter() - t0

    res.info["phase_s"] = phase_s
    if info_tune is not None:
        res.info["tune"] = info_tune
    if len(ladder) > 1 or fallback not in ("off", False, None):
        res.info["ladder"] = ladder
    return res


def _build_rungs(op, op_lo, *, method, strategy, precond, fallback):
    """The degradation ladder, most- to least-aggressive: the preferred
    configuration, then fused->composed, bf16-refined->f32, kernel
    backend->ref, and finally a bounded escalation retry (fresh x0 +
    jacobi where the method and operator support it).  Rungs that would
    repeat the previous configuration are skipped.

    A GENERATOR on purpose: the happy path consumes only the primary
    rung, so the fallback rungs' construction cost (imports, backend
    resolution, diagonal probing) is paid only after a failure — the
    ladder's happy-path overhead budget is enforced by
    ``benchmarks.bench_solve.MAX_LADDER_OVERHEAD``."""
    yield {"label": "primary", "op": op, "op_lo": op_lo,
           "strategy": strategy, "precond": precond, "fresh_x0": False}
    if fallback in ("off", False, None):
        return
    from repro.core.operator import DeviceOperator

    if strategy == "fused":
        yield {"label": "fused->composed", "op": op, "op_lo": op_lo,
               "strategy": "composed", "precond": precond,
               "fresh_x0": False}
    if op_lo is not None:
        yield {"label": "bf16->f32", "op": op, "op_lo": None,
               "strategy": "composed", "precond": precond,
               "fresh_x0": False}
    esc_op = op
    if isinstance(op, DeviceOperator):
        from repro.kernels import ops as K
        if K.resolve_backend(op.backend) == "kernel":
            esc_op = DeviceOperator(op.dev, backend="ref")
            yield {"label": "kernel->ref", "op": esc_op,
                   "op_lo": None, "strategy": "composed",
                   "precond": precond, "fresh_x0": False}
    esc_precond = precond
    if (precond is None and method in ("cg", "bicgstab")
            and getattr(esc_op, "diagonal", None) is not None):
        esc_precond = "jacobi"
    yield {"label": "escalate:fresh-x0"
           + ("+jacobi" if esc_precond == "jacobi"
              and precond is None else ""),
           "op": esc_op, "op_lo": None, "strategy": "composed",
           "precond": esc_precond, "fresh_x0": True}


_FAILURE_STATUSES = ("breakdown", "diverged", "non_finite")


def _ladder_solve(op, op_lo, b, *, method, strategy, maxiter, tol, precond,
                  x0, fallback):
    """Walk the degradation ladder.  Each rung runs, is certified
    (:func:`_certify` — the true-residual arbiter), and is recorded;
    success returns immediately.  ``maxiter`` (status "maxiter") is an
    honest typed outcome, not a fault — it returns without escalating
    (except for refined rungs, whose round cap should escalate to the
    f32 rung, not mask it).  When every rung fails, ``fallback="auto"``
    surfaces a typed :class:`SolveFailure`; ``fallback="off"`` returns
    the single rung's typed result as-is."""
    fallback_on = fallback not in ("off", False, None)
    if fallback not in ("auto", True, "off", False, None):
        raise ValueError(f"fallback must be 'auto' or 'off'; got "
                         f"{fallback!r}")
    rungs = _build_rungs(op, op_lo, method=method, strategy=strategy,
                         precond=precond, fallback=fallback)
    ladder, res, warm = [], None, None
    for rung in rungs:
        rung_x0 = None if rung["fresh_x0"] else (x0 if warm is None else warm)
        try:
            rn_prev, restarts = float("inf"), 0
            iters_acc = None
            while True:
                if rung["op_lo"] is not None:
                    res = _refined_solve(rung["op"], rung["op_lo"], b,
                                         method=method,
                                         strategy=rung["strategy"],
                                         maxiter=maxiter, tol=tol,
                                         precond=rung["precond"], x0=rung_x0)
                else:
                    res = _one_solve(rung["op"], b, method=method,
                                     strategy=rung["strategy"],
                                     maxiter=maxiter, tol=tol,
                                     precond=rung["precond"], x0=rung_x0)
                res = _certify(res, rung["op"], b, tol)
                status = res.status    # forces the device sync in-try
                # a warm restart is a continuation of the same solve:
                # report the rung's cumulative iteration count, not the
                # (often single-digit) final polish segment's
                iters_acc = (res.iters if iters_acc is None
                             else iters_acc + res.iters)
                res.iters = iters_acc
                rn = res.diagnostics.get("true_residual")
                # Certification miss from recurrence drift: warm-restart
                # the SAME rung — re-seeding from x resets the recurrence
                # to the true residual (the composed analogue of
                # _fused_drive's restart) — while it still improves.
                if (res.diagnostics.get("demoted") and restarts < 2
                        and rn is not None and math.isfinite(rn)
                        and rn < rn_prev):
                    rung_x0, rn_prev, restarts = res.x, rn, restarts + 1
                    continue
                break
        except Exception as e:
            if not fallback_on:
                raise                  # single rung: surface the original
            ladder.append({"rung": rung["label"],
                           "error": f"{type(e).__name__}: {e}"})
            continue
        entry = {"rung": rung["label"], "status": status}
        if restarts:
            entry["restarts"] = restarts
        if rn is not None:
            entry["true_residual"] = rn
        ladder.append(entry)
        if status == "converged":
            break
        if status == "maxiter" and rung["op_lo"] is None:
            break                      # honest out-of-budget — not a fault
        if not fallback_on:
            break
        # warm-start the next rung from any finite partial progress
        if rn is not None and math.isfinite(rn) and rn < 1.0:
            warm = res.x
    else:
        last = ladder[-1] if ladder else {}
        raise SolveFailure(
            f"solve({method}) failed on every ladder rung "
            f"(last: {last}); see .ladder / .result for diagnostics",
            result=res, ladder=ladder)
    return res, ladder
