"""Persistent JSON tuning cache.

One file, human-readable, atomic-replace on every write.  The key
anatomy (DESIGN.md §9) is

    <structural fingerprint> / <device kind> / <dtype policy> [/ fmt=...]

* **structural fingerprint** — ``formats.structural_fingerprint``: sha1
  of shape + indptr + indices, values excluded.  Re-assembling
  coefficients on a fixed sparsity pattern keeps the hit; any
  structural change invalidates it.
* **device kind** — ``measure.device_kind()``: measurements do not
  transfer between chips.
* **dtype policy** — the caller's storage precision contract
  (:func:`dtype_policy`); an f32 build and a bf16+int16 build tune
  separately.
* an optional trailing segment narrows the entry further (a format
  restriction, a partition geometry, ...).

The cache file location is ``$REPRO_TUNE_CACHE`` when set, else
``~/.cache/repro-spmv/tune_cache.json``.  A corrupt or
schema-mismatched file is treated as empty, never an error — losing a
tuning cache costs a re-measurement, not correctness.

Individual RECORDS are versioned too: ``put`` stamps each with
``"schema": RECORD_SCHEMA`` and ``get`` QUARANTINES (returns a miss
for, without crashing or deleting) records whose stamp is unknown or
which lack the caller's ``require``d keys — a cache written by a newer
version, or hand-edited into garbage, degrades to a re-measurement
instead of a KeyError deep in the autotuner.  Quarantined keys are
listed in ``cache.quarantined`` for inspection.
"""
from __future__ import annotations

import json
import os
import pathlib
import tempfile
from typing import Optional

import numpy as np

__all__ = [
    "SCHEMA_VERSION",
    "RECORD_SCHEMA",
    "TuneCache",
    "default_cache",
    "cache_key",
    "dtype_policy",
]

SCHEMA_VERSION = 1
RECORD_SCHEMA = 1
_ENV_VAR = "REPRO_TUNE_CACHE"


def _default_path() -> pathlib.Path:
    env = os.environ.get(_ENV_VAR)
    if env:
        return pathlib.Path(env)
    return pathlib.Path.home() / ".cache" / "repro-spmv" / "tune_cache.json"


def dtype_policy(dtype, index_dtype) -> str:
    """Canonical string for the (value dtype, index dtype) storage
    contract, e.g. ``"native+auto"`` (default build) or
    ``"bfloat16+int16"``."""
    v = "native" if dtype is None else np.dtype(dtype).name
    i = "auto" if index_dtype == "auto" else np.dtype(index_dtype).name
    return f"{v}+{i}"


def cache_key(fingerprint: str, device: str, policy: str,
              extra: str = "") -> str:
    key = f"{fingerprint}/{device}/{policy}"
    return f"{key}/{extra}" if extra else key


class TuneCache:
    """Lazy-loading JSON key-value store for tuning decisions.

    ``get``/``put`` operate on plain JSON-serialisable dicts; ``put``
    persists immediately via write-to-temp + ``os.replace`` so a
    crashed process never leaves a truncated cache behind."""

    def __init__(self, path: Optional[os.PathLike] = None):
        self.path = pathlib.Path(path) if path is not None \
            else _default_path()
        self._entries: Optional[dict] = None
        self.quarantined: dict = {}    # key -> reason, see module doc

    def _load(self) -> dict:
        if self._entries is None:
            self._entries = {}
            try:
                payload = json.loads(self.path.read_text())
                if payload.get("schema") == SCHEMA_VERSION:
                    self._entries = dict(payload.get("entries", {}))
            except (OSError, ValueError):
                pass
        return self._entries

    def get(self, key: str, require: tuple = ()) -> Optional[dict]:
        """Look ``key`` up; a malformed record — not a dict, an unknown
        ``schema`` stamp, or missing any of the ``require``d keys — is
        QUARANTINED: reported as a miss (the caller re-measures and
        overwrites it) but neither crashed on nor silently reused."""
        rec = self._load().get(key)
        if rec is None:
            return None
        reason = None
        if not isinstance(rec, dict):
            reason = f"record is {type(rec).__name__}, not a dict"
        elif rec.get("schema") != RECORD_SCHEMA:
            reason = f"unknown record schema {rec.get('schema')!r}"
        else:
            missing = [k for k in require if k not in rec]
            if missing:
                reason = f"missing keys {missing}"
        if reason is not None:
            self.quarantined[key] = reason
            return None
        return rec

    def put(self, key: str, record: dict) -> None:
        entries = self._load()
        entries[key] = {**record, "schema": RECORD_SCHEMA}
        self.quarantined.pop(key, None)
        self._flush()

    def clear(self) -> None:
        self._entries = {}
        self._flush()

    def __len__(self) -> int:
        return len(self._load())

    def _flush(self) -> None:
        payload = {"schema": SCHEMA_VERSION, "entries": self._entries}
        self.path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=str(self.path.parent),
                                   prefix=self.path.name, suffix=".tmp")
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=1, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise


_DEFAULT: Optional[TuneCache] = None


def default_cache() -> TuneCache:
    """The process-wide cache at the default path (the instance is
    shared so repeated ``tune="auto"`` calls load the file once)."""
    global _DEFAULT
    if _DEFAULT is None or _DEFAULT.path != _default_path():
        _DEFAULT = TuneCache()
    return _DEFAULT
