"""The tuning drivers: enumerate -> prune -> measure -> cache.

:func:`autotune` is the single-device driver behind
``ops.as_device(..., tune=...)`` / ``spmv(..., tune=...)`` /
``operator(..., tune=...)``; :func:`tune_partition` is the distributed
driver behind ``dist_operator(..., tune=...)``, which chooses the
``chunk_l`` of the LOCAL and REMOTE operands independently — their
row-length statistics differ structurally (the remote part holds only
the halo coupling, typically far sparser rows), so one shared tile
height wastes padding on one of them.

Both drivers go through the persistent :class:`cache.TuneCache`; a hit
returns the stored decision without building or measuring anything.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core import perf_model as PM
from repro.kernels import ops
from . import cache as C
from . import measure as ME
from .space import (Candidate, enumerate_candidates, heuristic_candidate,
                    price_candidate, prune_candidates, solver_candidates)

__all__ = ["TuneResult", "TunePartition", "SolverTuneResult",
           "autotune", "tune_partition", "tune_solver"]

_DEFAULT_TOP_K = 6


@dataclasses.dataclass
class TuneResult:
    """Outcome of one :func:`autotune` call.  ``rows`` carries one dict
    per measured candidate (statics + uncalibrated ``model_s`` +
    ``measured_s``) — the input ``calibrate.fit_calibration`` wants —
    and ``cached`` says whether measurement was skipped entirely."""

    best: Candidate
    rows: list
    cached: bool
    key: str

    @property
    def heuristic_row(self) -> Optional[dict]:
        for r in self.rows:
            if r.get("heuristic"):
                return r
        return None


def autotune(
    m: F.CSRMatrix,
    *,
    format: str = "auto",
    dtype=None,
    index_dtype="auto",
    top_k: int = _DEFAULT_TOP_K,
    warmup: int = 1,
    iters: int = 5,
    cache: Optional[C.TuneCache] = None,
    force: bool = False,
    measure_fn: Optional[Callable] = None,
    spec: PM.TPUSpec = PM.TPU_V5E,
) -> TuneResult:
    """Pick measured-best kernel statics for ``m`` under the given
    format restriction and dtype policy.

    Cache semantics: the key is (structural fingerprint, device kind,
    dtype policy, format restriction).  ``force=False`` returns a hit
    verbatim — zero builds, zero measurements; ``force=True``
    re-measures and overwrites.  ``measure_fn`` (same signature as
    ``measure.measure_candidate``) exists for tests and custom
    harnesses.

    A winner other than the heuristic default is CONFIRMED by a
    drift-robust paired comparison (``measure.ab_compare``) before it
    is cached; if it cannot beat the heuristic head-to-head the
    heuristic is kept — so a cached tuned decision is never a one-sided
    timing artifact.  (Skipped under an injected ``measure_fn``: custom
    harnesses own their noise model.)"""
    if cache is None:
        cache = C.default_cache()
    key = C.cache_key(F.structural_fingerprint(m), ME.device_kind(),
                      C.dtype_policy(dtype, index_dtype),
                      extra=f"fmt={format}" if format != "auto" else "")
    if not force:
        hit = cache.get(key, require=("best",))
        if hit is not None:
            try:
                return TuneResult(best=Candidate.from_dict(hit["best"]),
                                  rows=list(hit.get("rows", [])),
                                  cached=True, key=key)
            except (AttributeError, KeyError, TypeError, ValueError):
                cache.quarantined[key] = "malformed 'best' candidate"


    heur = heuristic_candidate(m, format, dtype, index_dtype)
    cands = prune_candidates(
        m, enumerate_candidates(m, format, dtype, index_dtype),
        top_k=top_k, dtype=dtype, index_dtype=index_dtype, spec=spec,
        heuristic=heur)
    confirm = measure_fn is None
    if measure_fn is None:
        measure_fn = ME.measure_candidate
    rows = []
    for c in cands:
        t = measure_fn(m, c, dtype=dtype, index_dtype=index_dtype,
                       warmup=warmup, iters=iters)
        rows.append({
            **c.as_dict(),
            "label": c.label(),
            "heuristic": c == heur,
            "model_s": price_candidate(m, c, dtype=dtype,
                                       index_dtype=index_dtype, spec=spec,
                                       calibration=None),
            "measured_s": float(t),
        })
    best = cands[int(np.argmin([r["measured_s"] for r in rows]))]
    if confirm and best != heur:
        t_h, t_b = ME.ab_compare(m, heur, best, dtype=dtype,
                                 index_dtype=index_dtype,
                                 rounds=5, iters=max(iters // 2, 2),
                                 warmup=warmup)
        if t_b >= t_h:
            best = heur
    cache.put(key, {"best": best.as_dict(), "rows": rows})
    return TuneResult(best=best, rows=rows, cached=False, key=key)


# --------------------------------------------------------------------------
# Solver-level tuning
# --------------------------------------------------------------------------
@dataclasses.dataclass
class SolverTuneResult:
    """Outcome of one :func:`tune_solver` call: the iteration STRATEGY
    (``"fused"`` / ``"composed"``) and the layout to build it on, plus
    one row per measured (strategy, layout) probe."""

    strategy: str
    layout: Candidate
    rows: list
    cached: bool
    key: str


def tune_solver(
    m: F.CSRMatrix,
    *,
    method: str = "cg",
    dtype=None,
    index_dtype="auto",
    probe_iters: int = 20,
    warmup: int = 1,
    iters: int = 3,
    cache: Optional[C.TuneCache] = None,
    force: bool = False,
    measure_fn: Optional[Callable] = None,
) -> SolverTuneResult:
    """Pick the measured-best (strategy, layout) for running ``method``
    on ``m`` — the config that wins per solver ITERATION, not per
    matvec: the fused spMV+dots pass amortizes differently than a bare
    matvec (no separate reduction passes, but an extra weight-slab read
    per window), so the per-matvec winner is not automatically the
    per-iteration winner.

    Same cache discipline as :func:`autotune` (persistent, keyed on the
    structural fingerprint + device kind + dtype policy, with the
    method as the ``extra`` component so cg and bicgstab tune
    independently); ``measure_fn`` (signature of
    ``measure.measure_solver_candidate``) exists for tests."""
    if cache is None:
        cache = C.default_cache()
    key = C.cache_key(F.structural_fingerprint(m), ME.device_kind(),
                      C.dtype_policy(dtype, index_dtype),
                      extra=f"solver:method={method}")
    if not force:
        hit = cache.get(key, require=("strategy", "layout"))
        if hit is not None:
            try:
                return SolverTuneResult(
                    strategy=str(hit["strategy"]),
                    layout=Candidate.from_dict(hit["layout"]),
                    rows=list(hit.get("rows", [])), cached=True, key=key)
            except (AttributeError, KeyError, TypeError, ValueError):
                cache.quarantined[key] = "malformed 'layout' candidate"


    if measure_fn is None:
        measure_fn = ME.measure_solver_candidate
    cands = solver_candidates(m, method=method, dtype=dtype,
                              index_dtype=index_dtype)
    rows = []
    for strategy, c in cands:
        t = measure_fn(m, strategy, c, method=method, dtype=dtype,
                       index_dtype=index_dtype, probe_iters=probe_iters,
                       warmup=warmup, iters=iters)
        rows.append({"strategy": strategy, "layout": c.as_dict(),
                     "label": f"{strategy}: {c.label()}",
                     "seconds_per_iter": float(t)})
    best = rows[int(np.argmin([r["seconds_per_iter"] for r in rows]))]
    cache.put(key, {"strategy": best["strategy"], "layout": best["layout"],
                    "rows": rows})
    return SolverTuneResult(strategy=best["strategy"],
                            layout=Candidate.from_dict(best["layout"]),
                            rows=rows, cached=False, key=key)


# --------------------------------------------------------------------------
# Distributed-partition tuning
# --------------------------------------------------------------------------
@dataclasses.dataclass
class TunePartition:
    """Independently chosen tile heights for a row partition's local
    (block-diagonal) and remote (halo-coupling) operands, plus — when
    the sweep ran over a mesh — the measured-best communication config
    (``halo`` flavour, execution ``mode``, 2-D ``grid`` shape; ``None``
    each when no mesh was given, ``grid=None`` also meaning the 1-D
    ``(n_dev, 1)`` winner)."""

    chunk_l: int
    rem_chunk_l: int
    rows: list
    cached: bool
    key: str
    halo: Optional[str] = None
    mode: Optional[str] = None
    grid: Optional[tuple] = None


def _measure_operand(sub: F.CSRMatrix, perm: np.ndarray, b_r: int,
                     diag_align: int, chunk_l: int, index_dtype,
                     warmup: int, iters: int) -> float:
    """Median seconds of one spMVM over a single device's operand built
    the exact way ``partition_csr`` builds it (shared windowed perm,
    then pJDS blocking at this chunk_l)."""
    pj = F._pjds_with_perm(sub, perm, b_r, max(diag_align, chunk_l),
                           False, index_dtype)
    dev = ops.to_device_pjds(pj, chunk_l=chunk_l)
    backend = ME.measurement_backend()
    rng = np.random.default_rng(ME.MEASURE_SEED)
    x = jnp.asarray(rng.standard_normal(sub.shape[1]).astype(np.float32))
    f = jax.jit(lambda v: ops.pjds_matvec(dev, v, backend=backend))
    return ME.median_seconds(f, x, warmup=warmup, iters=iters)


def tune_partition(
    m: F.CSRMatrix,
    n_dev: int,
    *,
    b_r: int = 128,
    diag_align: int = 8,
    sigma: Optional[int] = None,
    index_dtype="auto",
    chunk_l_options: Sequence[int] = (8, 16, 32),
    warmup: int = 1,
    iters: int = 3,
    cache: Optional[C.TuneCache] = None,
    force: bool = False,
    mesh=None,
    axis: str = "data",
    comm_candidates: Optional[Sequence[dict]] = None,
) -> TunePartition:
    """Measure the best ``chunk_l`` for the local and remote operands of
    an ``n_dev``-way row partition of ``m``, independently.

    The straggler device decides distributed step time, so measurement
    runs on the device whose operand stores the most (separately for
    local and remote — they need not be the same device), with the SAME
    shared total-row-length windowed sort ``partition_csr`` will use.
    The result feeds ``partition_csr(..., chunk_l=, rem_chunk_l=)``
    through ``core.operator.dist_operator(tune=...)``.

    With a ``mesh`` the tuner additionally sweeps the COMMUNICATION
    config — halo flavour x execution mode x 2-D grid shape
    (``space.dist_candidates``, or an explicit ``comm_candidates``
    list) — by timing one full sharded spMVM per candidate with the
    chunk winners baked in, and returns the measured-best triple in
    ``.halo`` / ``.mode`` / ``.grid``.  The sweep rows double as
    ``calibrate.fit_link_calibration`` input (each carries the
    candidate's ``msgs`` / ``bytes`` wire statistics), so one tuning
    pass also yields the calibrated gathered-vs-full crossover model.
    """
    from repro.core import dist_spmv as D   # deferred: dist_spmv imports ops
    from .space import dist_candidates as _dist_cands

    if cache is None:
        cache = C.default_cache()
    sweep = mesh is not None
    if sweep and comm_candidates is None:
        comm_candidates = _dist_cands(n_dev)
    comm_sig = ""
    if sweep:
        comm_sig = ":comm=" + ";".join(
            f"{c.get('grid')}/{c['halo']}/{c['mode']}/{c.get('halo_w')}"
            for c in comm_candidates)
    key = C.cache_key(
        F.structural_fingerprint(m), ME.device_kind(),
        C.dtype_policy(None, index_dtype),
        extra=(f"partition:n_dev={n_dev}:b_r={b_r}:sigma={sigma}"
               f":da={diag_align}"
               f":cl={','.join(map(str, chunk_l_options))}" + comm_sig))
    require = (("chunk_l", "rem_chunk_l", "halo", "mode")
               if sweep else ("chunk_l", "rem_chunk_l"))
    if not force:
        hit = cache.get(key, require=require)
        if hit is not None:
            try:
                return TunePartition(
                    chunk_l=int(hit["chunk_l"]),
                    rem_chunk_l=int(hit["rem_chunk_l"]),
                    rows=list(hit.get("rows", [])), cached=True, key=key,
                    halo=hit.get("halo"), mode=hit.get("mode"),
                    grid=(tuple(hit["grid"]) if hit.get("grid") else None))
            except (TypeError, ValueError):
                cache.quarantined[key] = "malformed chunk_l record"


    n_pad = D.padded_global_size(m.n_rows, n_dev, b_r)
    n_loc = n_pad // n_dev
    slices = [D._csr_row_slice(m, p * n_loc, (p + 1) * n_loc, n_loc)
              for p in range(n_dev)]
    needs = [F.csr_remote_columns_by_distance(sl, p, n_loc, n_dev)
             for p, sl in enumerate(slices)]
    halo_w = min(max((max((abs(d) for d in nd), default=0) for nd in needs),
                     default=0), n_dev // 2)
    sig = max(min(int(sigma) if sigma is not None else 8 * b_r, n_loc), 1)

    splits = [D._split_loc_rem(sl, p, n_loc, n_dev, halo_w)
              for p, sl in enumerate(slices)]
    perms = [F.windowed_sort_perm(loc.row_lengths() + rem.row_lengths(), sig)
             for loc, rem in splits]
    p_loc = int(np.argmax([loc.nnz for loc, _ in splits]))
    p_rem = int(np.argmax([rem.nnz for _, rem in splits]))

    rows, best = [], {}
    for which, p in (("loc", p_loc), ("rem", p_rem)):
        sub = splits[p][0 if which == "loc" else 1]
        for cl in chunk_l_options:
            t = _measure_operand(sub, perms[p], b_r, diag_align, cl,
                                 index_dtype, warmup, iters)
            rows.append(dict(operand=which, device=p, chunk_l=cl,
                             measured_s=float(t)))
            if t < best.get(which, (np.inf,))[0]:
                best[which] = (t, cl)
    chunk_l, rem_chunk_l = best["loc"][1], best["rem"][1]

    halo = mode = grid = None
    if sweep:
        comm_rows = []
        for cand in comm_candidates:
            r = ME.measure_dist_candidate(
                m, mesh, cand, axis=axis, b_r=b_r, diag_align=diag_align,
                chunk_l=chunk_l, rem_chunk_l=rem_chunk_l, sigma=sigma,
                index_dtype=index_dtype, warmup=warmup, iters=iters)
            r["operand"] = "comm"
            r["group"] = F.structural_fingerprint(m)
            comm_rows.append(r)
        w = comm_rows[int(np.argmin([r["measured_s"] for r in comm_rows]))]
        halo, mode = w["halo"], w["mode"]
        grid = tuple(w["grid"]) if w.get("grid") else None
        rows += comm_rows

    cache.put(key, {"chunk_l": chunk_l, "rem_chunk_l": rem_chunk_l,
                    "halo": halo, "mode": mode,
                    "grid": list(grid) if grid else None, "rows": rows})
    return TunePartition(chunk_l=chunk_l, rem_chunk_l=rem_chunk_l,
                         rows=rows, cached=False, key=key,
                         halo=halo, mode=mode, grid=grid)
