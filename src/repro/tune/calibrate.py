"""Calibration: fit the perf model's free terms from measured rows.

The structural byte model (``perf_model.spmvm_bytes``) is exact about
WHAT streams; what it guesses at is the rate (the data-sheet bandwidth
is an upper bound no kernel hits) and the per-launch cost each format
pays outside the streaming loop.  Both are fit here from measured rows

    { "fmt": ..., "model_s": <uncalibrated predicted seconds>,
      "measured_s": <median measured seconds> }

as the two-parameter-family ``measured ~ model_s / bw_scale +
overhead_s[fmt]`` by weighted least squares in RELATIVE error
(weights 1/measured, so a 10 us row and a 10 ms row count equally —
the tuner cares about ranking across sizes, not absolute microseconds).
The fit is coordinate descent (scale <-> per-format offsets, offsets
clamped >= 0), each step of which is an exact 1-D minimiser, so the
relative RMS error :func:`model_error` reports is monotonically
non-increasing — calibrating on a row set can only improve the model's
fit on it (the property ``tests/test_tune.py`` pins down and
``benchmarks/bench_tune.py`` guards on the BENCH_kernels roofline rows).

The fitted :class:`perf_model.Calibration` is installed process-wide
with ``perf_model.set_calibration``, after which every pricing call —
``select_format``, ``tune.space.price_candidate``, roofline reports —
tracks the machine that was measured instead of the data sheet.
"""
from __future__ import annotations

import json
import pathlib
from typing import Optional, Sequence

import numpy as np

from repro.core import perf_model as PM

__all__ = [
    "fit_calibration",
    "fit_link_calibration",
    "model_error",
    "link_model_error",
    "rows_from_bench_kernels",
    "fit_from_bench_kernels",
]

_FIT_SWEEPS = 3      # coordinate-descent passes (each pass is monotone)


def _predict(rows, calibration: Optional[PM.Calibration]) -> np.ndarray:
    model = np.asarray([r["model_s"] for r in rows], dtype=np.float64)
    if calibration is None:
        return model
    off = np.asarray([calibration.overhead_s.get(r["fmt"], 0.0)
                      for r in rows], dtype=np.float64)
    return model / calibration.bw_scale + off


def model_error(rows: Sequence[dict],
                calibration: Optional[PM.Calibration] = None) -> float:
    """Root-mean-square RELATIVE error of the (optionally calibrated)
    prediction against the measured rows — the quantity
    :func:`fit_calibration` minimises."""
    rows = list(rows)
    if not rows:
        raise ValueError("no rows")
    meas = np.asarray([r["measured_s"] for r in rows], dtype=np.float64)
    if np.any(meas <= 0):
        raise ValueError("measured_s must be positive")
    rel = (_predict(rows, calibration) - meas) / meas
    return float(np.sqrt(np.mean(rel ** 2)))


def fit_calibration(rows: Sequence[dict], source: str = "") -> PM.Calibration:
    """Fit ``(bw_scale, overhead_s)`` to measured rows (see the module
    docstring).  Raises on empty/degenerate input; a single row still
    fits (scale only)."""
    rows = list(rows)
    if not rows:
        raise ValueError("cannot calibrate from zero rows")
    t = np.asarray([r["measured_s"] for r in rows], dtype=np.float64)
    m = np.asarray([r["model_s"] for r in rows], dtype=np.float64)
    if np.any(t <= 0) or np.any(m <= 0):
        raise ValueError("model_s and measured_s must be positive")
    fmts = sorted({r["fmt"] for r in rows})
    fmt_of = np.asarray([fmts.index(r["fmt"]) for r in rows])
    w2 = 1.0 / t ** 2                       # relative-error weights

    # measured ~ a * model + c[fmt], a > 0, c >= 0.
    a = float(np.sum(w2 * t * m) / np.sum(w2 * m * m))
    c = np.zeros(len(fmts))
    for _ in range(_FIT_SWEEPS):
        resid = t - a * m
        for i in range(len(fmts)):
            sel = fmt_of == i
            c[i] = max(0.0, float(np.sum(w2[sel] * resid[sel])
                                  / np.sum(w2[sel])))
        a_new = float(np.sum(w2 * (t - c[fmt_of]) * m)
                      / np.sum(w2 * m * m))
        if a_new > 0:
            a = a_new
    return PM.Calibration(
        bw_scale=1.0 / a,
        overhead_s={f: float(ci) for f, ci in zip(fmts, c) if ci > 0.0},
        source=source,
    )


# --------------------------------------------------------------------------
# Link calibration (the distributed exchange's free terms)
# --------------------------------------------------------------------------
def _link_comm_s(rows, calibration, spec) -> np.ndarray:
    """Priced comm seconds of each row under ``calibration`` (None =
    data-sheet: pure bytes over the spec link bandwidth)."""
    return np.asarray([
        PM.t_link_gathered(
            float(r["bytes"]), spec.ici_bw, 1, 1, msgs=int(r["msgs"]),
            halo=r["halo"], calibration=calibration)
        for r in rows], dtype=np.float64)


def _best_bases(rows, comm_s: np.ndarray) -> np.ndarray:
    """Optimal per-group compute base given the comm model (exact 1-D
    weighted-relative-LSQ step, clamped >= 0)."""
    t = np.asarray([r["measured_s"] for r in rows], dtype=np.float64)
    groups = sorted({r["group"] for r in rows})
    g_of = np.asarray([groups.index(r["group"]) for r in rows])
    w2 = 1.0 / t ** 2
    resid = t - comm_s
    return np.asarray([
        max(0.0, float(np.sum(w2[g_of == gi] * resid[g_of == gi])
                       / np.sum(w2[g_of == gi])))
        for gi in range(len(groups))])[g_of]


def link_model_error(rows: Sequence[dict],
                     calibration: Optional[PM.Calibration] = None,
                     spec: PM.TPUSpec = PM.TPU_V5E) -> float:
    """RMS relative error of ``measured ~ base[group] + comm(calibration)``
    over link rows, with the per-group compute base chosen optimally for
    the given comm model — so the number isolates how well the COMM
    terms fit, which is what :func:`fit_link_calibration` minimises."""
    rows = list(rows)
    if not rows:
        raise ValueError("no rows")
    t = np.asarray([r["measured_s"] for r in rows], dtype=np.float64)
    if np.any(t <= 0):
        raise ValueError("measured_s must be positive")
    comm = _link_comm_s(rows, calibration, spec)
    rel = (_best_bases(rows, comm) + comm - t) / t
    return float(np.sqrt(np.mean(rel ** 2)))


def fit_link_calibration(rows: Sequence[dict],
                         spec: PM.TPUSpec = PM.TPU_V5E,
                         base: Optional[PM.Calibration] = None,
                         source: str = "") -> PM.Calibration:
    """Fit the LINK half of the calibration from measured distributed
    spMVM rows

        { "group": <matrix id>, "halo": "gathered" | "full",
          "msgs": <messages/device>, "bytes": <wire bytes/device>,
          "measured_s": <median wall seconds> }

    as ``measured ~ base[group] + msgs * c[halo] + bytes / bw_eff`` by
    weighted-relative-error coordinate descent (same discipline as
    :func:`fit_calibration`): ``base`` absorbs the compute time shared
    by both exchange flavours on one matrix, ``c[halo]`` is the
    per-MESSAGE fixed cost (gather/ppermute/scatter set-up — the term
    whose absence made the uncalibrated model prefer gathered exchanges
    that measure slower at toy scale), and ``bw_eff`` the effective
    link bandwidth.  All three are clamped to their physical signs.

    Returns a :class:`perf_model.Calibration` carrying the fitted
    ``link_bw_scale`` / ``msg_overhead_s`` on top of ``base`` (or the
    installed calibration, or data-sheet defaults), ready for
    ``perf_model.set_calibration`` —
    ``perf_model.choose_halo`` / ``dist_operator(halo="auto")`` then
    decide the gathered-vs-full crossover from measurements.
    """
    rows = list(rows)
    if not rows:
        raise ValueError("cannot calibrate from zero rows")
    t = np.asarray([r["measured_s"] for r in rows], dtype=np.float64)
    if np.any(t <= 0):
        raise ValueError("measured_s must be positive")
    msgs = np.asarray([r["msgs"] for r in rows], dtype=np.float64)
    byts = np.asarray([r["bytes"] for r in rows], dtype=np.float64)
    groups = sorted({r["group"] for r in rows})
    halos = sorted({r["halo"] for r in rows})
    g_of = np.asarray([groups.index(r["group"]) for r in rows])
    h_of = np.asarray([halos.index(r["halo"]) for r in rows])
    w2 = 1.0 / t ** 2

    bse = np.asarray([float(np.min(t[g_of == gi]))
                      for gi in range(len(groups))])
    c = np.zeros(len(halos))
    inv_bw = 0.0                        # seconds per wire byte
    for _ in range(16 * _FIT_SWEEPS):
        resid = t - msgs * c[h_of] - byts * inv_bw
        for gi in range(len(groups)):
            sel = g_of == gi
            bse[gi] = max(0.0, float(np.sum(w2[sel] * resid[sel])
                                     / np.sum(w2[sel])))
        resid = t - bse[g_of] - byts * inv_bw
        for hi in range(len(halos)):
            sel = h_of == hi
            den = float(np.sum(w2[sel] * msgs[sel] ** 2))
            c[hi] = (max(0.0, float(np.sum(w2[sel] * resid[sel] * msgs[sel]))
                         / den) if den > 0 else 0.0)
        resid = t - bse[g_of] - msgs * c[h_of]
        den = float(np.sum(w2 * byts ** 2))
        inv_bw = (max(0.0, float(np.sum(w2 * resid * byts)) / den)
                  if den > 0 else 0.0)

    link_scale = (1.0 / (inv_bw * spec.ici_bw)) if inv_bw > 0 else 1.0
    if base is None:
        base = PM.get_calibration()
    return PM.Calibration(
        bw_scale=base.bw_scale if base else 1.0,
        overhead_s=dict(base.overhead_s) if base else {},
        source=source or (base.source if base else ""),
        link_bw_scale=link_scale,
        msg_overhead_s={h: float(ci) for h, ci in zip(halos, c) if ci > 0.0},
    )


# --------------------------------------------------------------------------
# BENCH_kernels.json adapter (the committed roofline rows)
# --------------------------------------------------------------------------
def rows_from_bench_kernels(path) -> list[dict]:
    """Extract calibration rows from a ``BENCH_kernels.json`` produced
    by ``benchmarks/bench_kernels.py``: its ``bytes_per_nnz`` rows carry
    the uncalibrated prediction (``predicted_s``) next to the measured
    ref time (``measured_ref_s``) per format and storage variant."""
    payload = json.loads(pathlib.Path(path).read_text())
    out = []
    for r in payload.get("rows", []):
        if r.get("kind") != "bytes_per_nnz":
            continue
        if r.get("predicted_s", 0) > 0 and r.get("measured_ref_s", 0) > 0:
            out.append(dict(fmt=r["fmt"], model_s=float(r["predicted_s"]),
                            measured_s=float(r["measured_ref_s"])))
    return out


def fit_from_bench_kernels(path, source: Optional[str] = None
                           ) -> PM.Calibration:
    rows = rows_from_bench_kernels(path)
    if not rows:
        raise ValueError(f"no usable roofline rows in {path}")
    return fit_calibration(rows, source=source or f"bench_kernels:{path}")
