"""Empirical autotuner + perf-model calibration (DESIGN.md §9).

The paper picks formats with "a suitable performance model"; the
SELL-C-sigma follow-up (arXiv:1307.6209) shows the winning kernel
statics are hardware- AND matrix-dependent.  This package closes the
loop: enumerate the legal static space (``space``), prune it with the
model, MEASURE the survivors (``measure``), remember the decision in a
persistent cache keyed by structural fingerprint x device x dtype
policy (``cache``), and feed the measured rows back into the model as
an effective-bandwidth + per-format-overhead calibration
(``calibrate`` -> ``core.perf_model.set_calibration``).

Entry points most callers want are one level up —
``ops.spmv(a, x, tune="auto")`` / ``operator(a, tune="auto")`` /
``dist_operator(m, mesh, tune="auto")`` — which route here.
"""
from .space import (Candidate, enumerate_candidates, heuristic_candidate,
                    price_candidate, prune_candidates, solver_candidates,
                    dist_candidates)
from .measure import (measure_candidate, measure_solver_candidate,
                      measure_dist_candidate, prepare_candidate, ab_compare,
                      median_seconds, device_kind, measurement_backend)
from .cache import (TuneCache, default_cache, cache_key,
                    dtype_policy, RECORD_SCHEMA)
from .calibrate import (fit_calibration, model_error,
                        fit_link_calibration, link_model_error,
                        rows_from_bench_kernels, fit_from_bench_kernels)
from .autotune import (TuneResult, TunePartition, SolverTuneResult,
                       autotune, tune_partition, tune_solver)

__all__ = [
    "Candidate",
    "enumerate_candidates",
    "heuristic_candidate",
    "price_candidate",
    "prune_candidates",
    "measure_candidate",
    "prepare_candidate",
    "ab_compare",
    "median_seconds",
    "device_kind",
    "measurement_backend",
    "TuneCache",
    "RECORD_SCHEMA",
    "default_cache",
    "cache_key",
    "dtype_policy",
    "fit_calibration",
    "model_error",
    "fit_link_calibration",
    "link_model_error",
    "rows_from_bench_kernels",
    "fit_from_bench_kernels",
    "solver_candidates",
    "dist_candidates",
    "measure_solver_candidate",
    "measure_dist_candidate",
    "TuneResult",
    "TunePartition",
    "SolverTuneResult",
    "autotune",
    "tune_partition",
    "tune_solver",
]
