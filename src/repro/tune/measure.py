"""Measurement harness: warmup + median-of-n timing of a candidate build.

The timing discipline matches ``benchmarks/common.time_fn``: jit once,
run ``warmup`` calls to flush compilation and device caches, then take
the MEDIAN of ``iters`` blocked wall-clock samples (the median is robust
to the one-off scheduler hiccups that would otherwise make two tuner
runs disagree).

Off-TPU the Pallas kernels only execute in interpret mode — Python per
grid step — whose wall-time says nothing about the compiled kernel, so
the harness falls back to timing the jitted REF path instead
(``kernels.ops.resolve_backend("auto")`` makes the same call).  The
layout statics still matter there: padding, storage volume and the
permutation epilogue all show up in the ref's runtime, which is exactly
the structural signal the off-TPU tuner can act on.  On TPU the
compiled kernels themselves are timed.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.kernels import ops
from .space import Candidate

__all__ = [
    "median_seconds",
    "measurement_backend",
    "device_kind",
    "prepare_candidate",
    "measure_candidate",
    "measure_solver_candidate",
    "measure_dist_candidate",
    "ab_compare",
]

MEASURE_SEED = 0       # deterministic RHS for every measurement


def median_seconds(fn, *args, warmup: int = 1, iters: int = 5) -> float:
    """Median blocked wall-clock seconds per call."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def measurement_backend() -> str:
    """``"kernel"`` on TPU (compiled Pallas), ``"ref"`` elsewhere — see
    the module docstring for why interpret mode is never timed."""
    return ops.resolve_backend("auto")


def device_kind() -> str:
    """Cache-key component identifying the hardware the measurement ran
    on: platform plus the concrete device kind (tuned statics do not
    transfer between chips — that is the point of measuring)."""
    d = jax.devices()[0]
    return f"{jax.default_backend()}:{getattr(d, 'device_kind', 'unknown')}"


def prepare_candidate(
    m: F.CSRMatrix,
    c: Candidate,
    *,
    dtype=None,
    index_dtype="auto",
):
    """Build candidate ``c``'s device representation and return a
    nullary callable running one dispatched spMVM on the deterministic
    RHS (jitted once; conversion is NOT timed — it amortises over the
    operator's lifetime and the conversion cache)."""
    sd = ops.as_device(m, dtype=dtype, index_dtype=index_dtype,
                       **c.build_kwargs())
    backend = measurement_backend()
    rng = np.random.default_rng(MEASURE_SEED)
    x = jnp.asarray(rng.standard_normal(m.shape[1]).astype(np.float32))
    f = jax.jit(lambda v: sd.matvec(v, backend=backend))
    return lambda: f(x)


def measure_candidate(
    m: F.CSRMatrix,
    c: Candidate,
    *,
    dtype=None,
    index_dtype="auto",
    warmup: int = 1,
    iters: int = 5,
) -> float:
    """Median seconds of one dispatched spMVM through candidate ``c``'s
    device build."""
    return median_seconds(prepare_candidate(m, c, dtype=dtype,
                                            index_dtype=index_dtype),
                          warmup=warmup, iters=iters)


def measure_solver_candidate(
    m: F.CSRMatrix,
    strategy: str,
    c: Candidate,
    *,
    method: str = "cg",
    dtype=None,
    index_dtype="auto",
    probe_iters: int = 20,
    warmup: int = 1,
    iters: int = 3,
) -> float:
    """Median seconds PER SOLVER ITERATION of ``(strategy, c)``: a
    fixed-length probe solve (``maxiter=probe_iters, tol=0`` — no early
    exit, so every probe runs the same iteration count) divided by
    ``probe_iters``.  This times what :func:`median_seconds` over a bare
    matvec cannot: the fused epilogue's dot reductions vs the composed
    body's separate passes, under the method's real carrier traffic.
    Returns ``inf`` when the strategy cannot run this layout (fused
    needs a resident-x SELL build)."""
    from repro import api                     # deferred: api imports tune
    from repro.core.operator import operator

    op = operator(m, dtype=dtype, index_dtype=index_dtype,
                  **c.build_kwargs())
    rng = np.random.default_rng(MEASURE_SEED)
    b = jnp.asarray(rng.standard_normal(m.shape[0]).astype(np.float32))
    if strategy == "fused" and not api._fused_eligible(op, method, None, b):
        return float("inf")

    def probe():
        r = api._one_solve(op, b, method=method, strategy=strategy,
                           maxiter=probe_iters, tol=0.0, precond=None)
        return r.x

    return median_seconds(probe, warmup=warmup, iters=iters) / probe_iters


def measure_dist_candidate(
    m: F.CSRMatrix,
    mesh,
    cand: dict,
    *,
    axis: str = "data",
    b_r: int = 128,
    diag_align: int = 8,
    chunk_l: int = 8,
    rem_chunk_l=None,
    sigma=None,
    index_dtype="auto",
    warmup: int = 1,
    iters: int = 3,
) -> dict:
    """Partition ``m`` per distributed candidate ``cand`` (a
    ``space.dist_candidates`` dict: grid / halo / mode / halo_w) and
    time one sharded spMVM over ``mesh`` end to end — exchange,
    kernels, reduction epilogue, everything ``dist_matvec`` runs.

    Returns a row dict carrying the measured median next to the
    partition's wire statistics (``msgs`` / ``bytes`` per device), in
    exactly the shape ``calibrate.fit_link_calibration`` consumes —
    the sweep that picks a winner also feeds the calibrated crossover
    model for free.
    """
    from repro.core import dist_spmv as D     # deferred: imports ops

    n_dev = mesh.shape[axis]
    dist = D.partition_csr(
        m, n_dev, b_r=b_r, diag_align=diag_align, chunk_l=chunk_l,
        halo_w=cand.get("halo_w"), sigma=sigma, index_dtype=index_dtype,
        rem_chunk_l=rem_chunk_l, grid=cand.get("grid"),
        build_stages=(cand["mode"] == "pipeline"))
    fn = jax.jit(D._make_dist_op(dist, mesh, axis, cand["mode"], "auto",
                                 cand["halo"], multi_rhs=False))
    rng = np.random.default_rng(MEASURE_SEED)
    x = jnp.asarray(rng.standard_normal(dist.n_global_pad)
                    .astype(np.float32))
    t = median_seconds(fn, x, warmup=warmup, iters=iters)
    vb = dist.loc_val.dtype.itemsize
    return dict(
        grid=cand.get("grid"), halo=cand["halo"], mode=cand["mode"],
        halo_w=int(dist.halo_w), red_w=int(dist.red_w),
        msgs=int(dist.comm_msgs_per_device(halo=cand["halo"])),
        bytes=int(dist.comm_bytes_per_device(value_bytes=vb,
                                             halo=cand["halo"])),
        measured_s=float(t))


def ab_compare(
    m: F.CSRMatrix,
    a: Candidate,
    b: Candidate,
    *,
    dtype=None,
    index_dtype="auto",
    rounds: int = 7,
    iters: int = 3,
    warmup: int = 2,
) -> tuple[float, float]:
    """Drift-robust paired timing of two candidates: alternate the two
    builds round by round (order flipped every round) and keep each
    side's MINIMUM round median.  One-sided timing is poisoned by slow
    drift — background load, thermal/frequency state — that lands
    entirely on whichever side ran later; interleaving puts both sides
    under the same drift and the min discards the inflated rounds.
    Used for the guarded tuned-vs-heuristic comparison in
    ``benchmarks/bench_tune.py``."""
    fa = prepare_candidate(m, a, dtype=dtype, index_dtype=index_dtype)
    fb = prepare_candidate(m, b, dtype=dtype, index_dtype=index_dtype)
    for f in (fa, fb):
        for _ in range(warmup):
            jax.block_until_ready(f())
    ta, tb = np.inf, np.inf
    for r in range(rounds):
        order = ((0, fa), (1, fb)) if r % 2 == 0 else ((1, fb), (0, fa))
        for side, f in order:
            t = median_seconds(f, warmup=0, iters=iters)
            if side == 0:
                ta = min(ta, t)
            else:
                tb = min(tb, t)
    return float(ta), float(tb)
