"""Search-space enumeration + model-based pruning for the autotuner.

The kernel-static space after the PR-4 bandwidth overhaul is
``format x b_r x chunk_l x sigma x x_tiles`` (times the dtype policy,
which is an INPUT here, not a search axis: the caller's storage
precision is a contract, the tuner only picks layout statics for it).
Measuring the full cross product would take seconds per matrix, so the
space is pruned with the same ``perf_model`` pricing the static
dispatch heuristic uses — candidates whose predicted memory-bound time
is hopeless never get measured — with one guarantee the tuner's
correctness story rests on: :func:`prune_candidates` NEVER drops the
heuristic default (``kernels.ops.as_device``'s no-tuning build), so the
measured winner can only tie or beat what dispatch would have picked.

All legality constraints live in one place (:func:`enumerate_candidates`)
and mirror the converters': ``diag_align`` is raised to ``chunk_l``
exactly as ``as_device`` does, ``sigma`` is a SELL-only axis capped at
the padded row count (where it degenerates to the pJDS global sort),
and ``x_tiles > 1`` is offered only to the formats whose kernels can
column-block the RHS (sell/pjds — same restriction as
``select_format``).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core import formats as F
from repro.core import perf_model as PM
from repro.kernels import ops

__all__ = [
    "Candidate",
    "heuristic_candidate",
    "enumerate_candidates",
    "price_candidate",
    "prune_candidates",
    "solver_candidates",
    "dist_candidates",
]

# Default search axes.  Deliberately small: the point of the model-based
# prune is that ENUMERATION can stay generous while MEASUREMENT stays
# top-k; these are the values the converters are known to like on the
# (8, 128) register tile (DESIGN.md §2).
B_R_OPTIONS = (32, 64, 128)
CHUNK_L_OPTIONS = (8, 16, 32)
SIGMA_FACTORS = (1, 4, 8, 32)      # sigma = factor * b_r, capped at n_pad

_DEFAULT_B_R = 128                 # as_device defaults — the heuristic build
_DEFAULT_CHUNK_L = 16
_DEFAULT_DIAG_ALIGN = 8


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One point of the kernel-static search space: everything
    ``kernels.ops.as_device`` needs beyond the matrix and the dtype
    policy.  ``sigma`` is meaningful for sell only (None elsewhere);
    hashable/frozen so candidate sets dedupe, JSON-roundtrippable so
    the persistent cache can store the winning point."""

    fmt: str
    b_r: int = _DEFAULT_B_R
    chunk_l: int = _DEFAULT_CHUNK_L
    sigma: Optional[int] = None
    x_tiles: int = 1

    def build_kwargs(self) -> dict:
        """Keyword arguments for ``ops.as_device`` (minus the dtype
        policy, which the caller owns)."""
        return dict(
            format=self.fmt,
            b_r=self.b_r,
            diag_align=max(_DEFAULT_DIAG_ALIGN, self.chunk_l),
            sigma=self.sigma,
            chunk_l=self.chunk_l,
            x_tiles=self.x_tiles,
        )

    def as_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict) -> "Candidate":
        names = {f.name for f in dataclasses.fields(cls)}
        return cls(**{k: v for k, v in d.items() if k in names})

    def label(self) -> str:
        sig = f" sigma={self.sigma}" if self.sigma is not None else ""
        xt = f" x_tiles={self.x_tiles}" if self.x_tiles != 1 else ""
        return f"{self.fmt} b_r={self.b_r} chunk_l={self.chunk_l}{sig}{xt}"


def _auto_x_tiles(m: F.CSRMatrix) -> int:
    # Same rule as as_device: the tile is sized by the RUNTIME vector
    # width (>= f32), whatever the stored value width.
    return ops.choose_x_tiles(m.shape[1], max(4, m.data.dtype.itemsize))


def heuristic_candidate(
    m: F.CSRMatrix,
    format: str = "auto",
    dtype=None,
    index_dtype="auto",
) -> Candidate:
    """The exact build ``as_device`` produces with default statics and
    ``tune="off"`` — the baseline every tuned decision is benchmarked
    against, and the candidate :func:`prune_candidates` may never drop."""
    auto_t = _auto_x_tiles(m)
    da = max(_DEFAULT_DIAG_ALIGN, _DEFAULT_CHUNK_L)
    fmt = format
    if fmt == "auto":
        fmt = ops.select_format(m, b_r=_DEFAULT_B_R, diag_align=da,
                                sigma=None, value_dtype=dtype,
                                index_dtype=index_dtype, x_tiles=auto_t)
    sigma = None
    if fmt == "sell":
        sigma = min(8 * _DEFAULT_B_R,
                    _pad_to(max(m.n_rows, 1), _DEFAULT_B_R))
    return Candidate(
        fmt=fmt,
        b_r=_DEFAULT_B_R,
        chunk_l=_DEFAULT_CHUNK_L,
        sigma=sigma,
        x_tiles=auto_t if fmt in ("sell", "pjds") else 1,
    )


def _pad_to(x: int, mult: int) -> int:
    return ((x + mult - 1) // mult) * mult


def enumerate_candidates(
    m: F.CSRMatrix,
    format: str = "auto",
    dtype=None,
    index_dtype="auto",
    b_r_options: Sequence[int] = B_R_OPTIONS,
    chunk_l_options: Sequence[int] = CHUNK_L_OPTIONS,
    sigma_factors: Sequence[int] = SIGMA_FACTORS,
) -> list[Candidate]:
    """All legal kernel-static points for ``m`` under the given format
    restriction (``format != "auto"`` collapses the format axis).  The
    heuristic default is always a member.  Degenerate matrices (empty,
    or too few rows to fill one block at the smallest b_r) collapse to
    the CSR baseline."""
    heur = heuristic_candidate(m, format, dtype, index_dtype)
    n = m.n_rows
    if m.nnz == 0 or n < ops._CSR_MIN_ROWS_FACTOR * min(b_r_options):
        return list(dict.fromkeys([Candidate(fmt="csr"), heur]))

    fmts = (["csr", "ellpack_r", "pjds", "sell", "cmrs"] if format == "auto"
            else [format])
    auto_t = _auto_x_tiles(m)
    out = [heur]
    for fmt in fmts:
        if fmt == "csr":
            out.append(Candidate(fmt="csr"))
            continue
        # x cannot be VMEM-resident -> only the column-blocking kernels
        # may run (mirrors select_format's restriction); when it CAN be
        # resident, offering the tiled grid would only add re-read
        # traffic, so the resident build is the sole option.
        if fmt in ("sell", "pjds", "cmrs"):
            tile_opts = sorted({auto_t} | ({1} if auto_t == 1 else
                                           {auto_t, 2 * auto_t}))
        else:
            if auto_t > 1:
                continue
            tile_opts = [1]
        for b_r in b_r_options:
            if n < ops._CSR_MIN_ROWS_FACTOR * b_r:
                continue       # block padding dominates; csr covers this
            sigmas = [None]
            if fmt == "sell":
                n_pad = _pad_to(n, b_r)
                sigmas = sorted({min(f * b_r, n_pad)
                                 for f in sigma_factors})
            for chunk_l in chunk_l_options:
                for sigma in sigmas:
                    for xt in tile_opts:
                        out.append(Candidate(fmt=fmt, b_r=b_r,
                                             chunk_l=chunk_l, sigma=sigma,
                                             x_tiles=xt))
    return list(dict.fromkeys(out))


def solver_candidates(
    m: F.CSRMatrix,
    *,
    method: str = "cg",
    dtype=None,
    index_dtype="auto",
) -> list[tuple[str, Candidate]]:
    """The SOLVER-level probe set: (strategy, layout) pairs for
    ``tune_solver``, where strategy is ``"fused"`` (the fused
    spMV+dots iteration — needs a resident-x SELL build, so those
    candidates pin ``x_tiles=1``) or ``"composed"`` (separate
    matvec + reduction HLOs over whatever layout wins per matvec).

    Deliberately tiny — a handful of probes, each a fixed-iteration
    solve, because the per-matvec tuner (:func:`enumerate_candidates` +
    prune) already explored the layout space; here only the decisions
    that CHANGE at the solver level are measured: fused vs composed,
    and the fused path's tile height (the epilogue's dot reductions
    shift the best chunk_l relative to a bare matvec).
    """
    h_sell = heuristic_candidate(m, "sell", dtype, index_dtype)
    h_sell = dataclasses.replace(h_sell, x_tiles=1)
    alt_cl = 8 if h_sell.chunk_l != 8 else 16
    h_auto = heuristic_candidate(m, "auto", dtype, index_dtype)
    out: list[tuple[str, Candidate]] = [
        ("fused", h_sell),
        ("fused", dataclasses.replace(h_sell, chunk_l=alt_cl)),
        ("composed", h_auto),
    ]
    if h_auto != h_sell:
        out.append(("composed", h_sell))
    return list(dict.fromkeys(out))


def dist_candidates(
    n_dev: int,
    *,
    halos: Sequence[str] = ("gathered", "full"),
    modes: Sequence[str] = ("vector", "overlap", "pipeline"),
    grids: Optional[Sequence] = None,
    halo_w_options: Sequence[Optional[int]] = (None,),
) -> list[dict]:
    """The DISTRIBUTED probe set: one dict per (grid, halo, mode,
    halo_w) combination for ``tune_partition``'s communication sweep.

    The grid axis defaults to the three structurally distinct shapes of
    a ``n_dev`` mesh — pure row partitioning ``(P, 1)``, pure column
    partitioning ``(1, P)`` and the most-square 2-D factorization plus
    its transpose — because intermediate rectangles interpolate between
    those extremes in both halo volume and reduction volume.  The mode
    axis skips ``"naive"`` (strictly dominated: same exchange as
    ``"vector"`` plus one dense unpermute) and prunes ``"pipeline"``
    for full halos — staging a full exchange ships the same bytes in
    more messages, so it can only win where gathered/pipeline already
    does.  ``halo_w=None`` means the measured coupling width — wider
    explicit windows only add structurally empty exchange slots, so the
    default sweeps none.
    """
    if grids is None:
        gs: list = [(n_dev, 1)]
        if n_dev > 1:
            gs.append((1, n_dev))
        sq = max(g for g in range(1, int(np.sqrt(n_dev)) + 1)
                 if n_dev % g == 0)
        if sq > 1:
            gs += [(sq, n_dev // sq), (n_dev // sq, sq)]
        grids = list(dict.fromkeys(gs))
    out = []
    for grid in grids:
        for halo in halos:
            for mode in modes:
                if mode == "naive" or (mode == "pipeline" and halo == "full"):
                    continue
                for hw in halo_w_options:
                    out.append(dict(grid=(None if grid in (None, (n_dev, 1))
                                          else tuple(grid)),
                                    halo=str(halo), mode=str(mode),
                                    halo_w=hw))
    return [dict(t) for t in dict.fromkeys(
        tuple(sorted(c.items(), key=lambda kv: kv[0])) for c in out)]


def price_candidate(
    m: F.CSRMatrix,
    c: Candidate,
    *,
    dtype=None,
    index_dtype="auto",
    spec: PM.TPUSpec = PM.TPU_V5E,
    calibration="default",
) -> float:
    """Predicted memory-bound spMVM seconds of candidate ``c`` on ``m``
    — the same ``perf_model`` pricing ``select_format`` uses, extended
    over the full static space.  ``calibration=None`` forces the
    uncalibrated data-sheet model (what the calibration fit needs as
    its regressor); the default picks up any installed calibration."""
    n, n_nzr = m.n_rows, m.n_nzr
    vecb = max(4, m.data.dtype.itemsize)
    if c.fmt == "csr":
        vb = m.data.dtype.itemsize if dtype is None else np.dtype(dtype).itemsize
        # CSRDevice streams indices AND row ids per nnz (8 index bytes).
        return PM.predicted_spmv_seconds(
            m.nnz, n, n_nzr, irregular_factor=ops._CSR_IRREGULAR_FACTOR,
            spec=spec, value_bytes=vb, index_bytes=8, vec_bytes=vecb,
            fmt="csr", calibration=calibration)
    rl = m.row_lengths()
    vb = np.dtype(dtype).itemsize if dtype is not None \
        else m.data.dtype.itemsize
    ib = F.resolve_index_dtype(index_dtype, m.shape[1]).itemsize
    da = max(_DEFAULT_DIAG_ALIGN, c.chunk_l)
    elems = F.estimate_storage_elements(rl, c.fmt, c.b_r, da, c.sigma)
    perm_bytes = 0.0
    if c.fmt in ("sell", "pjds"):
        perm_bytes = PM.perm_traffic_bytes(
            n, vecb, window_local=(c.fmt == "sell"))
    if c.fmt == "cmrs":
        # Same max(memory, compute) pricing as select_format: the int8
        # row_in_strip stream adds a byte per slot, and the one-hot
        # reduction matmul can bound the kernel instead of HBM.
        ib += PM.CMRS_RIS_BYTES
    t = PM.predicted_spmv_seconds(
        elems, n, n_nzr, perm_bytes=perm_bytes, spec=spec,
        value_bytes=vb, index_bytes=ib, vec_bytes=vecb,
        x_tiles=c.x_tiles, n_row_blocks=-(-n // c.b_r),
        fmt=c.fmt, calibration=calibration)
    if c.fmt == "cmrs":
        t = max(t, PM.cmrs_reduce_seconds(elems * c.x_tiles, c.b_r, spec))
    return t


def prune_candidates(
    m: F.CSRMatrix,
    candidates: Sequence[Candidate],
    *,
    top_k: int = 6,
    dtype=None,
    index_dtype="auto",
    spec: PM.TPUSpec = PM.TPU_V5E,
    heuristic: Optional[Candidate] = None,
) -> list[Candidate]:
    """Keep the ``top_k`` model-cheapest candidates, ALWAYS including
    the heuristic default (appended back if the model would drop it —
    the guarantee that tuning can never do worse than dispatch by more
    than measurement noise).  Ordered cheapest-predicted first."""
    if heuristic is None:
        heuristic = heuristic_candidate(m, dtype=dtype,
                                        index_dtype=index_dtype)
    priced = sorted(
        dict.fromkeys(candidates),
        key=lambda c: price_candidate(m, c, dtype=dtype,
                                      index_dtype=index_dtype, spec=spec))
    kept = priced[: max(top_k, 1)]
    if heuristic not in kept:
        kept.append(heuristic)
    return kept
