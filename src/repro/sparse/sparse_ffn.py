"""SparseFFN: pruned FFN weights stored in pJDS, applied with pjds_spmm.

The paper's storage format promoted to a first-class LM feature
(DESIGN.md §4): magnitude-prune a trained FFN to ``density``, convert the
surviving weights to pJDS, and run the forward pass as multi-RHS spMVM.

Memory story (the paper's Table-1 argument, on LM weights): an FFN with
density d stores ~d * (4+4)/2 bytes per original bf16 element (f32 value
+ int32 index, halved... see ``memory_summary``), so densities below ~1/6
shrink the footprint vs dense bf16 while pJDS (vs ELLPACK) keeps the
padding overhead <1% even though per-row non-zero counts after magnitude
pruning vary wildly — exactly the row-length-variance regime (Fig. 3)
pJDS was designed for.

This module is single-device (inference compression); the distributed
dry-run path uses dense FFN.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.kernels import ops


@dataclasses.dataclass
class SparseLinear:
    """y = x @ W with W^T stored in pJDS (rows = output features)."""

    a: ops.PJDSDevice
    perm: np.ndarray          # row sort of the OUTPUT features
    n_out: int
    n_in_pad: int
    density: float

    @staticmethod
    def from_dense(w: np.ndarray, density: float, b_r: int = 128,
                   chunk_l: int = 8) -> "SparseLinear":
        """Magnitude-prune ``w`` (in, out) to ``density`` and pack."""
        n_in, n_out = w.shape
        k = max(int(w.size * density), 1)
        thresh = np.partition(np.abs(w).ravel(), -k)[-k]
        wp = np.where(np.abs(w) >= thresh, w, 0.0)
        # pJDS over W^T: each row = one output feature's input weights
        csr = F.csr_from_dense(np.asarray(wp.T, dtype=np.float32))
        pj = F.csr_to_pjds(csr, b_r=b_r, diag_align=chunk_l,
                           permuted_cols=False)
        return SparseLinear(
            a=ops.to_device_pjds(pj, chunk_l=chunk_l),
            perm=pj.perm,
            n_out=n_out,
            n_in_pad=_pad(n_in, 1),
            density=float((wp != 0).mean()),
        )

    def __call__(self, x: jax.Array, backend: ops.Backend = "ref") -> jax.Array:
        """x: (..., n_in) -> (..., n_out)."""
        lead = x.shape[:-1]
        n_in = x.shape[-1]
        xt = x.reshape(-1, n_in).T                    # (n_in, T)
        t = xt.shape[1]
        t_pad = _pad(t, 128)
        xt = jnp.pad(xt, ((0, 0), (0, t_pad - t)))
        y_perm = ops.pjds_matmat(self.a, xt, backend=backend)  # (rows_pad, T)
        # unpermute rows back to output-feature order
        inv = np.zeros(self.a.n_rows_pad, np.int32)
        valid = self.perm < self.n_out
        inv_idx = jnp.asarray(self.perm[valid])
        y = jnp.zeros((self.n_out, t_pad), y_perm.dtype)
        y = y.at[inv_idx].set(y_perm[jnp.asarray(np.nonzero(valid)[0])])
        return y[:, :t].T.reshape(*lead, self.n_out).astype(x.dtype)

    def memory_summary(self, dense_bytes_per_el: int = 2) -> dict:
        dense = self.n_in_pad * self.n_out * dense_bytes_per_el
        stored = ops_storage_bytes(self.a)
        csr_min = int(self.density * self.n_in_pad * self.n_out) * 8
        return {"dense_bytes": dense, "pjds_bytes": stored,
                "ratio_vs_dense": stored / dense,
                "padding_overhead": stored / max(csr_min, 1) - 1.0}


def ops_storage_bytes(a: ops.PJDSDevice, value_bytes: int = 4,
                      index_bytes: int = 4) -> int:
    return int(a.val.size) * (value_bytes + index_bytes) \
        + int(a.chunk_map.size) * 4


def _pad(x, m):
    return (x + m - 1) // m * m


def sparsify_ffn_params(ffn_params: dict, density: float) -> dict:
    """Convert a dense FFN param dict (w1/w3/w2) to SparseLinear ops."""
    out = {}
    for k, v in ffn_params.items():
        w = np.asarray(jax.device_get(v["w"]), np.float32)
        out[k] = SparseLinear.from_dense(w, density)
    return out


def sparse_ffn_apply(sp: dict, cfg, x: jax.Array,
                     backend: ops.Backend = "ref") -> jax.Array:
    from repro.models.common import activation
    act = activation(cfg.act)
    h = sp["w1"](x, backend)
    if "w3" in sp:
        h = act(h) * sp["w3"](x, backend)
    else:
        h = act(h)
    return sp["w2"](h, backend)
