"""SparseFFN: pruned FFN weights in blocked sparse storage + spMM.

The paper's storage format promoted to a first-class LM feature
(DESIGN.md §4): magnitude-prune a trained FFN to ``density``, convert the
surviving weights to SELL-C-sigma (default) or pJDS, and run the forward
pass as multi-RHS spMVM.

Format choice rides the unified dispatch layer (DESIGN.md §5): with
``format="sell"`` rows — i.e. output features — are sorted only inside
sigma-row windows, so the inverse permutation that restores feature
order after the spMM is a window-local gather instead of a global one.
``format="auto"`` (default) compares estimated padded storage between
SELL and pJDS — for multi-RHS spMM the unpermute amortises over the T
RHS columns while padding multiplies by T, so minimum storage wins and
the window is kept only when it is free.

Memory story (the paper's Table-1 argument, on LM weights): an FFN with
density d stores ~d * (4+4)/2 bytes per original bf16 element (f32 value
+ int32 index, halved... see ``memory_summary``), so densities below ~1/6
shrink the footprint vs dense bf16 while the block-local padding (vs
ELLPACK) stays <1% even though per-row non-zero counts after magnitude
pruning vary wildly — exactly the row-length-variance regime (Fig. 3)
pJDS/SELL were designed for.

This module is single-device (inference compression); the distributed
dry-run path uses dense FFN.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.kernels import ops


@dataclasses.dataclass
class SparseLinear:
    """y = x @ W with W^T stored blocked-sparse (rows = output features)."""

    a: ops.PJDSDevice
    inv_perm: jax.Array       # (n_out,) sorted position of each output feature
    fmt: str                  # "sell" | "pjds"
    sigma: int                # sort window (n_rows_pad for pjds)
    n_out: int
    n_in_pad: int
    density: float

    @staticmethod
    def from_dense(w: np.ndarray, density: float, b_r: int = 128,
                   chunk_l: int = 8, format: str = "auto",
                   sigma: int | None = None) -> "SparseLinear":
        """Magnitude-prune ``w`` (in, out) to ``density`` and pack."""
        n_in, n_out = w.shape
        k = max(int(w.size * density), 1)
        thresh = np.partition(np.abs(w).ravel(), -k)[-k]
        wp = np.where(np.abs(w) >= thresh, w, 0.0)
        # blocked storage over W^T: each row = one output feature's weights
        csr = F.csr_from_dense(np.asarray(wp.T, dtype=np.float32))
        if format == "auto":
            # Multi-RHS spMM economics differ from spMV: the unpermute
            # gather amortises over the T RHS columns while padding
            # multiplies by T, so minimum storage wins — keep the SELL
            # window (locality) only when it pads no worse than pJDS.
            rl = csr.row_lengths()
            sell_e = F.estimate_storage_elements(rl, "sell", b_r,
                                                 chunk_l, sigma)
            pjds_e = F.estimate_storage_elements(rl, "pjds", b_r, chunk_l)
            format = "sell" if sell_e <= pjds_e else "pjds"
        if format == "sell":
            s = F.csr_to_sell(csr, c=b_r, sigma=sigma, diag_align=chunk_l,
                              permuted_cols=False)
            pj, sig = s.pjds, s.sigma
        elif format == "pjds":
            pj = F.csr_to_pjds(csr, b_r=b_r, diag_align=chunk_l,
                               permuted_cols=False)
            sig = pj.n_rows_pad
        else:
            raise ValueError(f"unknown format {format!r}")
        return SparseLinear(
            a=ops.to_device_pjds(pj, chunk_l=chunk_l),
            inv_perm=jnp.asarray(pj.inv_perm[:n_out]),
            fmt=format,
            sigma=sig,
            n_out=n_out,
            n_in_pad=_pad(n_in, 1),
            density=float((wp != 0).mean()),
        )

    def __call__(self, x: jax.Array, backend: ops.Backend = "ref") -> jax.Array:
        """x: (..., n_in) -> (..., n_out)."""
        lead = x.shape[:-1]
        n_in = x.shape[-1]
        xt = x.reshape(-1, n_in).T                    # (n_in, T)
        t = xt.shape[1]
        t_pad = _pad(t, 128)
        xt = jnp.pad(xt, ((0, 0), (0, t_pad - t)))
        y_perm = ops.pjds_matmat(self.a, xt, backend=backend)  # (rows_pad, T)
        # rows back to output-feature order: window-local gather for SELL,
        # global gather for pJDS — never a scatter.
        y = y_perm[self.inv_perm]
        return y[:, :t].T.reshape(*lead, self.n_out).astype(x.dtype)

    def memory_summary(self, dense_bytes_per_el: int = 2) -> dict:
        dense = self.n_in_pad * self.n_out * dense_bytes_per_el
        stored = ops_storage_bytes(self.a)
        csr_min = int(self.density * self.n_in_pad * self.n_out) * 8
        return {"dense_bytes": dense, "pjds_bytes": stored,
                "ratio_vs_dense": stored / dense,
                "padding_overhead": stored / max(csr_min, 1) - 1.0}


def ops_storage_bytes(a: ops.PJDSDevice, value_bytes: int = 4,
                      index_bytes: int = 4) -> int:
    return int(a.val.size) * (value_bytes + index_bytes) \
        + int(a.chunk_map.size) * 4


def _pad(x, m):
    return (x + m - 1) // m * m


def sparsify_ffn_params(ffn_params: dict, density: float,
                        format: str = "auto") -> dict:
    """Convert a dense FFN param dict (w1/w3/w2) to SparseLinear ops."""
    out = {}
    for k, v in ffn_params.items():
        w = np.asarray(jax.device_get(v["w"]), np.float32)
        out[k] = SparseLinear.from_dense(w, density, format=format)
    return out


def sparse_ffn_apply(sp: dict, cfg, x: jax.Array,
                     backend: ops.Backend = "ref") -> jax.Array:
    from repro.models.common import activation
    act = activation(cfg.act)
    h = sp["w1"](x, backend)
    if "w3" in sp:
        h = act(h) * sp["w3"](x, backend)
    else:
        h = act(h)
    return sp["w2"](h, backend)
