"""SparseFFN: pruned FFN weights as a differentiable SparseOperator.

The paper's storage format promoted to a first-class LM feature
(DESIGN.md §4): magnitude-prune a trained FFN to ``density``, convert the
surviving weights to SELL-C-sigma (default) or pJDS, and run the forward
pass as multi-RHS spMVM through the operator protocol (DESIGN.md §8).

Format choice rides the unified dispatch layer (DESIGN.md §5): with
``format="sell"`` rows — i.e. output features — are sorted only inside
sigma-row windows, so the inverse permutation that restores feature
order after the spMM is a window-local gather instead of a global one.
``format="auto"`` (default) compares estimated padded storage between
SELL and pJDS — for multi-RHS spMM the unpermute amortises over the T
RHS columns while padding multiplies by T, so minimum storage wins and
the window is kept only when it is free.

Since PR 3 each :class:`SparseLinear` wraps a
``repro.core.operator.DeviceOperator`` and is itself a registered
pytree, so sparse layers sit inside param trees, flow through ``jit``
(e.g. the serving engine's decode step), and are TRAINABLE end-to-end:
the operator's ``custom_vjp`` makes ``jax.grad`` flow into the stored
values, with the pruned sparsity pattern fixed —

    g = jax.grad(lambda v: loss(sl.with_values(v)(x)))(sl.values)

Memory story (the paper's Table-1 argument, on LM weights): an FFN with
density d stores ~d * (4+4)/2 bytes per original bf16 element (f32 value
+ int32 index, halved... see ``memory_summary``), so densities below ~1/6
shrink the footprint vs dense bf16 while the block-local padding (vs
ELLPACK) stays <1% even though per-row non-zero counts after magnitude
pruning vary wildly — exactly the row-length-variance regime (Fig. 3)
pJDS/SELL were designed for.

This module is single-device (inference compression / fine-tuning); the
distributed dry-run path uses dense FFN.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F
from repro.core.operator import DeviceOperator, operator
from repro.kernels import ops


@jax.tree_util.register_pytree_node_class
class SparseLinear:
    """y = x @ W with W^T stored blocked-sparse (rows = output features),
    applied through a :class:`DeviceOperator`.  A registered pytree: the
    device arrays (values + indices) are the leaves."""

    def __init__(self, op: DeviceOperator, n_out: int, n_in_pad: int,
                 sigma: int, density: float):
        self.op = op
        self.n_out = n_out
        self.n_in_pad = n_in_pad
        self.sigma = sigma
        self.density = density

    @property
    def fmt(self) -> str:
        return self.op.fmt

    @property
    def a(self):
        """The inner blocked device operand (storage accounting)."""
        return self.op.dev.dev

    @property
    def values(self) -> jax.Array:
        """The stored (pruned) weights — the trainable parameters."""
        return self.op.values

    def with_values(self, val: jax.Array) -> "SparseLinear":
        """Same sparsity pattern, new stored values (the grad handle)."""
        return SparseLinear(self.op.with_values(val), self.n_out,
                            self.n_in_pad, self.sigma, self.density)

    @staticmethod
    def from_dense(w: np.ndarray, density: float, b_r: int = 128,
                   chunk_l: int = 8, format: str = "auto",
                   sigma: int | None = None, dtype=None,
                   index_dtype="auto") -> "SparseLinear":
        """Magnitude-prune ``w`` (in, out) to ``density`` and pack.

        ``dtype``/``index_dtype`` choose the stored value/index stream
        widths (``kernels.ops.as_device``): bf16 values + int16 indices
        store 4 bytes per survivor instead of 8, moving the
        break-even-vs-dense-bf16 density from ~1/6 to ~1/3."""
        n_in, n_out = w.shape
        k = max(int(w.size * density), 1)
        thresh = np.partition(np.abs(w).ravel(), -k)[-k]
        wp = np.where(np.abs(w) >= thresh, w, 0.0)
        # blocked storage over W^T: each row = one output feature's weights
        csr = F.csr_from_dense(np.asarray(wp.T, dtype=np.float32))
        if format == "auto":
            # Multi-RHS spMM economics differ from spMV: the unpermute
            # gather amortises over the T RHS columns while padding
            # multiplies by T, so minimum storage wins — keep the SELL
            # window (locality) only when it pads no worse than pJDS.
            rl = csr.row_lengths()
            sell_e = F.estimate_storage_elements(rl, "sell", b_r,
                                                 chunk_l, sigma)
            pjds_e = F.estimate_storage_elements(rl, "pjds", b_r, chunk_l)
            format = "sell" if sell_e <= pjds_e else "pjds"
        if format not in ("sell", "pjds"):
            raise ValueError(f"unknown format {format!r}")
        op = operator(csr, format=format, b_r=b_r, diag_align=chunk_l,
                      chunk_l=chunk_l, sigma=sigma, dtype=dtype,
                      index_dtype=index_dtype)
        sig = op.dev.dev.sigma if format == "sell" \
            else op.dev.dev.n_rows_pad
        return SparseLinear(
            op=op,
            n_out=n_out,
            n_in_pad=_pad(n_in, 1),
            sigma=sig,
            density=float((wp != 0).mean()),
        )

    def __call__(self, x: jax.Array,
                 backend: ops.Backend | None = None) -> jax.Array:
        """x: (..., n_in) -> (..., n_out)."""
        lead = x.shape[:-1]
        n_in = x.shape[-1]
        xt = x.reshape(-1, n_in).T                    # (n_in, T)
        t = xt.shape[1]
        t_pad = _pad(t, 128)
        xt = jnp.pad(xt, ((0, 0), (0, t_pad - t)))
        # the operator hides format, permutation and padding: (n_out, T)
        # back in output-feature order, differentiable through values & x
        y = self.op.matmat(xt, backend=backend)
        return y[:, :t].T.reshape(*lead, self.n_out).astype(x.dtype)

    def memory_summary(self, dense_bytes_per_el: int = 2) -> dict:
        dense = self.n_in_pad * self.n_out * dense_bytes_per_el
        stored = ops_storage_bytes(self.a)
        csr_min = int(self.density * self.n_in_pad * self.n_out) * 8
        return {"dense_bytes": dense, "pjds_bytes": stored,
                "ratio_vs_dense": stored / dense,
                "padding_overhead": stored / max(csr_min, 1) - 1.0}

    # -- pytree ------------------------------------------------------------
    def tree_flatten(self):
        return (self.op,), (self.n_out, self.n_in_pad, self.sigma,
                            self.density)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], *aux)


def ops_storage_bytes(a, value_bytes: int | None = None,
                      index_bytes: int | None = None) -> int:
    """Device-operand footprint at the widths ACTUALLY stored (so a
    bf16-value / int16-index build reports its compressed bytes)."""
    vb = a.val.dtype.itemsize if value_bytes is None else value_bytes
    ib = a.col_idx.dtype.itemsize if index_bytes is None else index_bytes
    return int(a.val.size) * (vb + ib) + int(a.chunk_map.size) * 4


def _pad(x, m):
    return (x + m - 1) // m * m


def sparsify_ffn_params(ffn_params: dict, density: float,
                        format: str = "auto") -> dict:
    """Convert a dense FFN param dict (w1/w3/w2) to SparseLinear ops."""
    out = {}
    for k, v in ffn_params.items():
        w = np.asarray(jax.device_get(v["w"]), np.float32)
        out[k] = SparseLinear.from_dense(w, density, format=format)
    return out


def sparse_ffn_apply(sp: dict, cfg, x: jax.Array,
                     backend: ops.Backend | None = None) -> jax.Array:
    from repro.models.common import activation
    act = activation(cfg.act)
    h = sp["w1"](x, backend)
    if "w3" in sp:
        h = act(h) * sp["w3"](x, backend)
    else:
        h = act(h)
    return sp["w2"](h, backend)
