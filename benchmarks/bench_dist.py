"""Distributed halo-exchange benchmark: gathered vs full-slice comm,
single vs multi-RHS.

Sweeps the banded boundary-coupled test matrix (halo_w = 2, sparse
coupling — the regime the paper's Eq. 3-4 link model cares about) over
communication modes x halo implementation x RHS block size on 8 virtual
host devices (subprocess, this process keeps one device), recording
per-device communication volume and wall-clock.  Also times k=4
``dist_matmat`` against 4 sequential ``dist_matvec`` calls — the
multi-RHS amortisation of the streamed matrix and the halo set-up.

Host-CPU collectives through shared memory are not an ICI fabric, so
(as with bench_scaling) the gathered-vs-full and matmat-vs-matvec
RATIOS are the comparable quantities; the comm_bytes columns are exact.

Writes ``BENCH_dist.json`` (CI artifact).
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import csv_row, write_bench_json

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import formats as F, dist_spmv as D
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh

    n_dev = 8
    mesh = make_host_mesh(n_dev)
    rng = np.random.default_rng(0)

    def banded(n, reach, stride=8):
        # tridiagonal band + sparse long-range coupling reaching into the
        # second neighbor slice: the gathered halo's winning regime
        a = np.zeros((n, n), np.float32)
        i = np.arange(n)
        a[i, i] = 4.0
        a[i[:-1], i[:-1] + 1] = -1.0
        a[i[1:], i[1:] - 1] = -1.0
        far = i[::stride]
        for sgn in (+1, -1):
            tgt = far + sgn * reach
            ok = (tgt >= 0) & (tgt < n)
            a[far[ok], tgt[ok]] = -0.5
        return F.csr_from_dense(a)

    def timed(fn, arg, warmup=3, iters=10):
        for _ in range(warmup):
            jax.block_until_ready(fn(arg))
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(arg))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    b_r = 128
    n = 8 * b_r * 2                       # n_loc = 256
    m = banded(n, reach=384)              # n_loc < reach < 2*n_loc
    dist = D.partition_csr(m, n_dev, b_r=b_r)
    assert dist.halo_w == 2, dist.halo_w

    out = {"halo_w": dist.halo_w, "halo_lens": list(dist.halo_lens),
           "n_loc": dist.n_loc, "nnz": int(m.nnz), "rows": []}
    shard = jax.NamedSharding(mesh, P("data"))
    shard2 = jax.NamedSharding(mesh, P("data", None))
    for k in (1, 4):
        X = rng.standard_normal((dist.n_global_pad, k)).astype(np.float32)
        for halo in ("gathered", "full"):
            comm = dist.comm_bytes_per_device(value_bytes=4, k=k, halo=halo)
            for mode in ("vector", "naive", "overlap"):
                op = dist_operator(dist, mesh, mode=mode, halo=halo)
                if k == 1:
                    f = jax.jit(op.matvec)
                    arg = jax.device_put(jnp.asarray(X[:, 0]), shard)
                else:
                    f = jax.jit(op.matmat)
                    arg = jax.device_put(jnp.asarray(X), shard2)
                t = timed(f, arg)
                out["rows"].append(dict(
                    kind="sweep", halo=halo, mode=mode, k=k, t_us=t * 1e6,
                    comm_bytes=comm,
                    gfs=2 * m.nnz * k / t / 1e9))

    # k=4 spMM vs 4 sequential spMVMs (overlap mode, gathered halo)
    X4 = rng.standard_normal((dist.n_global_pad, 4)).astype(np.float32)
    op = dist_operator(dist, mesh, mode="overlap")
    mm = jax.jit(op.matmat)
    arg4 = jax.device_put(jnp.asarray(X4), shard2)
    t_mm = timed(mm, arg4)
    mv = jax.jit(op.matvec)
    cols = [jax.device_put(jnp.asarray(X4[:, j]), shard) for j in range(4)]
    for c in cols:
        jax.block_until_ready(mv(c))
    import time as _t
    ts = []
    for _ in range(10):
        t0 = _t.perf_counter()
        for c in cols:
            jax.block_until_ready(mv(c))
        ts.append(_t.perf_counter() - t0)
    t_seq = float(np.median(ts))
    out["rows"].append(dict(kind="matmat_vs_seq", t_matmat_us=t_mm * 1e6,
                            t_seq4_us=t_seq * 1e6,
                            speedup=t_seq / t_mm))
    print("RESULTS " + json.dumps(out))
""")


def _measured():
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


def run(print_rows=True):
    res = _measured()
    rows = res["rows"]
    meta = dict(kind="meta", halo_w=res["halo_w"],
                halo_lens=res["halo_lens"], n_loc=res["n_loc"],
                nnz=res["nnz"])
    if print_rows:
        for r in rows:
            if r["kind"] == "sweep":
                print(csv_row(
                    f"dist_{r['halo']}_{r['mode']}_k{r['k']}", r["t_us"],
                    f"comm={r['comm_bytes']}B/dev {r['gfs']:.2f}GF/s"))
            else:
                print(csv_row("dist_matmat4_vs_4matvec", r["t_matmat_us"],
                              f"seq4={r['t_seq4_us']:.1f}us "
                              f"speedup={r['speedup']:.2f}x"))
        g = next(r for r in rows
                 if r["kind"] == "sweep" and r["halo"] == "gathered")
        f = next(r for r in rows
                 if r["kind"] == "sweep" and r["halo"] == "full")
        print(csv_row("dist_comm_reduction", 0.0,
                      f"{f['comm_bytes'] / max(g['comm_bytes'], 1):.1f}x "
                      f"less halo traffic (halo_w={res['halo_w']})"))
    write_bench_json("dist", [meta] + rows)
    return rows


if __name__ == "__main__":
    run()
