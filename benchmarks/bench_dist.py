"""Distributed halo-exchange benchmark: gathered vs full-slice comm,
1-D vs 2-D grids, bulk-synchronous vs overlapped vs pipelined, and the
calibrated ``halo="auto"`` crossover — plus single vs multi-RHS.

Sweeps two banded boundary-coupled test matrices (halo_w = 2 and
halo_w = 1 — both sides of the gathered-vs-full crossover the paper's
Eq. 3-4 link model prices) over communication mode x halo
implementation x device-grid shape on 8 virtual host devices
(subprocess, this process keeps one device), recording per-device wire
statistics (bytes AND messages) next to wall-clock.  Also times k=4
``dist_matmat`` against 4 sequential ``dist_matvec`` calls — the
multi-RHS amortisation of the streamed matrix and the halo set-up.

Host-CPU collectives through shared memory are not an ICI fabric, so
(as with bench_scaling) the gathered-vs-full and mode-vs-mode RATIOS
are the comparable quantities; the comm_bytes/comm_msgs columns are
exact.  That is exactly why the sweep also FITS the link calibration
(``tune.calibrate.fit_link_calibration``) from its own rows: the
per-message fixed cost is a property of whatever fabric ran the
benchmark, and the calibrated model must agree with it.

Two hard guards (SystemExit — CI fails loudly, not quietly):

* ``halo="auto"`` (``perf_model.choose_halo`` under the fitted link
  calibration) must pick the MEASURED gathered-vs-full winner on both
  bench matrices — the calibrated crossover never selects a measured
  loser.
* the best overlapped config (overlap/pipeline, any grid) must beat
  the best bulk-synchronous 1-D config at the largest emulated mesh —
  the explicit dependency structure has to pay for itself.

Writes ``BENCH_dist.json`` (CI artifact), including the strong/weak
scaling-efficiency curves from ``bench_scaling.scaling_curves``.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from .common import csv_row, write_bench_json

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import formats as F, dist_spmv as D
    from repro.core import perf_model as PM
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh
    from repro.tune import fit_link_calibration, link_model_error

    n_dev = 8
    mesh = make_host_mesh(n_dev)
    rng = np.random.default_rng(0)

    def banded(n, reach, stride=8):
        # tridiagonal band + sparse long-range coupling: the gathered
        # halo's winning regime (few scattered remote columns)
        a = np.zeros((n, n), np.float32)
        i = np.arange(n)
        a[i, i] = 4.0
        a[i[:-1], i[:-1] + 1] = -1.0
        a[i[1:], i[1:] - 1] = -1.0
        far = i[::stride]
        for sgn in (+1, -1):
            tgt = far + sgn * reach
            ok = (tgt >= 0) & (tgt < n)
            a[far[ok], tgt[ok]] = -0.5
        return F.csr_from_dense(a)

    def timed(fn, arg, warmup=3, iters=10):
        for _ in range(warmup):
            jax.block_until_ready(fn(arg))
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(arg))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    b_r = 128
    n = 8 * b_r * 2                       # n_loc = 256 on the 1-D grid
    shard = jax.NamedSharding(mesh, P("data"))
    shard2 = jax.NamedSharding(mesh, P("data", None))

    # reach384: n_loc < reach < 2*n_loc  -> halo_w=2, sparse coupling
    # reach96:  reach < n_loc            -> halo_w=1, denser coupling
    mats = [("reach384", banded(n, reach=384)),
            ("reach96", banded(n, reach=96, stride=2))]

    out = {"rows": []}

    def sweep(name, m, grid, halos_modes, k=1):
        dist = D.partition_csr(m, n_dev, b_r=b_r, grid=grid)
        X = rng.standard_normal((dist.n_global_pad, k)).astype(np.float32)
        for halo, mode in halos_modes:
            op = dist_operator(dist, mesh, mode=mode, halo=halo)
            if k == 1:
                f = jax.jit(op.matvec)
                arg = jax.device_put(jnp.asarray(X[:, 0]), shard)
            else:
                f = jax.jit(op.matmat)
                arg = jax.device_put(jnp.asarray(X), shard2)
            t = timed(f, arg)
            out["rows"].append(dict(
                kind="sweep", matrix=name, grid=grid, halo=halo, mode=mode,
                k=k, t_us=t * 1e6,
                halo_w=int(dist.halo_w), red_w=int(dist.red_w),
                comm_bytes=int(dist.comm_bytes_per_device(4, k, halo)),
                comm_msgs=int(dist.comm_msgs_per_device(halo)),
                group=f"{name}/{grid}/{mode}/k{k}",
                gfs=2 * m.nnz * k / t / 1e9))
        return dist

    m1 = mats[0][1]
    d1 = sweep("reach384", m1, None,
               [(h, mo) for h in ("gathered", "full")
                for mo in ("vector", "naive", "overlap")]
               + [("gathered", "pipeline")])
    out["halo_w"] = int(d1.halo_w)
    out["halo_lens"] = list(d1.halo_lens)
    out["n_loc"] = int(d1.n_loc)
    out["nnz"] = int(m1.nnz)
    for grid in ((2, 4), (1, 8)):
        sweep("reach384", m1, grid,
              [(h, mo) for h in ("gathered", "full")
               for mo in ("vector", "overlap")]
              + [("gathered", "pipeline")])
    sweep("reach384", m1, None,
          [(h, mo) for h in ("gathered", "full")
           for mo in ("vector", "overlap")], k=4)
    sweep("reach96", mats[1][1], None,
          [(h, mo) for h in ("gathered", "full")
           for mo in ("vector", "overlap")])

    # -- drift-robust paired timing (tune.measure.ab_compare style):
    # alternate the two sides round by round and keep each side's
    # minimum round median, so slow host drift lands on both sides and
    # the min discards the inflated rounds.  The guards compare PAIRED
    # numbers, never two one-sided sweep rows.
    def paired(f_a, arg_a, f_b, arg_b, rounds=5, iters=5):
        for f, a in ((f_a, arg_a), (f_b, arg_b)):
            for _ in range(2):
                jax.block_until_ready(f(a))
        t_a = t_b = float("inf")
        for r in range(rounds):
            order = (((0, f_a, arg_a), (1, f_b, arg_b)) if r % 2 == 0
                     else ((1, f_b, arg_b), (0, f_a, arg_a)))
            for side, f, a in order:
                ts = []
                for _ in range(iters):
                    t0 = time.perf_counter()
                    jax.block_until_ready(f(a))
                    ts.append(time.perf_counter() - t0)
                t = float(np.median(ts))
                if side == 0:
                    t_a = min(t_a, t)
                else:
                    t_b = min(t_b, t)
        return t_a, t_b

    # -- link calibration from PAIRED bulk-synchronous measurements ----
    # Only vector mode: bulk-synchronous time is base + comm (additive),
    # so the wire terms are identifiable; overlapped time hides comm
    # under compute (max), which a fit cannot invert.  Each (matrix,
    # grid) group is measured as an interleaved gathered-vs-full pair,
    # so the two rows a group's base must explain sat under the same
    # host drift — the fit sees the same data the guard judges by.
    sweep_rows = [r for r in out["rows"] if r["kind"] == "sweep"]
    fit_in = []
    pair_t = {}
    for name, m in mats:
        grids = (None, (2, 4), (1, 8)) if name == "reach384" else (None,)
        for grid in grids:
            dist = D.partition_csr(m, n_dev, b_r=b_r, grid=grid)
            x1 = jax.device_put(jnp.asarray(
                rng.standard_normal(dist.n_global_pad).astype(np.float32)),
                shard)
            f_g = jax.jit(dist_operator(dist, mesh, mode="vector",
                                        halo="gathered").matvec)
            f_f = jax.jit(dist_operator(dist, mesh, mode="vector",
                                        halo="full").matvec)
            t_g, t_f = paired(f_g, x1, f_f, x1)
            if grid is None:
                pair_t[name] = (t_g, t_f)
            for halo, t in (("gathered", t_g), ("full", t_f)):
                fit_in.append(dict(
                    group=f"{name}/{grid}", halo=halo,
                    msgs=int(dist.comm_msgs_per_device(halo)),
                    bytes=int(dist.comm_bytes_per_device(4, 1, halo)),
                    measured_s=t))
    cal = fit_link_calibration(fit_in, source="bench_dist")
    out["rows"].append(dict(
        kind="link_calibration",
        msg_overhead_us={h: v * 1e6 for h, v in cal.msg_overhead_s.items()},
        link_bw_scale=cal.link_bw_scale,
        err_uncal=link_model_error(fit_in, None),
        err_cal=link_model_error(fit_in, cal)))

    # -- guard 1: calibrated halo="auto" vs the paired measured winner -
    for name, m in mats:
        dist = D.partition_csr(m, n_dev, b_r=b_r)
        pick = PM.choose_halo(dist, mode="vector", value_bytes=4,
                              calibration=cal)
        t_g, t_f = pair_t[name]
        winner = "gathered" if t_g < t_f else "full"
        # a sub-5% gap is a tie at host-collective noise levels: either
        # pick is defensible, so the guard only fires on a CLEAR loser
        tie = abs(t_g - t_f) <= 0.05 * min(t_g, t_f)
        out["rows"].append(dict(
            kind="halo_auto", matrix=name, picked=pick, measured=winner,
            agree=bool(pick == winner or tie),
            t_gathered_us=t_g * 1e6, t_full_us=t_f * 1e6))

    # -- guard 2: overlapped vs bulk-synchronous at the full mesh ------
    k1 = [r for r in sweep_rows
          if r["matrix"] == "reach384" and r["k"] == 1]
    best_ov = min((r for r in k1 if r["mode"] in ("overlap", "pipeline")),
                  key=lambda r: r["t_us"])
    best_bs = min((r for r in k1 if r["mode"] == "vector"
                   and r["grid"] is None), key=lambda r: r["t_us"])
    d_ov = D.partition_csr(m1, n_dev, b_r=b_r, grid=best_ov["grid"])
    x_ov = jax.device_put(jnp.asarray(
        rng.standard_normal(d_ov.n_global_pad).astype(np.float32)), shard)
    f_ov = jax.jit(dist_operator(d_ov, mesh, mode=best_ov["mode"],
                                 halo=best_ov["halo"]).matvec)
    f_bs = jax.jit(dist_operator(d1, mesh, mode="vector",
                                 halo=best_bs["halo"]).matvec)
    t_ov, t_bs = paired(f_ov, x_ov, f_bs, x_ov)
    out["rows"].append(dict(
        kind="overlap_guard",
        best_overlapped=dict(grid=best_ov["grid"], halo=best_ov["halo"],
                             mode=best_ov["mode"], t_us=t_ov * 1e6),
        best_bulk_1d=dict(halo=best_bs["halo"], t_us=t_bs * 1e6),
        ok=bool(t_ov < t_bs)))

    # k=4 spMM vs 4 sequential spMVMs (overlap mode, gathered halo)
    dist = d1
    X4 = rng.standard_normal((dist.n_global_pad, 4)).astype(np.float32)
    op = dist_operator(dist, mesh, mode="overlap")
    mm = jax.jit(op.matmat)
    arg4 = jax.device_put(jnp.asarray(X4), shard2)
    t_mm = timed(mm, arg4)
    mv = jax.jit(op.matvec)
    cols = [jax.device_put(jnp.asarray(X4[:, j]), shard) for j in range(4)]
    for c in cols:
        jax.block_until_ready(mv(c))
    ts = []
    for _ in range(10):
        t0 = time.perf_counter()
        for c in cols:
            jax.block_until_ready(mv(c))
        ts.append(time.perf_counter() - t0)
    t_seq = float(np.median(ts))
    out["rows"].append(dict(kind="matmat_vs_seq", t_matmat_us=t_mm * 1e6,
                            t_seq4_us=t_seq * 1e6,
                            speedup=t_seq / t_mm))
    print("RESULTS " + json.dumps(out))
""")


def _measured():
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


def run(print_rows=True):
    from . import bench_scaling

    res = _measured()
    rows = res["rows"]
    meta = dict(kind="meta", halo_w=res["halo_w"],
                halo_lens=res["halo_lens"], n_loc=res["n_loc"],
                nnz=res["nnz"])
    if print_rows:
        for r in rows:
            if r["kind"] == "sweep":
                g = "x".join(map(str, r["grid"])) if r["grid"] else "1d"
                print(csv_row(
                    f"dist_{r['matrix']}_{g}_{r['halo']}_{r['mode']}"
                    f"_k{r['k']}", r["t_us"],
                    f"comm={r['comm_bytes']}B/{r['comm_msgs']}msg/dev "
                    f"{r['gfs']:.2f}GF/s"))
            elif r["kind"] == "link_calibration":
                ov = " ".join(f"{h}={v:.1f}us"
                              for h, v in r["msg_overhead_us"].items())
                print(csv_row("dist_link_calibration", 0.0,
                              f"msg_cost[{ov}] rel_err "
                              f"{r['err_uncal']:.3f}->{r['err_cal']:.3f}"))
            elif r["kind"] == "halo_auto":
                print(csv_row(f"dist_halo_auto_{r['matrix']}", 0.0,
                              f"picked={r['picked']} measured={r['measured']}"
                              f" agree={r['agree']}"))
            elif r["kind"] == "overlap_guard":
                b, s = r["best_overlapped"], r["best_bulk_1d"]
                g = "x".join(map(str, b["grid"])) if b["grid"] else "1d"
                print(csv_row(
                    "dist_overlap_guard", b["t_us"],
                    f"{g}/{b['halo']}/{b['mode']} vs bulk-1d/{s['halo']}="
                    f"{s['t_us']:.1f}us ok={r['ok']}"))
            elif r["kind"] == "matmat_vs_seq":
                print(csv_row("dist_matmat4_vs_4matvec", r["t_matmat_us"],
                              f"seq4={r['t_seq4_us']:.1f}us "
                              f"speedup={r['speedup']:.2f}x"))
        g = next(r for r in rows
                 if r["kind"] == "sweep" and r["halo"] == "gathered")
        f = next(r for r in rows
                 if r["kind"] == "sweep" and r["halo"] == "full")
        print(csv_row("dist_comm_reduction", 0.0,
                      f"{f['comm_bytes'] / max(g['comm_bytes'], 1):.1f}x "
                      f"less halo traffic (halo_w={res['halo_w']})"))

    scaling = bench_scaling.scaling_curves(print_rows=print_rows)
    write_bench_json("dist", [meta] + rows + scaling)

    bad = [r for r in rows if r["kind"] == "halo_auto" and not r["agree"]]
    if bad:
        raise SystemExit(
            "halo='auto' picked a measured loser on "
            + ", ".join(r["matrix"] for r in bad)
            + " — the fitted link calibration disagrees with the "
            "measured gathered-vs-full winner")
    guard = next(r for r in rows if r["kind"] == "overlap_guard")
    if not guard["ok"]:
        raise SystemExit(
            f"no overlapped config beat the bulk-synchronous 1-D baseline "
            f"at the full mesh: best overlapped "
            f"{guard['best_overlapped']} vs {guard['best_bulk_1d']}")
    return rows


if __name__ == "__main__":
    run()
