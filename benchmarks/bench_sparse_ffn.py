"""SparseFFN study: the paper's Table-1 memory argument applied to
magnitude-pruned LM FFN weights (DESIGN.md §4).

For a qwen-family FFN block at several densities: pJDS footprint vs
dense bf16, padding overhead (pJDS's selling point: row-length variance
after magnitude pruning is exactly the Fig. 3 regime), and the pJDS-vs-
ELLPACK reduction on the pruned weight matrix."""
from __future__ import annotations

import numpy as np

from repro.core import formats as F
from repro.sparse.sparse_ffn import SparseLinear
from .common import csv_row


def run(print_rows=True):
    rng = np.random.default_rng(0)
    d_model, d_ff = 1024, 2816
    w = (rng.standard_normal((d_model, d_ff)) *
         (1 + rng.random((d_model, 1)))).astype(np.float32)  # row variance
    rows = []
    for density in (0.5, 0.2, 0.1, 0.05):
        sl = SparseLinear.from_dense(w, density, b_r=128)
        mem = sl.memory_summary()
        k = max(int(w.size * density), 1)
        th = np.partition(np.abs(w).ravel(), -k)[-k]
        pruned = np.where(np.abs(w) >= th, w, 0.0)
        m = F.csr_from_dense(pruned.T.astype(np.float32))
        red = F.data_reduction_vs_ellpack(m, b_r=128) if m.nnz else 0.0
        rows.append(dict(density=density,
                         ratio_vs_dense=mem["ratio_vs_dense"],
                         padding_overhead=mem["padding_overhead"],
                         reduction_vs_ellpack=red))
        if print_rows:
            print(csv_row(
                f"sparse_ffn_d{density}", 0.0,
                f"bytes_vs_dense_bf16={mem['ratio_vs_dense']:.2f} "
                f"pad_overhead={100*mem['padding_overhead']:.1f}% "
                f"vs_ellpack_reduction={100*red:.1f}%"))
    return rows


if __name__ == "__main__":
    run()
