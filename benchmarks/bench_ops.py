"""Operator-wrapper overhead: what the SparseOperator abstraction costs.

Three nested layers compute the same y = A x (jitted ref path — see
bench_kernels on why CPU Pallas wall-time is not meaningful):

* ``raw``      — the bare format matvec in the PERMUTED basis
  (``ops.sell_matvec`` / ``ops.pjds_matvec`` on the inner operand): the
  kernel alone, no basis restore for pjds.
* ``device``   — ``SparseDevice.matvec``: + original-basis epilogue
  (the unpermute gather for pjds; fused already for sell) + bounds
  checks — the dispatch layer.
* ``operator`` — ``operator(m) @ x``: + the custom_vjp application and
  the protocol dispatch — the full DESIGN.md §8 surface.

``operator/device`` is pure abstraction cost (should be ~1.0: the
custom_vjp wrapper exists only at trace time); ``device/raw`` prices the
basis restore.  An eager (un-jitted) ``op @ x`` row tracks the
per-call Python dispatch the serving path pays when it cannot jit.
Emits BENCH_ops.json for the perf trajectory.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import matrices as M
from repro.core.operator import operator
from repro.kernels import ops
from .common import time_fn, csv_row, write_bench_json

B_R = 128


def _raw_fn(dev: ops.SparseDevice):
    """The bare inner-format matvec (permuted basis where applicable)."""
    inner = dev.dev
    if dev.fmt == "sell":
        return lambda v: ops.sell_matvec(inner, v)
    if dev.fmt == "pjds":
        return lambda v: ops.pjds_matvec(inner, v)
    if dev.fmt == "ellpack_r":
        return lambda v: ops.ell_matvec(inner, v)
    return lambda v: ops.csr_matvec(inner, v)


def _bench_matrix(name: str, m, rows, print_rows: bool) -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(m.shape[1]).astype(np.float32))
    op = operator(m, b_r=B_R)
    dev = op.dev

    t_raw = time_fn(jax.jit(_raw_fn(dev)), x)
    t_dev = time_fn(jax.jit(lambda v: dev.matvec(v)), x)
    t_op = time_fn(jax.jit(lambda v: op @ v), x)

    # eager per-call dispatch cost (no jit): the Python-side price
    for _ in range(2):
        jax.block_until_ready(op @ x)
    t0 = time.perf_counter()
    n_eager = 5
    for _ in range(n_eager):
        jax.block_until_ready(op @ x)
    t_eager = (time.perf_counter() - t0) / n_eager

    row = dict(kind="op_overhead", matrix=name, fmt=op.fmt,
               t_raw_us=t_raw * 1e6, t_device_us=t_dev * 1e6,
               t_operator_us=t_op * 1e6, t_eager_us=t_eager * 1e6,
               wrapper_vs_device=t_op / t_dev,
               device_vs_raw=t_dev / t_raw)
    rows.append(row)
    if print_rows:
        print(csv_row(f"ops_{name}_{op.fmt}", t_op * 1e6,
                      f"wrapper_vs_device={t_op/t_dev:.2f}x "
                      f"device_vs_raw={t_dev/t_raw:.2f}x "
                      f"eager={t_eager*1e6:.0f}us"))


def run(print_rows=True):
    rows = []
    _bench_matrix("powerlaw", M.power_law(4096, seed=7), rows, print_rows)
    _bench_matrix("sAMG", M.samg(scale=0.004), rows, print_rows)
    _bench_matrix("poisson", M.poisson_2d(64, 64), rows, print_rows)
    write_bench_json("ops", rows)
    return rows


if __name__ == "__main__":
    run()
