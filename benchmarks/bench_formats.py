"""Paper Table 1: pJDS data reduction vs ELLPACK + spMVM performance.

For each of the five test-matrix analogues (HMEp, sAMG, DLR1, DLR2,
UHBR):
* data reduction of pJDS vs ELLPACK (the paper's memory column; paper
  measured 19-71%),
* measured spMVM wall-time of the jitted pJDS and ELLPACK-R operators on
  THIS host (CPU, so absolute GF/s are not Fermi numbers; the
  FORMAT-vs-FORMAT ratio is the comparable quantity),
* model-predicted TPU v5e GF/s from the paper's code balance (Eq. 1) at
  both alpha bounds — the number the roofline analysis targets.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import formats as F, matrices as M, perf_model as PM
from repro.kernels import ops
from .common import time_fn, csv_row

SCALES = {"HMEp": 0.004, "sAMG": 0.007, "DLR1": 0.08, "DLR2": 0.04,
          "UHBR": 0.005}


def run(print_rows=True):
    rows = []
    for name, scale in SCALES.items():
        m = M.make_test_matrix(name, scale=scale)
        n = m.shape[0]
        red = F.data_reduction_vs_ellpack(m, b_r=128)

        pj = F.csr_to_pjds(m, b_r=128)
        pdev = ops.to_device_pjds(pj)
        ell = F.csr_to_ell(m, row_align=128)
        edev = ops.to_device_ell(ell)
        rng = np.random.default_rng(0)
        xp = jnp.asarray(pj.permute(rng.standard_normal(n).astype(np.float32)))
        xe = jnp.asarray(np.resize(np.asarray(xp), ell.n_rows_pad))

        import jax
        f_p = jax.jit(lambda x: ops.pjds_matvec(pdev, x))
        f_e = jax.jit(lambda x: ops.ell_matvec(edev, x))
        t_p = time_fn(f_p, xp)
        t_e = time_fn(f_e, xe)
        gf_p = 2 * m.nnz / t_p / 1e9
        gf_e = 2 * m.nnz / t_e / 1e9

        # model-predicted TPU v5e spMVM GF/s (DP) at the two alpha bounds
        lo_a, hi_a = PM.alpha_range(m.n_nzr)
        gf_best = PM.TPU_V5E.hbm_bw / PM.code_balance(lo_a, m.n_nzr) / 1e9
        gf_worst = PM.TPU_V5E.hbm_bw / PM.code_balance(hi_a, m.n_nzr) / 1e9

        rows.append(dict(
            name=name, n=n, nnz=m.nnz, n_nzr=round(m.n_nzr, 1),
            reduction_pct=round(100 * red, 1),
            cpu_pjds_gfs=round(gf_p, 3), cpu_ellr_gfs=round(gf_e, 3),
            pjds_vs_ellr=round(gf_p / gf_e, 2),
            tpu_pred_gfs_best=round(gf_best, 1),
            tpu_pred_gfs_worst=round(gf_worst, 1),
            us_per_call=t_p * 1e6,
        ))
        if print_rows:
            r = rows[-1]
            print(csv_row(
                f"table1_{name}", r["us_per_call"],
                f"reduction={r['reduction_pct']}% "
                f"pjds/ellr={r['pjds_vs_ellr']} "
                f"tpu_pred={r['tpu_pred_gfs_worst']}-{r['tpu_pred_gfs_best']}GF/s"))
    return rows


if __name__ == "__main__":
    run()
