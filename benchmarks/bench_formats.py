"""Paper Table 1: pJDS data reduction vs ELLPACK + spMVM performance.

For each of the five test-matrix analogues (HMEp, sAMG, DLR1, DLR2,
UHBR):
* data reduction of pJDS vs ELLPACK (the paper's memory column; paper
  measured 19-71%),
* measured spMVM wall-time of the jitted pJDS and ELLPACK-R operators on
  THIS host (CPU, so absolute GF/s are not Fermi numbers; the
  FORMAT-vs-FORMAT ratio is the comparable quantity),
* model-predicted TPU v5e GF/s from the paper's code balance (Eq. 1) at
  both alpha bounds — the number the roofline analysis targets.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.core import formats as F, matrices as M, perf_model as PM
from repro.kernels import ops
from .common import time_fn, csv_row

SCALES = {"HMEp": 0.004, "sAMG": 0.007, "DLR1": 0.08, "DLR2": 0.04,
          "UHBR": 0.005}


def run(print_rows=True):
    rows = []
    for name, scale in SCALES.items():
        m = M.make_test_matrix(name, scale=scale)
        n = m.shape[0]
        red = F.data_reduction_vs_ellpack(m, b_r=128)

        pj = F.csr_to_pjds(m, b_r=128)
        pdev = ops.to_device_pjds(pj)
        ell = F.csr_to_ell(m, row_align=128)
        edev = ops.to_device_ell(ell)
        rng = np.random.default_rng(0)
        xp = jnp.asarray(pj.permute(rng.standard_normal(n).astype(np.float32)))
        xe = jnp.asarray(np.resize(np.asarray(xp), ell.n_rows_pad))

        import jax
        f_p = jax.jit(lambda x: ops.pjds_matvec(pdev, x))
        f_e = jax.jit(lambda x: ops.ell_matvec(edev, x))
        t_p = time_fn(f_p, xp)
        t_e = time_fn(f_e, xe)
        gf_p = 2 * m.nnz / t_p / 1e9
        gf_e = 2 * m.nnz / t_e / 1e9

        # model-predicted TPU v5e spMVM GF/s (DP) at the two alpha bounds
        lo_a, hi_a = PM.alpha_range(m.n_nzr)
        gf_best = PM.TPU_V5E.hbm_bw / PM.code_balance(lo_a, m.n_nzr) / 1e9
        gf_worst = PM.TPU_V5E.hbm_bw / PM.code_balance(hi_a, m.n_nzr) / 1e9

        rows.append(dict(
            name=name, n=n, nnz=m.nnz, n_nzr=round(m.n_nzr, 1),
            reduction_pct=round(100 * red, 1),
            cpu_pjds_gfs=round(gf_p, 3), cpu_ellr_gfs=round(gf_e, 3),
            pjds_vs_ellr=round(gf_p / gf_e, 2),
            tpu_pred_gfs_best=round(gf_best, 1),
            tpu_pred_gfs_worst=round(gf_worst, 1),
            us_per_call=t_p * 1e6,
        ))
        if print_rows:
            r = rows[-1]
            print(csv_row(
                f"table1_{name}", r["us_per_call"],
                f"reduction={r['reduction_pct']}% "
                f"pjds/ellr={r['pjds_vs_ellr']} "
                f"tpu_pred={r['tpu_pred_gfs_worst']}-{r['tpu_pred_gfs_best']}GF/s"))
    return rows


# ---------------------------------------------------------------------------
# Corpus format sweep -> BENCH_formats.json  (``run.py --only formats``)
# ---------------------------------------------------------------------------

FORMATS = ("csr", "ellpack_r", "pjds", "sell", "cmrs")
MAX_DISPATCH_LOSS = 1.05    # dispatch may never pick a measured >5% loser
MAX_REORDER_LOSS = 1.05     # reorder="auto" may never lose >5% wall time


def _interleaved_times(fns: dict, rounds: int = 5, iters: int = 3,
                       warmup: int = 2) -> dict:
    """Min-of-round-medians for N prepared candidates, all sides
    interleaved inside every round (the ``tune.measure.ab_compare``
    drift story, generalized from 2 sides to N)."""
    import jax
    from repro.tune.measure import median_seconds
    for f in fns.values():
        for _ in range(warmup):
            jax.block_until_ready(f())
    best = {k: float("inf") for k in fns}
    keys = list(fns)
    for r in range(rounds):
        order = keys if r % 2 == 0 else keys[::-1]
        for k in order:
            best[k] = min(best[k], median_seconds(fns[k], warmup=0,
                                                  iters=iters))
    return best


def run_corpus(print_rows=True):
    """Format win-rate table over the on-disk ``.mtx`` corpus, with
    three REGRESSION GUARDS (SystemExit -> the tier-2 CI step fails):

    * the corpus round-trips losslessly through ``io_mm`` (generation
      itself re-reads every file via ``load_mm``);
    * dispatch never picks a measured >5% loser among the alternatives
      it considered: the MEASURED dispatch path (``tune="auto"``, a
      fresh cache) is re-timed inside the same interleaved sweep as the
      static pick it replaces and may not lose >5% to it (the tuner's
      prune keeps the heuristic in the measured set, so this can only
      fail by noise or a real dispatch bug); the full-sweep-best guard
      for the static pick runs only when the measurement backend is the
      compiled kernel (TPU) — the pricing targets that hardware, so on
      the ref backend the per-format times are recorded in the rows
      (the win-rate table) but the model pick is not guarded against
      them;
    * ``reorder="auto"`` never loses wall time to ``reorder="off"`` on
      the shuffled banded matrix.  Single-device the model must DECLINE
      the permutation (guarded), which makes the two builds
      bit-identical — asserted on the stored streams, which implies
      equal wall time without timing two identical jitted programs
      against each other (their measured delta is pure harness noise
      at ~20us/call).  The >5% timed guard runs only when a
      permutation was actually applied (TPU-scale meshes).  The
      RCM-permuted banded partition additionally must ship no more
      halo bytes per device than the unreordered one (deterministic,
      host-side).
    """
    import pathlib
    import tempfile

    import jax
    from benchmarks import corpus
    from repro import tune as T
    from repro.core import dist_spmv as D
    from repro.core.reorder import preprocess
    from repro.tune.measure import measurement_backend
    from .common import write_bench_json

    rows = []
    cache = T.TuneCache(
        pathlib.Path(tempfile.mkdtemp(prefix="bench_formats_")) / "c.json")
    mats = corpus.load()                 # lossless-round-trip guard inside
    for name, m in mats.items():
        orig = corpus.make(name)
        if not (np.array_equal(m.data, orig.data)
                and np.array_equal(m.indices, orig.indices)
                and np.array_equal(m.indptr, orig.indptr)):
            raise SystemExit(
                f"REGRESSION: corpus .mtx round-trip lossy for {name!r}")

        backend = measurement_backend()
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.standard_normal(m.shape[1]).astype(np.float32))
        tuned = T.autotune(m, cache=cache, warmup=2, iters=5).best
        fns = {}
        for fmt in FORMATS:
            sd = ops.as_device(m, fmt)
            fns[fmt] = (lambda f, v: (lambda: f(v)))(
                jax.jit(lambda v, s=sd: s.matvec(v, backend=backend)), x)
        sd_t = ops.as_device(m, **tuned.build_kwargs())
        fns["tuned"] = (lambda f, v: (lambda: f(v)))(
            jax.jit(lambda v, s=sd_t: s.matvec(v, backend=backend)), x)
        pick = ops.select_format(m, diag_align=16,
                                 x_tiles=ops.choose_x_tiles(m.shape[1], 4))
        times = _interleaved_times(fns)
        fmt_times = {k: v for k, v in times.items() if k != "tuned"}
        best_fmt = min(fmt_times, key=fmt_times.get)
        if times["tuned"] > MAX_DISPATCH_LOSS * fmt_times[pick]:
            raise SystemExit(
                f"REGRESSION: measured dispatch (tuned={tuned.label()}) on "
                f"{name!r} is a "
                f"{times['tuned'] / fmt_times[pick]:.2f}x loser vs the "
                f"static pick {pick!r} (guard: {MAX_DISPATCH_LOSS}x)")
        if backend == "kernel" and \
                fmt_times[pick] > MAX_DISPATCH_LOSS * fmt_times[best_fmt]:
            raise SystemExit(
                f"REGRESSION: static dispatch picked {pick!r} on {name!r} "
                f"but {best_fmt!r} measured "
                f"{fmt_times[pick] / fmt_times[best_fmt]:.2f}x faster "
                f"(guard: {MAX_DISPATCH_LOSS}x)")
        row = dict(name=name, n=m.shape[0], nnz=m.nnz, pick=pick,
                   tuned=tuned.label(), measured_best=best_fmt,
                   us_per_call=times["tuned"] * 1e6,
                   **{f"us_{f}": round(t * 1e6, 2) for f, t in times.items()})
        rows.append(row)
        if print_rows:
            print(csv_row(f"formats_{name}", row["us_per_call"],
                          f"pick={pick} measured_best={best_fmt} "
                          f"tuned={tuned.fmt}"))

    # -- reorder guards on the shuffled banded matrix ----------------------
    mb = mats["banded"]
    # Single-device there is no halo to save, only the permute sandwich
    # to pay: the calibrated model must DECLINE (the acceptance
    # criterion that reorder="auto" only applies on a predicted win).
    pp1 = preprocess(mb, reorder="auto", value_bytes=4)
    if pp1.applied:
        raise SystemExit(
            f"REGRESSION: reorder='auto' applied RCM single-device on the "
            f"banded matrix ({pp1.reason}) — no halo exists to pay for "
            f"the permute sandwich")
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(mb.shape[1]).astype(np.float32))
    backend = measurement_backend()
    sd_off = ops.as_device(mb, reorder="off")
    sd_auto = ops.as_device(mb, reorder="auto")
    fns = {}
    for tag, sd in (("off", sd_off), ("auto", sd_auto)):
        fns[tag] = (lambda f, v: (lambda: f(v)))(
            jax.jit(lambda v, s=sd: s.matvec(v, backend=backend)), x)
    t = _interleaved_times(fns)
    if sd_auto.pre_perm is None:
        # Declined -> the builds must be bit-identical (equal wall time
        # by construction; timing two identical programs only measures
        # harness noise).
        if sd_auto.fmt != sd_off.fmt or not all(
                np.array_equal(np.asarray(a), np.asarray(b))
                for a, b in zip(jax.tree.leaves(sd_auto.dev),
                                jax.tree.leaves(sd_off.dev))):
            raise SystemExit(
                "REGRESSION: reorder='auto' declined the permutation but "
                "built a different device operand than reorder='off'")
    elif t["auto"] > MAX_REORDER_LOSS * t["off"]:
        raise SystemExit(
            f"REGRESSION: reorder='auto' lost "
            f"{t['auto'] / t['off']:.2f}x vs 'off' on the banded matrix "
            f"(guard: {MAX_REORDER_LOSS}x) — the pricing model applied a "
            f"losing permutation")

    pp = preprocess(mb, reorder="rcm")
    n_dev = 8
    cb_off = D.partition_csr(mb, n_dev).comm_bytes_per_device(value_bytes=4)
    cb_on = D.partition_csr(pp.matrix, n_dev).comm_bytes_per_device(
        value_bytes=4)
    if cb_on > cb_off:
        raise SystemExit(
            f"REGRESSION: RCM-reordered banded partition ships MORE halo "
            f"bytes ({cb_on} > {cb_off}) at {n_dev} devices")
    rows.append(dict(name="banded_reorder", us_per_call=t["auto"] * 1e6,
                     us_off=round(t["off"] * 1e6, 2),
                     us_auto=round(t["auto"] * 1e6, 2),
                     bw_before=pp.bandwidth_before, bw_after=pp.bandwidth_after,
                     comm_bytes_off=cb_off, comm_bytes_on=cb_on))
    if print_rows:
        print(csv_row("formats_banded_reorder", t["auto"] * 1e6,
                      f"auto/off={t['auto'] / t['off']:.3f} "
                      f"bw={pp.bandwidth_before}->{pp.bandwidth_after} "
                      f"comm={cb_off}->{cb_on}B"))

    write_bench_json("formats", rows)
    return rows


if __name__ == "__main__":
    run()
    run_corpus()
