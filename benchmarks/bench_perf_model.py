"""Paper Eq. 1-4: code-balance + link-transfer threshold tables, for the
paper's Fermi/PCIe numbers (validating against the paper's own derived
values) and retargeted to TPU v5e HBM/ICI."""
from __future__ import annotations

from repro.core import perf_model as PM
from .common import csv_row


def run(print_rows=True):
    rows = []
    # paper hardware: B_GPU ~ 91 GB/s (ECC on), PCIe ~ 5 GB/s -> ratio ~ 18-20
    cases = [
        ("fermi", 91e9, 5e9),
        ("tpu_v5e_ici", PM.TPU_V5E.hbm_bw, PM.TPU_V5E.ici_bw),
        ("tpu_v5e_dcn", PM.TPU_V5E.hbm_bw, 12.5e9),  # pod-to-pod per-chip
    ]
    for name, dev, link in cases:
        for alpha in (0.05, 1.0):
            up = PM.n_nzr_upper_for_link_penalty(dev, link, alpha)
            lo = PM.n_nzr_lower_for_link_penalty(dev, link, alpha)
            rows.append(dict(hw=name, alpha=alpha,
                             n_nzr_50pct_penalty=round(up, 1),
                             n_nzr_10pct_penalty=round(lo, 1)))
            if print_rows:
                print(csv_row(
                    f"eq34_{name}_a{alpha}", 0.0,
                    f"link-dominated below N_nzr={up:.0f}; "
                    f"<10% penalty above N_nzr={lo:.0f}"))
    # Eq.1 code balance for each test matrix's N_nzr
    for n_nzr in (7, 15, 123, 144, 315):
        lo_a, hi_a = PM.alpha_range(n_nzr)
        b_best = PM.code_balance(lo_a, n_nzr)
        b_worst = PM.code_balance(hi_a, n_nzr)
        rows.append(dict(hw="eq1", n_nzr=n_nzr, b_best=round(b_best, 2),
                         b_worst=round(b_worst, 2)))
        if print_rows:
            print(csv_row(f"eq1_nnzr{n_nzr}", 0.0,
                          f"B_W^DP in [{b_best:.2f}, {b_worst:.2f}] B/F"))
    return rows


if __name__ == "__main__":
    run()
