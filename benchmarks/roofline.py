"""Deliverable (g): three-term roofline per (arch x shape) from the
dry-run artifacts in experiments/dryrun/single/.

    compute    = HLO_FLOPs(global)      / (chips * peak_FLOP/s)
    memory     = HLO_bytes(global)      / (chips * HBM_bw)
    collective = collective_bytes(glob) / (chips * link_bw)

Dry-run cost numbers are PER-DEVICE (the partitioned module), so global
= per_device * chips; the two 'chips' cancel and each term is simply
per_device_quantity / per_chip_rate.  MODEL_FLOPS = 6*N*D (dense) or
6*N_active*D (MoE) for the useful-compute ratio.  ``hlo_bytes`` comes
from HloCostAnalysis "bytes accessed", which counts every op's operands:
an UPPER bound on HBM traffic (fusion-aware but DUS-pessimistic); the
memory term is therefore conservative and flagged as such.

Usage: python -m benchmarks.roofline [--dir experiments/dryrun/single]
writes experiments/roofline.md + .json and prints the CSV.

``--solve-json BENCH_solve.json`` appends a solver-iteration section
from ``benchmarks/bench_solve.py``'s artifact: each row's measured
seconds per iteration against the roofline of its FULL per-iteration
traffic (spMV streams plus carrier-vector passes — the bytes this
harness used to omit when it priced an iteration as one spMVM).
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from repro.core import perf_model as PM


def load_cells(d: str):
    cells = []
    for f in sorted(glob.glob(os.path.join(d, "*.json"))):
        with open(f) as fh:
            cells.append(json.load(fh))
    return cells


def analyze(rec: dict) -> dict | None:
    if rec.get("status") != "ok" or "cost" not in rec:
        return None
    c = rec["cost"]
    # cost numbers are per-device; roofline terms divide by per-chip rates
    r = PM.roofline_terms(
        hlo_flops=c["flops"], hlo_bytes=c["bytes"],
        collective_bytes=c["collective_bytes"], chips=1)
    tokens = rec["tokens"]
    chips = rec["chips"]
    n = rec["n_active_params"]
    kind_mult = 6 if "train" in rec["shape"] else 2
    model_flops = kind_mult * n * tokens / chips    # per device
    bound = r.bound_s
    useful = model_flops / PM.TPU_V5E.peak_flops    # ideal compute-only time
    return dict(
        arch=rec["arch"], shape=rec["shape"],
        compute_s=r.compute_s, memory_s=r.memory_s,
        collective_s=r.collective_s, dominant=r.dominant,
        bound_s=bound,
        model_flops_ratio=model_flops / max(c["flops"], 1),
        roofline_fraction=useful / bound if bound else 0.0,
        temp_gib=rec["memory"].get("temp_size_in_bytes", 0) / 2 ** 30,
        arg_gib=rec["memory"].get("argument_size_in_bytes", 0) / 2 ** 30,
        compile_s=rec["compile_s"],
    )


def solve_rows(path: str) -> list[dict]:
    """Solver-iteration roofline rows from a BENCH_solve.json artifact.
    ``bytes_per_iter`` in the artifact already includes the carrier
    passes (``perf_model.solver_iteration_bytes``); the roofline here is
    that traffic over the spec HBM bandwidth, and ``effective GB/s`` is
    what the measured iteration actually streamed."""
    with open(path) as f:
        payload = json.load(f)
    out = []
    for r in payload["rows"]:
        if "seconds_per_iter" not in r or "bytes_per_iter" not in r:
            continue                      # convergence rows have no rate
        t, by = r["seconds_per_iter"], r["bytes_per_iter"]
        memory_s = by / PM.TPU_V5E.hbm_bw
        out.append(dict(
            name=r["name"], matrix=r["matrix"], method=r["method"],
            strategy=r["strategy"], measured_s=t, bytes_per_iter=by,
            memory_s=memory_s,
            effective_gbs=by / t / 1e9 if t else 0.0,
            roofline_fraction=memory_s / t if t else 0.0))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun/single")
    ap.add_argument("--out", default="experiments/roofline")
    ap.add_argument("--solve-json", default=None,
                    help="BENCH_solve.json artifact to append a "
                         "solver-iteration section from")
    args = ap.parse_args()
    cells = load_cells(args.dir)
    rows, skipped, errors = [], [], []
    for rec in cells:
        if rec.get("status") == "skipped":
            skipped.append(rec)
            continue
        if rec.get("status") == "error":
            errors.append(rec)
            continue
        a = analyze(rec)
        if a:
            rows.append(a)
    rows.sort(key=lambda r: (r["arch"], r["shape"]))

    hdr = ("| arch | shape | compute s | memory s | collective s | dominant "
           "| 6ND/HLO | roofline frac | temp GiB/dev |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.4f} "
            f"| {r['memory_s']:.4f} | {r['collective_s']:.4f} "
            f"| **{r['dominant']}** | {r['model_flops_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} | {r['temp_gib']:.2f} |")
    for s in skipped:
        lines.append(f"| {s['arch']} | {s['shape']} | — | — | — | skipped: "
                     f"{s['reason']} | — | — | — |")
    srows = solve_rows(args.solve_json) if args.solve_json else []
    if srows:
        lines += ["", "## Solver iterations (spMV + carrier traffic)", "",
                  "| row | bytes/iter | measured us | roofline us "
                  "| eff GB/s | frac |",
                  "|" + "---|" * 6]
        for r in srows:
            lines.append(
                f"| {r['name']} | {r['bytes_per_iter']:.3e} "
                f"| {r['measured_s'] * 1e6:.1f} "
                f"| {r['memory_s'] * 1e6:.1f} "
                f"| {r['effective_gbs']:.2f} "
                f"| {r['roofline_fraction']:.3f} |")
    md = "\n".join(lines)
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    with open(args.out + ".md", "w") as f:
        f.write(md + "\n")
    with open(args.out + ".json", "w") as f:
        json.dump(rows + srows, f, indent=1)
    print(md)
    if errors:
        print(f"\n# {len(errors)} cells errored:")
        for e in errors:
            print(f"#  {e['arch']}/{e['shape']}: {e.get('error','')[:120]}")
    return rows


if __name__ == "__main__":
    main()
