"""Paper Fig. 5: strong scaling of distributed spMVM, three comm modes.

Runs in a subprocess with 8 host devices (this process keeps 1 device)
and measures wall-time per spMVM for DLR1/UHBR analogues on 1/2/4/8
devices x {vector, naive, overlap}.  Host-CPU collectives through shared
memory are not an ICI fabric, so (as in the paper's own CPU-vs-GPU
caveats) the MODE-vs-MODE and scaling TRENDS are the comparable
quantities.  Alongside, the paper's performance model predicts the
strong-scaling curve for the TPU v5e target out to 32 chips: T(P) =
max(T_mvm/P, T_halo) for task mode, sum for vector mode (paper §3.1:
"the possible performance benefit can be at most a factor of two").

:func:`scaling_curves` additionally measures strong AND weak
parallel-efficiency curves across comm configs — bulk-synchronous
full-slice 1-D, gathered/overlap 1-D, and the 2-D grid — whose rows
``bench_dist`` folds into ``BENCH_dist.json`` (the scaling-trajectory
CI artifact)."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from repro.core import perf_model as PM
from .common import csv_row

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import matrices as M, dist_spmv as D
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh

    out = []
    rng = np.random.default_rng(0)
    for name, scale in [("DLR1", 0.15), ("UHBR", 0.01)]:
        m = M.make_test_matrix(name, scale=scale)
        for n_dev in (1, 2, 4, 8):
            mesh = make_host_mesh(n_dev)
            dist = D.partition_csr(m, n_dev, b_r=128)
            x = np.zeros(dist.n_global_pad, np.float32)
            x[:m.n_rows] = rng.standard_normal(m.n_rows)
            xj = jax.device_put(jnp.asarray(x),
                                jax.NamedSharding(mesh, P("data")))
            for mode in ("vector", "naive", "overlap"):
                mv = jax.jit(dist_operator(dist, mesh, mode=mode).matvec)
                for _ in range(3):
                    jax.block_until_ready(mv(xj))
                ts = []
                for _ in range(10):
                    t0 = time.perf_counter()
                    jax.block_until_ready(mv(xj))
                    ts.append(time.perf_counter() - t0)
                t = float(np.median(ts))
                out.append(dict(matrix=name, n_dev=n_dev, mode=mode,
                                t_us=t * 1e6,
                                gfs=2 * m.nnz / t / 1e9,
                                halo_w=dist.halo_w, nnz=int(m.nnz)))
    print("RESULTS " + json.dumps(out))
""")


def _measured():
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


_CURVES_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import formats as F, dist_spmv as D
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh

    rng = np.random.default_rng(0)

    def banded(n, reach, stride=8):
        a = np.zeros((n, n), np.float32)
        i = np.arange(n)
        a[i, i] = 4.0
        a[i[:-1], i[:-1] + 1] = -1.0
        a[i[1:], i[1:] - 1] = -1.0
        far = i[::stride]
        for sgn in (+1, -1):
            tgt = far + sgn * reach
            ok = (tgt >= 0) & (tgt < n)
            a[far[ok], tgt[ok]] = -0.5
        return F.csr_from_dense(a)

    def timed(fn, arg, warmup=3, iters=10):
        for _ in range(warmup):
            jax.block_until_ready(fn(arg))
        ts = []
        for _ in range(iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn(arg))
            ts.append(time.perf_counter() - t0)
        return float(np.median(ts))

    def square_grid(p):
        g = max(d for d in range(1, int(np.sqrt(p)) + 1) if p % d == 0)
        return None if g == 1 else (g, p // g)

    def measure(m, n_dev, grid, halo, mode):
        mesh = make_host_mesh(n_dev)
        dist = D.partition_csr(m, n_dev, b_r=128, grid=grid)
        x = np.zeros(dist.n_global_pad, np.float32)
        x[:m.n_rows] = rng.standard_normal(m.n_rows)
        xj = jax.device_put(jnp.asarray(x),
                            jax.NamedSharding(mesh, P("data")))
        mv = jax.jit(dist_operator(dist, mesh, mode=mode, halo=halo).matvec)
        return timed(mv, xj), dist

    out = []
    b_r = 128
    configs = [("bulk_full_1d", "full", "vector", False),
               ("gathered_overlap_1d", "gathered", "overlap", False),
               ("gathered_overlap_2d", "gathered", "overlap", True)]

    # strong scaling: fixed problem, growing mesh
    n_strong = 8 * b_r * 2
    m_strong = banded(n_strong, reach=384)
    base = {}
    for label, halo, mode, use2d in configs:
        for p in (1, 2, 4, 8):
            grid = square_grid(p) if use2d else None
            if use2d and grid is None and p > 1:
                continue                   # 2-D needs a composite mesh
            t, dist = measure(m_strong, p, grid, halo, mode)
            if p == 1:
                base[label] = t
            out.append(dict(kind="strong_scaling", config=label, n_dev=p,
                            grid=grid, halo=halo, mode=mode, t_us=t * 1e6,
                            halo_w=int(dist.halo_w),
                            efficiency=base[label] / (p * t)))

    # weak scaling: constant rows/device, growing mesh AND problem
    n_base = b_r * 2
    for label, halo, mode, use2d in configs:
        for p in (1, 2, 4, 8):
            grid = square_grid(p) if use2d else None
            if use2d and grid is None and p > 1:
                continue
            m = banded(n_base * p, reach=min(384, n_base * p // 2))
            t, dist = measure(m, p, grid, halo, mode)
            if p == 1:
                base[label] = t
            out.append(dict(kind="weak_scaling", config=label, n_dev=p,
                            grid=grid, halo=halo, mode=mode, t_us=t * 1e6,
                            halo_w=int(dist.halo_w),
                            efficiency=base[label] / t))
    print("RESULTS " + json.dumps(out))
""")


def scaling_curves(print_rows=True):
    """Measured strong/weak parallel-efficiency rows (see module
    docstring); consumed by ``bench_dist`` into ``BENCH_dist.json``."""
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _CURVES_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULTS ")][-1]
    rows = json.loads(line[len("RESULTS "):])
    if print_rows:
        for row in rows:
            print(csv_row(
                f"{row['kind']}_{row['config']}_p{row['n_dev']}",
                row["t_us"], f"eff={row['efficiency']:.2f} "
                f"halo_w={row['halo_w']}"))
    return rows


def _model_curve(n_rows, n_nzr, chips=(1, 2, 4, 8, 16, 32)):
    """TPU v5e predicted strong scaling (DP), task vs vector mode."""
    spec = PM.TPU_V5E
    rows = []
    for p in chips:
        t_mvm = PM.t_mvm(n_rows / p, n_nzr, alpha=1 / n_nzr,
                         dev_bw=spec.hbm_bw)
        t_halo = PM.t_link(n_rows / p, spec.ici_bw)  # halo ~ slice-sized
        task = max(t_mvm, t_halo)
        vector = t_mvm + t_halo
        rows.append(dict(chips=p,
                         task_gfs=2 * n_rows * n_nzr / task / 1e9,
                         vector_gfs=2 * n_rows * n_nzr / vector / 1e9))
    return rows


def run(print_rows=True):
    rows = {"measured": _measured(),
            "model_dlr1": _model_curve(280_000, 144),
            "model_uhbr": _model_curve(4_500_000, 123)}
    if print_rows:
        for r in rows["measured"]:
            print(csv_row(
                f"fig5_{r['matrix']}_p{r['n_dev']}_{r['mode']}",
                r["t_us"], f"{r['gfs']:.2f}GF/s halo_w={r['halo_w']}"))
        for key in ("model_dlr1", "model_uhbr"):
            for r in rows[key]:
                print(csv_row(
                    f"fig5_model_{key[6:]}_p{r['chips']}", 0.0,
                    f"task={r['task_gfs']:.0f}GF/s "
                    f"vector={r['vector_gfs']:.0f}GF/s"))
    return rows


if __name__ == "__main__":
    run()
