"""Paper Fig. 5: strong scaling of distributed spMVM, three comm modes.

Runs in a subprocess with 8 host devices (this process keeps 1 device)
and measures wall-time per spMVM for DLR1/UHBR analogues on 1/2/4/8
devices x {vector, naive, overlap}.  Host-CPU collectives through shared
memory are not an ICI fabric, so (as in the paper's own CPU-vs-GPU
caveats) the MODE-vs-MODE and scaling TRENDS are the comparable
quantities.  Alongside, the paper's performance model predicts the
strong-scaling curve for the TPU v5e target out to 32 chips: T(P) =
max(T_mvm/P, T_halo) for task mode, sum for vector mode (paper §3.1:
"the possible performance benefit can be at most a factor of two")."""
from __future__ import annotations

import json
import os
import subprocess
import sys
import textwrap

from repro.core import perf_model as PM
from .common import csv_row

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, time
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import matrices as M, dist_spmv as D
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh

    out = []
    rng = np.random.default_rng(0)
    for name, scale in [("DLR1", 0.15), ("UHBR", 0.01)]:
        m = M.make_test_matrix(name, scale=scale)
        for n_dev in (1, 2, 4, 8):
            mesh = make_host_mesh(n_dev)
            dist = D.partition_csr(m, n_dev, b_r=128)
            x = np.zeros(dist.n_global_pad, np.float32)
            x[:m.n_rows] = rng.standard_normal(m.n_rows)
            xj = jax.device_put(jnp.asarray(x),
                                jax.NamedSharding(mesh, P("data")))
            for mode in ("vector", "naive", "overlap"):
                mv = jax.jit(dist_operator(dist, mesh, mode=mode).matvec)
                for _ in range(3):
                    jax.block_until_ready(mv(xj))
                ts = []
                for _ in range(10):
                    t0 = time.perf_counter()
                    jax.block_until_ready(mv(xj))
                    ts.append(time.perf_counter() - t0)
                t = float(np.median(ts))
                out.append(dict(matrix=name, n_dev=n_dev, mode=mode,
                                t_us=t * 1e6,
                                gfs=2 * m.nnz / t / 1e9,
                                halo_w=dist.halo_w, nnz=int(m.nnz)))
    print("RESULTS " + json.dumps(out))
""")


def _measured():
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    if r.returncode != 0:
        raise RuntimeError(r.stderr[-2000:])
    line = [l for l in r.stdout.splitlines() if l.startswith("RESULTS ")][-1]
    return json.loads(line[len("RESULTS "):])


def _model_curve(n_rows, n_nzr, chips=(1, 2, 4, 8, 16, 32)):
    """TPU v5e predicted strong scaling (DP), task vs vector mode."""
    spec = PM.TPU_V5E
    rows = []
    for p in chips:
        t_mvm = PM.t_mvm(n_rows / p, n_nzr, alpha=1 / n_nzr,
                         dev_bw=spec.hbm_bw)
        t_halo = PM.t_link(n_rows / p, spec.ici_bw)  # halo ~ slice-sized
        task = max(t_mvm, t_halo)
        vector = t_mvm + t_halo
        rows.append(dict(chips=p,
                         task_gfs=2 * n_rows * n_nzr / task / 1e9,
                         vector_gfs=2 * n_rows * n_nzr / vector / 1e9))
    return rows


def run(print_rows=True):
    rows = {"measured": _measured(),
            "model_dlr1": _model_curve(280_000, 144),
            "model_uhbr": _model_curve(4_500_000, 123)}
    if print_rows:
        for r in rows["measured"]:
            print(csv_row(
                f"fig5_{r['matrix']}_p{r['n_dev']}_{r['mode']}",
                r["t_us"], f"{r['gfs']:.2f}GF/s halo_w={r['halo_w']}"))
        for key in ("model_dlr1", "model_uhbr"):
            for r in rows[key]:
                print(csv_row(
                    f"fig5_model_{key[6:]}_p{r['chips']}", 0.0,
                    f"task={r['task_gfs']:.0f}GF/s "
                    f"vector={r['vector_gfs']:.0f}GF/s"))
    return rows


if __name__ == "__main__":
    run()
