"""Solver-iteration benchmark: composed-launch vs fused vs bf16-refined.

Three ways to run the same Krylov iteration, timed per iteration on the
paper's two application matrices (uhbr, samg at bench scale):

* ``composed_launch`` — the scipy-style driver: one jitted STEP call
  per iteration from Python, with a host residual sync each step.  This
  is the baseline an application using the pre-``repro.solve`` pieces
  naturally writes, and the one the fused path is judged against.
* ``fused`` — ``repro.solve``'s fused strategy: the whole solve is one
  compiled ``while_loop`` whose body is the fused spMV+dots pass
  (``kernels.fused_iter``); no per-iteration dispatch, no per-iteration
  host sync, no standalone reduction passes.
* ``fused+bf16`` — the fused iteration over the bf16+int16 operand
  (0.50x bytes/nnz) inside mixed-precision refinement; per-iteration
  time shows the storage-bandwidth win, and a separate convergence row
  shows refinement still reaching the f32 tolerance.

Each row also carries the perf model's bytes/iteration
(``perf_model.solver_iteration_bytes`` — spMV streams PLUS the carrier
vector passes) so predicted-vs-measured stays honest.

Regression guards (SystemExit):
* fused must be >= MIN_FUSED_SPEEDUP x composed_launch per iteration on
  at least one matrix;
* bf16-inner refinement must reach REFINE_TOL true relative residual in
  <= MAX_REFINED_ITER_RATIO x the f32 iteration count (on the SPD
  matrix, where CG converges);
* the degradation ladder's happy path (``repro.solve`` with
  ``fallback="auto"``, primary rung succeeds) must stay within
  MAX_LADDER_OVERHEAD of the bare fused solve it wraps — the
  robustness layer is dispatch bookkeeping, not a second solve.
"""
from __future__ import annotations

import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import api
from repro.core import formats as F
from repro.core import matrices as M
from repro.core import perf_model as PM
from repro.core import solvers as S
from repro.core.operator import operator

from .common import csv_row, seeded_rng, write_bench_json

PROBE_ITERS = 100          # fixed-length probes: every strategy runs the same
                           # count, long enough to amortise both paths' fixed
                           # ends (compile-cache lookup + the fused driver's
                           # certification pass) into steady-state per-iter cost
TIME_ROUNDS = 3            # median-of-n probe timings
MIN_FUSED_SPEEDUP = 1.3    # per-iteration, vs composed_launch, >= 1 matrix
REFINE_TOL = 1e-6
MAX_REFINED_ITER_RATIO = 1.5
MAX_LADDER_OVERHEAD = 0.02   # ladder happy path vs bare fused, fractional
LADDER_PROBE_ITERS = 300     # the ladder's cost is FIXED per solve (dispatch
                             # + status sync + certification bookkeeping, no
                             # per-iteration term) — probe at a realistic
                             # solve length so the budget reads as steady
                             # state, not as a fixed cost over a toy solve

# samg is sized to a strong-scaled PER-DEVICE partition — 3.4M rows
# over the O(1000)-GPU scaling runs the paper targets leaves ~1k rows
# per device, the regime where iteration cost is launch/sync-bound and
# fusing the launches is the whole point.  uhbr stays at the usual
# bench scale as the compute-bound contrast, where fusion is judged on
# bytes alone and dispatch savings wash out.
_MATRICES = (
    ("samg", lambda: M.samg(scale=0.00025), "cg"),      # SPD -> CG
    ("uhbr", lambda: M.uhbr(scale=0.003), "bicgstab"),  # nonsymmetric
)


@functools.partial(jax.jit, static_argnums=(0,))
def _cg_step(matvec, x, r, p, rs):
    ap = matvec(p)
    alpha = rs / jnp.vdot(p, ap)
    x = x + alpha * p
    r = r - alpha * ap
    rs_new = jnp.vdot(r, r)
    p = r + (rs_new / rs) * p
    return x, r, p, rs_new


@functools.partial(jax.jit, static_argnums=(0,))
def _bicgstab_step(matvec, x, r, rhat, p, v, rho, alpha, omega):
    tiny = jnp.asarray(1e-30, r.dtype)
    safe = lambda d: jnp.where(jnp.abs(d) > tiny, d, tiny)
    rho_new = jnp.vdot(rhat, r)
    beta = (rho_new / safe(rho)) * (alpha / safe(omega))
    p = r + beta * (p - omega * v)
    v = matvec(p)
    alpha = rho_new / safe(jnp.vdot(rhat, v))
    s = r - alpha * v
    t = matvec(s)
    omega = jnp.vdot(t, s) / safe(jnp.vdot(t, t))
    x = x + alpha * p + omega * s
    r = s - omega * t
    return x, r, p, v, rho_new, alpha, omega, jnp.vdot(r, r)


def composed_launch_solve(op, b, method, maxiter, tol):
    """The per-step dispatch baseline: one jitted step per iteration
    driven from Python, residual synced to the host every step (what a
    scipy-style caller does with the composed pieces)."""
    mv = S._matvec_of(op)
    b2 = max(float(jnp.vdot(b, b)), 1e-30)
    x = jnp.zeros_like(b)
    r = b
    k = 0
    # tol <= 0 is the fixed-length probe contract (solvers._not_done):
    # the residual is still synced to the host every step — that IS the
    # per-iteration cost being measured — but never ends the loop early.
    if method == "cg":
        p, rs = r, jnp.vdot(r, r)
        while k < maxiter:
            if float(rs) / b2 <= tol ** 2 and tol > 0.0:
                break
            x, r, p, rs = _cg_step(mv, x, r, p, rs)
            k += 1
    else:
        rhat = r
        p = v = jnp.zeros_like(b)
        one = jnp.asarray(1.0, b.dtype)
        rho = alpha = omega = one
        rs = jnp.vdot(r, r)
        while k < maxiter:
            if float(rs) / b2 <= tol ** 2 and tol > 0.0:
                break
            x, r, p, v, rho, alpha, omega, rs = _bicgstab_step(
                mv, x, r, rhat, p, v, rho, alpha, omega)
            k += 1
    jax.block_until_ready(x)
    return x, k, float(np.sqrt(float(rs) / b2))


def _interleaved_seconds(fns, rounds=TIME_ROUNDS):
    """Per-probe best-of-rounds wall-clock, with the probes interleaved
    round by round (order rotated each round) so background-load drift
    lands on every side equally — same discipline as
    ``tune.measure.ab_compare``."""
    for fn in fns:                       # warmup: compile + caches
        fn()
    best = [float("inf")] * len(fns)
    for r in range(rounds):
        order = list(range(len(fns)))
        order = order[r % len(fns):] + order[:r % len(fns)]
        for i in order:
            t0 = time.perf_counter()
            fns[i]()
            best[i] = min(best[i], time.perf_counter() - t0)
    return best


def _iteration_bytes(m, op, method, strategy):
    vb = jnp.dtype(op.dev.value_dtype).itemsize
    ib = jnp.dtype(op.dev.index_dtype).itemsize
    return PM.solver_iteration_bytes(
        op.dev.storage_elements(), m.n_rows, m.n_nzr, method=method,
        strategy=strategy, value_bytes=vb, index_bytes=ib, vec_bytes=4)


def run(print_rows=True):
    rows = []
    speedups = {}
    for name, make, method in _MATRICES:
        m = make()
        rng = seeded_rng()
        b = jnp.asarray(rng.standard_normal(m.n_rows).astype(np.float32))
        op = operator(m, format="sell", x_tiles=1)
        op_lo = operator(m, format="sell", x_tiles=1,
                         dtype=jnp.bfloat16, index_dtype="auto")

        t_launch, t_fused, t_lo = (
            t / PROBE_ITERS for t in _interleaved_seconds([
                lambda: composed_launch_solve(op, b, method,
                                              PROBE_ITERS, 0.0),
                lambda: jax.block_until_ready(api._one_solve(
                    op, b, method=method, strategy="fused",
                    maxiter=PROBE_ITERS, tol=0.0, precond=None).x),
                lambda: jax.block_until_ready(api._one_solve(
                    op_lo, b, method=method, strategy="fused",
                    maxiter=PROBE_ITERS, tol=0.0, precond=None).x),
            ]))

        speedups[name] = t_launch / t_fused
        for label, t, o, strat in (
                ("composed_launch", t_launch, op, "composed"),
                ("fused", t_fused, op, "fused"),
                ("fused_bf16", t_lo, op_lo, "fused")):
            by = _iteration_bytes(m, o, method, strat)
            rows.append({
                "name": f"solve_{method}_{name}_{label}",
                "us_per_call": t * 1e6,
                "derived": (f"per-iter; bytes/iter={by:.0f} "
                            f"n={m.n_rows} n_nzr={m.n_nzr:.1f}"),
                "seconds_per_iter": t,
                "bytes_per_iter": by,
                "matrix": name, "method": method, "strategy": label,
            })
            if print_rows:
                print(csv_row(rows[-1]["name"], t * 1e6,
                              rows[-1]["derived"]))
        print(f"# {name}/{method}: fused speedup vs composed-launch = "
              f"{speedups[name]:.2f}x; bf16 fused = "
              f"{t_launch / t_lo:.2f}x")

    # -- convergence + refinement quality (SPD matrix; CG converges) -------
    name, make, method = _MATRICES[0]
    m = make()
    rng = seeded_rng()
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    bj = jnp.asarray(b)
    t0 = time.perf_counter()
    res_f32 = api.solve(m, bj, method=method, tol=REFINE_TOL,
                        maxiter=3000, tune="off", refine=False)
    t_f32 = time.perf_counter() - t0
    t0 = time.perf_counter()
    res_ref = api.solve(m, bj, method=method, tol=REFINE_TOL,
                        maxiter=3000, tune="off", dtype=jnp.bfloat16,
                        refine="auto")
    t_ref = time.perf_counter() - t0
    d = F.csr_to_dense(m)
    x_ref = np.asarray(res_ref.x)
    true_res = float(np.linalg.norm(d @ x_ref - b) / np.linalg.norm(b))
    it_f32, it_ref = int(res_f32.iters), int(res_ref.iters)
    rows.append({
        "name": f"solve_{method}_{name}_time_to_tol",
        "us_per_call": t_ref * 1e6,
        "derived": (f"refined: {it_ref} inner iters "
                    f"{len(res_ref.info['refine']['rounds'])} rounds "
                    f"true_res={true_res:.2e}; f32: {it_f32} iters "
                    f"{t_f32 * 1e6:.0f}us"),
        "f32_iters": it_f32, "refined_inner_iters": it_ref,
        "refined_true_residual": true_res,
        "f32_seconds": t_f32, "refined_seconds": t_ref,
        "matrix": name, "method": method,
    })
    if print_rows:
        print(csv_row(rows[-1]["name"], t_ref * 1e6, rows[-1]["derived"]))

    # -- ladder happy-path overhead (robustness layer dispatch cost) -------
    # Same fixed-length probe through both doors: bare fused _one_solve
    # vs repro.solve with the ladder armed.  tol=0 keeps both on the
    # probe contract (run to exactly LADDER_PROBE_ITERS, certification
    # pass skipped), so the difference IS the ladder's bookkeeping.
    name, make, method = _MATRICES[0]
    m = make()
    rng = seeded_rng()
    b = jnp.asarray(rng.standard_normal(m.n_rows).astype(np.float32))
    op = operator(m, format="sell", x_tiles=1)
    bare_fn = lambda: jax.block_until_ready(api._one_solve(
        op, b, method=method, strategy="fused",
        maxiter=LADDER_PROBE_ITERS, tol=0.0, precond=None).x)
    ladder_fn = lambda: jax.block_until_ready(api.solve(
        op, b, method=method, maxiter=LADDER_PROBE_ITERS, tol=0.0,
        tune="off", fallback="auto").x)
    bare_fn(); ladder_fn()               # warmup: compile + caches
    # The dispatch delta under test is tens of us on a ~ms-scale probe
    # — independent best-of-N drifts by more than that.  Pair the
    # probes back-to-back each round (shared background load) in
    # RANDOMISED order (a deterministic alternation can phase-lock with
    # periodic background load and bias the delta — measured, not
    # hypothetical), then take the 10%-trimmed mean of the per-round
    # deltas: drift cancels within a pair, outlier rounds drop out.
    order_rng = np.random.default_rng(0)
    samples_bare, samples_ladder = [], []
    for _ in range(150):
        pair = [(bare_fn, samples_bare), (ladder_fn, samples_ladder)]
        if order_rng.integers(2):
            pair.reverse()
        for fn, sink in pair:
            t0 = time.perf_counter()
            fn()
            sink.append(time.perf_counter() - t0)
    t_bare = min(samples_bare)
    t_ladder = min(samples_ladder)
    deltas = sorted(l - b for l, b in zip(samples_ladder, samples_bare))
    trim = len(deltas) // 10
    kept = deltas[trim:len(deltas) - trim]
    ladder_overhead = sum(kept) / len(kept) / t_bare
    rows.append({
        "name": f"solve_{method}_{name}_ladder_happy_path",
        "us_per_call": t_ladder / LADDER_PROBE_ITERS * 1e6,
        "derived": (f"per-iter; overhead vs bare fused = "
                    f"{ladder_overhead * 100:+.2f}% "
                    f"(bare {t_bare / LADDER_PROBE_ITERS * 1e6:.2f}us/iter)"),
        "seconds_per_iter": t_ladder / LADDER_PROBE_ITERS,
        "ladder_overhead": ladder_overhead,
        "matrix": name, "method": method, "strategy": "ladder",
    })
    if print_rows:
        print(csv_row(rows[-1]["name"], rows[-1]["us_per_call"],
                      rows[-1]["derived"]))

    path = write_bench_json("solve", rows)
    print(f"# wrote {path}")

    # -- regression guards --------------------------------------------------
    best = max(speedups.values())
    if best < MIN_FUSED_SPEEDUP:
        raise SystemExit(
            f"REGRESSION: fused iteration only {best:.2f}x over the "
            f"composed-launch baseline (need >= {MIN_FUSED_SPEEDUP}x on "
            f">= 1 matrix; per-matrix: "
            + ", ".join(f"{k}={v:.2f}x" for k, v in speedups.items()) + ")")
    if not res_f32.converged:
        raise SystemExit(
            f"REGRESSION: f32 {method} failed to reach {REFINE_TOL} on "
            f"{name} (residual {float(res_f32.residual):.2e})")
    if true_res > REFINE_TOL:
        raise SystemExit(
            f"REGRESSION: bf16-refined solve missed the f32 target: true "
            f"residual {true_res:.2e} > {REFINE_TOL}")
    if it_ref > MAX_REFINED_ITER_RATIO * max(it_f32, 1):
        raise SystemExit(
            f"REGRESSION: refinement needed {it_ref} inner iterations vs "
            f"{it_f32} f32 iterations "
            f"(> {MAX_REFINED_ITER_RATIO}x budget)")
    if ladder_overhead > MAX_LADDER_OVERHEAD:
        raise SystemExit(
            f"REGRESSION: degradation-ladder happy path adds "
            f"{ladder_overhead * 100:.2f}% over the bare fused solve "
            f"(budget {MAX_LADDER_OVERHEAD * 100:.0f}%)")
    print(f"# guards ok: fused {best:.2f}x >= {MIN_FUSED_SPEEDUP}x; "
          f"refined {it_ref} vs f32 {it_f32} iters, true_res "
          f"{true_res:.1e} <= {REFINE_TOL}")
    return rows


if __name__ == "__main__":
    run()
