"""Multi-tenant solve serving under traffic -> BENCH_serve.json.

Three studies against the serving subsystem (DESIGN.md §12):

* **continuous batching vs sequential** — the SAME request set (several
  tenants, several RHS each) served twice through identical machinery,
  once with ``slots=1`` (every request its own certified solve — what a
  caller who does not batch gets) and once with ``slots=SLOTS`` (the
  scheduler coalesces concurrent requests into block-CG groups).  The
  ratio is the request-queue-sourced spMM amortisation PR 2 measured at
  the kernel level;
* **registry warm-hit tuning cost** — admits run with an INJECTED
  counting ``measure_fn``, so the zero-warmup contract is counted, not
  assumed: cold admits measure, warm admits (fresh registry, same
  persistent cache file) measure exactly zero, and a value swap on a
  resident structure reconverts nothing;
* **latency under Poisson arrivals** — open-loop arrivals across all
  tenants at ~1.2x the measured batched capacity, p50/p99
  queue/solve/total latency and batch occupancy from the scheduler's
  own metrics.

REGRESSION GUARDS (non-zero exit, CI serve-smoke job):

* batched throughput >= MIN_BATCH_SPEEDUP x sequential at an offered
  load of >= 4 concurrent tenants;
* cold admits measure (> 0), warm admits measure EXACTLY zero;
* every request in every study finalizes converged (no failed/error).
"""
from __future__ import annotations

import pathlib
import tempfile
import time

import numpy as np

from repro.core import matrices as M
from repro.serve import OperatorRegistry, SolveRequest, SolveScheduler
from repro.tune.cache import TuneCache

from .common import csv_row, seeded_rng, write_bench_json

SLOTS = 4
REQS_PER_TENANT = 6
MIN_BATCH_SPEEDUP = 1.5
N_ARRIVALS = 32                # Poisson-arrival latency study size
MAXITER = 2000
TOL = 1e-6

# Four tenants, four distinct SPD structures (the offered-load floor
# the throughput guard requires): three 5-point Laplacians at different
# grids plus the paper's SAMG matrix at bench scale.
_TENANTS = (
    ("poisson20", lambda: M.poisson_2d(20, 20)),
    ("poisson24", lambda: M.poisson_2d(24, 24)),
    ("poisson28", lambda: M.poisson_2d(28, 28)),
    ("samg", lambda: M.samg(scale=0.00025)),
)


def _registry(tenants, **kw):
    reg = OperatorRegistry(capacity=len(tenants), tune=kw.pop("tune", "off"),
                           **kw)
    entries = {}
    for name, mk in tenants:
        entries[name] = reg.admit(mk())
    return reg, entries


def _request_set(entries, per_tenant):
    rng = seeded_rng()
    reqs = []
    rid = 0
    for name, e in entries.items():
        for _ in range(per_tenant):
            reqs.append((name, SolveRequest(
                rid=rid, b=rng.standard_normal(e.shape[0])
                .astype(np.float32), tenant=e.key)))
            rid += 1
    return reqs


def _serve_all(sched, reqs):
    t0 = time.perf_counter()
    for _, r in reqs:
        sched.submit(r)
    sched.run_until_drained()
    return time.perf_counter() - t0


def _assert_all_converged(reqs, label):
    bad = [(r.rid, r.status) for _, r in reqs if r.status != "converged"]
    if bad:
        raise SystemExit(f"REGRESSION: {label} left non-converged "
                         f"requests: {bad[:8]}")


def run(print_rows=True):
    rows = []

    # ---- study 1: registry admission cost, counted ----------------------
    calls = {"n": 0}

    def counting_measure(m, c, **kw):
        calls["n"] += 1
        # deterministic fake timing: the guard counts calls, it does not
        # care which candidate wins
        return 1e-3 + 1.0 / (c.b_r * c.chunk_l)

    cache_path = pathlib.Path(
        tempfile.mkdtemp(prefix="bench_serve_")) / "tune_cache.json"
    reg_cold, _ = _registry(_TENANTS, tune="auto",
                            cache=TuneCache(cache_path),
                            measure_fn=counting_measure)
    cold_measures = calls["n"]

    calls["n"] = 0
    reg_warm, warm_entries = _registry(_TENANTS, tune="auto",
                                       cache=TuneCache(cache_path),
                                       measure_fn=counting_measure)
    warm_measures = calls["n"]
    warm_cached = all(e.tune_info["cached"] for e in warm_entries.values())

    # value swap on a resident structure: zero reconversion, zero tuning
    import dataclasses
    m0 = _TENANTS[0][1]()
    m0b = dataclasses.replace(m0, data=(m0.data * 2.0).astype(m0.data.dtype))
    calls["n"] = 0
    e0 = reg_warm.admit(m0b)
    swap_measures = calls["n"]

    rows.append(dict(kind="registry", tenants=len(_TENANTS),
                     cold_measures=cold_measures,
                     warm_measures=warm_measures,
                     warm_cached=warm_cached,
                     swap_measures=swap_measures, swaps=e0.swaps))
    if print_rows:
        print(csv_row("serve_registry_cold", 0.0,
                      f"measures={cold_measures}"))
        print(csv_row("serve_registry_warm", 0.0,
                      f"measures={warm_measures} cached={warm_cached}"))
    if cold_measures <= 0:
        raise SystemExit("REGRESSION: cold registry admission measured "
                         "nothing — the tuning path is not running")
    if warm_measures != 0 or not warm_cached:
        raise SystemExit(
            f"REGRESSION: warm registry admission measured "
            f"{warm_measures} times (want 0, cached={warm_cached}) — the "
            "fingerprint-shared tune cache is broken")
    if swap_measures != 0 or e0.swaps != 1:
        raise SystemExit(
            f"REGRESSION: value swap on a resident structure measured "
            f"{swap_measures}, swaps={e0.swaps} (want 0 measures, 1 swap)")

    # ---- study 2: continuous batching vs sequential ----------------------
    # Untimed warmup pass per configuration first: admission conversion
    # and the block-CG jit compile (one key per slot count) must not
    # land inside either side of the A/B.
    timings = {}
    for label, slots in (("sequential", 1), ("batched", SLOTS)):
        reg, entries = _registry(_TENANTS, tune="off")
        sched = SolveScheduler(reg, slots=slots, maxiter=MAXITER, tol=TOL)
        warm = _request_set(entries, 1)
        _serve_all(sched, warm)
        _assert_all_converged(warm, f"{label} warmup")
        reqs = _request_set(entries, REQS_PER_TENANT)
        timings[label] = _serve_all(sched, reqs)
        _assert_all_converged(reqs, label)
        n = len(reqs)
        thr = n / timings[label]
        occ = sched.metrics.occupancy.snapshot()
        rows.append(dict(kind="throughput", mode=label, slots=slots,
                         requests=n, wall_s=timings[label],
                         req_per_s=thr,
                         batches=sched.metrics.counters["batches"],
                         occupancy_mean=occ.get("mean_s")))
        if print_rows:
            print(csv_row(f"serve_throughput_{label}",
                          timings[label] / n * 1e6,
                          f"{thr:.1f} req/s slots={slots}"))

    speedup = timings["sequential"] / timings["batched"]
    rows.append(dict(kind="throughput_ratio", speedup=speedup,
                     guard=MIN_BATCH_SPEEDUP))
    if print_rows:
        print(csv_row("serve_batching_speedup", 0.0, f"{speedup:.2f}x"))
    if speedup < MIN_BATCH_SPEEDUP:
        raise SystemExit(
            f"REGRESSION: continuous batching {speedup:.2f}x sequential "
            f"(want >= {MIN_BATCH_SPEEDUP}x) — coalescing is not "
            "amortising the matrix stream")

    # ---- study 3: p50/p99 under Poisson arrivals -------------------------
    # Open-loop offered load at ~1.2x measured batched capacity: the
    # queue builds, continuous batching drains it in full groups, and
    # the p99 shows the backlog price while p50 stays near one solve.
    rng = seeded_rng(1)
    reg, entries = _registry(_TENANTS, tune="off")
    sched = SolveScheduler(reg, slots=SLOTS, maxiter=MAXITER, tol=TOL)
    warm = _request_set(entries, 1)
    _serve_all(sched, warm)

    cap = len(_TENANTS) * REQS_PER_TENANT / timings["batched"]
    inter = 1.0 / (1.2 * cap)
    arrivals = np.cumsum(rng.exponential(inter, N_ARRIVALS))
    names = list(entries)
    sched_reqs = []
    for i, t_a in enumerate(arrivals):
        name = names[int(rng.integers(len(names)))]
        e = entries[name]
        sched_reqs.append((float(t_a), SolveRequest(
            rid=1000 + i, b=rng.standard_normal(e.shape[0])
            .astype(np.float32), tenant=e.key)))

    i, t0 = 0, time.monotonic()
    while i < len(sched_reqs) or sched.pending():
        now = time.monotonic() - t0
        while i < len(sched_reqs) and sched_reqs[i][0] <= now:
            sched.submit(sched_reqs[i][1])
            i += 1
        if sched.pending():
            sched.tick()
        elif i < len(sched_reqs):
            time.sleep(min(5e-3, sched_reqs[i][0] - now))
    wall = time.monotonic() - t0
    _assert_all_converged([("", r) for _, r in sched_reqs], "poisson")

    snap = sched.metrics.snapshot()
    rows.append(dict(kind="poisson_latency", arrivals=N_ARRIVALS,
                     offered_per_s=1.0 / inter, wall_s=wall,
                     queue_s=snap["queue_s"], solve_s=snap["solve_s"],
                     total_s=snap["total_s"],
                     occupancy=snap["occupancy"],
                     counters=snap["counters"]))
    if print_rows:
        print(csv_row("serve_poisson_p50", snap["total_s"]["p50_s"] * 1e6,
                      f"p99={snap['total_s']['p99_s'] * 1e3:.1f}ms "
                      f"occ={snap['occupancy']['mean_s']:.2f}"))

    path = write_bench_json("serve", rows)
    if print_rows:
        print(csv_row("serve_json", 0.0, path))
    return rows
