"""Paper Fig. 3: row-length histograms of the test matrices.

Prints a coarse text histogram + the spread statistics the paper uses to
predict pJDS data-reduction potential (max/min row length; weight near
the max)."""
from __future__ import annotations

import numpy as np

from repro.core import matrices as M
from .common import csv_row

SCALES = {"HMEp": 0.004, "sAMG": 0.007, "DLR1": 0.08, "DLR2": 0.04,
          "UHBR": 0.005}


def run(print_rows=True):
    rows = []
    for name, scale in SCALES.items():
        m = M.make_test_matrix(name, scale=scale)
        rl = m.row_lengths()
        rel_width = rl.max() / max(rl.min(), 1)
        frac_near_max = float((rl >= 0.8 * rl.max()).mean())
        hist, edges = np.histogram(rl, bins=10)
        rows.append(dict(name=name, min=int(rl.min()), max=int(rl.max()),
                         mean=round(float(rl.mean()), 1),
                         rel_width=round(float(rel_width), 2),
                         frac_near_max=round(frac_near_max, 3)))
        if print_rows:
            print(csv_row(f"fig3_{name}", 0.0,
                          f"rl {rl.min()}..{rl.max()} relwidth={rel_width:.1f} "
                          f"near_max={frac_near_max:.2f}"))
            top = hist.max()
            for h, lo, hi in zip(hist, edges[:-1], edges[1:]):
                bar = "#" * max(int(40 * h / top), 0)
                print(f"#   {lo:7.1f}-{hi:7.1f} {bar} {h}")
    return rows


if __name__ == "__main__":
    run()
