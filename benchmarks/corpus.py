"""On-disk Matrix-Market benchmark corpus (DESIGN.md §13).

Three structural classes cover the regimes where the dispatch heuristic
makes different calls, mirroring the paper's matrix set without
shipping multi-MB fixtures in the repo:

* ``fem2d``   — 5-point Poisson stencil: near-uniform ~5/row, the
  ELLPACK-friendly regime (paper's HMEp/sAMG analogues).
* ``graph``   — power-law (zipf) row lengths: the padding-hostile
  regime where pJDS/CMRS win (paper's DLR analogues).
* ``banded``  — symmetric band matrix under a random symmetric
  permutation: bandwidth-destroyed structure that RCM fully recovers —
  the preprocessing stage's acceptance matrix (``reorder="auto"``
  must decline it single-device and apply it distributed).

All values are small integers stored as f32, so any summation order
gives bit-identical results — format conformance and ``.mtx``
round-trips assert ``==``, not ``allclose``.  Files are generated
deterministically into ``corpus/`` (gitignored) on first use;
``load()`` round-trips through :mod:`repro.core.io_mm` so the corpus
also exercises the ingestion path every time a bench runs.
"""
from __future__ import annotations

import os
import pathlib

import numpy as np

from repro.core import formats as F, io_mm, matrices as M
from repro.core.reorder import permute_symmetric

__all__ = ["CORPUS", "generate", "load", "make"]

_DEFAULT_DIR = "corpus"


def _integer_values(rows: np.ndarray, cols: np.ndarray) -> np.ndarray:
    """Deterministic small-integer values, symmetric in (i, j)."""
    lo = np.minimum(rows, cols)
    hi = np.maximum(rows, cols)
    return ((lo * 31 + hi * 17) % 7 + 1).astype(np.float32)


def _fem2d() -> F.CSRMatrix:
    m = M.poisson_2d(48, 48)
    rows = np.repeat(np.arange(m.n_rows, dtype=np.int64), m.row_lengths())
    data = _integer_values(rows, m.indices.astype(np.int64))
    return F.CSRMatrix(m.indptr, m.indices, data, m.shape)


def _graph() -> F.CSRMatrix:
    m = M.power_law(n=4096, seed=11)
    rows = np.repeat(np.arange(m.n_rows, dtype=np.int64), m.row_lengths())
    data = ((rows * 13 + m.indices.astype(np.int64) * 5) % 7 + 1
            ).astype(np.float32)
    return F.CSRMatrix(m.indptr, m.indices, data, m.shape)


def _banded(n: int = 2048, band: int = 3, seed: int = 5) -> F.CSRMatrix:
    i = np.arange(n, dtype=np.int64)
    offs = np.arange(-band, band + 1, dtype=np.int64)
    rows = np.repeat(i, len(offs))
    cols = (rows + np.tile(offs, n))
    keep = (cols >= 0) & (cols < n)
    rows, cols = rows[keep], cols[keep]
    m = F.csr_from_coo(rows, cols, _integer_values(rows, cols), shape=(n, n))
    rng = np.random.default_rng(seed)
    return permute_symmetric(m, rng.permutation(n))


CORPUS = {
    "fem2d": _fem2d,
    "graph": _graph,
    "banded": _banded,
}


def make(name: str) -> F.CSRMatrix:
    """Build a corpus matrix in memory (no files touched)."""
    return CORPUS[name]()


def generate(out_dir: str = _DEFAULT_DIR, force: bool = False) -> dict:
    """Write every corpus matrix to ``<out_dir>/<name>.mtx`` (skipping
    files that already exist unless ``force``).  Returns name->path."""
    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    paths = {}
    for name in CORPUS:
        p = out / f"{name}.mtx"
        if force or not p.exists():
            io_mm.save_mm(p, make(name), comment=f"repro corpus: {name}")
        paths[name] = str(p)
    return paths


def load(out_dir: str = _DEFAULT_DIR) -> dict:
    """Load the corpus from disk (generating missing files first) as
    name -> CSRMatrix, every matrix passing through the ``load_mm``
    admission path."""
    paths = generate(out_dir)
    return {name: io_mm.load_mm(p) for name, p in paths.items()}
