"""SELL-C-sigma sigma-sweep vs pJDS: the window trade-off, measured.

For each test matrix, sweeps sigma in {b_r, 4*b_r, n_rows} (the last is
the pJDS special case) and records

* storage overhead vs nnz — padding grows as the window shrinks,
* unpermute locality — max |inv_perm[i] - i|, bounded by sigma; this is
  the gather radius of the kernel's fused epilogue (global for pJDS),
* jitted ref-path wall time (the Pallas kernels run interpret-mode on
  CPU, so kernel wall-time is not meaningful here — see DESIGN.md §3 for
  what transfers to TPU),
* what ``select_format`` would pick for the matrix.

Emits machine-readable BENCH_sell.json for the perf trajectory.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F, matrices as M
from repro.kernels import ops
from .common import time_fn, csv_row, write_bench_json

B_R = 128


def _sweep(name: str, m, rows, print_rows: bool) -> None:
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal(m.shape[1]).astype(np.float32))
    n_pad = ((m.n_rows + B_R - 1) // B_R) * B_R
    chosen = ops.select_format(m, b_r=B_R)
    for sigma in (B_R, 4 * B_R, n_pad):
        s = F.csr_to_sell(m, c=B_R, sigma=sigma, permuted_cols=False)
        dev = ops.to_device_sell(s)
        over = F.storage_elements(s) / m.nnz - 1
        locality = int(np.abs(np.asarray(s.pjds.inv_perm)
                              - np.arange(s.pjds.n_rows_pad)).max())
        f = jax.jit(lambda v: ops.sell_matvec(dev, v))
        t = time_fn(f, x)
        tag = "pjds" if sigma >= n_pad else str(sigma)
        rows.append(dict(kind="sell_sweep", matrix=name, sigma=sigma,
                         is_pjds=sigma >= n_pad, overhead=over,
                         unpermute_radius=locality, t_us=t * 1e6,
                         gfs=2 * m.nnz / t / 1e9, auto_format=chosen))
        if print_rows:
            print(csv_row(f"sell_{name}_sigma{tag}", t * 1e6,
                          f"overhead={100*over:.2f}% radius={locality} "
                          f"auto={chosen}"))


def run(print_rows=True):
    rows = []
    _sweep("powerlaw", M.power_law(4096, seed=7), rows, print_rows)
    _sweep("sAMG", M.samg(scale=0.004), rows, print_rows)
    _sweep("UHBR", M.uhbr(scale=0.003), rows, print_rows)
    write_bench_json("sell", rows)
    return rows


if __name__ == "__main__":
    run()
