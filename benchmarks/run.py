"""Benchmark driver: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  Heavy multi-device cases
run in subprocesses so this process keeps one device.

    PYTHONPATH=src python -m benchmarks.run [--only table1,fig3,...]
"""
from __future__ import annotations

import argparse
import sys
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list: table1,fig3,eq,scaling,kernels,sell,"
                         "ops,dist,tune,solve,serve,formats")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    from . import (bench_formats, bench_histograms, bench_perf_model,
                   bench_scaling, bench_kernels, bench_sell, bench_sparse_ffn,
                   bench_ops, bench_dist, bench_tune, bench_solve,
                   bench_serve)
    suites = [
        ("table1", bench_formats.run),      # paper Table 1
        ("fig3", bench_histograms.run),     # paper Fig. 3
        ("eq", bench_perf_model.run),       # paper Eq. 1-4
        ("kernels", bench_kernels.run),     # kernel study
        ("sell", bench_sell.run),           # SELL-C-sigma sigma sweep
        ("ops", bench_ops.run),             # operator-wrapper overhead
        ("sparse_ffn", bench_sparse_ffn.run),  # beyond-paper: pJDS in LMs
        ("scaling", bench_scaling.run),     # paper Fig. 5
        ("dist", bench_dist.run),           # gathered vs full halo, spMM
        ("tune", bench_tune.run),           # autotuner vs heuristic + calib
        ("solve", bench_solve.run),         # fused solver iterations
        ("serve", bench_serve.run),         # multi-tenant solve serving
        ("formats", bench_formats.run_corpus),  # .mtx corpus format sweep
    ]
    if only:
        unknown = only - {name for name, _ in suites}
        if unknown:
            sys.exit(f"unknown suite(s): {','.join(sorted(unknown))}; "
                     f"known: {','.join(name for name, _ in suites)}")

    print("name,us_per_call,derived")
    failed = 0
    for name, fn in suites:
        if only and name not in only:
            continue
        try:
            fn(print_rows=True)
        except Exception:
            failed += 1
            print(f"{name},0,FAILED", file=sys.stderr)
            traceback.print_exc()
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
