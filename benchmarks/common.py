"""Shared benchmark utilities."""
from __future__ import annotations

import json
import pathlib
import time

import jax
import numpy as np

# One seed policy for every suite (mirrored by tests/conftest.DEFAULT_SEED):
# benchmark inputs are deterministic so BENCH_*.json rows are comparable
# across runs and the CI regression guards never flake on input draw.
DEFAULT_SEED = 0


def seeded_rng(seed: int | None = None) -> np.random.Generator:
    """Deterministic generator for benchmark inputs."""
    return np.random.default_rng(DEFAULT_SEED if seed is None else seed)


def time_fn(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-clock seconds per call of a jitted fn."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    ts = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def csv_row(name: str, us_per_call: float, derived: str) -> str:
    return f"{name},{us_per_call:.1f},{derived}"


def _jsonable(o):
    if isinstance(o, (np.integer,)):
        return int(o)
    if isinstance(o, (np.floating,)):
        return float(o)
    if isinstance(o, np.ndarray):
        return o.tolist()
    raise TypeError(f"not JSON-serializable: {type(o)}")


def write_bench_json(suite: str, rows: list, out_dir: str | None = None) -> str:
    """Write machine-readable benchmark rows to ``BENCH_<suite>.json``
    (cwd by default) — the perf-trajectory artifact CI uploads."""
    path = pathlib.Path(out_dir or ".") / f"BENCH_{suite}.json"
    payload = {"suite": suite, "jax": jax.__version__, "rows": rows}
    path.write_text(json.dumps(payload, indent=2, default=_jsonable) + "\n")
    return str(path)
