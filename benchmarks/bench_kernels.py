"""Per-kernel microbenchmarks + the chunk_l / b_r trade-off study.

Wall-times are from the jitted REF path (the Pallas kernels execute in
interpret mode on CPU — Python per grid step — so their wall-time is not
meaningful; their correctness is covered by tests).  What IS meaningful
here and transfers to TPU:
* padding overhead as a function of (b_r, diag_align/chunk_l) — the
  structural cost of bigger VMEM tiles,
* the arithmetic-intensity jump from spMVM to multi-RHS spMM (the
  SparseFFN case), straight from the byte/flop model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F, matrices as M, perf_model as PM
from repro.kernels import ops
from .common import time_fn, csv_row, write_bench_json


def run(print_rows=True):
    rows = []
    m = M.uhbr(scale=0.003)
    n = m.shape[0]
    rng = np.random.default_rng(0)
    x = rng.standard_normal(n).astype(np.float32)

    # --- b_r x diag_align padding overhead (storage elements vs nnz) ----
    for b_r in (32, 128, 256):
        for diag_align in (8, 64):
            pj = F.csr_to_pjds(m, b_r=b_r, diag_align=diag_align)
            over = F.storage_elements(pj) / m.nnz - 1
            rows.append(dict(kind="padding", b_r=b_r, diag_align=diag_align,
                             overhead=over))
            if print_rows:
                print(csv_row(f"pad_br{b_r}_align{diag_align}", 0.0,
                              f"padding_overhead={100*over:.2f}%"))

    # --- spmv vs spmm arithmetic intensity (model) + measured ref time --
    pj = F.csr_to_pjds(m, b_r=128, diag_align=8)
    dev = ops.to_device_pjds(pj)
    xp = jnp.asarray(pj.permute(x))
    f_mv = jax.jit(lambda v: ops.pjds_matvec(dev, v))
    t_mv = time_fn(f_mv, xp)
    rows.append(dict(kind="spmv", t_us=t_mv * 1e6,
                     gfs=2 * m.nnz / t_mv / 1e9))
    if print_rows:
        print(csv_row("pjds_spmv_ref", t_mv * 1e6,
                      f"{rows[-1]['gfs']:.2f}GF/s"))
    for n_rhs in (8, 64):
        xs = jnp.asarray(
            rng.standard_normal((pj.n_rows_pad, n_rhs)).astype(np.float32))
        f_mm = jax.jit(lambda v: ops.pjds_matmat(dev, v))
        t_mm = time_fn(f_mm, xs)
        # intensity: flops / matrix bytes (values+idx), RHS amortised
        inten = 2 * n_rhs / 8.0
        rows.append(dict(kind=f"spmm{n_rhs}", t_us=t_mm * 1e6,
                         gfs=2 * m.nnz * n_rhs / t_mm / 1e9,
                         intensity=inten))
        if print_rows:
            print(csv_row(f"pjds_spmm_rhs{n_rhs}", t_mm * 1e6,
                          f"{rows[-1]['gfs']:.2f}GF/s intensity={inten:.0f}F/B"))

    # --- ELLPACK-R vs pJDS on a high-variance matrix (the paper's win) --
    ms = M.samg(scale=0.004)
    pj2 = F.csr_to_pjds(ms, b_r=128)
    ell2 = F.csr_to_ell(ms, row_align=128)
    d_p = ops.to_device_pjds(pj2)
    d_e = ops.to_device_ell(ell2)
    x2 = rng.standard_normal(ms.shape[0]).astype(np.float32)
    xp2 = jnp.asarray(pj2.permute(x2))
    xe2 = jnp.asarray(np.resize(x2, ell2.n_rows_pad))
    t_p = time_fn(jax.jit(lambda v: ops.pjds_matvec(d_p, v)), xp2)
    t_e = time_fn(jax.jit(lambda v: ops.ell_matvec(d_e, v)), xe2)
    stored_ratio = F.storage_elements(ell2) / F.storage_elements(pj2)
    rows.append(dict(kind="pjds_vs_ellr", speedup=t_e / t_p,
                     stored_ratio=stored_ratio))
    if print_rows:
        print(csv_row("pjds_vs_ellr_samg", t_p * 1e6,
                      f"speedup={t_e/t_p:.2f}x stored_ratio={stored_ratio:.2f}x"))

    # --- SELL-C-sigma vs pJDS storage on the power-law matrix ----------
    # pJDS is SELL's sigma = n_rows special case, so the best swept SELL
    # overhead is structurally <= pJDS; the interesting number is how
    # small a window already gets close (bench_sell.py has the full sweep).
    mp = M.power_law(4096, seed=7)
    b_r = 128
    pj_p = F.csr_to_pjds(mp, b_r=b_r, permuted_cols=False)
    over_pjds = F.storage_elements(pj_p) / mp.nnz - 1
    n_pad = pj_p.n_rows_pad
    best_sigma, best_over = None, np.inf
    for sigma in (b_r, 4 * b_r, n_pad):
        sl = F.csr_to_sell(mp, c=b_r, sigma=sigma, permuted_cols=False)
        over = F.storage_elements(sl) / mp.nnz - 1
        rows.append(dict(kind="sell_powerlaw_storage", sigma=sigma,
                         overhead=over))
        if over < best_over:
            best_sigma, best_over = sigma, over
    rows.append(dict(kind="sell_vs_pjds_powerlaw", pjds_overhead=over_pjds,
                     sell_overhead_best=best_over, sell_sigma_best=best_sigma,
                     sell_le_pjds=bool(best_over <= over_pjds)))
    if print_rows:
        print(csv_row("sell_vs_pjds_powerlaw", 0.0,
                      f"pjds_overhead={100*over_pjds:.2f}% "
                      f"sell_best={100*best_over:.2f}%@sigma={best_sigma}"))

    write_bench_json("kernels", rows)
    return rows


if __name__ == "__main__":
    run()
