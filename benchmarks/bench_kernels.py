"""Per-kernel microbenchmarks + the chunk_l / b_r trade-off study
+ the compressed-stream (bytes/nnz) accounting.

Wall-times are from the jitted REF path (the Pallas kernels execute in
interpret mode on CPU — Python per grid step — so their wall-time is not
meaningful; their correctness is covered by tests).  What IS meaningful
here and transfers to TPU:
* padding overhead as a function of (b_r, diag_align/chunk_l) — the
  structural cost of bigger VMEM tiles,
* measured stored bytes/nnz per storage variant (f32+int32 baseline,
  int16-compressed indices, bf16+int16 fully compressed) with the
  perf-model's predicted memory-bound spMVM time per variant — the
  roofline rows CI tracks, mirroring the paper's memory-footprint
  comparison at the byte-stream level,
* the arithmetic-intensity jump from spMVM to multi-RHS spMM (the
  SparseFFN case), straight from the byte/flop model.

The compressed-variant rows double as a REGRESSION GUARD: the bench
fails (non-zero exit, so the CI bench-smoke job fails) if the fully
compressed pJDS build stops saving at least 35% of the f32+int32
baseline's stored bytes/nnz, or if any compressed variant drifts from
the f32 reference beyond 1e-2 relative error.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import formats as F, matrices as M, perf_model as PM
from repro.kernels import ops
from .common import seeded_rng, time_fn, csv_row, write_bench_json

# Compressed-variant guard thresholds (see module docstring).
MAX_COMPRESSED_BYTES_RATIO = 0.65
MAX_COMPRESSED_REL_ERR = 1e-2

_VARIANTS = [
    # (label, value dtype (None = keep f32), index_dtype)
    ("f32+int32", None, np.int32),
    ("f32+int16", None, "auto"),
    ("bf16+int16", jnp.bfloat16, "auto"),
]


def _stored_bytes(sd: ops.SparseDevice) -> int:
    """Measured footprint of the device representation: value stream +
    index stream at their ACTUAL dtypes + per-format metadata arrays."""
    d = sd.dev
    if sd.fmt == "csr":
        return d.data.nbytes + d.indices.nbytes + d.row_ids.nbytes
    n = d.val.nbytes + d.col_idx.nbytes
    if sd.fmt == "ellpack_r":
        return n + d.rowlen.nbytes + d.tile_chunks.nbytes
    n += d.chunk_map.nbytes
    if sd.fmt == "sell":
        n += d.inv_perm.nbytes
    elif sd.inv_perm is not None:
        n += sd.inv_perm.nbytes
    return n


def bytes_per_nnz_rows(m, x, truth, mat: str, fmt: str, rows: list,
                       print_rows: bool) -> dict:
    """One bytes/nnz + predicted-vs-measured roofline row per storage
    variant; returns {variant label: bytes_per_nnz}."""
    out = {}
    n, n_nzr = m.n_rows, m.n_nzr
    scale = max(np.abs(truth).max(), 1.0)
    for label, vdt, idt in _VARIANTS:
        sd = ops.as_device(m, fmt, dtype=vdt, index_dtype=idt)
        bpn = _stored_bytes(sd) / m.nnz
        vb = np.dtype(jnp.bfloat16 if vdt is not None else np.float32).itemsize
        ib = sd.index_dtype.itemsize
        # vectors stay f32 whatever the stored width (vec_bytes default)
        pred_s = PM.predicted_spmv_seconds(
            sd.storage_elements(), n, n_nzr,
            perm_bytes=PM.perm_traffic_bytes(n, 4,
                                             window_local=(fmt != "pjds")),
            value_bytes=vb, index_bytes=ib)
        f = jax.jit(lambda v, sd=sd: sd.matvec(v, backend="ref"))
        xv = jnp.asarray(x)
        t_meas = time_fn(f, xv)
        err = float(np.abs(np.asarray(f(xv), np.float64) - truth).max()
                    / scale)
        if err > MAX_COMPRESSED_REL_ERR:
            raise SystemExit(
                f"REGRESSION: {mat}/{fmt}/{label} drifted from the f32 "
                f"reference: rel err {err:.2e} > {MAX_COMPRESSED_REL_ERR}")
        rows.append(dict(
            kind="bytes_per_nnz", matrix=mat, fmt=fmt, variant=label,
            bytes_per_nnz=bpn, value_bytes=vb, index_bytes=ib,
            predicted_s=pred_s, measured_ref_s=t_meas,
            roofline_fraction=pred_s / t_meas if t_meas > 0 else 0.0,
            rel_err_vs_f32=err,
            gbs_at_roofline=_stored_bytes(sd) / pred_s / 1e9))
        if print_rows:
            print(csv_row(f"bytes_{mat}_{fmt}_{label}", t_meas * 1e6,
                          f"bytes/nnz={bpn:.2f} pred={pred_s*1e6:.1f}us "
                          f"err={err:.1e}"))
        out[label] = bpn
    return out


def run(print_rows=True):
    rows = []
    m = M.uhbr(scale=0.003)
    n = m.shape[0]
    rng = seeded_rng()
    x = rng.standard_normal(n).astype(np.float32)

    # --- b_r x diag_align padding overhead (storage elements vs nnz) ----
    for b_r in (32, 128, 256):
        for diag_align in (8, 64):
            pj = F.csr_to_pjds(m, b_r=b_r, diag_align=diag_align)
            over = F.storage_elements(pj) / m.nnz - 1
            rows.append(dict(kind="padding", b_r=b_r, diag_align=diag_align,
                             overhead=over))
            if print_rows:
                print(csv_row(f"pad_br{b_r}_align{diag_align}", 0.0,
                              f"padding_overhead={100*over:.2f}%"))

    # --- chunk_l sweep: grid steps vs padding (the tile-size default) ---
    # The prefetched kernels stream (chunk_l, b_r) tiles and pad every
    # block to chunk_l jagged diagonals; chunk_l=16 is the dispatch-layer
    # default (ops.as_device) — this row records the measured trade.
    for chunk_l in (8, 16, 32):
        pj = F.csr_to_pjds(m, b_r=128, diag_align=chunk_l,
                           permuted_cols=False)
        over = F.storage_elements(pj) / m.nnz - 1
        steps = int(np.sum(pj.block_len // chunk_l))
        rows.append(dict(kind="chunk_l_sweep", chunk_l=chunk_l,
                         overhead=over, grid_steps=steps,
                         tile_kib=chunk_l * 128 * 4 / 1024))
        if print_rows:
            print(csv_row(f"chunk_l{chunk_l}", 0.0,
                          f"overhead={100*over:.2f}% steps={steps}"))

    # --- bytes/nnz + roofline rows per storage variant + guard ----------
    ms = M.samg(scale=0.004)
    xs = rng.standard_normal(ms.shape[0]).astype(np.float32)
    guard = []
    for mat, mm, xx in (("uhbr", m, x), ("samg", ms, xs)):
        truth = None
        for fmt in ("pjds", "sell"):
            if truth is None:
                truth = F.csr_to_dense(mm).astype(np.float64) @ xx
            bpn = bytes_per_nnz_rows(mm, xx, truth, mat, fmt, rows,
                                     print_rows)
            ratio = bpn["bf16+int16"] / bpn["f32+int32"]
            rows.append(dict(kind="compressed_ratio", matrix=mat, fmt=fmt,
                             ratio=ratio))
            if fmt == "pjds":
                guard.append((mat, ratio))
            if print_rows:
                print(csv_row(f"compress_{mat}_{fmt}", 0.0,
                              f"stored_ratio={ratio:.3f}"))
    for mat, ratio in guard:
        if ratio > MAX_COMPRESSED_BYTES_RATIO:
            raise SystemExit(
                f"REGRESSION: bf16+int16 pJDS on {mat} stores "
                f"{ratio:.2f}x the f32+int32 bytes/nnz "
                f"(> {MAX_COMPRESSED_BYTES_RATIO})")

    # --- spmv vs spmm arithmetic intensity (model) + measured ref time --
    pj = F.csr_to_pjds(m, b_r=128, diag_align=8)
    dev = ops.to_device_pjds(pj)
    xp = jnp.asarray(pj.permute(x))
    f_mv = jax.jit(lambda v: ops.pjds_matvec(dev, v))
    t_mv = time_fn(f_mv, xp)
    rows.append(dict(kind="spmv", t_us=t_mv * 1e6,
                     gfs=2 * m.nnz / t_mv / 1e9))
    if print_rows:
        print(csv_row("pjds_spmv_ref", t_mv * 1e6,
                      f"{rows[-1]['gfs']:.2f}GF/s"))
    for n_rhs in (8, 64):
        xs2 = jnp.asarray(
            rng.standard_normal((pj.n_rows_pad, n_rhs)).astype(np.float32))
        f_mm = jax.jit(lambda v: ops.pjds_matmat(dev, v))
        t_mm = time_fn(f_mm, xs2)
        # intensity: flops / matrix bytes (values+idx), RHS amortised
        inten = 2 * n_rhs / 8.0
        rows.append(dict(kind=f"spmm{n_rhs}", t_us=t_mm * 1e6,
                         gfs=2 * m.nnz * n_rhs / t_mm / 1e9,
                         intensity=inten))
        if print_rows:
            print(csv_row(f"pjds_spmm_rhs{n_rhs}", t_mm * 1e6,
                          f"{rows[-1]['gfs']:.2f}GF/s intensity={inten:.0f}F/B"))

    # --- ELLPACK-R vs pJDS on a high-variance matrix (the paper's win) --
    pj2 = F.csr_to_pjds(ms, b_r=128)
    ell2 = F.csr_to_ell(ms, row_align=128)
    d_p = ops.to_device_pjds(pj2)
    d_e = ops.to_device_ell(ell2)
    x2 = rng.standard_normal(ms.shape[0]).astype(np.float32)
    xp2 = jnp.asarray(pj2.permute(x2))
    xe2 = jnp.asarray(np.resize(x2, ell2.n_rows_pad))
    t_p = time_fn(jax.jit(lambda v: ops.pjds_matvec(d_p, v)), xp2)
    t_e = time_fn(jax.jit(lambda v: ops.ell_matvec(d_e, v)), xe2)
    stored_ratio = F.storage_elements(ell2) / F.storage_elements(pj2)
    rows.append(dict(kind="pjds_vs_ellr", speedup=t_e / t_p,
                     stored_ratio=stored_ratio))
    if print_rows:
        print(csv_row("pjds_vs_ellr_samg", t_p * 1e6,
                      f"speedup={t_e/t_p:.2f}x stored_ratio={stored_ratio:.2f}x"))

    # --- SELL-C-sigma vs pJDS storage on the power-law matrix ----------
    # pJDS is SELL's sigma = n_rows special case, so the best swept SELL
    # overhead is structurally <= pJDS; the interesting number is how
    # small a window already gets close (bench_sell.py has the full sweep).
    mp = M.power_law(4096, seed=7)
    b_r = 128
    pj_p = F.csr_to_pjds(mp, b_r=b_r, permuted_cols=False)
    over_pjds = F.storage_elements(pj_p) / mp.nnz - 1
    n_pad = pj_p.n_rows_pad
    best_sigma, best_over = None, np.inf
    for sigma in (b_r, 4 * b_r, n_pad):
        sl = F.csr_to_sell(mp, c=b_r, sigma=sigma, permuted_cols=False)
        over = F.storage_elements(sl) / mp.nnz - 1
        rows.append(dict(kind="sell_powerlaw_storage", sigma=sigma,
                         overhead=over))
        if over < best_over:
            best_sigma, best_over = sigma, over
    rows.append(dict(kind="sell_vs_pjds_powerlaw", pjds_overhead=over_pjds,
                     sell_overhead_best=best_over, sell_sigma_best=best_sigma,
                     sell_le_pjds=bool(best_over <= over_pjds)))
    if print_rows:
        print(csv_row("sell_vs_pjds_powerlaw", 0.0,
                      f"pjds_overhead={100*over_pjds:.2f}% "
                      f"sell_best={100*best_over:.2f}%@sigma={best_sigma}"))

    write_bench_json("kernels", rows)
    return rows


if __name__ == "__main__":
    run()
