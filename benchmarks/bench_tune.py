"""Tuned-vs-heuristic study + calibration guard -> BENCH_tune.json.

For each bench matrix (uhbr, samg — the two the kernel study uses) the
autotuner runs against a FRESH cache (no state leaks between CI runs),
the winning statics and the heuristic default are re-measured with one
shared harness, and the speedup is recorded.  Three REGRESSION GUARDS
(non-zero exit, so the CI tuner-smoke job fails):

* the tuned config is never more than 5% slower than the heuristic
  pick (the prune keeps the heuristic in the measured set, so the
  tuner can only lose by re-measurement noise);
* at least one matrix shows a measurable tuned win (>= 2%);
* re-running the tuner hits the cache (no re-measurement), and fitting
  the calibration on the BENCH_kernels roofline rows STRICTLY reduces
  the predicted-vs-measured error vs the uncalibrated model (falls
  back to this run's measured rows when BENCH_kernels.json is absent).
"""
from __future__ import annotations

import pathlib
import tempfile

from repro.core import matrices as M
from repro import tune as T
from .common import csv_row, write_bench_json

MAX_TUNED_SLOWDOWN = 1.05      # tuned may never lose > 5% to the heuristic
MIN_BEST_SPEEDUP = 1.02        # >= one matrix must win measurably

_MATRICES = (
    ("uhbr", lambda: M.uhbr(scale=0.003)),
    ("samg", lambda: M.samg(scale=0.004)),
)


def run(print_rows=True):
    rows = []
    cache = T.TuneCache(
        pathlib.Path(tempfile.mkdtemp(prefix="bench_tune_")) / "cache.json")

    speedups = {}
    for name, mk in _MATRICES:
        m = mk()
        heur = T.heuristic_candidate(m)
        res = T.autotune(m, cache=cache, warmup=2, iters=9)
        res2 = T.autotune(m, cache=cache)
        if not res2.cached or res2.best != res.best:
            raise SystemExit(
                f"REGRESSION: tuner cache round-trip failed on {name} "
                f"(cached={res2.cached})")

        # drift-robust interleaved A/B for the final comparison (this
        # number is the guarded artifact; one-sided timing would let
        # background-load drift land on one side)
        t_heur, t_tuned = T.ab_compare(m, heur, res.best,
                                       rounds=9, iters=3, warmup=3)
        speedups[name] = t_heur / t_tuned
        rows.append(dict(
            kind="tuned_vs_heuristic", matrix=name,
            heuristic=heur.as_dict(), tuned=res.best.as_dict(),
            heuristic_s=t_heur, tuned_s=t_tuned,
            speedup=speedups[name], cache_hit_roundtrip=True,
            n_measured=len(res.rows)))
        if print_rows:
            print(csv_row(f"tune_{name}", t_tuned * 1e6,
                          f"speedup={speedups[name]:.2f}x "
                          f"tuned=[{res.best.label()}] "
                          f"heur=[{heur.label()}]"))
        if t_tuned > MAX_TUNED_SLOWDOWN * t_heur:
            raise SystemExit(
                f"REGRESSION: tuned config on {name} is "
                f"{t_tuned / t_heur:.2f}x the heuristic time "
                f"(> {MAX_TUNED_SLOWDOWN})")

        # calibration input: this matrix's measured candidate rows
        rows.extend(dict(kind="measured_candidate", matrix=name, **r)
                    for r in res.rows)

    best_mat = max(speedups, key=speedups.get)
    rows.append(dict(kind="best_win", matrix=best_mat,
                     speedup=speedups[best_mat]))
    if speedups[best_mat] < MIN_BEST_SPEEDUP:
        raise SystemExit(
            f"REGRESSION: no matrix shows a measurable tuned win "
            f"(best {best_mat} at {speedups[best_mat]:.3f}x "
            f"< {MIN_BEST_SPEEDUP})")

    # ---- calibration: strict error improvement on roofline rows ------
    bk = pathlib.Path("BENCH_kernels.json")
    if bk.exists():
        cal_rows, cal_src = T.rows_from_bench_kernels(bk), str(bk)
    else:
        cal_rows = [r for r in rows if r.get("kind") == "measured_candidate"]
        cal_src = "bench_tune:measured_candidates"
    err0 = T.model_error(cal_rows)
    cal = T.fit_calibration(cal_rows, source=cal_src)
    err1 = T.model_error(cal_rows, cal)
    rows.append(dict(kind="calibration", source=cal_src, n_rows=len(cal_rows),
                     rms_rel_err_uncalibrated=err0,
                     rms_rel_err_calibrated=err1,
                     bw_scale=cal.bw_scale,
                     overhead_s=dict(cal.overhead_s)))
    if print_rows:
        print(csv_row("tune_calibration", 0.0,
                      f"rms_rel_err={err0:.3f}->{err1:.3f} "
                      f"bw_scale={cal.bw_scale:.2e}"))
    if not err1 < err0:
        raise SystemExit(
            f"REGRESSION: calibration did not improve the perf model on "
            f"{cal_src} ({err0:.4f} -> {err1:.4f})")

    write_bench_json("tune", rows)
    return rows


if __name__ == "__main__":
    run()
