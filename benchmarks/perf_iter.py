"""§Perf hillclimbing harness: re-lower a cell with a knob changed and
diff the roofline terms against the recorded baseline.

    PYTHONPATH=src python -m benchmarks.perf_iter \
        --arch deepseek-moe-16b --shape train_4k \
        --tag onehot --set moe_dispatch=onehot

Knobs: --attn-impl pairs|qloop, --q-chunk N, --k-chunk N, and
--set field=value for any ArchConfig field (type-coerced).  Results land
in experiments/perf/<arch>__<shape>__<tag>.json.

Solver mode prices one Krylov ITERATION instead of a model cell:

    PYTHONPATH=src python -m benchmarks.perf_iter \
        --solver samg --scale 0.001 --method cg

For each (strategy x stored dtype) it prints the spMV-only bytes next
to the full per-iteration bytes (spMV streams PLUS the carrier-vector
axpy/dot passes, ``perf_model.solver_iteration_bytes``) and the
predicted seconds.  The spMV-only column is the number this harness
used to (wrongly) report as the iteration cost — the carrier traffic it
hid is exactly what the fused kernel removes.
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json

from repro import configs
from repro.core import perf_model as PM


def term_row(cost: dict, tokens: int, chips: int, n_active: int,
             kind: str) -> dict:
    r = PM.roofline_terms(cost["flops"], cost["bytes"],
                          cost["collective_bytes"], chips=1)
    mult = 6 if kind == "train" else 2
    model_flops = mult * n_active * tokens / chips
    bound = r.bound_s
    return dict(compute_s=r.compute_s, memory_s=r.memory_s,
                collective_s=r.collective_s, dominant=r.dominant,
                bound_s=bound,
                roofline_fraction=(model_flops / PM.TPU_V5E.peak_flops)
                / bound if bound else 0.0)


def solver_pricing(matrix: str, scale: float, method: str) -> list[dict]:
    """Per-iteration pricing rows for one bench matrix: composed vs
    fused strategy, f32 vs bf16-compressed storage, each with the
    spMV-only figure alongside the full with-carriers figure."""
    from repro.core import matrices as M
    from repro.kernels import ops
    import jax.numpy as jnp

    m = getattr(M, matrix)(scale=scale)
    rows = []
    for dlabel, dtype in (("f32", None), ("bf16", jnp.bfloat16)):
        sd = ops.as_device(m, format="sell", dtype=dtype,
                           index_dtype="auto", x_tiles=1)
        vb = jnp.dtype(sd.value_dtype).itemsize
        ib = jnp.dtype(sd.index_dtype).itemsize
        stored = sd.storage_elements()
        spmv_only = PM.SOLVER_SPMV_COUNT[method] * PM.spmvm_bytes(
            stored, m.n_rows, 1.0 / max(m.n_nzr, 1.0), m.n_nzr,
            value_bytes=vb, index_bytes=ib, vec_bytes=4)
        for strategy in ("composed", "fused"):
            full = PM.solver_iteration_bytes(
                stored, m.n_rows, m.n_nzr, method=method,
                strategy=strategy, value_bytes=vb, index_bytes=ib)
            rows.append(dict(
                matrix=matrix, method=method, strategy=strategy,
                dtype=dlabel, spmv_only_bytes=spmv_only,
                iteration_bytes=full,
                carrier_fraction=1.0 - spmv_only / full,
                predicted_s=PM.predicted_iteration_seconds(
                    stored, m.n_rows, m.n_nzr, method=method,
                    strategy=strategy, value_bytes=vb, index_bytes=ib,
                    fmt="sell")))
    return rows


def solver_main(args):
    rows = solver_pricing(args.solver, args.scale, args.method)
    print(f"== solver iteration pricing: {args.solver} scale={args.scale} "
          f"method={args.method} ==")
    print(f"{'strategy':10s} {'dtype':6s} {'spMV-only B':>12s} "
          f"{'iter B':>12s} {'carrier %':>10s} {'pred s':>10s}")
    for r in rows:
        print(f"{r['strategy']:10s} {r['dtype']:6s} "
              f"{r['spmv_only_bytes']:12.0f} {r['iteration_bytes']:12.0f} "
              f"{r['carrier_fraction'] * 100:9.1f}% "
              f"{r['predicted_s']:10.3e}")
    os.makedirs(args.out, exist_ok=True)
    fname = os.path.join(
        args.out, f"solver__{args.solver}__{args.method}.json")
    with open(fname, "w") as f:
        json.dump(rows, f, indent=1)
    print(f"# wrote {fname}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--tag")
    ap.add_argument("--solver", metavar="MATRIX",
                    help="price a solver iteration on this bench matrix "
                         "(samg/uhbr/dlr1/...) instead of a model cell")
    ap.add_argument("--scale", type=float, default=0.001)
    ap.add_argument("--method", default="cg",
                    choices=sorted(PM.SOLVER_SPMV_COUNT))
    ap.add_argument("--attn-impl", default="pairs")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--k-chunk", type=int, default=512)
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig field override: name=value")
    ap.add_argument("--baseline-dir", default="experiments/dryrun/single")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    if args.solver:
        solver_main(args)
        return
    if not (args.arch and args.shape and args.tag):
        ap.error("--arch/--shape/--tag are required (or use --solver)")

    from repro.launch.dryrun import dryrun_cell

    overrides = {}
    cfg = configs.get(args.arch)
    for s in args.set:
        name, val = s.split("=", 1)
        cur = getattr(cfg, name)
        if isinstance(cur, bool):
            val = val.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            val = int(val)
        elif isinstance(cur, float):
            val = float(val)
        overrides[name] = val

    rec = dryrun_cell(args.arch, args.shape, "single",
                      q_chunk=args.q_chunk, k_chunk=args.k_chunk,
                      attn_impl=args.attn_impl, overrides=overrides)
    os.makedirs(args.out, exist_ok=True)
    fname = os.path.join(args.out,
                         f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)

    if rec["status"] != "ok":
        print(f"STATUS {rec['status']}: {rec.get('error','')[:400]}")
        return

    new = term_row(rec["cost"], rec["tokens"], rec["chips"],
                   rec["n_active_params"],
                   "train" if args.shape.startswith("train") else "other")
    base_f = os.path.join(args.baseline_dir,
                          f"{args.arch}__{args.shape}.json")
    print(f"== {args.arch} / {args.shape} / {args.tag} ==")
    if os.path.exists(base_f):
        base_rec = json.load(open(base_f))
        if base_rec.get("cost"):
            base = term_row(base_rec["cost"], base_rec["tokens"],
                            base_rec["chips"], base_rec["n_active_params"],
                            "train" if args.shape.startswith("train")
                            else "other")
            for k in ("compute_s", "memory_s", "collective_s", "bound_s",
                      "roofline_fraction"):
                delta = (new[k] - base[k]) / base[k] * 100 if base[k] else 0
                print(f"{k:18s} base={base[k]:.5f} new={new[k]:.5f} "
                      f"({delta:+.1f}%)")
            print(f"dominant: {base['dominant']} -> {new['dominant']}")
            return
    for k, v in new.items():
        print(f"{k:18s} {v}")


if __name__ == "__main__":
    main()
