"""§Perf hillclimbing harness: re-lower a cell with a knob changed and
diff the roofline terms against the recorded baseline.

    PYTHONPATH=src python -m benchmarks.perf_iter \
        --arch deepseek-moe-16b --shape train_4k \
        --tag onehot --set moe_dispatch=onehot

Knobs: --attn-impl pairs|qloop, --q-chunk N, --k-chunk N, and
--set field=value for any ArchConfig field (type-coerced).  Results land
in experiments/perf/<arch>__<shape>__<tag>.json.
"""
from __future__ import annotations

import os
os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=512")

import argparse
import dataclasses
import json

from repro import configs
from repro.core import perf_model as PM


def term_row(cost: dict, tokens: int, chips: int, n_active: int,
             kind: str) -> dict:
    r = PM.roofline_terms(cost["flops"], cost["bytes"],
                          cost["collective_bytes"], chips=1)
    mult = 6 if kind == "train" else 2
    model_flops = mult * n_active * tokens / chips
    bound = r.bound_s
    return dict(compute_s=r.compute_s, memory_s=r.memory_s,
                collective_s=r.collective_s, dominant=r.dominant,
                bound_s=bound,
                roofline_fraction=(model_flops / PM.TPU_V5E.peak_flops)
                / bound if bound else 0.0)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--tag", required=True)
    ap.add_argument("--attn-impl", default="pairs")
    ap.add_argument("--q-chunk", type=int, default=512)
    ap.add_argument("--k-chunk", type=int, default=512)
    ap.add_argument("--set", action="append", default=[],
                    help="ArchConfig field override: name=value")
    ap.add_argument("--baseline-dir", default="experiments/dryrun/single")
    ap.add_argument("--out", default="experiments/perf")
    args = ap.parse_args()

    from repro.launch.dryrun import dryrun_cell

    overrides = {}
    cfg = configs.get(args.arch)
    for s in args.set:
        name, val = s.split("=", 1)
        cur = getattr(cfg, name)
        if isinstance(cur, bool):
            val = val.lower() in ("1", "true", "yes")
        elif isinstance(cur, int):
            val = int(val)
        elif isinstance(cur, float):
            val = float(val)
        overrides[name] = val

    rec = dryrun_cell(args.arch, args.shape, "single",
                      q_chunk=args.q_chunk, k_chunk=args.k_chunk,
                      attn_impl=args.attn_impl, overrides=overrides)
    os.makedirs(args.out, exist_ok=True)
    fname = os.path.join(args.out,
                         f"{args.arch}__{args.shape}__{args.tag}.json")
    with open(fname, "w") as f:
        json.dump(rec, f, indent=1)

    if rec["status"] != "ok":
        print(f"STATUS {rec['status']}: {rec.get('error','')[:400]}")
        return

    new = term_row(rec["cost"], rec["tokens"], rec["chips"],
                   rec["n_active_params"],
                   "train" if args.shape.startswith("train") else "other")
    base_f = os.path.join(args.baseline_dir,
                          f"{args.arch}__{args.shape}.json")
    print(f"== {args.arch} / {args.shape} / {args.tag} ==")
    if os.path.exists(base_f):
        base_rec = json.load(open(base_f))
        if base_rec.get("cost"):
            base = term_row(base_rec["cost"], base_rec["tokens"],
                            base_rec["chips"], base_rec["n_active_params"],
                            "train" if args.shape.startswith("train")
                            else "other")
            for k in ("compute_s", "memory_s", "collective_s", "bound_s",
                      "roofline_fraction"):
                delta = (new[k] - base[k]) / base[k] * 100 if base[k] else 0
                print(f"{k:18s} base={base[k]:.5f} new={new[k]:.5f} "
                      f"({delta:+.1f}%)")
            print(f"dominant: {base['dominant']} -> {new['dominant']}")
            return
    for k, v in new.items():
        print(f"{k:18s} {v}")


if __name__ == "__main__":
    main()
