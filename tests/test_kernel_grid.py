"""Kernel parity grid: format x dtype policy x legal statics, interpret
mode vs the jnp refs.

This is the conformance gate ``repro.tune`` relies on: the autotuner is
free to pick ANY candidate from its search space, so every (format,
dtype policy, b_r, chunk_l, x_tiles) point the space can emit must
compute the same answer through the Pallas kernel as through the ref —
at tolerances set by the STORED value dtype, not by the statics.  The
matrix is built once (deterministic seed, row count not a multiple of
any swept b_r, so every case exercises partial-block padding).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import formats as F
from repro.kernels import ops

N = 160           # not a multiple of 64/128 -> padded tail blocks
_SEED = 0


def _build():
    rng = np.random.default_rng(_SEED)
    rl = np.clip(rng.zipf(1.8, size=N), 1, N // 4)     # skewed rows
    a = np.zeros((N, N), np.float32)
    for i in range(N):
        a[i, rng.integers(0, N, size=rl[i])] = rng.standard_normal(rl[i])
    return a, F.csr_from_dense(a)


_A, _M = _build()
_X = np.random.default_rng(_SEED + 1).standard_normal(N).astype(np.float32)
_TRUTH = _A.astype(np.float64) @ _X

# (value dtype, index_dtype, tolerance vs the f64 dense truth).  Kernel
# vs ref stays tight in BOTH policies: they read identical stored
# values and accumulate >= f32.
_DTYPES = [
    pytest.param(None, np.int32, 1e-4, id="f32+int32"),
    pytest.param(jnp.bfloat16, "auto", 3e-2, id="bf16+auto"),
]
_STATICS = [(32, 8), (64, 16), (128, 8)]        # (b_r, chunk_l)


def _parity(fmt, b_r, chunk_l, x_tiles, dtype, index_dtype, tol):
    sd = ops.as_device(_M, fmt, b_r=b_r, diag_align=max(8, chunk_l),
                       chunk_l=chunk_l, dtype=dtype,
                       index_dtype=index_dtype, x_tiles=x_tiles)
    x = jnp.asarray(_X)
    y_ref = np.asarray(sd.matvec(x, backend="ref"), np.float64)
    y_ker = np.asarray(sd.matvec(x, backend="kernel"), np.float64)
    scale = max(np.abs(_TRUTH).max(), 1.0)
    np.testing.assert_allclose(y_ker / scale, y_ref / scale, atol=1e-5)
    np.testing.assert_allclose(y_ker / scale, _TRUTH / scale, atol=tol)


@pytest.mark.parametrize("dtype,index_dtype,tol", _DTYPES)
@pytest.mark.parametrize("b_r,chunk_l", _STATICS)
@pytest.mark.parametrize("x_tiles", [1, 2])
@pytest.mark.parametrize("fmt", ["pjds", "sell", "cmrs"])
def test_blocked_kernel_grid(fmt, b_r, chunk_l, x_tiles, dtype,
                             index_dtype, tol):
    _parity(fmt, b_r, chunk_l, x_tiles, dtype, index_dtype, tol)


@pytest.mark.parametrize("dtype,index_dtype,tol", _DTYPES)
@pytest.mark.parametrize("b_r,chunk_l", _STATICS)
def test_ellr_kernel_grid(b_r, chunk_l, dtype, index_dtype, tol):
    # the ELLPACK-R kernel keeps x resident: x_tiles is not a legal axis
    _parity("ellpack_r", b_r, chunk_l, 1, dtype, index_dtype, tol)


@pytest.mark.parametrize("b_r,chunk_l", _STATICS[:2])
def test_sell_sigma_axis(b_r, chunk_l):
    # sigma sweeps reshuffle rows across windows; parity must hold at
    # every window size the tuner may choose, incl. the pJDS limit
    for sigma in (b_r, 4 * b_r, N + b_r):
        sd = ops.as_device(_M, "sell", b_r=b_r, diag_align=max(8, chunk_l),
                           chunk_l=chunk_l, sigma=sigma)
        x = jnp.asarray(_X)
        y_ref = np.asarray(sd.matvec(x, backend="ref"), np.float64)
        y_ker = np.asarray(sd.matvec(x, backend="kernel"), np.float64)
        scale = max(np.abs(_TRUTH).max(), 1.0)
        np.testing.assert_allclose(y_ker / scale, y_ref / scale, atol=1e-5)
        np.testing.assert_allclose(y_ref / scale, _TRUTH / scale, atol=1e-4)
