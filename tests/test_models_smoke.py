"""Per-architecture smoke tests (deliverable f): reduced same-family
configs run one forward/train step + prefill/decode on CPU, asserting
output shapes and no NaNs."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs

pytestmark = pytest.mark.slow    # full-architecture lowering, minutes of CPU
from repro.models.api import build_model

S, B = 32, 2


def _batch(cfg, rng):
    b = {
        "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
        "labels": jnp.asarray(rng.integers(0, cfg.vocab, (B, S)), jnp.int32),
    }
    total = S
    if cfg.frontend == "vision":
        b["frontend"] = jnp.asarray(
            rng.standard_normal((B, cfg.frontend_seq, cfg.d_model)),
            jnp.float32)
        total += cfg.frontend_seq
    if cfg.is_encdec:
        b["enc_frames"] = jnp.asarray(
            rng.standard_normal((B, S, cfg.d_model)), jnp.float32)
    return b, total


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_train_step_shapes_no_nan(arch, rng):
    cfg = configs.smoke(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch, _ = _batch(cfg, rng)
    loss, aux = jax.jit(
        lambda p, b: m.loss(p, b, q_chunk=16, k_chunk=16))(params, batch)
    assert np.isfinite(float(loss))
    assert 1.0 < float(loss) < 20.0       # ~ln(vocab) regime
    assert np.isfinite(float(aux["nll"]))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_grads_finite(arch, rng):
    cfg = configs.smoke(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(1))
    batch, _ = _batch(cfg, rng)
    grads = jax.jit(jax.grad(
        lambda p: m.loss(p, batch, q_chunk=16, k_chunk=16)[0]))(params)
    for leaf in jax.tree.leaves(grads):
        assert np.all(np.isfinite(np.asarray(leaf, dtype=np.float32)))


@pytest.mark.parametrize("arch", configs.ARCH_IDS)
def test_prefill_decode_consistency(arch, rng):
    """Incremental decode == full forward: prefill on S tokens, then the
    decode-step logits for token S must match prefill of S+1 tokens."""
    cfg = configs.smoke(arch)
    m = build_model(cfg)
    params = m.init(jax.random.PRNGKey(2))
    toks = rng.integers(0, cfg.vocab, (B, S + 1)).astype(np.int32)
    batch_s, total = _batch(cfg, rng)
    batch_s = dict(batch_s)
    batch_s["tokens"] = jnp.asarray(toks[:, :S])
    batch_s.pop("labels")
    max_len = total + 8

    cache, logits_s = jax.jit(
        lambda p, b: m.prefill(p, b, max_len=max_len, q_chunk=16,
                               k_chunk=16))(params, batch_s)
    pos = jnp.full((B,), total, jnp.int32)
    _, logits_step = jax.jit(m.decode_step)(
        params, cache, jnp.asarray(toks[:, S:S + 1]), pos)

    batch_s1 = dict(batch_s)
    batch_s1["tokens"] = jnp.asarray(toks)
    _, logits_full = jax.jit(
        lambda p, b: m.prefill(p, b, max_len=max_len + 1, q_chunk=16,
                               k_chunk=16))(params, batch_s1)
    a = np.asarray(logits_step[:, -1])
    b_ = np.asarray(logits_full[:, -1])
    # compare post-softmax (logit scale differs by masked -1e30 tail)
    pa = jax.nn.softmax(jnp.asarray(a)[:, :cfg.vocab], axis=-1)
    pb = jax.nn.softmax(jnp.asarray(b_)[:, :cfg.vocab], axis=-1)
    np.testing.assert_allclose(np.asarray(pa), np.asarray(pb),
                               atol=5e-3, rtol=1e-2)


def test_local_vs_global_attention_differ(rng):
    """gemma3 smoke: the sliding window must actually change attention."""
    from repro.models import attention as A
    q = jnp.asarray(rng.standard_normal((1, 64, 4, 16)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.float32)
    full = A.flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    loc = A.flash_attention(q, k, v, causal=True, window=8, q_chunk=16,
                            k_chunk=16)
    assert not np.allclose(np.asarray(full), np.asarray(loc))
    # first window tokens see identical context
    np.testing.assert_allclose(np.asarray(full[:, :8]),
                               np.asarray(loc[:, :8]), atol=1e-5)


def test_flash_attention_vs_naive(rng):
    from repro.models import attention as A
    b, s, hq, hkv, d = 2, 48, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    out = A.flash_attention(q, k, v, causal=True, q_chunk=16, k_chunk=16)
    # naive reference
    g = hq // hkv
    qg = np.asarray(q).reshape(b, s, hkv, g, d)
    scores = np.einsum("bqhgd,bkhd->bqhgk", qg, np.asarray(k)) / np.sqrt(d)
    mask = np.tril(np.ones((s, s), bool))
    scores = np.where(mask[None, :, None, None, :], scores, -np.inf)
    p = np.exp(scores - scores.max(-1, keepdims=True))
    p /= p.sum(-1, keepdims=True)
    ref = np.einsum("bqhgk,bkhd->bqhgd", p, np.asarray(v)).reshape(b, s, hq, d)
    np.testing.assert_allclose(np.asarray(out), ref, atol=2e-5, rtol=1e-3)


def test_qloop_attention_matches_pairs(rng):
    """The §Perf alternative attention schedule is numerically identical."""
    from repro.models import attention as A
    b, s, hq, hkv, d = 2, 64, 4, 2, 16
    q = jnp.asarray(rng.standard_normal((b, s, hq, d)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((b, s, hkv, d)), jnp.float32)
    for causal, window in [(True, None), (True, 8), (False, None)]:
        base = A.flash_attention(q, k, v, causal=causal, window=window,
                                 q_chunk=16, k_chunk=16)
        with A.use_attn_impl("qloop"):
            alt = A.flash_attention(q, k, v, causal=causal, window=window,
                                    q_chunk=16, k_chunk=16)
        np.testing.assert_allclose(np.asarray(base), np.asarray(alt),
                                   atol=1e-5)


def test_mamba_train_matches_stepwise(rng):
    """Chunked-scan train path == sequential decode recurrence."""
    from repro.models import ssm as SSM
    cfg = configs.smoke("falcon-mamba-7b")
    import repro.models.common as C
    key = jax.random.PRNGKey(0)
    p, _ = SSM.mamba_init(key, cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    y_train, _ = SSM.mamba_apply_train(p, cfg, x, ssm_chunk=4)
    cache = SSM.mamba_cache_init(cfg, 2, jnp.float32)
    outs = []
    for t in range(16):
        y_t, cache = SSM.mamba_apply_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(np.asarray(y_t))
    y_step = np.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_train), y_step, atol=1e-4,
                               rtol=1e-3)


def test_rglru_train_matches_stepwise(rng):
    from repro.models import rglru as RG
    cfg = configs.smoke("recurrentgemma-2b")
    p, _ = RG.rglru_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 12, cfg.d_model)), jnp.float32)
    y_train, _ = RG.rglru_apply_train(p, cfg, x, scan_chunk=4)
    cache = RG.rglru_cache_init(cfg, 2, jnp.float32)
    outs = []
    for t in range(12):
        y_t, cache = RG.rglru_apply_decode(p, cfg, x[:, t:t + 1], cache)
        outs.append(np.asarray(y_t))
    np.testing.assert_allclose(np.asarray(y_train),
                               np.concatenate(outs, axis=1), atol=1e-4,
                               rtol=1e-3)


def test_moe_top1_equals_dense_expert(rng):
    """With 1 expert and top-1, MoE must reduce to that expert's FFN."""
    import dataclasses
    from repro.models import moe as MOE, ffn as FF
    cfg = dataclasses.replace(configs.smoke("granite-moe-3b-a800m"),
                              n_experts=1, top_k=1, capacity_factor=2.0)
    p, _ = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 8, cfg.d_model)), jnp.float32)
    y, aux = MOE.moe_apply(p, cfg, x)
    ffn_p = {"w1": {"w": p["w1"][0]}, "w3": {"w": p["w3"][0]},
             "w2": {"w": p["w2"][0]}}
    y_ref = FF.ffn_apply(ffn_p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_ref), atol=1e-5)


def test_moe_sorted_matches_onehot_dispatch(rng):
    """The pJDS-analogue sorted dispatch == the GShard one-hot baseline
    when nothing is dropped (high capacity)."""
    import dataclasses
    from repro.models import moe as MOE
    cfg = dataclasses.replace(configs.smoke("deepseek-moe-16b"),
                              capacity_factor=4.0)
    p, _ = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    y_sorted, _ = MOE.moe_apply(p, cfg, x)
    y_onehot, _ = MOE.moe_apply(
        p, dataclasses.replace(cfg, moe_dispatch="onehot"), x)
    np.testing.assert_allclose(np.asarray(y_sorted), np.asarray(y_onehot),
                               atol=1e-5)


def test_moe_local_shard_dispatch_matches_global(rng):
    """§Perf lever: per-data-shard (vmapped) dispatch is numerically
    identical to the global sort when capacities don't drop."""
    import dataclasses
    from repro.models import moe as MOE
    cfg = dataclasses.replace(configs.smoke("deepseek-moe-16b"),
                              capacity_factor=4.0)
    p, _ = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((4, 32, cfg.d_model)), jnp.float32)
    y_g, _ = MOE.moe_apply(p, cfg, x)
    y_l, _ = MOE.moe_apply(
        p, dataclasses.replace(cfg, moe_local_shards=4), x)
    np.testing.assert_allclose(np.asarray(y_g), np.asarray(y_l), atol=1e-5)


def test_moe_load_balance_aux_positive(rng):
    cfg = configs.smoke("deepseek-moe-16b")
    from repro.models import moe as MOE
    p, _ = MOE.moe_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 32, cfg.d_model)), jnp.float32)
    y, aux = MOE.moe_apply(p, cfg, x)
    assert float(aux) > 0
    assert np.all(np.isfinite(np.asarray(y)))
