"""SparseFFN (pJDS-stored pruned weights) vs pruned-dense reference."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.sparse.sparse_ffn import (SparseLinear, sparse_ffn_apply,
                                     sparsify_ffn_params)


def _pruned(w, density):
    k = max(int(w.size * density), 1)
    th = np.partition(np.abs(w).ravel(), -k)[-k]
    return np.where(np.abs(w) >= th, w, 0.0)


@pytest.mark.parametrize("density", [0.05, 0.2, 0.5])
@pytest.mark.parametrize("backend", ["ref", "kernel"])
def test_sparse_linear_matches_pruned_dense(rng, density, backend):
    w = rng.standard_normal((96, 160)).astype(np.float32)
    sl = SparseLinear.from_dense(w, density, b_r=32)
    x = rng.standard_normal((3, 5, 96)).astype(np.float32)
    y = np.asarray(sl(jnp.asarray(x), backend=backend))
    ref = x @ _pruned(w, density)
    np.testing.assert_allclose(y, ref, atol=1e-4)


def test_memory_summary_shrinks_with_density(rng):
    w = rng.standard_normal((256, 512)).astype(np.float32)
    hi = SparseLinear.from_dense(w, 0.5, b_r=32).memory_summary()
    lo = SparseLinear.from_dense(w, 0.05, b_r=32).memory_summary()
    assert lo["pjds_bytes"] < hi["pjds_bytes"]
    # at 5% density the pJDS footprint beats dense bf16
    assert lo["ratio_vs_dense"] < 0.5


def test_padding_overhead_small_at_scale(rng):
    """Paper: pJDS overhead vs nnz-only storage < 1% for real matrices.
    Magnitude-pruned FFN rows vary in length — the pJDS sweet spot."""
    w = rng.standard_normal((512, 1024)).astype(np.float32)
    sl = SparseLinear.from_dense(w, 0.1, b_r=32)
    assert sl.memory_summary()["padding_overhead"] < 0.10


def test_sparse_ffn_full_block(rng):
    from repro import configs
    from repro.models import ffn as FF
    cfg = configs.smoke("qwen2.5-14b")
    p, _ = FF.ffn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 4, cfg.d_model)), jnp.float32)
    dense_y = FF.ffn_apply(p, cfg, x)
    sp = sparsify_ffn_params(p, density=1.0)   # keep all weights
    y = sparse_ffn_apply(sp, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense_y), atol=1e-3,
                               rtol=1e-3)


def test_sparse_linear_is_pytree_and_jits(rng):
    """SparseLinear params flow through jit like dense weights — the
    serving engine's decode step carries them as pytree leaves."""
    w = rng.standard_normal((96, 160)).astype(np.float32)
    sl = SparseLinear.from_dense(w, 0.2, b_r=32)
    x = jnp.asarray(rng.standard_normal((3, 96)).astype(np.float32))
    leaves, treedef = jax.tree_util.tree_flatten(sl)
    sl2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(sl(x)), np.asarray(sl2(x)))
    y_jit = jax.jit(lambda layer, xx: layer(xx))(sl, x)
    np.testing.assert_allclose(np.asarray(y_jit), np.asarray(sl(x)),
                               atol=1e-5)


def test_ffn_apply_dispatches_sparse_params(rng):
    """models.ffn.ffn_apply accepts SparseLinear leaves in place of the
    dense w-dicts (density=1 keeps every weight -> matches dense)."""
    from repro import configs
    from repro.models import ffn as FF
    cfg = configs.smoke("qwen2.5-14b")
    p, _ = FF.ffn_init(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jnp.asarray(rng.standard_normal((2, 4, cfg.d_model)), jnp.float32)
    sp = sparsify_ffn_params(p, density=1.0)
    y = FF.ffn_apply(sp, cfg, x)
    dense_y = FF.ffn_apply(p, cfg, x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(dense_y),
                               atol=1e-3, rtol=1e-3)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 9999), density=st.floats(0.05, 0.9))
def test_sparse_linear_property(seed, density):
    rng = np.random.default_rng(seed)
    w = rng.standard_normal((64, 96)).astype(np.float32)
    sl = SparseLinear.from_dense(w, density, b_r=32)
    x = rng.standard_normal((4, 64)).astype(np.float32)
    y = np.asarray(sl(jnp.asarray(x)))
    np.testing.assert_allclose(y, x @ _pruned(w, density), atol=1e-4)
