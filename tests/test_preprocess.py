"""The priced preprocessing stage: RCM bandwidth property, transparent
permute/unpermute round trips, model-gated application, and the
distributed halo-bytes win (DESIGN.md §13)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import assume, given, settings, strategies as st

from repro.core import dist_spmv as D, formats as F
from repro.core.operator import operator
from repro.core.reorder import bandwidth, permute_symmetric, preprocess
from repro.kernels import ops


def _banded(n, band, seed=None, integer_values=True):
    """Symmetric band matrix, optionally shuffled by a random symmetric
    permutation (seed!=None).  Integer-valued f32 data so any summation
    order is bit-exact."""
    i = np.arange(n, dtype=np.int64)
    offs = np.arange(-band, band + 1, dtype=np.int64)
    rows = np.repeat(i, len(offs))
    cols = rows + np.tile(offs, n)
    keep = (cols >= 0) & (cols < n)
    rows, cols = rows[keep], cols[keep]
    lo, hi = np.minimum(rows, cols), np.maximum(rows, cols)
    data = ((lo * 31 + hi * 17) % 7 + 1).astype(np.float32)
    m = F.csr_from_coo(rows, cols, data, shape=(n, n))
    if seed is not None:
        m = permute_symmetric(m, np.random.default_rng(seed).permutation(n))
    return m


# -- property: RCM never increases bandwidth on connected symmetric ----
@settings(max_examples=20, deadline=None)
@given(n=st.integers(40, 300), band=st.integers(1, 5),
       seed=st.integers(0, 10_000))
def test_rcm_never_increases_bandwidth(n, band, seed):
    from repro.core.reorder import rcm_permutation
    m = _banded(n, band, seed=seed)
    bw0 = bandwidth(m)
    assume(bw0 > 4 * band)         # the shuffle actually destroyed the band
    bw1 = bandwidth(permute_symmetric(m, rcm_permutation(m)))
    assert bw1 <= bw0
    # a connected band-b graph admits a BFS level width <= 2b
    assert bw1 <= 2 * band


def test_preprocess_forced_matvec_bit_exact(rng):
    m = _banded(512, 3, seed=1)
    pp = preprocess(m, reorder="rcm")
    assert pp.applied and pp.reason == "forced"
    op = operator(m, reorder="rcm")
    op0 = operator(m)
    x = rng.integers(-3, 4, size=m.shape[1]).astype(np.float32)
    assert np.array_equal(np.asarray(op @ x), np.asarray(op0 @ x))
    y = rng.integers(-3, 4, size=m.shape[0]).astype(np.float32)
    assert np.array_equal(np.asarray(op.T @ y), np.asarray(op0.T @ y))
    xs = rng.integers(-3, 4, size=(m.shape[1], 4)).astype(np.float32)
    assert np.array_equal(np.asarray(op @ xs), np.asarray(op0 @ xs))


def test_preprocess_diagonal_unpermuted(rng):
    m = _banded(256, 2, seed=2)
    op = operator(m, reorder="rcm")
    assert np.array_equal(np.asarray(op.diagonal()),
                          np.asarray(F.csr_diagonal(m)))


def test_preprocess_auto_declines_single_device():
    m = _banded(2048, 3, seed=5)
    pp = preprocess(m, reorder="auto", value_bytes=4)
    assert not pp.applied
    assert pp.reason.startswith("predicted_loss")
    # ... and as_device honours the decision: no permutation attached
    sd = ops.as_device(m, reorder="auto")
    assert sd.pre_perm is None


def test_preprocess_auto_applies_distributed():
    m = _banded(2048, 3, seed=5)
    pp = preprocess(m, reorder="auto", n_dev=8, value_bytes=4)
    assert pp.applied
    assert pp.reason.startswith("predicted_gain")
    assert pp.bandwidth_after < pp.bandwidth_before


def test_reordered_partition_ships_fewer_comm_bytes():
    m = _banded(2048, 3, seed=5)
    pp = preprocess(m, reorder="rcm")
    n_dev = 8
    off = D.partition_csr(m, n_dev).comm_bytes_per_device(value_bytes=4)
    on = D.partition_csr(pp.matrix, n_dev).comm_bytes_per_device(
        value_bytes=4)
    assert on <= off
    assert on < off / 10           # the band recovery is dramatic, not marginal


def test_preprocess_off_is_identity():
    m = _banded(128, 2, seed=3)
    pp = preprocess(m, reorder="off")
    assert not pp.applied and pp.matrix is m


def test_preprocess_rejects_bad_mode():
    m = _banded(64, 1)
    with pytest.raises(ValueError, match="reorder"):
        preprocess(m, reorder="bogus")
    with pytest.raises(ValueError, match="reorder"):
        ops.as_device(m, reorder="bogus")


def test_preprocess_cache_key_separation(rng):
    m = _banded(256, 2, seed=4)
    sd_off = ops.as_device(m)
    sd_on = ops.as_device(m, reorder="rcm")
    assert sd_on is not sd_off
    assert sd_on.pre_perm is not None and sd_off.pre_perm is None
    assert ops.as_device(m, reorder="rcm") is sd_on     # cache hit


def test_preprocess_non_square():
    d = np.zeros((6, 9), np.float32)
    d[1, 2] = 1.0
    m = F.csr_from_dense(d)
    pp = preprocess(m, reorder="auto")
    assert not pp.applied and pp.reason == "non_square"
    with pytest.raises(ValueError):
        preprocess(m, reorder="rcm")
