"""Distributed training integration: a reduced model trains for real on
an 8-device host mesh (4 data x 2 model) through the same pjit wiring the
dry-run lowers, including ZeRO-1 opt-state sharding and an elastic
restart on a different mesh (8 -> 4 devices)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = [pytest.mark.dist, pytest.mark.slow]

_SCRIPT = textwrap.dedent("""
    import os, json, tempfile
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro import configs
    from repro.models.api import build_model
    from repro.models.sharding import use_rules
    from repro.train.optimizer import AdamW
    from repro.train.schedules import constant
    from repro.train.step import (make_train_step, train_state_shardings,
                                  batch_shardings)
    from repro.checkpoint import store
    from repro._compat import set_mesh, make_mesh

    def mesh_of(dp, tp):
        return make_mesh((dp, tp), ("data", "model"))

    cfg = dataclasses.replace(
        configs.smoke("qwen2.5-14b"), d_model=64, d_ff=128, n_layers=2)
    model = build_model(cfg)
    rules = {"batch": ("data",), "model": ("model",), "expert": ("model",),
             "seq": None, "kvseq": None}
    out = {}

    def build(mesh):
        with set_mesh(mesh), use_rules(rules):
            param_sh, opt_sh = train_state_shardings(model, mesh, rules)
            opt = AdamW(lr_fn=constant(1e-3))
            step = jax.jit(
                make_train_step(model, opt, q_chunk=16, k_chunk=16),
                in_shardings=(param_sh, opt_sh, None),
                out_shardings=(param_sh, opt_sh, None))
            return opt, step, param_sh, opt_sh

    mesh8 = mesh_of(4, 2)
    opt, step, param_sh, opt_sh = build(mesh8)
    with set_mesh(mesh8), use_rules(rules):
        params = jax.jit(model.init, out_shardings=param_sh)(
            jax.random.PRNGKey(0))
        opt_state = jax.jit(opt.init, out_shardings=opt_sh)(params)
        rng = np.random.default_rng(0)
        losses = []
        for i in range(6):
            batch = {
                "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                      jnp.int32),
                "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                      jnp.int32),
            }
            params, opt_state, metrics = step(params, opt_state, batch)
            losses.append(float(metrics["loss"]))
        out["losses"] = losses
        # ZeRO-1: the biggest master-weight leaf must be sharded over
        # BOTH axes (param TP-sharding + data sharding)
        leaves = jax.tree.leaves(opt_state.master)
        big = max(leaves, key=lambda x: x.size)
        out["master_ndev"] = int(big.sharding.num_devices)
        out["master_is_fully_sharded"] = not big.sharding.is_fully_replicated
        tmp = tempfile.mkdtemp()
        store.save(tmp, 6, (params, opt_state))

    # elastic restart on a 4-device mesh
    mesh4 = mesh_of(2, 2)
    opt4, step4, p_sh4, o_sh4 = build(mesh4)
    with set_mesh(mesh4), use_rules(rules):
        tgt = (jax.eval_shape(model.init, jax.random.PRNGKey(0)),
               jax.eval_shape(opt4.init,
                              jax.eval_shape(model.init,
                                             jax.random.PRNGKey(0))))
        (params4, opt_state4), _ = store.restore(tmp, 6, tgt,
                                                 shardings=(p_sh4, o_sh4))
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 32)),
                                  jnp.int32),
        }
        params4, opt_state4, metrics4 = step4(params4, opt_state4, batch)
        out["resumed_loss"] = float(metrics4["loss"])
        out["resumed_step"] = int(opt_state4.step)
    print("OUT " + json.dumps(out))
""")


@pytest.fixture(scope="module")
def results():
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-4000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("OUT ")][-1]
    return json.loads(line[4:])


def test_loss_decreases_on_mesh(results):
    assert results["losses"][-1] < results["losses"][0]


def test_zero1_master_sharded(results):
    assert results["master_is_fully_sharded"]
    assert results["master_ndev"] == 8


def test_elastic_restart_trains(results):
    assert results["resumed_step"] == 7
    import math
    assert math.isfinite(results["resumed_loss"])
    assert results["resumed_loss"] < results["losses"][0]
