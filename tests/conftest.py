import os

import numpy as np
import pytest

# When hypothesis is not installed (the pinned container omits it; CI
# installs the real package), register the deterministic fallback before
# test modules import it.
from repro._compat import hypothesis_fallback

hypothesis_fallback.install()

import hypothesis  # noqa: E402  (the real package or the fallback)

# One seed policy for the whole suite (mirrored by
# benchmarks/common.DEFAULT_SEED): every test draws from a generator
# seeded here, so a failure reproduces without hunting for the RNG state.
DEFAULT_SEED = 0

# With the real hypothesis, pin CI to a fixed, deadline-free profile so
# the property jobs are deterministic and never flake on shared-runner
# timing (select with HYPOTHESIS_PROFILE=ci; the fallback is inherently
# deterministic and ignores profiles).
if not getattr(hypothesis, "__is_fallback__", False):
    hypothesis.settings.register_profile(
        "ci", deadline=None, max_examples=20, derandomize=True,
        print_blob=True)
    hypothesis.settings.register_profile("dev", deadline=None)
    hypothesis.settings.load_profile(
        os.environ.get("HYPOTHESIS_PROFILE", "dev"))


@pytest.fixture
def rng():
    return np.random.default_rng(DEFAULT_SEED)
