import numpy as np
import pytest

# When hypothesis is not installed (the pinned container omits it; CI
# installs the real package), register the deterministic fallback before
# test modules import it.
from repro._compat import hypothesis_fallback

hypothesis_fallback.install()


@pytest.fixture
def rng():
    return np.random.default_rng(0)
