"""Dry-run machinery smoke test (subprocess, one cheap decode cell).

The full 40-cell x 2-mesh sweep runs via
``python -m repro.launch.dryrun --all`` and its results are recorded in
EXPERIMENTS.md; this test proves the machinery end-to-end on the
cheapest cell so CI catches regressions in the lowering path.
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

_SCRIPT = textwrap.dedent("""
    from repro.launch.dryrun import dryrun_cell
    import json
    rec = dryrun_cell("seamless-m4t-medium", "decode_32k", "single",
                      with_cost=False)
    print("REC " + json.dumps({k: rec[k] for k in
          ("status", "chips", "hlo_flops_raw")
          if k in rec} | {"err": rec.get("error", "")[:200]}))
""")


@pytest.mark.slow
def test_dryrun_decode_cell():
    env = dict(os.environ, PYTHONPATH=os.path.join(
        os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines() if l.startswith("REC ")][-1]
    rec = json.loads(line[4:])
    assert rec["status"] == "ok", rec
    assert rec["chips"] == 256
    assert rec["hlo_flops_raw"] > 0


def test_skip_table_covers_non_subquadratic():
    from repro.launch.dryrun import SKIP
    from repro import configs
    for arch in configs.ARCH_IDS:
        cfg = configs.get(arch)
        if not cfg.subquadratic:
            assert (arch, "long_500k") in SKIP, arch
        else:
            assert (arch, "long_500k") not in SKIP, arch


def test_rules_for_context_parallel_decode():
    from repro.models.sharding import rules_for
    # long_500k: B=1 cannot shard over data -> kvseq takes the axis
    r = rules_for("decode", 1, {"data": 16, "model": 16})
    assert r["batch"] is None and r["kvseq"] == ("data",)
    r2 = rules_for("decode", 128, {"pod": 2, "data": 16, "model": 16})
    assert r2["batch"] == ("pod", "data") and r2["kvseq"] is None
    r3 = rules_for("train", 256, {"data": 16, "model": 16})
    assert r3["batch"] == ("data",)


def test_collective_bytes_parser():
    from repro.launch.hlo_analysis import collective_bytes
    hlo = """
      %ag = f32[16,512]{1,0} all-gather(f32[1,512]{1,0} %p), replica_groups={{0,1,2,3,4,5,6,7,8,9,10,11,12,13,14,15}}, dimensions={0}
      %ar = bf16[1024]{0} all-reduce(bf16[1024]{0} %x), replica_groups=[16,16]
      %agd = f32[16,512]{1,0} all-gather-done(f32[16,512]{1,0} %ags)
      %cp = f32[256]{0} collective-permute(f32[256]{0} %y), source_target_pairs={{0,1}}
    """
    r = collective_bytes(hlo)
    assert r["counts"] == {"all-gather": 1, "all-reduce": 1,
                           "collective-permute": 1}
    ag = 16 * 512 * 4 * 15 / 16
    ar = 2 * 1024 * 2 * 15 / 16
    cp = 256 * 4
    assert abs(r["total"] - (ag + ar + cp)) < 1e-6
