"""The paper's performance model (Eq. 1-4): reproduce its own numbers."""
import numpy as np
import pytest

from repro.core import perf_model as PM


def test_code_balance_dp_matches_eq1():
    # B_W^DP = 6 + 4*alpha + 8/N_nzr  (paper Eq. 1)
    for alpha in (0.1, 0.5, 1.0):
        for n in (7, 15, 123):
            assert PM.code_balance(alpha, n, value_bytes=8) == pytest.approx(
                6 + 4 * alpha + 8 / n)


def test_code_balance_sp():
    # SP: (4+4+4a+8/N)/2 = 4 + 2a + 4/N
    assert PM.code_balance(0.5, 16, value_bytes=4) == pytest.approx(
        4 + 1.0 + 0.25)


def test_alpha_range():
    lo, hi = PM.alpha_range(15)
    assert lo == pytest.approx(1 / 15) and hi == 1.0


def test_eq3_paper_numbers():
    """Paper §2.2: alpha=1/N_nzr and B_GPU ~ 20*B_PCI -> N_nzr <= 25;
    alpha=1, B_GPU ~ 10*B_PCI -> N_nzr <= 7."""
    # worst case: alpha = 1/n, solve self-consistently like the paper
    # (they use alpha ~ 0 in the denominator: 2*19/1.5 ~ 25)
    n = PM.n_nzr_upper_for_link_penalty(20.0, 1.0, alpha=0.08)
    assert 24 <= n <= 26
    n2 = PM.n_nzr_upper_for_link_penalty(10.0, 1.0, alpha=1.0)
    assert 7 <= n2 <= 7.3


def test_eq4_paper_numbers():
    """Paper: B_GPU ~ 10*B_PCI, alpha=1 -> N_nzr >= 80 sufficient;
    B_GPU ~ 20*B_PCI, alpha ~ 0 -> N_nzr >= 266."""
    n = PM.n_nzr_lower_for_link_penalty(10.0, 1.0, alpha=1.0)
    assert 79 <= n <= 80
    n2 = PM.n_nzr_lower_for_link_penalty(20.0, 1.0, alpha=0.0)
    assert 264 <= n2 <= 266


def test_paper_conclusion_hmep_samg_not_worthwhile():
    """Paper §3: HMEp (N_nzr~15) and sAMG (~7) fall below the Eq. 3
    threshold for the paper's hardware ratio -> no accelerator benefit."""
    thresh = PM.n_nzr_upper_for_link_penalty(20.0, 1.0, alpha=0.08)
    assert 15 < thresh and 7 < thresh          # both below threshold
    # DLR/UHBR (123-315) are clear of the 50%-penalty region
    assert 123 > thresh and 315 > thresh


def test_tpu_thresholds_documented():
    """Same analysis with TPU v5e numbers: HBM 819 GB/s vs ICI 50 GB/s/link
    gives ratio ~16 -> N_nzr <= ~19 is link-dominated."""
    spec = PM.TPU_V5E
    n = PM.n_nzr_upper_for_link_penalty(spec.hbm_bw, spec.ici_bw, alpha=0.1)
    assert 15 < n < 25


def test_t_mvm_t_link_crossover():
    n_rows = 1e6
    t_m = PM.t_mvm(n_rows, n_nzr=100, alpha=0.1, dev_bw=819e9)
    t_l = PM.t_link(n_rows, link_bw=50e9)
    assert t_m > t_l  # large N_nzr: compute dominates the link
    t_m2 = PM.t_mvm(n_rows, n_nzr=5, alpha=0.1, dev_bw=819e9)
    assert t_m2 < 3 * t_l


def test_t_link_gathered_prices_measured_halo():
    """The gathered-halo link term charges only the referenced entries:
    it agrees with t_link when the whole slice is referenced (plus the
    LHS return leg t_link also counts) and vanishes for block-diagonal
    partitions."""
    n_loc, link = 10_000, 50e9
    # halo == full slice in both directions ~ the t_link regime
    full = PM.t_link_gathered(2 * n_loc, link, value_bytes=8)
    assert full == pytest.approx(PM.t_link(n_loc, link, value_bytes=8))
    # measured coupling of 80 entries: 2*n_loc/80 = 250x cheaper
    sparse = PM.t_link_gathered(80, link, value_bytes=8)
    assert sparse * 250 == pytest.approx(full)
    assert PM.t_link_gathered(0, link) == 0.0
    # multi-RHS scales linearly
    assert PM.t_link_gathered(80, link, k=4) == pytest.approx(4 * sparse)


def test_t_link_gathered_msgs_term():
    """The per-message fixed cost and the link bandwidth scale only act
    through an installed/passed calibration; the old positional
    signature (no msgs, no calibration) is unchanged."""
    link = 50e9
    plain = PM.t_link_gathered(80, link, value_bytes=8)
    # msgs without calibration: fixed cost is 0, nothing changes
    assert PM.t_link_gathered(80, link, value_bytes=8, msgs=4,
                              calibration=None) == pytest.approx(plain)
    cal = PM.Calibration(bw_scale=1.0, link_bw_scale=0.5,
                         msg_overhead_s={"gathered": 25e-6, "full": 5e-6})
    got = PM.t_link_gathered(80, link, value_bytes=8, msgs=4,
                             halo="gathered", calibration=cal)
    assert got == pytest.approx(8 * 80 / (link * 0.5) + 4 * 25e-6)
    # the full flavour pays its own (cheaper) per-message cost
    got_f = PM.t_link_gathered(80, link, value_bytes=8, msgs=4,
                               halo="full", calibration=cal)
    assert got_f == pytest.approx(8 * 80 / (link * 0.5) + 4 * 5e-6)
    # unknown halo key costs 0 fixed (data-sheet behaviour)
    assert PM.t_link_gathered(80, link, value_bytes=8, msgs=4,
                              halo="exotic", calibration=cal) \
        == pytest.approx(8 * 80 / (link * 0.5))


def test_calibration_link_fields_validate():
    with pytest.raises(ValueError):
        PM.Calibration(bw_scale=1.0, link_bw_scale=0.0)
    with pytest.raises(ValueError):
        PM.Calibration(bw_scale=1.0, link_bw_scale=-2.0)
    cal = PM.Calibration(bw_scale=1.0)
    assert cal.link_bw_scale == 1.0 and dict(cal.msg_overhead_s) == {}


def _banded_partition(halo_w=1, n=256, n_dev=4, reach=None):
    """Diagonal plus a strided off-band: only every 4th row couples
    across the device boundary, so the gathered halo is genuinely
    smaller than the full neighbor slice."""
    from repro.core import dist_spmv as D, formats as F
    reach = reach if reach is not None else 64 * halo_w
    rows, cols, vals = [], [], []
    for r in range(n):
        offs = (r - reach, r, r + reach) if r % 4 == 0 else (r,)
        for c in offs:
            if 0 <= c < n:
                rows.append(r), cols.append(c), vals.append(1.0 + r + c)
    m = F.csr_from_coo(np.array(rows), np.array(cols),
                       np.array(vals, np.float32), (n, n))
    return D.partition_csr(m, n_dev, b_r=32)


def test_choose_halo_crossover():
    """Without a calibration the gathered exchange's byte advantage wins;
    a calibration pricing the gathered per-message set-up flips the
    decision — the measured toy-scale behaviour."""
    dist = _banded_partition(halo_w=1)
    assert dist.halo_w >= 1
    g_bytes = dist.comm_bytes_per_device(halo="gathered")
    f_bytes = dist.comm_bytes_per_device(halo="full")
    assert g_bytes < f_bytes
    assert PM.choose_halo(dist, calibration=None) == "gathered"
    pricey = PM.Calibration(bw_scale=1.0,
                            msg_overhead_s={"gathered": 1e-2})
    assert PM.choose_halo(dist, calibration=pricey) == "full"


def test_choose_halo_tie_goes_gathered():
    # block-diagonal: halo_w == 0, nothing crosses the wire either way
    from repro.core import dist_spmv as D, formats as F
    blk = np.kron(np.eye(4, dtype=np.float32),
                  np.arange(1, 65 * 64 + 1, dtype=np.float32)[:64 * 64]
                  .reshape(64, 64))
    dist = D.partition_csr(F.csr_from_dense(blk), 4, b_r=32)
    assert dist.halo_w == 0
    assert PM.choose_halo(dist, calibration=None) == "gathered"


def test_predicted_dist_overlap_hides_comm():
    """Bulk-synchronous modes serialize compute after comm; the
    overlapped modes charge max(local, comm) + remote, so they can
    never predict slower."""
    dist = _banded_partition(halo_w=1)
    for halo in ("gathered", "full"):
        t_bulk = PM.predicted_dist_spmv_seconds(
            dist, halo, "vector", calibration=None)
        t_ovl = PM.predicted_dist_spmv_seconds(
            dist, halo, "overlap", calibration=None)
        t_pipe = PM.predicted_dist_spmv_seconds(
            dist, halo, "pipeline", calibration=None)
        assert 0 < t_ovl <= t_bulk
        assert t_pipe == pytest.approx(t_ovl)
    # multi-RHS scales the wire term
    t1 = PM.predicted_dist_spmv_seconds(dist, "gathered", "vector",
                                        calibration=None)
    t4 = PM.predicted_dist_spmv_seconds(dist, "gathered", "vector", k=4,
                                        calibration=None)
    assert t4 > t1


def test_roofline_terms():
    r = PM.roofline_terms(hlo_flops=1e15, hlo_bytes=1e13,
                          collective_bytes=1e11, chips=256)
    assert r.compute_s == pytest.approx(1e15 / (256 * 197e12))
    assert r.memory_s == pytest.approx(1e13 / (256 * 819e9))
    assert r.collective_s == pytest.approx(1e11 / (256 * 50e9))
    assert r.dominant in ("compute", "memory", "collective")


def test_spmvm_bytes_model():
    b = PM.spmvm_bytes(stored_elements=1000, n_rows=100, alpha=1.0,
                       n_nzr=10, value_bytes=8)
    assert b == 1000 * 12 + 1.0 * 10 * 100 * 8 + 2 * 100 * 8
