"""SparseOperator protocol: conformance, transpose, autodiff, solvers.

The acceptance surface of the operator redesign (DESIGN.md §8):

* a conformance suite every implementation must pass — shapes, matvec /
  matmat / rmatvec / rmatmat against the dense reference, lazy ``.T``,
  pytree round-trips, jit and ``lax.while_loop`` carriers;
* property tests ``A.T @ x == dense.T @ x`` and ``jax.grad`` (through
  stored values AND x) vs the dense gradient, across all four formats;
* ONE solver source running unmodified on a single-device operator and
  on a distributed mesh operator (the mesh half runs in a subprocess
  with 8 host devices, like the other distributed tests);
* the new solvers: Jacobi-preconditioned CG and BiCGStab (whose dual
  ``A^T y = c`` solve exercises ``rmatvec`` through ``op.T``).
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F, matrices as M, solvers as S
from repro.core.operator import (DeviceOperator, TransposeOperator, operator)
from repro.kernels import ops

B_R = 32
FORMATS = ["csr", "ellpack_r", "pjds", "sell"]


def _random_sparse(rng, n_rows, n_cols, density=0.1):
    a = ((rng.random((n_rows, n_cols)) < density)
         * rng.standard_normal((n_rows, n_cols))).astype(np.float32)
    return a


def _scaled_close(got, want, atol=1e-5):
    scale = max(np.abs(want).max(), 1.0)
    np.testing.assert_allclose(got / scale, want / scale, atol=atol)


# --------------------------------------------------------------------------
# Conformance suite (single-device; the Dist half runs in the subprocess)
# --------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("transpose", ["ref", "device"])
def test_conformance_device_operator(rng, fmt, transpose):
    a = _random_sparse(rng, 96, 160)
    m = F.csr_from_dense(a)
    op = operator(m, format=fmt, b_r=B_R, transpose=transpose)

    assert op.shape == (96, 160)
    assert op.dtype == np.float32
    assert op.fmt == fmt
    assert isinstance(op.T, TransposeOperator)
    assert op.T.shape == (160, 96)
    assert op.T.T is op                      # lazy view collapses

    x = rng.standard_normal(160).astype(np.float32)
    y = rng.standard_normal(96).astype(np.float32)
    xs = rng.standard_normal((160, 4)).astype(np.float32)
    ys = rng.standard_normal((96, 3)).astype(np.float32)

    _scaled_close(np.asarray(op @ x), a @ x)
    _scaled_close(np.asarray(op.matvec(x)), a @ x)
    _scaled_close(np.asarray(op @ xs), a @ xs)
    _scaled_close(np.asarray(op.T @ y), a.T @ y)
    _scaled_close(np.asarray(op.rmatvec(y)), a.T @ y)
    _scaled_close(np.asarray(op.T @ ys), a.T @ ys)
    _scaled_close(np.asarray(op.rmatmat(ys)), a.T @ ys)


@pytest.mark.parametrize("fmt", FORMATS)
def test_operator_is_pytree_and_jit_carrier(rng, fmt):
    a = _random_sparse(rng, 96, 96)
    m = F.csr_from_dense(a)
    op = operator(m, format=fmt, b_r=B_R)
    x = jnp.asarray(rng.standard_normal(96).astype(np.float32))

    # flatten/unflatten round-trip preserves behaviour
    leaves, treedef = jax.tree_util.tree_flatten(op)
    assert all(isinstance(l, (jax.Array, np.ndarray)) for l in leaves)
    op2 = jax.tree_util.tree_unflatten(treedef, leaves)
    np.testing.assert_array_equal(np.asarray(op @ x), np.asarray(op2 @ x))

    # operators pass through jit as arguments...
    y_jit = jax.jit(lambda o, v: o @ v)(op, x)
    _scaled_close(np.asarray(y_jit), a @ np.asarray(x))

    # ...and ride lax.while_loop carriers (the solver use case)
    def body(state):
        o, v, k = state
        return o, o @ v, k + 1

    _, y3, _ = jax.lax.while_loop(lambda s: s[2] < 3, body,
                                  (op, x, jnp.int32(0)))
    want = a @ (a @ (a @ np.asarray(x)))
    _scaled_close(np.asarray(y3), want, atol=1e-4)

    # the transpose view is a pytree too
    yt = jax.jit(lambda o, v: o @ v)(op.T, x)
    _scaled_close(np.asarray(yt), a.T @ np.asarray(x))


def test_operator_factory_idempotent_and_shares_cache(rng):
    m = F.csr_from_dense(_random_sparse(rng, 96, 96))
    op = operator(m, b_r=B_R)
    assert operator(op) is op
    # the device representation comes from the as_device cache
    assert op.dev is ops.as_device(m, "auto", b_r=B_R)
    # wrapping an existing SparseDevice
    op2 = operator(op.dev)
    assert isinstance(op2, DeviceOperator) and op2.dev is op.dev
    with pytest.raises(ValueError):
        operator(op.dev, format="csr" if op.dev.fmt != "csr" else "pjds")


# --------------------------------------------------------------------------
# Transpose + autodiff property tests
# --------------------------------------------------------------------------
@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2 ** 16), fmt=st.sampled_from(FORMATS))
def test_transpose_matches_dense(seed, fmt):
    rng = np.random.default_rng(seed)
    n, c = rng.integers(40, 200), rng.integers(40, 200)
    a = _random_sparse(rng, int(n), int(c))
    op = operator(F.csr_from_dense(a), format=fmt, b_r=B_R)
    y = rng.standard_normal(int(n)).astype(np.float32)
    _scaled_close(np.asarray(op.T @ y), a.T @ y)


@pytest.mark.parametrize("fmt", FORMATS)
@pytest.mark.parametrize("backend", ["ref", "kernel"])
def test_grad_wrt_x_matches_dense(rng, fmt, backend):
    """Acceptance: jax.grad through operator.matvec == dense grad @1e-5.
    The kernel backend differentiates through the custom_vjp (the Pallas
    kernels themselves have no transpose rule)."""
    if fmt == "csr" and backend == "kernel":
        pytest.skip("csr has no kernel")
    a = _random_sparse(rng, 96, 96)
    op = operator(F.csr_from_dense(a), format=fmt, b_r=B_R,
                  backend=backend)
    x = jnp.asarray(rng.standard_normal(96).astype(np.float32))
    w = rng.standard_normal(96).astype(np.float32)
    gx = jax.grad(lambda v: jnp.vdot(jnp.asarray(w), op @ v))(x)
    _scaled_close(np.asarray(gx), a.T @ w, atol=1e-5)


@pytest.mark.parametrize("fmt", FORMATS)
def test_jvp_through_operator(rng, fmt):
    """Forward mode works too (the derivative rule is a custom_jvp, so
    spmv keeps the jvp support the plain ref path had)."""
    a = _random_sparse(rng, 96, 96)
    op = operator(F.csr_from_dense(a), format=fmt, b_r=B_R)
    x = jnp.asarray(rng.standard_normal(96).astype(np.float32))
    dx = jnp.asarray(rng.standard_normal(96).astype(np.float32))
    y, y_dot = jax.jvp(lambda v: op @ v, (x,), (dx,))
    _scaled_close(np.asarray(y), a @ np.asarray(x))
    _scaled_close(np.asarray(y_dot), a @ np.asarray(dx))


@pytest.mark.parametrize("fmt", FORMATS)
def test_grad_wrt_values_linearity(rng, fmt):
    """y is LINEAR in the stored values, so the value-gradient satisfies
    <grad, u> == loss(op.with_values(u) @ x) exactly — an independent
    check that d(Ax)/d(val) reuses the forward gather structure."""
    a = _random_sparse(rng, 96, 96)
    op = operator(F.csr_from_dense(a), format=fmt, b_r=B_R)
    x = jnp.asarray(rng.standard_normal(96).astype(np.float32))
    w = jnp.asarray(rng.standard_normal(96).astype(np.float32))

    def loss(v):
        return jnp.vdot(w, op.with_values(v) @ x)

    gv = jax.grad(loss)(op.values)
    assert gv.shape == op.values.shape and gv.dtype == op.values.dtype
    u = jnp.asarray(rng.standard_normal(op.values.shape).astype(np.float32))
    got = float(jnp.vdot(gv, u))
    want = float(loss(u))
    assert abs(got - want) <= 1e-3 * max(abs(want), 1.0)


def test_grad_wrt_values_matches_dense_pattern(rng):
    """For CSR the value stream maps 1:1 to (row, col) pairs, so the
    value-gradient must equal the dense gradient g x^T sampled at the
    sparsity pattern."""
    a = _random_sparse(rng, 64, 64)
    m = F.csr_from_dense(a)
    op = operator(m, format="csr", b_r=B_R)
    x = rng.standard_normal(64).astype(np.float32)
    w = rng.standard_normal(64).astype(np.float32)
    gv = jax.grad(lambda v: jnp.vdot(jnp.asarray(w),
                                     op.with_values(v) @ jnp.asarray(x)))(
        op.values)
    rows = np.repeat(np.arange(m.n_rows), m.row_lengths())
    want = w[rows] * x[m.indices]            # (g x^T)[row, col] per nnz
    _scaled_close(np.asarray(gv), want.astype(np.float32), atol=1e-5)


def test_sparse_ffn_trainable_end_to_end(rng):
    """jax.grad flows through a SparseLinear (operator-backed) layer:
    grad wrt the input matches the pruned-dense reference."""
    from repro.sparse.sparse_ffn import SparseLinear
    w = rng.standard_normal((64, 96)).astype(np.float32)
    sl = SparseLinear.from_dense(w, density=0.2, b_r=B_R)
    x = jnp.asarray(rng.standard_normal((4, 64)).astype(np.float32))
    wp = np.asarray(jax.device_get(
        sl.with_values(sl.values)(jnp.eye(64, dtype=jnp.float32))))

    def loss(xx):
        return jnp.sum(sl(xx) ** 2)

    gx = jax.grad(loss)(x)
    y = np.asarray(sl(x))
    want = 2 * y @ wp.T                      # d sum(y^2) / dx = 2 y W_p^T
    _scaled_close(np.asarray(gx), want, atol=1e-4)

    # and wrt the stored values (the fine-tuning handle): linearity of y
    gv = jax.grad(lambda v: jnp.sum(sl.with_values(v)(x)))(sl.values)
    u = jnp.asarray(rng.standard_normal(gv.shape).astype(np.float32))
    got = float(jnp.vdot(gv, u))
    want_dir = float(jnp.sum(sl.with_values(u)(x)))
    assert abs(got - want_dir) <= 1e-3 * max(abs(want_dir), 1.0)


# --------------------------------------------------------------------------
# Diagonal + preconditioned / non-symmetric solvers on the protocol
# --------------------------------------------------------------------------
@pytest.mark.parametrize("fmt", FORMATS)
def test_diagonal_matches_dense(rng, fmt):
    a = _random_sparse(rng, 96, 96, density=0.15)
    np.fill_diagonal(a, rng.standard_normal(96).astype(np.float32))
    op = operator(F.csr_from_dense(a), format=fmt, b_r=B_R)
    np.testing.assert_allclose(np.asarray(op.diagonal()), np.diag(a),
                               atol=1e-6)


def test_jacobi_pcg_beats_plain_cg(rng):
    """On an SPD system with a wildly varying diagonal the Jacobi
    preconditioner collapses the condition number — same cg() source."""
    m = M.poisson_2d(24, 24)
    s = (10.0 ** rng.uniform(-1.5, 1.5, m.n_rows)).astype(np.float32)
    d = F.csr_to_dense(m)
    a = (s[:, None] * d * s[None, :]).astype(np.float32)
    op = operator(F.csr_from_dense(a), b_r=B_R)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    plain = S.cg(op, jnp.asarray(b), maxiter=20000, tol=1e-6)
    pre = S.cg(op, jnp.asarray(b), maxiter=20000, tol=1e-6, M="jacobi")
    assert float(pre.residual) < 1e-5
    assert int(pre.iters) * 10 < int(plain.iters)
    x = np.asarray(pre.x)
    err = np.linalg.norm(a @ x - b) / np.linalg.norm(b)
    assert err < 1e-4


def test_bicgstab_nonsymmetric(rng):
    mn = M.convection_poisson(32, 32)
    a = F.csr_to_dense(mn).astype(np.float64)
    op = operator(mn, b_r=B_R)
    b = rng.standard_normal(mn.n_rows).astype(np.float32)
    res = S.bicgstab(op, jnp.asarray(b), maxiter=2000, tol=1e-8)
    x = np.asarray(res.x, np.float64)
    err = np.linalg.norm(a @ x - b) / np.linalg.norm(b)
    assert err < 1e-4
    # CG has no business converging here; BiCGStab is the first solver
    # in the repo that does.
    assert int(res.iters) < 2000


def test_bicgstab_dual_system_via_transpose_view(rng):
    """The dual residual check: solve A^T y = c by passing op.T — the
    rmatvec path — and verify against the dense transpose solve."""
    mn = M.convection_poisson(32, 32)
    a = F.csr_to_dense(mn).astype(np.float64)
    op = operator(mn, b_r=B_R)
    c = rng.standard_normal(mn.n_rows).astype(np.float32)
    res = S.bicgstab(op.T, jnp.asarray(c), maxiter=2000, tol=1e-8)
    y = np.asarray(res.x, np.float64)
    err = np.linalg.norm(a.T @ y - c) / np.linalg.norm(c)
    assert err < 1e-4
    # dual residual of the primal solve: r_dual = c - A^T y ~ 0 links the
    # two systems; recompute it through rmatvec to cross-check op.T
    r_dual = np.asarray(op.rmatvec(jnp.asarray(y.astype(np.float32))))
    _scaled_close(r_dual, (a.T @ y).astype(np.float32), atol=1e-4)


def test_solver_source_runs_on_device_operator(rng):
    """The single-device half of the acceptance criterion: the SAME
    S.cg / S.block_cg / S.bicgstab sources also run on DistOperator in
    the subprocess suite below."""
    m = M.poisson_2d(20, 20)
    op = operator(m, b_r=B_R)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    res = S.cg(op, jnp.asarray(b), maxiter=1500, tol=1e-7)
    a = F.csr_to_dense(m)
    err = np.linalg.norm(a @ np.asarray(res.x) - b) / np.linalg.norm(b)
    assert err < 1e-4
    bk = rng.standard_normal((m.n_rows, 4)).astype(np.float32)
    bres = S.block_cg(op, jnp.asarray(bk), maxiter=1500, tol=1e-7)
    assert float(np.max(np.asarray(bres.residual))) < 1e-5


# --------------------------------------------------------------------------
# Distributed conformance + solver parity (subprocess, 8 host devices)
# --------------------------------------------------------------------------
_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from repro.core import formats as F, matrices as M, solvers as S
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh

    out = {}
    mesh = make_host_mesh(8)
    rng = np.random.default_rng(0)

    # non-symmetric convection-diffusion system (BiCGStab + transpose)
    m = M.poisson_2d(40, 40)
    mn = M.convection_poisson(40, 40, beta=0.5)
    dense = F.csr_to_dense(mn).astype(np.float64)

    op = dist_operator(mn, mesh, b_r=32)
    n_pad = op.shape[0]
    sh = jax.NamedSharding(mesh, P("data"))

    x = np.zeros(n_pad, np.float32); x[:m.n_rows] = rng.standard_normal(m.n_rows)
    xj = jax.device_put(jnp.asarray(x), sh)
    scale = float(np.abs(dense @ x[:m.n_rows]).max())
    out["err_mv"] = float(np.abs(np.asarray(op @ xj)[:m.n_rows]
                                 - dense @ x[:m.n_rows]).max() / scale)
    out["err_rmv"] = float(np.abs(np.asarray(op.T @ xj)[:m.n_rows]
                                  - dense.T @ x[:m.n_rows]).max() / scale)
    g = jax.grad(lambda v: jnp.vdot(xj, op.matvec(v)))(xj)
    out["err_grad_x"] = float(np.abs(np.asarray(g)[:m.n_rows]
                                     - dense.T @ x[:m.n_rows]).max() / scale)
    X = np.zeros((n_pad, 4), np.float32)
    X[:m.n_rows] = rng.standard_normal((m.n_rows, 4))
    Xj = jax.device_put(jnp.asarray(X), jax.NamedSharding(mesh, P("data", None)))
    out["err_mm"] = float(np.abs(np.asarray(op @ Xj)[:m.n_rows]
                                 - dense @ X[:m.n_rows]).max() / scale)
    out["err_diag"] = float(np.abs(np.asarray(op.diagonal())[:m.n_rows]
                                   - np.diag(dense)).max())

    # ONE solver source on the mesh operator: cg (on the SPD system),
    # jacobi-pcg, block-cg, bicgstab (non-symmetric), bicgstab on op.T
    sym = dist_operator(m, mesh, b_r=32)
    b = np.zeros(n_pad, np.float32); b[:m.n_rows] = rng.standard_normal(m.n_rows)
    bj = jax.device_put(jnp.asarray(b), sh)
    dsym = F.csr_to_dense(m).astype(np.float64)
    res = S.cg(sym, bj, maxiter=2000, tol=1e-6)
    out["cg_err"] = float(np.linalg.norm(
        dsym @ np.asarray(res.x, np.float64)[:m.n_rows] - b[:m.n_rows])
        / np.linalg.norm(b[:m.n_rows]))
    res_j = S.cg(sym, bj, maxiter=2000, tol=1e-6, M="jacobi")
    out["pcg_err"] = float(np.linalg.norm(
        dsym @ np.asarray(res_j.x, np.float64)[:m.n_rows] - b[:m.n_rows])
        / np.linalg.norm(b[:m.n_rows]))
    Bj = jax.device_put(jnp.asarray(X), jax.NamedSharding(mesh, P("data", None)))
    bres = S.block_cg(sym, Bj, maxiter=2000, tol=1e-6)
    out["block_cg_res"] = float(np.max(np.asarray(bres.residual)))
    nres = S.bicgstab(op, bj, maxiter=2000, tol=1e-8)
    out["bicgstab_err"] = float(np.linalg.norm(
        dense @ np.asarray(nres.x, np.float64)[:m.n_rows] - b[:m.n_rows])
        / np.linalg.norm(b[:m.n_rows]))
    tres = S.bicgstab(op.T, bj, maxiter=2000, tol=1e-8)
    out["bicgstab_T_err"] = float(np.linalg.norm(
        dense.T @ np.asarray(tres.x, np.float64)[:m.n_rows] - b[:m.n_rows])
        / np.linalg.norm(b[:m.n_rows]))

    # serve-layer consumer: SolveEngine batches RHS against the mesh op
    from repro.serve.engine import SolveEngine, SolveRequest
    eng = SolveEngine(sym, slots=4, maxiter=2000, tol=1e-6)
    reqs = [SolveRequest(rid=i, b=np.asarray(X[:, i % 4])) for i in range(6)]
    eng.run(reqs)
    out["serve_done"] = int(sum(r.done for r in reqs))
    out["serve_res"] = float(max(r.residual for r in reqs))
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_op_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _DIST_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.dist
def test_dist_conformance(dist_op_results):
    for k in ("err_mv", "err_rmv", "err_grad_x", "err_mm"):
        assert dist_op_results[k] < 1e-5, (k, dist_op_results[k])
    assert dist_op_results["err_diag"] < 1e-6


@pytest.mark.dist
def test_solver_source_runs_on_dist_operator(dist_op_results):
    """Acceptance: the same cg/block_cg/bicgstab sources that ran on the
    DeviceOperator above converge on the mesh operator."""
    assert dist_op_results["cg_err"] < 1e-4
    assert dist_op_results["pcg_err"] < 1e-4
    assert dist_op_results["block_cg_res"] < 1e-5
    assert dist_op_results["bicgstab_err"] < 1e-4
    assert dist_op_results["bicgstab_T_err"] < 1e-4


def test_solve_engine_serves_dist_operator(dist_op_results):
    assert dist_op_results["serve_done"] == 6
    assert dist_op_results["serve_res"] < 1e-5
