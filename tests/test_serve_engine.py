"""Continuous-batching engine: correctness of slot reuse + greedy match."""
import numpy as np
import jax
import jax.numpy as jnp

from repro import configs
from repro.models.api import build_model
from repro.serve.engine import Engine, Request


def _greedy_reference(model, params, prompt, n_new, max_len):
    """Single-sequence greedy decode via prefill+decode_step."""
    toks = list(prompt)
    cache, logits = jax.jit(
        lambda p, b: model.prefill(p, b, max_len=max_len, q_chunk=16,
                                   k_chunk=16))(
        params, {"tokens": jnp.asarray([toks], jnp.int32)})
    out = [int(np.argmax(np.asarray(logits)[0, -1]))]
    step = jax.jit(model.decode_step)
    for i in range(n_new - 1):
        pos = jnp.asarray([len(toks) + i], jnp.int32)
        cache, lg = step(params, cache,
                         jnp.asarray([[out[-1]]], jnp.int32), pos)
        out.append(int(np.argmax(np.asarray(lg)[0, -1])))
    return out


def test_engine_matches_sequential_decode(rng):
    cfg = configs.smoke("qwen2.5-14b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = rng.integers(0, cfg.vocab, (6,)).astype(np.int32)

    eng = Engine(model, params, batch_slots=2, max_len=64)
    req = Request(rid=0, prompt=prompt, max_new=5)
    eng.run([req], max_ticks=50)
    ref = _greedy_reference(model, params, prompt, 5, 64)
    assert req.out == ref


def test_engine_batches_multiple_requests(rng):
    cfg = configs.smoke("minicpm-2b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(1))
    reqs = [Request(rid=i, prompt=rng.integers(0, cfg.vocab, (4 + i,))
                    .astype(np.int32), max_new=4) for i in range(3)]
    eng = Engine(model, params, batch_slots=2, max_len=32)
    eng.run(reqs, max_ticks=200)
    for r in reqs:
        assert r.done and len(r.out) >= 4


def test_solve_engine_batches_rhs_against_operator(rng):
    """SolveEngine: batched linear-solve serving over a SparseOperator —
    every request solved to tolerance, padded slots harmless (5 requests
    through 4 slots), results match the dense solve."""
    from repro.core import formats as F, matrices as M
    from repro.core.operator import operator
    from repro.serve.engine import SolveEngine, SolveRequest

    m = M.poisson_2d(16, 16)
    a = F.csr_to_dense(m).astype(np.float64)
    op = operator(m, b_r=32)
    reqs = [SolveRequest(rid=i,
                         b=rng.standard_normal(m.n_rows).astype(np.float32))
            for i in range(5)]
    eng = SolveEngine(op, slots=4, maxiter=1500, tol=1e-7)
    eng.run(reqs)
    for r in reqs:
        assert r.done and r.residual < 1e-6
        err = np.linalg.norm(a @ r.x - r.b) / np.linalg.norm(r.b)
        assert err < 1e-4


def test_solve_engine_jacobi_scaling(rng):
    """The Jacobi option solves the symmetrically scaled system — fewer
    iterations on a badly scaled SPD matrix, same answers."""
    from repro.core import formats as F, matrices as M
    from repro.core.operator import operator
    from repro.serve.engine import SolveEngine, SolveRequest

    m = M.poisson_2d(16, 16)
    s = (10.0 ** rng.uniform(-1.5, 1.5, m.n_rows)).astype(np.float32)
    d = F.csr_to_dense(m)
    a = (s[:, None] * d * s[None, :]).astype(np.float32)
    op = operator(F.csr_from_dense(a), b_r=32)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    plain = SolveEngine(op, slots=2, maxiter=20000, tol=1e-6)
    scaled = SolveEngine(op, slots=2, maxiter=20000, tol=1e-6,
                         jacobi_precond=True)
    r0 = SolveRequest(rid=0, b=b)
    r1 = SolveRequest(rid=1, b=b)
    plain.run([r0])
    scaled.run([r1])
    assert r1.iters * 5 < r0.iters
    err = np.linalg.norm(a.astype(np.float64) @ r1.x - b) / np.linalg.norm(b)
    assert err < 1e-3


def test_solve_engine_is_a_shim_over_the_scheduler(rng):
    """PR 8: SolveEngine routes through the registry + scheduler path —
    requests carry the serving diagnostics and the scheduler's metrics
    ledger is exposed, while the blocking run() contract is unchanged."""
    from repro.core import matrices as M
    from repro.core.operator import operator
    from repro.serve.engine import SolveEngine, SolveRequest
    from repro.serve.scheduler import SolveScheduler

    m = M.poisson_2d(10, 10)
    eng = SolveEngine(operator(m, b_r=32), slots=4, maxiter=1500, tol=1e-6)
    assert isinstance(eng.scheduler, SolveScheduler)
    assert len(eng.registry) == 1

    reqs = [SolveRequest(rid=i, b=rng.standard_normal(m.n_rows)
                         .astype(np.float32)) for i in range(5)]
    eng.run(reqs)
    assert all(r.status == "converged" for r in reqs)
    assert eng.metrics.counters["batches"] == 2          # 4 + 1
    assert eng.metrics.counters["converged"] == 5
    assert reqs[0].diagnostics["serve"]["batch_k"] == 4
    assert reqs[4].diagnostics["serve"]["batch_k"] == 1
