"""Scheduler: coalescing, deadline shedding order, slot recycling
through bisection, metrics — all under a deterministic injected clock."""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import formats as F
from repro.core import matrices as M
from repro.serve import (OperatorRegistry, ServeMetrics, SolveRequest,
                         SolveScheduler)


class FakeClock:
    def __init__(self, t: float = 0.0):
        self.t = t

    def __call__(self) -> float:
        return self.t

    def advance(self, dt: float) -> None:
        self.t += dt


def _serving(nx=10, ny=10, **kw):
    reg = OperatorRegistry(tune="off")
    entry = reg.admit(M.poisson_2d(nx, ny))
    clock = FakeClock()
    kw.setdefault("slots", 4)
    kw.setdefault("maxiter", 1500)
    kw.setdefault("tol", 1e-6)
    sched = SolveScheduler(reg, clock=clock, **kw)
    return reg, entry, sched, clock


def _reqs(rng, n, n_rows, **kw):
    return [SolveRequest(rid=i, b=rng.standard_normal(n_rows)
                         .astype(np.float32), **kw) for i in range(n)]


def test_async_admission_then_one_tick_coalesces(rng):
    """k concurrent requests against one operator become ONE block-CG
    group: submit solves nothing, the first tick solves all three in a
    single batch with occupancy k/slots."""
    reg, entry, sched, clock = _serving()
    reqs = _reqs(rng, 3, entry.shape[0])
    for r in reqs:
        sched.submit(r)
    assert all(r.status == "queued" and not r.done for r in reqs)
    assert sched.pending() == 3
    assert sched.metrics.counters["admitted"] == 3
    assert sched.metrics.counters.get("batches", 0) == 0

    done = sched.tick()
    assert done == 3 and sched.pending() == 0
    assert sched.metrics.counters["batches"] == 1        # ONE group
    assert sched.metrics.counters["converged"] == 3
    a = F.csr_to_dense(M.poisson_2d(10, 10)).astype(np.float64)
    for r in reqs:
        assert r.status == "converged"
        assert r.diagnostics["serve"]["batch_k"] == 3
        err = np.linalg.norm(a @ r.x - r.b) / np.linalg.norm(r.b)
        assert err < 1e-4
    assert sched.metrics.occupancy.snapshot()["max_s"] == 0.75  # 3/4 slots


def test_admission_rejects_bad_rhs_immediately(rng):
    reg, entry, sched, clock = _serving()
    bad = SolveRequest(rid=0, b=np.ones((4, 4), np.float32))
    sched.submit(bad)
    assert bad.status == "rejected" and bad.done
    assert sched.pending() == 0
    assert sched.metrics.counters["rejected"] == 1

    nan = SolveRequest(rid=1, b=np.full(entry.shape[0], np.nan, np.float32))
    sched.submit(nan)
    assert nan.status == "rejected"
    assert "non-finite" in nan.diagnostics["reason"]


def test_expired_deadlines_shed_before_dispatch(rng):
    reg, entry, sched, clock = _serving()
    live = _reqs(rng, 2, entry.shape[0])
    doomed = SolveRequest(rid=9, b=rng.standard_normal(entry.shape[0])
                          .astype(np.float32), deadline_s=1.0)
    for r in live + [doomed]:
        sched.submit(r)
    clock.advance(2.0)                       # doomed expires in queue
    sched.tick()
    assert doomed.status == "shed" and doomed.x is None
    assert doomed.diagnostics["deadline_s"] == 1.0
    assert doomed.diagnostics["serve"]["queue_s"] == 2.0
    assert sched.metrics.counters["shed"] == 1
    assert all(r.status == "converged" for r in live)


def test_deadline_order_earliest_first(rng):
    """Live deadlined requests are batched earliest-deadline-first,
    ahead of deadline-free ones, regardless of submission order."""
    reg, entry, sched, clock = _serving(slots=1)
    n = entry.shape[0]
    r_late = SolveRequest(rid=0, b=rng.standard_normal(n)
                          .astype(np.float32), deadline_s=50.0)
    r_none = SolveRequest(rid=1, b=rng.standard_normal(n)
                          .astype(np.float32))
    r_soon = SolveRequest(rid=2, b=rng.standard_normal(n)
                          .astype(np.float32), deadline_s=10.0)
    for r in (r_late, r_none, r_soon):       # submission order != deadline
        sched.submit(r)
    order = []
    while sched.pending():
        sched.tick()
        order = [r.rid for r in (r_late, r_none, r_soon) if r.done]
    assert order == [0, 1, 2]                # all completed eventually
    # completion ORDER: soon (10) before late (50) before none
    k_soon = r_soon.diagnostics["serve"]
    # soon solved in tick 1 (batch of 1), late in tick 2, none in tick 3:
    # with slots=1 each tick drains exactly one request in EDF order
    assert r_soon.status == r_late.status == r_none.status == "converged"
    assert k_soon["batch_k"] == 1
    # queue latencies under the fake clock are 0 (clock never advanced),
    # so order is proven by which tick finalized each request instead:
    assert sched.metrics.counters["batches"] == 3


def test_tick_order_is_edf_not_fifo(rng):
    """Single tick, slots=2, three queued: the two with the nearest
    deadlines fill the batch; the deadline-free request waits."""
    reg, entry, sched, clock = _serving(slots=2)
    n = entry.shape[0]
    r_none = SolveRequest(rid=0, b=rng.standard_normal(n)
                          .astype(np.float32))
    r_d2 = SolveRequest(rid=1, b=rng.standard_normal(n)
                        .astype(np.float32), deadline_s=20.0)
    r_d1 = SolveRequest(rid=2, b=rng.standard_normal(n)
                        .astype(np.float32), deadline_s=10.0)
    for r in (r_none, r_d2, r_d1):
        sched.submit(r)
    sched.tick()
    assert r_d1.done and r_d2.done and not r_none.done
    sched.tick()
    assert r_none.done


def test_slot_recycling_after_poisoned_bisection(rng):
    """Six requests through four slots with one poisoned column: tick 1
    dispatches a full batch, the bisection machinery isolates the
    poison (extra group solves, counted as splits, NOT as batches), the
    three healthy ones complete in the same tick, and tick 2 recycles
    the freed slots for the remaining two."""
    reg, entry, sched, clock = _serving(nx=12, ny=12)
    n = entry.shape[0]
    reqs = _reqs(rng, 6, n)
    reqs[1].b = reqs[1].b.copy()
    reqs[1].b[3] = np.nan
    sched.solver_for(entry)._admit_fn = lambda req: True   # let poison in
    for r in reqs:
        sched.submit(r)

    done1 = sched.tick()
    assert done1 == 4
    assert reqs[1].status in ("non_finite", "breakdown", "diverged")
    assert sched.metrics.counters["group_splits"] >= 1
    assert sched.metrics.counters["batches"] == 1
    assert sched.pending() == 2

    done2 = sched.tick()
    assert done2 == 2 and sched.pending() == 0
    assert sched.metrics.counters["batches"] == 2
    a = F.csr_to_dense(M.poisson_2d(12, 12)).astype(np.float64)
    for r in reqs:
        if r.rid == 1:
            continue
        assert r.status == "converged"
        err = np.linalg.norm(a @ r.x - r.b) / np.linalg.norm(r.b)
        assert err < 1e-4
    assert sched.metrics.counters["converged"] == 5
    assert sched.metrics.counters["failed"] == 1


def test_latency_accounting_under_fake_clock(rng):
    """queue/solve/total latencies come from the injected clock, so a
    deterministic test can assert EXACT values."""
    reg, entry, sched, clock = _serving()
    r = _reqs(rng, 1, entry.shape[0])[0]
    sched.submit(r)
    clock.advance(3.0)                       # queued for exactly 3s
    sched.tick()                             # solve at frozen clock: 0s
    s = r.diagnostics["serve"]
    assert s["queue_s"] == 3.0 and s["solve_s"] == 0.0
    assert s["total_s"] == 3.0
    snap = sched.metrics.snapshot()
    assert snap["queue_s"]["p50_s"] == 3.0
    assert snap["total_s"]["count"] == 1


def test_multi_tenant_routing_and_ambiguity(rng):
    reg = OperatorRegistry(tune="off")
    e1 = reg.admit(M.poisson_2d(8, 8))
    e2 = reg.admit(M.poisson_2d(9, 9))
    sched = SolveScheduler(reg, slots=4, maxiter=1500, tol=1e-6,
                           clock=FakeClock())
    with pytest.raises(ValueError, match="ambiguous"):
        sched.submit(SolveRequest(rid=0, b=np.ones(64, np.float32)))
    with pytest.raises(KeyError):
        sched.submit(SolveRequest(rid=0, b=np.ones(64, np.float32),
                                  tenant="no-such-tenant"))
    r1 = SolveRequest(rid=1, b=rng.standard_normal(e1.shape[0])
                      .astype(np.float32), tenant=e1.key)
    r2 = SolveRequest(rid=2, b=rng.standard_normal(e2.shape[0])
                      .astype(np.float32), tenant=e2.key)
    sched.submit(r1)
    sched.submit(r2)
    sched.run_until_drained()
    assert r1.status == "converged" and r2.status == "converged"
    assert r1.diagnostics["serve"]["tenant"] == e1.key
    assert r2.diagnostics["serve"]["tenant"] == e2.key
    assert sched.metrics.counters["batches"] == 2   # one group per tenant


def test_shared_metrics_object_injectable(rng):
    mx = ServeMetrics()
    reg = OperatorRegistry(tune="off")
    entry = reg.admit(M.poisson_2d(8, 8))
    sched = SolveScheduler(reg, slots=2, maxiter=1500, tol=1e-6,
                           clock=FakeClock(), metrics=mx)
    for r in _reqs(rng, 2, entry.shape[0]):
        sched.submit(r)
    sched.run_until_drained()
    assert mx.counters["converged"] == 2
    assert mx.occupancy.snapshot()["max_s"] == 1.0
