"""Matrix-Market ingestion/export: round trips, symmetry expansion,
the validate_csr admission funnel, and malformed-file rejection."""
import io

import numpy as np
import pytest

from repro.core import formats as F
from repro.core.io_mm import (MatrixMarketError, load_mm, read_mm,
                              save_mm)


def _random_csr(rng, n=40, density=0.1, dtype=np.float64):
    d = (rng.random((n, n)) < density) * rng.standard_normal((n, n))
    r, c = np.nonzero(d)
    return F.csr_from_coo(r, c, d[r, c].astype(dtype), shape=(n, n))


def _same(a, b):
    return (np.array_equal(a.indptr, b.indptr)
            and np.array_equal(a.indices, b.indices)
            and np.array_equal(a.data, b.data)
            and a.shape == b.shape)


def _roundtrip(m, **save_kw):
    buf = io.StringIO()
    save_mm(buf, m, **save_kw)
    buf.seek(0)
    return buf, load_mm(buf, dtype=m.data.dtype)


def test_general_roundtrip_f64_bit_exact(rng):
    m = _random_csr(rng)
    _, m2 = _roundtrip(m)
    assert _same(m, m2)


def test_general_roundtrip_f32_bit_exact(rng):
    m = _random_csr(rng, dtype=np.float32)
    _, m2 = _roundtrip(m)
    assert _same(m, m2)


def test_symmetric_detected_and_halved(rng):
    d = (rng.random((30, 30)) < 0.15) * rng.standard_normal((30, 30))
    d = d + d.T
    r, c = np.nonzero(d)
    m = F.csr_from_coo(r, c, d[r, c], shape=(30, 30))
    buf, m2 = _roundtrip(m)
    assert "coordinate real symmetric" in buf.getvalue().splitlines()[0]
    # lower triangle only on disk
    stored = int(buf.getvalue().splitlines()[1].split()[2])
    assert stored < m.nnz
    assert _same(m, m2)


def test_skew_symmetric_roundtrip(rng):
    u = np.triu((rng.random((24, 24)) < 0.2) * rng.standard_normal((24, 24)),
                1)
    d = u - u.T
    r, c = np.nonzero(d)
    m = F.csr_from_coo(r, c, d[r, c], shape=(24, 24))
    buf, m2 = _roundtrip(m)
    assert "skew-symmetric" in buf.getvalue().splitlines()[0]
    assert _same(m, m2)


def test_pattern_field_loads_as_ones(rng):
    m = _random_csr(rng)
    buf, m2 = _roundtrip(m, field="pattern")
    assert "pattern" in buf.getvalue().splitlines()[0]
    assert np.all(m2.data == 1.0)
    assert np.array_equal(m.indices, m2.indices)


def test_integer_field_roundtrip(rng):
    m = _random_csr(rng)
    mi = F.CSRMatrix(m.indptr, m.indices,
                     np.round(m.data * 100).astype(np.int64), m.shape)
    buf = io.StringIO()
    save_mm(buf, mi)
    assert "coordinate integer" in buf.getvalue().splitlines()[0]
    buf.seek(0)
    m2 = load_mm(buf)
    assert np.array_equal(mi.data.astype(np.float64), m2.data)


def test_array_format_column_major():
    txt = ("%%MatrixMarket matrix array real general\n"
           "% comment line\n2 3\n1.5\n2.5\n3.5\n4.5\n5.5\n6.5\n")
    m = load_mm(io.StringIO(txt))
    expect = np.array([[1.5, 3.5, 5.5], [2.5, 4.5, 6.5]])
    assert np.array_equal(F.csr_to_dense(m), expect)


def test_array_symmetric_expands_lower_triangle():
    txt = "%%MatrixMarket matrix array real symmetric\n2 2\n1\n2\n3\n"
    m = load_mm(io.StringIO(txt))
    assert np.array_equal(F.csr_to_dense(m),
                          np.array([[1., 2.], [2., 3.]]))


def test_duplicates_summed():
    txt = ("%%MatrixMarket matrix coordinate real general\n"
           "2 2 3\n1 1 2.0\n1 1 3.0\n2 2 1.0\n")
    m = load_mm(io.StringIO(txt))
    assert np.array_equal(F.csr_to_dense(m), np.array([[5., 0.], [0., 1.]]))


def test_unsupported_field_rejected():
    with pytest.raises(MatrixMarketError, match="complex"):
        load_mm(io.StringIO(
            "%%MatrixMarket matrix coordinate complex general\n1 1 0\n"))


def test_bad_banner_rejected():
    with pytest.raises(MatrixMarketError, match="banner"):
        load_mm(io.StringIO("not a matrix market file\n"))


def test_entry_count_mismatch_rejected():
    txt = ("%%MatrixMarket matrix coordinate real general\n"
           "2 2 3\n1 1 1.0\n2 2 1.0\n")
    with pytest.raises(MatrixMarketError, match="declared 3"):
        load_mm(io.StringIO(txt))


def test_nonzero_skew_diagonal_rejected():
    txt = ("%%MatrixMarket matrix coordinate real skew-symmetric\n"
           "2 2 2\n2 1 1.0\n1 1 5.0\n")
    with pytest.raises(MatrixMarketError, match="diagonal"):
        load_mm(io.StringIO(txt))


def test_out_of_range_repaired_or_strict():
    txt = ("%%MatrixMarket matrix coordinate real general\n"
           "2 2 2\n1 1 1.0\n3 1 9.0\n")
    m = load_mm(io.StringIO(txt))                   # repair drops it
    assert m.nnz == 1
    with pytest.raises(MatrixMarketError):
        load_mm(io.StringIO(txt), validate="strict")


def test_file_path_roundtrip(tmp_path, rng):
    m = _random_csr(rng, dtype=np.float32)
    p = tmp_path / "m.mtx"
    save_mm(p, m, comment="two\nlines")
    m2 = load_mm(p, dtype=np.float32)
    assert _same(m, m2)


def test_read_mm_header_fields():
    txt = ("%%MatrixMarket matrix coordinate real general\n"
           "2 3 1\n1 2 4.0\n")
    hdr, rows, cols, vals = read_mm(io.StringIO(txt))
    assert (hdr.format, hdr.field, hdr.symmetry) == ("coordinate", "real",
                                                     "general")
    assert hdr.shape == (2, 3) and hdr.nnz == 1
    assert rows[0] == 0 and cols[0] == 1 and vals[0] == 4.0


def test_rectangular_symmetric_rejected():
    txt = ("%%MatrixMarket matrix coordinate real symmetric\n"
           "2 3 1\n1 1 1.0\n")
    with pytest.raises(MatrixMarketError, match="2x3"):
        load_mm(io.StringIO(txt))
