"""Distributed spMVM tests — run in a subprocess with 8 host devices so
the main pytest process keeps a single device (per task spec, only the
dry-run entry point forces a device count)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

pytestmark = pytest.mark.dist

_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json, re
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import repro
    from repro.core import formats as F, matrices as M, dist_spmv as D
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh

    out = {}
    n_dev = 8
    mesh = make_host_mesh(n_dev)
    rng = np.random.default_rng(0)

    # banded SPD matrix
    m = M.poisson_2d(40, 40)
    dist = D.partition_csr(m, n_dev, b_r=32)
    x = np.zeros(dist.n_global_pad, np.float32)
    x[:m.n_rows] = rng.standard_normal(m.n_rows)
    xj = jax.device_put(jnp.asarray(x), jax.NamedSharding(mesh, P("data")))
    truth = F.csr_to_dense(m).astype(np.float64) @ x[:m.n_rows]
    scale = np.abs(truth).max()
    for mode in ("vector", "naive", "overlap"):
        op = dist_operator(dist, mesh, mode=mode)
        mv = jax.jit(op.matvec)
        y = np.asarray(mv(xj))[:m.n_rows]
        out[f"err_{mode}"] = float(np.abs(y - truth).max() / scale)
        hlo = mv.lower(xj).compile().as_text()
        out[f"cp_{mode}"] = len(re.findall(r"collective-permute", hlo))
        op_full = dist_operator(dist, mesh, mode=mode, halo="full")
        yf = np.asarray(jax.jit(op_full.matvec)(xj))[:m.n_rows]
        out[f"err_full_{mode}"] = float(np.abs(yf - truth).max() / scale)
    out["comm_gathered"] = dist.comm_bytes_per_device(4)
    out["comm_full"] = dist.comm_bytes_per_device(4, halo="full")

    # wide-halo random matrix
    a = ((rng.random((320, 320)) < 0.04)
         * rng.standard_normal((320, 320))).astype(np.float32)
    m2 = F.csr_from_dense(a)
    dist2 = D.partition_csr(m2, n_dev, b_r=32)
    out["halo_w_wide"] = dist2.halo_w
    x2 = np.zeros(dist2.n_global_pad, np.float32)
    x2[:320] = rng.standard_normal(320)
    xj2 = jax.device_put(jnp.asarray(x2), jax.NamedSharding(mesh, P("data")))
    y2 = np.asarray(jax.jit(dist_operator(dist2, mesh,
                                          mode="overlap").matvec)(xj2))[:320]
    t2 = a.astype(np.float64) @ x2[:320]
    out["err_wide"] = float(np.abs(y2 - t2).max() / np.abs(t2).max())

    # distributed CG on the Poisson system, through the repro.solve door
    b = np.zeros(dist.n_global_pad, np.float32)
    b[:m.n_rows] = rng.standard_normal(m.n_rows)
    bj = jax.device_put(jnp.asarray(b), jax.NamedSharding(mesh, P("data")))
    res = repro.solve(dist_operator(dist, mesh, mode="overlap"), bj,
                      method="cg", maxiter=2000, tol=1e-6)
    out["cg_res"] = float(res.residual)
    out["cg_iters"] = int(res.iters)
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _SCRIPT], capture_output=True,
                       text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_all_modes_correct(dist_results):
    for mode in ("vector", "naive", "overlap"):
        assert dist_results[f"err_{mode}"] < 1e-5


def test_full_slice_halo_agrees(dist_results):
    """The bulk ring-shift baseline and the gathered exchange compute
    the same operator in every mode."""
    for mode in ("vector", "naive", "overlap"):
        assert dist_results[f"err_full_{mode}"] < 1e-5


def test_gathered_halo_ships_less(dist_results):
    """On the banded Poisson matrix only one 40-column grid line crosses
    each slice boundary; the compressed exchange ships just that."""
    assert dist_results["comm_gathered"] * 5 <= dist_results["comm_full"]


def test_halo_exchange_in_hlo(dist_results):
    """Every mode moves the halo with collective-permutes (paper's p2p)."""
    for mode in ("vector", "naive", "overlap"):
        assert dist_results[f"cp_{mode}"] >= 2


def test_wide_halo_matrix(dist_results):
    assert dist_results["halo_w_wide"] >= 3
    assert dist_results["err_wide"] < 1e-5


def test_distributed_cg_converges(dist_results):
    assert dist_results["cg_res"] < 1e-5
    assert 0 < dist_results["cg_iters"] < 2000
