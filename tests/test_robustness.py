"""Chaos suite: every fault the harness can inject must end in a typed
status, a successful fallback rung, or an out-of-band-detectable
mismatch — never a silent wrong answer (DESIGN.md §11).

Faults come from ``repro.testing.faults``; the single-device tests run
in-process, the halo-exchange tests in an 8-virtual-device subprocess
like the rest of the ``dist`` mark.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax.numpy as jnp
import pytest

import repro
from repro import api
from repro.core import formats as F, matrices as M
from repro.core.operator import operator
from repro.kernels import ops as K
from repro.testing import faults


def _spd(rng, n=64):
    m = M.poisson_2d(8, 8)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    return m, b


# --------------------------------------------------------- value poison
def test_poisoned_values_fail_typed_not_silent(rng):
    m, b = _spd(rng)
    with faults.poison_values(m, count=3):
        res = repro.solve(m, b, tune="off", fallback="off")
        assert res.status == "non_finite"
        assert not bool(res.converged)
        with pytest.raises(repro.SolveFailure) as ei:
            repro.solve(m, b, tune="off", fallback="auto")
    # every rung saw the poison and said so — no rung claimed success
    assert ei.value.ladder
    assert all(e.get("status") in ("non_finite", "breakdown", "diverged")
               or "error" in e for e in ei.value.ladder)
    # harness restored the matrix: the same solve now succeeds
    res = repro.solve(m, b, tune="off")
    assert res.status == "converged"
    assert res.diagnostics["certified"]


def test_poison_restores_values(rng):
    m, _ = _spd(rng)
    before = np.asarray(m.data).copy()
    with faults.poison_values(m, count=5, value=np.inf):
        assert not np.all(np.isfinite(m.data))
    np.testing.assert_array_equal(np.asarray(m.data), before)


# --------------------------------------------------------- validation
def test_validate_check_raises_on_poison(rng):
    m, b = _spd(rng)
    with faults.poison_values(m, count=2):
        with pytest.raises(F.CSRValidationError) as ei:
            repro.solve(m, b, tune="off", validate="check")
    assert "non_finite_values" in ei.value.report.issues


def test_validate_repair_drops_poison_and_solves(rng):
    m, b = _spd(rng)
    with faults.poison_values(m, count=2):
        # dropping poisoned entries breaks the Poisson matrix's symmetry
        # — CG may legitimately break down on it, but it must do so
        # TYPED, and never leak a NaN
        res = repro.solve(m, b, tune="off", validate="repair",
                          fallback="off")
        assert res.status in ("converged", "maxiter", "breakdown",
                              "diverged")
        assert np.all(np.isfinite(np.asarray(res.x)))
        # bicgstab handles the now-nonsymmetric repaired operator
        res = repro.solve(m, b, method="bicgstab", tune="off",
                          validate="repair")
    assert res.status == "converged"
    assert res.diagnostics["certified"]


def test_as_device_validate_wiring(rng):
    m, _ = _spd(rng)
    with faults.poison_values(m, count=1):
        with pytest.raises(F.CSRValidationError):
            K.as_device(m, validate="check")
        dev = K.as_device(m, validate="repair")
        y = np.asarray(dev.matvec(jnp.ones(m.n_rows, jnp.float32)))
        assert np.all(np.isfinite(y))
    with pytest.raises(ValueError):
        K.as_device(m, validate="sometimes")


# --------------------------------------------------------- tune cache
@pytest.mark.parametrize("mode", ["truncate", "garbage", "bad_schema",
                                  "missing_keys"])
def test_corrupt_tune_cache_degrades_to_remeasure(mode, tmp_path,
                                                  monkeypatch, rng):
    from repro import tune as T
    from repro.tune import cache as TC
    monkeypatch.setenv("REPRO_TUNE_CACHE", str(tmp_path / "tc.json"))
    m, b = _spd(rng)
    r0 = repro.solve(m, b, tune="auto")
    assert r0.status == "converged"
    cache_path = T.default_cache().path
    assert cache_path.exists()

    def fresh_process():
        # corruption lands on disk; the process that SEES it is the next
        # one to load the file — simulate it by dropping the singleton
        monkeypatch.setattr(TC, "_DEFAULT", None)

    with faults.corrupt_tune_cache(cache_path, mode=mode):
        if mode in ("bad_schema", "missing_keys"):
            # a fresh loader quarantines every mangled record
            fresh = TC.TuneCache(cache_path)
            for key in json.loads(cache_path.read_text())["entries"]:
                assert fresh.get(key, require=("strategy",)) is None
            assert fresh.quarantined
        fresh_process()
        # never crashes; mangled records degrade to a re-measure (which
        # overwrites the record and clears its quarantine)
        res = repro.solve(m, b, tune="auto")
        assert res.status == "converged"
        assert not res.info["tune"]["cached"]
    # file restored: the original entry is a hit again
    fresh_process()
    res = repro.solve(m, b, tune="auto")
    assert res.info["tune"]["cached"]


# --------------------------------------------------------- forced rungs
def test_fused_failure_falls_through_to_composed(rng):
    m, b = _spd(rng)
    with faults.fail_strategy("fused"):
        res = repro.solve(m, b, tune="off", fallback="auto")
    assert res.status == "converged"
    ladder = res.info["ladder"]
    assert "error" in ladder[0] and "injected" in ladder[0]["error"]
    assert ladder[-1]["rung"] == "fused->composed"
    assert res.diagnostics["certified"]


def test_fused_failure_with_fallback_off_raises_original(rng):
    m, b = _spd(rng)
    with faults.fail_strategy("fused"):
        with pytest.raises(faults.InjectedFault):
            repro.solve(m, b, tune="off", fallback="off")


def test_kernel_failure_falls_through_to_ref(rng):
    m, b = _spd(rng)
    with faults.fail_kernel_backend():
        res = repro.solve(m, b, tune="off", backend="kernel",
                          fallback="auto")
    assert res.status == "converged"
    rungs = [e["rung"] for e in res.info["ladder"]]
    assert rungs[-1] in ("kernel->ref", "escalate:fresh-x0+jacobi")
    assert any("injected" in e.get("error", "")
               for e in res.info["ladder"][:-1])


def test_all_rungs_fail_raises_solve_failure(rng):
    m, b = _spd(rng)
    with faults.fail_strategy("fused", "composed"):
        with pytest.raises(repro.SolveFailure) as ei:
            repro.solve(m, b, tune="off", fallback="auto")
    assert all("injected" in e["error"] for e in ei.value.ladder)


# --------------------------------------------------------- serve engine
def _engine_setup(rng, **kw):
    from repro.serve.engine import SolveEngine, SolveRequest
    m = M.poisson_2d(12, 12)
    op = operator(m, b_r=32)
    reqs = [SolveRequest(rid=i, b=rng.standard_normal(m.n_rows)
                         .astype(np.float32)) for i in range(4)]
    return SolveEngine(op, slots=4, maxiter=1200, tol=1e-6, **kw), reqs, m


def test_engine_rejects_nonfinite_rhs(rng):
    eng, reqs, _ = _engine_setup(rng)
    reqs[2].b = reqs[2].b.copy()
    reqs[2].b[5] = np.nan
    eng.run(reqs)
    assert reqs[2].status == "rejected"
    assert "non-finite" in reqs[2].diagnostics["reason"]
    assert all(r.status == "converged" for r in reqs if r.rid != 2)


def test_engine_bisects_poisoned_batch(rng):
    """One poisoned column past admission NaNs the whole block-CG Gram;
    bisection must isolate it — the three healthy requests succeed with
    certified answers, only the poisoned one fails, typed."""
    eng, reqs, m = _engine_setup(rng)
    eng._admit = lambda req: True          # let the poison through
    reqs[1].b = reqs[1].b.copy()
    reqs[1].b[3] = np.nan
    eng.run(reqs)
    assert reqs[1].done and reqs[1].status in ("non_finite", "breakdown",
                                               "diverged")
    a = F.csr_to_dense(m).astype(np.float64)
    for r in reqs:
        if r.rid == 1:
            continue
        assert r.status == "converged"
        err = np.linalg.norm(a @ r.x - r.b) / np.linalg.norm(r.b)
        assert err < 1e-4


def test_engine_sheds_expired_deadlines(rng):
    eng, reqs, _ = _engine_setup(rng)
    reqs[0].deadline_s = 0.0               # already expired at run()
    eng.run(reqs)
    assert reqs[0].status == "shed" and reqs[0].x is None
    assert reqs[0].diagnostics["deadline_s"] == 0.0
    assert all(r.status == "converged" for r in reqs[1:])


def test_engine_infrastructure_error_is_typed(rng, monkeypatch):
    eng, reqs, _ = _engine_setup(rng)
    monkeypatch.setattr(
        eng, "_dispatch",
        lambda batch: (_ for _ in ()).throw(RuntimeError("boom")))
    eng.run(reqs[:1])
    assert reqs[0].status == "error"
    assert "boom" in reqs[0].diagnostics["error"]


# --------------------------------------------------------- halo chaos
_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import repro
    from repro.core import formats as F, matrices as M, dist_spmv as D
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh
    from repro.testing import faults

    mesh = make_host_mesh(8)
    m = M.poisson_2d(16, 16)
    rng = np.random.default_rng(0)
    dense = F.csr_to_dense(m).astype(np.float64)
    out = {}

    def padded_b(dist):
        b = np.zeros(dist.n_global_pad, np.float32)
        b[:m.n_rows] = rng.standard_normal(m.n_rows)
        bj = jax.device_put(jnp.asarray(b),
                            jax.NamedSharding(mesh, P("data")))
        return b, bj

    # garble: iterate-dependent corruption breaks linearity -> the
    # detectors or the certification arbiter must catch it in-band
    with faults.garble_halo(scale=1.0):
        op = dist_operator(m, mesh, b_r=32)   # traced under the fault
        b, bj = padded_b(op.dist)
        try:
            res = repro.solve(op, bj, tune="off", fallback="off",
                              maxiter=400)
            out["garble_status"] = res.status
        except Exception as e:
            out["garble_status"] = f"raise:{type(e).__name__}"

    # drop: a consistent wrong operator -- in-band certification is
    # blind to it by construction; out-of-band truth must catch it
    with faults.drop_halo():
        op = dist_operator(m, mesh, b_r=32)
        b, bj = padded_b(op.dist)
        res = repro.solve(op, bj, tune="off", fallback="off", maxiter=400)
        x = np.asarray(res.x, np.float64)[:m.n_rows]
        out["drop_true_rel"] = float(
            np.linalg.norm(dense @ x - b[:m.n_rows])
            / np.linalg.norm(b[:m.n_rows]))
        out["drop_status"] = res.status

    # harness restored the exchange: clean dist solve certifies
    op = dist_operator(m, mesh, b_r=32)
    b, bj = padded_b(op.dist)
    res = repro.solve(op, bj, tune="off", fallback="off", maxiter=2000)
    out["clean_status"] = res.status
    print(json.dumps(out))
""")


@pytest.mark.dist
def test_halo_faults_detected():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _DIST_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    # garbled exchange: typed failure, never a converged claim
    assert out["garble_status"] != "converged"
    # dropped halo: the solve's own operator can't see it (documented
    # detection boundary) -- ground truth must show a large residual
    assert out["drop_true_rel"] > 1e-2
    # and the harness restored the healthy exchange afterwards
    assert out["clean_status"] == "converged"
