"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret mode)."""
import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import formats as F
from repro.kernels import ops


def _mk(rng, n, density, dtype, b_r, diag_align=8):
    a = ((rng.random((n, n)) < density) * rng.standard_normal((n, n))).astype(dtype)
    m = F.csr_from_dense(a)
    return a, m


SWEEP = [
    (128, 0.02, np.float32, 32, 8),
    (256, 0.05, np.float32, 128, 8),
    (256, 0.05, np.float64, 64, 8),
    (384, 0.10, np.float32, 32, 16),
    (130, 0.08, np.float32, 32, 8),   # n not multiple of b_r
]


@pytest.mark.parametrize("n,density,dtype,b_r,diag_align", SWEEP)
def test_pjds_spmv_kernel_vs_ref(rng, n, density, dtype, b_r, diag_align):
    a, m = _mk(rng, n, density, dtype, b_r)
    p = F.csr_to_pjds(m, b_r=b_r, diag_align=diag_align)
    dev = ops.to_device_pjds(p, chunk_l=8)
    x = rng.standard_normal(n).astype(dtype)
    xp = jnp.asarray(p.permute(x))
    y_ref = np.asarray(ops.pjds_matvec(dev, xp, backend="ref"))
    y_ker = np.asarray(ops.pjds_matvec(dev, xp, backend="kernel"))
    np.testing.assert_allclose(y_ker, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(p.unpermute(y_ref.astype(np.float64)),
                               a.astype(np.float64) @ x, atol=1e-3)


@pytest.mark.parametrize("n,density,dtype,b_r,diag_align", SWEEP[:3])
def test_ellr_spmv_kernel_vs_ref(rng, n, density, dtype, b_r, diag_align):
    a, m = _mk(rng, n, density, dtype, b_r)
    e = F.csr_to_ell(m, row_align=128, diag_align=8)
    dev = ops.to_device_ell(e, chunk_l=8, tile_r=128)
    x = np.zeros(e.n_rows_pad, dtype)
    x[:n] = rng.standard_normal(n).astype(dtype)
    y_ref = np.asarray(ops.ell_matvec(dev, jnp.asarray(x), backend="ref"))
    y_ker = np.asarray(ops.ell_matvec(dev, jnp.asarray(x), backend="kernel"))
    np.testing.assert_allclose(y_ker, y_ref, atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(y_ref[:n].astype(np.float64),
                               a.astype(np.float64) @ x[:n], atol=1e-3)


@pytest.mark.parametrize("n_rhs", [128, 256])
@pytest.mark.parametrize("dtype", [np.float32])
def test_pjds_spmm_kernel_vs_ref(rng, n_rhs, dtype):
    a, m = _mk(rng, 192, 0.05, dtype, 64)
    p = F.csr_to_pjds(m, b_r=64)
    dev = ops.to_device_pjds(p)
    x = rng.standard_normal((p.n_rows_pad, n_rhs)).astype(dtype)
    y_ref = np.asarray(ops.pjds_matmat(dev, jnp.asarray(x), backend="ref"))
    y_ker = np.asarray(ops.pjds_matmat(dev, jnp.asarray(x), backend="kernel"))
    np.testing.assert_allclose(y_ker, y_ref, atol=1e-4, rtol=1e-4)


def test_bf16_accumulates_f32(rng):
    a, m = _mk(rng, 128, 0.1, np.float32, 32)
    p = F.csr_to_pjds(m, b_r=32)
    dev = ops.to_device_pjds(p, dtype=jnp.bfloat16)
    x = jnp.asarray(p.permute(rng.standard_normal(128).astype(np.float32))
                    ).astype(jnp.bfloat16)
    y_ref = ops.pjds_matvec(dev, x, backend="ref")
    y_ker = ops.pjds_matvec(dev, x, backend="kernel")
    assert y_ref.dtype == jnp.float32
    assert y_ker.dtype == jnp.float32
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               atol=1e-2, rtol=1e-2)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2 ** 16), n=st.sampled_from([64, 96, 160]),
       density=st.floats(0.02, 0.3))
def test_pjds_kernel_property(seed, n, density):
    rng = np.random.default_rng(seed)
    a = ((rng.random((n, n)) < density) * rng.standard_normal((n, n))
         ).astype(np.float32)
    m = F.csr_from_dense(a)
    p = F.csr_to_pjds(m, b_r=32)
    dev = ops.to_device_pjds(p)
    x = rng.standard_normal(n).astype(np.float32)
    xp = jnp.asarray(p.permute(x))
    y_ker = np.asarray(ops.pjds_matvec(dev, xp, backend="kernel"))
    truth = a.astype(np.float64) @ x
    np.testing.assert_allclose(p.unpermute(y_ker.astype(np.float64)), truth,
                               atol=1e-3)


def test_chunk_l_mismatch_raises(rng):
    _, m = _mk(rng, 64, 0.1, np.float32, 32)
    p = F.csr_to_pjds(m, b_r=32, diag_align=8)
    with pytest.raises(ValueError):
        ops.to_device_pjds(p, chunk_l=16)  # 16 doesn't divide blocks of 8
