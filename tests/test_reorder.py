"""RCM reordering: correctness + halo-width reduction (§Perf sparse-core)."""
import numpy as np
import pytest

from repro.core import formats as F, matrices as M, reorder as R
from repro.core import dist_spmv as D


def test_rcm_is_permutation(rng):
    m = M.samg(scale=0.001)
    perm = R.rcm_permutation(m)
    assert sorted(perm) == list(range(m.n_rows))


def test_permute_symmetric_preserves_spectrum(rng):
    a = (rng.random((60, 60)) < 0.1) * rng.standard_normal((60, 60))
    a = (a + a.T) / 2
    m = F.csr_from_dense(a)
    perm = R.rcm_permutation(m)
    b = R.permute_symmetric(m, perm)
    ev_a = np.sort(np.linalg.eigvalsh(a))
    ev_b = np.sort(np.linalg.eigvalsh(F.csr_to_dense(b)))
    np.testing.assert_allclose(ev_a, ev_b, atol=1e-10)


def test_rcm_reduces_bandwidth(rng):
    # a shuffled banded matrix: RCM should (mostly) recover the band
    n = 400
    base = np.zeros((n, n))
    for off in (-2, -1, 0, 1, 2):
        idx = np.arange(max(0, -off), min(n, n - off))
        base[idx, idx + off] = rng.standard_normal(len(idx))
    shuffle = rng.permutation(n)
    shuffled = base[np.ix_(shuffle, shuffle)]
    m = F.csr_from_dense(shuffled)
    bw0 = R.bandwidth(m)
    perm = R.rcm_permutation(m)
    bw1 = R.bandwidth(R.permute_symmetric(m, perm))
    assert bw1 < bw0 / 10


def test_rcm_shrinks_halo_width(rng):
    """The collective-term lever: RCM reduces the partitioner's halo."""
    n = 512
    base = np.zeros((n, n))
    for off in (-3, -2, -1, 0, 1, 2, 3):
        idx = np.arange(max(0, -off), min(n, n - off))
        base[idx, idx + off] = rng.standard_normal(len(idx))
    shuffle = rng.permutation(n)
    m = F.csr_from_dense(base[np.ix_(shuffle, shuffle)])
    w_before = D.partition_csr(m, 8, b_r=32).halo_w
    perm = R.rcm_permutation(m)
    m2 = R.permute_symmetric(m, perm)
    w_after = D.partition_csr(m2, 8, b_r=32).halo_w
    assert w_after < w_before
    assert w_after == 1
