"""RCM reordering: correctness + halo-width reduction (§Perf sparse-core)."""
import numpy as np
import pytest

from repro.core import formats as F, matrices as M, reorder as R
from repro.core import dist_spmv as D


def test_rcm_is_permutation(rng):
    m = M.samg(scale=0.001)
    perm = R.rcm_permutation(m)
    assert sorted(perm) == list(range(m.n_rows))


def test_permute_symmetric_preserves_spectrum(rng):
    a = (rng.random((60, 60)) < 0.1) * rng.standard_normal((60, 60))
    a = (a + a.T) / 2
    m = F.csr_from_dense(a)
    perm = R.rcm_permutation(m)
    b = R.permute_symmetric(m, perm)
    ev_a = np.sort(np.linalg.eigvalsh(a))
    ev_b = np.sort(np.linalg.eigvalsh(F.csr_to_dense(b)))
    np.testing.assert_allclose(ev_a, ev_b, atol=1e-10)


def test_rcm_reduces_bandwidth(rng):
    # a shuffled banded matrix: RCM should (mostly) recover the band
    n = 400
    base = np.zeros((n, n))
    for off in (-2, -1, 0, 1, 2):
        idx = np.arange(max(0, -off), min(n, n - off))
        base[idx, idx + off] = rng.standard_normal(len(idx))
    shuffle = rng.permutation(n)
    shuffled = base[np.ix_(shuffle, shuffle)]
    m = F.csr_from_dense(shuffled)
    bw0 = R.bandwidth(m)
    perm = R.rcm_permutation(m)
    bw1 = R.bandwidth(R.permute_symmetric(m, perm))
    assert bw1 < bw0 / 10


def test_rcm_shrinks_halo_width(rng):
    """The collective-term lever: RCM reduces the partitioner's halo."""
    n = 512
    base = np.zeros((n, n))
    for off in (-3, -2, -1, 0, 1, 2, 3):
        idx = np.arange(max(0, -off), min(n, n - off))
        base[idx, idx + off] = rng.standard_normal(len(idx))
    shuffle = rng.permutation(n)
    m = F.csr_from_dense(base[np.ix_(shuffle, shuffle)])
    w_before = D.partition_csr(m, 8, b_r=32).halo_w
    perm = R.rcm_permutation(m)
    m2 = R.permute_symmetric(m, perm)
    w_after = D.partition_csr(m2, 8, b_r=32).halo_w
    assert w_after < w_before
    assert w_after == 1


def test_bandwidth_exported():
    # the bugfix: bandwidth() is part of the module's public surface
    assert "bandwidth" in R.__all__
    assert R.bandwidth(M.poisson_2d(8, 8)) > 0


def test_permutation_convention_documented_and_consistent(rng):
    """perm[k] = old index at new position k — the ONE convention both
    rcm_permutation and permute_symmetric use (the docstring bugfix)."""
    m = M.poisson_2d(8, 8)
    perm = R.rcm_permutation(m)
    b = R.permute_symmetric(m, perm)
    a = F.csr_to_dense(m)
    np.testing.assert_array_equal(F.csr_to_dense(b),
                                  a[np.ix_(perm, perm)])
    assert "perm[k]" in R.rcm_permutation.__doc__


def test_permute_symmetric_rejects_non_square():
    d = np.zeros((4, 6))
    d[0, 1] = 1.0
    m = F.csr_from_dense(d)
    with pytest.raises(ValueError, match="square"):
        R.permute_symmetric(m, np.arange(4))


def test_permute_symmetric_rejects_bad_perm_length(rng):
    m = M.poisson_2d(6, 6)
    with pytest.raises(ValueError, match="perm"):
        R.permute_symmetric(m, np.arange(m.n_rows - 1))


def test_permute_symmetric_output_is_valid_csr(rng):
    """The sum_duplicates=False path must still produce sorted,
    duplicate-free rows (the audited invariant of csr_from_coo)."""
    m = M.samg(scale=0.002)
    perm = rng.permutation(m.n_rows)
    b = R.permute_symmetric(m, perm)
    _, report = F.validate_csr(b)          # raises on any violation
    assert not report.issues
