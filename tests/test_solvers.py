"""Krylov solvers on single-device pJDS operators."""
import numpy as np
import jax.numpy as jnp

from repro.core import formats as F, matrices as M, solvers as S
from repro.kernels import ops


def _op(m, b_r=32):
    p = F.csr_to_pjds(m, b_r=b_r)
    dev = ops.to_device_pjds(p)
    return p, (lambda x: ops.pjds_matvec(dev, x))


def test_cg_poisson(rng):
    m = M.poisson_2d(20, 20)
    p, mv = _op(m)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    res = S.cg(mv, jnp.asarray(p.permute(b)), maxiter=1500, tol=1e-7)
    x = p.unpermute(np.asarray(res.x))
    r = np.linalg.norm(F.csr_to_dense(m) @ x - b) / np.linalg.norm(b)
    assert r < 1e-4


def test_cg_on_samg_matrix(rng):
    m = M.samg(scale=0.0005)            # small SPD-shifted AMG analogue
    p, mv = _op(m)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    res = S.cg(mv, jnp.asarray(p.permute(b)), maxiter=3000, tol=1e-6)
    assert float(res.residual) < 1e-4


def test_lanczos_extremal_eigenvalue(rng):
    m = M.poisson_2d(16, 16)
    p, mv = _op(m)
    v0 = jnp.asarray(p.permute(rng.standard_normal(m.n_rows).astype(np.float32)))
    al, be = S.lanczos(mv, v0, m=60)
    ev = S.tridiag_eigvals(al, be)
    dense_ev = np.linalg.eigvalsh(F.csr_to_dense(m))
    assert abs(ev.max() - dense_ev.max()) < 1e-3 * abs(dense_ev.max())


def test_power_iteration(rng):
    m = M.poisson_2d(12, 12)
    p, mv = _op(m)
    v0 = jnp.asarray(p.permute(np.ones(m.n_rows, np.float32)))
    _, lam = S.power_iteration(mv, v0, iters=500)
    dense_ev = np.linalg.eigvalsh(F.csr_to_dense(m))
    assert abs(float(lam) - dense_ev.max()) < 1e-2 * abs(dense_ev.max())


def test_hmep_hamiltonian_lanczos(rng):
    """The paper's HMEp use case: extremal eigenvalue of a (symmetrised)
    Holstein-Hubbard-like Hamiltonian via Lanczos over pJDS spMVM."""
    m = M.hmep(scale=0.0002)
    # symmetrise: (A + A^T)/2 so Lanczos applies
    d = F.csr_to_dense(m)
    d = (d + d.T) / 2
    m = F.csr_from_dense(d)
    p, mv = _op(m)
    v0 = jnp.asarray(p.permute(rng.standard_normal(m.n_rows).astype(np.float32)))
    al, be = S.lanczos(mv, v0, m=80)
    ev = S.tridiag_eigvals(al, be)
    dense_ev = np.linalg.eigvalsh(d)
    assert abs(ev.max() - dense_ev.max()) < 5e-3 * max(abs(dense_ev).max(), 1)
