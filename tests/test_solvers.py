"""Krylov solvers on single-device pJDS operators."""
import numpy as np
import jax.numpy as jnp

from repro.core import formats as F, matrices as M, solvers as S
from repro.kernels import ops


def _op(m, b_r=32):
    p = F.csr_to_pjds(m, b_r=b_r)
    dev = ops.to_device_pjds(p)
    return p, (lambda x: ops.pjds_matvec(dev, x))


def _block_op(m, b_r=32):
    p = F.csr_to_pjds(m, b_r=b_r)
    dev = ops.to_device_pjds(p)
    return p, (lambda x: ops.pjds_matmat(dev, x))


def _permute_cols(p, a):
    return np.stack([p.permute(a[:, j]) for j in range(a.shape[1])], axis=1)


def test_cg_poisson(rng):
    m = M.poisson_2d(20, 20)
    p, mv = _op(m)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    res = S.cg(mv, jnp.asarray(p.permute(b)), maxiter=1500, tol=1e-7)
    x = p.unpermute(np.asarray(res.x))
    r = np.linalg.norm(F.csr_to_dense(m) @ x - b) / np.linalg.norm(b)
    assert r < 1e-4


def test_cg_on_samg_matrix(rng):
    m = M.samg(scale=0.0005)            # small SPD-shifted AMG analogue
    p, mv = _op(m)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    res = S.cg(mv, jnp.asarray(p.permute(b)), maxiter=3000, tol=1e-6)
    assert float(res.residual) < 1e-4


def test_lanczos_extremal_eigenvalue(rng):
    m = M.poisson_2d(16, 16)
    p, mv = _op(m)
    v0 = jnp.asarray(p.permute(rng.standard_normal(m.n_rows).astype(np.float32)))
    al, be = S.lanczos(mv, v0, m=60)
    ev = S.tridiag_eigvals(al, be)
    dense_ev = np.linalg.eigvalsh(F.csr_to_dense(m))
    assert abs(ev.max() - dense_ev.max()) < 1e-3 * abs(dense_ev.max())


def test_power_iteration(rng):
    m = M.poisson_2d(12, 12)
    p, mv = _op(m)
    v0 = jnp.asarray(p.permute(np.ones(m.n_rows, np.float32)))
    _, lam = S.power_iteration(mv, v0, iters=500)
    dense_ev = np.linalg.eigvalsh(F.csr_to_dense(m))
    assert abs(float(lam) - dense_ev.max()) < 1e-2 * abs(dense_ev.max())


def test_block_cg_matches_dense_solve(rng):
    """Block-CG over the multi-RHS pJDS operator solves all k systems."""
    m = M.poisson_2d(20, 20)
    p, mm = _block_op(m)
    k = 4
    b = rng.standard_normal((m.n_rows, k)).astype(np.float32)
    res = S.block_cg(mm, jnp.asarray(_permute_cols(p, b)),
                     maxiter=1500, tol=1e-7)
    assert float(np.max(np.asarray(res.residual))) < 1e-5
    x = np.stack([p.unpermute(np.asarray(res.x)[:, j]) for j in range(k)],
                 axis=1)
    r = np.linalg.norm(F.csr_to_dense(m) @ x - b) / np.linalg.norm(b)
    assert r < 1e-4


def test_block_cg_fewer_iters_than_scalar_cg(rng):
    """The block Krylov space is richer: block-CG needs fewer iterations
    (i.e. fewer matrix streams) than any of the k scalar solves."""
    m = M.poisson_2d(16, 16)
    p, mm = _block_op(m)
    _, mv = _op(m)
    b = rng.standard_normal((m.n_rows, 4)).astype(np.float32)
    res_blk = S.block_cg(mm, jnp.asarray(_permute_cols(p, b)),
                         maxiter=800, tol=1e-6)
    res_0 = S.cg(mv, jnp.asarray(p.permute(b[:, 0])), maxiter=800, tol=1e-6)
    assert int(res_blk.iters) < int(res_0.iters)


def test_block_lanczos_extremal_eigenvalue(rng):
    m = M.poisson_2d(16, 16)
    p, mm = _block_op(m)
    v0 = rng.standard_normal((m.n_rows, 4)).astype(np.float32)
    al, be = S.block_lanczos(mm, jnp.asarray(_permute_cols(p, v0)), m=20)
    assert al.shape == (20, 4, 4) and be.shape == (20, 4, 4)
    ev = S.block_tridiag_eigvals(al, be)
    dense_ev = np.linalg.eigvalsh(F.csr_to_dense(m))
    assert abs(ev.max() - dense_ev.max()) < 1e-3 * abs(dense_ev.max())


def test_hmep_hamiltonian_lanczos(rng):
    """The paper's HMEp use case: extremal eigenvalue of a (symmetrised)
    Holstein-Hubbard-like Hamiltonian via Lanczos over pJDS spMVM."""
    m = M.hmep(scale=0.0002)
    # symmetrise: (A + A^T)/2 so Lanczos applies
    d = F.csr_to_dense(m)
    d = (d + d.T) / 2
    m = F.csr_from_dense(d)
    p, mv = _op(m)
    v0 = jnp.asarray(p.permute(rng.standard_normal(m.n_rows).astype(np.float32)))
    al, be = S.lanczos(mv, v0, m=80)
    ev = S.tridiag_eigvals(al, be)
    dense_ev = np.linalg.eigvalsh(d)
    assert abs(ev.max() - dense_ev.max()) < 5e-3 * max(abs(dense_ev).max(), 1)
