"""Krylov solvers on single-device pJDS operators."""
import numpy as np
import jax.numpy as jnp

from repro.core import formats as F, matrices as M, solvers as S
from repro.kernels import ops


def _op(m, b_r=32):
    p = F.csr_to_pjds(m, b_r=b_r)
    dev = ops.to_device_pjds(p)
    return p, (lambda x: ops.pjds_matvec(dev, x))


def _block_op(m, b_r=32):
    p = F.csr_to_pjds(m, b_r=b_r)
    dev = ops.to_device_pjds(p)
    return p, (lambda x: ops.pjds_matmat(dev, x))


def _permute_cols(p, a):
    return np.stack([p.permute(a[:, j]) for j in range(a.shape[1])], axis=1)


def test_cg_poisson(rng):
    m = M.poisson_2d(20, 20)
    p, mv = _op(m)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    res = S.cg(mv, jnp.asarray(p.permute(b)), maxiter=1500, tol=1e-7)
    x = p.unpermute(np.asarray(res.x))
    r = np.linalg.norm(F.csr_to_dense(m) @ x - b) / np.linalg.norm(b)
    assert r < 1e-4


def test_cg_on_samg_matrix(rng):
    m = M.samg(scale=0.0005)            # small SPD-shifted AMG analogue
    p, mv = _op(m)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    res = S.cg(mv, jnp.asarray(p.permute(b)), maxiter=3000, tol=1e-6)
    assert float(res.residual) < 1e-4


def test_lanczos_extremal_eigenvalue(rng):
    m = M.poisson_2d(16, 16)
    p, mv = _op(m)
    v0 = jnp.asarray(p.permute(rng.standard_normal(m.n_rows).astype(np.float32)))
    al, be = S.lanczos(mv, v0, m=60)
    ev = S.tridiag_eigvals(al, be)
    dense_ev = np.linalg.eigvalsh(F.csr_to_dense(m))
    assert abs(ev.max() - dense_ev.max()) < 1e-3 * abs(dense_ev.max())


def test_power_iteration(rng):
    m = M.poisson_2d(12, 12)
    p, mv = _op(m)
    v0 = jnp.asarray(p.permute(np.ones(m.n_rows, np.float32)))
    _, lam = S.power_iteration(mv, v0, iters=500)
    dense_ev = np.linalg.eigvalsh(F.csr_to_dense(m))
    assert abs(float(lam) - dense_ev.max()) < 1e-2 * abs(dense_ev.max())


def test_block_cg_matches_dense_solve(rng):
    """Block-CG over the multi-RHS pJDS operator solves all k systems."""
    m = M.poisson_2d(20, 20)
    p, mm = _block_op(m)
    k = 4
    b = rng.standard_normal((m.n_rows, k)).astype(np.float32)
    res = S.block_cg(mm, jnp.asarray(_permute_cols(p, b)),
                     maxiter=1500, tol=1e-7)
    assert float(np.max(np.asarray(res.residual))) < 1e-5
    x = np.stack([p.unpermute(np.asarray(res.x)[:, j]) for j in range(k)],
                 axis=1)
    r = np.linalg.norm(F.csr_to_dense(m) @ x - b) / np.linalg.norm(b)
    assert r < 1e-4


def test_block_cg_fewer_iters_than_scalar_cg(rng):
    """The block Krylov space is richer: block-CG needs fewer iterations
    (i.e. fewer matrix streams) than any of the k scalar solves."""
    m = M.poisson_2d(16, 16)
    p, mm = _block_op(m)
    _, mv = _op(m)
    b = rng.standard_normal((m.n_rows, 4)).astype(np.float32)
    res_blk = S.block_cg(mm, jnp.asarray(_permute_cols(p, b)),
                         maxiter=800, tol=1e-6)
    res_0 = S.cg(mv, jnp.asarray(p.permute(b[:, 0])), maxiter=800, tol=1e-6)
    assert int(res_blk.iters) < int(res_0.iters)


def test_block_lanczos_extremal_eigenvalue(rng):
    m = M.poisson_2d(16, 16)
    p, mm = _block_op(m)
    v0 = rng.standard_normal((m.n_rows, 4)).astype(np.float32)
    al, be = S.block_lanczos(mm, jnp.asarray(_permute_cols(p, v0)), m=20)
    assert al.shape == (20, 4, 4) and be.shape == (20, 4, 4)
    ev = S.block_tridiag_eigvals(al, be)
    dense_ev = np.linalg.eigvalsh(F.csr_to_dense(m))
    assert abs(ev.max() - dense_ev.max()) < 1e-3 * abs(dense_ev.max())


def test_hmep_hamiltonian_lanczos(rng):
    """The paper's HMEp use case: extremal eigenvalue of a (symmetrised)
    Holstein-Hubbard-like Hamiltonian via Lanczos over pJDS spMVM."""
    m = M.hmep(scale=0.0002)
    # symmetrise: (A + A^T)/2 so Lanczos applies
    d = F.csr_to_dense(m)
    d = (d + d.T) / 2
    m = F.csr_from_dense(d)
    p, mv = _op(m)
    v0 = jnp.asarray(p.permute(rng.standard_normal(m.n_rows).astype(np.float32)))
    al, be = S.lanczos(mv, v0, m=80)
    ev = S.tridiag_eigvals(al, be)
    dense_ev = np.linalg.eigvalsh(d)
    assert abs(ev.max() - dense_ev.max()) < 5e-3 * max(abs(dense_ev).max(), 1)

# --------------------------------------------------------------------------
# repro.solve front door: fused/composed parity, refinement, the result
# contract, and the distributed leg of the parity grid
# --------------------------------------------------------------------------
import json
import os
import subprocess
import sys
import textwrap

import pytest

import repro
from repro import api
from repro.core.operator import operator

# 17x19 grids: 323 rows, not divisible by any tile height — both the
# fused kernel's slab epilogue and the composed path must mask the ragged
# tail identically
_PARITY_CASES = [
    ("cg", lambda: M.poisson_2d(17, 19)),
    ("bicgstab", lambda: M.convection_poisson(17, 19, beta=0.4)),
]


def _true_residual(m, x, b):
    d = F.csr_to_dense(m).astype(np.float64)
    return float(np.linalg.norm(d @ np.asarray(x, np.float64) - b)
                 / np.linalg.norm(b))


@pytest.mark.parametrize("method,mk", _PARITY_CASES,
                         ids=[c[0] for c in _PARITY_CASES])
def test_fused_composed_parity_device(method, mk, rng):
    """The fused spMV+dots iteration and the composed operator body are
    the same algorithm: same convergence, same solution."""
    m = mk()
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    bj = jnp.asarray(b)
    op = operator(m, format="sell", x_tiles=1)
    fused = api._one_solve(op, bj, method=method, strategy="fused",
                           maxiter=3000, tol=1e-7, precond=None)
    comp = api._one_solve(op, bj, method=method, strategy="composed",
                          maxiter=3000, tol=1e-7, precond=None)
    assert fused.info["strategy"] == "fused"
    assert comp.info["strategy"] == "composed"
    assert _true_residual(m, fused.x, b) < 1e-5
    assert _true_residual(m, comp.x, b) < 1e-5
    scale = max(np.abs(np.asarray(comp.x)).max(), 1e-30)
    assert np.abs(np.asarray(fused.x) - np.asarray(comp.x)).max() \
        / scale < 1e-4


@pytest.mark.parametrize("method,mk", _PARITY_CASES,
                         ids=[c[0] for c in _PARITY_CASES])
def test_refined_bf16_matches_f32_device(method, mk, rng):
    """bf16 inner iterations + f32 residual correction land on the same
    answer as the all-f32 solve, at the same tolerance."""
    m = mk()
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    bj = jnp.asarray(b)
    r32 = repro.solve(m, bj, method=method, tol=1e-6, maxiter=3000,
                      tune="off", refine=False)
    rref = repro.solve(m, bj, method=method, tol=1e-6, maxiter=3000,
                       tune="off", dtype=jnp.bfloat16, refine="auto")
    assert bool(r32.converged) and bool(rref.converged)
    assert _true_residual(m, r32.x, b) < 1e-5
    assert _true_residual(m, rref.x, b) < 1e-5
    rounds = rref.info["refine"]["rounds"]
    assert len(rounds) >= 1
    assert rref.info["refine"]["inner_dtype"] == "bfloat16"


def test_solve_result_contract(rng):
    """Every method returns the SAME result type with the same fields
    populated — the point of collapsing the per-solver NamedTuples."""
    m = M.poisson_2d(12, 14)                 # 168 rows, also non-divisible
    b1 = jnp.asarray(rng.standard_normal(m.n_rows).astype(np.float32))
    bk = jnp.asarray(rng.standard_normal((m.n_rows, 3)).astype(np.float32))
    for method, rhs in (("cg", b1), ("bicgstab", b1), ("block_cg", bk)):
        res = repro.solve(m, rhs, method=method, tol=1e-6, maxiter=2000,
                          tune="off", refine=False)
        assert isinstance(res, S.SolveResult)
        assert res.method == method
        assert res.x.shape == rhs.shape
        # residual: scalar for 1-D solves (possibly a certified host
        # float from the fused driver), per-column (k,) for block_cg
        assert np.shape(res.residual) == (() if rhs.ndim == 1 else (3,))
        assert bool(res.converged)
        assert 0 < int(res.iters) <= 2000
        assert res.info["strategy"] in ("fused", "composed")
        assert {"tune", "build", "solve"} <= set(res.info["phase_s"])


def test_solve_rejects_bad_arguments(rng):
    m = M.poisson_2d(8, 8)
    b = jnp.asarray(rng.standard_normal(m.n_rows).astype(np.float32))
    with pytest.raises(ValueError, match="method"):
        repro.solve(m, b, method="gmres")
    with pytest.raises(ValueError, match="shape"):
        repro.solve(m, b, method="block_cg")
    with pytest.raises(ValueError, match="refine"):
        repro.solve(m, jnp.stack([b, b], axis=1), method="block_cg",
                    refine=True)
    with pytest.raises(ValueError, match="closure"):
        op = operator(m)
        repro.solve(op.matvec, b, refine=True)


_DIST_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import repro
    from repro.core import formats as F, matrices as M, dist_spmv as D
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh

    out = {}
    mesh = make_host_mesh(8)
    rng = np.random.default_rng(0)
    cases = [("cg", M.poisson_2d(17, 19)),
             ("bicgstab", M.convection_poisson(17, 19, beta=0.4))]
    for method, m in cases:
        dist = D.partition_csr(m, 8, b_r=32)
        b = np.zeros(dist.n_global_pad, np.float32)
        b[:m.n_rows] = rng.standard_normal(m.n_rows)
        bj = jax.device_put(jnp.asarray(b),
                            jax.NamedSharding(mesh, P("data")))
        op = dist_operator(dist, mesh, mode="overlap")
        dense = F.csr_to_dense(m).astype(np.float64)
        bn = np.linalg.norm(b[:m.n_rows])
        res = repro.solve(op, bj, method=method, maxiter=4000, tol=1e-6)
        x = np.asarray(res.x, np.float64)[:m.n_rows]
        out[f"{method}_true"] = float(
            np.linalg.norm(dense @ x - b[:m.n_rows]) / bn)
        out[f"{method}_strategy"] = res.info["strategy"]
        resr = repro.solve(op, bj, method=method, maxiter=4000, tol=1e-6,
                           refine=True)
        xr = np.asarray(resr.x, np.float64)[:m.n_rows]
        out[f"{method}_true_refined"] = float(
            np.linalg.norm(dense @ xr - b[:m.n_rows]) / bn)
        out[f"{method}_rounds"] = len(resr.info["refine"]["rounds"])
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def dist_solve_results():
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _DIST_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


@pytest.mark.dist
@pytest.mark.parametrize("method", ["cg", "bicgstab"])
def test_solve_distributed_parity(dist_solve_results, method):
    """The Dist column of the parity grid: repro.solve over the mesh
    operator (composed strategy — fused is single-device) reaches the
    f32 tolerance, plain and bf16-refined."""
    out = dist_solve_results
    assert out[f"{method}_strategy"] == "composed"
    assert out[f"{method}_true"] < 1e-5
    assert out[f"{method}_true_refined"] < 1e-5
    assert out[f"{method}_rounds"] >= 1


# --------------------------------------------------------------------------
# Failure taxonomy: breakdown detection and the NaN-masking regression
# --------------------------------------------------------------------------
def _csr_op(a):
    return operator(F.csr_from_dense(np.asarray(a, np.float32)), b_r=32)


def _singular(rng, n=24):
    """Rank-deficient PSD: B B^T with a thin B — random b is outside the
    range, so the Krylov recurrence must break down, not converge."""
    bm = rng.standard_normal((n, n // 2))
    return bm @ bm.T


def _indefinite(rng, n=24):
    a = rng.standard_normal((n, n))
    a = (a + a.T) / 2
    np.fill_diagonal(a, np.linspace(-2.0, 2.0, n))   # eigenvalues both signs
    return a


def _skew(rng, n=24):
    a = rng.standard_normal((n, n))
    return a - a.T                                    # x^T A x == 0 for all x


def test_nan_residual_is_not_converged_composed(rng):
    """Regression: a NaN residual must flag non_finite, never satisfy
    the convergence predicate (NaN > tol*tol is False — the old
    ``_not_done`` read that as done)."""
    n = 24
    a = np.eye(n)
    a[3, 3] = np.nan
    op = _csr_op(a)
    b = rng.standard_normal(n).astype(np.float32)
    res = S.cg(op, b, maxiter=50, tol=1e-6)
    assert res.status == "non_finite"
    assert not bool(res.converged)
    res = S.bicgstab(op, b, maxiter=50, tol=1e-6)
    assert res.status == "non_finite"
    assert not bool(res.converged)


def test_nan_residual_is_not_converged_fused(rng):
    m = M.poisson_2d(6, 6)
    data = np.asarray(m.data)
    saved = data[0]
    data[0] = np.nan
    try:
        ops.clear_device_cache()
        res = api.solve(m, rng.standard_normal(m.n_rows).astype(np.float32),
                        tune="off", fallback="off")
    finally:
        data[0] = saved
        ops.clear_device_cache()
    assert res.info["strategy"] == "fused"
    assert res.status == "non_finite"
    assert not bool(res.converged)


def test_probe_contract_ignores_failure_detection(rng):
    """tol <= 0 is the tuner/bench fixed-length probe: it must run to
    exactly maxiter with no breakdown/divergence exits."""
    op = _csr_op(_indefinite(rng))
    b = rng.standard_normal(24).astype(np.float32)
    res = S.cg(op, b, maxiter=37, tol=0.0)
    assert int(res.iters) == 37
    assert res.status == "maxiter"


@pytest.mark.parametrize("mk,expected", [
    (_singular, ("breakdown", "diverged")),
    (_indefinite, ("breakdown", "diverged")),
    (_skew, ("breakdown",)),
])
def test_cg_breakdown_taxonomy(mk, expected, rng):
    a = mk(rng)
    op = _csr_op(a)
    b = rng.standard_normal(a.shape[0]).astype(np.float32)
    res = S.cg(op, b, maxiter=500, tol=1e-8)
    assert res.status in expected, res.status
    assert not bool(res.converged)
    assert np.all(np.isfinite(np.asarray(res.x)))


@pytest.mark.parametrize("mk", [_singular, _skew])
def test_bicgstab_breakdown_taxonomy(mk, rng):
    a = mk(rng)
    op = _csr_op(a)
    b = rng.standard_normal(a.shape[0]).astype(np.float32)
    res = S.bicgstab(op, b, maxiter=500, tol=1e-8)
    # typed, never a false converged claim
    assert res.status in ("breakdown", "diverged", "non_finite", "maxiter")
    if res.status == "maxiter":
        assert float(res.residual) > 1e-8


@pytest.mark.parametrize("mk", [_singular, _indefinite, _skew])
def test_block_cg_breakdown_taxonomy(mk, rng):
    a = mk(rng)
    op = _csr_op(a)
    b = rng.standard_normal((a.shape[0], 3)).astype(np.float32)
    res = S.block_cg(op, b, maxiter=500, tol=1e-8)
    assert res.status in ("breakdown", "diverged", "non_finite")
    assert not bool(res.converged)
    assert np.all(np.isfinite(np.asarray(res.x)))


def test_breakdown_statuses_survive_solve_front_door(rng):
    """repro.solve with the ladder: an indefinite system fails every
    rung and surfaces a typed SolveFailure whose ladder names them."""
    a = _indefinite(rng)
    m = F.csr_from_dense(np.asarray(a, np.float32))
    b = rng.standard_normal(a.shape[0]).astype(np.float32)
    with pytest.raises(repro.SolveFailure) as ei:
        repro.solve(m, b, tune="off", maxiter=500)
    assert all(e.get("status") in ("breakdown", "diverged", "non_finite")
               for e in ei.value.ladder)


_DIST_BREAKDOWN_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import numpy as np, jax, jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    import repro
    from repro.core import formats as F
    from repro.core.operator import dist_operator
    from repro.launch.mesh import make_host_mesh

    mesh = make_host_mesh(8)
    rng = np.random.default_rng(0)
    n = 96
    bm = rng.standard_normal((n, n // 2))
    m = F.csr_from_dense((bm @ bm.T).astype(np.float32))
    op = dist_operator(m, mesh, b_r=8)
    b = np.zeros(op.dist.n_global_pad, np.float32)
    b[:n] = rng.standard_normal(n)
    bj = jax.device_put(jnp.asarray(b), jax.NamedSharding(mesh, P("data")))
    res = repro.solve(op, bj, maxiter=500, tol=1e-8, tune="off",
                      fallback="off")
    print(json.dumps({"status": res.status,
                      "converged": bool(res.converged)}))
""")


@pytest.mark.dist
def test_breakdown_detected_on_dist_operator():
    """The same breakdown taxonomy holds through the mesh-distributed
    operator (singular PSD system, rows padded and sharded)."""
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _DIST_BREAKDOWN_SCRIPT],
                       capture_output=True, text=True, env=env, timeout=560)
    assert r.returncode == 0, r.stderr[-3000:]
    out = json.loads(r.stdout.strip().splitlines()[-1])
    assert out["status"] in ("breakdown", "diverged")
    assert not out["converged"]


# ---------------------------------------------------------------------------
# Refinement divergence guard: a stalled or poisoned refinement is a
# typed failure the ladder escalates off, not max_rounds of nothing
# ---------------------------------------------------------------------------
def test_refinement_guard_reason_codes():
    b = jnp.ones(8, jnp.float32)
    residual_of = lambda x: b - x           # A = I

    x, rn, rounds, reason = S.iterative_refinement(
        residual_of, lambda r: (r, 1, 0.0), b)
    assert reason == "converged" and rn <= 1e-6

    # a zero correction leaves the residual exactly where it was: one
    # wasted round, then the guard calls it, not max_rounds of them
    x, rn, rounds, reason = S.iterative_refinement(
        residual_of, lambda r: (jnp.zeros_like(r), 1, 1.0), b)
    assert reason == "stalled" and len(rounds) == 1

    x, rn, rounds, reason = S.iterative_refinement(
        residual_of, lambda r: (jnp.full_like(r, jnp.nan), 1, 1.0), b)
    assert reason == "non_finite"


def test_refined_stall_is_typed_and_escalates_to_f32(rng, monkeypatch):
    import repro
    m = M.poisson_2d(8, 8)
    b = rng.standard_normal(m.n_rows).astype(np.float32)
    orig = S.iterative_refinement

    def stalling(residual_of, inner, b_, **kw):
        # the inner solve never improves anything — the way a matrix
        # too ill-conditioned for bf16 values surfaces
        return orig(residual_of,
                    lambda r: (jnp.zeros_like(r), 1, 1.0), b_, **kw)

    monkeypatch.setattr(S, "iterative_refinement", stalling)

    res = repro.solve(m, b, dtype="bfloat16", refine="auto", tune="off",
                      fallback="off")
    assert res.status == "diverged"
    assert res.diagnostics["refine_reason"] == "stalled"

    res = repro.solve(m, b, dtype="bfloat16", refine="auto", tune="off",
                      fallback="auto")
    assert res.status == "converged"
    assert res.diagnostics["certified"]
    entries = {e["rung"]: e.get("status") for e in res.info["ladder"]}
    assert entries.get("bf16->f32") == "converged"
    assert all(s == "diverged" for r, s in entries.items()
               if r != "bf16->f32")
