"""Checkpoint store: atomic commit, async save, resume, elastic reshard."""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.checkpoint import store


def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "a": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
        "nested": {"b": jnp.asarray(rng.integers(0, 9, (4,)), jnp.int32)},
    }


def test_save_restore_roundtrip(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 7, t, extra={"data": {"seed": 1, "step": 7}})
    assert store.latest_step(str(tmp_path)) == 7
    restored, extra = store.restore(str(tmp_path), 7, t)
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["data"]["step"] == 7


def test_latest_ignores_uncommitted(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 3, t)
    # fake a torn write
    torn = tmp_path / "step_0000000009"
    torn.mkdir()
    (torn / "manifest.json").write_text("{}")
    assert store.latest_step(str(tmp_path)) == 3


def test_async_save(tmp_path):
    t = _tree()
    ck = store.AsyncCheckpointer()
    ck.save(str(tmp_path), 5, t)
    ck.wait()
    assert store.latest_step(str(tmp_path)) == 5


def test_leaf_count_mismatch_raises(tmp_path):
    t = _tree()
    store.save(str(tmp_path), 1, t)
    with pytest.raises(ValueError):
        store.restore(str(tmp_path), 1, {"only": t["a"]})


@pytest.mark.dist
def test_elastic_reshard_subprocess(tmp_path):
    """Save on an 8-device mesh, restore onto a 4-device mesh — the
    node-failure recovery path."""
    script = textwrap.dedent(f"""
        import os
        os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
        import numpy as np, jax, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.checkpoint import store
        from repro.launch.mesh import make_host_mesh
        mesh = make_host_mesh(8)
        x = jnp.arange(64.0).reshape(8, 8)
        xs = jax.device_put(x, NamedSharding(mesh, P("data", None)))
        store.save(r"{tmp_path}", 2, {{"x": xs}})

        mesh4 = make_host_mesh(4)
        sh = {{"x": NamedSharding(mesh4, P("data", None))}}
        restored, _ = store.restore(r"{tmp_path}", 2, {{"x": x}}, shardings=sh)
        assert restored["x"].sharding.num_devices == 4
        np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
        print("ELASTIC_OK")
    """)
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..", "src"))
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", script], capture_output=True,
                       text=True, env=env, timeout=300)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "ELASTIC_OK" in r.stdout


def test_data_pipeline_determinism_and_state():
    from repro.data.pipeline import SyntheticLM
    d1 = SyntheticLM(vocab=100, batch=2, seq=8, seed=3)
    batches = [d1.next() for _ in range(4)]
    d2 = SyntheticLM(vocab=100, batch=2, seq=8, seed=3)
    d2.load_state_dict({"seed": 3, "step": 2})
    resumed = d2.next()
    np.testing.assert_array_equal(batches[2]["tokens"], resumed["tokens"])
    np.testing.assert_array_equal(batches[2]["labels"], resumed["labels"])


def test_labels_are_shifted_tokens():
    from repro.data.pipeline import SyntheticLM
    d = SyntheticLM(vocab=50, batch=1, seq=16, seed=0)
    b = d.next()
    np.testing.assert_array_equal(b["tokens"][0, 1:], b["labels"][0, :-1])
